//! End-to-end driver (the repo's flagship experiment): run the trained,
//! quantized sentiment SNN through the *macro simulator pool*, prove
//! all three layers compose (optional XLA cross-check), and regenerate
//! Fig 9(b), Fig 10, and Fig 11(a).
//!
//!     cargo run --release --example sentiment_e2e [-- --max 200 --xla-check --trace]
//!
//! Requires `make artifacts`.

use impulse::coordinator::{InferenceServer, Request};
use impulse::data::{artifacts_available, artifacts_dir, Manifest, SentimentArtifacts};
use impulse::energy::EnergyModel;
use impulse::macro_sim::MacroConfig;
use impulse::metrics::eng;
use impulse::snn::SentimentNetwork;
use impulse::{NOMINAL_FREQ_HZ, NOMINAL_VDD};
use std::sync::Arc;
use std::time::Instant;

fn flag(name: &str) -> bool {
    std::env::args().any(|a| a == name)
}

fn flag_val(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> impulse::Result<()> {
    if !artifacts_available() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }
    let dir = artifacts_dir();
    let a = Arc::new(SentimentArtifacts::load(&dir)?);
    let man = Manifest::read(dir.join("manifest.txt"))?;
    let max: usize = flag_val("--max")
        .and_then(|v| v.parse().ok())
        .unwrap_or(a.test_seqs.len());
    let n = max.min(a.test_seqs.len());
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(12);

    println!("== IMPULSE sentiment e2e (Fig 9b / 10 / 11a) ==");
    println!(
        "model: 100→128→128→1 RMP SNN, {} params, 6-bit W / 11-bit V_MEM",
        man.get("snn_sentiment_params").unwrap_or("?")
    );

    // ---------------- Fig 9b: accuracy vs LSTM ----------------
    let mac = MacroConfig::fast();
    let a2 = Arc::clone(&a);
    let server = InferenceServer::start(workers, move || {
        SentimentNetwork::from_artifacts(&a2, mac)
    })?;
    let t0 = Instant::now();
    let reqs: Vec<Request> = (0..n)
        .map(|i| Request::words(i as u64, a.test_seqs[i].clone()))
        .collect();
    let (responses, _stats) = server.run_batch(reqs)?;
    let wall = t0.elapsed();
    server.shutdown();
    let correct = responses
        .iter()
        .filter(|r| r.pred == a.test_labels[r.id as usize])
        .count();
    let acc = correct as f64 / n as f64;

    println!("\n-- Fig 9b: accuracy & parameters --");
    println!("SNN on IMPULSE macro pool : {acc:.4} ({correct}/{n})");
    println!(
        "python int reference       : {}",
        man.get("snn_sentiment_quant_acc").unwrap_or("?")
    );
    println!(
        "float SNN                  : {}",
        man.get("snn_sentiment_float_acc").unwrap_or("?")
    );
    let lstm_p = man.get_f64("lstm_params").unwrap_or(0.0);
    let snn_p = man.get_f64("snn_sentiment_params").unwrap_or(1.0);
    println!(
        "2-layer LSTM baseline      : {} with {:.0} params ({:.1}× the SNN's {:.0}; paper: 8.5×)",
        man.get("lstm_acc").unwrap_or("?"),
        lstm_p,
        lstm_p / snn_p,
        snn_p
    );
    println!(
        "throughput                 : {:.1} reviews/s over {workers} workers ({wall:?})",
        n as f64 / wall.as_secs_f64()
    );

    // ---------------- Fig 10: V_out trajectories ----------------
    println!("\n-- Fig 10: output-neuron V_MEM over word sequence --");
    let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast())?;
    let pos = (0..n).find(|&i| a.test_labels[i] == 1).unwrap_or(0);
    let neg = (0..n).find(|&i| a.test_labels[i] == 0).unwrap_or(0);
    for (name, idx) in [("positive review", pos), ("negative review", neg)] {
        let r = net.run_review(&a.test_seqs[idx])?;
        println!("{name} (#{idx}): V_out after each word:");
        print!("  ");
        for v in &r.vout_trace {
            print!("{v:>6} ");
        }
        println!("\n  → {}", if r.pred == 1 { "POSITIVE" } else { "NEGATIVE" });
        if flag("--trace") {
            render_trace(&r.vout_trace);
        }
    }

    // ---------------- Fig 11a: per-layer per-timestep sparsity ----------------
    println!("\n-- Fig 11a: spike sparsity per layer per timestep --");
    let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast())?;
    for i in 0..n.min(100) {
        net.run_review(&a.test_seqs[i])?;
    }
    let table = net.tracker.table();
    println!("layer      t=1    2     3     4     5     6     7     8     9    10");
    for (l, name) in ["input(enc)", "FC1", "FC2"].iter().enumerate() {
        print!("{name:<9}");
        for t in 0..net.tracker.timesteps() {
            print!(" {:>5.2}", table[l][t]);
        }
        println!();
    }
    let overall = net.tracker.overall();
    println!("overall sparsity: {overall:.3}  (paper: ~0.85)");

    // ---------------- energy accounting ----------------
    let e = EnergyModel::calibrated();
    let hist = net.stats().histogram.clone();
    let cycles: u64 = net.stats().cycles;
    let energy = e.program_energy_j(&hist, NOMINAL_VDD);
    let per_review = energy / n.min(100) as f64;
    println!("\n-- macro-pool energy (point D: 0.85 V, 200 MHz) --");
    println!("instruction histogram      : {hist:?}");
    println!(
        "energy for {} reviews     : {} ({}/review)",
        n.min(100),
        eng(energy, "J"),
        eng(per_review, "J")
    );
    println!(
        "cycles                     : {cycles} ({} at 200 MHz)",
        eng(e.delay_s(cycles, NOMINAL_FREQ_HZ), "s")
    );

    // ---------------- optional: XLA cross-check ----------------
    if flag("--xla-check") {
        println!("\n-- XLA (PJRT) cross-check: L1+L2 AOT graph vs macro pool --");
        let rt = impulse::runtime::SentimentStepRuntime::load(
            &dir, a.w1.len(), a.w1[0].len(), a.w2[0].len(),
        )?;
        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast())?;
        let k = 8.min(n);
        for i in 0..k {
            let (pred_xla, trace) = rt.run_review(&a.emb_q, &a.test_seqs[i], 10)?;
            let r = net.run_review(&a.test_seqs[i])?;
            let t64: Vec<i64> = trace.iter().map(|&v| v as i64).collect();
            assert_eq!(r.vout_trace, t64, "review {i}");
            assert_eq!(r.pred, pred_xla, "review {i}");
        }
        println!("bit-exact agreement on {k} reviews ✓");
    }

    println!("\nOK");
    Ok(())
}

/// Tiny ASCII plot of a V_out trajectory.
fn render_trace(trace: &[i64]) {
    let max = trace.iter().map(|v| v.abs()).max().unwrap_or(1).max(1);
    for &v in trace {
        let w = ((v.abs() as f64 / max as f64) * 30.0) as usize;
        if v >= 0 {
            println!("  {:>31}|{}", "", "#".repeat(w));
        } else {
            println!("  {:>width$}{}|", "", "#".repeat(w), width = 31 - w);
        }
    }
}
