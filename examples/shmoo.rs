//! Fig 8: the Shmoo plot — pass/fail over (VDD, frequency) for plain
//! read/write vs CIM instructions, from the calibrated Fmax model,
//! with a functional pass/fail check at each published point.
//!
//!     cargo run --release --example shmoo

use impulse::bitcell::Parity;
use impulse::energy::{ShmooModel, ShmooPath};
use impulse::isa::Instruction;
use impulse::macro_sim::{ImpulseMacro, MacroConfig};

fn main() -> impulse::Result<()> {
    let m = ShmooModel::calibrated();
    println!("Fig 8 — Shmoo plot ( # = CIM pass, R = read/write only, . = fail )\n");
    print!("{}", m.standard_grid().render());
    println!("             VDD 0.6 → 1.2 V\n");

    println!("CIM Fmax boundary (published ↔ model):");
    for (v, f_pub) in impulse::energy::shmoo_boundary() {
        println!(
            "  {v:.2} V: published {:>6.1} MHz, model {:>6.1} MHz",
            f_pub / 1e6,
            m.fmax_hz(ShmooPath::Cim, v) / 1e6
        );
    }

    // Functional sanity at the nominal point: the full CIM instruction
    // set must run (the digital half of "pass"); analog failure beyond
    // Fmax comes from the calibrated model.
    let mut mac = ImpulseMacro::new(MacroConfig::bit_level());
    mac.write_weights(0, &[3; 12])?;
    mac.write_v(0, Parity::Odd, &[0; 6])?;
    mac.write_v(28, Parity::Odd, &[-5; 6])?;
    mac.write_v(30, Parity::Odd, &[0; 6])?;
    for instr in [
        Instruction::AccW2V { w_row: 0, v_src: 0, v_dst: 0, parity: Parity::Odd },
        Instruction::SpikeCheck { v_row: 0, thr_row: 28, parity: Parity::Odd },
        Instruction::ResetV { reset_row: 30, dst: 0, parity: Parity::Odd },
        Instruction::AccV2V {
            src_a: 0,
            src_b: 28,
            dst: 0,
            parity: Parity::Odd,
            mask: impulse::isa::WriteMaskMode::All,
        },
    ] {
        mac.execute(&instr)?;
    }
    println!("\nfunctional CIM instruction test at point D: PASS (all 4 instructions)");
    Ok(())
}
