//! Quickstart: program an IMPULSE macro by hand and watch the
//! in-memory instruction set implement an RMP neuron.
//!
//!     cargo run --release --example quickstart
//!
//! No artifacts required — this exercises the raw macro API.

use impulse::bitcell::Parity;
use impulse::energy::EnergyModel;
use impulse::isa::{Instruction, WriteMaskMode};
use impulse::macro_sim::{ImpulseMacro, MacroConfig};
use impulse::metrics::eng;
use impulse::NOMINAL_VDD;

fn main() -> impulse::Result<()> {
    // A macro with the bit-level (silicon-faithful) engine, tracing on.
    let mut m = ImpulseMacro::new(MacroConfig::bit_level().with_trace(true));

    // --- program the fused array -------------------------------------
    // W_MEM row 0: twelve 6-bit signed weights (one per output neuron).
    m.write_weights(0, &[5, -3, 12, 7, -31, 2, 9, 0, -1, 31, -17, 4])?;
    // W_MEM row 1: a second input neuron's weights.
    m.write_weights(1, &[1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1])?;

    // V_MEM: row 0 = odd-cycle potentials, row 1 = even-cycle (the
    // staggered mapping stores them in different rows).
    m.write_v(0, Parity::Odd, &[0; 6])?;
    m.write_v(1, Parity::Even, &[0; 6])?;
    // constants: −θ and the reset value, per alignment.
    let theta = 20;
    m.write_v(28, Parity::Odd, &[-theta; 6])?;
    m.write_v(29, Parity::Even, &[-theta; 6])?;
    m.write_v(30, Parity::Odd, &[0; 6])?;
    m.write_v(31, Parity::Even, &[0; 6])?;

    println!("IMPULSE quickstart — 2 input neurons → 12 RMP neurons, θ = {theta}\n");

    // --- run 4 timesteps ----------------------------------------------
    for t in 0..4 {
        // both inputs spike each timestep → AccW2V odd + even per input
        for w_row in [0usize, 1] {
            m.execute(&Instruction::AccW2V { w_row, v_src: 0, v_dst: 0, parity: Parity::Odd })?;
            m.execute(&Instruction::AccW2V { w_row, v_src: 1, v_dst: 1, parity: Parity::Even })?;
        }
        // RMP update: SpikeCheck then spike-gated soft reset (AccV2V −θ)
        let mut spikes = Vec::new();
        for (parity, v_row, thr_row) in [(Parity::Odd, 0usize, 28usize), (Parity::Even, 1, 29)] {
            m.execute(&Instruction::SpikeCheck { v_row, thr_row, parity })?;
            m.execute(&Instruction::AccV2V {
                src_a: v_row,
                src_b: thr_row,
                dst: v_row,
                parity,
                mask: WriteMaskMode::Spiked,
            })?;
            spikes.push(m.spikes(parity));
        }
        let v_odd = m.read_v(0, Parity::Odd)?;
        let v_even = m.read_v(1, Parity::Even)?;
        // interleave: even-indexed outputs live in the odd-cycle row
        let mut v = Vec::new();
        let mut s = Vec::new();
        for g in 0..6 {
            v.push(v_odd[g]);
            v.push(v_even[g]);
            s.push(spikes[0][g] as u8);
            s.push(spikes[1][g] as u8);
        }
        println!("t={t}  V = {v:?}");
        println!("     spk = {s:?}");
    }

    // --- accounting ----------------------------------------------------
    let e = EnergyModel::calibrated();
    println!("\ninstruction histogram: {:?}", m.counts());
    println!(
        "energy at point D (0.85 V, 200 MHz): {}",
        eng(e.program_energy_j(&m.counts(), NOMINAL_VDD), "J")
    );
    println!("trace length: {} events (bit-level engine)", m.trace().len());
    println!("\nOK — see examples/sentiment_e2e.rs for the full network.");
    Ok(())
}
