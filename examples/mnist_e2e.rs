//! Digits (MNIST stand-in) end-to-end: run the modified-LeNet5 SNN —
//! Conv2/Conv3/FC1/FC2 mapped on the distributed multi-macro pool —
//! over the synthetic digit test set.
//!
//!     cargo run --release --example mnist_e2e [-- --max 200]
//!
//! Requires `make artifacts`.

use impulse::data::{artifacts_available, artifacts_dir, DigitsArtifacts, Manifest};
use impulse::energy::EnergyModel;
use impulse::macro_sim::MacroConfig;
use impulse::metrics::eng;
use impulse::snn::DigitsNetwork;
use impulse::NOMINAL_VDD;
use std::time::Instant;

fn main() -> impulse::Result<()> {
    if !artifacts_available() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }
    let dir = artifacts_dir();
    let a = DigitsArtifacts::load(&dir)?;
    let man = Manifest::read(dir.join("manifest.txt"))?;
    let args: Vec<String> = std::env::args().collect();
    let max: usize = args
        .iter()
        .position(|x| x == "--max")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    let n = max.min(a.test_x.len());

    println!("== IMPULSE digits e2e (modified LeNet-5, fan-in ≤ 128) ==");
    let mut net = DigitsNetwork::from_artifacts(&a, MacroConfig::fast())?;
    println!(
        "macro pool: {} macros (conv2 {}, conv3 {}, fc1 {}, fc2 {})",
        net.num_macros(),
        net.conv2.num_macros(),
        net.conv3.num_macros(),
        net.fc1.num_macros(),
        net.fc2.num_macros()
    );

    let t0 = Instant::now();
    let mut correct = 0usize;
    for i in 0..n {
        let r = net.run_image(&a.test_x[i])?;
        if r.pred == a.test_y[i] {
            correct += 1;
        }
        if (i + 1) % 50 == 0 {
            println!(
                "  {}/{n}: running acc {:.4} ({:.1} img/s)",
                i + 1,
                correct as f64 / (i + 1) as f64,
                (i + 1) as f64 / t0.elapsed().as_secs_f64()
            );
        }
    }
    let acc = correct as f64 / n as f64;
    println!("\naccuracy on macro pool : {acc:.4} ({correct}/{n})");
    println!(
        "python int reference    : {} (paper MNIST: 0.9896)",
        man.get("snn_digits_quant_acc").unwrap_or("?")
    );

    // Fig 11a (digits): sparsity per layer
    println!("\nper-layer mean sparsity (conv1/enc, conv2, conv3, fc1):");
    for l in 0..4 {
        print!("  layer {l}: {:.3}", net.tracker.layer_sparsity(l));
    }
    println!("\noverall: {:.3}  (paper: ~0.85)", net.tracker.overall());

    let e = EnergyModel::calibrated();
    let stats = net.stats();
    println!(
        "\nenergy for {n} images   : {} ({} cycles)",
        eng(e.program_energy_j(&stats.histogram, NOMINAL_VDD), "J"),
        stats.cycles
    );
    println!(
        "per image               : {}",
        eng(e.program_energy_j(&stats.histogram, NOMINAL_VDD) / n as f64, "J")
    );
    println!("\nOK");
    Ok(())
}
