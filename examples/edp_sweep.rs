//! Fig 11b: EDP per neuron per timestep vs input-spike sparsity — both
//! from the analytic model *and* measured on the macro simulator
//! (instruction counts from actual scheduled streams must agree with
//! the model exactly).
//!
//!     cargo run --release --example edp_sweep

use impulse::bench_harness::Table;
use impulse::energy::{edp_per_neuron_timestep, EnergyModel, SparsitySweep};
use impulse::isa::NeuronType;
use impulse::macro_sim::MacroConfig;
use impulse::snn::{FcLayer, LayerParams};
use impulse::{NOMINAL_FREQ_HZ, NOMINAL_VDD};

fn main() -> impulse::Result<()> {
    let e = EnergyModel::calibrated();
    println!("Fig 11b — EDP per neuron per timestep vs sparsity (RMP, point D)\n");

    let mut t = Table::new(&[
        "sparsity", "model EDP (J·s)", "measured EDP (J·s)", "reduction",
    ]);
    let base = edp_per_neuron_timestep(&e, 0.0, NeuronType::RMP, NOMINAL_VDD, NOMINAL_FREQ_HZ);

    // a 128-input 12-neuron tile on the real simulator
    let weights: Vec<Vec<i64>> = (0..128)
        .map(|i| (0..12).map(|j| ((i * 7 + j * 3) % 63) as i64 - 31).collect())
        .collect();

    for pct in (0..=100).step_by(5) {
        let s = pct as f64 / 100.0;
        let model = edp_per_neuron_timestep(&e, s, NeuronType::RMP, NOMINAL_VDD, NOMINAL_FREQ_HZ);

        // measured: schedule + execute one timestep with that sparsity
        let mut layer = FcLayer::new(&weights, LayerParams::rmp(200), MacroConfig::fast())?;
        let n_spikes = ((1.0 - s) * 128.0).round() as usize;
        let mut spikes = vec![false; 128];
        for sp in spikes.iter_mut().take(n_spikes) {
            *sp = true;
        }
        layer.step(&spikes)?;
        let st = layer.stats();
        let energy = e.program_energy_j(&st.histogram, NOMINAL_VDD) / 12.0;
        let delay = e.delay_s(st.cycles, NOMINAL_FREQ_HZ) / 12.0;
        let measured = energy * delay;

        t.row(&[
            format!("{s:.2}"),
            format!("{:.4e}", model.edp),
            format!("{measured:.4e}"),
            format!("-{:.1}%", 100.0 * (1.0 - model.edp / base.edp)),
        ]);
    }
    println!("{}", t.render());

    let sweep = SparsitySweep::run(&e, NeuronType::RMP, 100);
    println!(
        "headline: EDP reduction at 85% sparsity = {:.1}%  (paper: 97.4%)",
        100.0 * sweep.reduction_at(0.85)
    );

    println!("\nneuron-type comparison at 85% sparsity:");
    for n in [NeuronType::IF, NeuronType::LIF, NeuronType::RMP] {
        let p = edp_per_neuron_timestep(&e, 0.85, n, NOMINAL_VDD, NOMINAL_FREQ_HZ);
        println!("  {:<4} EDP {:.4e} J·s", n.name(), p.edp);
    }
    Ok(())
}
