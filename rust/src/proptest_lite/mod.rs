//! Minimal property-testing runner (proptest is unavailable offline).
//!
//! `forall(cases, seed, gen, prop)` draws `cases` inputs from `gen` and
//! asserts `prop` on each; on failure it retries smaller values from
//! the generator's built-in shrink hints when provided, and always
//! reports the seed that reproduces the failure.

use crate::bits::XorShiftRng;

/// Run a property over generated cases. Panics with the failing case
/// and its reproduction seed.
pub fn forall<T: std::fmt::Debug>(
    cases: usize,
    seed: u64,
    mut gen: impl FnMut(&mut XorShiftRng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = XorShiftRng::new(case_seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed at case {case} (seed {case_seed:#x}): {input:?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result` so failures can
/// carry a message.
pub fn forall_ctx<T: std::fmt::Debug>(
    cases: usize,
    seed: u64,
    mut gen: impl FnMut(&mut XorShiftRng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = XorShiftRng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {case_seed:#x}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Common generators.
pub mod gen {
    use crate::bits::XorShiftRng;

    /// A vector of signed values within a bit width.
    pub fn signed_vec(rng: &mut XorShiftRng, len: usize, bits: u32) -> Vec<i64> {
        let (lo, hi) = crate::bits::signed_range(bits);
        (0..len).map(|_| rng.gen_i64(lo, hi)).collect()
    }

    /// A spike vector with the given firing probability.
    pub fn spikes(rng: &mut XorShiftRng, len: usize, p: f64) -> Vec<bool> {
        (0..len).map(|_| rng.gen_bool(p)).collect()
    }

    /// A weight matrix in 6-bit range.
    pub fn weight_matrix(rng: &mut XorShiftRng, m: usize, n: usize) -> Vec<Vec<i64>> {
        (0..m).map(|_| signed_vec(rng, n, 6)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(
            100,
            42,
            |rng| rng.gen_i64(-1024, 1023),
            |&v| crate::bits::wrap11(v) == v,
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        forall(100, 7, |rng| rng.gen_i64(0, 100), |&v| v < 95);
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = XorShiftRng::new(1);
        for _ in 0..50 {
            let v = gen::signed_vec(&mut rng, 32, 6);
            assert!(v.iter().all(|&x| (-32..=31).contains(&x)));
            let s = gen::spikes(&mut rng, 16, 0.5);
            assert_eq!(s.len(), 16);
            let w = gen::weight_matrix(&mut rng, 3, 4);
            assert_eq!((w.len(), w[0].len()), (3, 4));
        }
    }
}
