//! The digits network (modified LeNet-5, paper §III): Conv1 spike
//! encoder (off-macro) → pool → Conv2 → pool → Conv3 → pool → FC1 →
//! FC2 (output), with Conv2/Conv3/FC1/FC2 mapped on IMPULSE.

use super::{ConvEncoder, ConvLayer, FcLayer, LayerParams, LayerStats, SparsityTracker};
use crate::data::DigitsArtifacts;
use crate::macro_sim::MacroConfig;
use crate::Result;

/// Result of classifying one image.
#[derive(Clone, Debug)]
pub struct DigitsResult {
    pub pred: u8,
    /// Final output potentials (10 classes).
    pub v_out: Vec<i64>,
    pub cycles: u64,
}

/// The mapped digits SNN.
pub struct DigitsNetwork {
    pub encoder: ConvEncoder,
    pub conv2: ConvLayer,
    pub conv3: ConvLayer,
    pub fc1: FcLayer,
    pub fc2: FcLayer,
    pub t: usize,
    /// Layers tracked: enc(conv1), conv2, conv3, fc1.
    pub tracker: SparsityTracker,
}

impl DigitsNetwork {
    pub fn from_artifacts(a: &DigitsArtifacts, config: MacroConfig) -> Result<Self> {
        let c = a.k2_shape[2];
        let t = 10;
        Ok(Self {
            encoder: ConvEncoder::new(a.k1.clone(), &a.k1_shape, a.thr_c1, 28, 28),
            conv2: ConvLayer::new(
                &a.k2, 14, 14, c, a.k2_shape[3], 3,
                LayerParams::rmp(a.thr_c2),
                config,
            )?,
            conv3: ConvLayer::new(
                &a.k3, 7, 7, c, a.k3_shape[3], 3,
                LayerParams::rmp(a.thr_c3),
                config,
            )?,
            fc1: FcLayer::new(&a.w_fc1, LayerParams::rmp(a.thr_f1), config)?,
            fc2: FcLayer::new(&a.w_fc2, LayerParams::rmp(1), config)?.output_only(),
            t,
            tracker: SparsityTracker::new(4, t),
        })
    }

    /// Macros used by the on-macro layers.
    pub fn num_macros(&self) -> usize {
        self.conv2.num_macros()
            + self.conv3.num_macros()
            + self.fc1.num_macros()
            + self.fc2.num_macros()
    }

    pub fn reset_state(&mut self) -> Result<()> {
        self.conv2.reset_state()?;
        self.conv3.reset_state()?;
        self.fc1.reset_state()?;
        self.fc2.reset_state()?;
        Ok(())
    }

    /// Classify one 28×28 image.
    pub fn run_image(&mut self, image: &[f32]) -> Result<DigitsResult> {
        self.reset_state()?;
        self.encoder.set_image(image);
        let cycles0 = self.total_cycles();
        for t in 0..self.t {
            let s1 = self.encoder.step(); // 28×28×C
            let fired1 = s1.flatten().iter().filter(|&&b| b).count() as u64;
            self.tracker.record_counts(0, t, fired1, s1.len() as u64);
            let p1 = s1.maxpool2(); // 14×14×C
            let s2 = self.conv2.step(&p1)?;
            let fired2 = s2.flatten().iter().filter(|&&b| b).count() as u64;
            self.tracker.record_counts(1, t, fired2, s2.len() as u64);
            let p2 = s2.maxpool2(); // 7×7×C
            let s3 = self.conv3.step(&p2)?;
            let fired3 = s3.flatten().iter().filter(|&&b| b).count() as u64;
            self.tracker.record_counts(2, t, fired3, s3.len() as u64);
            let p3 = s3.maxpool2(); // 3×3×C
            let sf = self.fc1.step(&p3.flatten())?.to_vec();
            self.tracker.record(3, t, &sf);
            self.fc2.step(&sf)?;
        }
        let v_out = self.fc2.potentials()?;
        let pred = v_out
            .iter()
            .enumerate()
            .max_by_key(|&(_, v)| *v)
            .map(|(i, _)| i as u8)
            .unwrap_or(0);
        Ok(DigitsResult {
            pred,
            v_out,
            cycles: self.total_cycles() - cycles0,
        })
    }

    pub fn stats(&self) -> LayerStats {
        let mut s = self.conv2.stats();
        s.merge(&self.conv3.stats());
        s.merge(&self.fc1.stats());
        s.merge(&self.fc2.stats());
        s
    }

    fn total_cycles(&self) -> u64 {
        self.conv2.stats().cycles
            + self.conv3.stats().cycles
            + self.fc1.stats().cycles
            + self.fc2.stats().cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::XorShiftRng;
    use crate::data::DigitsArtifacts;

    fn mini_digits(seed: u64) -> DigitsArtifacts {
        let mut rng = XorShiftRng::new(seed);
        let c = 4usize; // small channel count for test speed
        let k1: Vec<f32> = (0..9 * c).map(|_| (rng.gen_f64() - 0.3) as f32).collect();
        let mut kernel = |n: usize| (0..n).map(|_| rng.gen_i64(-8, 8)).collect::<Vec<i64>>();
        DigitsArtifacts {
            k1,
            k1_shape: vec![3, 3, 1, c],
            thr_c1: 0.8,
            k2: kernel(9 * c * c),
            k2_shape: vec![3, 3, c, c],
            k3: kernel(9 * c * c),
            k3_shape: vec![3, 3, c, c],
            w_fc1: (0..9 * c)
                .map(|_| (0..20).map(|_| rng.gen_i64(-8, 8)).collect())
                .collect(),
            w_fc2: (0..20)
                .map(|_| (0..10).map(|_| rng.gen_i64(-8, 8)).collect())
                .collect(),
            thr_c2: 30,
            thr_c3: 30,
            thr_f1: 40,
            test_x: vec![],
            test_y: vec![],
        }
    }

    #[test]
    fn digits_network_runs_end_to_end() {
        let a = mini_digits(11);
        let mut net = DigitsNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let mut rng = XorShiftRng::new(3);
        let img: Vec<f32> = (0..28 * 28).map(|_| rng.gen_f64() as f32).collect();
        let r = net.run_image(&img).unwrap();
        assert!(r.pred < 10);
        assert_eq!(r.v_out.len(), 10);
        assert!(r.cycles > 0);
        // deterministic
        let r2 = net.run_image(&img).unwrap();
        assert_eq!(r.v_out, r2.v_out);
    }

    #[test]
    fn blank_image_mostly_silent() {
        let a = mini_digits(12);
        let mut net = DigitsNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let img = vec![0.0f32; 28 * 28];
        let r = net.run_image(&img).unwrap();
        // encoder gets zero current → zero spikes → no AccW2V anywhere
        let s = net.stats();
        assert_eq!(
            s.histogram.get(&crate::isa::InstructionKind::AccW2V),
            None,
            "blank image must not fire synapses"
        );
        assert!(r.v_out.iter().all(|&v| v == 0));
    }
}
