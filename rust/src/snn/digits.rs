//! The digits network (modified LeNet-5, paper §III): Conv1 spike
//! encoder (off-macro) → pool → Conv2 → pool → Conv3 → pool → FC1 →
//! FC2 (output), with Conv2/Conv3/FC1/FC2 mapped on IMPULSE.

use super::{ConvEncoder, ConvLayer, FcLayer, LayerParams, LayerStats, SparsityTracker};
use super::{SpikeMap, SpikePlane};
use crate::data::DigitsArtifacts;
use crate::macro_sim::MacroConfig;
use crate::Result;

/// Lowest-index argmax: on tied potentials the *smallest* class index
/// wins, matching the Python reference (`numpy.argmax`). `max_by_key`
/// would return the last maximum — a silent divergence on ties.
pub(crate) fn argmax_lowest(v: &[i64]) -> u8 {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate().skip(1) {
        if x > v[best] {
            best = i;
        }
    }
    best as u8
}

/// Result of classifying one image.
#[derive(Clone, Debug)]
pub struct DigitsResult {
    pub pred: u8,
    /// Final output potentials (10 classes).
    pub v_out: Vec<i64>,
    pub cycles: u64,
}

/// The mapped digits SNN.
pub struct DigitsNetwork {
    pub encoder: ConvEncoder,
    pub conv2: ConvLayer,
    pub conv3: ConvLayer,
    pub fc1: FcLayer,
    pub fc2: FcLayer,
    pub t: usize,
    /// Layers tracked: enc(conv1), conv2, conv3, fc1.
    pub tracker: SparsityTracker,
    // streaming-session state: set by `begin_stream`, advanced by
    // `stream_image_step`, read by `stream_read_out`
    stream_img: Option<Vec<f32>>,
    stream_t: usize,
    stream_cycles0: u64,
}

impl DigitsNetwork {
    pub fn from_artifacts(a: &DigitsArtifacts, config: MacroConfig) -> Result<Self> {
        let c = a.k2_shape[2];
        let t = 10;
        Ok(Self {
            encoder: ConvEncoder::new(a.k1.clone(), &a.k1_shape, a.thr_c1, 28, 28),
            conv2: ConvLayer::new(
                &a.k2, 14, 14, c, a.k2_shape[3], 3,
                LayerParams::rmp(a.thr_c2),
                config,
            )?,
            conv3: ConvLayer::new(
                &a.k3, 7, 7, c, a.k3_shape[3], 3,
                LayerParams::rmp(a.thr_c3),
                config,
            )?,
            fc1: FcLayer::new(&a.w_fc1, LayerParams::rmp(a.thr_f1), config)?,
            fc2: FcLayer::new(&a.w_fc2, LayerParams::rmp(1), config)?.output_only(),
            t,
            tracker: SparsityTracker::new(4, t),
            stream_img: None,
            stream_t: 0,
            stream_cycles0: 0,
        })
    }

    /// Macros used by the on-macro layers.
    pub fn num_macros(&self) -> usize {
        self.conv2.num_macros()
            + self.conv3.num_macros()
            + self.fc1.num_macros()
            + self.fc2.num_macros()
    }

    /// One representative tile schedule per on-macro layer, labeled —
    /// the input to `impulse check` and the validator property tests.
    /// The encoder (conv1) runs off-macro and emits no ISA stream.
    pub fn schedule_programs(&self, timesteps: usize) -> Vec<(String, crate::isa::Program)> {
        vec![
            ("conv2".into(), self.conv2.schedule_program(timesteps)),
            ("conv3".into(), self.conv3.schedule_program(timesteps)),
            ("fc1".into(), self.fc1.schedule_program(timesteps)),
            ("fc2".into(), self.fc2.schedule_program(timesteps)),
        ]
    }

    pub fn reset_state(&mut self) -> Result<()> {
        self.conv2.reset_state()?;
        self.conv3.reset_state()?;
        self.fc1.reset_state()?;
        self.fc2.reset_state()?;
        Ok(())
    }

    /// Classify one 28×28 image.
    pub fn run_image(&mut self, image: &[f32]) -> Result<DigitsResult> {
        self.reset_state()?;
        self.encoder.set_image(image);
        let cycles0 = self.total_cycles();
        for t in 0..self.t {
            let s1 = self.encoder.step(); // 28×28×C
            self.tracker.record_counts(0, t, s1.count_ones() as u64, s1.len() as u64);
            let p1 = s1.maxpool2(); // 14×14×C
            let s2 = self.conv2.step(&p1)?;
            self.tracker.record_counts(1, t, s2.count_ones() as u64, s2.len() as u64);
            let p2 = s2.maxpool2(); // 7×7×C
            let s3 = self.conv3.step(&p2)?;
            self.tracker.record_counts(2, t, s3.count_ones() as u64, s3.len() as u64);
            let p3 = s3.maxpool2(); // 3×3×C
            let sf = self.fc1.step_plane(p3.plane())?;
            self.tracker.record_plane(3, t, sf);
            self.fc2.step_plane(sf)?;
        }
        let v_out = self.fc2.potentials()?;
        let pred = argmax_lowest(&v_out);
        Ok(DigitsResult {
            pred,
            v_out,
            cycles: self.total_cycles() - cycles0,
        })
    }

    /// Begin a pinned-membrane streaming session: reset the mapped
    /// layers and zero the session's cycle attribution. The encoder is
    /// primed lazily by the first [`DigitsNetwork::stream_image_step`]
    /// (matching [`DigitsNetwork::run_image`]'s `set_image`).
    pub fn begin_stream(&mut self) -> Result<()> {
        self.reset_state()?;
        self.stream_img = None;
        self.stream_t = 0;
        self.stream_cycles0 = self.total_cycles();
        Ok(())
    }

    /// Integrate one image frame for one membrane timestep — exactly
    /// one iteration of the [`DigitsNetwork::run_image`] loop, so `t`
    /// appends of the same frame followed by one read-out are
    /// bit-identical (prediction, potentials, *and* cycles) to the
    /// one-shot run, however the appends are grouped. A
    /// pixel-identical frame keeps integrating the encoder's membrane
    /// (the one-shot path); a *new* frame re-primes the encoder
    /// (`set_image` zeroes its membrane) while the downstream
    /// Conv/FC membranes persist — the event-frame stream shape.
    /// Returns cumulative session macro cycles.
    pub fn stream_image_step(&mut self, image: &[f32]) -> Result<u64> {
        if self.stream_img.as_deref() != Some(image) {
            self.encoder.set_image(image);
            self.stream_img = Some(image.to_vec());
        }
        let t = self.stream_t;
        let s1 = self.encoder.step(); // 28×28×C
        self.tracker.record_counts(0, t, s1.count_ones() as u64, s1.len() as u64);
        let p1 = s1.maxpool2(); // 14×14×C
        let s2 = self.conv2.step(&p1)?;
        self.tracker.record_counts(1, t, s2.count_ones() as u64, s2.len() as u64);
        let p2 = s2.maxpool2(); // 7×7×C
        let s3 = self.conv3.step(&p2)?;
        self.tracker.record_counts(2, t, s3.count_ones() as u64, s3.len() as u64);
        let p3 = s3.maxpool2(); // 3×3×C
        let sf = self.fc1.step_plane(p3.plane())?;
        self.tracker.record_plane(3, t, sf);
        self.fc2.step_plane(sf)?;
        self.stream_t += 1;
        Ok(self.total_cycles() - self.stream_cycles0)
    }

    /// Read `(pred, v_all, cycles)` out of the pinned membrane state
    /// without disturbing it. Costs the same read-out ReadVs the
    /// one-shot path spends once at its end — call it once per stream
    /// for exact cycle identity (every call adds one read's cycles).
    pub fn stream_read_out(&mut self) -> Result<(u8, Vec<i64>, u64)> {
        let v_all = self.fc2.potentials()?;
        let pred = argmax_lowest(&v_all);
        Ok((pred, v_all, self.total_cycles() - self.stream_cycles0))
    }

    /// Batch lanes one pass through the macro pool can host (bounded
    /// by the V_MEM row budget of the mapped layers).
    pub fn max_batch_lanes(&self) -> usize {
        self.conv2
            .max_batch_lanes()
            .min(self.conv3.max_batch_lanes())
            .min(self.fc1.max_batch_lanes())
            .min(self.fc2.max_batch_lanes())
    }

    /// Classify a batch of images concurrently on the same macro pool:
    /// each image gets its own membrane-potential lane in every conv
    /// pixel and FC tile, and each timestep issues one fused AccW2V
    /// stream per pixel window / tile whose instruction count is the
    /// *union* of spiking inputs across the batch
    /// (`ImpulseMacro::acc_w2v_fused`). Images beyond the lane budget
    /// are processed in chunks.
    ///
    /// `v_out` and `pred` are bit-identical to running each image
    /// through [`DigitsNetwork::run_image`]; per-image `cycles` report
    /// each request's honest share of its chunk — fused (shared)
    /// AccW2V cycles split across the lanes that latched them, per-lane
    /// update/read-out cycles charged whole — summing exactly to the
    /// chunk's total spend (largest-remainder apportionment).
    pub fn run_images_batched(&mut self, images: &[&[f32]]) -> Result<Vec<DigitsResult>> {
        let max = self.max_batch_lanes();
        let mut out = Vec::with_capacity(images.len());
        for chunk in images.chunks(max) {
            out.extend(self.run_batch_chunk(chunk)?);
        }
        Ok(out)
    }

    fn run_batch_chunk(&mut self, images: &[&[f32]]) -> Result<Vec<DigitsResult>> {
        let lanes = images.len();
        self.conv2.begin_batch(lanes)?;
        self.conv3.begin_batch(lanes)?;
        self.fc1.begin_batch(lanes)?;
        self.fc2.begin_batch(lanes)?;
        let cycles0 = self.total_cycles();
        let mut encoders: Vec<ConvEncoder> = (0..lanes)
            .map(|b| {
                let mut e = self.encoder.clone();
                e.set_image(images[b]);
                e
            })
            .collect();
        // every image runs the full T timesteps: all lanes stay active
        let active = vec![true; lanes];
        let mut fc_in: Vec<SpikePlane> = vec![SpikePlane::default(); lanes];
        for t in 0..self.t {
            let mut p1 = Vec::with_capacity(lanes);
            for e in encoders.iter_mut() {
                let s1 = e.step(); // 28×28×C
                self.tracker.record_counts(0, t, s1.count_ones() as u64, s1.len() as u64);
                p1.push(s1.maxpool2()); // 14×14×C
            }
            let p1_refs: Vec<&SpikeMap> = p1.iter().collect();
            let s2 = self.conv2.step_batch(&p1_refs, &active)?;
            for s in &s2 {
                self.tracker.record_counts(1, t, s.count_ones() as u64, s.len() as u64);
            }
            let p2: Vec<SpikeMap> = s2.iter().map(|s| s.maxpool2()).collect(); // 7×7×C
            let p2_refs: Vec<&SpikeMap> = p2.iter().collect();
            let s3 = self.conv3.step_batch(&p2_refs, &active)?;
            for s in &s3 {
                self.tracker.record_counts(2, t, s.count_ones() as u64, s.len() as u64);
            }
            for (b, s) in s3.iter().enumerate() {
                fc_in[b] = s.maxpool2().into_plane(); // 3×3×C, stays packed
            }
            let sf = self.fc1.step_batch_planes(&fc_in, &active)?;
            for s in sf {
                self.tracker.record_plane(3, t, s);
            }
            self.fc2.step_batch_planes(sf, &active)?;
        }
        let mut v_outs = Vec::with_capacity(lanes);
        for b in 0..lanes {
            v_outs.push(self.fc2.lane_potentials(b)?);
        }
        let spent = self.total_cycles() - cycles0;
        // Honest per-request attribution: each lane's share of the
        // fused AccW2V issue, its own neuron-update cycles, and its
        // read-out ReadVs — rounded to integers without losing a cycle
        // (largest-remainder apportionment over the chunk's spend).
        let c2 = self.conv2.lane_attributed_cycles();
        let c3 = self.conv3.lane_attributed_cycles();
        let f1 = self.fc1.lane_attributed_cycles();
        let f2 = self.fc2.lane_attributed_cycles();
        let readv = (2 * self.fc2.num_macros()) as f64;
        let weights: Vec<f64> = (0..lanes)
            .map(|b| c2[b] + c3[b] + f1[b] + f2[b] + readv)
            .collect();
        let cycles = crate::metrics::apportion(&weights, spent);
        Ok(v_outs
            .into_iter()
            .zip(cycles)
            .map(|(v_out, cycles)| DigitsResult {
                pred: argmax_lowest(&v_out),
                v_out,
                cycles,
            })
            .collect())
    }

    pub fn stats(&self) -> LayerStats {
        let mut s = self.conv2.stats();
        s.merge(&self.conv3.stats());
        s.merge(&self.fc1.stats());
        s.merge(&self.fc2.stats());
        s
    }

    fn total_cycles(&self) -> u64 {
        self.conv2.stats().cycles
            + self.conv3.stats().cycles
            + self.fc1.stats().cycles
            + self.fc2.stats().cycles
    }

    /// FNV-1a digest of every mapped macro's V_MEM rows (conv2 →
    /// conv3 → fc1 → fc2, tile order within each layer; the off-macro
    /// encoder holds no V_MEM). A pure state read — no instruction is
    /// issued and no counter moves — so bit-identical membrane state
    /// digests identically: the record/replay checkpoint
    /// (`docs/REPLAY.md`).
    pub fn v_digest(&self) -> u64 {
        let mut h = crate::replay::FNV_OFFSET;
        self.conv2.fold_vmem_digest(&mut h);
        self.conv3.fold_vmem_digest(&mut h);
        self.fc1.fold_vmem_digest(&mut h);
        self.fc2.fold_vmem_digest(&mut h);
        h
    }

    /// Reset instruction counters (keeps weights and state).
    pub fn reset_counters(&mut self) {
        self.conv2.reset_counters();
        self.conv3.reset_counters();
        self.fc1.reset_counters();
        self.fc2.reset_counters();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::XorShiftRng;
    use crate::data::DigitsArtifacts;

    fn mini_digits(seed: u64) -> DigitsArtifacts {
        DigitsArtifacts::synthetic(seed)
    }

    /// The tie-break contract: tied potentials resolve to the lowest
    /// class index (matching the Python reference's `argmax`), not the
    /// last.
    #[test]
    fn argmax_ties_break_to_lowest_index() {
        assert_eq!(argmax_lowest(&[0, 5, 5, 3]), 1);
        assert_eq!(argmax_lowest(&[7, 5, 7]), 0);
        assert_eq!(argmax_lowest(&[0; 10]), 0);
        assert_eq!(argmax_lowest(&[-3, -1, -1]), 1);
        assert_eq!(argmax_lowest(&[4]), 0);
    }

    /// A batch of one must reproduce the sequential run exactly —
    /// including its cycle count (the attribution degenerates to the
    /// lane's own spend).
    #[test]
    fn singleton_batch_matches_run_image_exactly() {
        let a = mini_digits(21);
        let mut rng = XorShiftRng::new(4);
        let img: Vec<f32> = (0..28 * 28).map(|_| rng.gen_f64() as f32).collect();
        let mut seq = DigitsNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let want = seq.run_image(&img).unwrap();
        let mut net = DigitsNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let got = net.run_images_batched(&[&img[..]]).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].v_out, want.v_out);
        assert_eq!(got[0].pred, want.pred);
        assert_eq!(got[0].cycles, want.cycles, "singleton attribution");
    }

    #[test]
    fn digits_network_runs_end_to_end() {
        let a = mini_digits(11);
        let mut net = DigitsNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let mut rng = XorShiftRng::new(3);
        let img: Vec<f32> = (0..28 * 28).map(|_| rng.gen_f64() as f32).collect();
        let r = net.run_image(&img).unwrap();
        assert!(r.pred < 10);
        assert_eq!(r.v_out.len(), 10);
        assert!(r.cycles > 0);
        // deterministic
        let r2 = net.run_image(&img).unwrap();
        assert_eq!(r.v_out, r2.v_out);
    }

    /// The streaming differential: per-timestep appends of the same
    /// frame, split into two groups at every boundary, must be
    /// bit-identical (prediction, potentials, and cycles) to the
    /// one-shot run.
    #[test]
    fn streamed_image_bit_identical_to_one_shot_at_every_split() {
        let a = mini_digits(13);
        let mut rng = XorShiftRng::new(5);
        let img: Vec<f32> = (0..28 * 28).map(|_| rng.gen_f64() as f32).collect();
        let mut net = DigitsNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let want = net.run_image(&img).unwrap();
        let t = net.t;
        for split in 0..=t {
            net.begin_stream().unwrap();
            for _ in 0..split {
                net.stream_image_step(&img).unwrap();
            }
            for _ in split..t {
                net.stream_image_step(&img).unwrap();
            }
            let (pred, v_all, cycles) = net.stream_read_out().unwrap();
            assert_eq!(pred, want.pred, "split {split}");
            assert_eq!(v_all, want.v_out, "split {split}");
            assert_eq!(cycles, want.cycles, "split {split}");
        }
    }

    #[test]
    fn blank_image_mostly_silent() {
        let a = mini_digits(12);
        let mut net = DigitsNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let img = vec![0.0f32; 28 * 28];
        let r = net.run_image(&img).unwrap();
        // encoder gets zero current → zero spikes → no AccW2V anywhere
        let s = net.stats();
        assert_eq!(
            s.histogram.get(&crate::isa::InstructionKind::AccW2V),
            None,
            "blank image must not fire synapses"
        );
        assert!(r.v_out.iter().all(|&v| v == 0));
    }
}
