//! Convolutional layer executor over a macro pool (paper Fig 3b).

use super::{LayerParams, LayerStats, SpikeMap};
use crate::bitcell::Parity;
use crate::isa::{neuron_sequence, Instruction, Program};
use crate::macro_sim::{ImpulseMacro, MacroConfig};
use crate::mapper::{ConvLayout, OUTPUTS_PER_TILE};
use crate::Result;

/// A SAME-padded k×k conv layer distributed across a pool of macros:
/// kernel weights are replicated into every macro of a channel group;
/// each macro owns the membrane potentials of up to 13 output pixels.
///
/// Besides the classic one-image [`ConvLayer::step`], the layer
/// supports *batch lanes* (the conv counterpart of
/// [`super::FcLayer::step_batch`]): [`ConvLayer::begin_batch`] re-lays
/// the pool out so every output pixel keeps one V-row pair per lane in
/// its macro, and [`ConvLayer::step_batch`] issues one fused AccW2V
/// stream per pixel window covering the *union* of spiking taps across
/// lanes (`ImpulseMacro::acc_w2v_fused`), then the per-lane fused
/// neuron-update kernels. Results are bit-identical per lane to
/// sequential stepping; the AccW2V cycle cost is the union, not the
/// per-lane sum.
pub struct ConvLayer {
    pub layout: ConvLayout,
    macros: Vec<ImpulseMacro>,
    params: LayerParams,
    /// Kernel kept to program pools for lane counts not seen before.
    kernel_flat: Vec<i64>,
    config: MacroConfig,
    /// Programmed pools parked per lane count: switching back to a
    /// previously-used batch width swaps a pool in (state and counters
    /// reset — a handful of V-row writes) instead of reprogramming
    /// every kernel tap. Bounded by `max_batch_lanes` entries.
    pools: std::collections::HashMap<usize, (ConvLayout, Vec<ImpulseMacro>)>,
    /// Pool programmings performed by `begin_batch` (cache misses) —
    /// the serve path's lane-churn cost signal.
    reprograms: u64,
    /// Per-lane attributed cycles (fractional) since `begin_batch`:
    /// each fused AccW2V cycle is split across the lanes sharing that
    /// union row; neuron-update cycles are charged to their own lane.
    /// Sums exactly to the layer's batched cycle spend.
    lane_cycles: Vec<f64>,
    /// Scratch: fused spike union `(w_row, lane mask)` per pixel.
    union_rows: Vec<(usize, u32)>,
    /// Scratch: per-lane destination V rows of the current pixel.
    lane_rows_odd: Vec<usize>,
    lane_rows_even: Vec<usize>,
}

impl ConvLayer {
    /// Build from a dense kernel `[ky][kx][c_in][c_out]` (flattened,
    /// 6-bit values).
    pub fn new(
        kernel_flat: &[i64],
        h: usize,
        w: usize,
        c_in: usize,
        c_out: usize,
        ksize: usize,
        params: LayerParams,
        config: MacroConfig,
    ) -> Result<Self> {
        let layout = ConvLayout::new(h, w, c_in, c_out, ksize).map_err(anyhow::Error::from)?;
        assert_eq!(kernel_flat.len(), ksize * ksize * c_in * c_out);
        let macros = Self::build_macros(&layout, kernel_flat, params, config)?;
        Ok(Self {
            layout,
            macros,
            params,
            kernel_flat: kernel_flat.to_vec(),
            config,
            pools: std::collections::HashMap::new(),
            reprograms: 0,
            lane_cycles: vec![0.0],
            union_rows: Vec::new(),
            lane_rows_odd: vec![0],
            lane_rows_even: vec![1],
        })
    }

    /// Program a macro pool for `layout`: kernel taps replicated into
    /// every macro of a channel group, constants per parity, all pixel
    /// (and lane) V rows zeroed. Counters are reset — programming is
    /// not inference cost.
    fn build_macros(
        layout: &ConvLayout,
        kernel_flat: &[i64],
        params: LayerParams,
        config: MacroConfig,
    ) -> Result<Vec<ImpulseMacro>> {
        let ksize = layout.ksize;
        let mut macros = Vec::with_capacity(layout.num_macros());
        for g in 0..layout.n_channel_groups {
            for _ in 0..layout.macros_per_group() {
                let mut m = ImpulseMacro::new(config);
                for ky in 0..ksize {
                    for kx in 0..ksize {
                        for c in 0..layout.c_in {
                            let row = layout.tile_row_weights(kernel_flat, g, ky, kx, c);
                            m.write_weights(layout.tap_row(ky, kx, c), &row)?;
                        }
                    }
                }
                let cr = layout.const_rows;
                for (parity, thr, rst, lk) in [
                    (Parity::Odd, cr.neg_thr_odd, cr.reset_odd, cr.neg_leak_odd),
                    (Parity::Even, cr.neg_thr_even, cr.reset_even, cr.neg_leak_even),
                ] {
                    m.write_v(thr, parity, &[-params.threshold; 6])?;
                    m.write_v(rst, parity, &[params.reset; 6])?;
                    m.write_v(lk, parity, &[-params.leak; 6])?;
                }
                // zero every value row below the constant block (all
                // pixel slots of all lanes)
                for p in 0..cr.first_row() / 2 {
                    m.write_v(2 * p, Parity::Odd, &[0; 6])?;
                    m.write_v(2 * p + 1, Parity::Even, &[0; 6])?;
                }
                m.reset_counters();
                macros.push(m);
            }
        }
        Ok(macros)
    }

    /// One timestep: returns the output spike map (h × w × c_out).
    pub fn step(&mut self, input: &SpikeMap) -> Result<SpikeMap> {
        let l = &self.layout;
        assert_eq!((input.h, input.w, input.c), (l.h(), l.w(), l.c_in));
        let mut out = SpikeMap::new(l.h(), l.w(), l.c_out);
        let mut spiking_rows: Vec<usize> = Vec::with_capacity(l.fan_in());
        for y in 0..l.h() {
            for x in 0..l.w() {
                // spiking taps of this pixel's window (shared across groups)
                spiking_rows.clear();
                for (w_row, iy, ix, c) in l.window(y, x) {
                    if input.get(iy, ix, c) {
                        spiking_rows.push(w_row);
                    }
                }
                for g in 0..l.n_channel_groups {
                    let a = l.assign(y, x, g);
                    let m = &mut self.macros[a.macro_id];
                    for (parity, v) in
                        [(Parity::Odd, a.v_row_odd), (Parity::Even, a.v_row_even)]
                    {
                        m.acc_w2v_batch(&spiking_rows, v, parity)?;
                    }
                    // neuron update for this pixel
                    for (parity, v) in
                        [(Parity::Odd, a.v_row_odd), (Parity::Even, a.v_row_even)]
                    {
                        let rows = l.const_rows.for_parity(parity);
                        for instr in neuron_sequence(self.params.neuron, v, rows, parity) {
                            m.execute(&instr)?;
                        }
                        let spikes = m.spikes(parity);
                        for (field, &sp) in spikes.iter().enumerate() {
                            let local = match parity {
                                Parity::Odd => 2 * field,
                                Parity::Even => 2 * field + 1,
                            };
                            let co = g * OUTPUTS_PER_TILE + local;
                            if co < l.c_out && sp {
                                out.set(y, x, co, true);
                            }
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    /// Maximum batch lanes this layer can host: one odd/even V-row
    /// pair per (pixel, lane) in the rows below the constant block,
    /// with at least one pixel slot left per macro.
    pub fn max_batch_lanes(&self) -> usize {
        (self.layout.const_rows.first_row() / 2).min(crate::macro_sim::MAX_FUSED_LANES)
    }

    /// Configured batch lanes (1 unless `begin_batch` widened it).
    pub fn batch_lanes(&self) -> usize {
        self.layout.lanes()
    }

    /// Allocate and zero `lanes` independent batch lanes: the pool is
    /// re-laid-out so every output pixel keeps one V-row pair per lane
    /// in its macro (`ConvLayout::assign_lane`), shrinking the
    /// per-macro pixel budget and growing the pool to compensate. Also
    /// resets the per-lane cycle attribution.
    ///
    /// Pools are **cached per lane count**: a width served before
    /// swaps its programmed pool back in (membranes and counters
    /// reset, kernel taps untouched) instead of reprogramming every
    /// weight row — serve-path churn between batch widths costs a
    /// reprogram only the *first* time each width is seen
    /// ([`ConvLayer::reprograms`] counts the misses).
    pub fn begin_batch(&mut self, lanes: usize) -> Result<()> {
        anyhow::ensure!(
            lanes >= 1 && lanes <= self.max_batch_lanes(),
            "batch of {lanes} lanes outside 1..={} (V_MEM budget)",
            self.max_batch_lanes()
        );
        if lanes != self.layout.lanes() {
            let (layout, macros, fresh) = match self.pools.remove(&lanes) {
                Some((layout, macros)) => (layout, macros, false),
                None => {
                    let layout = self.layout.with_lanes(lanes).map_err(anyhow::Error::from)?;
                    let macros = Self::build_macros(
                        &layout,
                        &self.kernel_flat,
                        self.params,
                        self.config,
                    )?;
                    (layout, macros, true)
                }
            };
            let old_layout = std::mem::replace(&mut self.layout, layout);
            let old_macros = std::mem::replace(&mut self.macros, macros);
            self.pools.insert(old_layout.lanes(), (old_layout, old_macros));
            if fresh {
                // a freshly-programmed pool is already zeroed with
                // clean counters (build_macros resets them)
                self.reprograms += 1;
            } else {
                self.reset_counters();
                self.reset_state()?;
            }
        } else {
            self.reset_state()?;
        }
        // scratch reuse: re-arming at an unchanged width allocates
        // nothing (mirror of the FC layer's buffer discipline)
        if self.lane_cycles.len() == lanes {
            self.lane_cycles.fill(0.0);
        } else {
            self.lane_cycles = vec![0.0; lanes];
        }
        if self.lane_rows_odd.len() != lanes {
            self.lane_rows_odd = vec![0; lanes];
            self.lane_rows_even = vec![0; lanes];
        }
        Ok(())
    }

    /// How many pool programmings `begin_batch` has performed (cache
    /// misses on the per-lane-count pool cache). Repeating an
    /// already-seen batch width never increments this.
    pub fn reprograms(&self) -> u64 {
        self.reprograms
    }

    /// Run one fused timestep across all batch lanes: per output
    /// pixel, one AccW2V per parity per channel group per
    /// *union*-spiking window tap (lane-masked broadcast — see
    /// `ImpulseMacro::acc_w2v_fused`), then the per-lane fused
    /// neuron-update kernels. `active[b]` gates lanes that still have
    /// work; inactive lanes are untouched (and contribute nothing to
    /// the union). Returns per-lane output spike maps (all-false for
    /// inactive lanes). Bit-identical per lane to running `step`
    /// sequentially.
    pub fn step_batch(
        &mut self,
        batch: &[&SpikeMap],
        active: &[bool],
    ) -> Result<Vec<SpikeMap>> {
        let l = self.layout.clone();
        let lanes = l.lanes();
        anyhow::ensure!(
            batch.len() == lanes && active.len() == lanes,
            "batch of {} lanes, {} active flags; configured for {lanes} (call begin_batch)",
            batch.len(),
            active.len()
        );
        for (b, s) in batch.iter().enumerate() {
            if active[b] {
                anyhow::ensure!(
                    (s.h, s.w, s.c) == (l.h(), l.w(), l.c_in),
                    "lane {b}: input {}×{}×{} != {}×{}×{}",
                    s.h,
                    s.w,
                    s.c,
                    l.h(),
                    l.w(),
                    l.c_in
                );
            }
        }
        let mut out: Vec<SpikeMap> = (0..lanes)
            .map(|_| SpikeMap::new(l.h(), l.w(), l.c_out))
            .collect();
        let groups = l.n_channel_groups as f64;
        let upd = 2.0 * groups * self.params.neuron.instructions_per_update() as f64;
        // per-lane channel-run words of the window position currently
        // being probed (window taps iterate channels innermost, so one
        // packed fetch per (iy, ix) per lane covers all its taps);
        // `bits_at` reads at most 64 bits, so wider channel counts
        // (possible only for 1×1 kernels) fall back to per-bit probes
        let run_ok = l.c_in <= 64;
        let mut runs = [0u64; 32];
        for y in 0..l.h() {
            for x in 0..l.w() {
                // fused union of this pixel's window across lanes
                self.union_rows.clear();
                for (w_row, iy, ix, c) in l.window(y, x) {
                    let mut mask = 0u32;
                    if run_ok {
                        if c == 0 {
                            let start = (iy * l.w() + ix) * l.c_in;
                            for (b, (s, &a)) in batch.iter().zip(active).enumerate() {
                                runs[b] = if a { s.plane().bits_at(start, l.c_in) } else { 0 };
                            }
                        }
                        for (b, r) in runs[..lanes].iter().enumerate() {
                            mask |= (((r >> c) & 1) as u32) << b;
                        }
                    } else {
                        for (b, (s, &a)) in batch.iter().zip(active).enumerate() {
                            if a && s.get(iy, ix, c) {
                                mask |= 1 << b;
                            }
                        }
                    }
                    if mask != 0 {
                        self.union_rows.push((w_row, mask));
                    }
                }
                // Honest attribution: each union tap costs one AccW2V
                // per parity per channel group, split across the lanes
                // that latch it; updates are charged whole below.
                for &(_, mask) in &self.union_rows {
                    let share = 2.0 * groups / mask.count_ones() as f64;
                    let mut mm = mask;
                    while mm != 0 {
                        let b = mm.trailing_zeros() as usize;
                        mm &= mm - 1;
                        self.lane_cycles[b] += share;
                    }
                }
                for (b, &a) in active.iter().enumerate() {
                    if a {
                        self.lane_cycles[b] += upd;
                    }
                }
                for g in 0..l.n_channel_groups {
                    for b in 0..lanes {
                        let a = l.assign_lane(y, x, g, b);
                        self.lane_rows_odd[b] = a.v_row_odd;
                        self.lane_rows_even[b] = a.v_row_even;
                    }
                    let m = &mut self.macros[l.assign_lane(y, x, g, 0).macro_id];
                    m.acc_w2v_fused(&self.union_rows, &self.lane_rows_odd, Parity::Odd)?;
                    m.acc_w2v_fused(&self.union_rows, &self.lane_rows_even, Parity::Even)?;
                    for b in 0..lanes {
                        if !active[b] {
                            continue;
                        }
                        for parity in Parity::BOTH {
                            let v = match parity {
                                Parity::Odd => self.lane_rows_odd[b],
                                Parity::Even => self.lane_rows_even[b],
                            };
                            let spikes = m.neuron_update_fused(
                                self.params.neuron,
                                v,
                                l.const_rows.for_parity(parity),
                                parity,
                            )?;
                            for (field, &sp) in spikes.iter().enumerate() {
                                let local = match parity {
                                    Parity::Odd => 2 * field,
                                    Parity::Even => 2 * field + 1,
                                };
                                let co = g * OUTPUTS_PER_TILE + local;
                                if co < l.c_out && sp {
                                    out[b].set(y, x, co, true);
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    /// Per-lane attributed cycles accumulated since `begin_batch`:
    /// lane `b`'s honest share of this layer's batched spend (fused
    /// AccW2V cycles split across the lanes sharing each union tap,
    /// update cycles charged whole). The sum over lanes equals the
    /// layer's total batched cycle count exactly.
    pub fn lane_attributed_cycles(&self) -> &[f64] {
        &self.lane_cycles
    }

    /// Zero all pixel membrane potentials (all lanes).
    pub fn reset_state(&mut self) -> Result<()> {
        let pairs = self.layout.pixels_per_macro * self.layout.lanes();
        for m in self.macros.iter_mut() {
            for p in 0..pairs {
                m.write_v(2 * p, Parity::Odd, &[0; 6])?;
                m.write_v(2 * p + 1, Parity::Even, &[0; 6])?;
            }
        }
        Ok(())
    }

    pub fn stats(&self) -> LayerStats {
        let mut s = LayerStats::default();
        for m in &self.macros {
            s.cycles += m.cycles();
            for (k, v) in m.counts() {
                *s.histogram.entry(k).or_insert(0) += v;
            }
        }
        s
    }

    pub fn reset_counters(&mut self) {
        for m in self.macros.iter_mut() {
            m.reset_counters();
        }
    }

    pub fn num_macros(&self) -> usize {
        self.macros.len()
    }

    /// Fold the active macro pool's V_MEM rows into a running FNV-1a
    /// digest (see [`ImpulseMacro::fold_vmem_digest`]). Parked pools
    /// (other batch widths) are excluded: only the active pool's
    /// membrane state feeds the next request.
    pub fn fold_vmem_digest(&self, h: &mut u64) {
        for m in &self.macros {
            m.fold_vmem_digest(h);
        }
    }

    /// Emit macro 0's full instruction schedule as a [`Program`]:
    /// kernel-tap programming, per-parity constants, pixel-row
    /// zeroing, then `timesteps` dense timesteps — for each output
    /// pixel this macro owns, every window tap accumulated under both
    /// parities (the all-spiking worst case) followed by the
    /// per-parity neuron-update sequence — ending with a membrane
    /// readout per pixel. Tap *values* are emitted as zeros; row
    /// structure, constants, and ordering mirror
    /// [`ConvLayer::step`]'s issue order exactly, so the static
    /// analyzer (`impulse check`) can prove the conv stream
    /// hazard-free. Every macro in the pool runs the same shape of
    /// schedule over its own pixel set.
    pub fn schedule_program(&self, timesteps: usize) -> Program {
        let l = &self.layout;
        let mut b = Program::new();
        for ky in 0..l.ksize {
            for kx in 0..l.ksize {
                for c in 0..l.c_in {
                    b.push(Instruction::WriteW {
                        w_row: l.tap_row(ky, kx, c),
                        weights: [0; 12],
                    });
                }
            }
        }
        let cr = l.const_rows;
        for parity in Parity::BOTH {
            let r = cr.for_parity(parity);
            b.push(Instruction::WriteV {
                v_row: r.neg_threshold,
                parity,
                values: [-self.params.threshold; 6],
            });
            b.push(Instruction::WriteV {
                v_row: r.reset,
                parity,
                values: [self.params.reset; 6],
            });
            b.push(Instruction::WriteV {
                v_row: r.neg_leak,
                parity,
                values: [-self.params.leak; 6],
            });
        }
        for p in 0..cr.first_row() / 2 {
            b.push(Instruction::WriteV {
                v_row: 2 * p,
                parity: Parity::Odd,
                values: [0; 6],
            });
            b.push(Instruction::WriteV {
                v_row: 2 * p + 1,
                parity: Parity::Even,
                values: [0; 6],
            });
        }
        // pixels whose channel-group-0 assignment lands on macro 0
        let pixels: Vec<(usize, usize)> = (0..l.height)
            .flat_map(|y| (0..l.width).map(move |x| (y, x)))
            .filter(|&(y, x)| l.assign(y, x, 0).macro_id == 0)
            .collect();
        for _ in 0..timesteps {
            for &(y, x) in &pixels {
                let a = l.assign(y, x, 0);
                for (parity, v) in
                    [(Parity::Odd, a.v_row_odd), (Parity::Even, a.v_row_even)]
                {
                    for (w_row, _, _, _) in l.window(y, x) {
                        b.push(Instruction::AccW2V {
                            w_row,
                            v_src: v,
                            v_dst: v,
                            parity,
                        });
                    }
                }
                for (parity, v) in
                    [(Parity::Odd, a.v_row_odd), (Parity::Even, a.v_row_even)]
                {
                    let rows = cr.for_parity(parity);
                    for instr in neuron_sequence(self.params.neuron, v, rows, parity) {
                        b.push(instr);
                    }
                }
            }
        }
        for &(y, x) in &pixels {
            let a = l.assign(y, x, 0);
            b.push(Instruction::ReadV {
                v_row: a.v_row_odd,
                parity: Parity::Odd,
            });
            b.push(Instruction::ReadV {
                v_row: a.v_row_even,
                parity: Parity::Even,
            });
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::XorShiftRng;
    use crate::neuron::{GoldenLayer, NeuronParams};

    /// Golden conv: run each output pixel as an independent golden
    /// neuron bank over its im2col window.
    struct GoldenConv {
        layout: ConvLayout,
        #[allow(dead_code)]
        kernel: Vec<i64>,
        pixels: Vec<GoldenLayer>, // one per output pixel
    }

    impl GoldenConv {
        fn new(
            kernel: Vec<i64>,
            h: usize,
            w: usize,
            c_in: usize,
            c_out: usize,
            p: LayerParams,
        ) -> Self {
            let layout = ConvLayout::new(h, w, c_in, c_out, 3).unwrap();
            let np = NeuronParams {
                neuron: p.neuron,
                threshold: p.threshold,
                reset: p.reset,
                leak: p.leak,
            };
            // weights[tap][co] for the full fan-in (taps = 9*c_in rows)
            let fan = layout.fan_in();
            let mut wm = vec![vec![0i64; c_out]; fan];
            for ky in 0..3 {
                for kx in 0..3 {
                    for c in 0..c_in {
                        for co in 0..c_out {
                            wm[layout.tap_row(ky, kx, c)][co] =
                                kernel[((ky * 3 + kx) * c_in + c) * c_out + co];
                        }
                    }
                }
            }
            let pixels = (0..h * w)
                .map(|_| GoldenLayer::new(np, wm.clone()))
                .collect();
            Self {
                layout,
                kernel,
                pixels,
            }
        }

        fn step(&mut self, input: &SpikeMap) -> SpikeMap {
            let l = &self.layout;
            let mut out = SpikeMap::new(l.h(), l.w(), l.c_out);
            for y in 0..l.h() {
                for x in 0..l.w() {
                    let mut in_spikes = vec![false; l.fan_in()];
                    for (w_row, iy, ix, c) in l.window(y, x) {
                        in_spikes[w_row] = input.get(iy, ix, c);
                    }
                    let s = self.pixels[y * l.w() + x].step(&in_spikes);
                    for (co, &sp) in s.iter().enumerate() {
                        out.set(y, x, co, sp);
                    }
                }
            }
            out
        }
    }

    #[test]
    fn conv_layer_matches_golden_conv() {
        let mut rng = XorShiftRng::new(99);
        let (h, w, c_in, c_out) = (5, 5, 3, 14);
        let n = 9 * c_in * c_out;
        let kernel: Vec<i64> = (0..n).map(|_| rng.gen_i64(-10, 10)).collect();
        let p = LayerParams::rmp(40);
        let mut layer =
            ConvLayer::new(&kernel, h, w, c_in, c_out, 3, p, MacroConfig::fast()).unwrap();
        let mut golden = GoldenConv::new(kernel, h, w, c_in, c_out, p);
        assert_eq!(layer.num_macros(), layer.layout.num_macros());
        for t in 0..6 {
            let mut input = SpikeMap::new(h, w, c_in);
            for y in 0..h {
                for x in 0..w {
                    for c in 0..c_in {
                        input.set(y, x, c, rng.gen_bool(0.25));
                    }
                }
            }
            let got = layer.step(&input).unwrap();
            let want = golden.step(&input);
            assert_eq!(got, want, "t={t}");
        }
    }

    #[test]
    fn silent_input_issues_no_accw2v() {
        let kernel = vec![1i64; 9 * 2 * 4];
        let mut layer = ConvLayer::new(
            &kernel, 4, 4, 2, 4, 3,
            LayerParams::rmp(100),
            MacroConfig::fast(),
        )
        .unwrap();
        layer.step(&SpikeMap::new(4, 4, 2)).unwrap();
        let s = layer.stats();
        assert_eq!(
            s.histogram.get(&crate::isa::InstructionKind::AccW2V),
            None
        );
    }

    fn rand_map(rng: &mut XorShiftRng, h: usize, w: usize, c: usize, p: f64) -> SpikeMap {
        let mut m = SpikeMap::new(h, w, c);
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    m.set(y, x, ch, rng.gen_bool(p));
                }
            }
        }
        m
    }

    /// Batched conv execution must be bit-identical, lane for lane, to
    /// running each lane through its own sequential layer — the
    /// correctness anchor for the fused conv AccW2V path.
    #[test]
    fn step_batch_matches_per_lane_sequential() {
        let mut rng = XorShiftRng::new(321);
        for (params, lanes) in [
            (LayerParams::rmp(40), 4),
            (LayerParams::if_(35), 3),
            (LayerParams::lif(30, 2), 2),
        ] {
            let (h, w, c_in, c_out) = (5, 5, 3, 14);
            let kernel: Vec<i64> =
                (0..9 * c_in * c_out).map(|_| rng.gen_i64(-10, 10)).collect();
            let mut batched =
                ConvLayer::new(&kernel, h, w, c_in, c_out, 3, params, MacroConfig::fast())
                    .unwrap();
            batched.begin_batch(lanes).unwrap();
            let mut refs: Vec<ConvLayer> = (0..lanes)
                .map(|_| {
                    ConvLayer::new(&kernel, h, w, c_in, c_out, 3, params, MacroConfig::fast())
                        .unwrap()
                })
                .collect();
            let active = vec![true; lanes];
            for t in 0..5 {
                let inputs: Vec<SpikeMap> = (0..lanes)
                    .map(|_| rand_map(&mut rng, h, w, c_in, 0.25))
                    .collect();
                let in_refs: Vec<&SpikeMap> = inputs.iter().collect();
                let got = batched.step_batch(&in_refs, &active).unwrap();
                for (b, r) in refs.iter_mut().enumerate() {
                    let want = r.step(&inputs[b]).unwrap();
                    assert_eq!(got[b], want, "t={t} lane {b} {params:?}");
                }
            }
        }
    }

    /// Same check on the lockstep engine: the fused conv path must
    /// drive the bit-level engine through per-lane instruction effects.
    #[test]
    fn step_batch_matches_sequential_on_lockstep_engine() {
        let mut rng = XorShiftRng::new(55);
        let (h, w, c_in, c_out) = (3, 3, 2, 4);
        let kernel: Vec<i64> = (0..9 * c_in * c_out).map(|_| rng.gen_i64(-8, 8)).collect();
        let p = LayerParams::rmp(30);
        let mut batched =
            ConvLayer::new(&kernel, h, w, c_in, c_out, 3, p, MacroConfig::lockstep()).unwrap();
        batched.begin_batch(2).unwrap();
        let mut refs: Vec<ConvLayer> = (0..2)
            .map(|_| {
                ConvLayer::new(&kernel, h, w, c_in, c_out, 3, p, MacroConfig::lockstep())
                    .unwrap()
            })
            .collect();
        for _ in 0..3 {
            let inputs: Vec<SpikeMap> =
                (0..2).map(|_| rand_map(&mut rng, h, w, c_in, 0.3)).collect();
            let in_refs: Vec<&SpikeMap> = inputs.iter().collect();
            let got = batched.step_batch(&in_refs, &[true, true]).unwrap();
            for (b, r) in refs.iter_mut().enumerate() {
                assert_eq!(got[b], r.step(&inputs[b]).unwrap(), "lane {b}");
            }
        }
    }

    /// The fused stream's AccW2V count is the union across lanes, not
    /// the per-lane sum, and the per-lane attribution conserves the
    /// layer's real spend exactly.
    #[test]
    fn step_batch_accw2v_counts_union_and_attribution_conserves() {
        let mut rng = XorShiftRng::new(77);
        let (h, w, c_in, c_out) = (4, 4, 2, 4);
        let kernel: Vec<i64> = (0..9 * c_in * c_out).map(|_| rng.gen_i64(-8, 8)).collect();
        let mut layer = ConvLayer::new(
            &kernel, h, w, c_in, c_out, 3,
            LayerParams::rmp(50),
            MacroConfig::fast(),
        )
        .unwrap();
        layer.begin_batch(4).unwrap();
        layer.reset_counters();
        // all four lanes share one input map → union == single lane
        let shared = rand_map(&mut rng, h, w, c_in, 0.4);
        let refs: Vec<&SpikeMap> = (0..4).map(|_| &shared).collect();
        let active = [true, true, true, false];
        layer.step_batch(&refs, &active).unwrap();
        let s = layer.stats();
        let acc_fused = s.histogram[&crate::isa::InstructionKind::AccW2V];
        // a lone sequential lane pays the same AccW2V count
        let mut solo = ConvLayer::new(
            &kernel, h, w, c_in, c_out, 3,
            LayerParams::rmp(50),
            MacroConfig::fast(),
        )
        .unwrap();
        solo.step(&shared).unwrap();
        assert_eq!(
            acc_fused,
            solo.stats().histogram[&crate::isa::InstructionKind::AccW2V],
            "fused AccW2V must cost the union, not the per-lane sum"
        );
        // attribution conserves the batched spend exactly
        let attributed: f64 = layer.lane_attributed_cycles().iter().sum();
        assert!(
            (attributed - s.cycles as f64).abs() < 1e-6,
            "attributed {attributed} vs spent {}",
            s.cycles
        );
        assert_eq!(layer.lane_attributed_cycles()[3], 0.0, "inactive lane");
    }

    /// Channel counts beyond `bits_at`'s 64-bit run (possible only for
    /// 1×1 kernels) must take the per-bit fallback and stay
    /// bit-identical to sequential stepping.
    #[test]
    fn step_batch_wide_channels_falls_back_to_per_bit_probes() {
        let mut rng = XorShiftRng::new(408);
        let (h, w, c_in, c_out) = (2, 2, 80, 4);
        let kernel: Vec<i64> = (0..c_in * c_out).map(|_| rng.gen_i64(-8, 8)).collect();
        let p = LayerParams::rmp(30);
        let mut batched =
            ConvLayer::new(&kernel, h, w, c_in, c_out, 1, p, MacroConfig::fast()).unwrap();
        batched.begin_batch(2).unwrap();
        let mut refs: Vec<ConvLayer> = (0..2)
            .map(|_| {
                ConvLayer::new(&kernel, h, w, c_in, c_out, 1, p, MacroConfig::fast()).unwrap()
            })
            .collect();
        for t in 0..4 {
            let inputs: Vec<SpikeMap> =
                (0..2).map(|_| rand_map(&mut rng, h, w, c_in, 0.3)).collect();
            let in_refs: Vec<&SpikeMap> = inputs.iter().collect();
            let got = batched.step_batch(&in_refs, &[true, true]).unwrap();
            for (b, r) in refs.iter_mut().enumerate() {
                assert_eq!(got[b], r.step(&inputs[b]).unwrap(), "t={t} lane {b}");
            }
        }
    }

    #[test]
    fn begin_batch_rejects_overflow_and_rearms() {
        let kernel = vec![1i64; 9 * 2 * 4];
        let mut layer = ConvLayer::new(
            &kernel, 4, 4, 2, 4, 3,
            LayerParams::rmp(60),
            MacroConfig::fast(),
        )
        .unwrap();
        assert_eq!(layer.max_batch_lanes(), 13);
        assert!(layer.begin_batch(14).is_err());
        assert!(layer.begin_batch(0).is_err());
        let base_macros = layer.num_macros();
        layer.begin_batch(4).unwrap();
        assert_eq!(layer.batch_lanes(), 4);
        assert!(layer.num_macros() > base_macros, "pool must grow for lanes");
        let m = SpikeMap::new(4, 4, 2);
        let refs: Vec<&SpikeMap> = (0..4).map(|_| &m).collect();
        layer.step_batch(&refs, &[true; 4]).unwrap();
        // re-arming at the same width zeroes lane state, no rebuild
        let n = layer.num_macros();
        layer.begin_batch(4).unwrap();
        assert_eq!(layer.num_macros(), n);
    }

    /// The ROADMAP follow-up: churning between batch widths must not
    /// reprogram the macro pool when a width repeats — each width
    /// costs exactly one programming (cache miss), and a swapped-in
    /// cached pool computes bit-identically to a fresh one.
    #[test]
    fn begin_batch_caches_pools_per_lane_count() {
        let mut rng = XorShiftRng::new(613);
        let (h, w, c_in, c_out) = (4, 4, 2, 4);
        let kernel: Vec<i64> = (0..9 * c_in * c_out).map(|_| rng.gen_i64(-8, 8)).collect();
        let p = LayerParams::rmp(45);
        let mut layer =
            ConvLayer::new(&kernel, h, w, c_in, c_out, 3, p, MacroConfig::fast()).unwrap();
        assert_eq!(layer.reprograms(), 0, "construction is not a begin_batch miss");

        let inputs: Vec<SpikeMap> = (0..3).map(|_| rand_map(&mut rng, h, w, c_in, 0.3)).collect();
        let run = |layer: &mut ConvLayer, lanes: usize| -> Vec<SpikeMap> {
            layer.begin_batch(lanes).unwrap();
            let refs: Vec<&SpikeMap> = inputs.iter().take(lanes).collect();
            layer.step_batch(&refs, &vec![true; lanes]).unwrap()
        };

        // first visits miss (one programming each)…
        let first_w3 = run(&mut layer, 3);
        assert_eq!(layer.reprograms(), 1);
        let first_w1 = run(&mut layer, 1);
        assert_eq!(layer.reprograms(), 1, "the construction pool is cached for width 1");
        // …revisits hit the cache: no reprogram for a repeated width
        let again_w3 = run(&mut layer, 3);
        assert_eq!(layer.reprograms(), 1, "repeating width 3 must not reprogram");
        let again_w1 = run(&mut layer, 1);
        assert_eq!(layer.reprograms(), 1, "repeating width 1 must not reprogram");
        // repeating the *current* width never touches the cache either
        layer.begin_batch(1).unwrap();
        assert_eq!(layer.reprograms(), 1);

        // cached pools compute bit-identically to their first use
        assert_eq!(again_w3, first_w3, "swapped-in width-3 pool must match");
        assert_eq!(again_w1, first_w1, "swapped-in width-1 pool must match");
        // and to a never-cached fresh layer
        let mut fresh =
            ConvLayer::new(&kernel, h, w, c_in, c_out, 3, p, MacroConfig::fast()).unwrap();
        assert_eq!(run(&mut fresh, 3), first_w3, "cache must be invisible to results");
        // a genuinely new width still misses
        let _ = run(&mut layer, 2);
        assert_eq!(layer.reprograms(), 2);
    }

    #[test]
    fn reset_state_clears_potentials() {
        let kernel = vec![5i64; 9 * 2 * 2];
        let mut layer = ConvLayer::new(
            &kernel, 3, 3, 2, 2, 3,
            LayerParams::rmp(500),
            MacroConfig::fast(),
        )
        .unwrap();
        let mut input = SpikeMap::new(3, 3, 2);
        input.set(1, 1, 0, true);
        let o1 = layer.step(&input).unwrap();
        layer.reset_state().unwrap();
        // after reset, same input must give the same output again
        let o2 = layer.step(&input).unwrap();
        assert_eq!(o1, o2);
    }
}
