//! Convolutional layer executor over a macro pool (paper Fig 3b).

use super::{LayerParams, LayerStats, SpikeMap};
use crate::bitcell::Parity;
use crate::isa::neuron_sequence;
use crate::macro_sim::{ImpulseMacro, MacroConfig};
use crate::mapper::{ConvLayout, OUTPUTS_PER_TILE};
use crate::Result;

/// A SAME-padded k×k conv layer distributed across a pool of macros:
/// kernel weights are replicated into every macro of a channel group;
/// each macro owns the membrane potentials of up to 13 output pixels.
pub struct ConvLayer {
    pub layout: ConvLayout,
    macros: Vec<ImpulseMacro>,
    params: LayerParams,
}

impl ConvLayer {
    /// Build from a dense kernel `[ky][kx][c_in][c_out]` (flattened,
    /// 6-bit values).
    pub fn new(
        kernel_flat: &[i64],
        h: usize,
        w: usize,
        c_in: usize,
        c_out: usize,
        ksize: usize,
        params: LayerParams,
        config: MacroConfig,
    ) -> Result<Self> {
        let layout = ConvLayout::new(h, w, c_in, c_out, ksize).map_err(anyhow::Error::from)?;
        assert_eq!(kernel_flat.len(), ksize * ksize * c_in * c_out);
        let mut macros = Vec::with_capacity(layout.num_macros());
        for g in 0..layout.n_channel_groups {
            for _ in 0..layout.macros_per_group() {
                let mut m = ImpulseMacro::new(config);
                for ky in 0..ksize {
                    for kx in 0..ksize {
                        for c in 0..c_in {
                            let row = layout.tile_row_weights(kernel_flat, g, ky, kx, c);
                            m.write_weights(layout.tap_row(ky, kx, c), &row)?;
                        }
                    }
                }
                let cr = layout.const_rows;
                for (parity, thr, rst, lk) in [
                    (Parity::Odd, cr.neg_thr_odd, cr.reset_odd, cr.neg_leak_odd),
                    (Parity::Even, cr.neg_thr_even, cr.reset_even, cr.neg_leak_even),
                ] {
                    m.write_v(thr, parity, &[-params.threshold; 6])?;
                    m.write_v(rst, parity, &[params.reset; 6])?;
                    m.write_v(lk, parity, &[-params.leak; 6])?;
                }
                // zero all pixel V rows
                for p in 0..layout.pixels_per_macro {
                    m.write_v(2 * p, Parity::Odd, &[0; 6])?;
                    m.write_v(2 * p + 1, Parity::Even, &[0; 6])?;
                }
                m.reset_counters();
                macros.push(m);
            }
        }
        Ok(Self {
            layout,
            macros,
            params,
        })
    }

    /// One timestep: returns the output spike map (h × w × c_out).
    pub fn step(&mut self, input: &SpikeMap) -> Result<SpikeMap> {
        let l = &self.layout;
        assert_eq!((input.h, input.w, input.c), (l.h(), l.w(), l.c_in));
        let mut out = SpikeMap::new(l.h(), l.w(), l.c_out);
        let mut spiking_rows: Vec<usize> = Vec::with_capacity(l.fan_in());
        for y in 0..l.h() {
            for x in 0..l.w() {
                // spiking taps of this pixel's window (shared across groups)
                spiking_rows.clear();
                for (w_row, iy, ix, c) in l.window(y, x) {
                    if input.get(iy, ix, c) {
                        spiking_rows.push(w_row);
                    }
                }
                for g in 0..l.n_channel_groups {
                    let a = l.assign(y, x, g);
                    let m = &mut self.macros[a.macro_id];
                    for (parity, v) in
                        [(Parity::Odd, a.v_row_odd), (Parity::Even, a.v_row_even)]
                    {
                        m.acc_w2v_batch(&spiking_rows, v, parity)?;
                    }
                    // neuron update for this pixel
                    for (parity, v) in
                        [(Parity::Odd, a.v_row_odd), (Parity::Even, a.v_row_even)]
                    {
                        let rows = l.const_rows.for_parity(parity);
                        for instr in neuron_sequence(self.params.neuron, v, rows, parity) {
                            m.execute(&instr)?;
                        }
                        let spikes = m.spikes(parity);
                        for (field, &sp) in spikes.iter().enumerate() {
                            let local = match parity {
                                Parity::Odd => 2 * field,
                                Parity::Even => 2 * field + 1,
                            };
                            let co = g * OUTPUTS_PER_TILE + local;
                            if co < l.c_out && sp {
                                out.set(y, x, co, true);
                            }
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    /// Zero all pixel membrane potentials.
    pub fn reset_state(&mut self) -> Result<()> {
        let pixels = self.layout.pixels_per_macro;
        for m in self.macros.iter_mut() {
            for p in 0..pixels {
                m.write_v(2 * p, Parity::Odd, &[0; 6])?;
                m.write_v(2 * p + 1, Parity::Even, &[0; 6])?;
            }
        }
        Ok(())
    }

    pub fn stats(&self) -> LayerStats {
        let mut s = LayerStats::default();
        for m in &self.macros {
            s.cycles += m.cycles();
            for (k, v) in m.counts() {
                *s.histogram.entry(k).or_insert(0) += v;
            }
        }
        s
    }

    pub fn reset_counters(&mut self) {
        for m in self.macros.iter_mut() {
            m.reset_counters();
        }
    }

    pub fn num_macros(&self) -> usize {
        self.macros.len()
    }
}

// Convenience accessors (the layout's field names are h/w-ambiguous).
impl ConvLayout {
    pub fn h(&self) -> usize {
        self.height
    }
    pub fn w(&self) -> usize {
        self.width
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::XorShiftRng;
    use crate::neuron::{GoldenLayer, NeuronParams};

    /// Golden conv: run each output pixel as an independent golden
    /// neuron bank over its im2col window.
    struct GoldenConv {
        layout: ConvLayout,
        #[allow(dead_code)]
        kernel: Vec<i64>,
        pixels: Vec<GoldenLayer>, // one per output pixel
    }

    impl GoldenConv {
        fn new(
            kernel: Vec<i64>,
            h: usize,
            w: usize,
            c_in: usize,
            c_out: usize,
            p: LayerParams,
        ) -> Self {
            let layout = ConvLayout::new(h, w, c_in, c_out, 3).unwrap();
            let np = NeuronParams {
                neuron: p.neuron,
                threshold: p.threshold,
                reset: p.reset,
                leak: p.leak,
            };
            // weights[tap][co] for the full fan-in (taps = 9*c_in rows)
            let fan = layout.fan_in();
            let mut wm = vec![vec![0i64; c_out]; fan];
            for ky in 0..3 {
                for kx in 0..3 {
                    for c in 0..c_in {
                        for co in 0..c_out {
                            wm[layout.tap_row(ky, kx, c)][co] =
                                kernel[((ky * 3 + kx) * c_in + c) * c_out + co];
                        }
                    }
                }
            }
            let pixels = (0..h * w)
                .map(|_| GoldenLayer::new(np, wm.clone()))
                .collect();
            Self {
                layout,
                kernel,
                pixels,
            }
        }

        fn step(&mut self, input: &SpikeMap) -> SpikeMap {
            let l = &self.layout;
            let mut out = SpikeMap::new(l.h(), l.w(), l.c_out);
            for y in 0..l.h() {
                for x in 0..l.w() {
                    let mut in_spikes = vec![false; l.fan_in()];
                    for (w_row, iy, ix, c) in l.window(y, x) {
                        in_spikes[w_row] = input.get(iy, ix, c);
                    }
                    let s = self.pixels[y * l.w() + x].step(&in_spikes);
                    for (co, &sp) in s.iter().enumerate() {
                        out.set(y, x, co, sp);
                    }
                }
            }
            out
        }
    }

    #[test]
    fn conv_layer_matches_golden_conv() {
        let mut rng = XorShiftRng::new(99);
        let (h, w, c_in, c_out) = (5, 5, 3, 14);
        let n = 9 * c_in * c_out;
        let kernel: Vec<i64> = (0..n).map(|_| rng.gen_i64(-10, 10)).collect();
        let p = LayerParams::rmp(40);
        let mut layer =
            ConvLayer::new(&kernel, h, w, c_in, c_out, 3, p, MacroConfig::fast()).unwrap();
        let mut golden = GoldenConv::new(kernel, h, w, c_in, c_out, p);
        assert_eq!(layer.num_macros(), layer.layout.num_macros());
        for t in 0..6 {
            let mut input = SpikeMap::new(h, w, c_in);
            for y in 0..h {
                for x in 0..w {
                    for c in 0..c_in {
                        input.set(y, x, c, rng.gen_bool(0.25));
                    }
                }
            }
            let got = layer.step(&input).unwrap();
            let want = golden.step(&input);
            assert_eq!(got, want, "t={t}");
        }
    }

    #[test]
    fn silent_input_issues_no_accw2v() {
        let kernel = vec![1i64; 9 * 2 * 4];
        let mut layer = ConvLayer::new(
            &kernel, 4, 4, 2, 4, 3,
            LayerParams::rmp(100),
            MacroConfig::fast(),
        )
        .unwrap();
        layer.step(&SpikeMap::new(4, 4, 2)).unwrap();
        let s = layer.stats();
        assert_eq!(
            s.histogram.get(&crate::isa::InstructionKind::AccW2V),
            None
        );
    }

    #[test]
    fn reset_state_clears_potentials() {
        let kernel = vec![5i64; 9 * 2 * 2];
        let mut layer = ConvLayer::new(
            &kernel, 3, 3, 2, 2, 3,
            LayerParams::rmp(500),
            MacroConfig::fast(),
        )
        .unwrap();
        let mut input = SpikeMap::new(3, 3, 2);
        input.set(1, 1, 0, true);
        let o1 = layer.step(&input).unwrap();
        layer.reset_state().unwrap();
        // after reset, same input must give the same output again
        let o2 = layer.step(&input).unwrap();
        assert_eq!(o1, o2);
    }
}
