//! The sentiment network: spike encoder → FC1 → FC2 → output neuron,
//! processing one word per `t_word` timesteps with V_MEM carrying the
//! sequence memory (paper §III, Figs 9b/10/11a).

use super::{Encoder, FcLayer, LayerParams, LayerStats, SparsityTracker};
use crate::data::SentimentArtifacts;
use crate::macro_sim::MacroConfig;
use crate::Result;

/// Result of classifying one review.
#[derive(Clone, Debug)]
pub struct ReviewResult {
    /// Predicted label (1 = positive).
    pub pred: u8,
    /// Final output-neuron membrane potential.
    pub v_out: i64,
    /// V_out after each word (the Fig 10 trace).
    pub vout_trace: Vec<i64>,
    /// Total CIM cycles consumed on the macros.
    pub cycles: u64,
}

/// The mapped sentiment SNN.
pub struct SentimentNetwork {
    emb: Vec<Vec<i64>>,
    pub encoder: Encoder,
    pub fc1: FcLayer,
    pub fc2: FcLayer,
    pub out: FcLayer,
    pub t_word: usize,
    /// Per-layer per-timestep sparsity stats (layers: enc, fc1, fc2).
    pub tracker: SparsityTracker,
}

impl SentimentNetwork {
    /// Build from loaded artifacts.
    pub fn from_artifacts(a: &SentimentArtifacts, config: MacroConfig) -> Result<Self> {
        a.validate()?;
        let w_out: Vec<Vec<i64>> = a.w_out.iter().map(|&w| vec![w]).collect();
        Ok(Self {
            emb: a.emb_q.clone(),
            encoder: Encoder::new(a.w1.len(), a.thr_enc),
            fc1: FcLayer::new(&a.w1, LayerParams::rmp(a.thr1), config)?,
            fc2: FcLayer::new(&a.w2, LayerParams::rmp(a.thr2), config)?,
            out: FcLayer::new(&w_out, LayerParams::rmp(1), config)?.output_only(),
            t_word: 10,
            tracker: SparsityTracker::new(3, 10),
        })
    }

    /// Total macros across mapped layers.
    pub fn num_macros(&self) -> usize {
        self.fc1.num_macros() + self.fc2.num_macros() + self.out.num_macros()
    }

    /// Trainable-parameter count of the mapped model (paper: 29.3K).
    pub fn num_params(&self) -> usize {
        self.fc1.fan_in() * self.fc1.width()
            + self.fc2.fan_in() * self.fc2.width()
            + self.out.fan_in() * self.out.width()
            + 3 // thresholds
    }

    /// Reset all state for a new review.
    pub fn reset_state(&mut self) -> Result<()> {
        self.encoder.reset_state();
        self.fc1.reset_state()?;
        self.fc2.reset_state()?;
        self.out.reset_state()?;
        Ok(())
    }

    /// Classify one review (a slice of word ids; ids < 0 are padding
    /// and terminate the sequence).
    pub fn run_review(&mut self, word_ids: &[i64]) -> Result<ReviewResult> {
        self.reset_state()?;
        let cycles0 = self.total_cycles();
        let mut vout_trace = Vec::new();
        for &wid in word_ids {
            if wid < 0 {
                break;
            }
            let x = &self.emb[wid as usize];
            for t in 0..self.t_word {
                // disjoint field borrows: each layer's output slice is
                // consumed by the next without copying
                let s0 = self.encoder.step(x);
                self.tracker.record(0, t, s0);
                let s1 = self.fc1.step(s0)?;
                self.tracker.record(1, t, s1);
                let s2 = self.fc2.step(s1)?;
                self.tracker.record(2, t, s2);
                self.out.step(s2)?;
            }
            vout_trace.push(self.out.potentials()?[0]);
        }
        let v_out = *vout_trace.last().unwrap_or(&0);
        Ok(ReviewResult {
            pred: (v_out >= 0) as u8,
            v_out,
            vout_trace,
            cycles: self.total_cycles() - cycles0,
        })
    }

    /// Aggregate instruction stats across all mapped layers.
    pub fn stats(&self) -> LayerStats {
        let mut s = self.fc1.stats();
        s.merge(&self.fc2.stats());
        s.merge(&self.out.stats());
        s
    }

    fn total_cycles(&self) -> u64 {
        self.fc1.stats().cycles + self.fc2.stats().cycles + self.out.stats().cycles
    }

    /// Reset counters (keeps weights and state).
    pub fn reset_counters(&mut self) {
        self.fc1.reset_counters();
        self.fc2.reset_counters();
        self.out.reset_counters();
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::bits::XorShiftRng;

    /// Synthetic mini-artifacts for fast tests (no file IO).
    pub(crate) fn mini_artifacts(seed: u64) -> SentimentArtifacts {
        let mut rng = XorShiftRng::new(seed);
        let vocab = 20;
        let emb_q: Vec<Vec<i64>> = (0..vocab)
            .map(|_| (0..100).map(|_| rng.gen_i64(-40, 40)).collect())
            .collect();
        let w1: Vec<Vec<i64>> = (0..100)
            .map(|_| (0..128).map(|_| rng.gen_i64(-6, 6)).collect())
            .collect();
        let w2: Vec<Vec<i64>> = (0..128)
            .map(|_| (0..128).map(|_| rng.gen_i64(-6, 6)).collect())
            .collect();
        let w_out: Vec<i64> = (0..128).map(|_| rng.gen_i64(-10, 10)).collect();
        SentimentArtifacts {
            emb_q,
            w1,
            w2,
            w_out,
            thr_enc: 60,
            thr1: 150,
            thr2: 200,
            test_seqs: vec![vec![1, 2, 3, -1]],
            test_lens: vec![3],
            test_labels: vec![1],
            ref_vout_traces: vec![],
            ref_preds: vec![],
        }
    }

    #[test]
    fn network_builds_with_paper_parameter_count() {
        let a = mini_artifacts(1);
        let net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        // 100·128 + 128·128 + 128 + 3 = 29315 — the paper's 29.3K.
        assert_eq!(net.num_params(), 29315);
        assert_eq!(net.num_macros(), 11 + 11 + 1);
    }

    #[test]
    fn run_review_is_deterministic_and_tracks_words() {
        let a = mini_artifacts(2);
        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let r1 = net.run_review(&[3, 7, 5]).unwrap();
        let r2 = net.run_review(&[3, 7, 5]).unwrap();
        assert_eq!(r1.vout_trace, r2.vout_trace);
        assert_eq!(r1.vout_trace.len(), 3);
        assert!(r1.cycles > 0);
    }

    #[test]
    fn padding_terminates_sequence() {
        let a = mini_artifacts(3);
        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let r = net.run_review(&[4, 2, -1, 9, 9]).unwrap();
        assert_eq!(r.vout_trace.len(), 2);
    }

    #[test]
    fn sparsity_tracker_populated() {
        let a = mini_artifacts(4);
        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        net.run_review(&[1, 2, 3, 4]).unwrap();
        let overall = net.tracker.overall();
        assert!(overall > 0.3 && overall <= 1.0, "sparsity {overall}");
    }

    #[test]
    fn accw2v_count_equals_twice_spike_count() {
        // The scheduler's sparsity contract: every upstream spike costs
        // exactly 2 AccW2V per downstream tile-macro.
        let a = mini_artifacts(5);
        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        net.run_review(&[1, 2]).unwrap();
        let s = net.stats();
        let acc = s.histogram[&crate::isa::InstructionKind::AccW2V];
        assert!(acc > 0);
        // consistency: AccW2V is even (odd+even cycles come in pairs)
        assert_eq!(acc % 2, 0);
    }
}
