//! The sentiment network: spike encoder → FC1 → FC2 → output neuron,
//! processing one word per `t_word` timesteps with V_MEM carrying the
//! sequence memory (paper §III, Figs 9b/10/11a).

use super::{Encoder, FcLayer, LayerParams, LayerStats, SparsityTracker, SpikePlane};
use crate::data::SentimentArtifacts;
use crate::macro_sim::MacroConfig;
use crate::Result;

/// Result of classifying one review.
#[derive(Clone, Debug)]
pub struct ReviewResult {
    /// Predicted label (1 = positive).
    pub pred: u8,
    /// Final output-neuron membrane potential.
    pub v_out: i64,
    /// V_out after each word (the Fig 10 trace).
    pub vout_trace: Vec<i64>,
    /// CIM cycles attributed to this review: the full macro spend when
    /// run alone, or an honest per-request share of the fused chunk
    /// when batched (see [`SentimentNetwork::run_reviews_batched`]).
    pub cycles: u64,
}

/// The mapped sentiment SNN.
pub struct SentimentNetwork {
    emb: Vec<Vec<i64>>,
    pub encoder: Encoder,
    pub fc1: FcLayer,
    pub fc2: FcLayer,
    pub out: FcLayer,
    pub t_word: usize,
    /// Per-layer per-timestep sparsity stats (layers: enc, fc1, fc2).
    pub tracker: SparsityTracker,
    // streaming-session state: set by `begin_stream`, advanced by
    // `stream_words`, read by `stream_read_out`
    stream_ended: bool,
    stream_last_v: i64,
    stream_cycles0: u64,
}

impl SentimentNetwork {
    /// Build from loaded artifacts.
    pub fn from_artifacts(a: &SentimentArtifacts, config: MacroConfig) -> Result<Self> {
        a.validate()?;
        let w_out: Vec<Vec<i64>> = a.w_out.iter().map(|&w| vec![w]).collect();
        Ok(Self {
            emb: a.emb_q.clone(),
            encoder: Encoder::new(a.w1.len(), a.thr_enc),
            fc1: FcLayer::new(&a.w1, LayerParams::rmp(a.thr1), config)?,
            fc2: FcLayer::new(&a.w2, LayerParams::rmp(a.thr2), config)?,
            out: FcLayer::new(&w_out, LayerParams::rmp(1), config)?.output_only(),
            t_word: 10,
            tracker: SparsityTracker::new(3, 10),
            stream_ended: false,
            stream_last_v: 0,
            stream_cycles0: 0,
        })
    }

    /// Total macros across mapped layers.
    pub fn num_macros(&self) -> usize {
        self.fc1.num_macros() + self.fc2.num_macros() + self.out.num_macros()
    }

    /// One representative tile schedule per mapped layer, labeled —
    /// the input to `impulse check` and the validator property tests
    /// (see [`FcLayer::schedule_program`]).
    pub fn schedule_programs(&self, timesteps: usize) -> Vec<(String, crate::isa::Program)> {
        vec![
            ("fc1".into(), self.fc1.schedule_program(timesteps)),
            ("fc2".into(), self.fc2.schedule_program(timesteps)),
            ("out".into(), self.out.schedule_program(timesteps)),
        ]
    }

    /// Trainable-parameter count of the mapped model (paper: 29.3K).
    pub fn num_params(&self) -> usize {
        self.fc1.fan_in() * self.fc1.width()
            + self.fc2.fan_in() * self.fc2.width()
            + self.out.fan_in() * self.out.width()
            + 3 // thresholds
    }

    /// Reset all state for a new review.
    pub fn reset_state(&mut self) -> Result<()> {
        self.encoder.reset_state();
        self.fc1.reset_state()?;
        self.fc2.reset_state()?;
        self.out.reset_state()?;
        Ok(())
    }

    /// Classify one review (a slice of word ids; ids < 0 are padding
    /// and terminate the sequence).
    pub fn run_review(&mut self, word_ids: &[i64]) -> Result<ReviewResult> {
        self.reset_state()?;
        let cycles0 = self.total_cycles();
        let mut vout_trace = Vec::new();
        for &wid in word_ids {
            if wid < 0 {
                break;
            }
            let Some(x) = self.emb.get(wid as usize) else {
                anyhow::bail!(
                    "word id {wid} out of range (vocab {})",
                    self.emb.len()
                );
            };
            for t in 0..self.t_word {
                // disjoint field borrows: each layer's packed output
                // plane is consumed by the next without copying, and
                // sparsity accounting is one popcount per layer
                let s0 = self.encoder.step_plane(x);
                self.tracker.record_plane(0, t, s0);
                let s1 = self.fc1.step_plane(s0)?;
                self.tracker.record_plane(1, t, s1);
                let s2 = self.fc2.step_plane(s1)?;
                self.tracker.record_plane(2, t, s2);
                self.out.step_plane(s2)?;
            }
            vout_trace.push(self.out.potentials()?[0]);
        }
        let v_out = *vout_trace.last().unwrap_or(&0);
        Ok(ReviewResult {
            pred: (v_out >= 0) as u8,
            v_out,
            vout_trace,
            cycles: self.total_cycles() - cycles0,
        })
    }

    /// Begin a pinned-membrane streaming session: reset all layer
    /// state and zero the session's cycle attribution. The serve-side
    /// stream table calls this when a `StreamOpen` claims a lane.
    pub fn begin_stream(&mut self) -> Result<()> {
        self.reset_state()?;
        self.stream_ended = false;
        self.stream_last_v = 0;
        self.stream_cycles0 = self.total_cycles();
        Ok(())
    }

    /// Advance the stream by a chunk of word ids — exactly the
    /// [`SentimentNetwork::run_review`] inner loop, so chunked appends
    /// followed by a read-out are bit-identical (prediction, V_out,
    /// *and* cycles) to the one-shot run on the concatenated ids. A
    /// padding id (< 0) ends the sequence: it and all later words are
    /// ignored, as in the one-shot path. An out-of-range id errors
    /// mid-chunk after earlier words were integrated (appends are not
    /// transactional). Returns cumulative session macro cycles.
    pub fn stream_words(&mut self, word_ids: &[i64]) -> Result<u64> {
        for &wid in word_ids {
            if self.stream_ended {
                break;
            }
            if wid < 0 {
                self.stream_ended = true;
                break;
            }
            let Some(x) = self.emb.get(wid as usize) else {
                anyhow::bail!(
                    "word id {wid} out of range (vocab {})",
                    self.emb.len()
                );
            };
            for t in 0..self.t_word {
                let s0 = self.encoder.step_plane(x);
                self.tracker.record_plane(0, t, s0);
                let s1 = self.fc1.step_plane(s0)?;
                self.tracker.record_plane(1, t, s1);
                let s2 = self.fc2.step_plane(s1)?;
                self.tracker.record_plane(2, t, s2);
                self.out.step_plane(s2)?;
            }
            // the costed per-word V read, same as the one-shot trace —
            // this is what makes the later read-out free
            self.stream_last_v = self.out.potentials()?[0];
        }
        Ok(self.total_cycles() - self.stream_cycles0)
    }

    /// Read `(pred, v_out, cycles)` out of the pinned membrane state
    /// without disturbing it. Free of macro cycles: the costed V read
    /// already happened per word inside
    /// [`SentimentNetwork::stream_words`], mirroring the one-shot
    /// trace read, so read-outs never skew cycle identity.
    pub fn stream_read_out(&self) -> (u8, i64, u64) {
        let v = self.stream_last_v;
        ((v >= 0) as u8, v, self.total_cycles() - self.stream_cycles0)
    }

    /// Batch lanes one pass through the macro pool can host (bounded by
    /// the V_MEM row budget of the mapped layers).
    pub fn max_batch_lanes(&self) -> usize {
        self.fc1
            .max_batch_lanes()
            .min(self.fc2.max_batch_lanes())
            .min(self.out.max_batch_lanes())
    }

    /// Classify a batch of reviews concurrently on the same macro pool:
    /// each review gets its own membrane-potential lane in every tile,
    /// and each timestep issues one fused AccW2V stream per tile whose
    /// instruction count is the *union* of spiking inputs across the
    /// batch (amortizing issue cost — the batching analogue of the
    /// paper's sparsity proportionality). Reviews beyond the lane
    /// budget are processed in chunks.
    ///
    /// Predictions and V_out traces are bit-identical to running each
    /// review through [`SentimentNetwork::run_review`]; per-review
    /// `cycles` report each request's honest share of its chunk —
    /// fused (shared) AccW2V cycles split across the lanes that
    /// latched them, per-lane update/read-out cycles charged whole —
    /// summing exactly to the chunk's total spend.
    pub fn run_reviews_batched(&mut self, reviews: &[&[i64]]) -> Result<Vec<ReviewResult>> {
        let max = self.max_batch_lanes();
        let mut out = Vec::with_capacity(reviews.len());
        for chunk in reviews.chunks(max) {
            out.extend(self.run_batch_chunk(chunk)?);
        }
        Ok(out)
    }

    fn run_batch_chunk(&mut self, reviews: &[&[i64]]) -> Result<Vec<ReviewResult>> {
        let lanes = reviews.len();
        // effective sequences: cut at the first padding id, bounds-check
        let mut seqs: Vec<&[i64]> = Vec::with_capacity(lanes);
        for (b, r) in reviews.iter().enumerate() {
            let end = r.iter().position(|&w| w < 0).unwrap_or(r.len());
            let s = &r[..end];
            for &wid in s {
                anyhow::ensure!(
                    (wid as usize) < self.emb.len(),
                    "lane {b}: word id {wid} out of range (vocab {})",
                    self.emb.len()
                );
            }
            seqs.push(s);
        }
        self.fc1.begin_batch(lanes)?;
        self.fc2.begin_batch(lanes)?;
        self.out.begin_batch(lanes)?;
        let cycles0 = self.total_cycles();
        let mut encoders: Vec<Encoder> = (0..lanes)
            .map(|_| {
                let mut e = self.encoder.clone();
                e.reset_state();
                e
            })
            .collect();
        let max_words = seqs.iter().map(|s| s.len()).max().unwrap_or(0);
        let mut traces: Vec<Vec<i64>> = vec![Vec::new(); lanes];
        let mut active = vec![false; lanes];
        // packed per-lane encoder outputs, reused every timestep — no
        // per-call `Vec<&[bool]>` staging
        let mut enc_out: Vec<SpikePlane> = vec![SpikePlane::new(self.fc1.fan_in()); lanes];
        for wi in 0..max_words {
            for (b, a) in active.iter_mut().enumerate() {
                *a = wi < seqs[b].len();
            }
            for t in 0..self.t_word {
                for b in 0..lanes {
                    if !active[b] {
                        continue;
                    }
                    let x = &self.emb[seqs[b][wi] as usize];
                    let s = encoders[b].step_plane(x);
                    enc_out[b].clone_from(s);
                    self.tracker.record_plane(0, t, s);
                }
                let s1 = self.fc1.step_batch_planes(&enc_out, &active)?;
                for (b, s) in s1.iter().enumerate() {
                    if active[b] {
                        self.tracker.record_plane(1, t, s);
                    }
                }
                let s2 = self.fc2.step_batch_planes(s1, &active)?;
                for (b, s) in s2.iter().enumerate() {
                    if active[b] {
                        self.tracker.record_plane(2, t, s);
                    }
                }
                self.out.step_batch_planes(s2, &active)?;
            }
            for b in 0..lanes {
                if active[b] {
                    traces[b].push(self.out.lane_potentials(b)?[0]);
                }
            }
        }
        let spent = self.total_cycles() - cycles0;
        // Honest per-request attribution: each lane's share of the
        // fused AccW2V issue (split across the lanes latching each
        // union row), its own neuron-update cycles, and its read-out
        // ReadVs — rounded to integers without losing a cycle
        // (largest-remainder apportionment over the chunk's spend).
        let fc1 = self.fc1.lane_attributed_cycles();
        let fc2 = self.fc2.lane_attributed_cycles();
        let out_l = self.out.lane_attributed_cycles();
        let readv_per_trace = (2 * self.out.num_macros()) as f64;
        let weights: Vec<f64> = (0..lanes)
            .map(|b| fc1[b] + fc2[b] + out_l[b] + traces[b].len() as f64 * readv_per_trace)
            .collect();
        let cycles = crate::metrics::apportion(&weights, spent);
        Ok(traces
            .into_iter()
            .zip(cycles)
            .map(|(trace, cycles)| {
                let v_out = *trace.last().unwrap_or(&0);
                ReviewResult {
                    pred: (v_out >= 0) as u8,
                    v_out,
                    vout_trace: trace,
                    cycles,
                }
            })
            .collect())
    }

    /// Classify one review with the hidden layers running as wavefront
    /// pipeline stages (fc1 processes timestep *t* while fc2 processes
    /// *t−1* — the coordinator's `run_stages` engine on the serve
    /// path). Spikes and predictions are bit-identical to
    /// [`SentimentNetwork::run_review`]; the sparsity tracker is not
    /// updated on this path.
    pub fn run_review_pipelined(&mut self, word_ids: &[i64]) -> Result<ReviewResult> {
        self.reset_state()?;
        let cycles0 = self.total_cycles();
        // Encode every timestep up front (the encoder lives off-macro
        // and is cheap); the macro-mapped layers stream behind it.
        let mut inputs = Vec::new();
        for &wid in word_ids {
            if wid < 0 {
                break;
            }
            let Some(x) = self.emb.get(wid as usize) else {
                anyhow::bail!(
                    "word id {wid} out of range (vocab {})",
                    self.emb.len()
                );
            };
            for _ in 0..self.t_word {
                inputs.push(self.encoder.step_plane(x).clone());
            }
        }
        let s2 = crate::coordinator::pipeline::run_stages(
            vec![&mut self.fc1, &mut self.fc2],
            &inputs,
            4,
        )?;
        let mut vout_trace = Vec::new();
        for (i, s) in s2.iter().enumerate() {
            self.out.step_plane(s)?;
            if (i + 1) % self.t_word == 0 {
                vout_trace.push(self.out.potentials()?[0]);
            }
        }
        let v_out = *vout_trace.last().unwrap_or(&0);
        Ok(ReviewResult {
            pred: (v_out >= 0) as u8,
            v_out,
            vout_trace,
            cycles: self.total_cycles() - cycles0,
        })
    }

    /// Aggregate instruction stats across all mapped layers.
    pub fn stats(&self) -> LayerStats {
        let mut s = self.fc1.stats();
        s.merge(&self.fc2.stats());
        s.merge(&self.out.stats());
        s
    }

    /// FNV-1a digest of every mapped macro's V_MEM rows (fc1 → fc2 →
    /// out, tile order within each layer). A pure state read: no
    /// instruction is issued and no counter moves, so two runs that
    /// computed bit-identical membrane state digest identically — the
    /// record/replay checkpoint (`docs/REPLAY.md`).
    pub fn v_digest(&self) -> u64 {
        let mut h = crate::replay::FNV_OFFSET;
        self.fc1.fold_vmem_digest(&mut h);
        self.fc2.fold_vmem_digest(&mut h);
        self.out.fold_vmem_digest(&mut h);
        h
    }

    fn total_cycles(&self) -> u64 {
        self.fc1.stats().cycles + self.fc2.stats().cycles + self.out.stats().cycles
    }

    /// Reset counters (keeps weights and state).
    pub fn reset_counters(&mut self) {
        self.fc1.reset_counters();
        self.fc2.reset_counters();
        self.out.reset_counters();
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Synthetic mini-artifacts for fast tests (no file IO).
    pub(crate) fn mini_artifacts(seed: u64) -> SentimentArtifacts {
        SentimentArtifacts::synthetic(seed)
    }

    #[test]
    fn network_builds_with_paper_parameter_count() {
        let a = mini_artifacts(1);
        let net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        // 100·128 + 128·128 + 128 + 3 = 29315 — the paper's 29.3K.
        assert_eq!(net.num_params(), 29315);
        assert_eq!(net.num_macros(), 11 + 11 + 1);
    }

    #[test]
    fn run_review_is_deterministic_and_tracks_words() {
        let a = mini_artifacts(2);
        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let r1 = net.run_review(&[3, 7, 5]).unwrap();
        let r2 = net.run_review(&[3, 7, 5]).unwrap();
        assert_eq!(r1.vout_trace, r2.vout_trace);
        assert_eq!(r1.vout_trace.len(), 3);
        assert!(r1.cycles > 0);
    }

    #[test]
    fn padding_terminates_sequence() {
        let a = mini_artifacts(3);
        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let r = net.run_review(&[4, 2, -1, 9, 9]).unwrap();
        assert_eq!(r.vout_trace.len(), 2);
    }

    #[test]
    fn sparsity_tracker_populated() {
        let a = mini_artifacts(4);
        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        net.run_review(&[1, 2, 3, 4]).unwrap();
        let overall = net.tracker.overall();
        assert!(overall > 0.3 && overall <= 1.0, "sparsity {overall}");
    }

    /// The flagship batching differential: a mixed-length batch run
    /// through the fused lanes must reproduce every review's sequential
    /// V_out trace and prediction exactly.
    #[test]
    fn batched_reviews_bit_identical_to_sequential() {
        let a = mini_artifacts(6);
        let reviews: Vec<Vec<i64>> = vec![
            vec![3, 7, 5],
            vec![1],
            vec![4, 2, -1, 9, 9], // padding cuts after two words
            vec![0, 19, 8, 11, 6],
            vec![],
            vec![2, 2, 2],
        ];
        let mut seq_net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let want: Vec<ReviewResult> = reviews
            .iter()
            .map(|r| seq_net.run_review(r).unwrap())
            .collect();
        let mut batch_net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let refs: Vec<&[i64]> = reviews.iter().map(|r| r.as_slice()).collect();
        let got = batch_net.run_reviews_batched(&refs).unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.vout_trace, w.vout_trace, "review {i} trace");
            assert_eq!(g.pred, w.pred, "review {i} prediction");
            assert_eq!(g.v_out, w.v_out, "review {i} v_out");
        }
    }

    /// Batches wider than the lane budget chunk transparently.
    #[test]
    fn batched_reviews_chunk_beyond_lane_budget() {
        let a = mini_artifacts(10);
        let reviews: Vec<Vec<i64>> =
            (0..17).map(|i| vec![i % 20, (i * 3) % 20]).collect();
        let mut seq_net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let mut batch_net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        assert!(batch_net.max_batch_lanes() < reviews.len());
        let refs: Vec<&[i64]> = reviews.iter().map(|r| r.as_slice()).collect();
        let got = batch_net.run_reviews_batched(&refs).unwrap();
        for (i, r) in reviews.iter().enumerate() {
            let w = seq_net.run_review(r).unwrap();
            assert_eq!(got[i].vout_trace, w.vout_trace, "review {i}");
            assert_eq!(got[i].pred, w.pred, "review {i}");
        }
    }

    /// Batching must amortize the AccW2V issue: the fused union stream
    /// costs fewer cycles per review than sequential processing.
    #[test]
    fn batched_reviews_cost_less_per_review() {
        let a = mini_artifacts(12);
        let reviews: Vec<Vec<i64>> = (0..8).map(|i| vec![i % 20, (i + 5) % 20]).collect();
        let refs: Vec<&[i64]> = reviews.iter().map(|r| r.as_slice()).collect();

        let mut seq_net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let seq_cycles: u64 = refs
            .iter()
            .map(|r| seq_net.run_review(r).unwrap().cycles)
            .sum();
        let mut batch_net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let batch_cycles: u64 = batch_net
            .run_reviews_batched(&refs)
            .unwrap()
            .iter()
            .map(|r| r.cycles)
            .sum();
        assert!(
            batch_cycles < seq_cycles,
            "fused batch must amortize AccW2V issue: {batch_cycles} >= {seq_cycles}"
        );
    }

    /// Batched `cycles` are an honest per-request attribution, not an
    /// even split: a singleton batch matches its solo run exactly, an
    /// empty lane is charged nothing, and longer reviews pay more.
    #[test]
    fn batched_cycles_attribute_honestly_not_evenly() {
        let a = mini_artifacts(14);
        let long = vec![1i64, 5, 9, 13, 17];
        let short = vec![2i64];
        let empty: Vec<i64> = vec![];
        let mut seq = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let want_long = seq.run_review(&long).unwrap();

        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let got = net.run_reviews_batched(&[&long[..]]).unwrap();
        assert_eq!(got[0].cycles, want_long.cycles, "singleton attribution");

        let got = net.run_reviews_batched(&[&long[..], &empty[..]]).unwrap();
        assert_eq!(got[1].cycles, 0, "empty lane must cost nothing");
        assert_eq!(
            got[0].cycles, want_long.cycles,
            "the sole active lane pays exactly its own work"
        );

        let got = net.run_reviews_batched(&[&long[..], &short[..]]).unwrap();
        assert!(
            got[0].cycles > got[1].cycles,
            "5 words charged {} vs 1 word charged {}",
            got[0].cycles,
            got[1].cycles
        );
        assert!(got[1].cycles > 0);
    }

    /// The streaming differential: the same review split at every
    /// chunk boundary must be bit-identical (prediction, V_out, and
    /// cycles) to the one-shot run.
    #[test]
    fn streamed_review_bit_identical_to_one_shot_at_every_split() {
        let a = mini_artifacts(7);
        let ids = vec![3i64, 7, 5, 1, 9];
        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let want = net.run_review(&ids).unwrap();
        for split in 0..=ids.len() {
            net.begin_stream().unwrap();
            net.stream_words(&ids[..split]).unwrap();
            let cycles = net.stream_words(&ids[split..]).unwrap();
            let (pred, v_out, c2) = net.stream_read_out();
            assert_eq!(pred, want.pred, "split {split}");
            assert_eq!(v_out, want.v_out, "split {split}");
            assert_eq!(cycles, want.cycles, "split {split}");
            assert_eq!(c2, want.cycles, "read-out must be cycle-free");
        }
        // padding mid-stream ends the sequence like the one-shot path
        let want = net.run_review(&[4, 2, -1, 9]).unwrap();
        net.begin_stream().unwrap();
        net.stream_words(&[4, 2]).unwrap();
        net.stream_words(&[-1]).unwrap();
        net.stream_words(&[9]).unwrap();
        let (pred, v_out, cycles) = net.stream_read_out();
        assert_eq!((pred, v_out, cycles), (want.pred, want.v_out, want.cycles));
    }

    #[test]
    fn pipelined_review_matches_sequential() {
        let a = mini_artifacts(8);
        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        for ids in [vec![3i64, 7, 5, 1], vec![4], vec![], vec![2, -1, 9]] {
            let want = net.run_review(&ids).unwrap();
            let got = net.run_review_pipelined(&ids).unwrap();
            assert_eq!(got.vout_trace, want.vout_trace, "{ids:?}");
            assert_eq!(got.pred, want.pred);
            assert_eq!(got.cycles, want.cycles, "same instruction stream");
        }
    }

    #[test]
    fn out_of_range_word_id_is_an_error_not_a_panic() {
        let a = mini_artifacts(9);
        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        assert!(net.run_review(&[999]).is_err());
        assert!(net.run_review_pipelined(&[999]).is_err());
        let refs: Vec<&[i64]> = vec![&[1, 2][..], &[999][..]];
        assert!(net.run_reviews_batched(&refs).is_err());
        // the network still works afterwards
        assert!(net.run_review(&[1, 2]).is_ok());
    }

    #[test]
    fn accw2v_count_equals_twice_spike_count() {
        // The scheduler's sparsity contract: every upstream spike costs
        // exactly 2 AccW2V per downstream tile-macro.
        let a = mini_artifacts(5);
        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        net.run_review(&[1, 2]).unwrap();
        let s = net.stats();
        let acc = s.histogram[&crate::isa::InstructionKind::AccW2V];
        assert!(acc > 0);
        // consistency: AccW2V is even (odd+even cycles come in pairs)
        assert_eq!(acc % 2, 0);
    }
}
