//! Spike encoders — the network input layers, which live *off* the
//! macro (paper: "the input layer acts as spike-encoder"; for the conv
//! net, "the first Conv layer acts as a spike-encoder").

use super::{SpikeMap, SpikePlane};

/// Direct-input encoder: each of `m` neurons integrates its quantized
/// input current every timestep and fires with RMP-style soft reset.
/// Plain i32 state — hardware-exactly matches
/// `python/compile/kernels/ref.py::encoder_step_ref`.
#[derive(Clone, Debug)]
pub struct Encoder {
    pub threshold: i64,
    v: Vec<i64>,
    out: Vec<bool>,
    /// Output spikes in packed form — what the macro-side layers
    /// consume on the plane-native paths.
    out_plane: SpikePlane,
}

impl Encoder {
    pub fn new(m: usize, threshold: i64) -> Self {
        assert!(threshold > 0);
        Self {
            threshold,
            v: vec![0; m],
            out: vec![false; m],
            out_plane: SpikePlane::new(m),
        }
    }

    /// One timestep with input currents `x_q` (length m), producing
    /// the packed spike plane the downstream layers iterate by
    /// popcount. The integration itself is inherently O(m); everything
    /// after this point costs O(active spikes).
    pub fn step_plane(&mut self, x_q: &[i64]) -> &SpikePlane {
        assert_eq!(x_q.len(), self.v.len());
        self.out_plane.clear();
        for (i, (v, &x)) in self.v.iter_mut().zip(x_q).enumerate() {
            *v += x;
            if *v >= self.threshold {
                *v -= self.threshold;
                self.out_plane.set(i, true);
            }
        }
        &self.out_plane
    }

    /// One timestep with input currents `x_q` (length m). Boolean view
    /// of [`Encoder::step_plane`].
    pub fn step(&mut self, x_q: &[i64]) -> &[bool] {
        self.step_plane(x_q);
        self.out_plane.write_bools(&mut self.out);
        &self.out
    }

    pub fn reset_state(&mut self) {
        self.v.iter_mut().for_each(|v| *v = 0);
        self.out.iter_mut().for_each(|o| *o = false);
        self.out_plane.clear();
    }

    pub fn potentials(&self) -> &[i64] {
        &self.v
    }
}

/// Conv spike encoder: a float 3×3 SAME convolution whose output is the
/// constant input current to per-pixel RMP neurons (the digits
/// network's Conv1).
#[derive(Clone, Debug)]
pub struct ConvEncoder {
    /// Kernel `[ky][kx][1][c_out]` flattened row-major.
    kernel: Vec<f32>,
    pub c_out: usize,
    pub ksize: usize,
    pub threshold: f32,
    /// Per-pixel-channel state (f32, off-macro).
    v: Vec<f32>,
    h: usize,
    w: usize,
    /// Cached input currents for the current image.
    current: Vec<f32>,
}

impl ConvEncoder {
    pub fn new(
        kernel: Vec<f32>,
        kernel_shape: &[usize],
        threshold: f32,
        h: usize,
        w: usize,
    ) -> Self {
        assert_eq!(kernel_shape.len(), 4);
        assert_eq!(kernel_shape[2], 1, "encoder expects 1 input channel");
        let (ksize, c_out) = (kernel_shape[0], kernel_shape[3]);
        assert_eq!(kernel.len(), ksize * ksize * c_out);
        Self {
            kernel,
            c_out,
            ksize,
            threshold,
            v: vec![0.0; h * w * c_out],
            h,
            w,
            current: vec![0.0; h * w * c_out],
        }
    }

    /// Load a new image (h×w floats) and precompute the conv currents.
    pub fn set_image(&mut self, image: &[f32]) {
        assert_eq!(image.len(), self.h * self.w);
        let half = self.ksize / 2;
        for y in 0..self.h {
            for x in 0..self.w {
                for co in 0..self.c_out {
                    let mut acc = 0.0f32;
                    for ky in 0..self.ksize {
                        for kx in 0..self.ksize {
                            let iy = y as isize + ky as isize - half as isize;
                            let ix = x as isize + kx as isize - half as isize;
                            if iy < 0
                                || ix < 0
                                || iy >= self.h as isize
                                || ix >= self.w as isize
                            {
                                continue;
                            }
                            let pix = image[iy as usize * self.w + ix as usize];
                            let kidx = (ky * self.ksize + kx) * self.c_out + co;
                            acc += pix * self.kernel[kidx];
                        }
                    }
                    self.current[(y * self.w + x) * self.c_out + co] = acc;
                }
            }
        }
        self.v.iter_mut().for_each(|v| *v = 0.0);
    }

    /// One timestep: integrate the cached currents, fire, soft-reset.
    pub fn step(&mut self) -> SpikeMap {
        let mut out = SpikeMap::new(self.h, self.w, self.c_out);
        for y in 0..self.h {
            for x in 0..self.w {
                for co in 0..self.c_out {
                    let idx = (y * self.w + x) * self.c_out + co;
                    self.v[idx] += self.current[idx];
                    if self.v[idx] >= self.threshold {
                        self.v[idx] -= self.threshold;
                        out.set(y, x, co, true);
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encoder_rate_tracks_current() {
        // current = θ/2 → fires every other step (after the first two).
        let mut e = Encoder::new(1, 10);
        let pattern: Vec<bool> = (0..8).map(|_| e.step(&[5])[0]).collect();
        assert_eq!(pattern, vec![false, true, false, true, false, true, false, true]);
    }

    #[test]
    fn encoder_negative_current_never_fires() {
        let mut e = Encoder::new(2, 10);
        for _ in 0..20 {
            let s = e.step(&[-3, 0]);
            assert_eq!(s, &[false, false]);
        }
        assert_eq!(e.potentials()[0], -60);
        e.reset_state();
        assert_eq!(e.potentials(), &[0, 0]);
    }

    #[test]
    fn step_plane_matches_step() {
        let mut a = Encoder::new(5, 10);
        let mut b = Encoder::new(5, 10);
        for t in 0..20i64 {
            let x: Vec<i64> = (0..5).map(|i| (t * 3 + i) % 13 - 3).collect();
            let want = a.step(&x).to_vec();
            let got = b.step_plane(&x).to_bools();
            assert_eq!(got, want, "t={t}");
            assert_eq!(a.potentials(), b.potentials());
        }
    }

    #[test]
    fn encoder_residual_preserved() {
        let mut e = Encoder::new(1, 10);
        e.step(&[13]); // v=13 ≥ 10 → fire, residual 3
        assert_eq!(e.potentials(), &[3]);
    }

    #[test]
    fn conv_encoder_identity_kernel() {
        // 1×1-ish: 3×3 kernel with only center tap = 1, 1 channel.
        let mut k = vec![0.0f32; 9];
        k[4] = 1.0; // center (ky=1,kx=1), c_out=1
        let mut enc = ConvEncoder::new(k, &[3, 3, 1, 1], 0.5, 4, 4);
        let mut img = vec![0.0f32; 16];
        img[5] = 1.0; // pixel (1,1)
        enc.set_image(&img);
        let s = enc.step();
        assert!(s.get(1, 1, 0));
        assert_eq!(s.flatten().iter().filter(|&&b| b).count(), 1);
    }

    #[test]
    fn conv_encoder_edge_clipping() {
        // all-ones kernel, 1 channel: corner pixel sums a 2×2 region.
        let k = vec![1.0f32; 9];
        let mut enc = ConvEncoder::new(k, &[3, 3, 1, 1], 3.5, 3, 3);
        enc.set_image(&[1.0; 9]);
        let s = enc.step();
        // corner current = 4 ≥ 3.5 fires; center current = 9 fires
        assert!(s.get(0, 0, 0));
        assert!(s.get(1, 1, 0));
    }
}
