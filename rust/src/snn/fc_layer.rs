//! Fully-connected layer executor.

use super::{LayerParams, SpikePlane};
use crate::bitcell::Parity;
use crate::isa::{neuron_sequence, Instruction, InstructionKind, Program};
use crate::macro_sim::{ImpulseMacro, MacroConfig};
use crate::mapper::FcLayout;
use crate::Result;
use std::collections::BTreeMap;

/// Aggregated execution statistics of a layer.
#[derive(Clone, Debug, Default)]
pub struct LayerStats {
    pub cycles: u64,
    pub histogram: BTreeMap<InstructionKind, u64>,
}

impl LayerStats {
    pub fn merge(&mut self, other: &LayerStats) {
        self.cycles += other.cycles;
        for (k, v) in &other.histogram {
            *self.histogram.entry(*k).or_insert(0) += v;
        }
    }
}

/// An FC layer mapped across one macro per 12-output tile.
///
/// With `output_only` the layer skips SpikeCheck/reset entirely: its
/// neurons just integrate (the network's output neurons, read out via
/// their membrane potentials — paper Fig 10).
///
/// Besides the classic one-request [`FcLayer::step`], the layer
/// supports *batch lanes*: lane `b` keeps its membrane potentials in V
/// rows `(2b, 2b+1)` of every tile macro (the rows below the constant
/// block), and [`FcLayer::step_batch`] issues one fused AccW2V stream
/// per tile covering the union of spiking inputs across lanes. Lane 0
/// aliases the single-request rows.
pub struct FcLayer {
    pub layout: FcLayout,
    macros: Vec<ImpulseMacro>,
    params: LayerParams,
    output_only: bool,
    /// Scratch: spike staging buffer reused across timesteps.
    out_spikes: Vec<bool>,
    /// Scratch: packed view of `out_spikes` for the plane-native path.
    out_plane: SpikePlane,
    /// Scratch: spiking input rows of the current timestep.
    spiking_rows: Vec<usize>,
    /// Precomputed neuron-update sequences per parity (fixed rows).
    seq_odd: Vec<crate::isa::Instruction>,
    seq_even: Vec<crate::isa::Instruction>,
    /// Configured batch lanes (1 until `begin_batch` widens it).
    lanes: usize,
    /// Per-lane attributed cycles (fractional) since `begin_batch`:
    /// each fused AccW2V cycle is split across the lanes sharing that
    /// union row; neuron-update cycles are charged to their own lane.
    /// Sums exactly to the layer's batched cycle spend.
    lane_cycles: Vec<f64>,
    /// Per-lane destination V rows, indexed by lane, per parity.
    lane_rows_odd: Vec<usize>,
    lane_rows_even: Vec<usize>,
    /// Scratch: per-lane output spikes (boolean view).
    batch_out: Vec<Vec<bool>>,
    /// Scratch: per-lane output spikes in packed form.
    batch_planes: Vec<SpikePlane>,
    /// Scratch: packed per-lane inputs for the boolean `step_batch`
    /// wrapper (sized lazily, reused across timesteps).
    in_planes: Vec<SpikePlane>,
    /// Scratch: fused spike union `(row, lane mask)` of the timestep.
    union_rows: Vec<(usize, u32)>,
}

impl FcLayer {
    /// Build and program a layer from a dense `[fan_in][width]` weight
    /// matrix of 6-bit values.
    pub fn new(
        weights: &[Vec<i64>],
        params: LayerParams,
        config: MacroConfig,
    ) -> Result<Self> {
        let fan_in = weights.len();
        let width = weights.first().map(|r| r.len()).unwrap_or(0);
        let layout = FcLayout::new(fan_in, width).map_err(anyhow::Error::from)?;
        let mut macros = Vec::with_capacity(layout.tiles.len());
        for tile in &layout.tiles {
            let mut m = ImpulseMacro::new(config);
            for i in 0..fan_in {
                let row = layout.tile_row_weights(weights, tile, i);
                m.write_weights(i, &row)?;
            }
            // constants per alignment
            let c = layout.const_rows;
            for (parity, thr_row, reset_row, leak_row) in [
                (Parity::Odd, c.neg_thr_odd, c.reset_odd, c.neg_leak_odd),
                (Parity::Even, c.neg_thr_even, c.reset_even, c.neg_leak_even),
            ] {
                m.write_v(thr_row, parity, &[-params.threshold; 6])?;
                m.write_v(reset_row, parity, &[params.reset; 6])?;
                m.write_v(leak_row, parity, &[-params.leak; 6])?;
                m.write_v(tile_v_row(tile, parity), parity, &[0; 6])?;
            }
            m.reset_counters(); // programming is not inference cost
            macros.push(m);
        }
        // All tiles share v_row_odd=0 / v_row_even=1, so the update
        // sequences are identical across tiles and fixed for the layer.
        let c = layout.const_rows;
        let seq_odd = neuron_sequence(params.neuron, 0, c.for_parity(Parity::Odd), Parity::Odd);
        let seq_even = neuron_sequence(params.neuron, 1, c.for_parity(Parity::Even), Parity::Even);
        Ok(Self {
            layout,
            macros,
            params,
            output_only: false,
            out_spikes: vec![false; width],
            out_plane: SpikePlane::new(width),
            spiking_rows: Vec::with_capacity(fan_in),
            lanes: 1,
            lane_cycles: vec![0.0],
            lane_rows_odd: vec![0],
            lane_rows_even: vec![1],
            batch_out: vec![vec![false; width]],
            batch_planes: vec![SpikePlane::new(width)],
            in_planes: Vec::new(),
            union_rows: Vec::with_capacity(fan_in),
            seq_odd,
            seq_even,
        })
    }

    /// Mark as an output (integrate-only) layer.
    pub fn output_only(mut self) -> Self {
        self.output_only = true;
        self
    }

    pub fn width(&self) -> usize {
        self.layout.width
    }

    pub fn fan_in(&self) -> usize {
        self.layout.fan_in
    }

    /// Run one timestep: AccW2V per spiking input (both parities), then
    /// the neuron-update sequence (unless output-only). Returns output
    /// spikes (empty for output-only layers).
    pub fn step(&mut self, in_spikes: &[bool]) -> Result<&[bool]> {
        assert_eq!(in_spikes.len(), self.layout.fan_in, "fan-in mismatch");
        // Gather the spiking rows once; no spike → no instruction at all.
        self.spiking_rows.clear();
        for (i, &s) in in_spikes.iter().enumerate() {
            if s {
                self.spiking_rows.push(i);
            }
        }
        self.step_gathered()?;
        Ok(&self.out_spikes)
    }

    /// Plane-native timestep: identical contract to [`FcLayer::step`],
    /// but the spiking-row gather iterates only *set* bits
    /// (`trailing_zeros` over the packed words) — O(popcount) instead
    /// of O(fan-in).
    pub fn step_plane(&mut self, input: &SpikePlane) -> Result<&SpikePlane> {
        assert_eq!(input.len(), self.layout.fan_in, "fan-in mismatch");
        self.spiking_rows.clear();
        self.spiking_rows.extend(input.iter_ones());
        self.step_gathered()?;
        self.out_plane.fill_from_bools(&self.out_spikes);
        Ok(&self.out_plane)
    }

    /// Shared body of `step`/`step_plane`: issue the gathered spiking
    /// rows and the neuron updates, staging output spikes.
    fn step_gathered(&mut self) -> Result<()> {
        for (tile, m) in self.layout.tiles.iter().zip(self.macros.iter_mut()) {
            // 1. sparsity-gated synaptic accumulation (batched hot path)
            for parity in Parity::BOTH {
                m.acc_w2v_batch(&self.spiking_rows, tile_v_row(tile, parity), parity)?;
            }
            if self.output_only {
                continue;
            }
            // 2. neuron update per parity (precomputed sequences)
            for (parity, seq) in
                [(Parity::Odd, &self.seq_odd), (Parity::Even, &self.seq_even)]
            {
                for instr in seq {
                    m.execute(instr)?;
                }
                let spikes = m.spikes(parity);
                for (field, &sp) in spikes.iter().enumerate() {
                    let local = tile.local_out(parity, field);
                    if local < tile.out_count {
                        self.out_spikes[tile.out_base + local] = sp;
                    }
                }
            }
        }
        Ok(())
    }

    /// Maximum batch lanes this layer can host: one odd/even V-row pair
    /// per lane in the rows below the constant block.
    pub fn max_batch_lanes(&self) -> usize {
        (self.layout.const_rows.first_row() / 2).min(crate::macro_sim::MAX_FUSED_LANES)
    }

    /// Configured batch lanes (1 unless `begin_batch` widened it).
    pub fn batch_lanes(&self) -> usize {
        self.lanes
    }

    /// Allocate and zero `lanes` independent batch lanes: lane `b`'s
    /// membrane potentials live in V rows `(2b, 2b+1)` of every tile
    /// macro, updated by the fused per-type neuron kernels against the
    /// shared constant rows. Lane 0 aliases the classic single-request
    /// rows. Also resets the per-lane cycle attribution.
    ///
    /// Scratch buffers (`lane_cycles`, `batch_out`, the packed
    /// planes) are reused whenever the lane count is unchanged —
    /// re-arming a batch of the same width allocates nothing.
    pub fn begin_batch(&mut self, lanes: usize) -> Result<()> {
        anyhow::ensure!(
            lanes >= 1 && lanes <= self.max_batch_lanes(),
            "batch of {lanes} lanes outside 1..={} (V_MEM budget)",
            self.max_batch_lanes()
        );
        self.lanes = lanes;
        self.lane_rows_odd.clear();
        self.lane_rows_even.clear();
        for b in 0..lanes {
            self.lane_rows_odd.push(2 * b);
            self.lane_rows_even.push(2 * b + 1);
        }
        let width = self.layout.width;
        if self.lane_cycles.len() == lanes {
            self.lane_cycles.fill(0.0);
        } else {
            self.lane_cycles = vec![0.0; lanes];
        }
        if self.batch_out.len() == lanes {
            for out in self.batch_out.iter_mut() {
                out.fill(false);
            }
        } else {
            self.batch_out = vec![vec![false; width]; lanes];
        }
        if self.batch_planes.len() == lanes {
            for p in self.batch_planes.iter_mut() {
                p.reset(width);
            }
        } else {
            self.batch_planes = (0..lanes).map(|_| SpikePlane::new(width)).collect();
        }
        for m in self.macros.iter_mut() {
            for b in 0..lanes {
                m.write_v(2 * b, Parity::Odd, &[0; 6])?;
                m.write_v(2 * b + 1, Parity::Even, &[0; 6])?;
            }
        }
        Ok(())
    }

    /// Run one fused timestep across all batch lanes: one AccW2V per
    /// tile per parity per *union*-spiking input row (lane-masked
    /// broadcast — see `ImpulseMacro::acc_w2v_fused`), then the
    /// per-lane neuron-update sequences. `active[b]` gates lanes that
    /// still have work; inactive lanes are untouched. Returns per-lane
    /// output spikes (all-false rows for inactive or output-only
    /// lanes). Bit-identical per lane to running `step` sequentially.
    ///
    /// Boolean wrapper over [`FcLayer::step_batch_planes`] — inputs
    /// are packed into reused scratch planes, outputs expanded back.
    pub fn step_batch(&mut self, batch: &[&[bool]], active: &[bool]) -> Result<&[Vec<bool>]> {
        let lanes = self.lanes;
        anyhow::ensure!(
            batch.len() == lanes && active.len() == lanes,
            "batch of {} lanes, {} active flags; configured for {lanes} (call begin_batch)",
            batch.len(),
            active.len()
        );
        let fan_in = self.layout.fan_in;
        let mut in_planes = std::mem::take(&mut self.in_planes);
        if in_planes.len() != lanes {
            in_planes = (0..lanes).map(|_| SpikePlane::new(fan_in)).collect();
        }
        for ((p, s), &a) in in_planes.iter_mut().zip(batch).zip(active) {
            if a {
                p.fill_from_bools(s);
            } else {
                p.reset(fan_in);
            }
        }
        let res = self.step_batch_planes(&in_planes, active).map(|_| ());
        self.in_planes = in_planes;
        res?;
        for (out, plane) in self.batch_out.iter_mut().zip(&self.batch_planes) {
            plane.write_bools(out);
        }
        Ok(&self.batch_out)
    }

    /// Plane-native fused timestep — the serve path's hot loop. Same
    /// contract as [`FcLayer::step_batch`], but the batch union is
    /// computed word-at-a-time over the packed lanes
    /// ([`crate::snn::spike_union_planes`]) and outputs stay packed,
    /// so per-timestep cost scales with the active spike count.
    pub fn step_batch_planes(
        &mut self,
        batch: &[SpikePlane],
        active: &[bool],
    ) -> Result<&[SpikePlane]> {
        let lanes = self.lanes;
        anyhow::ensure!(
            batch.len() == lanes && active.len() == lanes,
            "batch of {} lanes, {} active flags; configured for {lanes} (call begin_batch)",
            batch.len(),
            active.len()
        );
        for (b, s) in batch.iter().enumerate() {
            if active[b] {
                anyhow::ensure!(
                    s.len() == self.layout.fan_in,
                    "lane {b}: fan-in {} != {}",
                    s.len(),
                    self.layout.fan_in
                );
            }
        }
        crate::snn::spike_union_planes(batch, active, &mut self.union_rows);
        for out in self.batch_planes.iter_mut() {
            out.clear();
        }
        // Honest per-lane cost attribution for this timestep: each
        // union row costs one AccW2V per tile per parity, split across
        // the lanes that latch it; the per-lane neuron updates are
        // charged whole to their lane. Sums exactly to the cycles the
        // macros record, so a chunk's spend apportions losslessly.
        let tiles = self.macros.len() as f64;
        for &(_, mask) in &self.union_rows {
            let share = 2.0 * tiles / mask.count_ones() as f64;
            let mut mm = mask;
            while mm != 0 {
                let b = mm.trailing_zeros() as usize;
                mm &= mm - 1;
                self.lane_cycles[b] += share;
            }
        }
        if !self.output_only {
            let upd = 2.0 * tiles * self.params.neuron.instructions_per_update() as f64;
            for (b, &a) in active.iter().enumerate() {
                if a {
                    self.lane_cycles[b] += upd;
                }
            }
        }
        for (tile, m) in self.layout.tiles.iter().zip(self.macros.iter_mut()) {
            m.acc_w2v_fused(&self.union_rows, &self.lane_rows_odd, Parity::Odd)?;
            m.acc_w2v_fused(&self.union_rows, &self.lane_rows_even, Parity::Even)?;
            if self.output_only {
                continue;
            }
            let c = self.layout.const_rows;
            for b in 0..lanes {
                if !active[b] {
                    continue;
                }
                for parity in Parity::BOTH {
                    // hot kernel: the neuron-update sequence with its
                    // operand rows decoded once — fused for all three
                    // neuron types (IF/LIF/RMP)
                    let spikes = m.neuron_update_fused(
                        self.params.neuron,
                        lane_v_row(b, parity),
                        c.for_parity(parity),
                        parity,
                    )?;
                    for (field, &sp) in spikes.iter().enumerate() {
                        let local = tile.local_out(parity, field);
                        if local < tile.out_count && sp {
                            self.batch_planes[b].set(tile.out_base + local, true);
                        }
                    }
                }
            }
        }
        Ok(&self.batch_planes)
    }

    /// Per-lane attributed cycles accumulated since `begin_batch`:
    /// lane `b`'s honest share of this layer's batched spend (fused
    /// AccW2V cycles split across the lanes sharing each union row,
    /// update cycles charged whole). The sum over lanes equals the
    /// layer's total batched cycle count exactly.
    pub fn lane_attributed_cycles(&self) -> &[f64] {
        &self.lane_cycles
    }

    /// Current membrane potentials of one batch lane's outputs.
    pub fn lane_potentials(&mut self, lane: usize) -> Result<Vec<i64>> {
        anyhow::ensure!(
            lane < self.lanes,
            "lane {lane} >= configured {} lanes",
            self.lanes
        );
        self.potentials_for(2 * lane, 2 * lane + 1)
    }

    /// Current membrane potentials of all outputs.
    pub fn potentials(&mut self) -> Result<Vec<i64>> {
        self.potentials_for(0, 1)
    }

    fn potentials_for(&mut self, v_odd: usize, v_even: usize) -> Result<Vec<i64>> {
        let mut out = vec![0i64; self.layout.width];
        for (tile, m) in self.layout.tiles.iter().zip(self.macros.iter_mut()) {
            for (parity, row) in [(Parity::Odd, v_odd), (Parity::Even, v_even)] {
                let vals = m.read_v(row, parity)?;
                for (field, &v) in vals.iter().enumerate() {
                    let local = tile.local_out(parity, field);
                    if local < tile.out_count {
                        out[tile.out_base + local] = v;
                    }
                }
            }
        }
        Ok(out)
    }

    /// Zero all membrane potentials (new inference).
    pub fn reset_state(&mut self) -> Result<()> {
        for (tile, m) in self.layout.tiles.iter().zip(self.macros.iter_mut()) {
            for parity in Parity::BOTH {
                m.write_v(tile_v_row(tile, parity), parity, &[0; 6])?;
            }
        }
        for s in self.out_spikes.iter_mut() {
            *s = false;
        }
        self.out_plane.clear();
        Ok(())
    }

    /// Aggregate stats across the layer's macros.
    pub fn stats(&self) -> LayerStats {
        let mut s = LayerStats::default();
        for m in &self.macros {
            s.cycles += m.cycles();
            for (k, v) in m.counts() {
                *s.histogram.entry(k).or_insert(0) += v;
            }
        }
        s
    }

    /// Reset instruction counters on all macros.
    pub fn reset_counters(&mut self) {
        for m in self.macros.iter_mut() {
            m.reset_counters();
        }
    }

    /// Number of macros (tiles).
    pub fn num_macros(&self) -> usize {
        self.macros.len()
    }

    /// Fold every tile's V_MEM rows into a running FNV-1a digest (see
    /// [`ImpulseMacro::fold_vmem_digest`]); tile order is the mapping
    /// order, so the digest is stable across runs.
    pub fn fold_vmem_digest(&self, h: &mut u64) {
        for m in &self.macros {
            m.fold_vmem_digest(h);
        }
    }

    /// The layer's neuron parameters.
    pub fn params(&self) -> LayerParams {
        self.params
    }

    /// Emit one tile's full instruction schedule as a [`Program`]:
    /// weight/constant programming, membrane zeroing, then
    /// `timesteps` dense timesteps (every input row accumulated under
    /// both parities — the all-spiking worst case — followed by the
    /// per-parity neuron-update sequence unless the layer is
    /// output-only), ending with a membrane readout. All tiles share
    /// the same row assignment, so one tile's schedule stands for the
    /// layer's. Weight *values* are emitted as zeros (the layer does
    /// not retain its dense matrix); row structure, constants, and
    /// ordering are exactly what [`FcLayer::new`] + [`FcLayer::step`]
    /// issue, so the static analyzer (`impulse check`) can prove the
    /// layer's stream hazard-free.
    pub fn schedule_program(&self, timesteps: usize) -> Program {
        let mut b = Program::new();
        for w_row in 0..self.layout.fan_in {
            b.push(Instruction::WriteW {
                w_row,
                weights: [0; 12],
            });
        }
        let c = self.layout.const_rows;
        for (parity, v_row) in [(Parity::Odd, 0usize), (Parity::Even, 1usize)] {
            let r = c.for_parity(parity);
            b.push(Instruction::WriteV {
                v_row: r.neg_threshold,
                parity,
                values: [-self.params.threshold; 6],
            });
            b.push(Instruction::WriteV {
                v_row: r.reset,
                parity,
                values: [self.params.reset; 6],
            });
            b.push(Instruction::WriteV {
                v_row: r.neg_leak,
                parity,
                values: [-self.params.leak; 6],
            });
            b.push(Instruction::WriteV {
                v_row,
                parity,
                values: [0; 6],
            });
        }
        for _ in 0..timesteps {
            for (parity, v_row) in [(Parity::Odd, 0usize), (Parity::Even, 1usize)] {
                for w_row in 0..self.layout.fan_in {
                    b.push(Instruction::AccW2V {
                        w_row,
                        v_src: v_row,
                        v_dst: v_row,
                        parity,
                    });
                }
            }
            if !self.output_only {
                for instr in self.seq_odd.iter().chain(self.seq_even.iter()) {
                    b.push(*instr);
                }
            }
        }
        b.push(Instruction::ReadV {
            v_row: 0,
            parity: Parity::Odd,
        });
        b.push(Instruction::ReadV {
            v_row: 1,
            parity: Parity::Even,
        });
        b
    }
}

#[inline]
fn tile_v_row(tile: &crate::mapper::TileMapping, parity: Parity) -> usize {
    match parity {
        Parity::Odd => tile.v_row_odd,
        Parity::Even => tile.v_row_even,
    }
}

/// Batch lane `b`'s V row for one parity: the pair `(2b, 2b+1)` below
/// the constant block (lane 0 aliases the single-request rows).
#[inline]
fn lane_v_row(lane: usize, parity: Parity) -> usize {
    match parity {
        Parity::Odd => 2 * lane,
        Parity::Even => 2 * lane + 1,
    }
}

/// Reference check helper shared by tests: dense golden layer built
/// from the same weights.
#[cfg(test)]
pub(crate) fn golden_of(
    weights: &[Vec<i64>],
    params: LayerParams,
) -> crate::neuron::GoldenLayer {
    let p = crate::neuron::NeuronParams {
        neuron: params.neuron,
        threshold: params.threshold,
        reset: params.reset,
        leak: params.leak,
    };
    crate::neuron::GoldenLayer::new(p, weights.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::XorShiftRng;

    fn rand_weights(rng: &mut XorShiftRng, m: usize, n: usize) -> Vec<Vec<i64>> {
        (0..m)
            .map(|_| (0..n).map(|_| rng.gen_i64(-20, 20)).collect())
            .collect()
    }

    fn rand_spikes(rng: &mut XorShiftRng, m: usize, p: f64) -> Vec<bool> {
        (0..m).map(|_| rng.gen_bool(p)).collect()
    }

    /// The macro-mapped layer must match the functional golden layer
    /// bit-for-bit over many random timesteps — the end-to-end
    /// correctness anchor for the whole mapping + macro stack.
    #[test]
    fn fc_layer_matches_golden_layer() {
        let mut rng = XorShiftRng::new(2024);
        for (m_in, n_out, neuron) in [
            (100, 128, LayerParams::rmp(150)),
            (128, 128, LayerParams::if_(100)),
            (64, 17, LayerParams::lif(80, 3)),
            (5, 3, LayerParams::rmp(25)),
        ] {
            let w = rand_weights(&mut rng, m_in, n_out);
            let mut layer = FcLayer::new(&w, neuron, MacroConfig::fast()).unwrap();
            let mut golden = golden_of(&w, neuron);
            for t in 0..30 {
                let spikes = rand_spikes(&mut rng, m_in, 0.2);
                let got = layer.step(&spikes).unwrap().to_vec();
                let want = golden.step(&spikes);
                assert_eq!(got, want, "t={t} {neuron:?}");
                assert_eq!(
                    layer.potentials().unwrap(),
                    golden.potentials(),
                    "t={t} potentials"
                );
            }
        }
    }

    #[test]
    fn fc_layer_matches_golden_on_bit_level_engine() {
        let mut rng = XorShiftRng::new(77);
        let w = rand_weights(&mut rng, 40, 24);
        let p = LayerParams::rmp(60);
        let mut layer = FcLayer::new(&w, p, MacroConfig::lockstep()).unwrap();
        let mut golden = golden_of(&w, p);
        for _ in 0..10 {
            let spikes = rand_spikes(&mut rng, 40, 0.3);
            assert_eq!(layer.step(&spikes).unwrap().to_vec(), golden.step(&spikes));
        }
    }

    #[test]
    fn no_input_spikes_issue_no_accw2v() {
        let mut rng = XorShiftRng::new(5);
        let w = rand_weights(&mut rng, 32, 12);
        let mut layer = FcLayer::new(&w, LayerParams::rmp(100), MacroConfig::fast()).unwrap();
        layer.step(&[false; 32]).unwrap();
        let s = layer.stats();
        assert_eq!(s.histogram.get(&InstructionKind::AccW2V), None);
        // neuron update still runs: 2 SpikeChecks (odd+even), 2 AccV2V
        assert_eq!(s.histogram[&InstructionKind::SpikeCheck], 2);
    }

    #[test]
    fn instruction_count_scales_with_spikes() {
        let mut rng = XorShiftRng::new(6);
        let w = rand_weights(&mut rng, 128, 12);
        let mut layer = FcLayer::new(&w, LayerParams::rmp(100), MacroConfig::fast()).unwrap();
        let mut spikes = vec![false; 128];
        for i in 0..32 {
            spikes[i * 4] = true;
        }
        layer.step(&spikes).unwrap();
        let s = layer.stats();
        assert_eq!(s.histogram[&InstructionKind::AccW2V], 64); // 32 spikes × 2 parities
    }

    #[test]
    fn output_only_layer_integrates_without_spiking() {
        let w = vec![vec![5i64], vec![7i64]];
        let mut layer = FcLayer::new(&w, LayerParams::rmp(1000), MacroConfig::fast())
            .unwrap()
            .output_only();
        for _ in 0..3 {
            let out = layer.step(&[true, true]).unwrap();
            assert!(out.iter().all(|&s| !s));
        }
        assert_eq!(layer.potentials().unwrap(), vec![36]);
        let s = layer.stats();
        assert_eq!(s.histogram.get(&InstructionKind::SpikeCheck), None);
    }

    #[test]
    fn reset_state_zeroes_potentials() {
        let w = vec![vec![10i64; 12]; 4];
        let mut layer = FcLayer::new(&w, LayerParams::rmp(500), MacroConfig::fast()).unwrap();
        layer.step(&[true, true, true, true]).unwrap();
        assert!(layer.potentials().unwrap().iter().any(|&v| v != 0));
        layer.reset_state().unwrap();
        assert!(layer.potentials().unwrap().iter().all(|&v| v == 0));
    }

    /// Batched execution must be bit-identical, lane for lane, to
    /// running each lane through its own sequential layer — the
    /// correctness anchor for the fused AccW2V path.
    #[test]
    fn step_batch_matches_per_lane_sequential() {
        let mut rng = XorShiftRng::new(99);
        for (m_in, n_out, params, lanes) in [
            (100, 128, LayerParams::rmp(150), 4),
            (64, 24, LayerParams::if_(100), 13),
            (32, 17, LayerParams::lif(80, 3), 2),
        ] {
            let w = rand_weights(&mut rng, m_in, n_out);
            let mut batched = FcLayer::new(&w, params, MacroConfig::fast()).unwrap();
            batched.begin_batch(lanes).unwrap();
            let mut refs: Vec<FcLayer> = (0..lanes)
                .map(|_| FcLayer::new(&w, params, MacroConfig::fast()).unwrap())
                .collect();
            let active = vec![true; lanes];
            for t in 0..12 {
                let spikes: Vec<Vec<bool>> = (0..lanes)
                    .map(|_| rand_spikes(&mut rng, m_in, 0.25))
                    .collect();
                let spike_refs: Vec<&[bool]> = spikes.iter().map(|s| s.as_slice()).collect();
                let got = batched.step_batch(&spike_refs, &active).unwrap().to_vec();
                for (b, r) in refs.iter_mut().enumerate() {
                    let want = r.step(&spikes[b]).unwrap().to_vec();
                    assert_eq!(got[b], want, "t={t} lane {b} spikes {params:?}");
                }
                for (b, r) in refs.iter_mut().enumerate() {
                    assert_eq!(
                        batched.lane_potentials(b).unwrap(),
                        r.potentials().unwrap(),
                        "t={t} lane {b} potentials"
                    );
                }
            }
        }
    }

    /// Same check on the lockstep engine: the fused path must drive the
    /// bit-level engine through per-lane instruction effects.
    #[test]
    fn step_batch_matches_sequential_on_lockstep_engine() {
        let mut rng = XorShiftRng::new(123);
        let w = rand_weights(&mut rng, 24, 12);
        let p = LayerParams::rmp(60);
        let mut batched = FcLayer::new(&w, p, MacroConfig::lockstep()).unwrap();
        batched.begin_batch(3).unwrap();
        let mut refs: Vec<FcLayer> = (0..3)
            .map(|_| FcLayer::new(&w, p, MacroConfig::lockstep()).unwrap())
            .collect();
        for _ in 0..5 {
            let spikes: Vec<Vec<bool>> =
                (0..3).map(|_| rand_spikes(&mut rng, 24, 0.3)).collect();
            let spike_refs: Vec<&[bool]> = spikes.iter().map(|s| s.as_slice()).collect();
            let got = batched.step_batch(&spike_refs, &[true, true, true]).unwrap().to_vec();
            for (b, r) in refs.iter_mut().enumerate() {
                assert_eq!(got[b], r.step(&spikes[b]).unwrap().to_vec(), "lane {b}");
            }
        }
    }

    /// The fused stream's AccW2V count is the union across lanes, not
    /// the per-lane sum — the batching cost model.
    #[test]
    fn step_batch_accw2v_counts_union_not_sum() {
        let mut rng = XorShiftRng::new(7);
        let w = rand_weights(&mut rng, 16, 12);
        let mut layer = FcLayer::new(&w, LayerParams::rmp(100), MacroConfig::fast()).unwrap();
        layer.begin_batch(4).unwrap();
        layer.reset_counters();
        // all four lanes spike on the same 3 rows → union = 3
        let mut s = vec![false; 16];
        s[1] = true;
        s[5] = true;
        s[9] = true;
        let refs: Vec<&[bool]> = (0..4).map(|_| s.as_slice()).collect();
        layer.step_batch(&refs, &[true; 4]).unwrap();
        let h = layer.stats().histogram;
        // 3 union rows × 2 parities (one tile), not 12 spikes × 2
        assert_eq!(h[&InstructionKind::AccW2V], 6);
        // neuron updates stay per-lane: 4 lanes × 2 SpikeChecks
        assert_eq!(h[&InstructionKind::SpikeCheck], 8);
    }

    /// The per-lane cycle attribution must conserve the layer's real
    /// batched spend exactly (fused cycles split by lane mask, update
    /// cycles charged whole), with inactive lanes charged nothing.
    #[test]
    fn lane_attributed_cycles_conserve_layer_spend() {
        let mut rng = XorShiftRng::new(44);
        for params in [
            LayerParams::rmp(120),
            LayerParams::if_(90),
            LayerParams::lif(70, 2),
        ] {
            let w = rand_weights(&mut rng, 48, 30); // 3 tiles
            let mut layer = FcLayer::new(&w, params, MacroConfig::fast()).unwrap();
            layer.begin_batch(4).unwrap();
            layer.reset_counters();
            let active = [true, true, true, false];
            for _ in 0..6 {
                let spikes: Vec<Vec<bool>> =
                    (0..4).map(|_| rand_spikes(&mut rng, 48, 0.3)).collect();
                let refs: Vec<&[bool]> = spikes.iter().map(|s| s.as_slice()).collect();
                layer.step_batch(&refs, &active).unwrap();
            }
            let attributed: f64 = layer.lane_attributed_cycles().iter().sum();
            let spent = layer.stats().cycles as f64;
            assert!(
                (attributed - spent).abs() < 1e-6,
                "{params:?}: attributed {attributed} vs spent {spent}"
            );
            assert_eq!(layer.lane_attributed_cycles()[3], 0.0, "inactive lane");
            assert!(layer.lane_attributed_cycles()[..3].iter().all(|&c| c > 0.0));
        }
    }

    /// PR 5 differential: the plane-native batch path must be
    /// bit-identical to the boolean `&[bool]` path at input sparsities
    /// {0.0, 0.15, 0.85, 1.0} — outputs, potentials, cycle spend, and
    /// per-lane attribution alike.
    #[test]
    fn step_batch_planes_matches_bool_path_at_sparsities() {
        use crate::snn::SpikePlane;
        let mut rng = XorShiftRng::new(5150);
        for &sparsity in &[0.0f64, 0.15, 0.85, 1.0] {
            let w = rand_weights(&mut rng, 100, 30);
            let params = LayerParams::rmp(120);
            let lanes = 4;
            let mut bool_layer = FcLayer::new(&w, params, MacroConfig::fast()).unwrap();
            bool_layer.begin_batch(lanes).unwrap();
            let mut plane_layer = FcLayer::new(&w, params, MacroConfig::fast()).unwrap();
            plane_layer.begin_batch(lanes).unwrap();
            let active = vec![true; lanes];
            for t in 0..8 {
                let spikes: Vec<Vec<bool>> = (0..lanes)
                    .map(|_| rand_spikes(&mut rng, 100, 1.0 - sparsity))
                    .collect();
                let planes: Vec<SpikePlane> =
                    spikes.iter().map(|s| SpikePlane::from_bools(s)).collect();
                let refs: Vec<&[bool]> = spikes.iter().map(|s| s.as_slice()).collect();
                let want = bool_layer.step_batch(&refs, &active).unwrap().to_vec();
                let got: Vec<Vec<bool>> = plane_layer
                    .step_batch_planes(&planes, &active)
                    .unwrap()
                    .iter()
                    .map(|p| p.to_bools())
                    .collect();
                assert_eq!(got, want, "s={sparsity} t={t}");
                for b in 0..lanes {
                    assert_eq!(
                        plane_layer.lane_potentials(b).unwrap(),
                        bool_layer.lane_potentials(b).unwrap(),
                        "s={sparsity} t={t} lane {b}"
                    );
                }
            }
            assert_eq!(
                plane_layer.stats().cycles,
                bool_layer.stats().cycles,
                "s={sparsity}: plane path must issue the identical stream"
            );
            assert_eq!(
                plane_layer.lane_attributed_cycles(),
                bool_layer.lane_attributed_cycles(),
                "s={sparsity}"
            );
        }
    }

    /// Sequential plane stepping must match the boolean path exactly
    /// (same gather → same instruction stream → same spikes).
    #[test]
    fn step_plane_matches_step() {
        let mut rng = XorShiftRng::new(616);
        let w = rand_weights(&mut rng, 64, 20);
        for params in [
            LayerParams::rmp(90),
            LayerParams::if_(70),
            LayerParams::lif(60, 2),
        ] {
            let mut a = FcLayer::new(&w, params, MacroConfig::fast()).unwrap();
            let mut b = FcLayer::new(&w, params, MacroConfig::fast()).unwrap();
            for t in 0..10 {
                let spikes = rand_spikes(&mut rng, 64, 0.25);
                let want = a.step(&spikes).unwrap().to_vec();
                let got = b
                    .step_plane(&crate::snn::SpikePlane::from_bools(&spikes))
                    .unwrap()
                    .to_bools();
                assert_eq!(got, want, "{params:?} t={t}");
            }
            assert_eq!(a.potentials().unwrap(), b.potentials().unwrap());
            assert_eq!(a.stats().cycles, b.stats().cycles);
        }
    }

    /// Re-arming a batch at the same width must not grow the scratch
    /// buffers — the PR 5 allocation-churn fix (buffers are reused, so
    /// results stay bit-identical across re-arms).
    #[test]
    fn begin_batch_reuses_scratch_across_rearms() {
        let mut rng = XorShiftRng::new(99182);
        let w = rand_weights(&mut rng, 32, 12);
        let mut layer = FcLayer::new(&w, LayerParams::rmp(80), MacroConfig::fast()).unwrap();
        let spikes: Vec<Vec<bool>> = (0..3).map(|_| rand_spikes(&mut rng, 32, 0.3)).collect();
        let refs: Vec<&[bool]> = spikes.iter().map(|s| s.as_slice()).collect();
        layer.begin_batch(3).unwrap();
        let first = layer.step_batch(&refs, &[true; 3]).unwrap().to_vec();
        // repeated re-arms at the same width reuse every buffer and
        // reproduce the run exactly
        for _ in 0..3 {
            layer.begin_batch(3).unwrap();
            let again = layer.step_batch(&refs, &[true; 3]).unwrap().to_vec();
            assert_eq!(again, first);
        }
        // width change still reshapes correctly
        layer.begin_batch(2).unwrap();
        let two = layer.step_batch(&refs[..2], &[true; 2]).unwrap();
        assert_eq!(two.len(), 2);
    }

    #[test]
    fn step_batch_skips_inactive_lanes() {
        let mut rng = XorShiftRng::new(8);
        let w = rand_weights(&mut rng, 8, 6);
        let mut layer = FcLayer::new(&w, LayerParams::rmp(50), MacroConfig::fast()).unwrap();
        layer.begin_batch(2).unwrap();
        let s_live = vec![true; 8];
        let s_dead = vec![true; 8]; // would spike if it were active
        layer
            .step_batch(&[&s_live[..], &s_dead[..]], &[true, false])
            .unwrap();
        assert!(layer.lane_potentials(0).unwrap().iter().any(|&v| v != 0));
        assert!(layer.lane_potentials(1).unwrap().iter().all(|&v| v == 0));
    }

    #[test]
    fn begin_batch_rejects_overflow_and_resets_lanes() {
        let w = vec![vec![1i64; 4]; 4];
        let mut layer = FcLayer::new(&w, LayerParams::rmp(10), MacroConfig::fast()).unwrap();
        assert_eq!(layer.max_batch_lanes(), 13);
        assert!(layer.begin_batch(14).is_err());
        assert!(layer.begin_batch(0).is_err());
        layer.begin_batch(2).unwrap();
        assert_eq!(layer.batch_lanes(), 2);
        let s = vec![true; 4];
        layer.step_batch(&[&s[..], &s[..]], &[true, true]).unwrap();
        // re-arming zeroes lane state
        layer.begin_batch(2).unwrap();
        assert!(layer.lane_potentials(0).unwrap().iter().all(|&v| v == 0));
        assert!(layer.lane_potentials(1).unwrap().iter().all(|&v| v == 0));
    }

    #[test]
    fn wide_layer_spans_tiles_correctly() {
        // width 30 → 3 tiles (12+12+6); verify weight placement via a
        // delta: input 2 spikes, all others silent.
        let mut w = vec![vec![0i64; 30]; 8];
        for o in 0..30 {
            w[2][o] = (o as i64 % 25) - 12;
        }
        let mut layer = FcLayer::new(&w, LayerParams::rmp(1000), MacroConfig::fast()).unwrap();
        assert_eq!(layer.num_macros(), 3);
        let mut spikes = vec![false; 8];
        spikes[2] = true;
        layer.step(&spikes).unwrap();
        let v = layer.potentials().unwrap();
        for o in 0..30 {
            assert_eq!(v[o], (o as i64 % 25) - 12, "o={o}");
        }
    }
}
