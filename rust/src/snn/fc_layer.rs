//! Fully-connected layer executor.

use super::LayerParams;
use crate::bitcell::Parity;
use crate::isa::{neuron_sequence, InstructionKind};
use crate::macro_sim::{ImpulseMacro, MacroConfig};
use crate::mapper::FcLayout;
use crate::Result;
use std::collections::BTreeMap;

/// Aggregated execution statistics of a layer.
#[derive(Clone, Debug, Default)]
pub struct LayerStats {
    pub cycles: u64,
    pub histogram: BTreeMap<InstructionKind, u64>,
}

impl LayerStats {
    pub fn merge(&mut self, other: &LayerStats) {
        self.cycles += other.cycles;
        for (k, v) in &other.histogram {
            *self.histogram.entry(*k).or_insert(0) += v;
        }
    }
}

/// An FC layer mapped across one macro per 12-output tile.
///
/// With `output_only` the layer skips SpikeCheck/reset entirely: its
/// neurons just integrate (the network's output neurons, read out via
/// their membrane potentials — paper Fig 10).
pub struct FcLayer {
    pub layout: FcLayout,
    macros: Vec<ImpulseMacro>,
    params: LayerParams,
    output_only: bool,
    /// Scratch: spike staging buffer reused across timesteps.
    out_spikes: Vec<bool>,
    /// Scratch: spiking input rows of the current timestep.
    spiking_rows: Vec<usize>,
    /// Precomputed neuron-update sequences per parity (fixed rows).
    seq_odd: Vec<crate::isa::Instruction>,
    seq_even: Vec<crate::isa::Instruction>,
}

impl FcLayer {
    /// Build and program a layer from a dense `[fan_in][width]` weight
    /// matrix of 6-bit values.
    pub fn new(
        weights: &[Vec<i64>],
        params: LayerParams,
        config: MacroConfig,
    ) -> Result<Self> {
        let fan_in = weights.len();
        let width = weights.first().map(|r| r.len()).unwrap_or(0);
        let layout = FcLayout::new(fan_in, width).map_err(anyhow::Error::from)?;
        let mut macros = Vec::with_capacity(layout.tiles.len());
        for tile in &layout.tiles {
            let mut m = ImpulseMacro::new(config);
            for i in 0..fan_in {
                let row = layout.tile_row_weights(weights, tile, i);
                m.write_weights(i, &row)?;
            }
            // constants per alignment
            let c = layout.const_rows;
            for (parity, thr_row, reset_row, leak_row) in [
                (Parity::Odd, c.neg_thr_odd, c.reset_odd, c.neg_leak_odd),
                (Parity::Even, c.neg_thr_even, c.reset_even, c.neg_leak_even),
            ] {
                m.write_v(thr_row, parity, &[-params.threshold; 6])?;
                m.write_v(reset_row, parity, &[params.reset; 6])?;
                m.write_v(leak_row, parity, &[-params.leak; 6])?;
                m.write_v(tile_v_row(tile, parity), parity, &[0; 6])?;
            }
            m.reset_counters(); // programming is not inference cost
            macros.push(m);
        }
        // All tiles share v_row_odd=0 / v_row_even=1, so the update
        // sequences are identical across tiles and fixed for the layer.
        let c = layout.const_rows;
        let seq_odd = neuron_sequence(params.neuron, 0, c.for_parity(Parity::Odd), Parity::Odd);
        let seq_even = neuron_sequence(params.neuron, 1, c.for_parity(Parity::Even), Parity::Even);
        Ok(Self {
            layout,
            macros,
            params,
            output_only: false,
            out_spikes: vec![false; width],
            spiking_rows: Vec::with_capacity(fan_in),
            seq_odd,
            seq_even,
        })
    }

    /// Mark as an output (integrate-only) layer.
    pub fn output_only(mut self) -> Self {
        self.output_only = true;
        self
    }

    pub fn width(&self) -> usize {
        self.layout.width
    }

    pub fn fan_in(&self) -> usize {
        self.layout.fan_in
    }

    /// Run one timestep: AccW2V per spiking input (both parities), then
    /// the neuron-update sequence (unless output-only). Returns output
    /// spikes (empty for output-only layers).
    pub fn step(&mut self, in_spikes: &[bool]) -> Result<&[bool]> {
        assert_eq!(in_spikes.len(), self.layout.fan_in, "fan-in mismatch");
        // Gather the spiking rows once; no spike → no instruction at all.
        self.spiking_rows.clear();
        for (i, &s) in in_spikes.iter().enumerate() {
            if s {
                self.spiking_rows.push(i);
            }
        }
        for (tile, m) in self.layout.tiles.iter().zip(self.macros.iter_mut()) {
            // 1. sparsity-gated synaptic accumulation (batched hot path)
            for parity in Parity::BOTH {
                m.acc_w2v_batch(&self.spiking_rows, tile_v_row(tile, parity), parity)?;
            }
            if self.output_only {
                continue;
            }
            // 2. neuron update per parity (precomputed sequences)
            for (parity, seq) in
                [(Parity::Odd, &self.seq_odd), (Parity::Even, &self.seq_even)]
            {
                for instr in seq {
                    m.execute(instr)?;
                }
                let spikes = m.spikes(parity);
                for (field, &sp) in spikes.iter().enumerate() {
                    let local = tile.local_out(parity, field);
                    if local < tile.out_count {
                        self.out_spikes[tile.out_base + local] = sp;
                    }
                }
            }
        }
        Ok(&self.out_spikes)
    }

    /// Current membrane potentials of all outputs.
    pub fn potentials(&mut self) -> Result<Vec<i64>> {
        let mut out = vec![0i64; self.layout.width];
        for (tile, m) in self.layout.tiles.iter().zip(self.macros.iter_mut()) {
            for parity in Parity::BOTH {
                let vals = m.read_v(tile_v_row(tile, parity), parity)?;
                for (field, &v) in vals.iter().enumerate() {
                    let local = tile.local_out(parity, field);
                    if local < tile.out_count {
                        out[tile.out_base + local] = v;
                    }
                }
            }
        }
        Ok(out)
    }

    /// Zero all membrane potentials (new inference).
    pub fn reset_state(&mut self) -> Result<()> {
        for (tile, m) in self.layout.tiles.iter().zip(self.macros.iter_mut()) {
            for parity in Parity::BOTH {
                m.write_v(tile_v_row(tile, parity), parity, &[0; 6])?;
            }
        }
        for s in self.out_spikes.iter_mut() {
            *s = false;
        }
        Ok(())
    }

    /// Aggregate stats across the layer's macros.
    pub fn stats(&self) -> LayerStats {
        let mut s = LayerStats::default();
        for m in &self.macros {
            s.cycles += m.cycles();
            for (k, v) in m.counts() {
                *s.histogram.entry(k).or_insert(0) += v;
            }
        }
        s
    }

    /// Reset instruction counters on all macros.
    pub fn reset_counters(&mut self) {
        for m in self.macros.iter_mut() {
            m.reset_counters();
        }
    }

    /// Number of macros (tiles).
    pub fn num_macros(&self) -> usize {
        self.macros.len()
    }

    /// The layer's neuron parameters.
    pub fn params(&self) -> LayerParams {
        self.params
    }
}

#[inline]
fn tile_v_row(tile: &crate::mapper::TileMapping, parity: Parity) -> usize {
    match parity {
        Parity::Odd => tile.v_row_odd,
        Parity::Even => tile.v_row_even,
    }
}

/// Reference check helper shared by tests: dense golden layer built
/// from the same weights.
#[cfg(test)]
pub(crate) fn golden_of(
    weights: &[Vec<i64>],
    params: LayerParams,
) -> crate::neuron::GoldenLayer {
    let p = crate::neuron::NeuronParams {
        neuron: params.neuron,
        threshold: params.threshold,
        reset: params.reset,
        leak: params.leak,
    };
    crate::neuron::GoldenLayer::new(p, weights.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::XorShiftRng;

    fn rand_weights(rng: &mut XorShiftRng, m: usize, n: usize) -> Vec<Vec<i64>> {
        (0..m)
            .map(|_| (0..n).map(|_| rng.gen_i64(-20, 20)).collect())
            .collect()
    }

    fn rand_spikes(rng: &mut XorShiftRng, m: usize, p: f64) -> Vec<bool> {
        (0..m).map(|_| rng.gen_bool(p)).collect()
    }

    /// The macro-mapped layer must match the functional golden layer
    /// bit-for-bit over many random timesteps — the end-to-end
    /// correctness anchor for the whole mapping + macro stack.
    #[test]
    fn fc_layer_matches_golden_layer() {
        let mut rng = XorShiftRng::new(2024);
        for (m_in, n_out, neuron) in [
            (100, 128, LayerParams::rmp(150)),
            (128, 128, LayerParams::if_(100)),
            (64, 17, LayerParams::lif(80, 3)),
            (5, 3, LayerParams::rmp(25)),
        ] {
            let w = rand_weights(&mut rng, m_in, n_out);
            let mut layer = FcLayer::new(&w, neuron, MacroConfig::fast()).unwrap();
            let mut golden = golden_of(&w, neuron);
            for t in 0..30 {
                let spikes = rand_spikes(&mut rng, m_in, 0.2);
                let got = layer.step(&spikes).unwrap().to_vec();
                let want = golden.step(&spikes);
                assert_eq!(got, want, "t={t} {neuron:?}");
                assert_eq!(
                    layer.potentials().unwrap(),
                    golden.potentials(),
                    "t={t} potentials"
                );
            }
        }
    }

    #[test]
    fn fc_layer_matches_golden_on_bit_level_engine() {
        let mut rng = XorShiftRng::new(77);
        let w = rand_weights(&mut rng, 40, 24);
        let p = LayerParams::rmp(60);
        let mut layer = FcLayer::new(&w, p, MacroConfig::lockstep()).unwrap();
        let mut golden = golden_of(&w, p);
        for _ in 0..10 {
            let spikes = rand_spikes(&mut rng, 40, 0.3);
            assert_eq!(layer.step(&spikes).unwrap().to_vec(), golden.step(&spikes));
        }
    }

    #[test]
    fn no_input_spikes_issue_no_accw2v() {
        let mut rng = XorShiftRng::new(5);
        let w = rand_weights(&mut rng, 32, 12);
        let mut layer = FcLayer::new(&w, LayerParams::rmp(100), MacroConfig::fast()).unwrap();
        layer.step(&vec![false; 32]).unwrap();
        let s = layer.stats();
        assert_eq!(s.histogram.get(&InstructionKind::AccW2V), None);
        // neuron update still runs: 2 SpikeChecks (odd+even), 2 AccV2V
        assert_eq!(s.histogram[&InstructionKind::SpikeCheck], 2);
    }

    #[test]
    fn instruction_count_scales_with_spikes() {
        let mut rng = XorShiftRng::new(6);
        let w = rand_weights(&mut rng, 128, 12);
        let mut layer = FcLayer::new(&w, LayerParams::rmp(100), MacroConfig::fast()).unwrap();
        let mut spikes = vec![false; 128];
        for i in 0..32 {
            spikes[i * 4] = true;
        }
        layer.step(&spikes).unwrap();
        let s = layer.stats();
        assert_eq!(s.histogram[&InstructionKind::AccW2V], 64); // 32 spikes × 2 parities
    }

    #[test]
    fn output_only_layer_integrates_without_spiking() {
        let w = vec![vec![5i64], vec![7i64]];
        let mut layer = FcLayer::new(&w, LayerParams::rmp(1000), MacroConfig::fast())
            .unwrap()
            .output_only();
        for _ in 0..3 {
            let out = layer.step(&[true, true]).unwrap();
            assert!(out.iter().all(|&s| !s));
        }
        assert_eq!(layer.potentials().unwrap(), vec![36]);
        let s = layer.stats();
        assert_eq!(s.histogram.get(&InstructionKind::SpikeCheck), None);
    }

    #[test]
    fn reset_state_zeroes_potentials() {
        let w = vec![vec![10i64; 12]; 4];
        let mut layer = FcLayer::new(&w, LayerParams::rmp(500), MacroConfig::fast()).unwrap();
        layer.step(&[true, true, true, true]).unwrap();
        assert!(layer.potentials().unwrap().iter().any(|&v| v != 0));
        layer.reset_state().unwrap();
        assert!(layer.potentials().unwrap().iter().all(|&v| v == 0));
    }

    #[test]
    fn wide_layer_spans_tiles_correctly() {
        // width 30 → 3 tiles (12+12+6); verify weight placement via a
        // delta: input 2 spikes, all others silent.
        let mut w = vec![vec![0i64; 30]; 8];
        for o in 0..30 {
            w[2][o] = (o as i64 % 25) - 12;
        }
        let mut layer = FcLayer::new(&w, LayerParams::rmp(1000), MacroConfig::fast()).unwrap();
        assert_eq!(layer.num_macros(), 3);
        let mut spikes = vec![false; 8];
        spikes[2] = true;
        layer.step(&spikes).unwrap();
        let v = layer.potentials().unwrap();
        for o in 0..30 {
            assert_eq!(v[o], (o as i64 % 25) - 12, "o={o}");
        }
    }
}
