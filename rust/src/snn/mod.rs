//! Network-level SNN inference on IMPULSE macro pools.
//!
//! Layers own their macros (one per mapped tile), translate spikes into
//! in-memory instruction streams — issuing AccW2V *only for spiking
//! inputs*, the macro's sparsity mechanism — and aggregate instruction
//! histograms for the energy model.

mod conv_layer;
mod digits;
mod encoder;
mod fc_layer;
pub(crate) mod network;
mod spikes;

pub use conv_layer::ConvLayer;
pub use digits::{DigitsNetwork, DigitsResult};
pub use encoder::{ConvEncoder, Encoder};
pub use fc_layer::{FcLayer, LayerStats};
pub use network::{ReviewResult, SentimentNetwork};
pub use spikes::{spike_union, spike_union_planes, Ones, SparsityTracker, SpikeMap, SpikePlane};

use crate::isa::NeuronType;

/// Integer neuron parameters of a mapped layer (quantized domain).
#[derive(Clone, Copy, Debug)]
pub struct LayerParams {
    pub neuron: NeuronType,
    /// Firing threshold θ (1..1023).
    pub threshold: i64,
    /// Hard-reset value (IF/LIF).
    pub reset: i64,
    /// Subtractive leak (LIF).
    pub leak: i64,
}

impl LayerParams {
    pub fn rmp(threshold: i64) -> Self {
        Self {
            neuron: NeuronType::RMP,
            threshold,
            reset: 0,
            leak: 0,
        }
    }

    pub fn if_(threshold: i64) -> Self {
        Self {
            neuron: NeuronType::IF,
            threshold,
            reset: 0,
            leak: 0,
        }
    }

    pub fn lif(threshold: i64, leak: i64) -> Self {
        Self {
            neuron: NeuronType::LIF,
            threshold,
            reset: 0,
            leak,
        }
    }
}
