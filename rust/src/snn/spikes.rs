//! Spike containers and sparsity accounting (Fig 11a).

/// A 3-D binary spike volume (height × width × channels), the
/// inter-layer currency of the conv network.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpikeMap {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    bits: Vec<bool>,
}

impl SpikeMap {
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        Self {
            h,
            w,
            c,
            bits: vec![false; h * w * c],
        }
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> bool {
        self.bits[(y * self.w + x) * self.c + ch]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: bool) {
        self.bits[(y * self.w + x) * self.c + ch] = v;
    }

    pub fn len(&self) -> usize {
        self.bits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bits.is_empty()
    }

    /// Fraction of set bits.
    pub fn density(&self) -> f64 {
        if self.bits.is_empty() {
            return 0.0;
        }
        self.bits.iter().filter(|&&b| b).count() as f64 / self.bits.len() as f64
    }

    /// 2×2 max-pool (binary OR — exact on spike maps), VALID padding.
    pub fn maxpool2(&self) -> SpikeMap {
        let (oh, ow) = (self.h / 2, self.w / 2);
        let mut out = SpikeMap::new(oh, ow, self.c);
        for y in 0..oh {
            for x in 0..ow {
                for ch in 0..self.c {
                    let v = self.get(2 * y, 2 * x, ch)
                        || self.get(2 * y, 2 * x + 1, ch)
                        || self.get(2 * y + 1, 2 * x, ch)
                        || self.get(2 * y + 1, 2 * x + 1, ch);
                    out.set(y, x, ch, v);
                }
            }
        }
        out
    }

    /// Flatten to a plain spike vector (row-major, channel innermost).
    pub fn flatten(&self) -> Vec<bool> {
        self.bits.clone()
    }

    pub fn from_flat(h: usize, w: usize, c: usize, bits: Vec<bool>) -> Self {
        assert_eq!(bits.len(), h * w * c);
        Self { h, w, c, bits }
    }
}

/// Build the fused (batched) spike union for one timestep: one
/// `(row, lane-bitmask)` entry per input row that spikes in at least
/// one active lane, in row order. `active[b]` gates lanes that still
/// have work; every active lane's spike vector must share one fan-in.
/// Returns the total spike count across active lanes — the AccW2V cost
/// a per-lane (sequential) issue would pay, against which the union
/// length measures the batching amortization.
pub fn spike_union(
    batch: &[&[bool]],
    active: &[bool],
    out: &mut Vec<(usize, u32)>,
) -> usize {
    assert!(batch.len() <= 32, "lane mask is 32 bits");
    assert_eq!(batch.len(), active.len());
    out.clear();
    let fan_in = batch
        .iter()
        .zip(active)
        .filter(|&(_, &a)| a)
        .map(|(s, _)| s.len())
        .max()
        .unwrap_or(0);
    let mut total = 0usize;
    for i in 0..fan_in {
        let mut mask = 0u32;
        for (b, (s, &a)) in batch.iter().zip(active).enumerate() {
            if a && s[i] {
                mask |= 1 << b;
                total += 1;
            }
        }
        if mask != 0 {
            out.push((i, mask));
        }
    }
    total
}

/// Accumulates per-layer per-timestep spike statistics across a run —
/// the data behind Fig 11(a).
#[derive(Clone, Debug)]
pub struct SparsityTracker {
    layers: usize,
    timesteps: usize,
    /// spikes[layer][t], total[layer][t]
    spikes: Vec<Vec<u64>>,
    total: Vec<Vec<u64>>,
}

impl SparsityTracker {
    pub fn new(layers: usize, timesteps: usize) -> Self {
        Self {
            layers,
            timesteps,
            spikes: vec![vec![0; timesteps]; layers],
            total: vec![vec![0; timesteps]; layers],
        }
    }

    /// Record one layer's spike vector at timestep `t` (mod the window;
    /// for the sentiment net t is the within-word timestep).
    pub fn record(&mut self, layer: usize, t: usize, spikes: &[bool]) {
        let t = t % self.timesteps;
        self.spikes[layer][t] += spikes.iter().filter(|&&s| s).count() as u64;
        self.total[layer][t] += spikes.len() as u64;
    }

    /// Record from a count (for map-shaped layers).
    pub fn record_counts(&mut self, layer: usize, t: usize, fired: u64, total: u64) {
        let t = t % self.timesteps;
        self.spikes[layer][t] += fired;
        self.total[layer][t] += total;
    }

    /// Sparsity (1 − firing-fraction) of a layer at a timestep.
    pub fn sparsity(&self, layer: usize, t: usize) -> f64 {
        let tot = self.total[layer][t];
        if tot == 0 {
            return 1.0;
        }
        1.0 - self.spikes[layer][t] as f64 / tot as f64
    }

    /// Mean sparsity of one layer across timesteps.
    pub fn layer_sparsity(&self, layer: usize) -> f64 {
        let s: u64 = self.spikes[layer].iter().sum();
        let t: u64 = self.total[layer].iter().sum();
        if t == 0 {
            return 1.0;
        }
        1.0 - s as f64 / t as f64
    }

    /// Overall sparsity across all layers.
    pub fn overall(&self) -> f64 {
        let s: u64 = self.spikes.iter().flatten().sum();
        let t: u64 = self.total.iter().flatten().sum();
        if t == 0 {
            return 1.0;
        }
        1.0 - s as f64 / t as f64
    }

    /// The Fig 11(a) series: rows = layers, cols = timesteps.
    pub fn table(&self) -> Vec<Vec<f64>> {
        (0..self.layers)
            .map(|l| (0..self.timesteps).map(|t| self.sparsity(l, t)).collect())
            .collect()
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn timesteps(&self) -> usize {
        self.timesteps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spikemap_get_set_density() {
        let mut m = SpikeMap::new(4, 4, 2);
        m.set(0, 0, 0, true);
        m.set(3, 3, 1, true);
        assert!(m.get(0, 0, 0));
        assert!(!m.get(0, 0, 1));
        assert!((m.density() - 2.0 / 32.0).abs() < 1e-12);
    }

    #[test]
    fn maxpool_is_binary_or() {
        let mut m = SpikeMap::new(4, 4, 1);
        m.set(0, 1, 0, true); // window (0,0)
        m.set(3, 3, 0, true); // window (1,1)
        let p = m.maxpool2();
        assert_eq!(p.h, 2);
        assert!(p.get(0, 0, 0));
        assert!(!p.get(0, 1, 0));
        assert!(!p.get(1, 0, 0));
        assert!(p.get(1, 1, 0));
    }

    #[test]
    fn maxpool_odd_dims_floor() {
        let m = SpikeMap::new(7, 7, 3);
        let p = m.maxpool2();
        assert_eq!((p.h, p.w, p.c), (3, 3, 3));
    }

    #[test]
    fn flatten_roundtrip() {
        let mut m = SpikeMap::new(2, 3, 2);
        m.set(1, 2, 1, true);
        let f = m.flatten();
        let m2 = SpikeMap::from_flat(2, 3, 2, f);
        assert_eq!(m, m2);
    }

    #[test]
    fn sparsity_tracker_math() {
        let mut t = SparsityTracker::new(2, 3);
        t.record(0, 0, &[true, false, false, false]); // 25% firing
        t.record(0, 0, &[false, false, false, false]);
        t.record(1, 2, &[true, true]);
        assert!((t.sparsity(0, 0) - 0.875).abs() < 1e-12);
        assert_eq!(t.sparsity(1, 2), 0.0);
        assert_eq!(t.sparsity(1, 0), 1.0); // nothing recorded
        assert!((t.layer_sparsity(0) - 0.875).abs() < 1e-12);
        let table = t.table();
        assert_eq!(table.len(), 2);
        assert_eq!(table[0].len(), 3);
    }

    #[test]
    fn spike_union_masks_and_total() {
        let a = [true, false, true, false];
        let b = [true, true, false, false];
        let c = [false, false, false, true];
        let mut rows = Vec::new();
        let total = spike_union(&[&a[..], &b[..], &c[..]], &[true, true, true], &mut rows);
        assert_eq!(total, 5);
        assert_eq!(rows, vec![(0, 0b011), (1, 0b010), (2, 0b001), (3, 0b100)]);
    }

    #[test]
    fn spike_union_skips_inactive_lanes() {
        let a = [true, true];
        let b = [true, false];
        let mut rows = Vec::new();
        let total = spike_union(&[&a[..], &b[..]], &[false, true], &mut rows);
        assert_eq!(total, 1);
        assert_eq!(rows, vec![(0, 0b10)]);
    }

    #[test]
    fn spike_union_empty_batch() {
        let mut rows = vec![(9usize, 1u32)];
        assert_eq!(spike_union(&[], &[], &mut rows), 0);
        assert!(rows.is_empty());
    }

    #[test]
    fn tracker_timestep_wraps() {
        let mut t = SparsityTracker::new(1, 10);
        t.record(0, 13, &[true]); // lands in slot 3
        assert_eq!(t.sparsity(0, 3), 0.0);
    }
}
