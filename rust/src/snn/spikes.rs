//! Spike containers and sparsity accounting (Fig 11a).
//!
//! The serving stack's spike currency is the packed [`SpikePlane`]:
//! one bit per input/neuron in u64 words, iterated over *active*
//! indices via `trailing_zeros` and counted via popcount. Everything
//! downstream of the encoders — layer steps, batch unions, sparsity
//! tracking — costs O(popcount), mirroring the macro's skip-on-zero
//! AccW2V issue (paper Fig 11b), instead of O(width) per timestep.

/// A packed spike bitset: one bit per unit, 64 units per word.
///
/// Invariant: bits at index ≥ `len` are always zero, so popcounts and
/// word-level unions never see phantom spikes.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SpikePlane {
    len: usize,
    words: Vec<u64>,
}

impl SpikePlane {
    /// An all-silent plane of `len` units.
    pub fn new(len: usize) -> Self {
        Self {
            len,
            words: vec![0; len.div_ceil(64)],
        }
    }

    /// Number of units (bits) in the plane.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the plane has zero units.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read one spike bit. Panics on out-of-range indices (matching
    /// `Vec<bool>` indexing — an index bug must not read the padded
    /// tail of the last word as silence).
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.len, "index {i} out of {}", self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Write one spike bit.
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        assert!(i < self.len, "index {i} out of {}", self.len);
        let w = &mut self.words[i >> 6];
        let m = 1u64 << (i & 63);
        if v {
            *w |= m;
        } else {
            *w &= !m;
        }
    }

    /// Silence every unit (length unchanged).
    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    /// Reset to `len` silent units, reusing the allocation when it
    /// fits — the scratch-buffer discipline of the batch paths.
    pub fn reset(&mut self, len: usize) {
        self.len = len;
        self.words.clear();
        self.words.resize(len.div_ceil(64), 0);
    }

    /// Number of set bits — the active-spike count feeding the
    /// sparsity trackers and telemetry counters.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Fraction of set bits.
    pub fn density(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        self.count_ones() as f64 / self.len as f64
    }

    /// Overwrite from a boolean spike vector (resizing to match).
    pub fn fill_from_bools(&mut self, bits: &[bool]) {
        self.reset(bits.len());
        for (w, chunk) in self.words.iter_mut().zip(bits.chunks(64)) {
            let mut x = 0u64;
            for (j, &b) in chunk.iter().enumerate() {
                x |= (b as u64) << j;
            }
            *w = x;
        }
    }

    /// Build from a boolean spike vector.
    pub fn from_bools(bits: &[bool]) -> Self {
        let mut p = Self::default();
        p.fill_from_bools(bits);
        p
    }

    /// Popcount an iterator of flags (e.g. "pixel is nonzero") via
    /// word packing, without materializing a plane — the
    /// allocation-free counter behind telemetry's sparsity counters.
    pub fn count_flags<I: IntoIterator<Item = bool>>(flags: I) -> usize {
        let mut cur = 0u64;
        let mut n = 0usize;
        let mut total = 0usize;
        for f in flags {
            cur |= (f as u64) << (n & 63);
            n += 1;
            if n & 63 == 0 {
                total += cur.count_ones() as usize;
                cur = 0;
            }
        }
        total + cur.count_ones() as usize
    }

    /// Pack an iterator of flags (e.g. "pixel is nonzero") into plane
    /// words.
    pub fn from_flags<I: IntoIterator<Item = bool>>(flags: I) -> Self {
        let mut words = Vec::new();
        let mut cur = 0u64;
        let mut n = 0usize;
        for f in flags {
            cur |= (f as u64) << (n & 63);
            n += 1;
            if n & 63 == 0 {
                words.push(cur);
                cur = 0;
            }
        }
        if n & 63 != 0 {
            words.push(cur);
        }
        Self { len: n, words }
    }

    /// Expand into a pre-sized boolean slice (lengths must match).
    pub fn write_bools(&self, out: &mut [bool]) {
        assert_eq!(out.len(), self.len, "length mismatch");
        for (chunk, &w) in out.chunks_mut(64).zip(&self.words) {
            for (j, o) in chunk.iter_mut().enumerate() {
                *o = (w >> j) & 1 == 1;
            }
        }
    }

    /// Expand into a boolean spike vector.
    pub fn to_bools(&self) -> Vec<bool> {
        let mut out = vec![false; self.len];
        self.write_bools(&mut out);
        out
    }

    /// OR another plane of the same length into this one.
    pub fn or_assign(&mut self, other: &SpikePlane) {
        assert_eq!(self.len, other.len, "length mismatch");
        for (a, &b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// The backing words (low bit of word 0 is unit 0).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Read up to 64 consecutive bits starting at `start` (may span a
    /// word boundary). Used by the conv union to fetch a pixel's whole
    /// channel run in one probe.
    #[inline]
    pub fn bits_at(&self, start: usize, n: usize) -> u64 {
        debug_assert!((1..=64).contains(&n));
        debug_assert!(start + n <= self.len);
        let wi = start >> 6;
        let off = start & 63;
        let lo = self.words[wi] >> off;
        let x = if off != 0 && wi + 1 < self.words.len() {
            lo | (self.words[wi + 1] << (64 - off))
        } else {
            lo
        };
        if n == 64 {
            x
        } else {
            x & ((1u64 << n) - 1)
        }
    }

    /// Iterate the indices of set bits in ascending order — cost
    /// proportional to the popcount, via `trailing_zeros`.
    pub fn iter_ones(&self) -> Ones<'_> {
        Ones {
            words: &self.words,
            wi: 0,
            cur: self.words.first().copied().unwrap_or(0),
        }
    }
}

/// Iterator over the set-bit indices of a [`SpikePlane`].
pub struct Ones<'a> {
    words: &'a [u64],
    wi: usize,
    cur: u64,
}

impl Iterator for Ones<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        while self.cur == 0 {
            self.wi += 1;
            if self.wi >= self.words.len() {
                return None;
            }
            self.cur = self.words[self.wi];
        }
        let b = self.cur.trailing_zeros() as usize;
        self.cur &= self.cur - 1;
        Some((self.wi << 6) | b)
    }
}

/// A 3-D binary spike volume (height × width × channels), the
/// inter-layer currency of the conv network — backed by a packed
/// [`SpikePlane`] (row-major, channel innermost).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpikeMap {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    plane: SpikePlane,
}

impl SpikeMap {
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        Self {
            h,
            w,
            c,
            plane: SpikePlane::new(h * w * c),
        }
    }

    #[inline]
    fn idx(&self, y: usize, x: usize, ch: usize) -> usize {
        (y * self.w + x) * self.c + ch
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> bool {
        self.plane.get(self.idx(y, x, ch))
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: bool) {
        let i = self.idx(y, x, ch);
        self.plane.set(i, v);
    }

    pub fn len(&self) -> usize {
        self.plane.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plane.is_empty()
    }

    /// Number of set bits (one word-popcount pass, no iteration).
    pub fn count_ones(&self) -> usize {
        self.plane.count_ones()
    }

    /// Fraction of set bits.
    pub fn density(&self) -> f64 {
        self.plane.density()
    }

    /// The packed backing plane.
    pub fn plane(&self) -> &SpikePlane {
        &self.plane
    }

    /// Consume into the packed backing plane (e.g. to feed an FC layer
    /// after the final pool without a boolean detour).
    pub fn into_plane(self) -> SpikePlane {
        self.plane
    }

    /// Rebuild from a packed plane of matching volume.
    pub fn from_plane(h: usize, w: usize, c: usize, plane: SpikePlane) -> Self {
        assert_eq!(plane.len(), h * w * c);
        Self { h, w, c, plane }
    }

    /// 2×2 max-pool (binary OR — exact on spike maps), VALID padding.
    pub fn maxpool2(&self) -> SpikeMap {
        let (oh, ow) = (self.h / 2, self.w / 2);
        let mut out = SpikeMap::new(oh, ow, self.c);
        for y in 0..oh {
            for x in 0..ow {
                for ch in 0..self.c {
                    let v = self.get(2 * y, 2 * x, ch)
                        || self.get(2 * y, 2 * x + 1, ch)
                        || self.get(2 * y + 1, 2 * x, ch)
                        || self.get(2 * y + 1, 2 * x + 1, ch);
                    out.set(y, x, ch, v);
                }
            }
        }
        out
    }

    /// Flatten to a plain spike vector (row-major, channel innermost).
    pub fn flatten(&self) -> Vec<bool> {
        self.plane.to_bools()
    }

    pub fn from_flat(h: usize, w: usize, c: usize, bits: Vec<bool>) -> Self {
        assert_eq!(bits.len(), h * w * c);
        Self {
            h,
            w,
            c,
            plane: SpikePlane::from_bools(&bits),
        }
    }
}

/// Build the fused (batched) spike union for one timestep: one
/// `(row, lane-bitmask)` entry per input row that spikes in at least
/// one active lane, in row order. `active[b]` gates lanes that still
/// have work; every active lane's spike vector must share one fan-in.
/// Returns the total spike count across active lanes — the AccW2V cost
/// a per-lane (sequential) issue would pay, against which the union
/// length measures the batching amortization.
pub fn spike_union(
    batch: &[&[bool]],
    active: &[bool],
    out: &mut Vec<(usize, u32)>,
) -> usize {
    assert!(batch.len() <= 32, "lane mask is 32 bits");
    assert_eq!(batch.len(), active.len());
    out.clear();
    let fan_in = batch
        .iter()
        .zip(active)
        .filter(|&(_, &a)| a)
        .map(|(s, _)| s.len())
        .max()
        .unwrap_or(0);
    let mut total = 0usize;
    for i in 0..fan_in {
        let mut mask = 0u32;
        for (b, (s, &a)) in batch.iter().zip(active).enumerate() {
            if a && s[i] {
                mask |= 1 << b;
                total += 1;
            }
        }
        if mask != 0 {
            out.push((i, mask));
        }
    }
    total
}

/// Plane-native fused spike union — the same contract as
/// [`spike_union`], but word-at-a-time: lanes are OR-ed 64 rows per
/// op, spike totals come from popcounts, and only rows set in the
/// union word are visited (via `trailing_zeros`). Cost scales with
/// the number of active spikes, not the fan-in.
pub fn spike_union_planes(
    batch: &[SpikePlane],
    active: &[bool],
    out: &mut Vec<(usize, u32)>,
) -> usize {
    assert!(batch.len() <= 32, "lane mask is 32 bits");
    assert_eq!(batch.len(), active.len());
    out.clear();
    let n_words = batch
        .iter()
        .zip(active)
        .filter(|&(_, &a)| a)
        .map(|(p, _)| p.words.len())
        .max()
        .unwrap_or(0);
    let mut total = 0usize;
    let mut lane_words = [0u64; 32];
    for wi in 0..n_words {
        let mut union = 0u64;
        for (b, (p, &a)) in batch.iter().zip(active).enumerate() {
            let w = if a {
                p.words.get(wi).copied().unwrap_or(0)
            } else {
                0
            };
            lane_words[b] = w;
            union |= w;
            total += w.count_ones() as usize;
        }
        let mut u = union;
        while u != 0 {
            let bit = u.trailing_zeros() as usize;
            u &= u - 1;
            let mut mask = 0u32;
            for (b, lw) in lane_words[..batch.len()].iter().enumerate() {
                mask |= (((lw >> bit) & 1) as u32) << b;
            }
            out.push(((wi << 6) | bit, mask));
        }
    }
    total
}

/// Accumulates per-layer per-timestep spike statistics across a run —
/// the data behind Fig 11(a).
#[derive(Clone, Debug)]
pub struct SparsityTracker {
    layers: usize,
    timesteps: usize,
    /// spikes[layer][t], total[layer][t]
    spikes: Vec<Vec<u64>>,
    total: Vec<Vec<u64>>,
}

impl SparsityTracker {
    pub fn new(layers: usize, timesteps: usize) -> Self {
        Self {
            layers,
            timesteps,
            spikes: vec![vec![0; timesteps]; layers],
            total: vec![vec![0; timesteps]; layers],
        }
    }

    /// Record one layer's spike vector at timestep `t` (mod the window;
    /// for the sentiment net t is the within-word timestep).
    pub fn record(&mut self, layer: usize, t: usize, spikes: &[bool]) {
        let t = t % self.timesteps;
        self.spikes[layer][t] += spikes.iter().filter(|&&s| s).count() as u64;
        self.total[layer][t] += spikes.len() as u64;
    }

    /// Record one layer's packed spike plane at timestep `t` — one
    /// popcount pass, the batch paths' accounting hook.
    pub fn record_plane(&mut self, layer: usize, t: usize, spikes: &SpikePlane) {
        self.record_counts(layer, t, spikes.count_ones() as u64, spikes.len() as u64);
    }

    /// Record from a count (for map-shaped layers).
    pub fn record_counts(&mut self, layer: usize, t: usize, fired: u64, total: u64) {
        let t = t % self.timesteps;
        self.spikes[layer][t] += fired;
        self.total[layer][t] += total;
    }

    /// Sparsity (1 − firing-fraction) of a layer at a timestep.
    pub fn sparsity(&self, layer: usize, t: usize) -> f64 {
        let tot = self.total[layer][t];
        if tot == 0 {
            return 1.0;
        }
        1.0 - self.spikes[layer][t] as f64 / tot as f64
    }

    /// Mean sparsity of one layer across timesteps.
    pub fn layer_sparsity(&self, layer: usize) -> f64 {
        let s: u64 = self.spikes[layer].iter().sum();
        let t: u64 = self.total[layer].iter().sum();
        if t == 0 {
            return 1.0;
        }
        1.0 - s as f64 / t as f64
    }

    /// Overall sparsity across all layers.
    pub fn overall(&self) -> f64 {
        let s: u64 = self.spikes.iter().flatten().sum();
        let t: u64 = self.total.iter().flatten().sum();
        if t == 0 {
            return 1.0;
        }
        1.0 - s as f64 / t as f64
    }

    /// The Fig 11(a) series: rows = layers, cols = timesteps.
    pub fn table(&self) -> Vec<Vec<f64>> {
        (0..self.layers)
            .map(|l| (0..self.timesteps).map(|t| self.sparsity(l, t)).collect())
            .collect()
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn timesteps(&self) -> usize {
        self.timesteps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::XorShiftRng;

    #[test]
    fn spikemap_get_set_density() {
        let mut m = SpikeMap::new(4, 4, 2);
        m.set(0, 0, 0, true);
        m.set(3, 3, 1, true);
        assert!(m.get(0, 0, 0));
        assert!(!m.get(0, 0, 1));
        assert!((m.density() - 2.0 / 32.0).abs() < 1e-12);
        assert_eq!(m.count_ones(), 2);
    }

    #[test]
    fn maxpool_is_binary_or() {
        let mut m = SpikeMap::new(4, 4, 1);
        m.set(0, 1, 0, true); // window (0,0)
        m.set(3, 3, 0, true); // window (1,1)
        let p = m.maxpool2();
        assert_eq!(p.h, 2);
        assert!(p.get(0, 0, 0));
        assert!(!p.get(0, 1, 0));
        assert!(!p.get(1, 0, 0));
        assert!(p.get(1, 1, 0));
    }

    #[test]
    fn maxpool_odd_dims_floor() {
        let m = SpikeMap::new(7, 7, 3);
        let p = m.maxpool2();
        assert_eq!((p.h, p.w, p.c), (3, 3, 3));
    }

    #[test]
    fn flatten_roundtrip() {
        let mut m = SpikeMap::new(2, 3, 2);
        m.set(1, 2, 1, true);
        let f = m.flatten();
        let m2 = SpikeMap::from_flat(2, 3, 2, f);
        assert_eq!(m, m2);
    }

    #[test]
    fn plane_bools_roundtrip_and_counts() {
        let mut rng = XorShiftRng::new(11);
        for len in [0usize, 1, 63, 64, 65, 100, 128, 200] {
            let bits: Vec<bool> = (0..len).map(|_| rng.gen_bool(0.3)).collect();
            let p = SpikePlane::from_bools(&bits);
            assert_eq!(p.len(), len);
            assert_eq!(p.to_bools(), bits);
            assert_eq!(
                p.count_ones(),
                bits.iter().filter(|&&b| b).count(),
                "len={len}"
            );
            let ones: Vec<usize> = p.iter_ones().collect();
            let want: Vec<usize> = bits
                .iter()
                .enumerate()
                .filter(|(_, &b)| b)
                .map(|(i, _)| i)
                .collect();
            assert_eq!(ones, want, "len={len}");
            for (i, &b) in bits.iter().enumerate() {
                assert_eq!(p.get(i), b);
            }
        }
    }

    #[test]
    fn plane_set_clear_reset() {
        let mut p = SpikePlane::new(70);
        p.set(0, true);
        p.set(69, true);
        assert_eq!(p.count_ones(), 2);
        p.set(0, false);
        assert_eq!(p.iter_ones().collect::<Vec<_>>(), vec![69]);
        p.clear();
        assert_eq!(p.count_ones(), 0);
        assert_eq!(p.len(), 70);
        p.reset(3);
        assert_eq!(p.len(), 3);
        assert_eq!(p.count_ones(), 0);
    }

    #[test]
    fn plane_or_assign_and_from_flags() {
        let a = SpikePlane::from_bools(&[true, false, true, false]);
        let mut b = SpikePlane::from_bools(&[false, false, true, true]);
        b.or_assign(&a);
        assert_eq!(b.to_bools(), vec![true, false, true, true]);
        let f = SpikePlane::from_flags([1.0f32, 0.0, -2.0, 0.0].iter().map(|&x| x != 0.0));
        assert_eq!(f.len(), 4);
        assert_eq!(f.count_ones(), 2);
    }

    /// The allocation-free flag counter must agree with a direct count
    /// across word boundaries.
    #[test]
    fn count_flags_matches_direct_count() {
        let mut rng = XorShiftRng::new(31);
        for len in [0usize, 1, 63, 64, 65, 129, 200] {
            let bits: Vec<bool> = (0..len).map(|_| rng.gen_bool(0.4)).collect();
            assert_eq!(
                SpikePlane::count_flags(bits.iter().copied()),
                bits.iter().filter(|&&b| b).count(),
                "len={len}"
            );
        }
    }

    #[test]
    fn plane_bits_at_spans_words() {
        let mut bits = vec![false; 130];
        bits[60] = true;
        bits[64] = true;
        bits[70] = true;
        let p = SpikePlane::from_bools(&bits);
        // run of 14 starting at 58: bits 60, 64, 70 → offsets 2, 6, 12
        assert_eq!(p.bits_at(58, 14), (1 << 2) | (1 << 6) | (1 << 12));
        assert_eq!(p.bits_at(64, 7), 1 | (1 << 6));
        assert_eq!(p.bits_at(0, 64), 1 << 60);
    }

    #[test]
    fn sparsity_tracker_math() {
        let mut t = SparsityTracker::new(2, 3);
        t.record(0, 0, &[true, false, false, false]); // 25% firing
        t.record(0, 0, &[false, false, false, false]);
        t.record(1, 2, &[true, true]);
        assert!((t.sparsity(0, 0) - 0.875).abs() < 1e-12);
        assert_eq!(t.sparsity(1, 2), 0.0);
        assert_eq!(t.sparsity(1, 0), 1.0); // nothing recorded
        assert!((t.layer_sparsity(0) - 0.875).abs() < 1e-12);
        let table = t.table();
        assert_eq!(table.len(), 2);
        assert_eq!(table[0].len(), 3);
    }

    #[test]
    fn tracker_record_plane_matches_record() {
        let bits = [true, false, true, false, false];
        let mut a = SparsityTracker::new(1, 4);
        a.record(0, 1, &bits);
        let mut b = SparsityTracker::new(1, 4);
        b.record_plane(0, 1, &SpikePlane::from_bools(&bits));
        assert_eq!(a.sparsity(0, 1), b.sparsity(0, 1));
    }

    #[test]
    fn spike_union_masks_and_total() {
        let a = [true, false, true, false];
        let b = [true, true, false, false];
        let c = [false, false, false, true];
        let mut rows = Vec::new();
        let total = spike_union(&[&a[..], &b[..], &c[..]], &[true, true, true], &mut rows);
        assert_eq!(total, 5);
        assert_eq!(rows, vec![(0, 0b011), (1, 0b010), (2, 0b001), (3, 0b100)]);
    }

    #[test]
    fn spike_union_skips_inactive_lanes() {
        let a = [true, true];
        let b = [true, false];
        let mut rows = Vec::new();
        let total = spike_union(&[&a[..], &b[..]], &[false, true], &mut rows);
        assert_eq!(total, 1);
        assert_eq!(rows, vec![(0, 0b10)]);
    }

    #[test]
    fn spike_union_empty_batch() {
        let mut rows = vec![(9usize, 1u32)];
        assert_eq!(spike_union(&[], &[], &mut rows), 0);
        assert!(rows.is_empty());
    }

    /// The plane union must agree with the boolean reference on random
    /// batches across word boundaries and activity patterns.
    #[test]
    fn spike_union_planes_matches_bool_reference() {
        let mut rng = XorShiftRng::new(2025);
        for &fan_in in &[1usize, 17, 64, 65, 128, 190] {
            for &lanes in &[1usize, 2, 7, 13] {
                let bools: Vec<Vec<bool>> = (0..lanes)
                    .map(|_| (0..fan_in).map(|_| rng.gen_bool(0.2)).collect())
                    .collect();
                let active: Vec<bool> = (0..lanes).map(|_| rng.gen_bool(0.8)).collect();
                let planes: Vec<SpikePlane> =
                    bools.iter().map(|b| SpikePlane::from_bools(b)).collect();
                let refs: Vec<&[bool]> = bools.iter().map(|b| b.as_slice()).collect();
                let mut want_rows = Vec::new();
                let want_total = spike_union(&refs, &active, &mut want_rows);
                let mut got_rows = Vec::new();
                let got_total = spike_union_planes(&planes, &active, &mut got_rows);
                assert_eq!(got_total, want_total, "fan_in={fan_in} lanes={lanes}");
                assert_eq!(got_rows, want_rows, "fan_in={fan_in} lanes={lanes}");
            }
        }
    }

    #[test]
    fn spike_union_planes_empty_batch() {
        let mut rows = vec![(9usize, 1u32)];
        assert_eq!(spike_union_planes(&[], &[], &mut rows), 0);
        assert!(rows.is_empty());
    }

    #[test]
    fn tracker_timestep_wraps() {
        let mut t = SparsityTracker::new(1, 10);
        t.record(0, 13, &[true]); // lands in slot 3
        assert_eq!(t.sparsity(0, 3), 0.0);
    }
}
