//! The workload abstraction: what the coordinator's batcher and
//! worker pool need from a servable model.
//!
//! PR 1/2 hard-wired the router to `SentimentNetwork`; this trait is
//! the seam that makes every network with a fused-lane batched path
//! servable through the same `InferenceServer`/`ShardRouter`/adaptive
//! sizing machinery. Two workloads ship today: the sentiment FC stack
//! (word-id sequences) and the digits conv network (28×28 images).

use crate::isa::InstructionKind;
use crate::snn::{DigitsNetwork, SentimentNetwork};
use crate::Result;
use std::collections::BTreeMap;

/// One request's input, workload-tagged. The coordinator treats it as
/// opaque; workloads reject kinds they cannot serve.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkloadInput {
    /// A word-id sequence (sentiment; ids < 0 are padding).
    Words(Vec<i64>),
    /// A grayscale image, row-major (digits; 28×28 on the mapped net).
    Image {
        /// Image height in pixels.
        h: usize,
        /// Image width in pixels.
        w: usize,
        /// `h·w` pixel intensities, row-major.
        pixels: Vec<f32>,
    },
}

impl WorkloadInput {
    /// Which workload family this input belongs to.
    pub fn kind(&self) -> WorkloadKind {
        match self {
            WorkloadInput::Words(_) => WorkloadKind::Sentiment,
            WorkloadInput::Image { .. } => WorkloadKind::Digits,
        }
    }

    /// Total and active (spiking-relevant) input units — the telemetry
    /// sparsity signal: non-padding word ids for sentiment, nonzero
    /// pixels for digits. The digits count word-packs the nonzero
    /// flags and popcounts them
    /// ([`crate::snn::SpikePlane::count_flags`]), allocation-free on
    /// the submit path.
    pub fn unit_counts(&self) -> (u64, u64) {
        match self {
            WorkloadInput::Words(ids) => (
                ids.len() as u64,
                ids.iter().filter(|&&w| w >= 0).count() as u64,
            ),
            WorkloadInput::Image { pixels, .. } => {
                let active = crate::snn::SpikePlane::count_flags(pixels.iter().map(|&p| p != 0.0));
                (pixels.len() as u64, active as u64)
            }
        }
    }
}

/// Workload families servable by the coordinator (used to pick the
/// response wire encoding).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    /// Sentiment FC stack: word ids in, binary prediction out.
    Sentiment,
    /// Digits conv network: image in, 10-class prediction out.
    Digits,
}

/// One request's result in workload-neutral form.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkloadOutput {
    /// Predicted label (sentiment: 1 = positive; digits: 0–9).
    pub pred: u8,
    /// Headline potential: the output neuron (sentiment) or the
    /// winning class (digits).
    pub v_out: i64,
    /// All output potentials (length 1 for sentiment, 10 for digits).
    pub v_all: Vec<i64>,
    /// Macro cycles attributed to this request (honest share of its
    /// fused batch).
    pub cycles: u64,
}

/// A model servable by the coordinator's micro-batching worker pool:
/// one request at a time, a whole micro-batch on fused lanes, and the
/// fused-lane budget the adaptive batcher sizes against. Batched
/// execution must be bit-identical per lane to `run_one`.
pub trait Workload: Send + 'static {
    /// Serve one request.
    fn run_one(&mut self, input: &WorkloadInput) -> Result<WorkloadOutput>;

    /// Serve one request with layer-pipelined execution, where the
    /// workload supports it (defaults to [`Workload::run_one`]).
    fn run_one_pipelined(&mut self, input: &WorkloadInput) -> Result<WorkloadOutput> {
        self.run_one(input)
    }

    /// Serve a micro-batch on fused lanes (chunking internally when
    /// `inputs` exceeds the lane budget).
    fn run_batched(&mut self, inputs: &[&WorkloadInput]) -> Result<Vec<WorkloadOutput>>;

    /// Widest batch one pass through the macro pool can fuse.
    fn max_batch_lanes(&self) -> usize;

    /// Drain the macro pools' instruction counters accumulated since
    /// the last call (resetting them), for telemetry's instruction and
    /// energy accounting. `None` when the workload does not track
    /// instruction histograms (the default) — telemetry then skips
    /// energy attribution for its batches. Workloads that implement
    /// this must only be probed *between* runs: per-run cycle
    /// accounting inside `run_one`/`run_batched` snapshots its own
    /// baseline, so a between-runs reset never skews it.
    fn take_instr_histogram(&mut self) -> Option<BTreeMap<InstructionKind, u64>> {
        None
    }

    /// Which workload family this engine serves — picks the response
    /// wire encoding for stream read-outs.
    fn kind(&self) -> WorkloadKind;

    /// Begin a pinned-membrane streaming session: reset layer state
    /// and zero the session's cycle attribution. A streaming engine
    /// serves one session at a time — the serve-side stream table
    /// gives each stream its own engine lane.
    fn begin_stream(&mut self) -> Result<()> {
        anyhow::bail!("this workload does not support streaming sessions")
    }

    /// Integrate one chunk into the pinned membrane state: word ids
    /// advance a sentiment stream word-by-word, one image frame is one
    /// membrane timestep for digits. Returns the session's cumulative
    /// macro cycles since [`Workload::begin_stream`].
    fn step_stream(&mut self, chunk: &WorkloadInput) -> Result<u64> {
        let _ = chunk;
        anyhow::bail!("this workload does not support streaming sessions")
    }

    /// Read the current prediction out of the pinned membrane state
    /// without ending the session. Chunked [`Workload::step_stream`]s
    /// followed by one `read_out` are bit-identical to
    /// [`Workload::run_one`] on the concatenated input.
    fn read_out(&mut self) -> Result<WorkloadOutput> {
        anyhow::bail!("this workload does not support streaming sessions")
    }

    /// FNV-1a digest of the workload's current V_MEM state — the
    /// record/replay checkpoint (`docs/REPLAY.md`). Must be a pure
    /// state read: no instruction issued, no counter moved. `None`
    /// (the default) when the workload does not expose membrane state;
    /// recording then captures wire bytes only.
    fn v_digest(&self) -> Option<u64> {
        None
    }
}

fn want_words(input: &WorkloadInput) -> Result<&[i64]> {
    match input {
        WorkloadInput::Words(ids) => Ok(ids),
        WorkloadInput::Image { .. } => {
            anyhow::bail!("sentiment workload cannot serve image requests")
        }
    }
}

impl Workload for SentimentNetwork {
    fn run_one(&mut self, input: &WorkloadInput) -> Result<WorkloadOutput> {
        let r = self.run_review(want_words(input)?)?;
        Ok(WorkloadOutput {
            pred: r.pred,
            v_out: r.v_out,
            v_all: vec![r.v_out],
            cycles: r.cycles,
        })
    }

    fn run_one_pipelined(&mut self, input: &WorkloadInput) -> Result<WorkloadOutput> {
        let r = self.run_review_pipelined(want_words(input)?)?;
        Ok(WorkloadOutput {
            pred: r.pred,
            v_out: r.v_out,
            v_all: vec![r.v_out],
            cycles: r.cycles,
        })
    }

    fn run_batched(&mut self, inputs: &[&WorkloadInput]) -> Result<Vec<WorkloadOutput>> {
        let seqs: Vec<&[i64]> =
            inputs.iter().map(|i| want_words(i)).collect::<Result<_>>()?;
        Ok(self
            .run_reviews_batched(&seqs)?
            .into_iter()
            .map(|r| WorkloadOutput {
                pred: r.pred,
                v_out: r.v_out,
                v_all: vec![r.v_out],
                cycles: r.cycles,
            })
            .collect())
    }

    fn max_batch_lanes(&self) -> usize {
        SentimentNetwork::max_batch_lanes(self)
    }

    fn take_instr_histogram(&mut self) -> Option<BTreeMap<InstructionKind, u64>> {
        let h = self.stats().histogram;
        self.reset_counters();
        Some(h)
    }

    fn kind(&self) -> WorkloadKind {
        WorkloadKind::Sentiment
    }

    fn begin_stream(&mut self) -> Result<()> {
        SentimentNetwork::begin_stream(self)
    }

    fn step_stream(&mut self, chunk: &WorkloadInput) -> Result<u64> {
        self.stream_words(want_words(chunk)?)
    }

    fn read_out(&mut self) -> Result<WorkloadOutput> {
        let (pred, v_out, cycles) = self.stream_read_out();
        Ok(WorkloadOutput { pred, v_out, v_all: vec![v_out], cycles })
    }

    fn v_digest(&self) -> Option<u64> {
        Some(SentimentNetwork::v_digest(self))
    }
}

fn want_image(input: &WorkloadInput) -> Result<&[f32]> {
    match input {
        WorkloadInput::Image { h, w, pixels } => {
            anyhow::ensure!(
                *h == 28 && *w == 28 && pixels.len() == 28 * 28,
                "digits workload needs 28×28 images, got {h}×{w} ({} pixels)",
                pixels.len()
            );
            Ok(pixels)
        }
        WorkloadInput::Words(_) => {
            anyhow::bail!("digits workload cannot serve word-id requests")
        }
    }
}

impl Workload for DigitsNetwork {
    fn run_one(&mut self, input: &WorkloadInput) -> Result<WorkloadOutput> {
        let r = self.run_image(want_image(input)?)?;
        let v_out = r.v_out[r.pred as usize];
        Ok(WorkloadOutput {
            pred: r.pred,
            v_out,
            v_all: r.v_out,
            cycles: r.cycles,
        })
    }

    fn run_batched(&mut self, inputs: &[&WorkloadInput]) -> Result<Vec<WorkloadOutput>> {
        let imgs: Vec<&[f32]> =
            inputs.iter().map(|i| want_image(i)).collect::<Result<_>>()?;
        Ok(self
            .run_images_batched(&imgs)?
            .into_iter()
            .map(|r| {
                let v_out = r.v_out[r.pred as usize];
                WorkloadOutput {
                    pred: r.pred,
                    v_out,
                    v_all: r.v_out,
                    cycles: r.cycles,
                }
            })
            .collect())
    }

    fn max_batch_lanes(&self) -> usize {
        DigitsNetwork::max_batch_lanes(self)
    }

    fn take_instr_histogram(&mut self) -> Option<BTreeMap<InstructionKind, u64>> {
        let h = self.stats().histogram;
        self.reset_counters();
        Some(h)
    }

    fn kind(&self) -> WorkloadKind {
        WorkloadKind::Digits
    }

    fn begin_stream(&mut self) -> Result<()> {
        DigitsNetwork::begin_stream(self)
    }

    fn step_stream(&mut self, chunk: &WorkloadInput) -> Result<u64> {
        self.stream_image_step(want_image(chunk)?)
    }

    fn read_out(&mut self) -> Result<WorkloadOutput> {
        let (pred, v_all, cycles) = self.stream_read_out()?;
        let v_out = v_all[pred as usize];
        Ok(WorkloadOutput { pred, v_out, v_all, cycles })
    }

    fn v_digest(&self) -> Option<u64> {
        Some(DigitsNetwork::v_digest(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DigitsArtifacts, SentimentArtifacts};
    use crate::macro_sim::MacroConfig;

    /// The telemetry sparsity signal: plane-popcounted active units
    /// must match a direct count on both input kinds.
    #[test]
    fn unit_counts_match_direct_counts() {
        let words = WorkloadInput::Words(vec![3, -1, 7, -1, 0]);
        assert_eq!(words.unit_counts(), (5, 3));
        let mut pixels = vec![0.0f32; 130];
        pixels[0] = 0.5;
        pixels[63] = -1.0;
        pixels[64] = 1e-9;
        pixels[129] = 2.0;
        let img = WorkloadInput::Image { h: 13, w: 10, pixels };
        assert_eq!(img.unit_counts(), (130, 4));
        let empty = WorkloadInput::Words(vec![]);
        assert_eq!(empty.unit_counts(), (0, 0));
    }

    #[test]
    fn workloads_reject_foreign_inputs() {
        let a = SentimentArtifacts::synthetic(3);
        let mut s = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let img = WorkloadInput::Image { h: 28, w: 28, pixels: vec![0.0; 28 * 28] };
        assert!(s.run_one(&img).is_err());

        let d = DigitsArtifacts::synthetic(3);
        let mut net = DigitsNetwork::from_artifacts(&d, MacroConfig::fast()).unwrap();
        assert!(net.run_one(&WorkloadInput::Words(vec![1, 2])).is_err());
        let bad = WorkloadInput::Image { h: 4, w: 4, pixels: vec![0.0; 16] };
        assert!(net.run_one(&bad).is_err());
    }

    /// `take_instr_histogram` hands telemetry the instruction issue
    /// since the last call and drains the counters, without touching
    /// per-run cycle accounting.
    #[test]
    fn take_instr_histogram_drains_counters_between_runs() {
        let a = SentimentArtifacts::synthetic(3);
        let mut net = SentimentNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let _ = net.take_instr_histogram(); // discard construction writes
        let input = WorkloadInput::Words(vec![1, 2, 3]);
        let r1 = net.run_one(&input).unwrap();
        assert!(r1.cycles > 0);
        let h = net.take_instr_histogram().expect("sentiment tracks histograms");
        assert!(h.values().sum::<u64>() > 0, "a run must issue instructions");
        let drained = net.take_instr_histogram().unwrap();
        assert_eq!(drained.values().sum::<u64>(), 0, "counters must drain");
        // cycle accounting is per-run and survives the reset
        let r2 = net.run_one(&input).unwrap();
        assert_eq!(r2.cycles, r1.cycles, "reset must not skew per-run cycles");
    }

    #[test]
    fn digits_workload_serves_images_and_reports_lanes() {
        let d = DigitsArtifacts::synthetic(5);
        let mut net = DigitsNetwork::from_artifacts(&d, MacroConfig::fast()).unwrap();
        assert!(net.max_batch_lanes() >= 2);
        let input = WorkloadInput::Image {
            h: 28,
            w: 28,
            pixels: d.test_x[0].clone(),
        };
        let out = Workload::run_one(&mut net, &input).unwrap();
        assert!(out.pred < 10);
        assert_eq!(out.v_all.len(), 10);
        assert_eq!(out.v_out, out.v_all[out.pred as usize]);
        assert_eq!(input.kind(), WorkloadKind::Digits);
    }
}
