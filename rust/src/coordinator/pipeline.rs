//! Layer-pipelined execution.
//!
//! The paper maps layers "successively on IMPULSE"; with one macro pool
//! per layer, layer *l* can process timestep *t* while layer *l+1*
//! processes *t−1* — wavefront pipelining over timesteps. The pipeline
//! moves spike vectors across thread-backed stages via bounded
//! channels (backpressure: a slow stage stalls its producer).
//!
//! Used by the throughput benches and, behind `--pipeline`, by the
//! serve front-end (`crate::serve`) for singleton batches on both the
//! TCP and stdio transports; differential-tested against the
//! sequential execution order, which must produce identical spikes
//! (the stages share no state).

use crate::snn::{FcLayer, LayerStats, SpikePlane};
use crate::Result;
use std::sync::mpsc;

/// Run `inputs` through a chain of borrowed layer stages, one scoped
/// thread per stage with bounded channels in between — the wavefront
/// engine behind both [`LayerPipeline::run_pipelined`] and the serve
/// path's pipelined reviews
/// (`SentimentNetwork::run_review_pipelined`). Stage *i* processes
/// timestep *t* while stage *i+1* processes *t−1*; a slow stage stalls
/// its producer through channel backpressure. Spikes move between
/// stages as packed [`SpikePlane`]s — a 128-wide timestep is two u64
/// words on the wire, and each stage's gather costs its popcount.
///
/// Semantically identical to stepping each timestep through all stages
/// in order (stages share no state); wall-clock approaches
/// `max(stage time) · timesteps` instead of `sum(stage time) ·
/// timesteps`.
pub fn run_stages(
    stages: Vec<&mut FcLayer>,
    inputs: &[SpikePlane],
    channel_depth: usize,
) -> Result<Vec<SpikePlane>> {
    assert!(!stages.is_empty(), "pipeline needs at least one stage");
    let depth = channel_depth.max(1);
    let n = inputs.len();
    std::thread::scope(|scope| -> Result<Vec<SpikePlane>> {
        let (feeder_tx, mut prev_rx) = mpsc::sync_channel::<SpikePlane>(depth);
        let mut handles = Vec::new();
        for layer in stages {
            let (tx, rx_next) = mpsc::sync_channel::<SpikePlane>(depth);
            let rx = std::mem::replace(&mut prev_rx, rx_next);
            handles.push(scope.spawn(move || -> Result<()> {
                while let Ok(spikes) = rx.recv() {
                    let out = layer.step_plane(&spikes)?.clone();
                    if tx.send(out).is_err() {
                        break;
                    }
                }
                Ok(())
            }));
        }
        let final_rx = prev_rx;
        // Feed inputs (blocking on backpressure) off the collector
        // thread so bounded channels cannot deadlock.
        let feeder = scope.spawn(move || {
            for spikes in inputs {
                if feeder_tx.send(spikes.clone()).is_err() {
                    break;
                }
            }
        });
        let mut results = Vec::with_capacity(n);
        let mut starved = false;
        for _ in 0..n {
            match final_rx.recv() {
                Ok(v) => results.push(v),
                Err(_) => {
                    starved = true;
                    break;
                }
            }
        }
        drop(final_rx);
        feeder.join().expect("feeder panicked");
        for h in handles {
            // surfaces the first failing stage's error
            h.join().expect("stage panicked")?;
        }
        if starved {
            anyhow::bail!("pipeline stage died before finishing");
        }
        Ok(results)
    })
}

/// A chain of FC layers executed as a thread-per-stage pipeline.
pub struct LayerPipeline {
    layers: Vec<FcLayer>,
}

impl LayerPipeline {
    pub fn new(layers: Vec<FcLayer>) -> Self {
        assert!(!layers.is_empty());
        for pair in layers.windows(2) {
            assert_eq!(
                pair[0].width(),
                pair[1].fan_in(),
                "layer widths must chain"
            );
        }
        Self { layers }
    }

    /// Sequential reference execution: feed each timestep through all
    /// layers in order. Returns the last layer's spike train.
    pub fn run_sequential(&mut self, inputs: &[Vec<bool>]) -> Result<Vec<Vec<bool>>> {
        let mut out = Vec::with_capacity(inputs.len());
        for spikes in inputs {
            let mut cur = spikes.clone();
            for layer in self.layers.iter_mut() {
                cur = layer.step(&cur)?.to_vec();
            }
            out.push(cur);
        }
        Ok(out)
    }

    /// Pipelined execution: one thread per layer, bounded channels in
    /// between (see [`run_stages`]). Semantically identical to
    /// `run_sequential`. Boolean convenience wrapper — the stages
    /// themselves exchange packed planes.
    pub fn run_pipelined(
        &mut self,
        inputs: &[Vec<bool>],
        channel_depth: usize,
    ) -> Result<Vec<Vec<bool>>> {
        let planes: Vec<SpikePlane> = inputs.iter().map(|v| SpikePlane::from_bools(v)).collect();
        let out = run_stages(self.layers.iter_mut().collect(), &planes, channel_depth)?;
        Ok(out.into_iter().map(|p| p.to_bools()).collect())
    }

    /// Reset all layer states.
    pub fn reset_state(&mut self) -> Result<()> {
        for l in self.layers.iter_mut() {
            l.reset_state()?;
        }
        Ok(())
    }

    /// Merged stats across stages.
    pub fn stats(&self) -> LayerStats {
        let mut s = LayerStats::default();
        for l in &self.layers {
            s.merge(&l.stats());
        }
        s
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::XorShiftRng;
    use crate::macro_sim::MacroConfig;
    use crate::snn::LayerParams;

    fn rand_layers(rng: &mut XorShiftRng, dims: &[usize]) -> Vec<FcLayer> {
        dims.windows(2)
            .map(|d| {
                let w: Vec<Vec<i64>> = (0..d[0])
                    .map(|_| (0..d[1]).map(|_| rng.gen_i64(-8, 8)).collect())
                    .collect();
                FcLayer::new(&w, LayerParams::rmp(50), MacroConfig::fast()).unwrap()
            })
            .collect()
    }

    fn rand_inputs(rng: &mut XorShiftRng, t: usize, m: usize) -> Vec<Vec<bool>> {
        (0..t)
            .map(|_| (0..m).map(|_| rng.gen_bool(0.3)).collect())
            .collect()
    }

    #[test]
    fn pipelined_equals_sequential() {
        let mut rng = XorShiftRng::new(31);
        let dims = [40, 32, 24, 16];
        let inputs = rand_inputs(&mut rng, 20, dims[0]);

        let mut seq = LayerPipeline::new(rand_layers(&mut XorShiftRng::new(500), &dims));
        let want = seq.run_sequential(&inputs).unwrap();

        let mut pipe = LayerPipeline::new(rand_layers(&mut XorShiftRng::new(500), &dims));
        let got = pipe.run_pipelined(&inputs, 4).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn pipeline_reusable_after_run() {
        let mut rng = XorShiftRng::new(32);
        let dims = [16, 8];
        let mut pipe = LayerPipeline::new(rand_layers(&mut rng, &dims));
        let inputs = rand_inputs(&mut rng, 5, 16);
        let a = pipe.run_pipelined(&inputs, 2).unwrap();
        pipe.reset_state().unwrap();
        let b = pipe.run_pipelined(&inputs, 2).unwrap();
        assert_eq!(a, b);
        assert_eq!(pipe.num_layers(), 1);
        assert!(pipe.stats().cycles > 0);
    }

    #[test]
    #[should_panic(expected = "chain")]
    fn mismatched_dims_rejected() {
        let mut rng = XorShiftRng::new(33);
        let l1 = rand_layers(&mut rng, &[8, 4]).remove(0);
        let l2 = rand_layers(&mut rng, &[5, 3]).remove(0);
        LayerPipeline::new(vec![l1, l2]);
    }
}
