//! Request router + worker pool: micro-batched inference over
//! replicated model instances (each worker owns a full macro pool),
//! with latency and energy accounting. This is the deployment shape of
//! L3: the binary is self-contained, Python never runs on this path.
//!
//! The serve path is three stages:
//!
//! 1. **submit** — callers enqueue [`Request`]s on a channel;
//! 2. **batcher** — a collector thread forms micro-batches of up to
//!    `batch_size` requests (or whatever arrived within
//!    `batch_deadline`) and hands each batch to the *least-loaded*
//!    worker shard;
//! 3. **workers** — each worker drains its own shard queue and, when
//!    empty, steals from the most-loaded peer; batches run through the
//!    workload's fused-lane batched path ([`Workload::run_batched`] —
//!    union AccW2V streams), singleton batches optionally through the
//!    wavefront pipeline.
//!
//! The server is workload-generic: any model implementing
//! [`Workload`] (today `SentimentNetwork` and `DigitsNetwork`) serves
//! through the same batcher, shard router, and adaptive sizing.

use super::workload::{Workload, WorkloadInput, WorkloadKind};
use crate::metrics::LatencyStats;
use crate::obs::trace::{elapsed_us, Phase, Span, TraceCtx, TraceRecorder, TraceSummary};
use crate::telemetry::Telemetry;
use crate::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One classification request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// The workload-tagged input (word ids or an image).
    pub input: WorkloadInput,
    /// Trace context from the transport's decode chokepoint, so the
    /// queue/batch/execute spans correlate with the listener-side
    /// ones. `None` (the constructors' default) records nothing.
    pub trace: Option<TraceCtx>,
}

impl Request {
    /// A sentiment request over a word-id sequence.
    pub fn words(id: u64, word_ids: Vec<i64>) -> Request {
        Request { id, input: WorkloadInput::Words(word_ids), trace: None }
    }

    /// A digits request over an `h`×`w` image (row-major pixels).
    pub fn image(id: u64, h: usize, w: usize, pixels: Vec<f32>) -> Request {
        Request { id, input: WorkloadInput::Image { h, w, pixels }, trace: None }
    }
}

/// One classification response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Which workload family served this request (selects the wire
    /// encoding on the serve path).
    pub kind: WorkloadKind,
    pub pred: u8,
    /// Headline potential (output neuron / winning class).
    pub v_out: i64,
    /// All output potentials (length 1 for sentiment, 10 for digits;
    /// empty on errors).
    pub v_all: Vec<i64>,
    pub cycles: u64,
    pub latency: std::time::Duration,
    pub worker: usize,
    /// Size of the micro-batch this request was served in.
    pub batch_size: usize,
    /// Set when inference failed; the numeric fields are zeroed then.
    pub err: Option<String>,
    /// Post-request V_MEM digest of the worker replica that served
    /// this request ([`Workload::v_digest`]), captured only when
    /// [`ServerOptions::capture_digests`] is on and the workload
    /// exposes membrane state. `None` on error responses. Never
    /// serialized onto the wire — this is the record/replay
    /// checkpoint's server-side tap.
    pub v_digest: Option<u64>,
    /// Per-phase timing summary, present only when the request carried
    /// a [`TraceCtx`] and the server is tracing. The transport uses it
    /// to record the write span under the right trace id and to answer
    /// trace-echo requests. Never serialized onto the wire directly.
    pub trace: Option<TraceSummary>,
}

/// Aggregated server statistics.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub completed: u64,
    pub total_cycles: u64,
    pub latency: LatencyStats,
}

/// Serving configuration of an [`InferenceServer`].
#[derive(Clone, Debug)]
pub struct ServerOptions {
    /// Worker threads (each owns a full network replica).
    pub workers: usize,
    /// Maximum requests fused into one micro-batch (1 = no batching).
    pub batch_size: usize,
    /// How long the batcher waits for a batch to fill once its first
    /// request arrived.
    pub batch_deadline: Duration,
    /// Run singleton batches through the wavefront layer pipeline
    /// (`run_review_pipelined`) instead of the sequential step order.
    pub pipeline: bool,
    /// Queue-depth-driven batch sizing: instead of waiting for a fixed
    /// `batch_size` to fill, each batch fuses exactly the requests
    /// already queued when its first request is picked up (capped at
    /// `adaptive_cap`). An idle server answers singletons at minimum
    /// latency; a backed-up queue fuses wide batches automatically.
    /// Ignores `batch_size`/`batch_deadline`.
    pub adaptive: bool,
    /// Widest batch the adaptive batcher forms. Set this to the
    /// model's real fused-lane budget
    /// (`SentimentNetwork::max_batch_lanes`) so backlog spreads across
    /// workers instead of serializing as chunks on one; always clamped
    /// to [`crate::macro_sim::MAX_FUSED_LANES`].
    pub adaptive_cap: usize,
    /// Live telemetry registry the submit chokepoint and worker pool
    /// update in-band (per-kind request/response counters, queue
    /// depth, batch occupancy, instruction and energy attribution).
    /// `None` (the default) records nothing; `serve::ServeCore`
    /// always wires one in.
    pub telemetry: Option<Arc<Telemetry>>,
    /// Most streaming sessions (`serve::StreamTable`) live at once —
    /// each pins an engine replica's membrane state, so the cap bounds
    /// pinned memory. Opens past it are rejected with `StreamLimit`.
    pub max_streams: usize,
    /// Idle time after which a streaming session is evicted (swept by
    /// the TCP accept loop and lazily by every stream operation).
    pub stream_ttl: Duration,
    /// Capture a [`Workload::v_digest`] after every served request and
    /// carry it on [`Response::v_digest`]. Off by default (a digest
    /// walks every macro's V_MEM); `impulse serve --record` and the
    /// replay runner turn it on.
    pub capture_digests: bool,
    /// Per-request span recorder (`impulse serve --trace-dir`),
    /// threaded through exactly like `telemetry`. `None` (the default)
    /// records nothing and costs one branch per chokepoint.
    pub trace: Option<Arc<TraceRecorder>>,
}

impl ServerOptions {
    /// Human-readable description of the configured batching mode
    /// (shared by the `eval`/`serve` CLI banners).
    pub fn batching_label(&self) -> String {
        if self.adaptive {
            "adaptive (queue-depth)".to_string()
        } else if self.batch_size > 1 {
            format!("batch {} deadline {:?}", self.batch_size, self.batch_deadline)
        } else {
            "unbatched".to_string()
        }
    }
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            workers: 1,
            batch_size: 1,
            batch_deadline: Duration::from_micros(200),
            pipeline: false,
            adaptive: false,
            adaptive_cap: crate::macro_sim::MAX_FUSED_LANES,
            telemetry: None,
            max_streams: 8,
            stream_ttl: Duration::from_secs(120),
            capture_digests: false,
            trace: None,
        }
    }
}

/// A request queued with its arrival time.
struct Queued {
    req: Request,
    t0: Instant,
    /// When the batcher picked this request into a batch (initialized
    /// to `t0`; overwritten at batch formation when tracing is on, so
    /// queue wait and batch formation separate into distinct spans).
    t_batched: Instant,
}

/// Shared submit path of [`InferenceServer`] and [`Submitter`] — the
/// single chokepoint every transport funnels through, which is what
/// makes the telemetry submit/queue-depth counters exact.
fn submit_inner(
    tx: &mpsc::Sender<Queued>,
    inflight: &AtomicU64,
    telemetry: &Option<Arc<Telemetry>>,
    req: Request,
) -> Result<()> {
    inflight.fetch_add(1, Ordering::SeqCst);
    let kind = req.input.kind();
    // count the submission *before* it can be answered — a fast worker
    // must never decrement the depth gauge ahead of the increment —
    // and roll back if the queue is gone (mirrors `inflight`)
    if let Some(t) = telemetry {
        t.record_submit(kind);
    }
    let now = Instant::now();
    match tx.send(Queued { req, t0: now, t_batched: now }) {
        Ok(()) => Ok(()),
        Err(_) => {
            inflight.fetch_sub(1, Ordering::SeqCst);
            if let Some(t) = telemetry {
                t.record_submit_rejected(kind);
            }
            Err(anyhow::anyhow!("server shut down"))
        }
    }
}

/// A clone-able request-submission handle onto a running
/// [`InferenceServer`] — the serve front-end's fan-in: every TCP
/// connection and stdio session holds one. The server's batcher only
/// winds down once the server *and* every `Submitter` clone are
/// dropped.
#[derive(Clone)]
pub struct Submitter {
    tx: mpsc::Sender<Queued>,
    inflight: Arc<AtomicU64>,
    telemetry: Option<Arc<Telemetry>>,
}

impl Submitter {
    /// Enqueue a request (same contract as [`InferenceServer::submit`]).
    pub fn submit(&self, req: Request) -> Result<()> {
        submit_inner(&self.tx, &self.inflight, &self.telemetry, req)
    }

    /// Requests submitted but not yet answered (server-wide).
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::SeqCst)
    }
}

/// Load-aware shard queues with work stealing: `push` places an item
/// on the least-loaded shard, `pop(me)` drains the caller's shard and
/// steals from the most-loaded peer when it runs dry. One global mutex
/// — at macro-simulation granularity (milliseconds per batch) the
/// queue is never the bottleneck.
pub struct ShardRouter<T> {
    state: Mutex<ShardState<T>>,
    cv: Condvar,
}

struct ShardState<T> {
    queues: Vec<VecDeque<(T, usize)>>,
    loads: Vec<usize>,
    closed: bool,
}

impl<T> ShardRouter<T> {
    pub fn new(shards: usize) -> Self {
        assert!(shards >= 1);
        Self {
            state: Mutex::new(ShardState {
                queues: (0..shards).map(|_| VecDeque::new()).collect(),
                loads: vec![0; shards],
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue an item of the given weight on the least-loaded shard.
    pub fn push(&self, item: T, weight: usize) {
        let mut s = self.state.lock().expect("router poisoned");
        let shard = s
            .loads
            .iter()
            .enumerate()
            .min_by_key(|&(_, &l)| l)
            .map(|(i, _)| i)
            .unwrap_or(0);
        s.loads[shard] += weight;
        s.queues[shard].push_back((item, weight));
        self.cv.notify_one();
    }

    /// Dequeue for shard `me`: own queue first, then steal from the
    /// most-loaded peer. Blocks until an item is available or the
    /// router is closed and fully drained (→ `None`).
    pub fn pop(&self, me: usize) -> Option<T> {
        let mut s = self.state.lock().expect("router poisoned");
        loop {
            if let Some((item, w)) = s.queues[me].pop_front() {
                s.loads[me] -= w;
                return Some(item);
            }
            let victim = (0..s.queues.len())
                .filter(|&i| i != me && !s.queues[i].is_empty())
                .max_by_key(|&i| s.loads[i]);
            if let Some(v) = victim {
                let (item, w) = s.queues[v].pop_front().expect("victim non-empty");
                s.loads[v] -= w;
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.cv.wait(s).expect("router poisoned");
        }
    }

    /// Close the router: queued items still drain, then `pop` returns
    /// `None` for every shard.
    pub fn close(&self) {
        let mut s = self.state.lock().expect("router poisoned");
        s.closed = true;
        drop(s);
        self.cv.notify_all();
    }

    /// Outstanding weight on one shard (diagnostics).
    pub fn load(&self, shard: usize) -> usize {
        self.state.lock().expect("router poisoned").loads[shard]
    }
}

/// A fixed-pool inference server over replicated [`Workload`] model
/// instances (sentiment or digits — the serving machinery is
/// workload-generic).
pub struct InferenceServer {
    tx: mpsc::Sender<Queued>,
    rx_out: mpsc::Receiver<Response>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    inflight: Arc<AtomicU64>,
    telemetry: Option<Arc<Telemetry>>,
}

impl InferenceServer {
    /// Spawn `n_workers` workers with default (unbatched) options.
    pub fn start<W, F>(n_workers: usize, factory: F) -> Result<Self>
    where
        W: Workload,
        F: Fn() -> Result<W> + Send + Sync + 'static,
    {
        Self::start_with(
            ServerOptions {
                workers: n_workers,
                ..ServerOptions::default()
            },
            factory,
        )
    }

    /// Spawn the batcher and worker pool described by `opts`, each
    /// worker building its own model replica via `factory`.
    pub fn start_with<W, F>(opts: ServerOptions, factory: F) -> Result<Self>
    where
        W: Workload,
        F: Fn() -> Result<W> + Send + Sync + 'static,
    {
        assert!(opts.workers >= 1);
        assert!(opts.batch_size >= 1);
        let (tx, rx) = mpsc::channel::<Queued>();
        let (tx_out, rx_out) = mpsc::channel::<Response>();
        let factory = Arc::new(factory);
        let inflight = Arc::new(AtomicU64::new(0));
        let router: Arc<ShardRouter<Vec<Queued>>> = Arc::new(ShardRouter::new(opts.workers));

        let batcher = {
            let router = Arc::clone(&router);
            let opts = opts.clone();
            let cap = opts.adaptive_cap.clamp(1, crate::macro_sim::MAX_FUSED_LANES);
            std::thread::spawn(move || {
                loop {
                    let first = match rx.recv() {
                        Ok(q) => q,
                        Err(_) => break,
                    };
                    let mut batch = vec![first];
                    if opts.adaptive {
                        // Queue depth drives the batch: fuse whatever
                        // is already waiting (up to the model's fused
                        // lane budget) without holding the head
                        // request back for a deadline.
                        while batch.len() < cap {
                            match rx.try_recv() {
                                Ok(q) => batch.push(q),
                                Err(_) => break,
                            }
                        }
                    } else if opts.batch_size > 1 {
                        let deadline = Instant::now() + opts.batch_deadline;
                        while batch.len() < opts.batch_size {
                            let rem = deadline.saturating_duration_since(Instant::now());
                            if rem.is_zero() {
                                break;
                            }
                            match rx.recv_timeout(rem) {
                                Ok(q) => batch.push(q),
                                Err(_) => break,
                            }
                        }
                    }
                    if opts.trace.is_some() {
                        // one stamp for the whole batch: formation ends
                        // for every member when the batch is sealed
                        let tb = Instant::now();
                        for q in &mut batch {
                            q.t_batched = tb;
                        }
                    }
                    let weight = batch.len();
                    router.push(batch, weight);
                }
                router.close();
            })
        };

        let mut workers = Vec::with_capacity(opts.workers);
        for w in 0..opts.workers {
            let router = Arc::clone(&router);
            let tx_out = tx_out.clone();
            let factory = Arc::clone(&factory);
            let inflight = Arc::clone(&inflight);
            let opts = opts.clone();
            workers.push(std::thread::spawn(move || {
                let mut net = match factory() {
                    Ok(n) => n,
                    Err(e) => {
                        crate::error!("worker", "failed to build network worker={w} err={e:#}");
                        return;
                    }
                };
                // discard construction-time instruction counts so the
                // first batch's telemetry delta is inference only
                let _ = net.take_instr_histogram();
                while let Some(batch) = router.pop(w) {
                    serve_batch(&mut net, w, &opts, batch, &tx_out, &inflight);
                }
            }));
        }
        Ok(Self {
            tx,
            rx_out,
            batcher: Some(batcher),
            workers,
            inflight,
            telemetry: opts.telemetry,
        })
    }

    /// Enqueue a request.
    pub fn submit(&self, req: Request) -> Result<()> {
        submit_inner(&self.tx, &self.inflight, &self.telemetry, req)
    }

    /// A clone-able submission handle sharing this server's queue —
    /// the serve front-end hands one to every client session.
    pub fn submitter(&self) -> Submitter {
        Submitter {
            tx: self.tx.clone(),
            inflight: Arc::clone(&self.inflight),
            telemetry: self.telemetry.clone(),
        }
    }

    /// Block for the next response.
    pub fn recv(&self) -> Result<Response> {
        Ok(self.rx_out.recv()?)
    }

    /// Block up to `timeout` for the next response. Timeout and
    /// disconnection (all workers gone) are distinct errors so pollers
    /// can retry the former and stop on the latter.
    pub fn recv_timeout(
        &self,
        timeout: Duration,
    ) -> std::result::Result<Response, mpsc::RecvTimeoutError> {
        self.rx_out.recv_timeout(timeout)
    }

    /// Non-blocking receive: a ready response, if any.
    pub fn try_recv(&self) -> Option<Response> {
        self.rx_out.try_recv().ok()
    }

    /// Requests submitted but not yet answered.
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Run a whole batch to completion, returning responses ordered by
    /// request id, plus aggregate stats.
    pub fn run_batch(&self, reqs: Vec<Request>) -> Result<(Vec<Response>, ServerStats)> {
        let n = reqs.len();
        for r in reqs {
            self.submit(r)?;
        }
        let mut out = Vec::with_capacity(n);
        let mut stats = ServerStats::default();
        for _ in 0..n {
            let r = self.recv()?;
            stats.completed += 1;
            stats.total_cycles += r.cycles;
            stats.latency.record(r.latency);
            out.push(r);
        }
        out.sort_by_key(|r| r.id);
        Ok((out, stats))
    }

    /// Shut down: close the queue, drain the batcher, join workers.
    pub fn shutdown(self) {
        drop(self.tx);
        if let Some(b) = self.batcher {
            let _ = b.join();
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Energy in femtojoules, for telemetry's integer accumulators.
fn joules_to_fj(e: f64) -> u64 {
    (e * 1e15).round() as u64
}

/// Drain the worker's instruction counters into telemetry and return
/// the batch's attributed energy as femtojoules (0 when the workload
/// does not track histograms).
fn record_batch_energy<W: Workload>(net: &mut W, tele: &Telemetry) -> u64 {
    match net.take_instr_histogram() {
        Some(h) => {
            tele.record_instr(&h);
            joules_to_fj(tele.energy_of(&h))
        }
        None => 0,
    }
}

/// Run one micro-batch on a worker's replica and publish one response
/// per request. Every submitted request yields exactly one response —
/// inference errors come back with [`Response::err`] set instead of
/// being dropped (the serve loop's drain bookkeeping relies on this).
///
/// When a telemetry registry is wired in, the batch is accounted
/// in-band: lane occupancy and observed input sparsity up front, then
/// the worker's instruction-histogram delta is priced through the
/// energy model and split across the batch's requests in proportion to
/// their attributed cycles (`metrics::apportion` — exact, like the
/// cycle split itself).
fn serve_batch<W: Workload>(
    net: &mut W,
    worker: usize,
    opts: &ServerOptions,
    batch: Vec<Queued>,
    tx_out: &mpsc::Sender<Response>,
    inflight: &AtomicU64,
) {
    let n = batch.len();
    let tele = opts.telemetry.as_deref();
    let tr = opts.trace.as_deref();
    // one stamp for the whole batch: queue/batch phases end and the
    // execute phase begins when the worker picks the batch up
    let t_serve = tr.map(|_| Instant::now());
    if let Some(t) = tele {
        t.record_batch(n as u64, net.max_batch_lanes() as u64);
        for q in &batch {
            t.record_input(&q.req.input);
        }
    }
    let outcome = if n == 1 {
        let r = if opts.pipeline {
            net.run_one_pipelined(&batch[0].req.input)
        } else {
            net.run_one(&batch[0].req.input)
        };
        r.map(|r| vec![r])
    } else {
        let inputs: Vec<&WorkloadInput> = batch.iter().map(|q| &q.req.input).collect();
        net.run_batched(&inputs)
    };
    match outcome {
        Ok(results) => {
            // One digest per batch: a fused batch finishes atomically,
            // so every member observes the same post-batch V_MEM. In
            // record mode batches are forced to width 1, making this
            // the exact post-request checkpoint.
            let v_digest = if opts.capture_digests { net.v_digest() } else { None };
            let energy_fj = tele.map(|t| {
                let total = record_batch_energy(net, t);
                let weights: Vec<f64> = results.iter().map(|r| r.cycles as f64).collect();
                crate::metrics::apportion(&weights, total)
            });
            for (i, (q, r)) in batch.iter().zip(results).enumerate() {
                let e = energy_fj.as_ref().map_or(0, |v| v[i]);
                if let Some(t) = tele {
                    t.record_response(q.req.input.kind(), r.cycles, e, true);
                }
                let trace = record_request_spans(tr, q, worker, n, t_serve, r.cycles, e, true);
                // decrement before publishing so inflight() == 0 is
                // observable once every response has been received
                inflight.fetch_sub(1, Ordering::SeqCst);
                let _ = tx_out.send(Response {
                    id: q.req.id,
                    kind: q.req.input.kind(),
                    pred: r.pred,
                    v_out: r.v_out,
                    v_all: r.v_all,
                    cycles: r.cycles,
                    latency: q.t0.elapsed(),
                    worker,
                    batch_size: n,
                    err: None,
                    v_digest,
                    trace,
                });
            }
        }
        Err(e) if n == 1 => {
            let e_fj = tele.map_or(0, |t| {
                // the failed attempt's instruction spend is real; fold
                // it into the error response's attribution
                let e_fj = record_batch_energy(net, t);
                t.record_response(batch[0].req.input.kind(), 0, e_fj, false);
                e_fj
            });
            let trace = record_request_spans(tr, &batch[0], worker, n, t_serve, 0, e_fj, false);
            inflight.fetch_sub(1, Ordering::SeqCst);
            let _ = tx_out.send(err_response(&batch[0], worker, &e, trace));
        }
        Err(_) => {
            // A bad request poisons the fused batch; retry each request
            // alone so its batchmates still succeed.
            let poisoned_fj = tele.map_or_else(Vec::new, |t| {
                // the poisoned fused attempt's spend is real but has no
                // per-lane cycle attribution — split it evenly so the
                // energy counters stay consistent with the instruction
                // counters it was recorded into
                let total = record_batch_energy(net, t);
                crate::metrics::apportion(&vec![1.0; n], total)
            });
            for (i, q) in batch.iter().enumerate() {
                let res = net.run_one(&q.req.input);
                let e_fj = tele.map_or(0, |t| {
                    let e_fj =
                        record_batch_energy(net, t) + poisoned_fj.get(i).copied().unwrap_or(0);
                    match &res {
                        Ok(r) => t.record_response(q.req.input.kind(), r.cycles, e_fj, true),
                        Err(_) => t.record_response(q.req.input.kind(), 0, e_fj, false),
                    }
                    e_fj
                });
                let trace = record_request_spans(
                    tr,
                    q,
                    worker,
                    1,
                    t_serve,
                    res.as_ref().map_or(0, |r| r.cycles),
                    e_fj,
                    res.is_ok(),
                );
                inflight.fetch_sub(1, Ordering::SeqCst);
                let resp = match res {
                    Ok(r) => Response {
                        id: q.req.id,
                        kind: q.req.input.kind(),
                        pred: r.pred,
                        v_out: r.v_out,
                        v_all: r.v_all,
                        cycles: r.cycles,
                        latency: q.t0.elapsed(),
                        worker,
                        batch_size: 1,
                        err: None,
                        v_digest: if opts.capture_digests { net.v_digest() } else { None },
                        trace,
                    },
                    Err(e) => err_response(q, worker, &e, trace),
                };
                let _ = tx_out.send(resp);
            }
        }
    }
}

/// An error response for a failed request (numeric fields zeroed).
fn err_response(
    q: &Queued,
    worker: usize,
    e: &anyhow::Error,
    trace: Option<TraceSummary>,
) -> Response {
    Response {
        id: q.req.id,
        kind: q.req.input.kind(),
        pred: 0,
        v_out: 0,
        v_all: Vec::new(),
        cycles: 0,
        latency: q.t0.elapsed(),
        worker,
        batch_size: 1,
        err: Some(format!("{e:#}")),
        v_digest: None,
        trace,
    }
}

/// Record one request's queue/batch/execute spans and fold the phase
/// durations into the [`TraceSummary`] the transport needs for write
/// spans and trace-echo trailers. A no-op returning `None` unless the
/// server is tracing *and* the request carried a [`TraceCtx`] (solo
/// [`InferenceServer::submit`] callers pass `trace: None` and pay one
/// `Option` branch here).
#[allow(clippy::too_many_arguments)]
fn record_request_spans(
    tr: Option<&TraceRecorder>,
    q: &Queued,
    worker: usize,
    batch: usize,
    t_exec: Option<Instant>,
    cycles: u64,
    energy_fj: u64,
    ok: bool,
) -> Option<TraceSummary> {
    let tr = tr?;
    let ctx = q.req.trace?;
    let t_exec = t_exec?;
    let queue_start = tr.us_of(q.t0);
    let batch_start = tr.us_of(q.t_batched);
    let exec_start = tr.us_of(t_exec);
    let queue_us = batch_start.saturating_sub(queue_start);
    let batch_us = exec_start.saturating_sub(batch_start);
    let execute_us = elapsed_us(t_exec);
    tr.record(Span::new(
        Phase::Queue,
        ctx.trace_id,
        ctx.request_id,
        ctx.conn,
        queue_start,
        queue_us,
    ));
    tr.record(Span::new(
        Phase::Batch,
        ctx.trace_id,
        ctx.request_id,
        ctx.conn,
        batch_start,
        batch_us,
    ));
    tr.record(
        Span::new(
            Phase::Execute,
            ctx.trace_id,
            ctx.request_id,
            ctx.conn,
            exec_start,
            execute_us,
        )
        .with_worker(worker as u32, batch as u32)
        .with_cost(cycles, energy_fj)
        .with_ok(ok),
    );
    Some(TraceSummary {
        trace_id: ctx.trace_id,
        decode_us: ctx.decode_us,
        queue_us,
        batch_us,
        execute_us,
        echo: ctx.echo,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::macro_sim::MacroConfig;
    use crate::snn::{DigitsNetwork, SentimentNetwork};

    fn mini_factory(
        seed: u64,
    ) -> impl Fn() -> Result<SentimentNetwork> + Send + Sync + 'static {
        move || {
            let a = crate::snn::network::tests::mini_artifacts(seed);
            SentimentNetwork::from_artifacts(&a, MacroConfig::fast())
        }
    }

    fn digits_factory(
        seed: u64,
    ) -> impl Fn() -> Result<DigitsNetwork> + Send + Sync + 'static {
        move || {
            let a = crate::data::DigitsArtifacts::synthetic(seed);
            DigitsNetwork::from_artifacts(&a, MacroConfig::fast())
        }
    }

    /// The workload-generic server must serve the digits conv network
    /// through the same batcher/worker machinery, bit-identical to
    /// solo `run_image` runs — including under adaptive batching.
    #[test]
    fn digits_workload_serves_batched_and_matches_solo() {
        let a = crate::data::DigitsArtifacts::synthetic(19);
        let mut solo = DigitsNetwork::from_artifacts(&a, MacroConfig::fast()).unwrap();
        let want: Vec<_> = a
            .test_x
            .iter()
            .take(4)
            .map(|img| solo.run_image(img).unwrap())
            .collect();

        let server = InferenceServer::start_with(
            ServerOptions {
                workers: 2,
                adaptive: true,
                ..ServerOptions::default()
            },
            digits_factory(19),
        )
        .unwrap();
        let reqs: Vec<Request> = a
            .test_x
            .iter()
            .take(4)
            .enumerate()
            .map(|(i, img)| Request::image(i as u64, 28, 28, img.clone()))
            .collect();
        let (responses, stats) = server.run_batch(reqs).unwrap();
        assert_eq!(stats.completed, 4);
        for (r, w) in responses.iter().zip(&want) {
            assert!(r.err.is_none(), "req {} failed: {:?}", r.id, r.err);
            assert_eq!(r.kind, WorkloadKind::Digits);
            assert_eq!(r.pred, w.pred, "req {}", r.id);
            assert_eq!(r.v_all, w.v_out, "req {}: served vs solo potentials", r.id);
            assert_eq!(r.v_out, w.v_out[w.pred as usize]);
        }
        server.shutdown();
    }

    /// A words request on a digits server errs per request instead of
    /// wedging the pool (and vice versa the workload seam holds).
    #[test]
    fn foreign_input_kind_yields_error_response() {
        let server = InferenceServer::start(1, digits_factory(3)).unwrap();
        let (responses, _) = server
            .run_batch(vec![Request::words(0, vec![1, 2, 3])])
            .unwrap();
        assert!(responses[0].err.is_some());
        assert_eq!(server.inflight(), 0);
        server.shutdown();
    }

    #[test]
    fn batch_completes_with_consistent_results() {
        let server = InferenceServer::start(3, mini_factory(7)).unwrap();
        let reqs: Vec<Request> = (0..12)
            .map(|i| Request::words(i, vec![(i as i64) % 20, 3, 5]))
            .collect();
        let (responses, stats) = server.run_batch(reqs.clone()).unwrap();
        assert_eq!(responses.len(), 12);
        assert_eq!(stats.completed, 12);
        assert!(stats.total_cycles > 0);
        assert_eq!(server.inflight(), 0);
        assert!(responses.iter().all(|r| r.err.is_none()));

        // same request id → same prediction regardless of worker
        let (responses2, _) = server.run_batch(reqs).unwrap();
        for (a, b) in responses.iter().zip(&responses2) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.v_out, b.v_out, "req {}: worker replicas must agree", a.id);
        }
        server.shutdown();
    }

    #[test]
    fn single_worker_serializes() {
        let server = InferenceServer::start(1, mini_factory(9)).unwrap();
        let (responses, _) = server
            .run_batch(vec![
                Request::words(0, vec![1]),
                Request::words(1, vec![2]),
            ])
            .unwrap();
        assert!(responses.iter().all(|r| r.worker == 0));
        server.shutdown();
    }

    #[test]
    fn micro_batched_results_match_unbatched() {
        let reqs: Vec<Request> = (0..20)
            .map(|i| Request::words(i, vec![(i as i64) % 20, (3 * i as i64) % 20, 7]))
            .collect();
        let plain = InferenceServer::start(2, mini_factory(11)).unwrap();
        let (want, _) = plain.run_batch(reqs.clone()).unwrap();
        plain.shutdown();

        let batched = InferenceServer::start_with(
            ServerOptions {
                workers: 2,
                batch_size: 8,
                batch_deadline: Duration::from_millis(20),
                ..ServerOptions::default()
            },
            mini_factory(11),
        )
        .unwrap();
        let (got, _) = batched.run_batch(reqs).unwrap();
        assert!(got.iter().any(|r| r.batch_size > 1), "no batch formed");
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.id, w.id);
            assert_eq!(g.pred, w.pred, "req {}", g.id);
            assert_eq!(g.v_out, w.v_out, "req {}: batched vs unbatched", g.id);
        }
        batched.shutdown();
    }

    #[test]
    fn pipelined_singletons_match_sequential() {
        let reqs: Vec<Request> = (0..6)
            .map(|i| Request::words(i, vec![(i as i64) % 20, 2, 9, 4]))
            .collect();
        let plain = InferenceServer::start(1, mini_factory(21)).unwrap();
        let (want, _) = plain.run_batch(reqs.clone()).unwrap();
        plain.shutdown();

        let piped = InferenceServer::start_with(
            ServerOptions {
                workers: 2,
                pipeline: true,
                ..ServerOptions::default()
            },
            mini_factory(21),
        )
        .unwrap();
        let (got, _) = piped.run_batch(reqs).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert_eq!((g.id, g.pred, g.v_out), (w.id, w.pred, w.v_out));
        }
        piped.shutdown();
    }

    #[test]
    fn bad_request_yields_error_response_not_a_drop() {
        let server = InferenceServer::start_with(
            ServerOptions {
                workers: 1,
                batch_size: 4,
                batch_deadline: Duration::from_millis(10),
                ..ServerOptions::default()
            },
            mini_factory(5),
        )
        .unwrap();
        // vocab is 20 in the mini artifacts: id 999 is out of range and
        // must come back as an error response, not poison its batch.
        let reqs = vec![
            Request::words(0, vec![1, 2]),
            Request::words(1, vec![999]),
            Request::words(2, vec![3]),
        ];
        let (responses, _) = server.run_batch(reqs).unwrap();
        assert_eq!(responses.len(), 3);
        assert!(responses[0].err.is_none());
        assert!(responses[1].err.is_some(), "bad word id must error");
        assert!(responses[2].err.is_none());
        assert_eq!(server.inflight(), 0);
        server.shutdown();
    }

    /// Adaptive batches must stay bit-identical to unbatched serving:
    /// queue-depth sizing only changes *how many* requests fuse, never
    /// what any of them computes.
    #[test]
    fn adaptive_batching_matches_unbatched() {
        let reqs: Vec<Request> = (0..24)
            .map(|i| Request::words(i, vec![(i as i64) % 20, (5 * i as i64) % 20, 13]))
            .collect();
        let plain = InferenceServer::start(2, mini_factory(31)).unwrap();
        let (want, _) = plain.run_batch(reqs.clone()).unwrap();
        plain.shutdown();

        let adaptive = InferenceServer::start_with(
            ServerOptions {
                workers: 2,
                adaptive: true,
                ..ServerOptions::default()
            },
            mini_factory(31),
        )
        .unwrap();
        let (got, _) = adaptive.run_batch(reqs).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.id, w.id);
            assert_eq!(g.pred, w.pred, "req {}", g.id);
            assert_eq!(g.v_out, w.v_out, "req {}: adaptive vs unbatched", g.id);
            assert!(
                g.batch_size >= 1 && g.batch_size <= crate::macro_sim::MAX_FUSED_LANES,
                "req {}: batch {} outside the lane cap",
                g.id,
                g.batch_size
            );
        }
        adaptive.shutdown();
    }

    /// The adaptive batcher never forms a batch wider than
    /// `adaptive_cap` (the model's fused-lane budget), so backlog
    /// spreads across workers instead of serializing in chunks.
    #[test]
    fn adaptive_cap_bounds_batch_width() {
        let server = InferenceServer::start_with(
            ServerOptions {
                workers: 1,
                adaptive: true,
                adaptive_cap: 3,
                ..ServerOptions::default()
            },
            mini_factory(23),
        )
        .unwrap();
        let reqs: Vec<Request> = (0..10)
            .map(|i| Request::words(i, vec![(i as i64) % 20]))
            .collect();
        let (responses, _) = server.run_batch(reqs).unwrap();
        assert_eq!(responses.len(), 10);
        assert!(
            responses.iter().all(|r| r.batch_size <= 3),
            "a batch exceeded adaptive_cap"
        );
        server.shutdown();
    }

    /// Submitter clones from many threads all feed the same queue and
    /// every request is answered exactly once.
    #[test]
    fn submitter_clones_fan_into_one_server() {
        let server = InferenceServer::start_with(
            ServerOptions {
                workers: 2,
                adaptive: true,
                ..ServerOptions::default()
            },
            mini_factory(17),
        )
        .unwrap();
        let n_threads = 4;
        let per_thread = 6u64;
        let handles: Vec<_> = (0..n_threads)
            .map(|t| {
                let s = server.submitter();
                std::thread::spawn(move || {
                    for i in 0..per_thread {
                        s.submit(Request::words(t * 100 + i, vec![(i as i64) % 20, 2]))
                            .unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total = n_threads * per_thread;
        let mut seen = std::collections::HashSet::new();
        for _ in 0..total {
            let r = server.recv().unwrap();
            assert!(r.err.is_none(), "req {} failed: {:?}", r.id, r.err);
            assert!(seen.insert(r.id), "req {} answered twice", r.id);
        }
        assert_eq!(server.inflight(), 0);
        server.shutdown();
    }

    /// With a telemetry registry wired in, the counters account the
    /// served load exactly: per-kind submissions and outcomes, cycle
    /// totals conserved against the responses, nonzero energy/EDP,
    /// batch-lane occupancy summing to the request count, and a
    /// drained queue-depth gauge.
    #[test]
    fn telemetry_accounts_served_batches_exactly() {
        use crate::isa::InstructionKind;
        let tele = Arc::new(Telemetry::default());
        let server = InferenceServer::start_with(
            ServerOptions {
                workers: 2,
                adaptive: true,
                telemetry: Some(Arc::clone(&tele)),
                ..ServerOptions::default()
            },
            mini_factory(41),
        )
        .unwrap();
        let reqs: Vec<Request> = (0..9)
            .map(|i| Request::words(i, vec![(i as i64) % 20, 4, 11]))
            .collect();
        let (responses, _) = server.run_batch(reqs).unwrap();
        assert!(responses.iter().all(|r| r.err.is_none()));
        server.shutdown();

        let s = tele.snapshot();
        let k = s.kind(WorkloadKind::Sentiment).unwrap();
        assert_eq!((k.submitted, k.ok, k.err), (9, 9, 0));
        let total_cycles: u64 = responses.iter().map(|r| r.cycles).sum();
        assert_eq!(k.cycles, total_cycles, "attributed cycles must be conserved");
        assert!(k.energy_fj > 0, "served load must attribute energy");
        assert!(k.edp_js > 0.0, "served load must attribute EDP");
        assert_eq!(k.input_units, 9 * 3);
        assert_eq!(k.input_active, 9 * 3, "no padding ids in this load");
        assert_eq!(s.queue_depth, 0, "gauge must drain with the queue");
        assert_eq!(s.batch_lanes, 9, "every request occupies exactly one lane");
        assert!(s.batches >= 1 && s.batches <= 9);
        assert!(s.batch_lane_capacity >= s.batch_lanes);
        assert!(
            s.instr_count(InstructionKind::AccW2V) > 0,
            "spike-driven AccW2V issue must be visible"
        );
        // the digits row stays untouched by a sentiment-only load
        let d = s.kind(WorkloadKind::Digits).unwrap();
        assert_eq!((d.submitted, d.ok, d.err), (0, 0, 0));
    }

    /// Failed requests are accounted as errors (cycles 0) without
    /// wedging the gauge or the per-kind totals.
    #[test]
    fn telemetry_counts_error_responses() {
        let tele = Arc::new(Telemetry::default());
        let server = InferenceServer::start_with(
            ServerOptions {
                workers: 1,
                telemetry: Some(Arc::clone(&tele)),
                ..ServerOptions::default()
            },
            mini_factory(43),
        )
        .unwrap();
        // vocab is 20 in the mini artifacts: id 999 fails inference
        let (responses, _) = server
            .run_batch(vec![Request::words(0, vec![1, 2]), Request::words(1, vec![999])])
            .unwrap();
        assert!(responses[0].err.is_none());
        assert!(responses[1].err.is_some());
        server.shutdown();
        let s = tele.snapshot();
        let k = s.kind(WorkloadKind::Sentiment).unwrap();
        assert_eq!((k.submitted, k.ok, k.err), (2, 1, 1));
        assert_eq!(s.queue_depth, 0);
    }

    #[test]
    fn shard_router_balances_and_steals() {
        let r: ShardRouter<u32> = ShardRouter::new(3);
        r.push(10, 4); // shard 0
        r.push(20, 1); // shard 1 (least loaded)
        r.push(30, 1); // shard 2
        assert_eq!(r.load(0), 4);
        assert_eq!(r.load(1), 1);
        // shard 1 drains its own queue first…
        assert_eq!(r.pop(1), Some(20));
        // …then steals from the most-loaded peer (shard 0)
        assert_eq!(r.pop(1), Some(10));
        assert_eq!(r.load(0), 0);
        assert_eq!(r.pop(2), Some(30));
        r.close();
        assert_eq!(r.pop(0), None);
    }

    #[test]
    fn shard_router_blocks_until_close() {
        let r: Arc<ShardRouter<u8>> = Arc::new(ShardRouter::new(2));
        let r2 = Arc::clone(&r);
        let h = std::thread::spawn(move || r2.pop(0));
        std::thread::sleep(Duration::from_millis(20));
        r.push(7, 1);
        assert_eq!(h.join().unwrap(), Some(7));
        r.close();
        assert_eq!(r.pop(1), None);
    }
}
