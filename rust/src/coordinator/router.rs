//! Request router + worker pool: batched inference over replicated
//! model instances (each worker owns a full macro pool), with latency
//! and energy accounting. This is the deployment shape of L3: the
//! binary is self-contained, Python never runs on this path.

use crate::metrics::LatencyStats;
use crate::snn::SentimentNetwork;
use crate::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// One classification request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub word_ids: Vec<i64>,
}

/// One classification response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub pred: u8,
    pub v_out: i64,
    pub cycles: u64,
    pub latency: std::time::Duration,
    pub worker: usize,
}

/// Aggregated server statistics.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub completed: u64,
    pub total_cycles: u64,
    pub latency: LatencyStats,
}

/// A fixed-pool inference server over replicated sentiment networks.
pub struct InferenceServer {
    tx: mpsc::Sender<Request>,
    rx_out: mpsc::Receiver<Response>,
    workers: Vec<std::thread::JoinHandle<()>>,
    inflight: Arc<AtomicU64>,
}

impl InferenceServer {
    /// Spawn `n_workers` workers, each building its own network replica
    /// via `factory`.
    pub fn start<F>(n_workers: usize, factory: F) -> Result<Self>
    where
        F: Fn() -> Result<SentimentNetwork> + Send + Sync + 'static,
    {
        assert!(n_workers >= 1);
        let (tx, rx) = mpsc::channel::<Request>();
        let (tx_out, rx_out) = mpsc::channel::<Response>();
        let rx = Arc::new(Mutex::new(rx));
        let factory = Arc::new(factory);
        let inflight = Arc::new(AtomicU64::new(0));
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let rx = Arc::clone(&rx);
            let tx_out = tx_out.clone();
            let factory = Arc::clone(&factory);
            let inflight = Arc::clone(&inflight);
            workers.push(std::thread::spawn(move || {
                let mut net = match factory() {
                    Ok(n) => n,
                    Err(e) => {
                        eprintln!("worker {w}: failed to build network: {e}");
                        return;
                    }
                };
                loop {
                    let req = {
                        let guard = rx.lock().expect("poisoned request queue");
                        guard.recv()
                    };
                    let Ok(req) = req else { break };
                    let t0 = Instant::now();
                    let outcome = net.run_review(&req.word_ids);
                    // decrement before publishing so inflight() == 0 is
                    // observable once every response has been received
                    inflight.fetch_sub(1, Ordering::SeqCst);
                    match outcome {
                        Ok(r) => {
                            let _ = tx_out.send(Response {
                                id: req.id,
                                pred: r.pred,
                                v_out: r.v_out,
                                cycles: r.cycles,
                                latency: t0.elapsed(),
                                worker: w,
                            });
                        }
                        Err(e) => eprintln!("worker {w}: inference failed: {e}"),
                    }
                }
            }));
        }
        Ok(Self {
            tx,
            rx_out,
            workers,
            inflight,
        })
    }

    /// Enqueue a request.
    pub fn submit(&self, req: Request) -> Result<()> {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(req)
            .map_err(|_| anyhow::anyhow!("server shut down"))
    }

    /// Block for the next response.
    pub fn recv(&self) -> Result<Response> {
        Ok(self.rx_out.recv()?)
    }

    /// Requests submitted but not yet answered.
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Run a whole batch to completion, returning responses ordered by
    /// request id, plus aggregate stats.
    pub fn run_batch(&self, reqs: Vec<Request>) -> Result<(Vec<Response>, ServerStats)> {
        let n = reqs.len();
        for r in reqs {
            self.submit(r)?;
        }
        let mut out = Vec::with_capacity(n);
        let mut stats = ServerStats::default();
        for _ in 0..n {
            let r = self.recv()?;
            stats.completed += 1;
            stats.total_cycles += r.cycles;
            stats.latency.record(r.latency);
            out.push(r);
        }
        out.sort_by_key(|r| r.id);
        Ok((out, stats))
    }

    /// Shut down: drop the queue and join workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::macro_sim::MacroConfig;

    fn mini_factory(
        seed: u64,
    ) -> impl Fn() -> Result<SentimentNetwork> + Send + Sync + 'static {
        move || {
            let a = crate::snn::network::tests::mini_artifacts(seed);
            SentimentNetwork::from_artifacts(&a, MacroConfig::fast())
        }
    }

    #[test]
    fn batch_completes_with_consistent_results() {
        let server = InferenceServer::start(3, mini_factory(7)).unwrap();
        let reqs: Vec<Request> = (0..12)
            .map(|i| Request {
                id: i,
                word_ids: vec![(i as i64) % 20, 3, 5],
            })
            .collect();
        let (responses, stats) = server.run_batch(reqs.clone()).unwrap();
        assert_eq!(responses.len(), 12);
        assert_eq!(stats.completed, 12);
        assert!(stats.total_cycles > 0);
        assert_eq!(server.inflight(), 0);

        // same request id → same prediction regardless of worker
        let (responses2, _) = server.run_batch(reqs).unwrap();
        for (a, b) in responses.iter().zip(&responses2) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.v_out, b.v_out, "req {}: worker replicas must agree", a.id);
        }
        server.shutdown();
    }

    #[test]
    fn single_worker_serializes() {
        let server = InferenceServer::start(1, mini_factory(9)).unwrap();
        let (responses, _) = server
            .run_batch(vec![
                Request { id: 0, word_ids: vec![1] },
                Request { id: 1, word_ids: vec![2] },
            ])
            .unwrap();
        assert!(responses.iter().all(|r| r.worker == 0));
        server.shutdown();
    }
}
