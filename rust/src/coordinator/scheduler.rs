//! Spike-driven instruction scheduling.
//!
//! The scheduler is where the paper's sparsity claim becomes mechanism:
//! it receives the upstream spike vector and emits AccW2V instructions
//! *only for spiking inputs*, followed by the neuron-update sequence.
//! Instruction count — and therefore energy and delay — is proportional
//! to `(1 − sparsity)`.
//!
//! Batched serving adds a second axis: a *fused* timestep issues one
//! AccW2V per input row in the union of spiking inputs across the
//! batch, broadcast to the spiking lanes' V rows (per-lane write
//! enable). Cost becomes proportional to the union, amortizing
//! instruction issue across requests.

use crate::bitcell::Parity;
use crate::isa::{neuron_sequence, Instruction, NeuronConfigRows, NeuronType, Program};
use crate::snn::spike_union;

/// The plan for one timestep of one tile.
#[derive(Clone, Debug)]
pub struct TimestepPlan {
    pub program: Program,
    pub spikes_in: usize,
    pub fan_in: usize,
}

impl TimestepPlan {
    /// Input sparsity this plan was scheduled under.
    pub fn sparsity(&self) -> f64 {
        if self.fan_in == 0 {
            return 1.0;
        }
        1.0 - self.spikes_in as f64 / self.fan_in as f64
    }
}

/// The fused (batched) plan for one timestep of one tile: the union of
/// spiking input rows across batch lanes, with a per-row lane bitmask.
///
/// This is the *planning/diagnostic* view of the fused issue —
/// `rows` is exactly the stream `FcLayer::step_batch` builds for
/// `ImpulseMacro::acc_w2v_fused` (both go through
/// [`crate::snn::spike_union`], which keeps the two views consistent),
/// packaged with the amortization and union-sparsity figures for
/// cost analysis. The execution path itself calls `spike_union`
/// directly into a reused scratch buffer rather than allocating a
/// plan per timestep; nothing on the serve path constructs a plan.
#[derive(Clone, Debug, Default)]
pub struct FusedTimestepPlan {
    /// `(w_row, lane-bitmask)` per union-spiking input row, row order.
    pub rows: Vec<(usize, u32)>,
    /// Batch lanes the plan covers (active and inactive).
    pub lanes: usize,
    /// Fan-in of the scheduled layer.
    pub fan_in: usize,
    /// Total spikes across lanes — the AccW2V count a per-request
    /// (sequential) issue would pay.
    pub spikes_total: usize,
}

impl FusedTimestepPlan {
    /// AccW2V instructions the fused stream issues (per parity).
    pub fn union_len(&self) -> usize {
        self.rows.len()
    }

    /// Issue amortization vs per-request scheduling: total spikes per
    /// fused instruction (≥ 1 when any lane spikes; 2.0 means each
    /// fused AccW2V serves two lanes on average).
    pub fn amortization(&self) -> f64 {
        if self.rows.is_empty() {
            1.0
        } else {
            self.spikes_total as f64 / self.rows.len() as f64
        }
    }

    /// Sparsity of the fused stream: `1 − union/fan_in`. This is what
    /// the macro's energy proportionality sees under batching.
    pub fn union_sparsity(&self) -> f64 {
        if self.fan_in == 0 {
            1.0
        } else {
            1.0 - self.rows.len() as f64 / self.fan_in as f64
        }
    }
}

/// Scheduler for one tile (one odd/even V-row pair).
#[derive(Clone, Debug)]
pub struct SpikeScheduler {
    pub v_row_odd: usize,
    pub v_row_even: usize,
    pub neuron: NeuronType,
    pub rows_odd: NeuronConfigRows,
    pub rows_even: NeuronConfigRows,
}

impl SpikeScheduler {
    pub fn for_tile(
        v_row_odd: usize,
        v_row_even: usize,
        neuron: NeuronType,
        const_rows: crate::mapper::ConstRows,
    ) -> Self {
        Self {
            v_row_odd,
            v_row_even,
            neuron,
            rows_odd: const_rows.for_parity(Parity::Odd),
            rows_even: const_rows.for_parity(Parity::Even),
        }
    }

    /// Schedule one timestep given the upstream spike vector.
    pub fn schedule(&self, in_spikes: &[bool], with_update: bool) -> TimestepPlan {
        let mut program = Program::new();
        let mut spikes_in = 0;
        for (i, &s) in in_spikes.iter().enumerate() {
            if !s {
                continue;
            }
            spikes_in += 1;
            for (parity, v) in [(Parity::Odd, self.v_row_odd), (Parity::Even, self.v_row_even)]
            {
                program.push(Instruction::AccW2V {
                    w_row: i,
                    v_src: v,
                    v_dst: v,
                    parity,
                });
            }
        }
        if with_update {
            for (parity, v, rows) in [
                (Parity::Odd, self.v_row_odd, self.rows_odd),
                (Parity::Even, self.v_row_even, self.rows_even),
            ] {
                for instr in neuron_sequence(self.neuron, v, rows, parity) {
                    program.push(instr);
                }
            }
        }
        TimestepPlan {
            program,
            spikes_in,
            fan_in: in_spikes.len(),
        }
    }

    /// Schedule one *fused* timestep for a batch of upstream spike
    /// vectors: one AccW2V per union-spiking row, lane-masked.
    /// `active[b]` gates lanes that still have work; every active
    /// lane's spike vector must have the tile's fan-in.
    pub fn schedule_fused(&self, batch: &[&[bool]], active: &[bool]) -> FusedTimestepPlan {
        let fan_in = batch
            .iter()
            .zip(active)
            .filter(|&(_, &a)| a)
            .map(|(s, _)| s.len())
            .max()
            .unwrap_or(0);
        let mut rows = Vec::new();
        let spikes_total = spike_union(batch, active, &mut rows);
        FusedTimestepPlan {
            rows,
            lanes: batch.len(),
            fan_in,
            spikes_total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::InstructionKind;
    use crate::mapper::ConstRows;
    use crate::proptest_lite::{forall_ctx, gen};

    fn sched(neuron: NeuronType) -> SpikeScheduler {
        SpikeScheduler::for_tile(0, 1, neuron, ConstRows::default())
    }

    #[test]
    fn instruction_count_proportional_to_spikes() {
        let s = sched(NeuronType::RMP);
        for n_spikes in [0usize, 1, 13, 64, 128] {
            let mut spikes = vec![false; 128];
            for i in 0..n_spikes {
                spikes[i] = true;
            }
            let plan = s.schedule(&spikes, true);
            let h = plan.program.histogram();
            assert_eq!(
                h.get(&InstructionKind::AccW2V).copied().unwrap_or(0),
                2 * n_spikes as u64
            );
            // RMP update: 2 SpikeCheck + 2 AccV2V
            assert_eq!(h[&InstructionKind::SpikeCheck], 2);
            assert_eq!(plan.program.len() as u64, 2 * n_spikes as u64 + 4);
        }
    }

    #[test]
    fn sparsity_computed_from_plan() {
        let s = sched(NeuronType::IF);
        let mut spikes = vec![false; 100];
        for i in 0..15 {
            spikes[i] = true;
        }
        let plan = s.schedule(&spikes, false);
        assert!((plan.sparsity() - 0.85).abs() < 1e-9);
    }

    /// Property: the scheduled program only ever touches the tile's own
    /// V rows and the constant rows — scheduling cannot corrupt other
    /// tiles' state (the coordinator's isolation invariant).
    #[test]
    fn prop_schedule_touches_only_tile_rows() {
        let s = sched(NeuronType::LIF);
        let allowed: std::collections::HashSet<usize> = [
            0usize, 1, 26, 27, 28, 29, 30, 31,
        ]
        .into_iter()
        .collect();
        forall_ctx(
            200,
            0xBEEF,
            |rng| { let p = rng.gen_f64(); gen::spikes(rng, 128, p) },
            |spikes| {
                let plan = s.schedule(spikes, true);
                for instr in &plan.program {
                    let rows: Vec<usize> = match *instr {
                        Instruction::AccW2V { v_src, v_dst, .. } => vec![v_src, v_dst],
                        Instruction::AccV2V {
                            src_a, src_b, dst, ..
                        } => vec![src_a, src_b, dst],
                        Instruction::SpikeCheck { v_row, thr_row, .. } => {
                            vec![v_row, thr_row]
                        }
                        Instruction::ResetV { reset_row, dst, .. } => vec![reset_row, dst],
                        _ => vec![],
                    };
                    for r in rows {
                        if !allowed.contains(&r) {
                            return Err(format!("instruction {instr:?} touches row {r}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fused_plan_amortizes_shared_spikes() {
        let s = sched(NeuronType::RMP);
        // Three lanes spiking on overlapping rows: union is 3 rows,
        // total is 6 spikes → amortization 2.0.
        let a = vec![true, true, false, false];
        let b = vec![true, false, true, false];
        let c = vec![true, true, true, false];
        let plan = s.schedule_fused(
            &[&a[..], &b[..], &c[..]],
            &[true, true, true],
        );
        assert_eq!(plan.union_len(), 3);
        assert_eq!(plan.spikes_total, 6);
        assert!((plan.amortization() - 2.0).abs() < 1e-12);
        assert!((plan.union_sparsity() - 0.25).abs() < 1e-12);
        assert_eq!(plan.rows[0], (0, 0b111));
        assert_eq!(plan.rows[1], (1, 0b101));
        assert_eq!(plan.rows[2], (2, 0b110));
    }

    #[test]
    fn fused_plan_single_lane_matches_sequential_schedule() {
        let s = sched(NeuronType::IF);
        let mut spikes = vec![false; 64];
        for i in [3usize, 17, 40] {
            spikes[i] = true;
        }
        let plan = s.schedule(&spikes, false);
        let fused = s.schedule_fused(&[&spikes[..]], &[true]);
        assert_eq!(fused.union_len(), plan.spikes_in);
        assert_eq!(fused.spikes_total, plan.spikes_in);
        assert!((fused.amortization() - 1.0).abs() < 1e-12);
        let rows: Vec<usize> = fused.rows.iter().map(|&(r, _)| r).collect();
        assert_eq!(rows, vec![3, 17, 40]);
    }

    #[test]
    fn fused_plan_all_silent_is_empty() {
        let s = sched(NeuronType::RMP);
        let quiet = vec![false; 16];
        let plan = s.schedule_fused(&[&quiet[..], &quiet[..]], &[true, false]);
        assert_eq!(plan.union_len(), 0);
        assert_eq!(plan.union_sparsity(), 1.0);
        assert_eq!(plan.amortization(), 1.0);
    }

    /// Property: instruction count is exactly 2·spikes + update cost.
    #[test]
    fn prop_cost_model_exact() {
        let s = sched(NeuronType::RMP);
        forall_ctx(
            300,
            0xCAFE,
            |rng| { let p = rng.gen_f64(); gen::spikes(rng, 128, p) },
            |spikes| {
                let plan = s.schedule(spikes, true);
                let n = spikes.iter().filter(|&&b| b).count();
                let expect = 2 * n + 2 * NeuronType::RMP.instructions_per_update();
                if plan.program.len() != expect {
                    return Err(format!("{} != {}", plan.program.len(), expect));
                }
                Ok(())
            },
        );
    }
}
