//! The multi-macro coordinator (Layer 3).
//!
//! The paper's contribution is the macro; the coordinator is the
//! runtime a deployment wraps around a *pool* of such macros
//! ("scalable to larger networks by employing a distributed
//! multi-macro architecture"):
//!
//! - [`workload`] — the model seam: any [`Workload`] (sentiment FC
//!   stack, digits conv network, …) with a fused-lane batched path
//!   serves through the same batcher/router/adaptive machinery.
//! - [`scheduler`] — turns spike activity into per-macro instruction
//!   streams, exploiting input sparsity (spikes → instructions is the
//!   macro's energy-proportionality mechanism).
//! - [`router`] — a micro-batching request router + work-stealing
//!   worker pool running replicated model instances: batches fuse their
//!   AccW2V issue across requests (union of spiking inputs), and shards
//!   are assigned by load rather than round-robin (the serving-system
//!   shape of L3).
//! - [`pipeline`] — layer-pipelined execution across threads: layer *l*
//!   processes timestep *t* while layer *l+1* processes *t−1*, matching
//!   the paper's "mapped successively on IMPULSE" dataflow. Wired into
//!   the serve path for singleton batches via
//!   `SentimentNetwork::run_review_pipelined`.

pub mod pipeline;
pub mod router;
pub mod scheduler;
pub mod workload;

pub use pipeline::{run_stages, LayerPipeline};
pub use router::{
    InferenceServer, Request, Response, ServerOptions, ServerStats, ShardRouter, Submitter,
};
pub use scheduler::{FusedTimestepPlan, SpikeScheduler, TimestepPlan};
pub use workload::{Workload, WorkloadInput, WorkloadKind, WorkloadOutput};
