//! The multi-macro coordinator (Layer 3).
//!
//! The paper's contribution is the macro; the coordinator is the
//! runtime a deployment wraps around a *pool* of such macros
//! ("scalable to larger networks by employing a distributed
//! multi-macro architecture"):
//!
//! - [`scheduler`] — turns spike activity into per-macro instruction
//!   streams, exploiting input sparsity (spikes → instructions is the
//!   macro's energy-proportionality mechanism).
//! - [`router`] — a request router + worker pool running replicated
//!   model instances: batched inference with latency accounting (the
//!   serving-system shape of L3).
//! - [`pipeline`] — layer-pipelined execution across threads: layer *l*
//!   processes timestep *t* while layer *l+1* processes *t−1*, matching
//!   the paper's "mapped successively on IMPULSE" dataflow.

pub mod pipeline;
pub mod router;
pub mod scheduler;

pub use pipeline::LayerPipeline;
pub use router::{InferenceServer, Request, Response, ServerStats};
pub use scheduler::{SpikeScheduler, TimestepPlan};
