//! The calibrated power/energy model.

use crate::isa::InstructionKind;
use crate::NOMINAL_VDD;
use std::collections::BTreeMap;

/// Published per-instruction energy efficiency at point D
/// (0.85 V, 200 MHz), in TOPS/W; 1 op ≡ one 11-bit CIM instruction.
/// (Paper §III: "0.99 TOPS/W for AccW2V … AccV2V, ResetV, and
/// SpikeCheck achieve 1.18, 1.02, and 1.22 TOPS/W".)
pub const TOPS_PER_W_AT_D: [(InstructionKind, f64); 4] = [
    (InstructionKind::AccW2V, 0.99),
    (InstructionKind::AccV2V, 1.18),
    (InstructionKind::ResetV, 1.02),
    (InstructionKind::SpikeCheck, 1.22),
];

/// One (V, f) operating point with the paper's measured power, from
/// Table I's three "This Work" columns.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OperatingPoint {
    pub label: &'static str,
    pub vdd: f64,
    pub freq_hz: f64,
    /// Measured average power from the paper (W); None for the
    /// intermediate Shmoo points A–G the paper marks but does not
    /// tabulate.
    pub measured_power_w: Option<f64>,
}

/// The Fig 9(a) operating points of interest (A–G). The paper
/// identifies seven points on the CIM Shmoo boundary but tabulates
/// power only at the three Table I columns; the intermediate labels
/// follow the boundary (modelling choice; DESIGN.md §6).
pub const OPERATING_POINTS: [OperatingPoint; 7] = [
    OperatingPoint { label: "A", vdd: 0.70, freq_hz: 66.67e6, measured_power_w: Some(0.072e-3) },
    OperatingPoint { label: "B", vdd: 0.75, freq_hz: 100.0e6, measured_power_w: None },
    OperatingPoint { label: "C", vdd: 0.80, freq_hz: 150.0e6, measured_power_w: None },
    OperatingPoint { label: "D", vdd: 0.85, freq_hz: 200.0e6, measured_power_w: Some(0.201e-3) },
    OperatingPoint { label: "E", vdd: 0.95, freq_hz: 300.0e6, measured_power_w: None },
    OperatingPoint { label: "F", vdd: 1.05, freq_hz: 400.0e6, measured_power_w: None },
    OperatingPoint { label: "G", vdd: 1.20, freq_hz: 500.0e6, measured_power_w: Some(0.88e-3) },
];

/// Per-instruction energy table at a given supply.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct InstrEnergy {
    pub acc_w2v_pj: f64,
    pub acc_v2v_pj: f64,
    pub spike_check_pj: f64,
    pub reset_v_pj: f64,
    /// Plain SRAM read/write energy (modelled at 0.8× of ResetV — a
    /// single-row access without the adder chain).
    pub sram_rw_pj: f64,
}

impl InstrEnergy {
    pub fn of(&self, k: InstructionKind) -> f64 {
        match k {
            InstructionKind::AccW2V => self.acc_w2v_pj,
            InstructionKind::AccV2V => self.acc_v2v_pj,
            InstructionKind::SpikeCheck => self.spike_check_pj,
            InstructionKind::ResetV => self.reset_v_pj,
            InstructionKind::ReadV | InstructionKind::WriteV | InstructionKind::WriteW => {
                self.sram_rw_pj
            }
        }
    }
}

/// The calibrated model.
///
/// `P(V, f) = E_dyn(V)·f + P_static(V)` with
/// `E_dyn(V) = (ē − P₀/f₀)·(V/V₀)^γ` and
/// `P_static(V) = P₀·e^{k(V−V₀)}`, where ē is the total AccW2V energy
/// per cycle at point D (from the published 0.99 TOPS/W) and f₀ =
/// 200 MHz. P_static bundles true leakage with frequency-independent
/// overhead (clock tree, control), so its fitted slope `k` may be
/// negative — the published measurements have *higher* energy/cycle at
/// 0.7 V/66.67 MHz than at point D, which only a static component that
/// does not vanish at low V can reproduce. The three shape parameters
/// (γ, P₀, k) are fitted by grid search + refinement to the three
/// published (V, f, P) points.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    /// Total AccW2V energy per cycle at point D (J).
    e0: f64,
    /// Voltage exponent of dynamic energy.
    gamma: f64,
    /// Static/overhead power at V₀ (W).
    leak0: f64,
    /// Static-power voltage slope (1/V); may be negative (see above).
    leak_k: f64,
    /// Per-instruction total energy at point D (J), keyed by kind.
    instr0: BTreeMap<InstructionKind, f64>,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self::calibrated()
    }
}

impl EnergyModel {
    /// Calibrate against the paper's published numbers.
    pub fn calibrated() -> Self {
        // Per-instruction energies at point D from TOPS/W.
        let mut instr0 = BTreeMap::new();
        for (k, tops_per_w) in TOPS_PER_W_AT_D {
            instr0.insert(k, 1e-12 / tops_per_w); // J per op
        }
        let e0 = 1e-12 / 0.99; // AccW2V is the headline per-cycle energy

        // Fit (gamma, leak0, leak_k) to the three measured points by
        // coarse-to-fine grid search on summed squared relative error.
        let pts: Vec<(f64, f64, f64)> = OPERATING_POINTS
            .iter()
            .filter_map(|p| p.measured_power_w.map(|w| (p.vdd, p.freq_hz, w)))
            .collect();
        let f0 = crate::NOMINAL_FREQ_HZ;
        let mut best = (f64::INFINITY, 1.6, 1e-5, 0.0);
        type Best = (f64, f64, f64, f64);
        let search = |g_lo: f64,
                      g_hi: f64,
                      l_lo: f64,
                      l_hi: f64,
                      k_lo: f64,
                      k_hi: f64,
                      n: usize,
                      best: &mut Best| {
            for gi in 0..n {
                let g = g_lo + (g_hi - g_lo) * gi as f64 / (n - 1) as f64;
                for li in 0..n {
                    let l = l_lo + (l_hi - l_lo) * li as f64 / (n - 1) as f64;
                    let e_dyn0 = e0 - l / f0;
                    if e_dyn0 <= 0.0 {
                        continue;
                    }
                    for ki in 0..n {
                        let k = k_lo + (k_hi - k_lo) * ki as f64 / (n - 1) as f64;
                        let err: f64 = pts
                            .iter()
                            .map(|&(v, f, p)| {
                                let pred = e_dyn0 * (v / NOMINAL_VDD).powf(g) * f
                                    + l * ((v - NOMINAL_VDD) * k).exp();
                                ((pred - p) / p).powi(2)
                            })
                            .sum();
                        if err < best.0 {
                            *best = (err, g, l, k);
                        }
                    }
                }
            }
        };
        search(0.5, 2.4, 1e-7, 1.2e-4, -8.0, 8.0, 49, &mut best);
        let (_, g, l, k) = best;
        search(
            (g - 0.1).max(0.3),
            g + 0.1,
            (l * 0.6).max(1e-8),
            l * 1.4,
            k - 0.4,
            k + 0.4,
            49,
            &mut best,
        );
        let (err, gamma, leak0, leak_k) = best;
        debug_assert!(err.is_finite());

        Self {
            e0,
            gamma,
            leak0,
            leak_k,
            instr0,
        }
    }

    /// Fitted voltage exponent.
    pub fn gamma(&self) -> f64 {
        self.gamma
    }

    /// Dynamic-energy voltage scaling factor relative to V₀.
    #[inline]
    pub fn vscale(&self, vdd: f64) -> f64 {
        (vdd / NOMINAL_VDD).powf(self.gamma)
    }

    /// Energy of one instruction at the given supply (J), at the
    /// nominal V↔f pairing (i.e. the static share is the point-D one,
    /// scaled with V^γ like the dynamic part). This is the quantity the
    /// paper's Fig 6 / Fig 11 report; for off-pairing frequencies use
    /// [`EnergyModel::tops_per_w`], which splits static power out
    /// explicitly.
    pub fn instr_energy_j(&self, k: InstructionKind, vdd: f64) -> f64 {
        let sram = self.instr0[&InstructionKind::ResetV] * 0.8;
        let base = match k {
            InstructionKind::ReadV | InstructionKind::WriteV | InstructionKind::WriteW => sram,
            _ => self.instr0[&k],
        };
        base * self.vscale(vdd)
    }

    /// Dynamic-only energy of one instruction at a supply (J).
    fn instr_dyn_energy_j(&self, k: InstructionKind, vdd: f64) -> f64 {
        let static_share = self.leak0 / crate::NOMINAL_FREQ_HZ;
        (self.instr_energy_j(k, NOMINAL_VDD) - static_share).max(1e-15) * self.vscale(vdd)
    }

    /// Per-instruction energy table at a supply (pJ).
    pub fn instr_table(&self, vdd: f64) -> InstrEnergy {
        InstrEnergy {
            acc_w2v_pj: self.instr_energy_j(InstructionKind::AccW2V, vdd) * 1e12,
            acc_v2v_pj: self.instr_energy_j(InstructionKind::AccV2V, vdd) * 1e12,
            spike_check_pj: self.instr_energy_j(InstructionKind::SpikeCheck, vdd) * 1e12,
            reset_v_pj: self.instr_energy_j(InstructionKind::ResetV, vdd) * 1e12,
            sram_rw_pj: self.instr_energy_j(InstructionKind::ReadV, vdd) * 1e12,
        }
    }

    /// Static (leakage + fixed-overhead) power at a supply (W).
    pub fn leakage_w(&self, vdd: f64) -> f64 {
        self.leak0 * ((vdd - NOMINAL_VDD) * self.leak_k).exp()
    }

    /// Average power running AccW2V back-to-back at (V, f) (W) — what
    /// Fig 9(a) plots.
    pub fn avg_power_w(&self, vdd: f64, freq_hz: f64) -> f64 {
        let e_dyn0 = self.e0 - self.leak0 / crate::NOMINAL_FREQ_HZ;
        e_dyn0 * self.vscale(vdd) * freq_hz + self.leakage_w(vdd)
    }

    /// Energy efficiency for an instruction kind at (V, f) in TOPS/W
    /// (1 op = one 11-bit instruction), including the static-power
    /// share of the cycle.
    pub fn tops_per_w(&self, k: InstructionKind, vdd: f64, freq_hz: f64) -> f64 {
        let e_cycle = self.instr_dyn_energy_j(k, vdd) + self.leakage_w(vdd) / freq_hz;
        1e-12 / e_cycle
    }

    /// Total energy (J) of an instruction histogram at a supply.
    pub fn program_energy_j(
        &self,
        hist: &BTreeMap<InstructionKind, u64>,
        vdd: f64,
    ) -> f64 {
        hist.iter()
            .map(|(k, &n)| self.instr_energy_j(*k, vdd) * n as f64)
            .sum()
    }

    /// Wall-clock (s) of `cycles` at `freq_hz` (every instruction is
    /// single-cycle).
    pub fn delay_s(&self, cycles: u64, freq_hz: f64) -> f64 {
        cycles as f64 / freq_hz
    }

    /// GOPS/mm² at an operating point given the die area (Table I row).
    pub fn gops_per_mm2(&self, freq_hz: f64, area_mm2: f64) -> f64 {
        freq_hz / 1e9 / area_mm2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_d_energies_match_published_tops_per_w() {
        let m = EnergyModel::calibrated();
        let t = m.instr_table(NOMINAL_VDD);
        assert!((t.acc_w2v_pj - 1.0101).abs() < 0.01, "{}", t.acc_w2v_pj);
        assert!((t.acc_v2v_pj - 0.8475).abs() < 0.01);
        assert!((t.reset_v_pj - 0.9804).abs() < 0.01);
        assert!((t.spike_check_pj - 0.8197).abs() < 0.01);
    }

    #[test]
    fn fig6_neuron_update_energies() {
        // IF = SpikeCheck + ResetV ≈ 1.81 pJ; LIF ≈ 2.67; RMP ≈ 1.68.
        let m = EnergyModel::calibrated();
        let t = m.instr_table(NOMINAL_VDD);
        let if_e = t.spike_check_pj + t.reset_v_pj;
        let lif_e = t.acc_v2v_pj + t.spike_check_pj + t.reset_v_pj;
        let rmp_e = t.spike_check_pj + t.acc_v2v_pj;
        assert!((if_e - 1.81).abs() < 0.02, "IF {if_e}");
        assert!((lif_e - 2.67).abs() < 0.04, "LIF {lif_e}");
        assert!((rmp_e - 1.68).abs() < 0.02, "RMP {rmp_e}");
    }

    #[test]
    fn fitted_power_matches_measured_points() {
        let m = EnergyModel::calibrated();
        for p in OPERATING_POINTS {
            if let Some(meas) = p.measured_power_w {
                let pred = m.avg_power_w(p.vdd, p.freq_hz);
                let rel = (pred - meas).abs() / meas;
                assert!(
                    rel < 0.15,
                    "point {}: predicted {:.4} mW vs measured {:.4} mW (rel {rel:.3})",
                    p.label,
                    pred * 1e3,
                    meas * 1e3
                );
            }
        }
    }

    #[test]
    fn efficiency_peaks_near_point_d() {
        // Table I: 0.91 (0.7 V) / 0.99 (0.85 V) / 0.57 (1.2 V) TOPS/W —
        // point D is the optimum. The model must reproduce the ordering.
        let m = EnergyModel::calibrated();
        let eff = |label: &str| {
            let p = OPERATING_POINTS.iter().find(|p| p.label == label).unwrap();
            m.tops_per_w(InstructionKind::AccW2V, p.vdd, p.freq_hz)
        };
        let (a, d, g) = (eff("A"), eff("D"), eff("G"));
        assert!(d > a, "D ({d}) should beat A ({a})");
        assert!(d > g, "D ({d}) should beat G ({g})");
        assert!((d - 0.99).abs() < 0.12, "D efficiency {d}");
        assert!(g < 0.75, "G efficiency {g}");
    }

    #[test]
    fn energy_scales_with_voltage() {
        let m = EnergyModel::calibrated();
        let lo = m.instr_energy_j(InstructionKind::AccW2V, 0.7);
        let mid = m.instr_energy_j(InstructionKind::AccW2V, 0.85);
        let hi = m.instr_energy_j(InstructionKind::AccW2V, 1.2);
        assert!(lo < mid && mid < hi);
    }

    #[test]
    fn program_energy_sums_histogram() {
        let m = EnergyModel::calibrated();
        let mut h = BTreeMap::new();
        h.insert(InstructionKind::AccW2V, 10u64);
        h.insert(InstructionKind::SpikeCheck, 2u64);
        let e = m.program_energy_j(&h, NOMINAL_VDD) * 1e12;
        assert!((e - (10.0 * 1.0101 + 2.0 * 0.8197)).abs() < 0.05);
    }

    #[test]
    fn delay_is_cycles_over_freq() {
        let m = EnergyModel::calibrated();
        assert_eq!(m.delay_s(200, crate::NOMINAL_FREQ_HZ), 1e-6);
    }

    #[test]
    fn table1_gops_per_area() {
        // 200 MHz / 0.089 mm² = 2.24 GOPS/mm² (Table I, point D column).
        let m = EnergyModel::calibrated();
        let g = m.gops_per_mm2(200e6, 0.089);
        assert!((g - 2.247).abs() < 0.01);
    }
}
