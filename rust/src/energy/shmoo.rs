//! Shmoo (Fig 8): pass/fail regions over (V, f) for plain read/write
//! vs CIM instructions.
//!
//! The analog content of the Shmoo is the maximum-frequency boundary;
//! we model it with the alpha-power law `Fmax(V) = K·(V−V_th)^α / V`
//! whose (K, α, V_th) are fitted so the CIM boundary passes through the
//! three published operating points (0.7 V→66.67 MHz, 0.85→200,
//! 1.2→500). The read/write path is shorter than the
//! sense→BLFA→ripple→CWD chain, so its boundary sits higher — the paper
//! shows the CIM window strictly inside the R/W window; we model
//! `K_rw = 1.6·K_cim` (modelling choice, DESIGN.md §6).

/// Which timing path the Shmoo tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShmooPath {
    /// Plain SRAM read/write.
    ReadWrite,
    /// CIM instructions (all four; the paper's CIM Shmoo covers the
    /// full instruction test).
    Cim,
}

/// Fitted Fmax model.
#[derive(Clone, Debug)]
pub struct ShmooModel {
    k_cim: f64,
    alpha: f64,
    v_th: f64,
    rw_ratio: f64,
}

/// Published CIM boundary points (V, Fmax Hz).
pub const CIM_BOUNDARY: [(f64, f64); 3] = [
    (0.70, 66.67e6),
    (0.85, 200.0e6),
    (1.20, 500.0e6),
];

impl Default for ShmooModel {
    fn default() -> Self {
        Self::calibrated()
    }
}

impl ShmooModel {
    /// Fit (K, α, V_th) to the published boundary by grid search.
    pub fn calibrated() -> Self {
        let mut best = (f64::INFINITY, 1.0, 1.5, 0.45);
        for vi in 0..30 {
            let v_th = 0.30 + 0.01 * vi as f64;
            for ai in 0..60 {
                let alpha = 1.0 + 0.03 * ai as f64;
                // K from the 0.85 V point, error over the others.
                let k = 200.0e6 * 0.85 / (0.85f64 - v_th).powf(alpha);
                let err: f64 = CIM_BOUNDARY
                    .iter()
                    .map(|&(v, f)| {
                        let pred = k * (v - v_th).max(1e-9).powf(alpha) / v;
                        ((pred - f) / f).powi(2)
                    })
                    .sum();
                if err < best.0 {
                    best = (err, k, alpha, v_th);
                }
            }
        }
        let (_, k_cim, alpha, v_th) = best;
        Self {
            k_cim,
            alpha,
            v_th,
            rw_ratio: 1.6,
        }
    }

    /// Maximum passing frequency for a path at a supply (Hz).
    pub fn fmax_hz(&self, path: ShmooPath, vdd: f64) -> f64 {
        if vdd <= self.v_th {
            return 0.0;
        }
        let k = match path {
            ShmooPath::Cim => self.k_cim,
            ShmooPath::ReadWrite => self.k_cim * self.rw_ratio,
        };
        k * (vdd - self.v_th).powf(self.alpha) / vdd
    }

    /// Does (V, f) pass for the path?
    pub fn passes(&self, path: ShmooPath, vdd: f64, freq_hz: f64) -> bool {
        freq_hz <= self.fmax_hz(path, vdd)
    }

    /// Generate the full pass/fail grid (the Shmoo plot data).
    pub fn grid(
        &self,
        vdds: &[f64],
        freqs_hz: &[f64],
    ) -> ShmooGrid {
        let mut cells = Vec::with_capacity(vdds.len() * freqs_hz.len());
        for &f in freqs_hz {
            for &v in vdds {
                cells.push((
                    self.passes(ShmooPath::ReadWrite, v, f),
                    self.passes(ShmooPath::Cim, v, f),
                ));
            }
        }
        ShmooGrid {
            vdds: vdds.to_vec(),
            freqs_hz: freqs_hz.to_vec(),
            cells,
        }
    }

    /// The standard sweep the harness prints (0.6–1.2 V × 25–550 MHz).
    pub fn standard_grid(&self) -> ShmooGrid {
        let vdds: Vec<f64> = (0..13).map(|i| 0.60 + 0.05 * i as f64).collect();
        let freqs: Vec<f64> = (1..=22).map(|i| 25.0e6 * i as f64).collect();
        self.grid(&vdds, &freqs)
    }
}

/// A rendered Shmoo grid: `cells[f_idx * vdds.len() + v_idx] =
/// (rw_pass, cim_pass)`.
#[derive(Clone, Debug)]
pub struct ShmooGrid {
    pub vdds: Vec<f64>,
    pub freqs_hz: Vec<f64>,
    pub cells: Vec<(bool, bool)>,
}

impl ShmooGrid {
    pub fn get(&self, v_idx: usize, f_idx: usize) -> (bool, bool) {
        self.cells[f_idx * self.vdds.len() + v_idx]
    }

    /// ASCII rendering (highest frequency on top): `#` both pass,
    /// `R` only read/write passes, `.` fail.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f_idx in (0..self.freqs_hz.len()).rev() {
            out.push_str(&format!("{:>7.1} MHz |", self.freqs_hz[f_idx] / 1e6));
            for v_idx in 0..self.vdds.len() {
                let (rw, cim) = self.get(v_idx, f_idx);
                out.push(if cim {
                    '#'
                } else if rw {
                    'R'
                } else {
                    '.'
                });
            }
            out.push('\n');
        }
        out.push_str("            +");
        out.push_str(&"-".repeat(self.vdds.len()));
        out.push('\n');
        out.push_str("             ");
        for (i, v) in self.vdds.iter().enumerate() {
            out.push(if i % 4 == 0 {
                char::from_digit(((v * 10.0).round() as u32) % 10, 10).unwrap_or('?')
            } else {
                ' '
            });
        }
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_fits_published_points() {
        let m = ShmooModel::calibrated();
        for (v, f) in CIM_BOUNDARY {
            let pred = m.fmax_hz(ShmooPath::Cim, v);
            let rel = (pred - f).abs() / f;
            assert!(rel < 0.25, "V={v}: Fmax {pred:.3e} vs {f:.3e}");
        }
        // All three published operating points must PASS.
        for (v, f) in CIM_BOUNDARY {
            assert!(m.passes(ShmooPath::Cim, v, f * 0.999), "V={v} f={f}");
        }
    }

    #[test]
    fn cim_window_strictly_inside_rw_window() {
        let m = ShmooModel::calibrated();
        for i in 0..20 {
            let v = 0.6 + 0.03 * i as f64;
            assert!(
                m.fmax_hz(ShmooPath::ReadWrite, v) >= m.fmax_hz(ShmooPath::Cim, v),
                "V={v}"
            );
        }
        // Somewhere the windows genuinely differ.
        assert!(
            m.fmax_hz(ShmooPath::ReadWrite, 0.9) > m.fmax_hz(ShmooPath::Cim, 0.9) * 1.2
        );
    }

    #[test]
    fn fmax_monotonic_in_voltage() {
        let m = ShmooModel::calibrated();
        let mut prev = 0.0;
        for i in 0..25 {
            let v = 0.5 + 0.03 * i as f64;
            let f = m.fmax_hz(ShmooPath::Cim, v);
            assert!(f >= prev, "V={v}");
            prev = f;
        }
    }

    #[test]
    fn below_threshold_never_passes() {
        let m = ShmooModel::calibrated();
        assert_eq!(m.fmax_hz(ShmooPath::Cim, 0.2), 0.0);
        assert!(!m.passes(ShmooPath::Cim, 0.2, 1.0e6));
    }

    #[test]
    fn grid_dimensions_and_render() {
        let m = ShmooModel::calibrated();
        let g = m.standard_grid();
        assert_eq!(g.cells.len(), g.vdds.len() * g.freqs_hz.len());
        let s = g.render();
        assert!(s.contains('#'));
        assert!(s.contains('.'));
        // low-V high-f corner fails, high-V low-f corner passes
        assert_eq!(g.get(0, g.freqs_hz.len() - 1), (false, false));
        let (rw, cim) = g.get(g.vdds.len() - 1, 0);
        assert!(rw && cim);
    }
}
