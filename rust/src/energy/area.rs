//! Die-area model (Fig 7): component breakdown calibrated to the
//! published totals — 0.089 mm² macro area, 54.2 % memory area
//! efficiency, 65 nm CMOS.

use crate::bitcell::{COLS, V_ROWS, W_ROWS};

/// Published totals.
pub const TOTAL_AREA_MM2: f64 = 0.089;
pub const MEMORY_AREA_EFFICIENCY: f64 = 0.542;

/// Component-level area breakdown (mm²).
#[derive(Clone, Copy, Debug)]
pub struct AreaBreakdown {
    pub bitcells_mm2: f64,
    pub column_periph_mm2: f64,
    pub decoders_mm2: f64,
    pub control_mm2: f64,
}

impl AreaBreakdown {
    pub fn total_mm2(&self) -> f64 {
        self.bitcells_mm2 + self.column_periph_mm2 + self.decoders_mm2 + self.control_mm2
    }

    pub fn memory_efficiency(&self) -> f64 {
        self.bitcells_mm2 / self.total_mm2()
    }
}

/// The model: per-10T-bitcell area is derived from the published
/// totals; peripheral/decoder/control areas use relative transistor
/// budgets (modelled split — the paper's Fig 7 pie is not numerically
/// annotated beyond the 54.2 % memory share).
#[derive(Clone, Debug)]
pub struct AreaModel {
    /// 10T bitcell area (µm²).
    pub bitcell_um2: f64,
    /// One reconfigurable column peripheral (SINV+BLFA+CMUX+CWD) (µm²).
    pub column_periph_um2: f64,
    /// Triple-row decoder + wordline drivers (µm²).
    pub decoder_um2: f64,
    /// Control, spike buffers, timing (µm²).
    pub control_um2: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        Self::calibrated()
    }
}

impl AreaModel {
    /// Calibrate to the published totals.
    pub fn calibrated() -> Self {
        let cells = ((W_ROWS + V_ROWS) * COLS) as f64;
        let mem_mm2 = TOTAL_AREA_MM2 * MEMORY_AREA_EFFICIENCY;
        let bitcell_um2 = mem_mm2 * 1e6 / cells;
        // Non-memory split (modelled): column peripherals dominate
        // (78 chains of SINV+BLFA+CMUX+CWD ≈ 62 %), decoders 18 %,
        // control/spike-buffers/timing 20 %.
        let rest_mm2 = TOTAL_AREA_MM2 - mem_mm2;
        Self {
            bitcell_um2,
            column_periph_um2: rest_mm2 * 0.62 * 1e6 / COLS as f64,
            decoder_um2: rest_mm2 * 0.18 * 1e6,
            control_um2: rest_mm2 * 0.20 * 1e6,
        }
    }

    /// The breakdown for the standard macro geometry.
    pub fn breakdown(&self) -> AreaBreakdown {
        let cells = ((W_ROWS + V_ROWS) * COLS) as f64;
        AreaBreakdown {
            bitcells_mm2: self.bitcell_um2 * cells / 1e6,
            column_periph_mm2: self.column_periph_um2 * COLS as f64 / 1e6,
            decoders_mm2: self.decoder_um2 / 1e6,
            control_mm2: self.control_um2 / 1e6,
        }
    }

    /// Area of a hypothetical macro with different geometry (used by
    /// the multi-macro scaling analysis).
    pub fn scaled_macro_mm2(&self, w_rows: usize, v_rows: usize, cols: usize) -> f64 {
        let cells = ((w_rows + v_rows) * cols) as f64;
        let periph = self.column_periph_um2 * cols as f64;
        // decoder grows ~log2(rows), control roughly constant
        let dec = self.decoder_um2 * ((w_rows + v_rows) as f64).log2()
            / ((W_ROWS + V_ROWS) as f64).log2();
        (self.bitcell_um2 * cells + periph + dec + self.control_um2) / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_matches_published_totals() {
        let b = AreaModel::calibrated().breakdown();
        assert!((b.total_mm2() - TOTAL_AREA_MM2).abs() < 1e-6);
        assert!((b.memory_efficiency() - MEMORY_AREA_EFFICIENCY).abs() < 1e-6);
    }

    #[test]
    fn bitcell_area_plausible_for_65nm_10t() {
        // 6T at 65nm ≈ 0.5–1.5 µm²; a 10T CIM cell with dual read ports
        // lands in the 2–6 µm² band.
        let m = AreaModel::calibrated();
        assert!(
            m.bitcell_um2 > 1.5 && m.bitcell_um2 < 8.0,
            "{} µm²",
            m.bitcell_um2
        );
    }

    #[test]
    fn scaled_macro_grows_with_geometry() {
        let m = AreaModel::calibrated();
        let base = m.scaled_macro_mm2(W_ROWS, V_ROWS, COLS);
        let double = m.scaled_macro_mm2(2 * W_ROWS, 2 * V_ROWS, COLS);
        assert!((base - TOTAL_AREA_MM2).abs() / TOTAL_AREA_MM2 < 0.05);
        assert!(double > 1.5 * base && double < 2.5 * base);
    }
}
