//! Energy-delay product vs input-spike sparsity (Fig 11b).
//!
//! The macro exploits sparsity *architecturally*: the number of input
//! spikes determines how many AccW2V instructions are issued at all. At
//! sparsity `s` a 128-input layer issues `2·(1−s)·128` AccW2V cycles
//! (odd + even) plus the fixed neuron-update sequence per timestep, so
//! both the energy and the delay scale with `(1−s)` and their product
//! falls quadratically — 97.4 % at 85 % sparsity, the paper's headline.

use super::model::EnergyModel;
use crate::isa::{InstructionKind, NeuronType};
use crate::NOMINAL_VDD;
use std::collections::BTreeMap;

/// One point of the EDP-vs-sparsity curve.
#[derive(Clone, Copy, Debug)]
pub struct EdpPoint {
    pub sparsity: f64,
    /// Energy per neuron per timestep (J).
    pub energy_j: f64,
    /// Delay per neuron per timestep (s).
    pub delay_s: f64,
    /// EDP (J·s).
    pub edp: f64,
}

/// Analytic instruction counts for one timestep of a 128-input,
/// 12-neuron (one V-row pair) layer slice at input sparsity `s`.
fn timestep_histogram(s: f64, neuron: NeuronType) -> BTreeMap<InstructionKind, u64> {
    let spikes = ((1.0 - s) * 128.0).round() as u64;
    let mut h = BTreeMap::new();
    // one AccW2V per spiking input per parity
    if spikes > 0 {
        h.insert(InstructionKind::AccW2V, 2 * spikes);
    }
    let (v2v, check, reset) = match neuron {
        NeuronType::IF => (0, 2, 2),
        NeuronType::LIF => (2, 2, 2),
        NeuronType::RMP => (2, 2, 0),
    };
    if v2v > 0 {
        h.insert(InstructionKind::AccV2V, v2v);
    }
    h.insert(InstructionKind::SpikeCheck, check);
    if reset > 0 {
        h.insert(InstructionKind::ResetV, reset);
    }
    h
}

/// EDP per neuron per timestep at input sparsity `s` (12 neurons share
/// the odd+even V-row pair).
pub fn edp_per_neuron_timestep(
    model: &EnergyModel,
    s: f64,
    neuron: NeuronType,
    vdd: f64,
    freq_hz: f64,
) -> EdpPoint {
    assert!((0.0..=1.0).contains(&s), "sparsity out of range");
    let h = timestep_histogram(s, neuron);
    let cycles: u64 = h.values().sum();
    let neurons = 12.0;
    let energy_j = model.program_energy_j(&h, vdd) / neurons;
    let delay_s = model.delay_s(cycles, freq_hz) / neurons;
    EdpPoint {
        sparsity: s,
        energy_j,
        delay_s,
        edp: energy_j * delay_s,
    }
}

/// A full sparsity sweep (the Fig 11b series).
#[derive(Clone, Debug)]
pub struct SparsitySweep {
    pub points: Vec<EdpPoint>,
}

impl SparsitySweep {
    /// Sweep sparsity 0..=1 in `n` steps.
    pub fn run(model: &EnergyModel, neuron: NeuronType, n: usize) -> Self {
        let points = (0..=n)
            .map(|i| {
                edp_per_neuron_timestep(
                    model,
                    i as f64 / n as f64,
                    neuron,
                    NOMINAL_VDD,
                    crate::NOMINAL_FREQ_HZ,
                )
            })
            .collect();
        Self { points }
    }

    /// EDP reduction (fraction) at sparsity `s` relative to s = 0.
    pub fn reduction_at(&self, s: f64) -> f64 {
        let base = self.points[0].edp;
        let p = self
            .points
            .iter()
            .min_by(|a, b| {
                (a.sparsity - s)
                    .abs()
                    .partial_cmp(&(b.sparsity - s).abs())
                    .unwrap()
            })
            .unwrap();
        1.0 - p.edp / base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_97_4_percent_reduction_at_85_sparsity() {
        let m = EnergyModel::calibrated();
        let sweep = SparsitySweep::run(&m, NeuronType::RMP, 100);
        let red = sweep.reduction_at(0.85);
        assert!(
            (red - 0.974).abs() < 0.005,
            "EDP reduction at 85% sparsity: {red:.4} (paper: 0.974)"
        );
    }

    #[test]
    fn edp_monotonically_decreases_with_sparsity() {
        let m = EnergyModel::calibrated();
        let sweep = SparsitySweep::run(&m, NeuronType::RMP, 50);
        for w in sweep.points.windows(2) {
            assert!(w[1].edp <= w[0].edp);
        }
    }

    #[test]
    fn full_sparsity_costs_only_neuron_updates() {
        let h = timestep_histogram(1.0, NeuronType::RMP);
        assert!(!h.contains_key(&InstructionKind::AccW2V));
        assert_eq!(h[&InstructionKind::SpikeCheck], 2);
        assert_eq!(h[&InstructionKind::AccV2V], 2);
    }

    #[test]
    fn zero_sparsity_issues_all_256_accw2v() {
        let h = timestep_histogram(0.0, NeuronType::IF);
        assert_eq!(h[&InstructionKind::AccW2V], 256);
    }

    #[test]
    fn lif_costs_more_than_rmp_at_same_sparsity() {
        let m = EnergyModel::calibrated();
        let lif = edp_per_neuron_timestep(&m, 0.85, NeuronType::LIF, NOMINAL_VDD, 200e6);
        let rmp = edp_per_neuron_timestep(&m, 0.85, NeuronType::RMP, NOMINAL_VDD, 200e6);
        assert!(lif.edp > rmp.edp);
    }

    #[test]
    fn quadratic_shape() {
        // EDP(0.5)/EDP(0) ≈ ((0.5·256+4)/(256+4))² ≈ 0.258
        let m = EnergyModel::calibrated();
        let sweep = SparsitySweep::run(&m, NeuronType::RMP, 100);
        let r = sweep.points[50].edp / sweep.points[0].edp;
        assert!((r - 0.26).abs() < 0.02, "ratio {r}");
    }
}
