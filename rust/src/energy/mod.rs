//! Silicon-calibrated energy, power, timing, and area models.
//!
//! The simulator replaces the paper's silicon measurements with
//! analytical models whose free parameters are calibrated to the
//! published numbers (DESIGN.md §1, §6):
//!
//! - per-instruction energies at point D (0.85 V / 200 MHz) derived from
//!   the published per-instruction TOPS/W;
//! - a two-component power model `P(V,f) = E(V)·f + P_leak(V)` fitted to
//!   the three published operating points (0.7/0.85/1.2 V columns of
//!   Table I);
//! - alpha-power-law Fmax curves for the Shmoo (Fig 8);
//! - a component area model reproducing Fig 7's breakdown.

mod area;
mod edp;
mod model;
mod shmoo;

pub use area::{AreaBreakdown, AreaModel};
pub use edp::{edp_per_neuron_timestep, EdpPoint, SparsitySweep};
pub use model::{EnergyModel, InstrEnergy, OperatingPoint, OPERATING_POINTS};
pub use shmoo::{ShmooGrid, ShmooModel, ShmooPath};

/// Published CIM Shmoo boundary points `(V, Fmax Hz)` (Table I columns).
pub fn shmoo_boundary() -> [(f64, f64); 3] {
    shmoo::CIM_BOUNDARY
}
