//! Offline micro-benchmark harness (criterion is unavailable in the
//! offline environment): warmup + timed iterations with robust stats,
//! plus table formatting for the per-figure benches.

use std::time::{Duration, Instant};

/// One benchmark's result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iterations: u64,
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    pub min: Duration,
    pub throughput_per_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12.3?} median  ±{:>10.3?} MAD  ({:.2e}/s, n={})",
            self.name, self.median, self.mad, self.throughput_per_s, self.iterations
        )
    }
}

/// Benchmark runner with fixed-budget adaptive iteration counts.
pub struct Bencher {
    /// Target wall-clock per benchmark.
    pub budget: Duration,
    /// Minimum timed iterations.
    pub min_iters: u64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(Duration::from_millis(700))
    }
}

impl Bencher {
    pub fn new(budget: Duration) -> Self {
        Self {
            budget,
            min_iters: 10,
            results: Vec::new(),
        }
    }

    /// Time `f` repeatedly; `work` is the number of logical operations
    /// per call (for throughput).
    pub fn bench<F: FnMut()>(&mut self, name: &str, work: u64, mut f: F) -> &BenchResult {
        // warmup + calibration
        let t0 = Instant::now();
        f();
        let one = t0.elapsed().max(Duration::from_nanos(50));
        let iters = ((self.budget.as_secs_f64() / one.as_secs_f64()) as u64)
            .clamp(self.min_iters, 1_000_000);
        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        samples.sort();
        let median = samples[samples.len() / 2];
        let mut devs: Vec<Duration> = samples
            .iter()
            .map(|&s| if s > median { s - median } else { median - s })
            .collect();
        devs.sort();
        let mad = devs[devs.len() / 2];
        let result = BenchResult {
            name: name.to_string(),
            iterations: iters,
            median,
            mad,
            min: samples[0],
            throughput_per_s: work as f64 / median.as_secs_f64(),
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Minimal fixed-width table printer for bench outputs that mirror the
/// paper's tables/figures.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join(" | ")
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher::new(Duration::from_millis(20));
        let mut x = 0u64;
        let r = b
            .bench("spin", 1000, || {
                for i in 0..1000u64 {
                    x = x.wrapping_add(i * i);
                }
            })
            .clone();
        assert!(r.iterations >= 10);
        assert!(r.median >= r.min);
        assert!(r.throughput_per_s > 0.0);
        assert!(x != 0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1.00".into()]);
        t.row(&["longer-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("longer-name"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
