//! Spike buffers — one latch per value field.
//!
//! SpikeCheck writes them from the comparator outputs; the following
//! instruction (ResetV or soft-reset AccV2V) consumes them as the CWD
//! gate; the coordinator drains them as the layer's output spikes.

use crate::bitcell::VALUES_PER_ROW;

/// The per-parity spike buffer bank (6 buffers, one per field).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpikeBuffers {
    bits: [bool; VALUES_PER_ROW],
}

impl SpikeBuffers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Latch comparator outputs (overwrites all six).
    pub fn latch(&mut self, outs: [bool; VALUES_PER_ROW]) {
        self.bits = outs;
    }

    /// Current buffer contents.
    #[inline]
    pub fn bits(&self) -> &[bool; VALUES_PER_ROW] {
        &self.bits
    }

    /// Read one buffer.
    #[inline]
    pub fn get(&self, g: usize) -> bool {
        self.bits[g]
    }

    /// Clear all buffers.
    pub fn clear(&mut self) {
        self.bits = [false; VALUES_PER_ROW];
    }

    /// Number of set buffers.
    pub fn count(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latch_and_read() {
        let mut sb = SpikeBuffers::new();
        assert_eq!(sb.count(), 0);
        sb.latch([true, false, false, true, true, false]);
        assert_eq!(sb.count(), 3);
        assert!(sb.get(0));
        assert!(!sb.get(1));
        sb.clear();
        assert_eq!(sb.count(), 0);
    }

    #[test]
    fn latch_overwrites() {
        let mut sb = SpikeBuffers::new();
        sb.latch([true; 6]);
        sb.latch([false, true, false, false, false, false]);
        assert_eq!(sb.bits(), &[false, true, false, false, false, false]);
    }
}
