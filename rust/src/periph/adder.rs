//! The reconfigurable ripple-carry adder formed by chaining column
//! peripherals, bit-accurately.
//!
//! Six independent 12-column adders are active per cycle; their spans
//! depend on the cycle parity (see [`super::column_modes`]). Within a
//! field the carry ripples LSB → MSB, *skipping* the hole column, whose
//! peripheral instead latches the sensed weight sign and broadcasts it
//! to the six upper columns (in-array sign extension of the 6-bit
//! weight to the 11-bit membrane potential).

use super::blfa::{blfa, blfa_bcast};
use super::{column_modes, ColumnMode};
use crate::bitcell::{DualRead, Parity, COLS, VALUES_PER_ROW, VALUE_HOLE_OFFSET};

/// Result of one field's (one value's) add.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FieldResult {
    /// Carry-out of the MSB column peripheral — the comparator output
    /// the paper's SpikeCheck uses.
    pub msb_cout: bool,
    /// The MSB *sum* bit — the sign of the 11-bit result.
    pub sign: bool,
    /// The latched broadcast (weight sign) — diagnostic.
    pub wsign: bool,
}

/// Output of a full-array add cycle.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AdderOutput {
    /// Packed 78-column SUM word (hole columns forced to 0 — the CS
    /// peripheral writes back `0`, preserving the V_MEM hole invariant).
    pub sum: u128,
    /// Per-field comparator/sign outputs.
    pub fields: [FieldResult; VALUES_PER_ROW],
}

/// The chained column-peripheral adder for one parity.
#[derive(Clone, Debug)]
pub struct ColumnAdder {
    parity: Parity,
    modes: [ColumnMode; COLS],
    /// When true, upper-half columns add the broadcast weight sign
    /// (AccW2V). When false (AccV2V / SpikeCheck), both operands come
    /// from cells on every column and the broadcast input is gated off.
    bcast_enable: bool,
}

impl ColumnAdder {
    /// Adder configured for AccW2V (weight-sign broadcast active).
    pub fn for_acc_w2v(parity: Parity) -> Self {
        Self {
            parity,
            modes: column_modes(parity),
            bcast_enable: true,
        }
    }

    /// Adder configured for V+V operations (AccV2V, SpikeCheck): all
    /// eleven value columns carry two cells; the hole column carries
    /// two zeros and is still skipped.
    pub fn for_v_plus_v(parity: Parity) -> Self {
        Self {
            parity,
            modes: column_modes(parity),
            bcast_enable: false,
        }
    }

    /// The parity this adder is configured for.
    pub fn parity(&self) -> Parity {
        self.parity
    }

    /// Propagate the sensed bitlines through the six chained adders.
    ///
    /// This walks column-by-column exactly like the silicon ripple
    /// chain: per column one BLFA evaluation, with the CMUX selecting
    /// carry-in 0 (LSB), the previous COUT (CF), or the skipped carry
    /// (CS → first upper column).
    pub fn propagate(&self, sensed: &DualRead) -> AdderOutput {
        let mut sum = 0u128;
        let mut fields = [FieldResult {
            msb_cout: false,
            sign: false,
            wsign: false,
        }; VALUES_PER_ROW];

        let mut carry = false;
        let mut bcast = false;
        let mut field_idx = 0usize;

        for c in 0..COLS {
            let or = (sensed.or >> c) & 1 == 1;
            let and = (sensed.and >> c) & 1 == 1;
            match self.modes[c] {
                ColumnMode::Inactive => {}
                ColumnMode::Lsb => {
                    let out = blfa(or, and, false);
                    if out.sum {
                        sum |= 1u128 << c;
                    }
                    carry = out.cout;
                }
                ColumnMode::CarryForward => {
                    let out = blfa(or, and, carry);
                    if out.sum {
                        sum |= 1u128 << c;
                    }
                    carry = out.cout;
                }
                ColumnMode::CarrySkip => {
                    // The hole column: the only possible driven-high cell
                    // is the weight sign (V_MEM keeps this bit 0), so the
                    // sensed OR *is* Wsign. Latch it for broadcast, let
                    // the carry skip past, write back 0.
                    debug_assert!(
                        c >= VALUE_HOLE_OFFSET,
                        "hole column index underflow"
                    );
                    bcast = self.bcast_enable && or;
                    fields[field_idx].wsign = or;
                    // carry unchanged (skip); sum bit forced 0.
                }
                ColumnMode::CarryForwardBcast => {
                    // Upper half: single cell (the V bit) + broadcast.
                    // With one driven cell, or == and == v.
                    let v = or;
                    let out = if self.bcast_enable {
                        blfa_bcast(v, bcast, carry)
                    } else {
                        blfa(or, and, carry)
                    };
                    if out.sum {
                        sum |= 1u128 << c;
                    }
                    carry = out.cout;
                }
                ColumnMode::MsbBcast => {
                    let v = or;
                    let out = if self.bcast_enable {
                        blfa_bcast(v, bcast, carry)
                    } else {
                        blfa(or, and, carry)
                    };
                    if out.sum {
                        sum |= 1u128 << c;
                    }
                    fields[field_idx].msb_cout = out.cout;
                    fields[field_idx].sign = out.sum;
                    field_idx += 1;
                    carry = false;
                }
            }
        }
        debug_assert_eq!(field_idx, VALUES_PER_ROW);
        AdderOutput { sum, fields }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitcell::{encode_weight_row, BitArray, FieldLayout, WEIGHTS_PER_ROW};
    use crate::bits::{wrap11, XorShiftRng};

    /// Build sensed bitlines for an AccW2V cycle directly from arrays.
    fn sense_w2v(
        w: &BitArray,
        v: &BitArray,
        w_row: usize,
        v_row: usize,
        parity: Parity,
    ) -> DualRead {
        let l = FieldLayout::new(parity);
        DualRead::combine(
            w.read_masked(w_row, l.w_drive_mask()),
            v.read_masked(v_row, crate::bitcell::COL_MASK),
        )
    }

    #[test]
    fn acc_w2v_is_v_plus_sext_w_mod_2pow11() {
        let mut rng = XorShiftRng::new(42);
        for parity in Parity::BOTH {
            let l = FieldLayout::new(parity);
            for _ in 0..300 {
                let ws: Vec<i64> =
                    (0..WEIGHTS_PER_ROW).map(|_| rng.gen_i64(-32, 31)).collect();
                let vs: Vec<i64> = (0..VALUES_PER_ROW).map(|_| rng.gen_i64(-1024, 1023)).collect();
                let mut wmem = BitArray::new(1);
                wmem.set_row(0, encode_weight_row(&ws));
                let mut vmem = BitArray::new(1);
                vmem.set_row(0, l.encode_row(&vs));

                let sensed = sense_w2v(&wmem, &vmem, 0, 0, parity);
                let out = ColumnAdder::for_acc_w2v(parity).propagate(&sensed);

                for g in 0..VALUES_PER_ROW {
                    let j = crate::bitcell::weight_index(g, parity);
                    let expect = wrap11(vs[g] + ws[j]);
                    let got = l.decode_value(out.sum, g);
                    assert_eq!(got, expect, "parity={parity:?} g={g} v={} w={}", vs[g], ws[j]);
                    // sign bit of result reported per field
                    assert_eq!(out.fields[g].sign, expect < 0);
                    assert_eq!(out.fields[g].wsign, ws[j] < 0);
                }
                // hole columns stay zero in the written-back sum
                assert_eq!(out.sum & l.hole_mask(), 0);
            }
        }
    }

    #[test]
    fn v_plus_v_adds_two_vmem_rows() {
        let mut rng = XorShiftRng::new(7);
        for parity in Parity::BOTH {
            let l = FieldLayout::new(parity);
            for _ in 0..300 {
                let a: Vec<i64> = (0..VALUES_PER_ROW).map(|_| rng.gen_i64(-1024, 1023)).collect();
                let b: Vec<i64> = (0..VALUES_PER_ROW).map(|_| rng.gen_i64(-1024, 1023)).collect();
                let mut vmem = BitArray::new(2);
                vmem.set_row(0, l.encode_row(&a));
                vmem.set_row(1, l.encode_row(&b));
                let sensed = DualRead::combine(
                    vmem.read_masked(0, crate::bitcell::COL_MASK),
                    vmem.read_masked(1, crate::bitcell::COL_MASK),
                );
                let out = ColumnAdder::for_v_plus_v(parity).propagate(&sensed);
                for g in 0..VALUES_PER_ROW {
                    let expect = wrap11(a[g] + b[g]);
                    assert_eq!(l.decode_value(out.sum, g), expect);
                    assert_eq!(out.fields[g].sign, expect < 0);
                }
            }
        }
    }

    #[test]
    fn msb_cout_is_unsigned_carry() {
        // COUT of the MSB column = carry out of the 11-bit unsigned add
        // (the paper's literal comparator signal).
        let parity = Parity::Odd;
        let l = FieldLayout::new(parity);
        let cases = [
            (100i64, -50i64, true),   // 100 + (2048-50): wraps => carry
            (10, -50, false),         // 10 + 1998 = 2008 < 2048
            (-1, -1, true),           // 2047+2047 -> carry
            (0, 5, false),
        ];
        for (va, vb, want_carry) in cases {
            let mut vmem = BitArray::new(2);
            vmem.set_row(0, l.encode_row(&[va; 6]));
            vmem.set_row(1, l.encode_row(&[vb; 6]));
            let sensed = DualRead::combine(
                vmem.read_masked(0, crate::bitcell::COL_MASK),
                vmem.read_masked(1, crate::bitcell::COL_MASK),
            );
            let out = ColumnAdder::for_v_plus_v(parity).propagate(&sensed);
            for g in 0..VALUES_PER_ROW {
                assert_eq!(
                    out.fields[g].msb_cout, want_carry,
                    "va={va} vb={vb} g={g}"
                );
            }
        }
    }

    #[test]
    fn carries_do_not_leak_between_fields() {
        let parity = Parity::Odd;
        let l = FieldLayout::new(parity);
        // Field 0 overflows (max + max); field 1 must still be exact.
        let mut vmem = BitArray::new(2);
        vmem.set_row(0, l.encode_row(&[1023, 5, 0, 0, 0, 0]));
        vmem.set_row(1, l.encode_row(&[1023, 7, 0, 0, 0, 0]));
        let sensed = DualRead::combine(
            vmem.read_masked(0, crate::bitcell::COL_MASK),
            vmem.read_masked(1, crate::bitcell::COL_MASK),
        );
        let out = ColumnAdder::for_v_plus_v(parity).propagate(&sensed);
        assert_eq!(l.decode_value(out.sum, 0), wrap11(2046));
        assert_eq!(l.decode_value(out.sum, 1), 12);
    }
}
