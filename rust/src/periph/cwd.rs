//! The conditional write driver (CWD).
//!
//! Each column's CWD either actively drives WBL/WBLB with the selected
//! write-back bit, or leaves both precharged so the enabled WWL cell
//! keeps its value. The per-column gate comes from the spike buffers
//! (one buffer gating all 12 columns of its field) or is forced open
//! for unconditional writes.

use crate::bitcell::{FieldLayout, Parity, VALUES_PER_ROW};

/// What gates the write drivers this cycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteGate {
    /// Drive every active field (unconditional write-back: AccW2V,
    /// LIF-leak AccV2V).
    AllFields,
    /// Drive only fields whose spike buffer is set (ResetV, RMP
    /// soft-reset AccV2V).
    SpikedFields,
    /// Drive only fields whose spike buffer is *clear* (used by the
    /// inverse-gated variants; not exercised by the paper's sequences
    /// but the CWD supports it symmetrically).
    NonSpikedFields,
}

/// The bank of conditional write drivers for one cycle parity.
#[derive(Clone, Copy, Debug)]
pub struct ConditionalWriteDriver {
    layout: FieldLayout,
}

impl ConditionalWriteDriver {
    pub fn new(parity: Parity) -> Self {
        Self {
            layout: FieldLayout::new(parity),
        }
    }

    /// Compute the column mask actually driven, given the gate mode and
    /// the spike buffers. Columns outside active fields are never
    /// driven (their values in other-parity fields must survive).
    pub fn drive_mask(&self, gate: WriteGate, spikes: &[bool; VALUES_PER_ROW]) -> u128 {
        let mut mask = 0u128;
        for g in 0..VALUES_PER_ROW {
            let write = match gate {
                WriteGate::AllFields => true,
                WriteGate::SpikedFields => spikes[g],
                WriteGate::NonSpikedFields => !spikes[g],
            };
            if write {
                mask |= self.layout.field_mask(g);
            }
        }
        mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_fields_drives_every_active_column() {
        for p in Parity::BOTH {
            let cwd = ConditionalWriteDriver::new(p);
            let mask = cwd.drive_mask(WriteGate::AllFields, &[false; 6]);
            assert_eq!(mask, FieldLayout::new(p).all_fields_mask());
        }
    }

    #[test]
    fn spiked_fields_drives_only_set_buffers() {
        let cwd = ConditionalWriteDriver::new(Parity::Odd);
        let spikes = [true, false, true, false, false, true];
        let mask = cwd.drive_mask(WriteGate::SpikedFields, &spikes);
        let l = FieldLayout::new(Parity::Odd);
        for g in 0..VALUES_PER_ROW {
            let fm = l.field_mask(g);
            if spikes[g] {
                assert_eq!(mask & fm, fm);
            } else {
                assert_eq!(mask & fm, 0);
            }
        }
    }

    #[test]
    fn non_spiked_is_complement_within_fields() {
        let cwd = ConditionalWriteDriver::new(Parity::Even);
        let spikes = [true, true, false, true, false, false];
        let a = cwd.drive_mask(WriteGate::SpikedFields, &spikes);
        let b = cwd.drive_mask(WriteGate::NonSpikedFields, &spikes);
        let l = FieldLayout::new(Parity::Even);
        assert_eq!(a & b, 0);
        assert_eq!(a | b, l.all_fields_mask());
    }

    #[test]
    fn even_parity_never_drives_low_six_columns() {
        let cwd = ConditionalWriteDriver::new(Parity::Even);
        let mask = cwd.drive_mask(WriteGate::AllFields, &[true; 6]);
        assert_eq!(mask & 0b111111, 0);
    }
}
