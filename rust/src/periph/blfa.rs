//! The bitwise-logic full adder (BLFA).
//!
//! Unlike a conventional full adder fed by two operand wires, the BLFA
//! receives the *combined* bitline signals — `OR` and `AND` of the two
//! cells enabled on its column — plus a ripple carry. That is enough:
//! `XOR = OR ∧ ¬AND` and `{generate, propagate} = {AND, OR}`.

/// One column-peripheral add step's outputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlfaOut {
    pub sum: bool,
    pub cout: bool,
}

/// Combinational BLFA: given the sensed `or`/`and` of the column's
/// enabled cells and the carry-in, produce SUM and COUT.
#[inline]
pub fn blfa(or: bool, and: bool, cin: bool) -> BlfaOut {
    debug_assert!(or || !and, "sensed AND=1 with OR=0 is unphysical on a driven column");
    let xor = or && !and;
    BlfaOut {
        sum: xor ^ cin,
        cout: and || (xor && cin),
    }
}

/// BLFA with an extra broadcast operand substituted for the (absent)
/// second cell. Used by the upper-half columns during AccW2V: the only
/// cell on the column is the V_MEM bit, so `or == and == v`, and the
/// carry-skip broadcast supplies the weight-sign as operand `b`.
#[inline]
pub fn blfa_bcast(v: bool, bcast: bool, cin: bool) -> BlfaOut {
    let xor = v ^ bcast;
    BlfaOut {
        sum: xor ^ cin,
        cout: (v && bcast) || (xor && cin),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exhaustive truth-table check against a+b+cin.
    #[test]
    fn blfa_matches_full_adder() {
        for a in [false, true] {
            for b in [false, true] {
                for cin in [false, true] {
                    let or = a || b;
                    let and = a && b;
                    let expect = a as u8 + b as u8 + cin as u8;
                    let out = blfa(or, and, cin);
                    assert_eq!(out.sum as u8, expect & 1, "a={a} b={b} cin={cin}");
                    assert_eq!(out.cout as u8, expect >> 1, "a={a} b={b} cin={cin}");
                }
            }
        }
    }

    #[test]
    fn blfa_bcast_matches_full_adder() {
        for v in [false, true] {
            for w in [false, true] {
                for cin in [false, true] {
                    let expect = v as u8 + w as u8 + cin as u8;
                    let out = blfa_bcast(v, w, cin);
                    assert_eq!(out.sum as u8, expect & 1);
                    assert_eq!(out.cout as u8, expect >> 1);
                }
            }
        }
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn unphysical_sense_asserts() {
        blfa(false, true, false);
    }
}
