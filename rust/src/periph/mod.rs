//! Reconfigurable column peripherals.
//!
//! Every bitline pair (RBL/RBLB + WBL/WBLB) terminates in one column
//! peripheral consisting of:
//!
//! - **SINV** — sensing inverters that latch the bitline levels; after
//!   sensing, the peripheral holds `OR` and `AND` of the cells enabled
//!   on its column.
//! - **BLFA** — a bitwise-logic full adder that derives `SUM`/`COUT`
//!   from the latched `OR`/`AND` plus a ripple carry-in:
//!   `XOR = OR ∧ ¬AND`, `SUM = XOR ⊕ Cin`, `COUT = AND ∨ (XOR ∧ Cin)`.
//! - **CMUX** — carry multiplexers that chain BLFAs into ripple-carry
//!   adders whose *span is reconfigured every cycle*: odd cycles chain
//!   columns 0–11, 12–23, …; even cycles 6–17, 18–29, … (the staggered
//!   mapping). Modes: LSB (carry-in 0), CF (carry forward), CS (carry
//!   *skip*: the hole column forwards its carry untouched and
//!   broadcasts the sensed weight-sign to the six upper columns — the
//!   in-array sign extension), MSB (terminates the chain, exporting
//!   `COUT` and the sum sign to the spike logic).
//! - **CWD** — conditional write drivers: drive WBL/WBLB with the
//!   selected write-back value, or leave them precharged so the write
//!   is suppressed (spike-gated writes in ResetV / soft-reset AccV2V).
//! - **Spike buffers** — one per value field, set by SpikeCheck,
//!   consumed as the CWD gate by the following instruction.

mod adder;
mod blfa;
mod cwd;
mod spikebuf;

pub use adder::{AdderOutput, ColumnAdder, FieldResult};
pub use blfa::{blfa, blfa_bcast, BlfaOut};
pub use cwd::{ConditionalWriteDriver, WriteGate};
pub use spikebuf::SpikeBuffers;

use crate::bitcell::{field_base, Parity, FIELD_WIDTH, VALUES_PER_ROW, VALUE_HOLE_OFFSET};

/// Per-column peripheral configuration for one cycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ColumnMode {
    /// Not part of any active adder this cycle (bitlines ignored).
    Inactive,
    /// Starts an adder chain: carry-in forced to 0.
    Lsb,
    /// Carry forward from the previous column.
    CarryForward,
    /// The hole column: skips the ripple carry past itself and latches
    /// the sensed weight sign for broadcast to the upper columns.
    CarrySkip,
    /// Upper-half column receiving the broadcast weight sign as its
    /// second operand (AccW2V sign extension).
    CarryForwardBcast,
    /// Terminates the chain; exports COUT/sign to the spike logic. Also
    /// receives the broadcast (it is the top of the upper half).
    MsbBcast,
}

/// The full 78-column mode vector for a given parity.
///
/// Layout per 12-column field `[b..b+12)`:
/// `Lsb, CF, CF, CF, CF, CS, CFB, CFB, CFB, CFB, CFB, MSB`.
pub fn column_modes(parity: Parity) -> [ColumnMode; crate::bitcell::COLS] {
    let mut modes = [ColumnMode::Inactive; crate::bitcell::COLS];
    for g in 0..VALUES_PER_ROW {
        let b = field_base(g, parity);
        modes[b] = ColumnMode::Lsb;
        for off in 1..VALUE_HOLE_OFFSET {
            modes[b + off] = ColumnMode::CarryForward;
        }
        modes[b + VALUE_HOLE_OFFSET] = ColumnMode::CarrySkip;
        for off in (VALUE_HOLE_OFFSET + 1)..(FIELD_WIDTH - 1) {
            modes[b + off] = ColumnMode::CarryForwardBcast;
        }
        modes[b + FIELD_WIDTH - 1] = ColumnMode::MsbBcast;
    }
    modes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitcell::COLS;

    #[test]
    fn odd_modes_cover_low_72_columns() {
        let m = column_modes(Parity::Odd);
        assert_eq!(m[0], ColumnMode::Lsb);
        assert_eq!(m[5], ColumnMode::CarrySkip);
        assert_eq!(m[11], ColumnMode::MsbBcast);
        assert_eq!(m[12], ColumnMode::Lsb);
        for c in 72..COLS {
            assert_eq!(m[c], ColumnMode::Inactive);
        }
    }

    #[test]
    fn even_modes_staggered_by_six() {
        let m = column_modes(Parity::Even);
        for c in 0..6 {
            assert_eq!(m[c], ColumnMode::Inactive, "col {c}");
        }
        assert_eq!(m[6], ColumnMode::Lsb);
        assert_eq!(m[11], ColumnMode::CarrySkip);
        assert_eq!(m[17], ColumnMode::MsbBcast);
        assert_eq!(m[77], ColumnMode::MsbBcast);
    }

    #[test]
    fn six_adders_per_parity() {
        for p in Parity::BOTH {
            let m = column_modes(p);
            assert_eq!(m.iter().filter(|&&x| x == ColumnMode::Lsb).count(), 6);
            assert_eq!(m.iter().filter(|&&x| x == ColumnMode::MsbBcast).count(), 6);
            assert_eq!(m.iter().filter(|&&x| x == ColumnMode::CarrySkip).count(), 6);
        }
    }
}
