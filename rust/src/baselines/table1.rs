//! Table I: comparison with other SNN and digital-CIM macros.
//!
//! Competitor rows are the published numbers the paper itself cites;
//! the "This Work" rows are *computed* from our calibrated models at
//! the three published operating points, so the harness checks that the
//! simulation reproduces the paper's own columns.

use crate::energy::{AreaModel, EnergyModel};
use crate::isa::InstructionKind;

/// One macro's comparison row.
#[derive(Clone, Debug)]
pub struct MacroRow {
    pub name: &'static str,
    pub technology_nm: u32,
    pub application: &'static str,
    pub macro_type: &'static str,
    pub precision: &'static str,
    pub bitcell: &'static str,
    pub read_disturb: Option<bool>,
    pub flexible_neuron: bool,
    pub sparsity_support: bool,
    pub area_mm2: Option<f64>,
    pub supply_v: f64,
    pub freq_mhz: f64,
    pub power_mw: Option<f64>,
    pub gops_per_mm2: Option<f64>,
    pub tops_per_w: Option<f64>,
}

/// The three published "This Work" operating points (labels from
/// Fig 9a; Table I columns).
pub const THIS_WORK_POINTS: [(&str, f64, f64); 3] = [
    ("A", 0.70, 66.67),
    ("D", 0.85, 200.0),
    ("G", 1.20, 500.0),
];

/// Published competitor rows (paper Table I; "-" entries are None).
pub fn competitor_rows() -> Vec<MacroRow> {
    vec![
        MacroRow {
            name: "VLSI'15 [12]",
            technology_nm: 28,
            application: "CAM/Logic",
            macro_type: "CIM",
            precision: "-",
            bitcell: "6T",
            read_disturb: Some(true),
            flexible_neuron: false,
            sparsity_support: false,
            area_mm2: Some(0.0012),
            supply_v: 1.0,
            freq_mhz: 370.0,
            power_mw: None,
            gops_per_mm2: None,
            tops_per_w: None,
        },
        MacroRow {
            name: "CICC'17 [9]",
            technology_nm: 65,
            application: "SNN",
            macro_type: "Time based",
            precision: "3b/8b",
            bitcell: "-",
            read_disturb: None,
            flexible_neuron: false,
            sparsity_support: false,
            area_mm2: Some(0.24),
            supply_v: 1.2,
            freq_mhz: 99.0,
            power_mw: Some(20.48),
            gops_per_mm2: Some(1.65),
            tops_per_w: Some(0.019),
        },
        MacroRow {
            name: "CICC'19 [10]",
            technology_nm: 28,
            application: "SNN",
            macro_type: "Digital",
            precision: "4b/-",
            bitcell: "6T",
            read_disturb: Some(false),
            flexible_neuron: false,
            sparsity_support: false,
            area_mm2: Some(0.266),
            supply_v: 1.1,
            freq_mhz: 255.0,
            power_mw: Some(1.023),
            gops_per_mm2: None,
            tops_per_w: None,
        },
        MacroRow {
            name: "ISSCC'19 [13]",
            technology_nm: 28,
            application: "CNN/FC",
            macro_type: "CIM",
            precision: "8b/-",
            bitcell: "8T",
            read_disturb: Some(false),
            flexible_neuron: false,
            sparsity_support: false,
            area_mm2: Some(2.7),
            supply_v: 0.6,
            freq_mhz: 114.0,
            power_mw: Some(105.0),
            gops_per_mm2: Some(27.3),
            tops_per_w: Some(0.97), // scaled to 65nm, 8b
        },
        MacroRow {
            name: "VLSI'20 [14]",
            technology_nm: 65,
            application: "CNN",
            macro_type: "CIM",
            precision: "16b/16b",
            bitcell: "8T",
            read_disturb: Some(false),
            flexible_neuron: false,
            sparsity_support: true,
            area_mm2: Some(0.377),
            supply_v: 1.0,
            freq_mhz: 200.0,
            power_mw: Some(5.294),
            gops_per_mm2: Some(8.4),
            tops_per_w: Some(0.31), // 16b
        },
        MacroRow {
            name: "ASSCC'20 [11]",
            technology_nm: 65,
            application: "SNN",
            macro_type: "Async",
            precision: "1b/6b",
            bitcell: "-",
            read_disturb: None,
            flexible_neuron: false,
            sparsity_support: true,
            area_mm2: Some(1.99),
            supply_v: 0.5,
            freq_mhz: 0.07,
            power_mw: Some(0.0003),
            gops_per_mm2: None,
            tops_per_w: Some(0.67), // 6b
        },
    ]
}

/// The full table: competitors + our computed "This Work" rows.
pub fn table1_rows(energy: &EnergyModel, area: &AreaModel) -> Vec<MacroRow> {
    let mut rows = competitor_rows();
    let area_mm2 = area.breakdown().total_mm2();
    for (label, vdd, freq_mhz) in THIS_WORK_POINTS {
        let f = freq_mhz * 1e6;
        let power_w = energy.avg_power_w(vdd, f);
        rows.push(MacroRow {
            name: match label {
                "A" => "This Work (0.7V)",
                "D" => "This Work (0.85V)",
                _ => "This Work (1.2V)",
            },
            technology_nm: 65,
            application: "SNN",
            macro_type: "CIM",
            precision: "6b/11b (signed)",
            bitcell: "10T",
            read_disturb: Some(false),
            flexible_neuron: true,
            sparsity_support: true,
            area_mm2: Some(area_mm2),
            supply_v: vdd,
            freq_mhz,
            power_mw: Some(power_w * 1e3),
            gops_per_mm2: Some(energy.gops_per_mm2(f, area_mm2)),
            tops_per_w: Some(energy.tops_per_w(InstructionKind::AccW2V, vdd, f)),
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn this_work_rows_match_published_columns() {
        let rows = table1_rows(&EnergyModel::calibrated(), &AreaModel::calibrated());
        let published = [
            ("This Work (0.7V)", 0.072, 0.75, 0.91),
            ("This Work (0.85V)", 0.201, 2.24, 0.99),
            ("This Work (1.2V)", 0.88, 5.61, 0.57),
        ];
        for (name, p_mw, gops, tops) in published {
            let r = rows.iter().find(|r| r.name == name).unwrap();
            let power = r.power_mw.unwrap();
            let g = r.gops_per_mm2.unwrap();
            let t = r.tops_per_w.unwrap();
            assert!(
                (power - p_mw).abs() / p_mw < 0.15,
                "{name} power {power:.3} vs {p_mw}"
            );
            assert!((g - gops).abs() / gops < 0.02, "{name} GOPS/mm2 {g:.2} vs {gops}");
            assert!((t - tops).abs() / tops < 0.15, "{name} TOPS/W {t:.3} vs {tops}");
        }
    }

    #[test]
    fn only_this_work_has_flexible_neuron() {
        // The paper's qualitative claim: first digital CIM SNN macro
        // with multiple neuron functionalities.
        let rows = table1_rows(&EnergyModel::calibrated(), &AreaModel::calibrated());
        for r in &rows {
            assert_eq!(r.flexible_neuron, r.name.starts_with("This Work"), "{}", r.name);
        }
    }

    #[test]
    fn efficiency_ratios_vs_competitors() {
        // §III: [13] has 1.5× and [14] 2.2× lower efficiency (scaled);
        // we check the same ordering holds in the table.
        let rows = table1_rows(&EnergyModel::calibrated(), &AreaModel::calibrated());
        let ours = rows
            .iter()
            .find(|r| r.name == "This Work (0.85V)")
            .unwrap()
            .tops_per_w
            .unwrap();
        for competitor in ["ISSCC'19 [13]", "VLSI'20 [14]", "ASSCC'20 [11]", "CICC'17 [9]"] {
            let t = rows
                .iter()
                .find(|r| r.name == competitor)
                .unwrap()
                .tops_per_w
                .unwrap();
            assert!(ours > t, "{competitor}: ours {ours:.3} vs {t:.3}");
        }
    }

    #[test]
    fn six_competitors_three_ours() {
        let rows = table1_rows(&EnergyModel::calibrated(), &AreaModel::calibrated());
        assert_eq!(rows.len(), 9);
    }
}
