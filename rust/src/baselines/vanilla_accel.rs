//! The Fig 2 strawman: a digital SNN accelerator with *separate*
//! weight and membrane-potential SRAMs.
//!
//! Per synaptic event (one input spike hitting one 12-neuron row
//! group), the non-fused design pays discrete memory traffic:
//! read the weight row, read the V_MEM row, add in a digital ALU,
//! write the V_MEM row back — 3 SRAM row accesses + an ALU op, where
//! IMPULSE pays a single fused CIM cycle. The model uses the calibrated
//! plain-SRAM access energy so the comparison shares one calibration.

use crate::energy::EnergyModel;
use crate::isa::{InstructionKind, NeuronType};

/// Energy model of the separate-SRAM baseline accelerator.
#[derive(Clone, Debug)]
pub struct VanillaAccelModel<'a> {
    energy: &'a EnergyModel,
    /// ALU add energy relative to one SRAM access (digital adder tree
    /// for 6 values ≈ 30 % of an SRAM row access at 65 nm).
    pub alu_fraction: f64,
}

impl<'a> VanillaAccelModel<'a> {
    pub fn new(energy: &'a EnergyModel) -> Self {
        Self {
            energy,
            alu_fraction: 0.3,
        }
    }

    /// Energy (J) of one synaptic accumulate event at `vdd`
    /// (weight-row read + V read + V write + ALU).
    pub fn accumulate_energy_j(&self, vdd: f64) -> f64 {
        let sram = self.energy.instr_energy_j(InstructionKind::ReadV, vdd);
        3.0 * sram + self.alu_fraction * sram
    }

    /// Energy of one neuron update (read V, compare+reset in ALU,
    /// write V).
    pub fn update_energy_j(&self, vdd: f64, neuron: NeuronType) -> f64 {
        let sram = self.energy.instr_energy_j(InstructionKind::ReadV, vdd);
        let steps = neuron.instructions_per_update() as f64;
        // each sequence step ≈ read + ALU + write
        steps * (2.0 * sram + self.alu_fraction * sram)
    }

    /// Cycles per synaptic event (3 SRAM ports… modelled sequential:
    /// read W, read V, write V = 3 cycles vs IMPULSE's 1).
    pub fn accumulate_cycles(&self) -> u64 {
        3
    }

    /// Per-timestep energy of a 128-input 12-neuron row group at input
    /// sparsity `s`, for comparison against the fused macro.
    pub fn timestep_energy_j(&self, s: f64, neuron: NeuronType, vdd: f64) -> f64 {
        let events = 2.0 * (1.0 - s) * 128.0; // odd+even halves
        events * self.accumulate_energy_j(vdd) + 2.0 * self.update_energy_j(vdd, neuron)
    }

    /// The fused macro's energy for the same work (via the calibrated
    /// instruction energies).
    pub fn impulse_timestep_energy_j(&self, s: f64, neuron: NeuronType, vdd: f64) -> f64 {
        let p = crate::energy::edp_per_neuron_timestep(
            self.energy,
            s,
            neuron,
            vdd,
            crate::NOMINAL_FREQ_HZ,
        );
        p.energy_j * 12.0
    }

    /// Energy ratio (vanilla / IMPULSE) at a sparsity point — the Fig 2
    /// motivation number.
    pub fn energy_ratio(&self, s: f64, neuron: NeuronType, vdd: f64) -> f64 {
        self.timestep_energy_j(s, neuron, vdd) / self.impulse_timestep_energy_j(s, neuron, vdd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::NOMINAL_VDD;

    #[test]
    fn fused_macro_beats_separate_srams_at_all_sparsities() {
        let e = EnergyModel::calibrated();
        let v = VanillaAccelModel::new(&e);
        for s in [0.0, 0.25, 0.5, 0.85, 0.99] {
            let r = v.energy_ratio(s, NeuronType::RMP, NOMINAL_VDD);
            assert!(r > 1.5, "sparsity {s}: ratio {r}");
        }
    }

    #[test]
    fn ratio_roughly_3x_at_high_spike_traffic() {
        // At s=0 the accumulate term dominates: 3.3 SRAM-equivalents vs
        // ~1.3 CIM-equivalents (AccW2V ≈ 1.29× the plain access).
        let e = EnergyModel::calibrated();
        let v = VanillaAccelModel::new(&e);
        let r = v.energy_ratio(0.0, NeuronType::RMP, NOMINAL_VDD);
        assert!(r > 2.0 && r < 4.0, "ratio {r}");
    }

    #[test]
    fn vanilla_needs_3x_cycles_per_event() {
        let e = EnergyModel::calibrated();
        assert_eq!(VanillaAccelModel::new(&e).accumulate_cycles(), 3);
    }
}
