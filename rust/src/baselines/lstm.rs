//! 2-layer LSTM inference (the paper's sequence-model baseline).
//!
//! Architecture matches `python/compile/lstm_baseline.py` exactly:
//! no biases (4·(mn + n²) per layer → 247,808 ≈ 247.8K parameters for
//! m=100, n=128, the paper's count), forget-gate +1 bias folded into
//! the activation, gate order [i, f, g, o].

use crate::data::binfmt::Tensor;
use crate::Result;
use anyhow::Context;
use std::path::Path;

const H: usize = 128;

/// A dense f32 matrix in row-major order.
#[derive(Clone, Debug)]
struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    fn from_tensor(t: &Tensor) -> Result<Mat> {
        anyhow::ensure!(t.shape.len() == 2, "expected rank-2, got {:?}", t.shape);
        Ok(Mat {
            rows: t.shape[0],
            cols: t.shape[1],
            data: t.to_f32()?,
        })
    }

    /// y += xᵀ · M (x: rows, y: cols)
    fn accum_vec_mul(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(y.len(), self.cols);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &self.data[i * self.cols..(i + 1) * self.cols];
            for (yj, &wij) in y.iter_mut().zip(row) {
                *yj += xi * wij;
            }
        }
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// The 2-layer LSTM with a linear readout.
pub struct Lstm {
    wx1: Mat,
    wh1: Mat,
    wx2: Mat,
    wh2: Mat,
    w_out: Mat,
}

impl Lstm {
    /// Load from the artifact bundle (`artifacts/lstm/*.bin`).
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let d = artifacts_dir.as_ref().join("lstm");
        let m = |name: &str| -> Result<Mat> {
            Mat::from_tensor(
                &Tensor::read(d.join(format!("{name}.bin")))
                    .with_context(|| format!("lstm weight {name}"))?,
            )
        };
        let lstm = Self {
            wx1: m("wx1")?,
            wh1: m("wh1")?,
            wx2: m("wx2")?,
            wh2: m("wh2")?,
            w_out: m("w_out")?,
        };
        anyhow::ensure!(lstm.wx1.cols == 4 * H && lstm.wh1.rows == H);
        Ok(lstm)
    }

    /// Parameter count (the Fig 9b comparison number).
    pub fn num_params(&self) -> usize {
        [&self.wx1, &self.wh1, &self.wx2, &self.wh2, &self.w_out]
            .iter()
            .map(|m| m.rows * m.cols)
            .sum()
    }

    /// Classify one sequence of embedding vectors. Returns the logit.
    pub fn run(&self, emb_seq: &[Vec<f32>]) -> f32 {
        let mut h1 = vec![0f32; H];
        let mut c1 = vec![0f32; H];
        let mut h2 = vec![0f32; H];
        let mut c2 = vec![0f32; H];
        let mut z = vec![0f32; 4 * H];
        for x in emb_seq {
            cell(&self.wx1, &self.wh1, x, &mut h1, &mut c1, &mut z);
            let h1_snapshot = h1.clone();
            cell(&self.wx2, &self.wh2, &h1_snapshot, &mut h2, &mut c2, &mut z);
        }
        let mut logit = vec![0f32; 1];
        self.w_out.accum_vec_mul(&h2, &mut logit);
        logit[0]
    }

    /// Predicted label.
    pub fn predict(&self, emb_seq: &[Vec<f32>]) -> u8 {
        (self.run(emb_seq) >= 0.0) as u8
    }
}

fn cell(wx: &Mat, wh: &Mat, x: &[f32], h: &mut [f32], c: &mut [f32], z: &mut [f32]) {
    z.iter_mut().for_each(|v| *v = 0.0);
    wx.accum_vec_mul(x, z);
    wh.accum_vec_mul(h, z);
    for j in 0..H {
        let i_g = sigmoid(z[j]);
        let f_g = sigmoid(z[H + j] + 1.0);
        let g_g = z[2 * H + j].tanh();
        let o_g = sigmoid(z[3 * H + j]);
        c[j] = f_g * c[j] + i_g * g_g;
        h[j] = o_g * c[j].tanh();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_lstm() -> Lstm {
        // deterministic small weights exercising every gate
        let fill = |rows: usize, cols: usize, scale: f32| Mat {
            rows,
            cols,
            data: (0..rows * cols)
                .map(|i| ((i % 17) as f32 - 8.0) * scale)
                .collect(),
        };
        Lstm {
            wx1: fill(100, 4 * H, 0.01),
            wh1: fill(H, 4 * H, 0.01),
            wx2: fill(H, 4 * H, 0.01),
            wh2: fill(H, 4 * H, 0.01),
            w_out: fill(H, 1, 0.05),
        }
    }

    #[test]
    fn param_count_matches_paper() {
        let l = tiny_lstm();
        // 4(100·128+128²) + 4(128·128+128²) + 128 = 247,936
        assert_eq!(l.num_params(), 247_936);
    }

    #[test]
    fn run_is_deterministic_and_state_dependent() {
        let l = tiny_lstm();
        let seq1: Vec<Vec<f32>> = (0..5)
            .map(|t| (0..100).map(|i| ((i + t) % 7) as f32 * 0.1).collect())
            .collect();
        let a = l.run(&seq1);
        let b = l.run(&seq1);
        assert_eq!(a, b);
        // order matters (sequence memory)
        let mut seq2 = seq1.clone();
        seq2.reverse();
        assert_ne!(l.run(&seq1), l.run(&seq2));
    }

    #[test]
    fn empty_sequence_gives_zero_logit() {
        let l = tiny_lstm();
        assert_eq!(l.run(&[]), 0.0);
    }
}
