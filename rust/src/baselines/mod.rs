//! Comparison baselines:
//!
//! - [`lstm`] — the paper's accuracy/parameter-count baseline (2-layer
//!   LSTM, 247.8K parameters vs the SNN's 29.3K) running the weights
//!   trained at build time.
//! - [`vanilla_accel`] — the Fig 2 strawman: a digital SNN accelerator
//!   with *separate* weight and V_MEM SRAMs (every synaptic event costs
//!   discrete read/compute/write traffic instead of one fused CIM
//!   cycle).
//! - [`table1`] — the published competitor-macro numbers and our
//!   model's "This Work" columns.

pub mod lstm;
pub mod table1;
pub mod vanilla_accel;

pub use lstm::Lstm;
pub use table1::{table1_rows, MacroRow, THIS_WORK_POINTS};
pub use vanilla_accel::VanillaAccelModel;
