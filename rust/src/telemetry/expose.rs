//! The plaintext metrics exposition endpoint (`--metrics-listen`).
//!
//! A dedicated thread serves the registry in the Prometheus text
//! format (version 0.0.4) over bare HTTP — no dependencies, no TLS,
//! one short-lived connection per scrape. Any `GET` path answers with
//! the full metrics page ([`StatsSnapshot::to_prometheus`]); anything
//! else is answered `400` and closed. This endpoint is for scrapers
//! and `curl`; the request/response path for programs is the
//! `StatsRequest`/`StatsResponse` frames of the binary protocol.
//!
//! [`StatsSnapshot::to_prometheus`]: super::StatsSnapshot::to_prometheus

use super::Telemetry;
use crate::Result;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// A running metrics exposition endpoint.
pub struct MetricsHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl MetricsHandle {
    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting scrapes and join the serving thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

/// Bind `addr` (e.g. `127.0.0.1:9200`, port `0` for ephemeral) and
/// serve the registry as Prometheus text until
/// [`MetricsHandle::stop`].
pub fn serve_metrics(addr: &str, telemetry: Arc<Telemetry>) -> Result<MetricsHandle> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let thread = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            loop {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // scrapes are tiny and rare: handle inline so a
                        // single thread bounds resource use
                        let _ = answer_scrape(stream, &telemetry);
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    Err(e) => {
                        eprintln!("impulse metrics: accept failed: {e}");
                        break;
                    }
                }
            }
        })
    };
    Ok(MetricsHandle { addr: local, stop, thread: Some(thread) })
}

/// Read one HTTP request head and answer it with the metrics page.
fn answer_scrape(mut stream: TcpStream, telemetry: &Telemetry) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    // read until the end of the request head (or a small cap — the
    // request body, if any, is irrelevant to a scrape)
    let mut head = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && head.len() < 8192 {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&chunk[..n]),
            Err(_) => break, // timeout or reset: answer what we can
        }
    }
    let is_get = head.starts_with(b"GET ");
    let (status, body) = if is_get {
        // the pinned StatsSnapshot page, plus the stream-session
        // counters (registry-only — not part of the stats wire struct)
        let mut page = telemetry.snapshot().to_prometheus();
        page.push_str(&telemetry.stream_stats().to_prometheus());
        ("200 OK", page)
    } else {
        ("400 Bad Request", "metrics endpoint: GET only\n".to_string())
    };
    let response = format!(
        "HTTP/1.0 {status}\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    let _ = stream.shutdown(std::net::Shutdown::Both);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::WorkloadKind;

    fn http_get(addr: SocketAddr, request: &[u8]) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(request).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn scrape_returns_prometheus_text() {
        let t = Arc::new(Telemetry::default());
        t.record_submit(WorkloadKind::Digits);
        t.record_response(WorkloadKind::Digits, 10, 10, true);
        let h = serve_metrics("127.0.0.1:0", Arc::clone(&t)).unwrap();
        let page = http_get(h.local_addr(), b"GET /metrics HTTP/1.0\r\n\r\n");
        assert!(page.starts_with("HTTP/1.0 200 OK"), "{page}");
        assert!(page.contains("text/plain; version=0.0.4"));
        assert!(page.contains("impulse_requests_submitted_total{kind=\"digits\"} 1"));
        assert!(page.contains("impulse_queue_depth 0"));
        assert!(page.contains("impulse_streams_active 0"));

        let bad = http_get(h.local_addr(), b"POST /metrics HTTP/1.0\r\n\r\n");
        assert!(bad.starts_with("HTTP/1.0 400"), "{bad}");
        h.stop();
    }
}
