//! The plaintext metrics exposition endpoint (`--metrics-listen`).
//!
//! A dedicated thread serves the registry in the Prometheus text
//! format (version 0.0.4) over bare HTTP — no dependencies, no TLS,
//! one short-lived connection per scrape. `GET /healthz` answers a
//! bare `200 ok` for load-balancer liveness probes; any other `GET`
//! path answers with the full metrics page
//! ([`StatsSnapshot::to_prometheus`] plus the
//! `impulse_build_info{version,git_rev}` gauge); anything else is
//! answered `400` and closed. This endpoint is for scrapers and
//! `curl`; the request/response path for programs is the
//! `StatsRequest`/`StatsResponse` frames of the binary protocol.
//!
//! [`StatsSnapshot::to_prometheus`]: super::StatsSnapshot::to_prometheus

use super::Telemetry;
use crate::Result;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// A running metrics exposition endpoint.
pub struct MetricsHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl MetricsHandle {
    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting scrapes and join the serving thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

/// A per-scrape hook appending extra Prometheus text to the metrics
/// page (e.g. the proxy tier's per-backend counters). Called once per
/// scrape, after the registry pages.
pub type ExtraPage = Arc<dyn Fn() -> String + Send + Sync>;

/// Bind `addr` (e.g. `127.0.0.1:9200`, port `0` for ephemeral) and
/// serve the registry as Prometheus text until
/// [`MetricsHandle::stop`].
pub fn serve_metrics(addr: &str, telemetry: Arc<Telemetry>) -> Result<MetricsHandle> {
    serve_metrics_with(addr, telemetry, Arc::new(String::new))
}

/// [`serve_metrics`] with an [`ExtraPage`] hook: every scrape appends
/// `extra()`'s output after the registry pages (and before the
/// build-info gauge). `/healthz` is unaffected — liveness probes never
/// walk the registry or the hook.
pub fn serve_metrics_with(
    addr: &str,
    telemetry: Arc<Telemetry>,
    extra: ExtraPage,
) -> Result<MetricsHandle> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let thread = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            loop {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // scrapes are tiny and rare: handle inline so a
                        // single thread bounds resource use
                        let _ = answer_scrape(stream, &telemetry, &*extra);
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    Err(e) => {
                        crate::error!("metrics", "accept failed: {e}");
                        break;
                    }
                }
            }
        })
    };
    Ok(MetricsHandle { addr: local, stop, thread: Some(thread) })
}

/// Read one HTTP request head and answer it with the metrics page.
fn answer_scrape(
    mut stream: TcpStream,
    telemetry: &Telemetry,
    extra: &(dyn Fn() -> String + Send + Sync),
) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    // read until the end of the request head (or a small cap — the
    // request body, if any, is irrelevant to a scrape)
    let mut head = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && head.len() < 8192 {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&chunk[..n]),
            Err(_) => break, // timeout or reset: answer what we can
        }
    }
    let is_get = head.starts_with(b"GET ");
    let path = request_path(&head);
    let (status, body) = if is_get && path == "/healthz" {
        // bare liveness answer: reaching this handler at all proves
        // the exposition thread is accepting, which is the probe's
        // whole question — no registry walk on the probe path
        ("200 OK", "ok\n".to_string())
    } else if is_get {
        // the pinned StatsSnapshot page, plus the stream-session
        // counters (registry-only — not part of the stats wire struct)
        // and the constant build-info gauge
        let mut page = telemetry.snapshot().to_prometheus();
        page.push_str(&telemetry.stream_stats().to_prometheus());
        page.push_str(&extra());
        page.push_str(build_info_line());
        ("200 OK", page)
    } else {
        ("400 Bad Request", "metrics endpoint: GET only\n".to_string())
    };
    let response = format!(
        "HTTP/1.0 {status}\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    let _ = stream.shutdown(std::net::Shutdown::Both);
    Ok(())
}

/// The request path from an HTTP request head (`""` if unparsable).
fn request_path(head: &[u8]) -> &str {
    let line = head.split(|&b| b == b'\r').next().unwrap_or(b"");
    std::str::from_utf8(line)
        .ok()
        .and_then(|l| l.split_whitespace().nth(1))
        .unwrap_or("")
}

/// The constant `impulse_build_info` gauge: version and revision as
/// labels, value pinned to 1 (the standard Prometheus idiom for
/// exposing build metadata). Computed once — `git rev-parse` forks.
fn build_info_line() -> &'static str {
    static LINE: OnceLock<String> = OnceLock::new();
    LINE.get_or_init(|| {
        format!(
            "# HELP impulse_build_info Build metadata as labels (value is always 1).\n\
             # TYPE impulse_build_info gauge\n\
             impulse_build_info{{version=\"{}\",git_rev=\"{}\"}} 1\n",
            env!("CARGO_PKG_VERSION"),
            git_rev()
        )
    })
}

/// Best-effort revision stamp: CI's `GITHUB_SHA`, else `git
/// rev-parse`, else "unknown".
fn git_rev() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .unwrap_or_else(|| "unknown".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::WorkloadKind;

    fn http_get(addr: SocketAddr, request: &[u8]) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(request).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn scrape_returns_prometheus_text() {
        let t = Arc::new(Telemetry::default());
        t.record_submit(WorkloadKind::Digits);
        t.record_response(WorkloadKind::Digits, 10, 10, true);
        let h = serve_metrics("127.0.0.1:0", Arc::clone(&t)).unwrap();
        let page = http_get(h.local_addr(), b"GET /metrics HTTP/1.0\r\n\r\n");
        assert!(page.starts_with("HTTP/1.0 200 OK"), "{page}");
        assert!(page.contains("text/plain; version=0.0.4"));
        assert!(page.contains("impulse_requests_submitted_total{kind=\"digits\"} 1"));
        assert!(page.contains("impulse_queue_depth 0"));
        assert!(page.contains("impulse_streams_active 0"));
        assert!(page.contains("impulse_build_info{version=\""), "{page}");
        assert!(page.contains("git_rev=\""), "{page}");
        assert!(page.contains("\"} 1"), "{page}");

        let bad = http_get(h.local_addr(), b"POST /metrics HTTP/1.0\r\n\r\n");
        assert!(bad.starts_with("HTTP/1.0 400"), "{bad}");
        h.stop();
    }

    #[test]
    fn healthz_answers_bare_ok_without_a_metrics_page() {
        let t = Arc::new(Telemetry::default());
        let h = serve_metrics("127.0.0.1:0", Arc::clone(&t)).unwrap();
        let page = http_get(h.local_addr(), b"GET /healthz HTTP/1.0\r\n\r\n");
        assert!(page.starts_with("HTTP/1.0 200 OK"), "{page}");
        assert!(page.ends_with("ok\n"), "{page}");
        assert!(!page.contains("impulse_"), "healthz must not walk the registry: {page}");
        h.stop();
    }

    #[test]
    fn request_path_parses_the_head_defensively() {
        assert_eq!(request_path(b"GET /healthz HTTP/1.0\r\n\r\n"), "/healthz");
        assert_eq!(request_path(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n"), "/metrics");
        assert_eq!(request_path(b"GET"), "");
        assert_eq!(request_path(b""), "");
        assert_eq!(request_path(&[0xFF, 0xFE]), "");
    }
}
