//! Lock-free sharded latency histograms.
//!
//! A [`ShardedHistogram`] is a fixed set of power-of-two microsecond
//! buckets striped across several cache-line-aligned shards: recording
//! touches only the caller's shard (plain relaxed atomic adds — no
//! locks, no CAS loops), so many worker/responder threads can record
//! concurrently without bouncing one hot line between cores. Readers
//! merge the shards into a [`HistogramSummary`] — merged totals are
//! exact (every recorded sample lands in exactly one shard bucket);
//! only the *instantaneous* cross-shard view is relaxed.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

/// Number of latency buckets: bucket 0 holds 0 µs exactly, bucket
/// `i ≥ 1` holds latencies in `[2^(i-1), 2^i)` µs; the last bucket
/// additionally absorbs everything above its lower bound (~67 s).
pub const N_LATENCY_BUCKETS: usize = 28;

/// Stripe count. Eight shards comfortably cover the worker + responder
/// thread counts this server runs; more would only pad the merge.
const N_SHARDS: usize = 8;

/// One stripe of the histogram, padded to its own cache lines so
/// adjacent shards never share one.
#[repr(align(128))]
#[derive(Debug)]
struct Shard {
    count: AtomicU64,
    sum_us: AtomicU64,
    buckets: [AtomicU64; N_LATENCY_BUCKETS],
}

impl Shard {
    fn new() -> Shard {
        Shard {
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// The stable per-thread shard index: threads are handed stripes
/// round-robin on first use, so a given thread always records into the
/// same shard (no hashing on the hot path).
fn shard_index() -> usize {
    use std::cell::Cell;
    thread_local! {
        static IDX: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    IDX.with(|c| {
        let mut v = c.get();
        if v == usize::MAX {
            static NEXT: AtomicUsize = AtomicUsize::new(0);
            v = NEXT.fetch_add(1, Ordering::Relaxed);
            c.set(v);
        }
        v % N_SHARDS
    })
}

/// The bucket a latency of `us` microseconds falls into (see
/// [`N_LATENCY_BUCKETS`] for the bucket boundaries).
pub fn bucket_index(us: u64) -> usize {
    if us == 0 {
        0
    } else {
        (64 - us.leading_zeros() as usize).min(N_LATENCY_BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `i` in microseconds (`u64::MAX` for
/// the final catch-all bucket).
pub fn bucket_upper_us(i: usize) -> u64 {
    if i >= N_LATENCY_BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Quantile estimate in microseconds over bucketed samples: the
/// inclusive upper bound of the bucket holding the `q`-th of `count`
/// samples (0 when empty). Shared by the local [`HistogramSummary`]
/// and the wire-side transport rows so the two views can never
/// diverge. A bucket estimate is within 2× of the true value by
/// construction.
pub fn quantile_from_buckets(count: u64, buckets: &[u64], q: f64) -> u64 {
    if count == 0 {
        return 0;
    }
    let rank = (count as f64 * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
    let mut seen = 0u64;
    for (i, &b) in buckets.iter().enumerate() {
        seen += b;
        if seen >= rank {
            return bucket_upper_us(i);
        }
    }
    bucket_upper_us(N_LATENCY_BUCKETS - 1)
}

/// A lock-free latency histogram striped across cache-aligned shards.
#[derive(Debug)]
pub struct ShardedHistogram {
    shards: Vec<Shard>,
}

impl Default for ShardedHistogram {
    fn default() -> Self {
        ShardedHistogram::new()
    }
}

impl ShardedHistogram {
    /// An empty histogram.
    pub fn new() -> ShardedHistogram {
        ShardedHistogram {
            shards: (0..N_SHARDS).map(|_| Shard::new()).collect(),
        }
    }

    /// Record one latency sample (relaxed atomics on the caller's own
    /// shard — safe from any number of threads).
    pub fn record(&self, d: Duration) {
        let us = u64::try_from(d.as_micros()).unwrap_or(u64::MAX);
        let s = &self.shards[shard_index()];
        s.count.fetch_add(1, Ordering::Relaxed);
        s.sum_us.fetch_add(us, Ordering::Relaxed);
        s.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Merge all shards into one consistent-enough summary (totals are
    /// exact for all samples recorded-before the merge began).
    pub fn merge(&self) -> HistogramSummary {
        let mut out = HistogramSummary::default();
        for s in &self.shards {
            out.count += s.count.load(Ordering::Relaxed);
            out.sum_us += s.sum_us.load(Ordering::Relaxed);
            for (o, b) in out.buckets.iter_mut().zip(&s.buckets) {
                *o += b.load(Ordering::Relaxed);
            }
        }
        out
    }
}

/// A merged, read-only view of a [`ShardedHistogram`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistogramSummary {
    /// Total samples recorded.
    pub count: u64,
    /// Sum of all samples in microseconds (saturating per sample).
    pub sum_us: u64,
    /// Per-bucket sample counts (see [`bucket_index`]).
    pub buckets: [u64; N_LATENCY_BUCKETS],
}

impl HistogramSummary {
    /// Mean latency in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Quantile estimate in microseconds (see
    /// [`quantile_from_buckets`]).
    pub fn quantile_us(&self, q: f64) -> u64 {
        quantile_from_buckets(self.count, &self.buckets, q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_partition_the_axis() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), N_LATENCY_BUCKETS - 1);
        // every bucket's upper bound lands back in that bucket
        for i in 1..N_LATENCY_BUCKETS - 1 {
            assert_eq!(bucket_index(bucket_upper_us(i)), i, "bucket {i}");
            assert_eq!(bucket_index(bucket_upper_us(i) + 1), i + 1, "bucket {i}+1");
        }
    }

    #[test]
    fn records_merge_exactly() {
        let h = ShardedHistogram::new();
        for us in [0u64, 1, 5, 100, 1000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        let m = h.merge();
        assert_eq!(m.count, 6);
        assert_eq!(m.sum_us, 101_106);
        assert_eq!(m.buckets.iter().sum::<u64>(), 6);
        assert_eq!(m.buckets[0], 1); // the 0 µs sample
    }

    #[test]
    fn concurrent_records_are_all_counted() {
        let h = std::sync::Arc::new(ShardedHistogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = std::sync::Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record(Duration::from_micros(t * 1000 + i));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.merge().count, 4000);
    }

    #[test]
    fn quantiles_walk_the_buckets() {
        let h = ShardedHistogram::new();
        for _ in 0..90 {
            h.record(Duration::from_micros(10)); // bucket 4 ([8, 16))
        }
        for _ in 0..10 {
            h.record(Duration::from_micros(5000)); // bucket 13
        }
        let m = h.merge();
        assert_eq!(m.quantile_us(0.5), bucket_upper_us(4));
        assert_eq!(m.quantile_us(0.99), bucket_upper_us(13));
        assert!(m.mean_us() > 10.0 && m.mean_us() < 5000.0);
    }

    #[test]
    fn empty_summary_is_zeroed() {
        let m = ShardedHistogram::new().merge();
        assert_eq!(m.count, 0);
        assert_eq!(m.quantile_us(0.5), 0);
        assert_eq!(m.mean_us(), 0.0);
    }
}
