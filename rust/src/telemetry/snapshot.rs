//! Point-in-time telemetry snapshots and their stable wire codes.
//!
//! A [`StatsSnapshot`] is the plain (non-atomic) view a
//! [`Telemetry`](super::Telemetry) registry produces on demand. It is
//! what the `StatsResponse` wire payload carries (codec in
//! `serve::session`, spec in `docs/PROTOCOL.md` §4.9), what the
//! `--metrics-listen` endpoint renders as Prometheus text, and what
//! `impulse stats` prints. The numeric codes in this module are wire
//! contract — change them only in lockstep with `docs/PROTOCOL.md`.

use super::histogram::bucket_upper_us;
use crate::coordinator::WorkloadKind;
use crate::isa::InstructionKind;

/// Stats payload format version carried in `StatsResponse` (§4.9).
pub const STATS_VERSION: u8 = 1;

/// Transports a response can be delivered over (wire codes in §4.9).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Transport {
    /// The binary-framed TCP listener.
    Tcp,
    /// The stdio line loop.
    Stdio,
}

/// All transports, in wire-code order.
pub const ALL_TRANSPORTS: [Transport; 2] = [Transport::Tcp, Transport::Stdio];

impl Transport {
    /// Stable wire code of this transport.
    pub fn code(self) -> u8 {
        match self {
            Transport::Tcp => 0,
            Transport::Stdio => 1,
        }
    }

    /// Decode a wire code; `None` for unassigned values.
    pub fn from_code(c: u8) -> Option<Transport> {
        match c {
            0 => Some(Transport::Tcp),
            1 => Some(Transport::Stdio),
            _ => None,
        }
    }

    /// Lower-case label used in Prometheus labels and CLI output.
    pub fn name(self) -> &'static str {
        match self {
            Transport::Tcp => "tcp",
            Transport::Stdio => "stdio",
        }
    }
}

/// All workload kinds, in wire-code order.
pub const ALL_KINDS: [WorkloadKind; 2] = [WorkloadKind::Sentiment, WorkloadKind::Digits];

/// Stable wire code of a workload kind (§4.9).
pub fn kind_code(k: WorkloadKind) -> u8 {
    match k {
        WorkloadKind::Sentiment => 0,
        WorkloadKind::Digits => 1,
    }
}

/// Decode a workload-kind wire code; `None` for unassigned values.
pub fn kind_from_code(c: u8) -> Option<WorkloadKind> {
    match c {
        0 => Some(WorkloadKind::Sentiment),
        1 => Some(WorkloadKind::Digits),
        _ => None,
    }
}

/// Lower-case label of a workload kind (Prometheus / CLI).
pub fn kind_name(k: WorkloadKind) -> &'static str {
    match k {
        WorkloadKind::Sentiment => "sentiment",
        WorkloadKind::Digits => "digits",
    }
}

/// All instruction kinds, in wire-code order.
pub const ALL_INSTR_KINDS: [InstructionKind; 7] = [
    InstructionKind::AccW2V,
    InstructionKind::AccV2V,
    InstructionKind::SpikeCheck,
    InstructionKind::ResetV,
    InstructionKind::ReadV,
    InstructionKind::WriteV,
    InstructionKind::WriteW,
];

/// Stable wire code of an instruction kind (§4.9).
pub fn instr_code(k: InstructionKind) -> u8 {
    match k {
        InstructionKind::AccW2V => 0,
        InstructionKind::AccV2V => 1,
        InstructionKind::SpikeCheck => 2,
        InstructionKind::ResetV => 3,
        InstructionKind::ReadV => 4,
        InstructionKind::WriteV => 5,
        InstructionKind::WriteW => 6,
    }
}

/// Decode an instruction-kind wire code; `None` for unassigned values.
pub fn instr_from_code(c: u8) -> Option<InstructionKind> {
    ALL_INSTR_KINDS.get(c as usize).copied()
}

/// Lower-case label of an instruction kind (Prometheus / CLI).
pub fn instr_name(k: InstructionKind) -> &'static str {
    match k {
        InstructionKind::AccW2V => "acc_w2v",
        InstructionKind::AccV2V => "acc_v2v",
        InstructionKind::SpikeCheck => "spike_check",
        InstructionKind::ResetV => "reset_v",
        InstructionKind::ReadV => "read_v",
        InstructionKind::WriteV => "write_v",
        InstructionKind::WriteW => "write_w",
    }
}

/// Per-workload-kind counters of a [`StatsSnapshot`].
#[derive(Clone, Debug, PartialEq)]
pub struct KindStats {
    /// Which workload family these counters describe.
    pub kind: WorkloadKind,
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Successful responses published.
    pub ok: u64,
    /// Error responses published.
    pub err: u64,
    /// Macro cycles attributed to this kind's responses.
    pub cycles: u64,
    /// Energy attributed through `energy::model`, in femtojoules.
    pub energy_fj: u64,
    /// Energy–delay product attributed to this kind, in J·s.
    pub edp_js: f64,
    /// Input units observed (word-id slots / pixels).
    pub input_units: u64,
    /// Input units that were active (non-padding ids / nonzero
    /// pixels) — `1 − active/units` is the observed input sparsity the
    /// macro's energy proportionality rides on.
    pub input_active: u64,
}

impl KindStats {
    /// An all-zero row for a kind.
    pub fn zero(kind: WorkloadKind) -> KindStats {
        KindStats {
            kind,
            submitted: 0,
            ok: 0,
            err: 0,
            cycles: 0,
            energy_fj: 0,
            edp_js: 0.0,
            input_units: 0,
            input_active: 0,
        }
    }

    /// Observed input sparsity in `[0, 1]` (0 when nothing observed).
    pub fn input_sparsity(&self) -> f64 {
        if self.input_units == 0 {
            0.0
        } else {
            1.0 - self.input_active as f64 / self.input_units as f64
        }
    }
}

/// Per-transport latency histogram of a [`StatsSnapshot`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransportStats {
    /// Which transport delivered these responses.
    pub transport: Transport,
    /// Responses delivered.
    pub count: u64,
    /// Sum of server-side latencies in microseconds.
    pub sum_us: u64,
    /// Power-of-two latency buckets (see
    /// [`bucket_index`](super::histogram::bucket_index)).
    pub buckets: Vec<u64>,
}

impl TransportStats {
    /// Quantile estimate in microseconds from the buckets (see
    /// [`quantile_from_buckets`](super::histogram::quantile_from_buckets)).
    pub fn quantile_us(&self, q: f64) -> u64 {
        super::histogram::quantile_from_buckets(self.count, &self.buckets, q)
    }
}

/// A point-in-time view of a server's telemetry registry.
#[derive(Clone, Debug, PartialEq)]
pub struct StatsSnapshot {
    /// Requests submitted but not yet answered.
    pub queue_depth: u64,
    /// Configured backpressure soft limit (0 = always signalled).
    pub queue_soft_limit: u64,
    /// Whether the queue depth is at or over the soft limit.
    pub soft_limited: bool,
    /// Micro-batches executed by the worker pool.
    pub batches: u64,
    /// Total fused lanes those batches occupied (Σ batch sizes).
    pub batch_lanes: u64,
    /// Total fused-lane capacity those batches had available.
    pub batch_lane_capacity: u64,
    /// Per-workload-kind counters, in wire-code order.
    pub kinds: Vec<KindStats>,
    /// Instruction issue counters as `(wire code, count)` pairs.
    pub instr: Vec<(u8, u64)>,
    /// Per-transport latency histograms.
    pub transports: Vec<TransportStats>,
}

impl StatsSnapshot {
    /// Mean fused-lane occupancy per batch (0 when no batches ran).
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_lanes as f64 / self.batches as f64
        }
    }

    /// The counter row for one workload kind, if present.
    pub fn kind(&self, k: WorkloadKind) -> Option<&KindStats> {
        self.kinds.iter().find(|s| s.kind == k)
    }

    /// The histogram row for one transport, if present.
    pub fn transport(&self, t: Transport) -> Option<&TransportStats> {
        self.transports.iter().find(|s| s.transport == t)
    }

    /// Instruction count by kind (0 when absent).
    pub fn instr_count(&self, k: InstructionKind) -> u64 {
        let code = instr_code(k);
        self.instr
            .iter()
            .find(|(c, _)| *c == code)
            .map(|(_, n)| *n)
            .unwrap_or(0)
    }

    /// Render in the Prometheus text exposition format (version
    /// 0.0.4) — what `--metrics-listen` serves. No dependencies: the
    /// format is plain `name{labels} value` lines.
    pub fn to_prometheus(&self) -> String {
        let mut o = String::with_capacity(4096);
        let mut put = |line: String| {
            o.push_str(&line);
            o.push('\n');
        };
        put("# HELP impulse_queue_depth Requests submitted but not yet answered.".into());
        put("# TYPE impulse_queue_depth gauge".into());
        put(format!("impulse_queue_depth {}", self.queue_depth));
        put("# TYPE impulse_queue_soft_limit gauge".into());
        put(format!("impulse_queue_soft_limit {}", self.queue_soft_limit));
        put("# HELP impulse_queue_soft_limited 1 when backpressure is signalled.".into());
        put("# TYPE impulse_queue_soft_limited gauge".into());
        put(format!("impulse_queue_soft_limited {}", u8::from(self.soft_limited)));
        put("# TYPE impulse_batches_total counter".into());
        put(format!("impulse_batches_total {}", self.batches));
        put("# HELP impulse_batch_lanes_total Fused lanes occupied by batches.".into());
        put("# TYPE impulse_batch_lanes_total counter".into());
        put(format!("impulse_batch_lanes_total {}", self.batch_lanes));
        put("# TYPE impulse_batch_lane_capacity_total counter".into());
        put(format!("impulse_batch_lane_capacity_total {}", self.batch_lane_capacity));

        put("# TYPE impulse_requests_submitted_total counter".into());
        put("# TYPE impulse_responses_total counter".into());
        put("# TYPE impulse_cycles_total counter".into());
        put("# HELP impulse_energy_joules_total Energy attributed via the energy model.".into());
        put("# TYPE impulse_energy_joules_total counter".into());
        put("# TYPE impulse_edp_joule_seconds_total counter".into());
        put("# TYPE impulse_input_units_total counter".into());
        put("# TYPE impulse_input_active_total counter".into());
        for k in &self.kinds {
            let name = kind_name(k.kind);
            let kl = format!("{{kind=\"{name}\"}}");
            put(format!("impulse_requests_submitted_total{kl} {}", k.submitted));
            put(format!("impulse_responses_total{{kind=\"{name}\",outcome=\"ok\"}} {}", k.ok));
            put(format!("impulse_responses_total{{kind=\"{name}\",outcome=\"err\"}} {}", k.err));
            put(format!("impulse_cycles_total{kl} {}", k.cycles));
            put(format!("impulse_energy_joules_total{kl} {:e}", k.energy_fj as f64 * 1e-15));
            put(format!("impulse_edp_joule_seconds_total{kl} {:e}", k.edp_js));
            put(format!("impulse_input_units_total{kl} {}", k.input_units));
            put(format!("impulse_input_active_total{kl} {}", k.input_active));
        }

        put("# HELP impulse_instructions_total Macro instructions issued, by kind.".into());
        put("# TYPE impulse_instructions_total counter".into());
        for &(code, n) in &self.instr {
            let label = instr_from_code(code).map(instr_name).unwrap_or("unknown");
            put(format!("impulse_instructions_total{{instr=\"{label}\"}} {n}"));
        }

        put("# HELP impulse_request_latency_seconds Server-side latency per transport.".into());
        put("# TYPE impulse_request_latency_seconds histogram".into());
        for t in &self.transports {
            let name = t.transport.name();
            let mut cum = 0u64;
            for (i, &b) in t.buckets.iter().enumerate() {
                cum += b;
                let le = if bucket_upper_us(i) == u64::MAX {
                    "+Inf".to_string()
                } else {
                    format!("{:e}", (bucket_upper_us(i) + 1) as f64 / 1e6)
                };
                put(format!(
                    "impulse_request_latency_seconds_bucket\
                     {{transport=\"{name}\",le=\"{le}\"}} {cum}"
                ));
            }
            put(format!(
                "impulse_request_latency_seconds_sum{{transport=\"{name}\"}} {:e}",
                t.sum_us as f64 / 1e6
            ));
            put(format!(
                "impulse_request_latency_seconds_count{{transport=\"{name}\"}} {}",
                t.count
            ));
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::N_LATENCY_BUCKETS;

    #[test]
    fn wire_codes_roundtrip() {
        for k in ALL_KINDS {
            assert_eq!(kind_from_code(kind_code(k)), Some(k));
        }
        assert_eq!(kind_from_code(9), None);
        for t in ALL_TRANSPORTS {
            assert_eq!(Transport::from_code(t.code()), Some(t));
        }
        assert_eq!(Transport::from_code(7), None);
        for (i, k) in ALL_INSTR_KINDS.iter().enumerate() {
            assert_eq!(instr_code(*k) as usize, i);
            assert_eq!(instr_from_code(i as u8), Some(*k));
        }
        assert_eq!(instr_from_code(7), None);
    }

    #[test]
    fn sparsity_and_occupancy_derivations() {
        let mut k = KindStats::zero(WorkloadKind::Sentiment);
        assert_eq!(k.input_sparsity(), 0.0);
        k.input_units = 100;
        k.input_active = 15;
        assert!((k.input_sparsity() - 0.85).abs() < 1e-12);

        let s = StatsSnapshot {
            queue_depth: 0,
            queue_soft_limit: 8,
            soft_limited: false,
            batches: 4,
            batch_lanes: 10,
            batch_lane_capacity: 52,
            kinds: vec![k],
            instr: vec![(0, 42)],
            transports: vec![],
        };
        assert_eq!(s.mean_batch_occupancy(), 2.5);
        assert_eq!(s.instr_count(InstructionKind::AccW2V), 42);
        assert_eq!(s.instr_count(InstructionKind::WriteW), 0);
        assert!(s.kind(WorkloadKind::Sentiment).is_some());
        assert!(s.kind(WorkloadKind::Digits).is_none());
    }

    #[test]
    fn prometheus_rendering_contains_core_series() {
        let s = StatsSnapshot {
            queue_depth: 3,
            queue_soft_limit: 8,
            soft_limited: false,
            batches: 2,
            batch_lanes: 5,
            batch_lane_capacity: 26,
            kinds: vec![KindStats {
                submitted: 5,
                ok: 4,
                err: 1,
                cycles: 999,
                energy_fj: 1_000_000,
                edp_js: 2.5e-12,
                input_units: 80,
                input_active: 20,
                ..KindStats::zero(WorkloadKind::Sentiment)
            }],
            instr: vec![(0, 123)],
            transports: vec![TransportStats {
                transport: Transport::Tcp,
                count: 5,
                sum_us: 900,
                buckets: vec![0; N_LATENCY_BUCKETS],
            }],
        };
        let text = s.to_prometheus();
        assert!(text.contains("impulse_queue_depth 3"));
        assert!(text.contains("impulse_requests_submitted_total{kind=\"sentiment\"} 5"));
        assert!(text.contains("impulse_responses_total{kind=\"sentiment\",outcome=\"err\"} 1"));
        assert!(text.contains("impulse_instructions_total{instr=\"acc_w2v\"} 123"));
        assert!(text.contains("impulse_request_latency_seconds_count{transport=\"tcp\"} 5"));
        assert!(text.contains("le=\"+Inf\"}"));
        // every non-comment line is `name[{labels}] value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad line: {line}");
        }
    }

    #[test]
    fn transport_quantiles_match_histogram_semantics() {
        let mut buckets = vec![0u64; N_LATENCY_BUCKETS];
        buckets[4] = 90;
        buckets[13] = 10;
        let t = TransportStats { transport: Transport::Tcp, count: 100, sum_us: 0, buckets };
        assert_eq!(t.quantile_us(0.5), bucket_upper_us(4));
        assert_eq!(t.quantile_us(0.99), bucket_upper_us(13));
        let empty = TransportStats {
            transport: Transport::Stdio,
            count: 0,
            sum_us: 0,
            buckets: vec![0; N_LATENCY_BUCKETS],
        };
        assert_eq!(empty.quantile_us(0.5), 0);
    }
}
