//! Per-backend counters for the proxy tier (`impulse proxy`).
//!
//! The proxy's health/failover machinery keeps its own accounting,
//! separate from the per-process [`Telemetry`] registry: the numbers
//! here describe the *fleet* (which backend is up, where requests
//! went, what was re-submitted after a death), not one engine's
//! workload counters. They are deliberately **not** part of the
//! pinned `StatsResponse` wire struct — the proxy exposes them only
//! on its Prometheus page, via the [`ExtraPage`] hook of
//! [`serve_metrics_with`].
//!
//! [`Telemetry`]: super::Telemetry
//! [`ExtraPage`]: super::ExtraPage
//! [`serve_metrics_with`]: super::serve_metrics_with

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

/// State code for a healthy backend taking new work.
pub const BACKEND_UP: u8 = 0;
/// State code for a suspect backend: finishes what it has, gets new
/// work only when every `Up` peer is worse.
pub const BACKEND_DRAINING: u8 = 1;
/// State code for a dead backend: link torn down, reconnect loop
/// running, never routed to.
pub const BACKEND_DOWN: u8 = 2;

/// One backend's cells. All plain atomics — updated from the client
/// listener, the per-link reader threads, and the health prober
/// without coordination.
struct BackendCells {
    addr: String,
    state: AtomicU8,
    in_flight: AtomicU64,
    requests: AtomicU64,
    retries: AtomicU64,
    failovers: AtomicU64,
    spills: AtomicU64,
    health_failures: AtomicU64,
    streams_lost: AtomicU64,
}

/// A point-in-time copy of one backend's cells (see
/// [`ProxyStats::snapshot`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BackendSnapshot {
    /// The backend's address as given on the command line.
    pub addr: String,
    /// Lifecycle state code ([`BACKEND_UP`] / [`BACKEND_DRAINING`] /
    /// [`BACKEND_DOWN`]).
    pub state: u8,
    /// Requests currently forwarded and awaiting a response.
    pub in_flight: u64,
    /// Requests ever forwarded to this backend (including
    /// re-submissions that landed here).
    pub requests: u64,
    /// In-flight requests this backend lost (died holding them) that
    /// were re-submitted to a peer.
    pub retries: u64,
    /// Times this backend's link died while it was not already down.
    pub failovers: u64,
    /// New requests diverted *away* from this backend because it was
    /// soft-limited or draining while a healthier peer had capacity.
    pub spills: u64,
    /// Active health probes that failed.
    pub health_failures: u64,
    /// Pinned streams whose membrane state died with this backend.
    pub streams_lost: u64,
}

/// The proxy tier's per-backend accounting (see module docs).
pub struct ProxyStats {
    backends: Vec<BackendCells>,
    /// Requests answered with `BackendLost` because no healthy
    /// backend remained (not attributable to any one backend).
    no_backend: AtomicU64,
}

impl ProxyStats {
    /// Cells for `addrs`, all starting [`BACKEND_DOWN`] with zeroed
    /// counters — backends count as up only once their link connects.
    pub fn new(addrs: &[String]) -> ProxyStats {
        ProxyStats {
            backends: addrs
                .iter()
                .map(|a| BackendCells {
                    addr: a.clone(),
                    state: AtomicU8::new(BACKEND_DOWN),
                    in_flight: AtomicU64::new(0),
                    requests: AtomicU64::new(0),
                    retries: AtomicU64::new(0),
                    failovers: AtomicU64::new(0),
                    spills: AtomicU64::new(0),
                    health_failures: AtomicU64::new(0),
                    streams_lost: AtomicU64::new(0),
                })
                .collect(),
            no_backend: AtomicU64::new(0),
        }
    }

    /// Number of backends tracked.
    pub fn len(&self) -> usize {
        self.backends.len()
    }

    /// True when no backends are tracked (never the case for a
    /// running proxy — the CLI requires at least one `--backend`).
    pub fn is_empty(&self) -> bool {
        self.backends.is_empty()
    }

    /// Swap backend `idx`'s state code, returning the previous one.
    /// The swap is the proxy's idempotence guard: concurrent death
    /// reports race here and only the first transition acts.
    pub fn set_state(&self, idx: usize, state: u8) -> u8 {
        self.backends[idx].state.swap(state, Ordering::SeqCst)
    }

    /// Backend `idx`'s current state code.
    pub fn state(&self, idx: usize) -> u8 {
        self.backends[idx].state.load(Ordering::SeqCst)
    }

    /// Move backend `idx` from `from` to `to` only if it is still in
    /// `from` — the health prober's guard against resurrecting (or
    /// demoting) a backend whose state changed under it.
    pub fn transition(&self, idx: usize, from: u8, to: u8) -> bool {
        self.backends[idx]
            .state
            .compare_exchange(from, to, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
    }

    /// Backends currently [`BACKEND_UP`].
    pub fn up_count(&self) -> usize {
        self.backends
            .iter()
            .filter(|b| b.state.load(Ordering::SeqCst) == BACKEND_UP)
            .count()
    }

    /// A request was forwarded to backend `idx`.
    pub fn record_request(&self, idx: usize) {
        self.backends[idx].requests.fetch_add(1, Ordering::Relaxed);
        self.backends[idx].in_flight.fetch_add(1, Ordering::Relaxed);
    }

    /// A forwarded request to backend `idx` completed (answered,
    /// re-submitted elsewhere, or failed).
    pub fn record_done(&self, idx: usize) {
        self.backends[idx].in_flight.fetch_sub(1, Ordering::Relaxed);
    }

    /// Requests currently in flight to backend `idx`.
    pub fn in_flight(&self, idx: usize) -> u64 {
        self.backends[idx].in_flight.load(Ordering::Relaxed)
    }

    /// Backend `idx` died holding a request that was re-submitted.
    pub fn record_retry(&self, idx: usize) {
        self.backends[idx].retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Backend `idx`'s link died (counted once per death).
    pub fn record_failover(&self, idx: usize) {
        self.backends[idx].failovers.fetch_add(1, Ordering::Relaxed);
    }

    /// A new request avoided backend `idx` (soft-limited/draining).
    pub fn record_spill(&self, idx: usize) {
        self.backends[idx].spills.fetch_add(1, Ordering::Relaxed);
    }

    /// An active health probe of backend `idx` failed.
    pub fn record_health_failure(&self, idx: usize) {
        self.backends[idx].health_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// A stream pinned to backend `idx` died with it.
    pub fn record_stream_lost(&self, idx: usize) {
        self.backends[idx].streams_lost.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was answered `BackendLost` with no healthy backend
    /// left to blame.
    pub fn record_no_backend(&self) {
        self.no_backend.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time copies of every backend's cells.
    pub fn snapshot(&self) -> Vec<BackendSnapshot> {
        self.backends
            .iter()
            .map(|b| BackendSnapshot {
                addr: b.addr.clone(),
                state: b.state.load(Ordering::SeqCst),
                in_flight: b.in_flight.load(Ordering::Relaxed),
                requests: b.requests.load(Ordering::Relaxed),
                retries: b.retries.load(Ordering::Relaxed),
                failovers: b.failovers.load(Ordering::Relaxed),
                spills: b.spills.load(Ordering::Relaxed),
                health_failures: b.health_failures.load(Ordering::Relaxed),
                streams_lost: b.streams_lost.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Render the fleet as Prometheus text (0.0.4), one labelled line
    /// per backend per metric. Appended to the proxy's metrics page
    /// after the registry pages.
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let snaps = self.snapshot();
        let mut out = String::with_capacity(1024);
        out.push_str(
            "# HELP impulse_proxy_backend_up Whether the backend is routable (1 = up, 0 = draining or down).\n\
             # TYPE impulse_proxy_backend_up gauge\n",
        );
        for s in &snaps {
            let up = if s.state == BACKEND_UP { 1 } else { 0 };
            let _ = writeln!(out, "impulse_proxy_backend_up{{backend=\"{}\"}} {}", s.addr, up);
        }
        out.push_str(
            "# HELP impulse_proxy_backend_state Lifecycle state code (0 = up, 1 = draining, 2 = down).\n\
             # TYPE impulse_proxy_backend_state gauge\n",
        );
        for s in &snaps {
            let _ =
                writeln!(out, "impulse_proxy_backend_state{{backend=\"{}\"}} {}", s.addr, s.state);
        }
        out.push_str(
            "# HELP impulse_proxy_in_flight Requests forwarded and awaiting a backend response.\n\
             # TYPE impulse_proxy_in_flight gauge\n",
        );
        for s in &snaps {
            let _ =
                writeln!(out, "impulse_proxy_in_flight{{backend=\"{}\"}} {}", s.addr, s.in_flight);
        }
        out.push_str(
            "# HELP impulse_proxy_requests_total Requests forwarded to the backend (including re-submissions that landed there).\n\
             # TYPE impulse_proxy_requests_total counter\n",
        );
        for s in &snaps {
            let _ =
                writeln!(out, "impulse_proxy_requests_total{{backend=\"{}\"}} {}", s.addr, s.requests);
        }
        out.push_str(
            "# HELP impulse_proxy_retries_total In-flight requests the backend died holding that were re-submitted to a peer.\n\
             # TYPE impulse_proxy_retries_total counter\n",
        );
        for s in &snaps {
            let _ =
                writeln!(out, "impulse_proxy_retries_total{{backend=\"{}\"}} {}", s.addr, s.retries);
        }
        out.push_str(
            "# HELP impulse_proxy_failovers_total Times the backend's link died while it held Up or Draining state.\n\
             # TYPE impulse_proxy_failovers_total counter\n",
        );
        for s in &snaps {
            let _ = writeln!(
                out,
                "impulse_proxy_failovers_total{{backend=\"{}\"}} {}",
                s.addr, s.failovers
            );
        }
        out.push_str(
            "# HELP impulse_proxy_spills_total New requests diverted away from the backend while it was soft-limited or draining.\n\
             # TYPE impulse_proxy_spills_total counter\n",
        );
        for s in &snaps {
            let _ = writeln!(out, "impulse_proxy_spills_total{{backend=\"{}\"}} {}", s.addr, s.spills);
        }
        out.push_str(
            "# HELP impulse_proxy_health_failures_total Active health probes that failed.\n\
             # TYPE impulse_proxy_health_failures_total counter\n",
        );
        for s in &snaps {
            let _ = writeln!(
                out,
                "impulse_proxy_health_failures_total{{backend=\"{}\"}} {}",
                s.addr, s.health_failures
            );
        }
        out.push_str(
            "# HELP impulse_proxy_streams_lost_total Pinned streams whose membrane state died with the backend.\n\
             # TYPE impulse_proxy_streams_lost_total counter\n",
        );
        for s in &snaps {
            let _ = writeln!(
                out,
                "impulse_proxy_streams_lost_total{{backend=\"{}\"}} {}",
                s.addr, s.streams_lost
            );
        }
        let _ = writeln!(
            out,
            "# HELP impulse_proxy_no_backend_total Requests answered BackendLost with no healthy backend left.\n\
             # TYPE impulse_proxy_no_backend_total counter\n\
             impulse_proxy_no_backend_total {}",
            self.no_backend.load(Ordering::Relaxed)
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:{}", 9000 + i)).collect()
    }

    #[test]
    fn backends_start_down_with_zeroed_counters() {
        let s = ProxyStats::new(&addrs(2));
        assert_eq!(s.len(), 2);
        assert_eq!(s.up_count(), 0);
        for b in s.snapshot() {
            assert_eq!(b.state, BACKEND_DOWN);
            assert_eq!(
                (b.in_flight, b.requests, b.retries, b.failovers, b.spills),
                (0, 0, 0, 0, 0)
            );
        }
    }

    #[test]
    fn set_state_swaps_and_reports_the_prior_state() {
        let s = ProxyStats::new(&addrs(1));
        assert_eq!(s.set_state(0, BACKEND_UP), BACKEND_DOWN);
        assert_eq!(s.up_count(), 1);
        // the swap is the idempotence guard: a second death report
        // sees Down and must not double-fire
        assert_eq!(s.set_state(0, BACKEND_DOWN), BACKEND_UP);
        assert_eq!(s.set_state(0, BACKEND_DOWN), BACKEND_DOWN);
    }

    #[test]
    fn transition_is_a_guarded_cas() {
        let s = ProxyStats::new(&addrs(1));
        assert!(s.transition(0, BACKEND_DOWN, BACKEND_UP));
        // stale transitions (wrong `from`) must not fire
        assert!(!s.transition(0, BACKEND_DOWN, BACKEND_DRAINING));
        assert_eq!(s.state(0), BACKEND_UP);
    }

    #[test]
    fn request_and_done_track_in_flight() {
        let s = ProxyStats::new(&addrs(1));
        s.record_request(0);
        s.record_request(0);
        assert_eq!(s.in_flight(0), 2);
        s.record_done(0);
        assert_eq!(s.in_flight(0), 1);
        let snap = &s.snapshot()[0];
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.in_flight, 1);
    }

    #[test]
    fn prometheus_page_labels_every_backend_and_parses_cleanly() {
        let s = ProxyStats::new(&addrs(2));
        s.set_state(0, BACKEND_UP);
        s.record_request(0);
        s.record_retry(1);
        s.record_failover(1);
        s.record_spill(1);
        s.record_no_backend();
        let page = s.to_prometheus();
        assert!(page.contains("impulse_proxy_backend_up{backend=\"127.0.0.1:9000\"} 1"), "{page}");
        assert!(page.contains("impulse_proxy_backend_up{backend=\"127.0.0.1:9001\"} 0"), "{page}");
        assert!(page.contains("impulse_proxy_requests_total{backend=\"127.0.0.1:9000\"} 1"));
        assert!(page.contains("impulse_proxy_retries_total{backend=\"127.0.0.1:9001\"} 1"));
        assert!(page.contains("impulse_proxy_failovers_total{backend=\"127.0.0.1:9001\"} 1"));
        assert!(page.contains("impulse_proxy_spills_total{backend=\"127.0.0.1:9001\"} 1"));
        assert!(page.contains("impulse_proxy_no_backend_total 1"));
        // same shape rule the registry pages follow: every sample line
        // is `name{labels} value` with no internal spaces
        for line in page.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            assert_eq!(line.split_whitespace().count(), 2, "bad sample line: {line}");
        }
    }
}
