//! The lock-free telemetry registry.
//!
//! One [`Telemetry`] instance is shared (via `Arc`) by everything on a
//! server's serve path — the coordinator's submit chokepoint, every
//! worker, the TCP responder threads, and the stdio loop. All updates
//! are single atomic adds (plus one short CAS loop for the f64 EDP
//! accumulator) on pre-allocated cells: no locks, no allocation, no
//! map lookups in-band. Reads ([`Telemetry::snapshot`]) merge the
//! cells into a [`StatsSnapshot`] without stopping writers.

use super::histogram::ShardedHistogram;
use super::snapshot::{
    instr_code, kind_code, KindStats, StatsSnapshot, Transport, TransportStats, ALL_INSTR_KINDS,
    ALL_KINDS, ALL_TRANSPORTS,
};
use crate::coordinator::{WorkloadInput, WorkloadKind};
use crate::energy::EnergyModel;
use crate::isa::InstructionKind;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Default backpressure soft limit (queued requests) when none is
/// configured: deep enough that a healthy server never trips it.
pub const DEFAULT_QUEUE_SOFT_LIMIT: u64 = 1024;

/// An `f64` accumulator over an atomic bit pattern (short CAS loop —
/// lock-free, used only for the EDP total where integer units would
/// overflow).
#[derive(Debug, Default)]
pub struct AtomicF64(AtomicU64);

impl AtomicF64 {
    /// Add `d` atomically.
    pub fn add(&self, d: f64) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
                Some((f64::from_bits(bits) + d).to_bits())
            });
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Static configuration of a [`Telemetry`] registry.
#[derive(Clone, Copy, Debug)]
pub struct TelemetryConfig {
    /// Supply voltage the energy attribution is evaluated at.
    pub vdd: f64,
    /// Clock frequency (Hz) used to turn cycles into delay for EDP.
    pub freq_hz: f64,
    /// Queue depth at which the server starts signalling backpressure
    /// (the soft-limit bit in response frame flags and in
    /// `StatsResponse`). `0` signals **unconditionally** — an
    /// operator-facing "drain me" mode for maintenance.
    pub queue_soft_limit: u64,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            vdd: crate::NOMINAL_VDD,
            freq_hz: crate::NOMINAL_FREQ_HZ,
            queue_soft_limit: DEFAULT_QUEUE_SOFT_LIMIT,
        }
    }
}

/// A point-in-time view of the streaming-session counters.
///
/// These live alongside (not inside) [`StatsSnapshot`] — the stats
/// wire struct is pinned by the frame-codec tests, so stream metrics
/// are surfaced through [`Telemetry::stream_stats`] and the Prometheus
/// scrape page instead of the `StatsResponse` payload.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StreamStats {
    /// Streams opened (monotonic).
    pub opened: u64,
    /// Streams closed by their client, or reaped when its connection
    /// ended (monotonic).
    pub closed: u64,
    /// Streams evicted by the idle-TTL sweep (monotonic).
    pub expired: u64,
    /// Opens rejected by the max-streams cap (monotonic).
    pub rejected: u64,
    /// Chunks appended across all streams (monotonic).
    pub appends: u64,
    /// Streams currently pinning a lane's membrane state (gauge).
    pub active: u64,
}

impl StreamStats {
    /// Prometheus text-format lines for these counters (each sample
    /// line is exactly `name value`, matching the scrape page format).
    pub fn to_prometheus(&self) -> String {
        format!(
            "# HELP impulse_streams_active Streams currently pinning a lane's membrane state.\n\
             # TYPE impulse_streams_active gauge\n\
             impulse_streams_active {}\n\
             # TYPE impulse_streams_opened_total counter\n\
             impulse_streams_opened_total {}\n\
             # TYPE impulse_streams_closed_total counter\n\
             impulse_streams_closed_total {}\n\
             # HELP impulse_streams_expired_total Streams evicted by the idle-TTL sweep.\n\
             # TYPE impulse_streams_expired_total counter\n\
             impulse_streams_expired_total {}\n\
             # HELP impulse_streams_rejected_total Opens rejected by the max-streams cap.\n\
             # TYPE impulse_streams_rejected_total counter\n\
             impulse_streams_rejected_total {}\n\
             # TYPE impulse_stream_appends_total counter\n\
             impulse_stream_appends_total {}\n\
             # HELP impulse_streams_evicted_reason Streams lost to pressure, by reason: \
             ttl = idle sessions evicted by the TTL sweep, cap = opens rejected at the \
             max-streams cap.\n\
             # TYPE impulse_streams_evicted_reason counter\n\
             impulse_streams_evicted_reason{{reason=\"ttl\"}} {}\n\
             impulse_streams_evicted_reason{{reason=\"cap\"}} {}\n",
            self.active,
            self.opened,
            self.closed,
            self.expired,
            self.rejected,
            self.appends,
            self.expired,
            self.rejected,
        )
    }
}

/// Atomic cells behind [`StreamStats`].
#[derive(Debug, Default)]
struct StreamCells {
    opened: AtomicU64,
    closed: AtomicU64,
    expired: AtomicU64,
    rejected: AtomicU64,
    appends: AtomicU64,
    active: AtomicU64,
}

/// Per-workload-kind atomic counter cell.
#[derive(Debug, Default)]
struct KindCell {
    submitted: AtomicU64,
    ok: AtomicU64,
    err: AtomicU64,
    cycles: AtomicU64,
    energy_fj: AtomicU64,
    edp_js: AtomicF64,
    input_units: AtomicU64,
    input_active: AtomicU64,
}

/// The registry every serve-path component updates in-band.
///
/// Counter semantics (all monotonic except the depth gauge):
///
/// - **per kind** — submissions, ok/err responses, attributed cycles,
///   attributed energy (fJ) and EDP (J·s), input units/active units;
/// - **queue depth** — submitted minus answered (a gauge; drives the
///   backpressure flags word);
/// - **batches** — micro-batch count, occupied fused lanes, and the
///   lane capacity that was available;
/// - **instructions** — per-[`InstructionKind`] issue counts sampled
///   from the worker pools' macro counters;
/// - **per transport** — server-side latency histograms recorded at
///   response delivery.
pub struct Telemetry {
    cfg: TelemetryConfig,
    /// Per-instruction energy (J) at `cfg.vdd`, indexed by wire code —
    /// precomputed so recording never touches the energy model.
    instr_energy_j: [f64; ALL_INSTR_KINDS.len()],
    kinds: [KindCell; ALL_KINDS.len()],
    depth: AtomicU64,
    batches: AtomicU64,
    batch_lanes: AtomicU64,
    batch_lane_capacity: AtomicU64,
    instr: [AtomicU64; ALL_INSTR_KINDS.len()],
    wire: [ShardedHistogram; ALL_TRANSPORTS.len()],
    streams: StreamCells,
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Telemetry")
            .field("cfg", &self.cfg)
            .field("queue_depth", &self.queue_depth())
            .finish_non_exhaustive()
    }
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::new(TelemetryConfig::default())
    }
}

impl Telemetry {
    /// A zeroed registry attributing energy at the configured
    /// operating point (calibrates the energy model once, up front).
    pub fn new(cfg: TelemetryConfig) -> Telemetry {
        let model = EnergyModel::calibrated();
        let instr_energy_j =
            std::array::from_fn(|i| model.instr_energy_j(ALL_INSTR_KINDS[i], cfg.vdd));
        Telemetry {
            cfg,
            instr_energy_j,
            kinds: std::array::from_fn(|_| KindCell::default()),
            depth: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_lanes: AtomicU64::new(0),
            batch_lane_capacity: AtomicU64::new(0),
            instr: std::array::from_fn(|_| AtomicU64::new(0)),
            wire: std::array::from_fn(|_| ShardedHistogram::new()),
            streams: StreamCells::default(),
        }
    }

    /// The configuration this registry was built with.
    pub fn config(&self) -> &TelemetryConfig {
        &self.cfg
    }

    fn cell(&self, kind: WorkloadKind) -> &KindCell {
        &self.kinds[kind_code(kind) as usize]
    }

    /// Record a request accepted into the queue (the coordinator's
    /// submit chokepoint calls this — every transport funnels through
    /// it exactly once per request, *before* the enqueue, so a fast
    /// worker can never decrement the depth gauge ahead of it).
    pub fn record_submit(&self, kind: WorkloadKind) {
        self.cell(kind).submitted.fetch_add(1, Ordering::Relaxed);
        self.depth.fetch_add(1, Ordering::Relaxed);
    }

    /// Roll back a [`Telemetry::record_submit`] whose enqueue failed
    /// (server shutting down): the request never entered the queue and
    /// will never produce a response.
    pub fn record_submit_rejected(&self, kind: WorkloadKind) {
        let c = &self.cell(kind).submitted;
        let _ = c.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(1))
        });
        let _ = self
            .depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| Some(d.saturating_sub(1)));
    }

    /// Record one published response: outcome, attributed cycles, and
    /// attributed energy (femtojoules; EDP is derived here from the
    /// configured clock). Decrements the queue-depth gauge.
    pub fn record_response(&self, kind: WorkloadKind, cycles: u64, energy_fj: u64, ok: bool) {
        let c = self.cell(kind);
        if ok {
            c.ok.fetch_add(1, Ordering::Relaxed);
        } else {
            c.err.fetch_add(1, Ordering::Relaxed);
        }
        c.cycles.fetch_add(cycles, Ordering::Relaxed);
        c.energy_fj.fetch_add(energy_fj, Ordering::Relaxed);
        if cycles > 0 && energy_fj > 0 {
            let delay_s = cycles as f64 / self.cfg.freq_hz;
            c.edp_js.add(energy_fj as f64 * 1e-15 * delay_s);
        }
        // saturating decrement: a response must never wrap the gauge
        // even if its submission predates this registry
        let _ = self
            .depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| Some(d.saturating_sub(1)));
    }

    /// Record the observed input of a request: total units and active
    /// (spiking-relevant) units — non-padding word ids for sentiment,
    /// nonzero pixels for digits. Counts come from
    /// [`WorkloadInput::unit_counts`], which word-packs and popcounts
    /// the image path's nonzero flags (`SpikePlane::count_flags`)
    /// rather than branch-counting booleans on every submit.
    pub fn record_input(&self, input: &WorkloadInput) {
        let (units, active) = input.unit_counts();
        self.record_input_counts(input.kind(), units, active);
    }

    /// Record precomputed input-sparsity counts (e.g. from a decode
    /// path that already holds a packed plane).
    pub fn record_input_counts(&self, kind: WorkloadKind, units: u64, active: u64) {
        let c = self.cell(kind);
        c.input_units.fetch_add(units, Ordering::Relaxed);
        c.input_active.fetch_add(active, Ordering::Relaxed);
    }

    /// Record one executed micro-batch: occupied fused lanes and the
    /// lane capacity the worker had available.
    pub fn record_batch(&self, lanes: u64, capacity: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_lanes.fetch_add(lanes, Ordering::Relaxed);
        self.batch_lane_capacity.fetch_add(capacity.max(lanes), Ordering::Relaxed);
    }

    /// Fold a worker's instruction-histogram delta into the issue
    /// counters.
    pub fn record_instr(&self, hist: &BTreeMap<InstructionKind, u64>) {
        for (&k, &n) in hist {
            self.instr[instr_code(k) as usize].fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Total energy (J) of an instruction histogram at the configured
    /// supply — the attribution the serve path splits across a fused
    /// batch's requests in proportion to their cycles.
    pub fn energy_of(&self, hist: &BTreeMap<InstructionKind, u64>) -> f64 {
        hist.iter()
            .map(|(&k, &n)| self.instr_energy_j[instr_code(k) as usize] * n as f64)
            .sum()
    }

    /// Record a delivered response's server-side latency on its
    /// transport.
    pub fn record_wire(&self, transport: Transport, latency: Duration) {
        self.wire[transport.code() as usize].record(latency);
    }

    /// Record a stream session claiming a lane (raises the active
    /// gauge).
    pub fn record_stream_open(&self) {
        self.streams.opened.fetch_add(1, Ordering::Relaxed);
        self.streams.active.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a stream released by its client (or reaped because its
    /// connection ended).
    pub fn record_stream_closed(&self) {
        self.streams.closed.fetch_add(1, Ordering::Relaxed);
        self.stream_gauge_down();
    }

    /// Record a stream evicted by the idle-TTL sweep.
    pub fn record_stream_expired(&self) {
        self.streams.expired.fetch_add(1, Ordering::Relaxed);
        self.stream_gauge_down();
    }

    /// Record a stream open rejected by the max-streams cap (the
    /// active gauge is untouched — no lane was claimed).
    pub fn record_stream_rejected(&self) {
        self.streams.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one chunk appended to a live stream.
    pub fn record_stream_append(&self) {
        self.streams.appends.fetch_add(1, Ordering::Relaxed);
    }

    // saturating decrement: mirrors the queue-depth gauge so a stray
    // release can never wrap the active count
    fn stream_gauge_down(&self) {
        let _ = self
            .streams
            .active
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
    }

    /// Current streaming-session counters.
    pub fn stream_stats(&self) -> StreamStats {
        StreamStats {
            opened: self.streams.opened.load(Ordering::Relaxed),
            closed: self.streams.closed.load(Ordering::Relaxed),
            expired: self.streams.expired.load(Ordering::Relaxed),
            rejected: self.streams.rejected.load(Ordering::Relaxed),
            appends: self.streams.appends.load(Ordering::Relaxed),
            active: self.streams.active.load(Ordering::Relaxed),
        }
    }

    /// Current queue depth (submitted minus answered).
    pub fn queue_depth(&self) -> u64 {
        self.depth.load(Ordering::Relaxed)
    }

    /// Whether backpressure is currently signalled: queue depth at or
    /// over the soft limit (a limit of 0 signals unconditionally).
    pub fn soft_limited(&self) -> bool {
        self.queue_depth() >= self.cfg.queue_soft_limit
    }

    /// Merge every cell into a plain snapshot (writers keep going;
    /// totals are exact for everything recorded-before the call).
    pub fn snapshot(&self) -> StatsSnapshot {
        let kinds = ALL_KINDS
            .iter()
            .map(|&k| {
                let c = self.cell(k);
                KindStats {
                    kind: k,
                    submitted: c.submitted.load(Ordering::Relaxed),
                    ok: c.ok.load(Ordering::Relaxed),
                    err: c.err.load(Ordering::Relaxed),
                    cycles: c.cycles.load(Ordering::Relaxed),
                    energy_fj: c.energy_fj.load(Ordering::Relaxed),
                    edp_js: c.edp_js.get(),
                    input_units: c.input_units.load(Ordering::Relaxed),
                    input_active: c.input_active.load(Ordering::Relaxed),
                }
            })
            .collect();
        let instr = ALL_INSTR_KINDS
            .iter()
            .enumerate()
            .map(|(i, &k)| (instr_code(k), self.instr[i].load(Ordering::Relaxed)))
            .collect();
        let transports = ALL_TRANSPORTS
            .iter()
            .map(|&t| {
                let m = self.wire[t.code() as usize].merge();
                TransportStats {
                    transport: t,
                    count: m.count,
                    sum_us: m.sum_us,
                    buckets: m.buckets.to_vec(),
                }
            })
            .collect();
        StatsSnapshot {
            queue_depth: self.queue_depth(),
            queue_soft_limit: self.cfg.queue_soft_limit,
            soft_limited: self.soft_limited(),
            batches: self.batches.load(Ordering::Relaxed),
            batch_lanes: self.batch_lanes.load(Ordering::Relaxed),
            batch_lane_capacity: self.batch_lane_capacity.load(Ordering::Relaxed),
            kinds,
            instr,
            transports,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_and_response_drive_the_depth_gauge() {
        let t = Telemetry::new(TelemetryConfig { queue_soft_limit: 2, ..Default::default() });
        assert_eq!(t.queue_depth(), 0);
        assert!(!t.soft_limited());
        t.record_submit(WorkloadKind::Sentiment);
        t.record_submit(WorkloadKind::Sentiment);
        assert_eq!(t.queue_depth(), 2);
        assert!(t.soft_limited());
        t.record_response(WorkloadKind::Sentiment, 100, 50, true);
        assert_eq!(t.queue_depth(), 1);
        assert!(!t.soft_limited());
        // extra responses saturate at zero instead of wrapping
        t.record_response(WorkloadKind::Sentiment, 0, 0, false);
        t.record_response(WorkloadKind::Sentiment, 0, 0, false);
        assert_eq!(t.queue_depth(), 0);

        let s = t.snapshot();
        let k = s.kind(WorkloadKind::Sentiment).unwrap();
        assert_eq!((k.submitted, k.ok, k.err), (2, 1, 2));
        assert_eq!(k.cycles, 100);
        assert_eq!(k.energy_fj, 50);
        assert!(k.edp_js > 0.0);
    }

    #[test]
    fn soft_limit_zero_signals_unconditionally() {
        let t = Telemetry::new(TelemetryConfig { queue_soft_limit: 0, ..Default::default() });
        assert!(t.soft_limited());
    }

    #[test]
    fn input_observation_tracks_sparsity_per_kind() {
        let t = Telemetry::default();
        t.record_input(&WorkloadInput::Words(vec![3, -1, 7, -1]));
        t.record_input(&WorkloadInput::Image {
            h: 2,
            w: 2,
            pixels: vec![0.0, 0.5, 0.0, 0.0],
        });
        let s = t.snapshot();
        let w = s.kind(WorkloadKind::Sentiment).unwrap();
        assert_eq!((w.input_units, w.input_active), (4, 2));
        let d = s.kind(WorkloadKind::Digits).unwrap();
        assert_eq!((d.input_units, d.input_active), (4, 1));
        assert!((d.input_sparsity() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn instruction_histograms_fold_into_energy() {
        let t = Telemetry::default();
        let mut h = BTreeMap::new();
        h.insert(InstructionKind::AccW2V, 10u64);
        h.insert(InstructionKind::SpikeCheck, 2u64);
        let e = t.energy_of(&h);
        // point D: 10 × 1.0101 pJ + 2 × 0.8197 pJ (energy/model.rs)
        assert!((e * 1e12 - (10.0 * 1.0101 + 2.0 * 0.8197)).abs() < 0.05, "{e}");
        t.record_instr(&h);
        let s = t.snapshot();
        assert_eq!(s.instr_count(InstructionKind::AccW2V), 10);
        assert_eq!(s.instr_count(InstructionKind::SpikeCheck), 2);
        assert_eq!(s.instr_count(InstructionKind::WriteW), 0);
    }

    #[test]
    fn batches_and_wire_latency_accumulate() {
        let t = Telemetry::default();
        t.record_batch(3, 13);
        t.record_batch(1, 13);
        t.record_wire(Transport::Tcp, Duration::from_micros(500));
        t.record_wire(Transport::Stdio, Duration::from_micros(9));
        let s = t.snapshot();
        assert_eq!((s.batches, s.batch_lanes, s.batch_lane_capacity), (2, 4, 26));
        assert_eq!(s.mean_batch_occupancy(), 2.0);
        assert_eq!(s.transport(Transport::Tcp).unwrap().count, 1);
        assert_eq!(s.transport(Transport::Stdio).unwrap().sum_us, 9);
    }

    #[test]
    fn stream_counters_drive_the_active_gauge() {
        let t = Telemetry::default();
        assert_eq!(t.stream_stats(), StreamStats::default());
        t.record_stream_open();
        t.record_stream_open();
        t.record_stream_append();
        t.record_stream_append();
        t.record_stream_append();
        t.record_stream_rejected();
        t.record_stream_closed();
        t.record_stream_expired();
        let s = t.stream_stats();
        assert_eq!((s.opened, s.closed, s.expired), (2, 1, 1));
        assert_eq!((s.rejected, s.appends, s.active), (1, 3, 0));
        // extra releases saturate at zero instead of wrapping
        t.record_stream_closed();
        assert_eq!(t.stream_stats().active, 0);

        let page = s.to_prometheus();
        assert!(page.contains("impulse_streams_opened_total 2"), "{page}");
        assert!(page.contains("impulse_streams_active 0"), "{page}");
        for line in page.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad sample line: {line}");
        }
    }

    #[test]
    fn atomic_f64_accumulates_concurrently() {
        let a = std::sync::Arc::new(AtomicF64::default());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let a = std::sync::Arc::clone(&a);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        a.add(0.5);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(a.get(), 2000.0);
    }
}
