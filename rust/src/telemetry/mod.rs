//! Live serving telemetry (the always-on counterpart of [`metrics`]).
//!
//! The paper's headline claims are *operational* — 0.99 TOPS/W, a
//! 97.4 % EDP reduction at 85 % input sparsity — so the serving stack
//! must be able to report them while it runs, not only in offline
//! reports. This subsystem is the in-band accounting path:
//!
//! - [`registry`] — the lock-free [`Telemetry`] registry every worker,
//!   session, and batcher updates with plain atomic adds: requests and
//!   responses per workload kind, attributed cycles/energy/EDP
//!   (through the calibrated [`EnergyModel`] tables), observed input
//!   sparsity, instruction-issue counters (AccW2V ∝ spikes — the
//!   macro's energy-proportionality signal), queue depth, and
//!   batch-lane occupancy.
//! - [`histogram`] — sharded, cache-line-aligned latency histograms
//!   (per transport: TCP framing vs the stdio loop).
//! - [`snapshot`] — the plain [`StatsSnapshot`] view, its stable wire
//!   codes, and the Prometheus text rendering.
//! - [`expose`] — the `--metrics-listen` plaintext exposition
//!   endpoint ([`serve_metrics`]), dependency-free.
//!
//! The same snapshot travels three ways: the `StatsRequest` (`0x14`) /
//! `StatsResponse` (`0x15`) frames of `docs/PROTOCOL.md` (served by
//! the TCP listener, fetched by `impulse stats <addr>`), the
//! Prometheus endpoint, and the backpressure flags word the listener
//! stamps on response frames (queue depth + soft-limit bit) for
//! clients that negotiated the capability.
//!
//! [`metrics`]: crate::metrics
//! [`EnergyModel`]: crate::energy::EnergyModel

#![warn(missing_docs)]

pub mod expose;
pub mod histogram;
pub mod proxy;
pub mod registry;
pub mod snapshot;

pub use expose::{serve_metrics, serve_metrics_with, ExtraPage, MetricsHandle};
pub use proxy::{
    BackendSnapshot, ProxyStats, BACKEND_DOWN, BACKEND_DRAINING, BACKEND_UP,
};
pub use histogram::{
    bucket_index, bucket_upper_us, HistogramSummary, ShardedHistogram, N_LATENCY_BUCKETS,
};
pub use registry::{AtomicF64, StreamStats, Telemetry, TelemetryConfig, DEFAULT_QUEUE_SOFT_LIMIT};
pub use snapshot::{
    instr_code, instr_from_code, instr_name, kind_code, kind_from_code, kind_name, KindStats,
    StatsSnapshot, Transport, TransportStats, ALL_INSTR_KINDS, ALL_KINDS, ALL_TRANSPORTS,
    STATS_VERSION,
};
