//! Run metrics: counters, latency histograms, and report emission.

use std::collections::BTreeMap;
use std::time::Duration;

/// A set of named monotonically-increasing counters.
#[derive(Clone, Debug, Default)]
pub struct Counters {
    values: BTreeMap<String, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, delta: u64) {
        *self.values.entry(name.to_string()).or_insert(0) += delta;
    }

    pub fn get(&self, name: &str) -> u64 {
        self.values.get(name).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.values.iter().map(|(k, &v)| (k.as_str(), v))
    }

    pub fn merge(&mut self, other: &Counters) {
        for (k, v) in &other.values {
            *self.values.entry(k.clone()).or_insert(0) += v;
        }
    }
}

/// Streaming latency statistics (count/mean/min/max + fixed quantile
/// estimates from a reservoir).
#[derive(Clone, Debug)]
pub struct LatencyStats {
    count: u64,
    sum: Duration,
    min: Duration,
    max: Duration,
    reservoir: Vec<Duration>,
    cap: usize,
    rng_state: u64,
}

impl Default for LatencyStats {
    fn default() -> Self {
        Self::new(4096)
    }
}

impl LatencyStats {
    pub fn new(cap: usize) -> Self {
        Self {
            count: 0,
            sum: Duration::ZERO,
            min: Duration::MAX,
            max: Duration::ZERO,
            reservoir: Vec::with_capacity(cap.min(1024)),
            cap,
            rng_state: 0x12345678,
        }
    }

    pub fn record(&mut self, d: Duration) {
        self.count += 1;
        self.sum += d;
        self.min = self.min.min(d);
        self.max = self.max.max(d);
        if self.reservoir.len() < self.cap {
            self.reservoir.push(d);
        } else {
            // reservoir sampling
            self.rng_state ^= self.rng_state << 13;
            self.rng_state ^= self.rng_state >> 7;
            self.rng_state ^= self.rng_state << 17;
            let j = (self.rng_state % self.count) as usize;
            if j < self.cap {
                self.reservoir[j] = d;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        self.sum / self.count as u32
    }

    pub fn min(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            self.min
        }
    }

    pub fn max(&self) -> Duration {
        self.max
    }

    /// Quantile estimate from the reservoir (q in [0, 1]).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.reservoir.is_empty() {
            return Duration::ZERO;
        }
        let mut v = self.reservoir.clone();
        v.sort();
        let ix = ((v.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        v[ix]
    }

    pub fn report(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:?} p50={:?} p99={:?} min={:?} max={:?}",
            self.count,
            self.mean(),
            self.quantile(0.5),
            self.quantile(0.99),
            self.min(),
            self.max()
        )
    }
}

/// Split an integer `total` across `weights` proportionally, exactly
/// (largest-remainder / Hamilton rounding): the returned shares sum to
/// `total`, each within one unit of its exact quota. Used by the
/// batched serve path to attribute a fused chunk's cycle spend to the
/// requests that caused it. Non-positive or all-zero weights fall back
/// to an even split.
pub fn apportion(weights: &[f64], total: u64) -> Vec<u64> {
    if weights.is_empty() {
        return Vec::new();
    }
    let sum: f64 = weights.iter().filter(|w| w.is_finite() && **w > 0.0).sum();
    let quotas: Vec<f64> = if sum > 0.0 {
        weights
            .iter()
            .map(|&w| {
                if w.is_finite() && w > 0.0 {
                    w / sum * total as f64
                } else {
                    0.0
                }
            })
            .collect()
    } else {
        vec![total as f64 / weights.len() as f64; weights.len()]
    };
    let mut out: Vec<u64> = quotas.iter().map(|&q| q.floor() as u64).collect();
    let assigned: u64 = out.iter().sum();
    let mut order: Vec<usize> = (0..weights.len()).collect();
    // largest fractional part first; index breaks ties deterministically
    order.sort_by(|&a, &b| {
        let fa = quotas[a] - quotas[a].floor();
        let fb = quotas[b] - quotas[b].floor();
        fb.partial_cmp(&fa).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let mut left = total.saturating_sub(assigned);
    let mut k = 0usize;
    while left > 0 && k < order.len() * 2 {
        out[order[k % order.len()]] += 1;
        left -= 1;
        k += 1;
    }
    // floating-point pathologies aside, `left` is 0 here; dump any
    // residue on the largest-remainder index so the sum stays exact
    if left > 0 {
        out[order[0]] += left;
    }
    out
}

/// Format a float with engineering notation for reports.
pub fn eng(value: f64, unit: &str) -> String {
    let (scale, prefix) = if value == 0.0 {
        (1.0, "")
    } else {
        let exp = value.abs().log10().floor() as i32;
        match exp {
            e if e >= 12 => (1e12, "T"),
            e if e >= 9 => (1e9, "G"),
            e if e >= 6 => (1e6, "M"),
            e if e >= 3 => (1e3, "k"),
            e if e >= 0 => (1.0, ""),
            e if e >= -3 => (1e-3, "m"),
            e if e >= -6 => (1e-6, "µ"),
            e if e >= -9 => (1e-9, "n"),
            _ => (1e-12, "p"),
        }
    };
    format!("{:.3} {}{}", value / scale, prefix, unit)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_merge() {
        let mut a = Counters::new();
        a.add("x", 2);
        a.add("x", 3);
        let mut b = Counters::new();
        b.add("x", 5);
        b.add("y", 1);
        a.merge(&b);
        assert_eq!(a.get("x"), 10);
        assert_eq!(a.get("y"), 1);
        assert_eq!(a.get("z"), 0);
        assert_eq!(a.iter().count(), 2);
    }

    #[test]
    fn latency_stats_quantiles() {
        let mut s = LatencyStats::new(1000);
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i));
        }
        assert_eq!(s.count(), 100);
        assert_eq!(s.min(), Duration::from_micros(1));
        assert_eq!(s.max(), Duration::from_micros(100));
        let p50 = s.quantile(0.5);
        assert!(p50 >= Duration::from_micros(45) && p50 <= Duration::from_micros(55));
        assert!(s.report("t").contains("n=100"));
    }

    #[test]
    fn latency_reservoir_overflow_safe() {
        let mut s = LatencyStats::new(16);
        for i in 0..10_000u64 {
            s.record(Duration::from_nanos(i % 1000));
        }
        assert_eq!(s.count(), 10_000);
        assert!(s.quantile(0.9) <= Duration::from_nanos(1000));
    }

    #[test]
    fn apportion_conserves_and_is_proportional() {
        let shares = apportion(&[1.0, 1.0, 2.0], 8);
        assert_eq!(shares.iter().sum::<u64>(), 8);
        assert_eq!(shares, vec![2, 2, 4]);

        // fractional quotas: sum still exact, each within 1 of quota
        let w = [3.3, 1.1, 2.2, 0.4];
        let total = 1001u64;
        let shares = apportion(&w, total);
        assert_eq!(shares.iter().sum::<u64>(), total);
        let sum: f64 = w.iter().sum();
        for (i, &s) in shares.iter().enumerate() {
            let quota = w[i] / sum * total as f64;
            assert!((s as f64 - quota).abs() < 1.0 + 1e-9, "share {i}: {s} vs {quota}");
        }
    }

    #[test]
    fn apportion_zero_weight_lanes_get_nothing() {
        let shares = apportion(&[5.0, 0.0, 0.0], 7);
        assert_eq!(shares, vec![7, 0, 0]);
    }

    /// An empty reservoir must yield zero quantiles (and a sane
    /// report), not a panic or an out-of-bounds index.
    #[test]
    fn quantile_on_empty_reservoir_is_zero() {
        let s = LatencyStats::new(64);
        assert_eq!(s.count(), 0);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(s.quantile(q), Duration::ZERO, "q={q}");
        }
        assert_eq!(s.mean(), Duration::ZERO);
        assert_eq!(s.min(), Duration::ZERO);
        assert!(s.report("empty").contains("n=0"));
        // a zero-capacity reservoir never holds samples but must keep
        // counting and stay quantile-safe
        let mut z = LatencyStats::new(0);
        z.record(Duration::from_micros(7));
        assert_eq!(z.count(), 1);
        assert_eq!(z.quantile(0.5), Duration::ZERO);
    }

    /// All-zero weights with a total that does not divide evenly: the
    /// even-split fallback must still conserve the total exactly, with
    /// shares within one unit of each other.
    #[test]
    fn apportion_all_zero_weights_conserves_uneven_totals() {
        for (n, total) in [(3usize, 10u64), (7, 11), (4, 1), (5, 0)] {
            let shares = apportion(&vec![0.0; n], total);
            assert_eq!(shares.iter().sum::<u64>(), total, "n={n} total={total}");
            let lo = *shares.iter().min().unwrap();
            let hi = *shares.iter().max().unwrap();
            assert!(hi - lo <= 1, "even split must stay within 1: {shares:?}");
        }
    }

    /// Totals far larger than the weight sum (the femtojoule-scale
    /// energy splits telemetry performs): rounding must stay exact and
    /// proportional even when each quota has a huge integer part.
    #[test]
    fn apportion_total_much_larger_than_weight_sum() {
        let w = [1e-9, 2e-9, 3e-9];
        let total = 1_000_000_007u64; // prime: every quota is fractional
        let shares = apportion(&w, total);
        assert_eq!(shares.iter().sum::<u64>(), total);
        for (i, &s) in shares.iter().enumerate() {
            let quota = w[i] / 6e-9 * total as f64;
            assert!((s as f64 - quota).abs() <= 1.0 + 1e-6, "share {i}: {s} vs {quota}");
        }
        // one tiny weight among zeros still takes the whole total
        assert_eq!(apportion(&[0.0, 1e-300], 42), vec![0, 42]);
    }

    #[test]
    fn apportion_degenerate_inputs() {
        assert_eq!(apportion(&[], 10), Vec::<u64>::new());
        // all-zero weights fall back to an even split, still exact
        let shares = apportion(&[0.0, 0.0, 0.0], 10);
        assert_eq!(shares.iter().sum::<u64>(), 10);
        assert!(shares.iter().all(|&s| s >= 3));
        assert_eq!(apportion(&[1.0], 0), vec![0]);
        // negative/NaN weights are treated as zero
        let shares = apportion(&[f64::NAN, -3.0, 2.0], 4);
        assert_eq!(shares, vec![0, 0, 4]);
    }

    #[test]
    fn eng_formatting() {
        assert_eq!(eng(1.0101e-12, "J"), "1.010 pJ");
        assert_eq!(eng(0.99e12, "OPS/W"), "990.000 GOPS/W");
        assert_eq!(eng(1.2e12, "OPS/W"), "1.200 TOPS/W");
        assert_eq!(eng(200e6, "Hz"), "200.000 MHz");
        assert_eq!(eng(0.0, "x"), "0.000 x");
    }
}
