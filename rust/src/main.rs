//! `impulse` — the Layer-3 coordinator binary.
//!
//! Self-contained after `make artifacts`: loads the AOT-compiled model
//! bundle and runs inference, reports, sweeps, and a line-oriented
//! serve mode, all on the macro simulator. Python is never on this
//! path.
//!
//! Subcommands:
//!   report   --fig {2|6|7|8|9a|11b} | --table 1   regenerate paper artifacts
//!   check    [--model M] [--json]                 static-validate the built-in
//!                                                 ISA streams (docs/VALIDATION.md)
//!   infer    --text "w1 w2 …" | --sample N        classify via the macro pool
//!            [--stream [--addr ADDR]]             …or word-by-word over a
//!                                                 pinned streaming session
//!   eval     [--max N] [--xla-check]              full test-set evaluation
//!   bench    [--json PATH] [--quick]              perf sweeps → BENCH_PR6.json
//!   serve    [--listen ADDR | --stdio]            binary-framed TCP server
//!            [--workers N] [--batch B]            (docs/PROTOCOL.md) or the
//!            [--batch-deadline-us U]              stdin/stdout line loop
//!            [--adaptive] [--pipeline]
//!            [--metrics-listen ADDR]              Prometheus text endpoint
//!            [--queue-soft-limit N]               backpressure threshold
//!            [--record DIR] [--synthetic SEED]    deterministic capture mode
//!   proxy    --listen ADDR --backend ADDR…        fault-tolerant front tier:
//!            [--metrics-listen ADDR]              health-checked routing,
//!            [--retry-max N]                      failover with re-submission
//!                                                 (docs/PROXY.md)
//!   replay   DIR [--engine fast|bit|lockstep]     re-execute a capture, diff
//!                                                 frames + V-digests
//!   loadgen  SCENARIO --addr ADDR                 scripted load + envelope
//!                                                 assertions via telemetry
//!   trace    DIR [--slowest N] [--json]           summarize a --trace-dir
//!                                                 span export offline
//!   stats    ADDR                                 live telemetry of a server
//!   shmoo                                         print the Fig 8 grid
//!   sweep    [--neuron rmp|if|lif]                EDP vs sparsity (Fig 11b)
//!   info                                          artifact + model summary

mod cli;

use impulse::Result;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.is_empty() { &[] } else { &args[1..] };
    match cmd {
        "report" => cli::report::run(rest),
        "infer" => cli::infer::run(rest),
        "eval" => cli::eval::run(rest),
        "bench" => cli::bench::run(rest),
        "check" => cli::check::run(rest),
        "serve" => cli::serve::run(rest),
        "proxy" => cli::proxy::run(rest),
        "replay" => cli::replay::run(rest),
        "loadgen" => cli::loadgen::run(rest),
        "stats" => cli::stats::run(rest),
        "shmoo" => cli::report::shmoo(),
        "sweep" => cli::report::sweep(rest),
        "trace" => cli::trace::run(rest),
        "trace-vmem" => cli::infer::trace_vmem(rest),
        "info" => cli::info::run(),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print!("{}", HELP);
            std::process::exit(2);
        }
    }
}

const HELP: &str = r#"impulse — IMPULSE CIM-macro SNN coordinator (SSCL'21 reproduction)

USAGE:
    impulse <COMMAND> [OPTIONS]

COMMANDS:
    report --fig {2|6|7|8|9a|11b}   regenerate a paper figure's data
    report --table 1                regenerate Table I
    infer --sample N                classify test review N
    infer --words "id id id"        classify a word-id sequence
    infer --stream [--addr ADDR]    stream the review word-by-word over a
                                    session-pinned membrane (StreamOpen/
                                    StreamAppend frames; ephemeral local
                                    server unless --addr targets a running
                                    impulse serve --listen)
    check [--model sentiment|digits|all] [--timesteps T] [--seed S]
          [--json]                  statically validate the built-in ISA
                                    streams (neuron sequences + one tile
                                    schedule per network layer) with the
                                    shared structural + dataflow linter
                                    (docs/VALIDATION.md); exits nonzero
                                    on any Error-severity diagnostic
    eval [--max N] [--xla-check]    evaluate the test set on the macro pool
    bench [--json PATH] [--quick]   macro-throughput + sparsity + streaming
                                    sweeps; --json writes machine-readable
                                    results (req/s, cycles/req, ns/op,
                                    streams/s, git rev) for the perf
                                    trajectory (BENCH_PR6.json)
    eval digits [--max N] [--batch B] [--adaptive]
                                    evaluate the digits conv network on
                                    fused batch lanes (the workload-
                                    generic server path)
    serve [--listen ADDR | --stdio] [--model sentiment|digits]
          [--workers N] [--batch B]
          [--batch-deadline-us U] [--adaptive] [--pipeline]
          [--metrics-listen ADDR] [--queue-soft-limit N]
          [--max-streams N] [--stream-ttl-s S]
                                    inference server: --listen serves the
                                    length-prefixed binary frame protocol
                                    (docs/PROTOCOL.md) to concurrent TCP
                                    clients and drains cleanly on SIGINT/
                                    SIGTERM; --stdio (default) keeps the
                                    line loop. --batch fuses up to B
                                    requests into one instruction stream
                                    per tile; --adaptive sizes batches
                                    from queue depth instead; --model
                                    digits serves 28×28 image payloads.
                                    --metrics-listen exposes live
                                    telemetry as Prometheus text;
                                    --queue-soft-limit sets the depth at
                                    which responses advertise
                                    backpressure (0 = always, for drains);
                                    --max-streams caps concurrent pinned
                                    streaming sessions, --stream-ttl-s
                                    their idle eviction time.
                                    --record DIR taps every connection's
                                    wire traffic + per-request V_MEM
                                    digests into DIR/capture.imp1cap
                                    (forces 1 worker, no batching);
                                    --synthetic SEED serves the
                                    deterministic synthetic bundle
                                    instead of compiled artifacts;
                                    --engine overrides the execution
                                    engine (fast|bit|lockstep);
                                    --trace-dir DIR records per-request
                                    lifecycle spans as Chrome trace JSON
                                    rotations (docs/OBSERVABILITY.md);
                                    --log-level error|warn|info|debug
                                    sets stderr log verbosity (also
                                    IMPULSE_LOG)
    proxy --listen ADDR --backend ADDR [--backend ADDR…]
          [--metrics-listen ADDR] [--health-interval-ms MS]
          [--health-timeout-ms MS] [--retry-max N]
          [--request-deadline-ms MS] [--reconnect-base-ms MS]
          [--trace-dir DIR] [--log-level L]
                                    fault-tolerant front tier over a
                                    backend fleet (docs/PROXY.md):
                                    least-loaded routing with health
                                    probes every --health-interval-ms;
                                    streaming sessions pin to one
                                    backend for their life; when a
                                    backend dies, in-flight idempotent
                                    requests re-submit to a survivor
                                    (up to --retry-max, within
                                    --request-deadline-ms) and pinned
                                    streams answer BackendLost; the
                                    metrics page adds per-backend
                                    impulse_proxy_* counters
    replay DIR [--engine E]         re-execute a capture against a core
                                    rebuilt from its metadata; diffs
                                    response frames and V-digests,
                                    exits nonzero on divergence
                                    (docs/REPLAY.md). --engine replays
                                    on a different engine — cross-
                                    engine bit-identity on recorded
                                    traffic; --trace-dir records the
                                    replayed requests' lifecycle spans
    loadgen SCENARIO --addr ADDR    drive a scripted scenario (smoke,
                                    burst, ramp, mixed, stream,
                                    slowloris, fuzz, or a TOML file) at
                                    a live server; asserts min-ok /
                                    error-rate / p99 envelopes via the
                                    server's own StatsRequest telemetry;
                                    --trace-dir records client-observed
                                    per-operation spans;
                                    --chaos kill|stall|blackhole
                                    schedules one mid-run fault
                                    (--chaos-after-ms, --chaos-for-ms,
                                    --chaos-kill-pid) — stall/blackhole
                                    degrade the path via an interposed
                                    relay, kill SIGKILLs a pid (e.g.
                                    one backend behind impulse proxy)
    trace DIR [--slowest N] [--json]
                                    summarize a --trace-dir export:
                                    per-phase p50/p99/max and the
                                    slowest traces with their phase
                                    breakdown (docs/OBSERVABILITY.md)
    stats ADDR                      fetch a running server's live
                                    telemetry (StatsRequest over the
                                    frame protocol): requests, energy,
                                    EDP, sparsity, queue depth, latency
    shmoo                           print the Fig 8 Shmoo grid
    sweep [--neuron rmp|if|lif]     EDP vs sparsity sweep (Fig 11b)
    trace-vmem [--sample N]         Fig 10: output-neuron V_MEM trajectory
    info                            artifact bundle + model summary
    help                            this message

OPTIONS (common):
    --config FILE                   TOML run config (see configs/)
    --vdd V --freq-mhz F            operating point for energy reports
"#;
