//! Reader/writer for the IMPT tensor format and `key=value` manifests
//! (see `python/compile/binfmt.py` — the two must stay in lockstep).

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"IMPT";

/// Element type codes (must match the Python side).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    I8 = 0,
    I16 = 1,
    I32 = 2,
    F32 = 3,
    I64 = 4,
    F64 = 5,
    U8 = 6,
}

impl Dtype {
    fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => Dtype::I8,
            1 => Dtype::I16,
            2 => Dtype::I32,
            3 => Dtype::F32,
            4 => Dtype::I64,
            5 => Dtype::F64,
            6 => Dtype::U8,
            _ => bail!("unknown dtype code {c}"),
        })
    }

    pub fn size(&self) -> usize {
        match self {
            Dtype::I8 | Dtype::U8 => 1,
            Dtype::I16 => 2,
            Dtype::I32 | Dtype::F32 => 4,
            Dtype::I64 | Dtype::F64 => 8,
        }
    }
}

/// A loaded tensor: shape + raw little-endian payload, with typed
/// accessors that convert on demand.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    data: Vec<u8>,
}

impl Tensor {
    /// Read from an IMPT file.
    pub fn read(path: impl AsRef<Path>) -> Result<Tensor> {
        let path = path.as_ref();
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: bad magic", path.display());
        }
        let mut hdr = [0u8; 2];
        f.read_exact(&mut hdr)?;
        let dtype = Dtype::from_code(hdr[0])?;
        let rank = hdr[1] as usize;
        let mut dims = vec![0usize; rank];
        for d in dims.iter_mut() {
            let mut b = [0u8; 4];
            f.read_exact(&mut b)?;
            *d = u32::from_le_bytes(b) as usize;
        }
        let n: usize = dims.iter().product::<usize>().max(1);
        let mut data = vec![0u8; n * dtype.size()];
        f.read_exact(&mut data)
            .with_context(|| format!("{}: truncated payload", path.display()))?;
        Ok(Tensor {
            dtype,
            shape: dims,
            data,
        })
    }

    /// Write to an IMPT file (used by the workload generators and the
    /// Rust-side round-trip tests).
    pub fn write(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(MAGIC)?;
        f.write_all(&[self.dtype as u8, self.shape.len() as u8])?;
        for &d in &self.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        f.write_all(&self.data)?;
        Ok(())
    }

    /// Build from i8 values.
    pub fn from_i8(shape: Vec<usize>, values: &[i8]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Tensor {
            dtype: Dtype::I8,
            shape,
            data: values.iter().map(|&v| v as u8).collect(),
        }
    }

    /// Build from i32 values.
    pub fn from_i32(shape: Vec<usize>, values: &[i32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Tensor {
            dtype: Dtype::I32,
            shape,
            data: values.iter().flat_map(|v| v.to_le_bytes()).collect(),
        }
    }

    /// Build from f32 values.
    pub fn from_f32(shape: Vec<usize>, values: &[f32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Tensor {
            dtype: Dtype::F32,
            shape,
            data: values.iter().flat_map(|v| v.to_le_bytes()).collect(),
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Elements widened to i64 (integer dtypes only).
    pub fn to_i64(&self) -> Result<Vec<i64>> {
        let n = self.len();
        let mut out = Vec::with_capacity(n);
        match self.dtype {
            Dtype::I8 => out.extend(self.data.iter().map(|&b| b as i8 as i64)),
            Dtype::U8 => out.extend(self.data.iter().map(|&b| b as i64)),
            Dtype::I16 => {
                for c in self.data.chunks_exact(2) {
                    out.push(i16::from_le_bytes([c[0], c[1]]) as i64);
                }
            }
            Dtype::I32 => {
                for c in self.data.chunks_exact(4) {
                    out.push(i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as i64);
                }
            }
            Dtype::I64 => {
                for c in self.data.chunks_exact(8) {
                    out.push(i64::from_le_bytes(c.try_into().unwrap()));
                }
            }
            _ => bail!("to_i64 on float tensor"),
        }
        Ok(out)
    }

    /// Elements as f32 (float dtypes only).
    pub fn to_f32(&self) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(self.len());
        match self.dtype {
            Dtype::F32 => {
                for c in self.data.chunks_exact(4) {
                    out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
            }
            Dtype::F64 => {
                for c in self.data.chunks_exact(8) {
                    out.push(f64::from_le_bytes(c.try_into().unwrap()) as f32);
                }
            }
            _ => bail!("to_f32 on integer tensor"),
        }
        Ok(out)
    }

    /// Interpret a rank-2 integer tensor as rows of i64.
    pub fn to_matrix_i64(&self) -> Result<Vec<Vec<i64>>> {
        if self.shape.len() != 2 {
            bail!("expected rank-2, got {:?}", self.shape);
        }
        let flat = self.to_i64()?;
        let (r, c) = (self.shape[0], self.shape[1]);
        Ok((0..r).map(|i| flat[i * c..(i + 1) * c].to_vec()).collect())
    }
}

/// A `key=value` manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: std::collections::BTreeMap<String, String>,
}

impl Manifest {
    pub fn read(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        let mut entries = std::collections::BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some((k, v)) = line.split_once('=') {
                entries.insert(k.to_string(), v.to_string());
            }
        }
        Ok(Manifest { entries })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key)?.parse().ok()
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key)?.parse().ok()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("impulse_binfmt_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn i32_roundtrip() {
        let t = Tensor::from_i32(vec![2, 3], &[1, -2, 3, -4, 5, -6]);
        let p = tmp("a.bin");
        t.write(&p).unwrap();
        let r = Tensor::read(&p).unwrap();
        assert_eq!(r.dtype, Dtype::I32);
        assert_eq!(r.shape, vec![2, 3]);
        assert_eq!(r.to_i64().unwrap(), vec![1, -2, 3, -4, 5, -6]);
        assert_eq!(
            r.to_matrix_i64().unwrap(),
            vec![vec![1, -2, 3], vec![-4, 5, -6]]
        );
    }

    #[test]
    fn i8_roundtrip() {
        let t = Tensor::from_i8(vec![4], &[-32, -1, 0, 31]);
        let p = tmp("b.bin");
        t.write(&p).unwrap();
        let r = Tensor::read(&p).unwrap();
        assert_eq!(r.to_i64().unwrap(), vec![-32, -1, 0, 31]);
    }

    #[test]
    fn f32_roundtrip() {
        let t = Tensor::from_f32(vec![3], &[1.5, -2.25, 0.0]);
        let p = tmp("c.bin");
        t.write(&p).unwrap();
        let r = Tensor::read(&p).unwrap();
        assert_eq!(r.to_f32().unwrap(), vec![1.5, -2.25, 0.0]);
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("bad.bin");
        std::fs::write(&p, b"NOPE aaaa").unwrap();
        assert!(Tensor::read(&p).is_err());
    }

    #[test]
    fn truncated_payload_rejected() {
        let t = Tensor::from_i32(vec![8], &[0; 8]);
        let p = tmp("trunc.bin");
        t.write(&p).unwrap();
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() - 5]).unwrap();
        assert!(Tensor::read(&p).is_err());
    }

    #[test]
    fn type_confusion_rejected() {
        let t = Tensor::from_f32(vec![2], &[1.0, 2.0]);
        assert!(t.to_i64().is_err());
        let t = Tensor::from_i32(vec![2], &[1, 2]);
        assert!(t.to_f32().is_err());
    }

    #[test]
    fn manifest_parse() {
        let p = tmp("m.txt");
        std::fs::write(&p, "# comment\nacc=0.88\nn=29315\nname=impulse\n\n").unwrap();
        let m = Manifest::read(&p).unwrap();
        assert_eq!(m.get_f64("acc"), Some(0.88));
        assert_eq!(m.get_i64("n"), Some(29315));
        assert_eq!(m.get("name"), Some("impulse"));
        assert_eq!(m.get("missing"), None);
        assert_eq!(m.keys().count(), 3);
    }
}
