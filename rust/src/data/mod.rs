//! Artifact loading: the IMPT binary tensor format, manifests, and the
//! typed views of the exported model/dataset bundles.

mod artifacts;
pub mod binfmt;

pub use artifacts::{DigitsArtifacts, KernelVector, SentimentArtifacts};
pub use binfmt::{Dtype, Manifest, Tensor};

use std::path::{Path, PathBuf};

/// Locate the artifacts directory: `$IMPULSE_ARTIFACTS`, else
/// `artifacts/` relative to the working directory, else relative to the
/// crate root (so tests work from any cwd).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("IMPULSE_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = Path::new("artifacts");
    if cwd.exists() {
        return cwd.to_path_buf();
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if the artifact bundle looks complete (manifest present).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}
