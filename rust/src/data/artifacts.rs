//! Typed views over the exported artifact bundles.

use super::binfmt::{Manifest, Tensor};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// The quantized sentiment model + its test set.
#[derive(Clone, Debug)]
pub struct SentimentArtifacts {
    /// Quantized embeddings `[vocab][100]` (encoder input currents).
    pub emb_q: Vec<Vec<i64>>,
    /// FC1 weights `[100][128]` in [-32, 31].
    pub w1: Vec<Vec<i64>>,
    /// FC2 weights `[128][128]`.
    pub w2: Vec<Vec<i64>>,
    /// Output weights `[128]` (column vector flattened).
    pub w_out: Vec<i64>,
    pub thr_enc: i64,
    pub thr1: i64,
    pub thr2: i64,
    /// Padded test sequences `[n][max_len]` (pad = -1).
    pub test_seqs: Vec<Vec<i64>>,
    pub test_lens: Vec<i64>,
    pub test_labels: Vec<u8>,
    /// Reference integer V_out traces from the Python int model
    /// `[32][max_len]` — differential-test fixture.
    pub ref_vout_traces: Vec<Vec<i64>>,
    pub ref_preds: Vec<u8>,
}

impl SentimentArtifacts {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let man = Manifest::read(dir.join("manifest.txt")).context("manifest")?;
        let s = dir.join("sentiment");
        let t = |name: &str| Tensor::read(s.join(name));
        Ok(Self {
            emb_q: t("emb_q.bin")?.to_matrix_i64()?,
            w1: t("w1.bin")?.to_matrix_i64()?,
            w2: t("w2.bin")?.to_matrix_i64()?,
            w_out: t("w_out.bin")?.to_i64()?,
            thr_enc: man
                .get_i64("snn_thr_enc")
                .context("snn_thr_enc missing")?,
            thr1: man.get_i64("snn_thr1").context("snn_thr1 missing")?,
            thr2: man.get_i64("snn_thr2").context("snn_thr2 missing")?,
            test_seqs: t("test_seqs.bin")?.to_matrix_i64()?,
            test_lens: t("test_lens.bin")?.to_i64()?,
            test_labels: t("test_labels.bin")?
                .to_i64()?
                .iter()
                .map(|&v| v as u8)
                .collect(),
            ref_vout_traces: t("ref_vout_traces.bin")?.to_matrix_i64()?,
            ref_preds: t("ref_preds.bin")?
                .to_i64()?
                .iter()
                .map(|&v| v as u8)
                .collect(),
        })
    }

    /// A deterministic synthetic bundle with the paper's sentiment
    /// geometry (100→128→128→1, vocab 20) and in-range 6-bit weights.
    /// No file IO: used by benches and integration tests when `make
    /// artifacts` has not run. Not a trained model — predictions are
    /// meaningful only for differential comparisons.
    pub fn synthetic(seed: u64) -> Self {
        let mut rng = crate::bits::XorShiftRng::new(seed);
        let vocab = 20;
        let emb_q: Vec<Vec<i64>> = (0..vocab)
            .map(|_| (0..100).map(|_| rng.gen_i64(-40, 40)).collect())
            .collect();
        let w1: Vec<Vec<i64>> = (0..100)
            .map(|_| (0..128).map(|_| rng.gen_i64(-6, 6)).collect())
            .collect();
        let w2: Vec<Vec<i64>> = (0..128)
            .map(|_| (0..128).map(|_| rng.gen_i64(-6, 6)).collect())
            .collect();
        let w_out: Vec<i64> = (0..128).map(|_| rng.gen_i64(-10, 10)).collect();
        Self {
            emb_q,
            w1,
            w2,
            w_out,
            thr_enc: 60,
            thr1: 150,
            thr2: 200,
            test_seqs: vec![vec![1, 2, 3, -1]],
            test_lens: vec![3],
            test_labels: vec![1],
            ref_vout_traces: vec![],
            ref_preds: vec![],
        }
    }

    /// Validate ranges against the hardware formats.
    pub fn validate(&self) -> Result<()> {
        for (name, m) in [("w1", &self.w1), ("w2", &self.w2)] {
            for row in m {
                for &w in row {
                    if !crate::bits::fits(w, crate::bits::W_BITS) {
                        bail!("{name}: weight {w} outside 6-bit range");
                    }
                }
            }
        }
        for &w in &self.w_out {
            if !crate::bits::fits(w, crate::bits::W_BITS) {
                bail!("w_out: weight {w} outside 6-bit range");
            }
        }
        if self.w1.len() != 100 || self.w1[0].len() != 128 {
            bail!("w1 shape {:?}x{:?}", self.w1.len(), self.w1[0].len());
        }
        if !(1..1024).contains(&self.thr1) || !(1..1024).contains(&self.thr2) {
            bail!("thresholds out of 11-bit range");
        }
        Ok(())
    }
}

/// The quantized digits model + test set.
#[derive(Clone, Debug)]
pub struct DigitsArtifacts {
    /// Encoder conv kernel `[3][3][1][C]` flattened (float, off-macro).
    pub k1: Vec<f32>,
    pub k1_shape: Vec<usize>,
    pub thr_c1: f32,
    /// Conv2 kernel `[3][3][C][C]` flattened (int).
    pub k2: Vec<i64>,
    pub k2_shape: Vec<usize>,
    pub k3: Vec<i64>,
    pub k3_shape: Vec<usize>,
    pub w_fc1: Vec<Vec<i64>>,
    pub w_fc2: Vec<Vec<i64>>,
    pub thr_c2: i64,
    pub thr_c3: i64,
    pub thr_f1: i64,
    /// Test images `[n][28][28]` flattened per image.
    pub test_x: Vec<Vec<f32>>,
    pub test_y: Vec<u8>,
}

impl DigitsArtifacts {
    /// A deterministic synthetic digits bundle (4 channels instead of
    /// the paper's 14, for test speed) with a handful of synthetic
    /// test images — lets the batched digits path run in tests,
    /// benches, and the CLI without the compiled artifact bundle.
    pub fn synthetic(seed: u64) -> Self {
        let mut rng = crate::bits::XorShiftRng::new(seed);
        let c = 4usize;
        let k1: Vec<f32> = (0..9 * c).map(|_| (rng.gen_f64() - 0.3) as f32).collect();
        let mut kernel = |n: usize| (0..n).map(|_| rng.gen_i64(-8, 8)).collect::<Vec<i64>>();
        let k2 = kernel(9 * c * c);
        let k3 = kernel(9 * c * c);
        let w_fc1: Vec<Vec<i64>> = (0..9 * c)
            .map(|_| (0..20).map(|_| rng.gen_i64(-8, 8)).collect())
            .collect();
        let w_fc2: Vec<Vec<i64>> = (0..20)
            .map(|_| (0..10).map(|_| rng.gen_i64(-8, 8)).collect())
            .collect();
        let n_imgs = 8usize;
        let test_x: Vec<Vec<f32>> = (0..n_imgs)
            .map(|_| (0..28 * 28).map(|_| rng.gen_f64() as f32).collect())
            .collect();
        let test_y: Vec<u8> = (0..n_imgs).map(|_| (rng.gen_i64(0, 9)) as u8).collect();
        Self {
            k1,
            k1_shape: vec![3, 3, 1, c],
            thr_c1: 0.8,
            k2,
            k2_shape: vec![3, 3, c, c],
            k3,
            k3_shape: vec![3, 3, c, c],
            w_fc1,
            w_fc2,
            thr_c2: 30,
            thr_c3: 30,
            thr_f1: 40,
            test_x,
            test_y,
        }
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let d = dir.join("digits");
        let k1 = Tensor::read(d.join("k1.bin"))?;
        let k2 = Tensor::read(d.join("k2.bin"))?;
        let k3 = Tensor::read(d.join("k3.bin"))?;
        let thr = Tensor::read(d.join("thresholds.bin"))?.to_i64()?;
        let thr_c1 = Tensor::read(d.join("thr_c1.bin"))?.to_f32()?[0];
        let tx = Tensor::read(d.join("test_x.bin"))?;
        let n = tx.shape[0];
        let img = tx.shape[1] * tx.shape[2];
        let flat = tx.to_f32()?;
        Ok(Self {
            k1_shape: k1.shape.clone(),
            k1: k1.to_f32()?,
            thr_c1,
            k2_shape: k2.shape.clone(),
            k2: k2.to_i64()?,
            k3_shape: k3.shape.clone(),
            k3: k3.to_i64()?,
            w_fc1: Tensor::read(d.join("w_fc1.bin"))?.to_matrix_i64()?,
            w_fc2: Tensor::read(d.join("w_fc2.bin"))?.to_matrix_i64()?,
            thr_c2: thr[0],
            thr_c3: thr[1],
            thr_f1: thr[2],
            test_x: (0..n).map(|i| flat[i * img..(i + 1) * img].to_vec()).collect(),
            test_y: Tensor::read(d.join("test_y.bin"))?
                .to_i64()?
                .iter()
                .map(|&v| v as u8)
                .collect(),
        })
    }
}

/// One exported kernel cross-check vector (inputs + oracle outputs of
/// the fused step, produced by the L1 reference).
#[derive(Clone, Debug)]
pub struct KernelVector {
    pub name: String,
    pub spikes: Vec<Vec<i64>>,   // [B][M] {0,1}
    pub weights: Vec<Vec<i64>>,  // [M][N]
    pub v: Vec<Vec<i64>>,        // [B][N]
    pub v_next: Vec<Vec<i64>>,   // oracle output
    pub spikes_out: Vec<Vec<i64>>,
    pub mode: i64, // 0=IF 1=LIF 2=RMP
    pub threshold: i64,
    pub leak: i64,
}

impl KernelVector {
    /// Load all exported vectors.
    pub fn load_all(dir: impl AsRef<Path>) -> Result<Vec<KernelVector>> {
        let d = dir.as_ref().join("kernel_vectors");
        let index = std::fs::read_to_string(d.join("index.txt")).context("index.txt")?;
        let mut out = Vec::new();
        for name in index.lines().filter(|l| !l.trim().is_empty()) {
            let t = |suffix: &str| Tensor::read(d.join(format!("{name}_{suffix}.bin")));
            let meta = t("meta")?.to_i64()?;
            out.push(KernelVector {
                name: name.to_string(),
                spikes: t("spikes")?.to_matrix_i64()?,
                weights: t("weights")?.to_matrix_i64()?,
                v: t("v")?.to_matrix_i64()?,
                v_next: t("v_next")?.to_matrix_i64()?,
                spikes_out: t("spikes_out")?.to_matrix_i64()?,
                mode: meta[0],
                threshold: meta[1],
                leak: meta[2],
            });
        }
        Ok(out)
    }
}
