//! Deterministic record/replay for the serving stack.
//!
//! The macro's claim to fame is bit-exact digital CIM arithmetic, and
//! the repo pins that claim with differential tests (SWAR vs
//! bit-level, chunked vs one-shot, batched vs sequential). This module
//! turns the same guarantee into an *operational tool*:
//!
//! * **Recording** (`impulse serve --record <dir>`) taps every TCP
//!   connection server-side: inbound bytes (below the frame decoder,
//!   so malformed traffic is captured verbatim), outbound frames (in
//!   wire order), and a per-request **V-digest** — an FNV-1a hash of
//!   every mapped macro's V_MEM rows taken right after the request
//!   finished ([`crate::coordinator::Workload::v_digest`]). Nothing
//!   changes on the wire; recording is invisible to clients.
//! * **Replay** (`impulse replay <dir>`, [`runner::replay_capture`])
//!   re-executes a capture through a fresh [`ServeCore`] and diffs
//!   response frames and digests, failing loudly on the first
//!   divergence. This is the safety net refactors of the serve path
//!   (epoll rewrite, proxy tier) run under.
//! * **Load generation** (`impulse loadgen <scenario>`,
//!   [`loadgen::run_scenario`]) drives scripted traffic — burst, ramp,
//!   mixed kinds, streaming with random chunk splits, slow-loris,
//!   malformed-frame fuzz — against a live server and asserts
//!   latency/throughput/error envelopes read back via the `0x14`
//!   stats telemetry.
//!
//! The capture format and digest definition are specified in
//! `docs/REPLAY.md`.
//!
//! [`ServeCore`]: crate::serve::ServeCore

pub mod loadgen;
pub mod runner;

use crate::Result;
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// FNV-1a 64-bit offset basis — the digest accumulator's start value.
pub const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;

/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x100_0000_01B3;

/// Fold bytes into a running FNV-1a 64 accumulator (seed with
/// [`FNV_OFFSET`]).
pub fn fold_bytes(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

/// File name a directory capture is written to.
pub const CAPTURE_FILE: &str = "capture.imp1cap";

/// First line of every capture file.
pub const CAPTURE_HEADER: &str = "IMPULSE-CAPTURE v1";

/// One recorded event, in capture order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Event {
    /// Raw bytes read from a client socket (below the frame decoder,
    /// so undecodable traffic is captured verbatim).
    BytesIn {
        /// The connection these bytes arrived on.
        conn: u64,
        /// The bytes, exactly as read.
        bytes: Vec<u8>,
    },
    /// One encoded frame written to a client socket, in wire order.
    FrameOut {
        /// The connection the frame was written to.
        conn: u64,
        /// The full encoded frame (header, payload, CRC).
        bytes: Vec<u8>,
    },
    /// A post-request V_MEM digest checkpoint.
    Digest {
        /// The connection whose request produced this checkpoint.
        conn: u64,
        /// The client's request id the checkpoint belongs to.
        request_id: u64,
        /// FNV-1a digest of the serving engine's V_MEM rows.
        digest: u64,
    },
}

/// A loaded (or in-memory) capture: metadata plus the event log.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Capture {
    /// `(key, value)` metadata lines, in file order (model, engine,
    /// artifact provenance — whatever the recorder chose to note).
    pub meta: Vec<(String, String)>,
    /// The recorded events, in capture order.
    pub events: Vec<Event>,
}

impl Capture {
    /// First metadata value for `key`, if present.
    pub fn meta_value(&self, key: &str) -> Option<&str> {
        self.meta
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Serialize to the line-oriented capture text format.
    pub fn to_text(&self) -> String {
        let mut o = String::new();
        o.push_str(CAPTURE_HEADER);
        o.push('\n');
        for (k, v) in &self.meta {
            o.push_str(&format!("meta {k} {v}\n"));
        }
        for e in &self.events {
            o.push_str(&event_line(e));
        }
        o
    }

    /// Parse the capture text format (strict: unknown or malformed
    /// lines are errors, so a truncated or tampered capture cannot
    /// silently replay as a shorter run).
    pub fn from_text(text: &str) -> Result<Capture> {
        let mut lines = text.lines();
        let head = lines.next().unwrap_or("");
        anyhow::ensure!(
            head == CAPTURE_HEADER,
            "not a capture file: first line {head:?} (want {CAPTURE_HEADER:?})"
        );
        let mut cap = Capture::default();
        for (ix, line) in lines.enumerate() {
            let n = ix + 2; // 1-based, after the header
            if line.is_empty() {
                continue;
            }
            let (tag, rest) = line
                .split_once(' ')
                .ok_or_else(|| anyhow::anyhow!("capture line {n}: no fields in {line:?}"))?;
            match tag {
                "meta" => {
                    let (k, v) = rest.split_once(' ').unwrap_or((rest, ""));
                    cap.meta.push((k.to_string(), v.to_string()));
                }
                "I" | "O" => {
                    let (conn, hex) = rest
                        .split_once(' ')
                        .ok_or_else(|| anyhow::anyhow!("capture line {n}: missing bytes"))?;
                    let conn: u64 = conn
                        .parse()
                        .map_err(|e| anyhow::anyhow!("capture line {n}: bad conn id: {e}"))?;
                    let bytes = unhex(hex)
                        .map_err(|e| anyhow::anyhow!("capture line {n}: {e}"))?;
                    cap.events.push(if tag == "I" {
                        Event::BytesIn { conn, bytes }
                    } else {
                        Event::FrameOut { conn, bytes }
                    });
                }
                "D" => {
                    let mut f = rest.split(' ');
                    let parse = |s: Option<&str>, what: &str| -> Result<u64> {
                        let s =
                            s.ok_or_else(|| anyhow::anyhow!("capture line {n}: missing {what}"))?;
                        u64::from_str_radix(s.trim_start_matches("0x"), 16)
                            .map_err(|e| anyhow::anyhow!("capture line {n}: bad {what}: {e}"))
                    };
                    let conn = f
                        .next()
                        .and_then(|s| s.parse::<u64>().ok())
                        .ok_or_else(|| anyhow::anyhow!("capture line {n}: bad conn id"))?;
                    let request_id = f
                        .next()
                        .and_then(|s| s.parse::<u64>().ok())
                        .ok_or_else(|| anyhow::anyhow!("capture line {n}: bad request id"))?;
                    let digest = parse(f.next(), "digest")?;
                    anyhow::ensure!(f.next().is_none(), "capture line {n}: trailing fields");
                    cap.events.push(Event::Digest { conn, request_id, digest });
                }
                other => anyhow::bail!("capture line {n}: unknown tag {other:?}"),
            }
        }
        Ok(cap)
    }

    /// Write the capture to a file (see [`Capture::to_text`]).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_text())?;
        Ok(())
    }

    /// Load a capture from a file, or from `<path>/capture.imp1cap`
    /// when `path` is a directory (the `--record <dir>` layout).
    pub fn load(path: &Path) -> Result<Capture> {
        let file = if path.is_dir() { path.join(CAPTURE_FILE) } else { path.to_path_buf() };
        let text = std::fs::read_to_string(&file)
            .map_err(|e| anyhow::anyhow!("reading capture {}: {e}", file.display()))?;
        Self::from_text(&text)
    }
}

/// One capture event as its file line (with trailing newline).
fn event_line(e: &Event) -> String {
    match e {
        Event::BytesIn { conn, bytes } => format!("I {conn} {}\n", hex(bytes)),
        Event::FrameOut { conn, bytes } => format!("O {conn} {}\n", hex(bytes)),
        Event::Digest { conn, request_id, digest } => {
            format!("D {conn} {request_id} {digest:016x}\n")
        }
    }
}

/// Lowercase hex encoding.
pub fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// Decode lowercase/uppercase hex (even length required).
pub fn unhex(s: &str) -> Result<Vec<u8>> {
    anyhow::ensure!(s.len() % 2 == 0, "odd hex length {}", s.len());
    (0..s.len() / 2)
        .map(|i| {
            u8::from_str_radix(&s[2 * i..2 * i + 2], 16)
                .map_err(|e| anyhow::anyhow!("bad hex at {}: {e}", 2 * i))
        })
        .collect()
}

struct RecorderInner {
    meta: Vec<(String, String)>,
    events: Vec<Event>,
    file: Option<BufWriter<std::fs::File>>,
}

/// A thread-safe capture sink the serve path records into.
///
/// Events are kept in memory (for [`Recorder::capture`]) and, when the
/// recorder was opened with [`Recorder::to_dir`], written through to
/// the capture file line-by-line so a crash mid-run still leaves a
/// usable prefix on disk.
pub struct Recorder {
    inner: Mutex<RecorderInner>,
}

impl Recorder {
    /// An in-memory recorder (the replay runner's comparison sink).
    pub fn in_memory() -> Recorder {
        Recorder {
            inner: Mutex::new(RecorderInner { meta: Vec::new(), events: Vec::new(), file: None }),
        }
    }

    /// A write-through recorder at `<dir>/capture.imp1cap` (directory
    /// created if needed), with the given metadata written up front.
    pub fn to_dir(dir: &Path, meta: &[(String, String)]) -> Result<(Recorder, PathBuf)> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(CAPTURE_FILE);
        let mut w = BufWriter::new(std::fs::File::create(&path)?);
        writeln!(w, "{CAPTURE_HEADER}")?;
        for (k, v) in meta {
            writeln!(w, "meta {k} {v}")?;
        }
        w.flush()?;
        Ok((
            Recorder {
                inner: Mutex::new(RecorderInner {
                    meta: meta.to_vec(),
                    events: Vec::new(),
                    file: Some(w),
                }),
            },
            path,
        ))
    }

    fn push(&self, e: Event) {
        let mut g = self.inner.lock().expect("recorder poisoned");
        if let Some(f) = g.file.as_mut() {
            let _ = f.write_all(event_line(&e).as_bytes());
        }
        g.events.push(e);
    }

    /// Record raw inbound bytes from a connection.
    pub fn bytes_in(&self, conn: u64, bytes: &[u8]) {
        self.push(Event::BytesIn { conn, bytes: bytes.to_vec() });
    }

    /// Record one encoded outbound frame (call under the connection's
    /// write lock so capture order matches wire order).
    pub fn frame_out(&self, conn: u64, bytes: &[u8]) {
        self.push(Event::FrameOut { conn, bytes: bytes.to_vec() });
    }

    /// Record a post-request V-digest checkpoint.
    pub fn digest(&self, conn: u64, request_id: u64, digest: u64) {
        self.push(Event::Digest { conn, request_id, digest });
    }

    /// Snapshot the recording as a [`Capture`].
    pub fn capture(&self) -> Capture {
        let g = self.inner.lock().expect("recorder poisoned");
        Capture { meta: g.meta.clone(), events: g.events.clone() }
    }

    /// Number of events recorded so far.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("recorder poisoned").events.len()
    }

    /// Whether nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flush the write-through file, if any.
    pub fn flush(&self) -> Result<()> {
        let mut g = self.inner.lock().expect("recorder poisoned");
        if let Some(f) = g.file.as_mut() {
            f.flush()?;
        }
        Ok(())
    }
}

/// A [`Read`] adapter that tees every chunk read into a [`Recorder`]
/// as [`Event::BytesIn`]. With no tap attached it is a transparent
/// passthrough, so the listener wraps every connection in one
/// unconditionally.
pub struct TapRead<R> {
    inner: R,
    tap: Option<(Arc<Recorder>, u64)>,
}

impl<R: Read> TapRead<R> {
    /// Wrap a transport; `tap` is `(recorder, connection id)`.
    pub fn new(inner: R, tap: Option<(Arc<Recorder>, u64)>) -> TapRead<R> {
        TapRead { inner, tap }
    }
}

impl<R: Read> Read for TapRead<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        if n > 0 {
            if let Some((rec, conn)) = &self.tap {
                rec.bytes_in(*conn, &buf[..n]);
            }
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_reference_vectors() {
        // Published FNV-1a 64 vectors.
        let mut h = FNV_OFFSET;
        fold_bytes(&mut h, b"");
        assert_eq!(h, 0xCBF2_9CE4_8422_2325);
        let mut h = FNV_OFFSET;
        fold_bytes(&mut h, b"a");
        assert_eq!(h, 0xAF63_DC4C_8601_EC8C);
        let mut h = FNV_OFFSET;
        fold_bytes(&mut h, b"foobar");
        assert_eq!(h, 0x85944171F73967E8);
    }

    #[test]
    fn hex_roundtrip_and_rejects() {
        assert_eq!(hex(&[0x00, 0xAB, 0xFF]), "00abff");
        assert_eq!(unhex("00abff").unwrap(), vec![0x00, 0xAB, 0xFF]);
        assert_eq!(unhex("00ABFF").unwrap(), vec![0x00, 0xAB, 0xFF]);
        assert_eq!(unhex("").unwrap(), Vec::<u8>::new());
        assert!(unhex("0").is_err());
        assert!(unhex("zz").is_err());
    }

    #[test]
    fn capture_text_roundtrip() {
        let cap = Capture {
            meta: vec![
                ("model".into(), "sentiment".into()),
                ("note".into(), "a value with spaces".into()),
            ],
            events: vec![
                Event::BytesIn { conn: 1, bytes: vec![0x49, 0x4D, 0x50, 0x31] },
                Event::FrameOut { conn: 1, bytes: vec![0xFF, 0x00] },
                Event::Digest { conn: 1, request_id: 7, digest: 0xDEAD_BEEF_0000_0001 },
                Event::BytesIn { conn: 2, bytes: vec![] },
            ],
        };
        let text = cap.to_text();
        let back = Capture::from_text(&text).unwrap();
        assert_eq!(back, cap);
        assert_eq!(back.meta_value("model"), Some("sentiment"));
        assert_eq!(back.meta_value("note"), Some("a value with spaces"));
        assert_eq!(back.meta_value("absent"), None);
    }

    #[test]
    fn capture_parser_rejects_garbage() {
        assert!(Capture::from_text("").is_err());
        assert!(Capture::from_text("NOT-A-CAPTURE\n").is_err());
        let ok = format!("{CAPTURE_HEADER}\nI 1 00ff\n");
        assert!(Capture::from_text(&ok).is_ok());
        assert!(Capture::from_text(&format!("{CAPTURE_HEADER}\nX 1 00\n")).is_err());
        assert!(Capture::from_text(&format!("{CAPTURE_HEADER}\nI one 00\n")).is_err());
        assert!(Capture::from_text(&format!("{CAPTURE_HEADER}\nI 1 0\n")).is_err());
        assert!(Capture::from_text(&format!("{CAPTURE_HEADER}\nD 1 2 xyz\n")).is_err());
        assert!(Capture::from_text(&format!("{CAPTURE_HEADER}\nD 1 2 00 trailing\n")).is_err());
    }

    #[test]
    fn recorder_accumulates_and_snapshots() {
        let rec = Recorder::in_memory();
        assert!(rec.is_empty());
        rec.bytes_in(3, &[1, 2, 3]);
        rec.frame_out(3, &[4, 5]);
        rec.digest(3, 9, 0x123);
        assert_eq!(rec.len(), 3);
        let cap = rec.capture();
        assert_eq!(cap.events.len(), 3);
        assert_eq!(cap.events[0], Event::BytesIn { conn: 3, bytes: vec![1, 2, 3] });
        assert_eq!(cap.events[2], Event::Digest { conn: 3, request_id: 9, digest: 0x123 });
    }

    #[test]
    fn recorder_writes_through_to_disk() {
        let dir = std::env::temp_dir().join(format!("impulse-rec-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let meta = vec![("model".to_string(), "digits".to_string())];
        let (rec, path) = Recorder::to_dir(&dir, &meta).unwrap();
        rec.bytes_in(1, &[0xAA]);
        rec.digest(1, 4, 42);
        rec.flush().unwrap();
        let cap = Capture::load(&dir).unwrap();
        assert_eq!(cap.meta_value("model"), Some("digits"));
        assert_eq!(cap.events.len(), 2);
        assert_eq!(Capture::load(&path).unwrap(), cap);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tap_read_tees_and_passes_through() {
        let rec = Arc::new(Recorder::in_memory());
        let src = std::io::Cursor::new(vec![9u8, 8, 7, 6]);
        let mut tap = TapRead::new(src, Some((Arc::clone(&rec), 5)));
        let mut out = Vec::new();
        tap.read_to_end(&mut out).unwrap();
        assert_eq!(out, vec![9, 8, 7, 6]);
        let cap = rec.capture();
        let total: Vec<u8> = cap
            .events
            .iter()
            .flat_map(|e| match e {
                Event::BytesIn { conn, bytes } => {
                    assert_eq!(*conn, 5);
                    bytes.clone()
                }
                _ => panic!("unexpected event"),
            })
            .collect();
        assert_eq!(total, vec![9, 8, 7, 6]);

        // no tap → pure passthrough, nothing recorded
        let mut plain = TapRead::new(std::io::Cursor::new(vec![1u8]), None);
        let mut out = Vec::new();
        plain.read_to_end(&mut out).unwrap();
        assert_eq!(out, vec![1]);
    }
}
