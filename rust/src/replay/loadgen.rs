//! Scripted scenario load generation against a live framed server.
//!
//! `impulse loadgen <scenario>` drives a mix of one-shot inference,
//! streaming sessions with randomized chunk splits, slow-loris
//! trickle connections, and malformed-frame fuzz at a running
//! `impulse serve --listen` instance, then asserts an **envelope** —
//! minimum completed requests, maximum error rate, maximum p99
//! latency — read back over the wire via the `StatsRequest` (0x14)
//! telemetry the server already exposes. The p99 check uses the
//! *delta* of the TCP transport histogram across the run, so a
//! long-lived server's history does not pollute the measurement.
//!
//! Scenarios are deterministic: every random choice (request mix,
//! chunk sizes, fuzz mutations) flows from the scenario seed through
//! [`XorShiftRng`], so a failing run reproduces with the same seed.
//!
//! `--chaos` schedules one mid-run fault on top of any scenario
//! ([`run_scenario_chaos`]): stall or black-hole the path through an
//! interposed [`FaultRelay`], or `kill -9` a process (typically one
//! backend behind an `impulse proxy`) — then judge the same envelope,
//! so resilience claims are asserted, not assumed.

use crate::bits::XorShiftRng;
use crate::config::TomlDoc;
use crate::coordinator::WorkloadInput;
use crate::obs::trace::{elapsed_us, Phase, Span, TraceRecorder};
use crate::proxy::{FaultMode, FaultRelay};
use crate::serve::{FrameClient, ServerError};
use crate::telemetry::{Transport, TransportStats};
use crate::Result;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

pub use crate::telemetry::StatsSnapshot;

/// Pass/fail bounds a scenario run is held to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Envelope {
    /// Minimum successfully answered requests (one-shot + stream ops).
    pub min_ok: u64,
    /// Maximum tolerated error fraction over all attempted operations
    /// (server-answered error frames and transport failures alike).
    pub max_error_rate: f64,
    /// Maximum tolerated server-side p99 latency in microseconds, per
    /// the TCP transport histogram delta; `0` disables the check.
    pub max_p99_us: u64,
}

impl Default for Envelope {
    fn default() -> Envelope {
        Envelope { min_ok: 1, max_error_rate: 0.0, max_p99_us: 0 }
    }
}

/// What `--chaos` does to the traffic path mid-run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosMode {
    /// `kill -9` the given pid — typically one backend behind an
    /// `impulse proxy`, so the run asserts failover, not survival of
    /// the process itself. Not restored; death is not reversible.
    Kill {
        /// The process id to kill.
        pid: u32,
    },
    /// Stall the interposed relay: bytes stop moving in both
    /// directions but nothing errors — a wedged process under an
    /// intact TCP session.
    Stall,
    /// Black-hole the interposed relay: bytes are read and silently
    /// discarded — the connection looks healthy and only an answer
    /// timeout can tell.
    Blackhole,
}

impl ChaosMode {
    /// The relay mode this chaos shape maps to (`None` for kill,
    /// which targets a process, not the relay).
    fn fault_mode(self) -> Option<FaultMode> {
        match self {
            ChaosMode::Kill { .. } => None,
            ChaosMode::Stall => Some(FaultMode::Stall),
            ChaosMode::Blackhole => Some(FaultMode::Blackhole),
        }
    }
}

/// One scheduled mid-run fault (`impulse loadgen --chaos`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChaosSpec {
    /// The fault to inject.
    pub mode: ChaosMode,
    /// How long after traffic starts the fault fires.
    pub after: Duration,
    /// How long the fault lasts before the path is restored. Ignored
    /// by [`ChaosMode::Kill`].
    pub duration: Duration,
}

/// One scripted traffic scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Display name (also the builtin lookup key).
    pub name: String,
    /// Seed for every random choice the scenario makes.
    pub seed: u64,
    /// Concurrent request connections.
    pub connections: usize,
    /// One-shot inference requests per connection.
    pub requests_per_conn: usize,
    /// Fraction of one-shot requests sent as `DigitsInferRequest`
    /// (the rest are sentiment word requests). Against a single-model
    /// server the foreign kind is *expected* to answer an error frame;
    /// the envelope's error budget accounts for it.
    pub mix_digits: f64,
    /// Streaming sessions per connection (words appended in chunks of
    /// random length, one read-out, then close).
    pub streams_per_conn: usize,
    /// Chunk appends per streaming session.
    pub appends_per_stream: usize,
    /// Stagger connection start times across this window (0 = all at
    /// once, i.e. a burst).
    pub ramp_ms: u64,
    /// Extra slow-loris connections: a valid request trickled
    /// byte-by-byte. The server must still answer it — and must keep
    /// serving everyone else meanwhile.
    pub slow_loris: usize,
    /// Malformed frames to throw at the server (seeded mutations of a
    /// valid frame). Each must be answered with an error frame or a
    /// clean close — never a hang — and the server must stay live.
    pub fuzz_frames: usize,
    /// The pass/fail bounds.
    pub envelope: Envelope,
}

impl Default for Scenario {
    fn default() -> Scenario {
        Scenario {
            name: "smoke".to_string(),
            seed: 7,
            connections: 2,
            requests_per_conn: 8,
            mix_digits: 0.0,
            streams_per_conn: 1,
            appends_per_stream: 4,
            ramp_ms: 0,
            slow_loris: 0,
            fuzz_frames: 0,
            envelope: Envelope { min_ok: 16, max_error_rate: 0.0, max_p99_us: 0 },
        }
    }
}

/// Builtin scenario names, in presentation order.
pub const BUILTIN_SCENARIOS: [&str; 7] =
    ["smoke", "burst", "ramp", "mixed", "stream", "slowloris", "fuzz"];

impl Scenario {
    /// Look up a builtin scenario by name.
    pub fn builtin(name: &str) -> Option<Scenario> {
        let base = Scenario::default();
        let s = match name {
            "smoke" => base,
            "burst" => Scenario {
                name: "burst".into(),
                connections: 8,
                requests_per_conn: 25,
                streams_per_conn: 0,
                envelope: Envelope { min_ok: 200, max_error_rate: 0.0, max_p99_us: 0 },
                ..base
            },
            "ramp" => Scenario {
                name: "ramp".into(),
                connections: 4,
                requests_per_conn: 15,
                ramp_ms: 500,
                envelope: Envelope { min_ok: 60, max_error_rate: 0.0, max_p99_us: 0 },
                ..base
            },
            "mixed" => Scenario {
                name: "mixed".into(),
                connections: 4,
                requests_per_conn: 12,
                mix_digits: 0.5,
                streams_per_conn: 2,
                // ~half the one-shots target the kind the server does
                // not host and are answered with error frames
                envelope: Envelope { min_ok: 24, max_error_rate: 0.65, max_p99_us: 0 },
                ..base
            },
            "stream" => Scenario {
                name: "stream".into(),
                connections: 2,
                requests_per_conn: 0,
                streams_per_conn: 4,
                appends_per_stream: 16,
                envelope: Envelope { min_ok: 100, max_error_rate: 0.0, max_p99_us: 0 },
                ..base
            },
            "slowloris" => Scenario {
                name: "slowloris".into(),
                connections: 2,
                requests_per_conn: 6,
                slow_loris: 4,
                envelope: Envelope { min_ok: 12, max_error_rate: 0.0, max_p99_us: 0 },
                ..base
            },
            "fuzz" => Scenario {
                name: "fuzz".into(),
                connections: 2,
                requests_per_conn: 6,
                streams_per_conn: 0,
                fuzz_frames: 64,
                envelope: Envelope { min_ok: 12, max_error_rate: 0.0, max_p99_us: 0 },
                ..base
            },
            _ => return None,
        };
        Some(s)
    }

    /// Load a scenario from a TOML file (`[scenario]` + `[envelope]`
    /// sections; every key optional, defaulting to the smoke
    /// scenario — the format is documented in `docs/REPLAY.md`).
    pub fn from_file(path: &std::path::Path) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading scenario {}: {e}", path.display()))?;
        let doc = TomlDoc::parse(&text)?;
        let mut s = Scenario::default();
        let sec = "scenario";
        if let Some(v) = doc.get_str(sec, "name") {
            s.name = v.to_string();
        }
        let usize_of = |v: i64| usize::try_from(v).unwrap_or(0);
        if let Some(v) = doc.get_i64(sec, "seed") {
            s.seed = v as u64;
        }
        if let Some(v) = doc.get_i64(sec, "connections") {
            s.connections = usize_of(v);
        }
        if let Some(v) = doc.get_i64(sec, "requests_per_conn") {
            s.requests_per_conn = usize_of(v);
        }
        if let Some(v) = doc.get_f64(sec, "mix_digits") {
            s.mix_digits = v.clamp(0.0, 1.0);
        }
        if let Some(v) = doc.get_i64(sec, "streams_per_conn") {
            s.streams_per_conn = usize_of(v);
        }
        if let Some(v) = doc.get_i64(sec, "appends_per_stream") {
            s.appends_per_stream = usize_of(v);
        }
        if let Some(v) = doc.get_i64(sec, "ramp_ms") {
            s.ramp_ms = v as u64;
        }
        if let Some(v) = doc.get_i64(sec, "slow_loris") {
            s.slow_loris = usize_of(v);
        }
        if let Some(v) = doc.get_i64(sec, "fuzz_frames") {
            s.fuzz_frames = usize_of(v);
        }
        if let Some(v) = doc.get_i64("envelope", "min_ok") {
            s.envelope.min_ok = v.max(0) as u64;
        }
        if let Some(v) = doc.get_f64("envelope", "max_error_rate") {
            s.envelope.max_error_rate = v.clamp(0.0, 1.0);
        }
        if let Some(v) = doc.get_i64("envelope", "max_p99_us") {
            s.envelope.max_p99_us = v.max(0) as u64;
        }
        Ok(s)
    }
}

/// The outcome of one scenario run.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    /// Successfully answered operations (inference + stream ops +
    /// slow-loris completions).
    pub ok: u64,
    /// Server-answered error frames (the protocol's per-request error
    /// path — the connection survived).
    pub errors: u64,
    /// Transport-level failures (connect refused, unexpected close,
    /// undecodable response).
    pub transport_errors: u64,
    /// Server-side p99 latency in microseconds over the run, from the
    /// TCP transport histogram delta (0 when nothing was measured).
    pub p99_us: u64,
    /// Completed operations per wall-clock second.
    pub throughput_rps: f64,
    /// Envelope violations, empty on a passing run.
    pub violations: Vec<String>,
}

impl LoadgenReport {
    /// Whether the run stayed inside its envelope.
    pub fn is_ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// All attempted operations.
    pub fn attempted(&self) -> u64 {
        self.ok + self.errors + self.transport_errors
    }

    /// Errors (both classes) as a fraction of attempts (0 when none).
    pub fn error_rate(&self) -> f64 {
        if self.attempted() == 0 {
            0.0
        } else {
            (self.errors + self.transport_errors) as f64 / self.attempted() as f64
        }
    }
}

/// Per-thread tally folded into the report at join time.
#[derive(Clone, Copy, Debug, Default)]
struct Tally {
    ok: u64,
    errors: u64,
    transport: u64,
}

impl Tally {
    /// Classify one operation outcome: an `Err` carrying a
    /// [`ServerError`] is a served error frame, anything else a
    /// transport failure.
    fn count<T>(&mut self, r: &Result<T>) {
        match r {
            Ok(_) => self.ok += 1,
            Err(e) if e.downcast_ref::<ServerError>().is_some() => self.errors += 1,
            Err(_) => self.transport += 1,
        }
    }
}

/// A deterministic sentiment request: 1–8 word ids in `[0, 20)` (the
/// synthetic vocabulary).
fn random_words(rng: &mut XorShiftRng) -> Vec<i64> {
    let n = 1 + rng.gen_range(8) as usize;
    (0..n).map(|_| rng.gen_range(20) as i64).collect()
}

/// A deterministic sparse 28×28 image (~10% active pixels), the shape
/// the digits workload requires.
fn random_image(rng: &mut XorShiftRng) -> WorkloadInput {
    let pixels = (0..784)
        .map(|_| if rng.gen_bool(0.1) { 1.0 } else { 0.0 })
        .collect();
    WorkloadInput::Image { h: 28, w: 28, pixels }
}

/// Run one request connection: `requests_per_conn` one-shot calls in
/// the scenario's kind mix, then `streams_per_conn` streaming sessions
/// with random chunk splits.
fn run_conn(addr: &str, sc: &Scenario, idx: usize, trace: Option<&TraceRecorder>) -> Tally {
    let mut tally = Tally::default();
    let mut rng = XorShiftRng::new(sc.seed ^ (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    // ride out a momentary refusal (proxy failover window, backend
    // restart) instead of charging a transport error on first contact
    let mut client = match FrameClient::connect_with_backoff(addr, 4, Duration::from_millis(50)) {
        Ok(c) => c,
        Err(_) => {
            tally.transport += 1;
            return tally;
        }
    };
    if client.hello().is_err() {
        tally.transport += 1;
        return tally;
    }
    for op in 0..sc.requests_per_conn {
        let input = if rng.gen_f64() < sc.mix_digits {
            random_image(&mut rng)
        } else {
            WorkloadInput::Words(random_words(&mut rng))
        };
        let t0 = trace.map(|_| Instant::now());
        let outcome = client.call(&input).and_then(|p| client.wait(&p));
        // one client-side span per one-shot op: wall time from submit
        // to answer, as this client observed it (conn = generator
        // thread, request id = op index)
        if let (Some(tr), Some(t0)) = (trace, t0) {
            tr.record(
                Span::new(
                    Phase::Client,
                    tr.next_trace_id(),
                    op as u64,
                    idx as u64,
                    tr.us_of(t0),
                    elapsed_us(t0),
                )
                .with_ok(outcome.is_ok()),
            );
        }
        tally.count(&outcome);
    }
    for _ in 0..sc.streams_per_conn {
        let h = match client.stream_open() {
            Ok(h) => {
                tally.ok += 1;
                h
            }
            Err(e) => {
                tally.count::<()>(&Err(e));
                continue;
            }
        };
        for _ in 0..sc.appends_per_stream {
            // random chunk split: 1–4 word ids per append
            let n = 1 + rng.gen_range(4) as usize;
            let chunk =
                WorkloadInput::Words((0..n).map(|_| rng.gen_range(20) as i64).collect());
            let outcome = client.stream_append(&h, &chunk);
            tally.count(&outcome);
        }
        tally.count(&client.stream_read_out(&h));
        tally.count(&client.stream_close(&h));
    }
    tally
}

/// A slow-loris connection: one valid request trickled byte-by-byte.
/// A correct server answers once the frame completes; its other
/// clients never notice.
fn run_slow_loris(addr: &str, sc: &Scenario, idx: usize, trace: Option<&TraceRecorder>) -> Tally {
    let mut tally = Tally::default();
    let mut rng =
        XorShiftRng::new(sc.seed ^ 0x510F ^ (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let t0 = trace.map(|_| Instant::now());
    let outcome = slow_loris_once(addr, &mut rng);
    // conn ids continue past the request connections so trickle spans
    // never collide with run_conn's in a Perfetto lane
    if let (Some(tr), Some(t0)) = (trace, t0) {
        tr.record(
            Span::new(
                Phase::Client,
                tr.next_trace_id(),
                0,
                (sc.connections + idx) as u64,
                tr.us_of(t0),
                elapsed_us(t0),
            )
            .with_ok(outcome.is_ok()),
        );
    }
    tally.count(&outcome);
    tally
}

/// Trickle one valid request byte-by-byte and require its answer.
fn slow_loris_once(addr: &str, rng: &mut XorShiftRng) -> Result<()> {
    let mut s = TcpStream::connect(addr)?;
    s.set_nodelay(true).ok();
    s.set_read_timeout(Some(Duration::from_secs(20)))?;
    let words = random_words(rng);
    let payload = crate::serve::encode_infer_request(&words).map_err(anyhow::Error::from)?;
    let frame =
        crate::serve::Frame::new(crate::serve::PayloadType::InferRequest, 1, payload).encode();
    for b in frame {
        s.write_all(&[b])?;
        std::thread::sleep(Duration::from_millis(2));
    }
    // the server must answer the completed frame
    let mut reader = crate::serve::FrameReader::new(s);
    let f = reader
        .next_frame()
        .map_err(anyhow::Error::from)?
        .ok_or_else(|| anyhow::anyhow!("connection closed before the trickled answer"))?;
    anyhow::ensure!(
        f.payload_type == crate::serve::PayloadType::InferResponse,
        "trickled request answered with {:?}",
        f.payload_type
    );
    Ok(())
}

/// Throw seeded malformed frames at the server. Every mutation must be
/// answered with an error frame or a clean close — a hang or a panic
/// fails the scenario as a transport error. Fuzz outcomes do not count
/// toward `ok`/`errors`: the envelope judges the legitimate traffic.
fn run_fuzz(addr: &str, sc: &Scenario) -> Tally {
    let mut tally = Tally::default();
    let mut rng = XorShiftRng::new(sc.seed ^ 0xF0_22);
    for _ in 0..sc.fuzz_frames {
        let outcome = fuzz_once(addr, &mut rng);
        if outcome.is_err() {
            tally.transport += 1;
        }
    }
    tally
}

/// One fuzz shot: mutate a valid frame, send it, and require an error
/// frame or EOF within the timeout.
fn fuzz_once(addr: &str, rng: &mut XorShiftRng) -> Result<()> {
    let payload = crate::serve::encode_infer_request(&[1, 2, 3]).map_err(anyhow::Error::from)?;
    let mut bytes =
        crate::serve::Frame::new(crate::serve::PayloadType::InferRequest, 9, payload).encode();
    match rng.gen_range(4) {
        0 => {
            // truncate mid-frame
            let keep = 1 + rng.gen_range(bytes.len() as u64 - 1) as usize;
            bytes.truncate(keep);
        }
        1 => {
            // flip one byte anywhere (magic, version, type, CRC, …)
            let i = rng.gen_range(bytes.len() as u64) as usize;
            bytes[i] ^= 1 << rng.gen_range(8);
        }
        2 => {
            // oversized length prefix
            let n = (crate::serve::MAX_PAYLOAD as u32) + 1 + rng.gen_range(1 << 16) as u32;
            bytes[16..20].copy_from_slice(&n.to_be_bytes());
        }
        _ => {
            // unknown payload type
            bytes[5] = 0x20 + rng.gen_range(0x5F) as u8;
        }
    }
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(Duration::from_secs(10)))?;
    s.write_all(&bytes)?;
    let _ = s.shutdown(std::net::Shutdown::Write);
    // drain: either an error frame arrives or the server closes; a
    // read timeout means the connection wedged — the one failure mode
    let mut buf = [0u8; 1024];
    loop {
        match s.read(&mut buf) {
            Ok(0) => return Ok(()),
            Ok(_) => continue,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                anyhow::bail!("server neither answered nor closed a malformed-frame connection")
            }
            Err(_) => return Ok(()),
        }
    }
}

/// The TCP transport histogram delta between two snapshots (so a
/// long-lived server's history does not pollute this run's envelope).
fn tcp_delta(before: &StatsSnapshot, after: &StatsSnapshot) -> Option<TransportStats> {
    let b = before.transport(Transport::Tcp);
    let a = after.transport(Transport::Tcp)?;
    let (b_count, b_sum, b_buckets) = match b {
        Some(b) => (b.count, b.sum_us, b.buckets.clone()),
        None => (0, 0, vec![0; a.buckets.len()]),
    };
    Some(TransportStats {
        transport: Transport::Tcp,
        count: a.count.saturating_sub(b_count),
        sum_us: a.sum_us.saturating_sub(b_sum),
        buckets: a
            .buckets
            .iter()
            .zip(b_buckets.iter().chain(std::iter::repeat(&0)))
            .map(|(x, y)| x.saturating_sub(*y))
            .collect(),
    })
}

/// Drive `scenario` at the server on `addr` and judge the run against
/// its envelope. The report's `violations` list is empty on a pass;
/// the CLI exits nonzero otherwise.
pub fn run_scenario(addr: &str, scenario: &Scenario) -> Result<LoadgenReport> {
    run_scenario_traced(addr, scenario, None)
}

/// [`run_scenario`] with client-side span recording: each one-shot
/// request and slow-loris trickle records one `client` phase span
/// (submit → answer, as this generator observed it). Pass `None` for
/// the untraced behavior; the caller owns exporting the recorder
/// (`impulse loadgen --trace-dir`). Fuzz shots are not traced — their
/// timing measures the mutation schedule, not the server.
pub fn run_scenario_traced(
    addr: &str,
    scenario: &Scenario,
    trace: Option<Arc<TraceRecorder>>,
) -> Result<LoadgenReport> {
    run_scenario_chaos(addr, scenario, trace, None)
}

/// [`run_scenario_traced`] with one scheduled mid-run fault. For
/// [`ChaosMode::Stall`] and [`ChaosMode::Blackhole`] the traffic is
/// driven through an interposed [`FaultRelay`] whose mode flips to
/// the fault `after` into the run and back to pass-through `duration`
/// later — the server is untouched, the *path* degrades, so the run
/// measures client (or proxy) resilience. [`ChaosMode::Kill`] sends
/// `kill -9` to the given pid instead. The envelope's before/after
/// stats are always read from `addr` directly, never through the
/// relay, and the post-run liveness probe runs after the fault window
/// has closed.
pub fn run_scenario_chaos(
    addr: &str,
    scenario: &Scenario,
    trace: Option<Arc<TraceRecorder>>,
    chaos: Option<ChaosSpec>,
) -> Result<LoadgenReport> {
    let relay = match chaos.as_ref().and_then(|c| c.mode.fault_mode()) {
        Some(_) => Some(Arc::new(FaultRelay::start(addr)?)),
        None => None,
    };
    // stall/blackhole interpose the relay on the traffic path; kill
    // (and no chaos at all) drive the server directly
    let target = match &relay {
        Some(r) => r.local_addr().to_string(),
        None => addr.to_string(),
    };

    let mut stats_client = FrameClient::connect(addr)
        .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e} (is `impulse serve` up?)"))?;
    stats_client.hello()?;
    let (before, _) = stats_client.stats()?;

    let t0 = Instant::now();
    // the fault clock starts with the traffic
    let chaos_timer = chaos.map(|spec| {
        let relay = relay.clone();
        std::thread::spawn(move || {
            std::thread::sleep(spec.after);
            match (spec.mode, relay) {
                (ChaosMode::Kill { pid }, _) => {
                    let _ = std::process::Command::new("kill")
                        .args(["-9", &pid.to_string()])
                        .status();
                }
                (mode, Some(relay)) => {
                    if let Some(m) = mode.fault_mode() {
                        relay.set_mode(m);
                        std::thread::sleep(spec.duration);
                        relay.set_mode(FaultMode::Pass);
                    }
                }
                (_, None) => {}
            }
        })
    });

    let mut threads: Vec<std::thread::JoinHandle<Tally>> = Vec::new();
    for idx in 0..scenario.connections {
        let addr = target.clone();
        let sc = scenario.clone();
        let trace = trace.clone();
        threads.push(std::thread::spawn(move || {
            if sc.ramp_ms > 0 && sc.connections > 1 {
                // stagger starts across the ramp window
                let delay = sc.ramp_ms * idx as u64 / sc.connections as u64;
                std::thread::sleep(Duration::from_millis(delay));
            }
            run_conn(&addr, &sc, idx, trace.as_deref())
        }));
    }
    for idx in 0..scenario.slow_loris {
        let addr = target.clone();
        let sc = scenario.clone();
        let trace = trace.clone();
        threads.push(std::thread::spawn(move || {
            run_slow_loris(&addr, &sc, idx, trace.as_deref())
        }));
    }
    if scenario.fuzz_frames > 0 {
        let addr = target.clone();
        let sc = scenario.clone();
        threads.push(std::thread::spawn(move || run_fuzz(&addr, &sc)));
    }

    let mut total = Tally::default();
    for t in threads {
        let tally = t.join().map_err(|_| anyhow::anyhow!("scenario worker panicked"))?;
        total.ok += tally.ok;
        total.errors += tally.errors;
        total.transport += tally.transport;
    }
    let elapsed = t0.elapsed();

    // the fault window is part of the run: wait until the path is
    // restored (or the kill has fired) before judging liveness
    if let Some(t) = chaos_timer {
        let _ = t.join();
    }

    // liveness probe: after fuzz/slow-loris/chaos abuse a fresh client
    // must still be served normally (through the restored relay when
    // one is interposed)
    let mut probe = FrameClient::connect(target.as_str())?;
    probe.hello()?;
    let pending = probe.call(&WorkloadInput::Words(vec![1, 2, 3]))?;
    let live = probe.wait(&pending);
    match live {
        Ok(_) => total.ok += 1,
        Err(ref e) if e.downcast_ref::<ServerError>().is_some() => total.errors += 1,
        Err(_) => total.transport += 1,
    }

    let (after, _) = stats_client.stats()?;
    let p99_us = tcp_delta(&before, &after).map(|d| d.quantile_us(0.99)).unwrap_or(0);

    let mut report = LoadgenReport {
        ok: total.ok,
        errors: total.errors,
        transport_errors: total.transport,
        p99_us,
        throughput_rps: total.ok as f64 / elapsed.as_secs_f64().max(1e-9),
        violations: Vec::new(),
    };
    let env = &scenario.envelope;
    if report.ok < env.min_ok {
        report.violations.push(format!(
            "completed {} operations, envelope requires >= {}",
            report.ok, env.min_ok
        ));
    }
    if report.error_rate() > env.max_error_rate {
        report.violations.push(format!(
            "error rate {:.3} ({} errors + {} transport over {} attempts) exceeds envelope {:.3}",
            report.error_rate(),
            report.errors,
            report.transport_errors,
            report.attempted(),
            env.max_error_rate
        ));
    }
    if env.max_p99_us > 0 && report.p99_us > env.max_p99_us {
        report.violations.push(format!(
            "p99 latency {}us exceeds envelope {}us",
            report.p99_us, env.max_p99_us
        ));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_and_unknown_does_not() {
        for name in BUILTIN_SCENARIOS {
            let s = Scenario::builtin(name).expect(name);
            assert_eq!(s.name, name);
            assert!(s.envelope.min_ok >= 1);
        }
        assert!(Scenario::builtin("nope").is_none());
    }

    #[test]
    fn scenario_file_overrides_defaults() {
        let dir = std::env::temp_dir().join(format!("impulse-ldg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("scenario.toml");
        std::fs::write(
            &path,
            "[scenario]\nname = \"custom\"\nseed = 99\nconnections = 3\nmix_digits = 0.25\n\
             fuzz_frames = 5\n\n[envelope]\nmin_ok = 4\nmax_error_rate = 0.5\nmax_p99_us = 1000\n",
        )
        .unwrap();
        let s = Scenario::from_file(&path).unwrap();
        assert_eq!(s.name, "custom");
        assert_eq!(s.seed, 99);
        assert_eq!(s.connections, 3);
        assert!((s.mix_digits - 0.25).abs() < 1e-12);
        assert_eq!(s.fuzz_frames, 5);
        assert_eq!(s.envelope.min_ok, 4);
        assert!((s.envelope.max_error_rate - 0.5).abs() < 1e-12);
        assert_eq!(s.envelope.max_p99_us, 1000);
        // unspecified keys keep the smoke defaults
        assert_eq!(s.requests_per_conn, Scenario::default().requests_per_conn);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn chaos_modes_map_to_relay_faults() {
        assert_eq!(ChaosMode::Stall.fault_mode(), Some(FaultMode::Stall));
        assert_eq!(ChaosMode::Blackhole.fault_mode(), Some(FaultMode::Blackhole));
        // kill targets a process, not the relay
        assert_eq!(ChaosMode::Kill { pid: 1 }.fault_mode(), None);
        let spec = ChaosSpec {
            mode: ChaosMode::Stall,
            after: Duration::from_millis(500),
            duration: Duration::from_millis(1000),
        };
        let copy = spec;
        assert_eq!(spec, copy);
    }

    #[test]
    fn report_math_and_envelope_accessors() {
        let r = LoadgenReport {
            ok: 8,
            errors: 1,
            transport_errors: 1,
            p99_us: 500,
            throughput_rps: 100.0,
            violations: vec![],
        };
        assert!(r.is_ok());
        assert_eq!(r.attempted(), 10);
        assert!((r.error_rate() - 0.2).abs() < 1e-12);
        let empty = LoadgenReport::default();
        assert_eq!(empty.error_rate(), 0.0);
    }

    #[test]
    fn tcp_delta_subtracts_history() {
        let row = |count: u64, b4: u64| TransportStats {
            transport: Transport::Tcp,
            count,
            sum_us: count * 10,
            buckets: {
                let mut b = vec![0u64; 28];
                b[4] = b4;
                b
            },
        };
        let before = StatsSnapshot {
            queue_depth: 0,
            queue_soft_limit: 0,
            soft_limited: false,
            batches: 0,
            batch_lanes: 0,
            batch_lane_capacity: 0,
            kinds: vec![],
            instr: vec![],
            transports: vec![row(10, 10)],
        };
        let mut after = before.clone();
        after.transports = vec![row(25, 25)];
        let d = tcp_delta(&before, &after).unwrap();
        assert_eq!(d.count, 15);
        assert_eq!(d.buckets[4], 15);
        // all fifteen new samples sit in bucket 4
        assert_eq!(d.quantile_us(0.99), crate::telemetry::bucket_upper_us(4));
    }
}
