//! Re-execute a recorded capture against a fresh serve core and diff
//! the outcome.
//!
//! The runner replays each recorded connection's inbound bytes —
//! verbatim, at the recorded chunk boundaries — through a real TCP
//! connection to a real [`serve_tcp`] listener over the caller's
//! [`ServeCore`], records the re-execution with the same server-side
//! tap, and diffs the two captures:
//!
//! * **Response frames**, keyed `(connection, request id, occurrence)`
//!   and normalized first (CRC stripped, flags zeroed, and the
//!   timing/placement fields a scheduler is free to vary — latency,
//!   batch, worker, lane — masked; stats responses compare envelope
//!   only). Everything the macro *computed* — predictions, membrane
//!   potentials, cycle counts, error codes — must match bit-for-bit.
//! * **V-digests**, keyed the same way: the FNV-1a checkpoints of
//!   every macro's V_MEM rows must agree exactly. This is the deep
//!   check — two runs can emit identical wire bytes yet hold different
//!   hidden state, and the digest catches it.
//!
//! Responses are compared by request id, not global order: the
//! listener's reader thread answers stream ops and stats inline while
//! the responder thread writes inference responses, so the interleaving
//! of *different* requests on one connection is scheduling — but the
//! frames of one request id are ordered, and all content is pinned.
//!
//! Connections replay sequentially (the recorder forces one worker and
//! batch width 1, so request state never spans connections) and are
//! matched recorded↔replayed by first-appearance order.

use super::{hex, Capture, Event};
use crate::serve::{serve_tcp, ServeCore, CRC_LEN, HEADER_LEN};
use crate::Result;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// How long the replay client waits on a quiet socket before treating
/// the connection as finished (covers worst-case inference latency on
/// a loaded CI runner).
const DRAIN_TIMEOUT: Duration = Duration::from_secs(30);

/// The outcome of one [`replay_capture`] run.
#[derive(Clone, Debug, Default)]
pub struct ReplayReport {
    /// Connections replayed.
    pub connections: usize,
    /// Total inbound bytes written back to the server.
    pub bytes_in: usize,
    /// Outbound frames compared.
    pub frames_out: usize,
    /// V-digest checkpoints compared.
    pub digests: usize,
    /// First divergence found, if any (human-readable, with hex
    /// context); `None` means the replay matched the recording.
    pub divergence: Option<String>,
}

impl ReplayReport {
    /// Whether the replay matched the recording everywhere.
    pub fn is_ok(&self) -> bool {
        self.divergence.is_none()
    }
}

/// Everything recorded for one connection, in event order.
#[derive(Default)]
struct ConnLog {
    /// Inbound byte chunks, at recorded boundaries.
    inbound: Vec<Vec<u8>>,
    /// Encoded outbound frames, in wire order.
    outbound: Vec<Vec<u8>>,
    /// `(request id, digest)` checkpoints, in record order.
    digests: Vec<(u64, u64)>,
}

/// Split a capture into per-connection logs, preserving each
/// connection's first-appearance order (the recorded↔replayed match
/// key).
fn group(cap: &Capture) -> Vec<(u64, ConnLog)> {
    let mut order: Vec<u64> = Vec::new();
    let mut logs: BTreeMap<u64, ConnLog> = BTreeMap::new();
    for e in &cap.events {
        let conn = match e {
            Event::BytesIn { conn, .. }
            | Event::FrameOut { conn, .. }
            | Event::Digest { conn, .. } => *conn,
        };
        if !logs.contains_key(&conn) {
            order.push(conn);
            logs.insert(conn, ConnLog::default());
        }
        let log = logs.get_mut(&conn).expect("just inserted");
        match e {
            Event::BytesIn { bytes, .. } => log.inbound.push(bytes.clone()),
            Event::FrameOut { bytes, .. } => log.outbound.push(bytes.clone()),
            Event::Digest { request_id, digest, .. } => log.digests.push((*request_id, *digest)),
        }
    }
    order
        .into_iter()
        .map(|c| {
            let log = logs.remove(&c).expect("grouped above");
            (c, log)
        })
        .collect()
}

/// Normalize one encoded outbound frame for comparison: strip the CRC
/// trailer, zero the flags word (live backpressure advertisements),
/// and mask the fields a replay is allowed to differ in — wall-clock
/// latency and scheduler placement. Stats responses keep only their
/// envelope (type + request id): their payload is live telemetry,
/// nondeterministic by nature.
fn normalize_frame(bytes: &[u8]) -> Vec<u8> {
    if bytes.len() < HEADER_LEN + CRC_LEN {
        return bytes.to_vec(); // never produced by the server; compare raw
    }
    let mut b = bytes[..bytes.len() - CRC_LEN].to_vec();
    b[6] = 0;
    b[7] = 0;
    match b[5] {
        // InferResponse / DigitsInferResponse: the trailing 12 bytes
        // are latency_us (8) + batch (2) + worker (2)
        0x11 | 0x13 => {
            let n = b.len();
            if n >= HEADER_LEN + 12 {
                for x in &mut b[n - 12..] {
                    *x = 0;
                }
            }
        }
        // StreamAck: bytes 9..11 of the payload are the lane index
        0x1A => {
            if b.len() >= HEADER_LEN + 11 {
                b[HEADER_LEN + 9] = 0;
                b[HEADER_LEN + 10] = 0;
            }
        }
        // StatsResponse: envelope only
        0x15 => {
            b.truncate(HEADER_LEN);
            for x in &mut b[16..20] {
                *x = 0;
            }
        }
        _ => {}
    }
    b
}

/// The request id a server-produced frame answers (bytes 8..16 BE).
fn frame_request_id(bytes: &[u8]) -> u64 {
    if bytes.len() < 16 {
        return u64::MAX;
    }
    u64::from_be_bytes(bytes[8..16].try_into().expect("8 bytes"))
}

/// Frames grouped per request id, normalized, in wire order.
fn frames_by_request(frames: &[Vec<u8>]) -> BTreeMap<u64, Vec<Vec<u8>>> {
    let mut m: BTreeMap<u64, Vec<Vec<u8>>> = BTreeMap::new();
    for f in frames {
        m.entry(frame_request_id(f)).or_default().push(normalize_frame(f));
    }
    m
}

/// Digests grouped per request id, in record order.
fn digests_by_request(digests: &[(u64, u64)]) -> BTreeMap<u64, Vec<u64>> {
    let mut m: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
    for (id, d) in digests {
        m.entry(*id).or_default().push(*d);
    }
    m
}

/// Replay a capture through `core` and diff the re-execution against
/// the recording. The core must have been built to match the capture's
/// recording configuration (same model, artifacts, engine, timestep
/// count — `impulse replay` rebuilds it from the capture metadata) and
/// must not already have a recorder attached.
pub fn replay_capture(capture: &Capture, core: &Arc<ServeCore>) -> Result<ReplayReport> {
    let recorded = group(capture);
    let rec = Arc::new(super::Recorder::in_memory());
    core.set_recorder(Arc::clone(&rec));
    let handle = serve_tcp("127.0.0.1:0", Arc::clone(core))?;
    let addr = handle.local_addr();

    let mut report = ReplayReport { connections: recorded.len(), ..ReplayReport::default() };
    for (_conn, log) in &recorded {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(DRAIN_TIMEOUT))?;
        let mut rx = stream.try_clone()?;
        // Drain concurrently with writing: without a reader the server
        // can fill the socket buffer mid-connection and deadlock the
        // write side. EOF doubles as the completion barrier — the
        // server shuts down its write half only after the responder
        // drained every in-flight answer.
        let drain = std::thread::spawn(move || {
            let mut buf = [0u8; 4096];
            loop {
                match rx.read(&mut buf) {
                    Ok(0) => break,
                    Ok(_) => continue,
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock
                            || e.kind() == std::io::ErrorKind::TimedOut =>
                    {
                        break // quiet too long: treat as finished
                    }
                    Err(_) => break,
                }
            }
        });
        let mut tx = stream;
        for chunk in &log.inbound {
            report.bytes_in += chunk.len();
            if tx.write_all(chunk).is_err() {
                break; // server closed on us (recorded close, fuzz, …)
            }
        }
        let _ = tx.shutdown(Shutdown::Write);
        drain.join().ok();
    }
    handle.stop();

    let replayed = group(&rec.capture());
    let divergence = diff(&recorded, &replayed, &mut report);
    report.divergence = divergence;
    Ok(report)
}

/// First divergence between the recorded and replayed logs, if any.
fn diff(
    recorded: &[(u64, ConnLog)],
    replayed: &[(u64, ConnLog)],
    report: &mut ReplayReport,
) -> Option<String> {
    if recorded.len() != replayed.len() {
        return Some(format!(
            "connection count diverged: recorded {}, replayed {}",
            recorded.len(),
            replayed.len()
        ));
    }
    for (ix, ((rc, rlog), (_pc, plog))) in recorded.iter().zip(replayed).enumerate() {
        let tag = format!("connection {} (recorded id {rc})", ix + 1);

        let want = frames_by_request(&rlog.outbound);
        let got = frames_by_request(&plog.outbound);
        for (id, wf) in &want {
            let gf = got.get(id).map(Vec::as_slice).unwrap_or(&[]);
            if wf.len() != gf.len() {
                return Some(format!(
                    "{tag}, request {id}: recorded {} response frame(s), replay produced {}",
                    wf.len(),
                    gf.len()
                ));
            }
            for (occ, (w, g)) in wf.iter().zip(gf).enumerate() {
                report.frames_out += 1;
                if w != g {
                    return Some(format!(
                        "{tag}, request {id}, frame {}: response bytes diverged\n  recorded  {}\n  replayed  {}",
                        occ + 1,
                        hex(w),
                        hex(g)
                    ));
                }
            }
        }
        if let Some(extra) = got.keys().find(|id| !want.contains_key(id)) {
            return Some(format!(
                "{tag}: replay produced response frames for request {extra} that were never recorded"
            ));
        }

        let want = digests_by_request(&rlog.digests);
        let got = digests_by_request(&plog.digests);
        for (id, wd) in &want {
            let gd = got.get(id).map(Vec::as_slice).unwrap_or(&[]);
            if wd.len() != gd.len() {
                return Some(format!(
                    "{tag}, request {id}: recorded {} V-digest(s), replay produced {}",
                    wd.len(),
                    gd.len()
                ));
            }
            for (occ, (w, g)) in wd.iter().zip(gd).enumerate() {
                report.digests += 1;
                if w != g {
                    return Some(format!(
                        "{tag}, request {id}, checkpoint {}: V-digest diverged: recorded {w:016x}, replayed {g:016x}",
                        occ + 1
                    ));
                }
            }
        }
        if let Some(extra) = got.keys().find(|id| !want.contains_key(id)) {
            return Some(format!(
                "{tag}: replay produced V-digests for request {extra} that were never recorded"
            ));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(ptype: u8, id: u64, payload: &[u8], flags: u16) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"IMP1");
        b.push(1);
        b.push(ptype);
        b.extend_from_slice(&flags.to_be_bytes());
        b.extend_from_slice(&id.to_be_bytes());
        b.extend_from_slice(&(payload.len() as u32).to_be_bytes());
        b.extend_from_slice(payload);
        let crc = crate::serve::crc32(&b);
        b.extend_from_slice(&crc.to_be_bytes());
        b
    }

    #[test]
    fn normalize_masks_flags_and_timing_fields() {
        // InferResponse: 29-byte payload, last 12 = latency/batch/worker
        let mut p1 = vec![1u8; 29];
        let mut p2 = p1.clone();
        p1[17..29].copy_from_slice(&[9; 12]);
        p2[17..29].copy_from_slice(&[3; 12]);
        let a = normalize_frame(&frame(0x11, 7, &p1, 0x8001));
        let b = normalize_frame(&frame(0x11, 7, &p2, 0x0000));
        assert_eq!(a, b);
        // but the computed fields still compare
        let mut p3 = p1.clone();
        p3[0] = 0; // flip the prediction
        assert_ne!(normalize_frame(&frame(0x11, 7, &p3, 0)), a);
    }

    #[test]
    fn normalize_masks_stream_ack_lane_but_not_cycles() {
        let mut a = vec![0u8; 19];
        let mut b = vec![0u8; 19];
        a[9] = 1; // lane 1
        b[9] = 2; // lane 2
        let norm = |p: &[u8]| normalize_frame(&frame(0x1A, 3, p, 0));
        assert_eq!(norm(&a), norm(&b));
        let mut c = a.clone();
        c[11] = 99; // cycles differ
        assert_ne!(norm(&c), norm(&a));
    }

    #[test]
    fn normalize_reduces_stats_to_envelope() {
        let a = normalize_frame(&frame(0x15, 5, &[1, 2, 3], 0));
        let b = normalize_frame(&frame(0x15, 5, &[9, 9, 9, 9, 9], 0));
        assert_eq!(a, b);
        assert_ne!(a, normalize_frame(&frame(0x15, 6, &[1, 2, 3], 0)));
    }

    #[test]
    fn grouping_preserves_first_appearance_order() {
        let cap = Capture {
            meta: vec![],
            events: vec![
                Event::BytesIn { conn: 9, bytes: vec![1] },
                Event::BytesIn { conn: 2, bytes: vec![2] },
                Event::FrameOut { conn: 9, bytes: vec![3] },
                Event::Digest { conn: 2, request_id: 1, digest: 42 },
            ],
        };
        let g = group(&cap);
        assert_eq!(g.len(), 2);
        assert_eq!(g[0].0, 9);
        assert_eq!(g[1].0, 2);
        assert_eq!(g[0].1.outbound, vec![vec![3]]);
        assert_eq!(g[1].1.digests, vec![(1, 42)]);
    }
}
