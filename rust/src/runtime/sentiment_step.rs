//! The AOT-compiled quantized sentiment timestep
//! (`artifacts/sentiment_step.hlo.txt`).
//!
//! Signature (all int32, batch 1; the weight matrices are passed as
//! parameters because `as_hlo_text()` elides large constants):
//!   inputs:  x_q[1,M], v_e[1,M], v1[1,H1], v2[1,H2], v_o[1,1],
//!            w1[M,H1], w2[H1,H2], w_out[H2,1]
//!   outputs: (v_e', v1', v2', v_o', s1[1,H1], s2[1,H2])

use super::HloRuntime;
use crate::Result;
use anyhow::Context;
use std::path::Path;

/// Mutable network state carried across timesteps.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepState {
    pub v_e: Vec<i32>,
    pub v1: Vec<i32>,
    pub v2: Vec<i32>,
    pub v_o: i32,
}

impl StepState {
    pub fn zeros(m: usize, h1: usize, h2: usize) -> Self {
        Self {
            v_e: vec![0; m],
            v1: vec![0; h1],
            v2: vec![0; h2],
            v_o: 0,
        }
    }
}

/// Output spikes of one executed step.
#[derive(Clone, Debug)]
pub struct StepSpikes {
    pub s1: Vec<i32>,
    pub s2: Vec<i32>,
}

/// The compiled step function.
pub struct SentimentStepRuntime {
    rt: HloRuntime,
    pub m: usize,
    pub h1: usize,
    pub h2: usize,
    w1: Vec<i32>,
    w2: Vec<i32>,
    w_out: Vec<i32>,
}

impl SentimentStepRuntime {
    /// Load from the artifact bundle (HLO text + weight tensors).
    pub fn load(artifacts_dir: impl AsRef<Path>, m: usize, h1: usize, h2: usize) -> Result<Self> {
        let dir = artifacts_dir.as_ref();
        let path = dir.join("sentiment_step.hlo.txt");
        let flat_i32 = |name: &str| -> Result<Vec<i32>> {
            Ok(crate::data::Tensor::read(dir.join("sentiment").join(name))?
                .to_i64()?
                .iter()
                .map(|&v| v as i32)
                .collect())
        };
        let w1 = flat_i32("w1.bin")?;
        let w2 = flat_i32("w2.bin")?;
        let w_out = flat_i32("w_out.bin")?;
        anyhow::ensure!(w1.len() == m * h1 && w2.len() == h1 * h2 && w_out.len() == h2);
        Ok(Self {
            rt: HloRuntime::load(&path).context("load sentiment step HLO")?,
            m,
            h1,
            h2,
            w1,
            w2,
            w_out,
        })
    }

    /// Run one timestep in place; returns the hidden-layer spikes.
    pub fn step(&self, x_q: &[i32], state: &mut StepState) -> Result<StepSpikes> {
        anyhow::ensure!(x_q.len() == self.m, "x_q length {}", x_q.len());
        let outs = self.rt.execute_i32(&[
            (x_q.to_vec(), vec![1, self.m]),
            (state.v_e.clone(), vec![1, self.m]),
            (state.v1.clone(), vec![1, self.h1]),
            (state.v2.clone(), vec![1, self.h2]),
            (vec![state.v_o], vec![1, 1]),
            (self.w1.clone(), vec![self.m, self.h1]),
            (self.w2.clone(), vec![self.h1, self.h2]),
            (self.w_out.clone(), vec![self.h2, 1]),
        ])?;
        anyhow::ensure!(outs.len() == 6, "expected 6 outputs, got {}", outs.len());
        state.v_e = outs[0].clone();
        state.v1 = outs[1].clone();
        state.v2 = outs[2].clone();
        state.v_o = outs[3][0];
        Ok(StepSpikes {
            s1: outs[4].clone(),
            s2: outs[5].clone(),
        })
    }

    /// Classify a full review through the XLA path.
    pub fn run_review(
        &self,
        emb_q: &[Vec<i64>],
        word_ids: &[i64],
        t_word: usize,
    ) -> Result<(u8, Vec<i32>)> {
        let mut state = StepState::zeros(self.m, self.h1, self.h2);
        let mut trace = Vec::new();
        for &wid in word_ids {
            if wid < 0 {
                break;
            }
            let x: Vec<i32> = emb_q[wid as usize].iter().map(|&v| v as i32).collect();
            for _ in 0..t_word {
                self.step(&x, &mut state)?;
            }
            trace.push(state.v_o);
        }
        Ok(((state.v_o >= 0) as u8, trace))
    }
}
