//! PJRT runtime: load the AOT-compiled JAX graphs (HLO text) and run
//! them from Rust — the L2↔L3 bridge.
//!
//! HLO *text* is the interchange format (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see aot_recipe / DESIGN.md §3).
//!
//! The runtime serves two roles:
//! 1. cross-validation — the quantized sentiment step executed through
//!    XLA must match the macro simulator bit-for-bit (`impulse eval
//!    --xla-check`), anchoring the whole serving stack — including the
//!    TCP/stdio front-end in [`crate::serve`] — to the trained JAX
//!    model;
//! 2. a reference execution path for the serving examples.
//!
//! The PJRT client needs the external `xla` crate, which is not
//! available in the offline build; it is gated behind the `xla` cargo
//! feature. Without the feature, [`HloRuntime::load`] returns a clean
//! error and every cross-check that needs it reports itself as
//! unavailable instead of failing the build.

mod sentiment_step;

pub use sentiment_step::{SentimentStepRuntime, StepState};

use crate::Result;
use std::path::Path;

#[cfg(feature = "xla")]
use anyhow::Context;

/// A compiled HLO executable on the PJRT CPU client.
#[cfg(feature = "xla")]
pub struct HloRuntime {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "xla")]
impl HloRuntime {
    /// Load HLO text from a file and compile it.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compile HLO")?;
        Ok(Self { client, exe })
    }

    /// Execute with i32 tensor inputs; returns the flattened i32
    /// outputs of the result tuple.
    pub fn execute_i32(&self, inputs: &[(Vec<i32>, Vec<usize>)]) -> Result<Vec<Vec<i32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data.as_slice());
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(lit.reshape(&dims).context("reshape input literal")?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("execute")?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        // jax lowering uses return_tuple=True
        let mut result = result;
        let elems = result.decompose_tuple().context("decompose tuple")?;
        let mut out = Vec::with_capacity(elems.len());
        for e in elems {
            out.push(e.to_vec::<i32>().context("read output")?);
        }
        Ok(out)
    }

    /// The PJRT platform (for diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Stub used when the crate is built without the `xla` feature: the
/// public surface is identical, but loading reports a clean error so
/// callers (CLI `--xla-check`, integration tests) can degrade.
#[cfg(not(feature = "xla"))]
pub struct HloRuntime {
    _private: (),
}

#[cfg(not(feature = "xla"))]
impl HloRuntime {
    /// Always errors: the PJRT client was compiled out.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        anyhow::bail!(
            "cannot load {}: this build has no PJRT runtime (the `xla` feature needs the \
             external `xla` crate vendored as a dependency, which the offline build omits)",
            path.as_ref().display()
        )
    }

    /// Unreachable in practice — the stub cannot be constructed.
    pub fn execute_i32(&self, _inputs: &[(Vec<i32>, Vec<usize>)]) -> Result<Vec<Vec<i32>>> {
        anyhow::bail!("PJRT runtime unavailable (built without the `xla` feature)")
    }

    /// The PJRT platform (for diagnostics).
    pub fn platform(&self) -> String {
        "unavailable (no `xla` feature)".to_string()
    }
}

/// True when the crate was built with the PJRT/XLA runtime compiled in.
pub fn xla_available() -> bool {
    cfg!(feature = "xla")
}
