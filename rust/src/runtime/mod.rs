//! PJRT runtime: load the AOT-compiled JAX graphs (HLO text) and run
//! them from Rust — the L2↔L3 bridge.
//!
//! HLO *text* is the interchange format (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see aot_recipe / DESIGN.md §3).
//!
//! The runtime serves two roles:
//! 1. cross-validation — the quantized sentiment step executed through
//!    XLA must match the macro simulator bit-for-bit;
//! 2. a reference execution path for the serving examples.

mod sentiment_step;

pub use sentiment_step::{SentimentStepRuntime, StepState};

use crate::Result;
use anyhow::Context;
use std::path::Path;

/// A compiled HLO executable on the PJRT CPU client.
pub struct HloRuntime {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

impl HloRuntime {
    /// Load HLO text from a file and compile it.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compile HLO")?;
        Ok(Self { client, exe })
    }

    /// Execute with i32 tensor inputs; returns the flattened i32
    /// outputs of the result tuple.
    pub fn execute_i32(&self, inputs: &[(Vec<i32>, Vec<usize>)]) -> Result<Vec<Vec<i32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data.as_slice());
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(lit.reshape(&dims).context("reshape input literal")?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("execute")?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        // jax lowering uses return_tuple=True
        let mut result = result;
        let elems = result.decompose_tuple().context("decompose tuple")?;
        let mut out = Vec::with_capacity(elems.len());
        for e in elems {
            out.push(e.to_vec::<i32>().context("read output")?);
        }
        Ok(out)
    }

    /// The PJRT platform (for diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
