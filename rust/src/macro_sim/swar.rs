//! SWAR (SIMD-within-a-register) arithmetic over the six 11-bit value
//! fields of a packed V_MEM row.
//!
//! A V_MEM row stores six membrane potentials in 12-column fields at
//! stride [`FIELD_WIDTH`], based at the parity's stagger offset. Within
//! a field the 11 value bits occupy offsets {0..4, 6..11}; offset 5 —
//! the hole column that carries the weight sign bit during AccW2V — is
//! hardware-forced to 0. Because a field is exactly one bit wider than
//! the value it stores, *closing the hole* ([`pack`]) leaves one
//! carry-guard bit at the top of every 12-bit lane: two 11-bit
//! operands sum to at most `0x7FF + 0x7FF = 0xFFE`, so a plain `u128`
//! add never carries across lanes, and one AND with [`VAL_MASK`] wraps
//! all six sums mod 2048 at once ([`add_wrap`]). The fast engine
//! executes AccW2V / AccV2V / SpikeCheck on all six fields per
//! instruction this way — two shifts, two masks, one add — instead of
//! six extract-field/insert-field round-trips.
//!
//! All helpers operate on *stagger-normalized* rows (`row >>
//! parity.stagger()`); callers shift back when writing to V_MEM.

use super::ComparatorMode;
use crate::bitcell::{FIELD_WIDTH, VALUES_PER_ROW};

/// Replicate a ≤ 12-bit per-lane pattern into all six field lanes.
const fn rep(v: u128) -> u128 {
    let mut m = 0u128;
    let mut g = 0;
    while g < VALUES_PER_ROW {
        m |= v << (g * FIELD_WIDTH);
        g += 1;
    }
    m
}

/// Low 5 value bits of every lane (field offsets 0..4).
pub const LOW5: u128 = rep(0x01F);
/// Stored high 6 value bits of every lane (field offsets 6..11).
pub const HI6_STORED: u128 = rep(0xFC0);
/// Hole-closed high 6 value bits of every lane (offsets 5..10).
pub const HI6_PACKED: u128 = rep(0x7E0);
/// All 12 field bits of every lane.
pub const FIELD_MASK: u128 = rep(0xFFF);
/// The 11 value bits of every hole-closed lane — the per-lane mod-2048
/// wrap mask. Bit 11 of each lane is the carry guard it clears.
pub const VAL_MASK: u128 = rep(0x7FF);
/// Bit 0 of every lane (the indicator position).
pub const LANE_LSB: u128 = rep(1);

/// Close the hole of every field of a stagger-normalized row, leaving
/// six 11-bit unsigned (mod-2048) values in 12-bit lanes with one
/// carry-guard bit each.
#[inline]
pub fn pack(row: u128) -> u128 {
    (row & LOW5) | ((row >> 1) & HI6_PACKED)
}

/// Re-open the hole: the inverse of [`pack`] for lane values within
/// [`VAL_MASK`]. The hole bit of every produced field is 0, preserving
/// the stored-row invariant.
#[inline]
pub fn unpack(vals: u128) -> u128 {
    (vals & LOW5) | ((vals << 1) & HI6_STORED)
}

/// Add two packed operands lane-wise and wrap every lane mod 2048 —
/// the six-field AccW2V/AccV2V adder. Each lane's carry lands in its
/// own guard bit and is cleared by the wrap mask; lanes never
/// interact.
#[inline]
pub fn add_wrap(a: u128, b: u128) -> u128 {
    (a + b) & VAL_MASK
}

/// Lane `g` of a packed word as a sign-extended 11-bit value in
/// [-1024, 1023].
#[inline]
pub fn lane(vals: u128, g: usize) -> i64 {
    let u = ((vals >> (g * FIELD_WIDTH)) as u64) & 0x7FF;
    ((u as i64) << 53) >> 53
}

/// Pack six 11-bit signed values into lanes (their mod-2048 images).
/// Test/bring-up helper — the engines build packed words with
/// [`pack`].
pub fn from_lanes(vals: &[i64; VALUES_PER_ROW]) -> u128 {
    let mut w = 0u128;
    for (g, &v) in vals.iter().enumerate() {
        w |= (((v as u64) & 0x7FF) as u128) << (g * FIELD_WIDTH);
    }
    w
}

/// Expand a per-lane indicator word (bit 0 of each lane, as produced
/// by [`spike_indicators`]) into a full-field write mask — `0xFFF` in
/// every indicated lane. Lanes are exactly 12 bits wide, so the
/// multiply cannot carry between lanes.
#[inline]
pub fn expand_mask(ind: u128) -> u128 {
    ind * 0xFFF
}

/// Per-lane spike indicators of a SpikeCheck, from the *unwrapped*
/// lane-wise sum `pack(v) + pack(−θ)`:
///
/// - [`ComparatorMode::SignBit`]: spike ⇔ sign bit (bit 10) of the
///   wrapped sum is 0 — masking the guard bit never changes bit 10.
/// - [`ComparatorMode::MsbCout`]: spike ⇔ unsigned carry out of the
///   11-bit add, i.e. the guard bit (bit 11) itself.
#[inline]
pub fn spike_indicators(sum: u128, mode: ComparatorMode) -> u128 {
    match mode {
        ComparatorMode::SignBit => (!(sum >> 10)) & LANE_LSB,
        ComparatorMode::MsbCout => (sum >> 11) & LANE_LSB,
    }
}

/// Indicator word with bit 0 of lane `g` set for every `true` flag —
/// the bridge from the spike-buffer bank to [`expand_mask`].
#[inline]
pub fn indicators_from_flags(flags: &[bool; VALUES_PER_ROW]) -> u128 {
    let mut ind = 0u128;
    for (g, &f) in flags.iter().enumerate() {
        ind |= (f as u128) << (g * FIELD_WIDTH);
    }
    ind
}

/// Read the indicator bit of lane `g`.
#[inline]
pub fn indicator(ind: u128, g: usize) -> bool {
    (ind >> (g * FIELD_WIDTH)) & 1 == 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitcell::{FieldLayout, Parity};
    use crate::bits::wrap11;
    use crate::proptest_lite::forall_ctx;

    /// Six values hitting the carry-guard edges (±1024, ±1023, 0) more
    /// often than uniform sampling would.
    fn edgy_values(rng: &mut crate::bits::XorShiftRng) -> [i64; 6] {
        let edges = [-1024i64, -1023, -1, 0, 1, 1022, 1023];
        let mut v = [0i64; 6];
        for x in v.iter_mut() {
            *x = if rng.gen_bool(0.4) {
                edges[rng.gen_i64(0, edges.len() as i64 - 1) as usize]
            } else {
                rng.gen_i64(-1024, 1023)
            };
        }
        v
    }

    #[test]
    fn pack_unpack_roundtrips_encoded_rows() {
        forall_ctx(
            300,
            0x5174,
            |rng| {
                let parity = if rng.gen_bool(0.5) {
                    Parity::Odd
                } else {
                    Parity::Even
                };
                (edgy_values(rng), parity)
            },
            |&(vals, parity)| {
                let l = FieldLayout::new(parity);
                let row = l.encode_row(&vals);
                let st = parity.stagger();
                let packed = pack(row >> st);
                for (g, &v) in vals.iter().enumerate() {
                    if lane(packed, g) != v {
                        return Err(format!("lane {g}: {} != {v}", lane(packed, g)));
                    }
                }
                if (unpack(packed) << st) != row {
                    return Err("unpack is not the inverse of pack".into());
                }
                Ok(())
            },
        );
    }

    /// The headline property: the SWAR six-field adder is bit-identical
    /// to per-field extract/insert arithmetic (`wrap11` per field),
    /// for random rows of both parities including the carry-guard edge
    /// values ±1024/±1023.
    #[test]
    fn swar_adder_matches_per_field_wrap11() {
        forall_ctx(
            500,
            0xADD5,
            |rng| {
                let parity = if rng.gen_bool(0.5) {
                    Parity::Odd
                } else {
                    Parity::Even
                };
                (edgy_values(rng), edgy_values(rng), parity)
            },
            |&(a, b, parity)| {
                let l = FieldLayout::new(parity);
                let st = parity.stagger();
                let pa = pack(l.encode_row(&a) >> st);
                let pb = pack(l.encode_row(&b) >> st);
                let sum = add_wrap(pa, pb);
                for g in 0..6 {
                    let want = wrap11(a[g] + b[g]);
                    let got = lane(sum, g);
                    if got != want {
                        return Err(format!("f{g}: {} + {} -> {got}, want {want}", a[g], b[g]));
                    }
                }
                // and the re-packed row decodes to the same values
                let row = unpack(sum) << st;
                for g in 0..6 {
                    if l.decode_value(row, g) != wrap11(a[g] + b[g]) {
                        return Err(format!("re-packed field {g} diverges"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Repeated SWAR accumulation (the AccW2V stream pattern: wrap
    /// after every add) equals a single wrap of the i64 sum.
    #[test]
    fn chained_adds_commute_with_wrapping() {
        forall_ctx(
            200,
            0xCAB1,
            |rng| {
                let n = rng.gen_i64(1, 20) as usize;
                (0..n).map(|_| edgy_values(rng)).collect::<Vec<[i64; 6]>>()
            },
            |terms| {
                let mut acc = 0u128;
                for t in terms {
                    acc = add_wrap(acc, from_lanes(t));
                }
                for g in 0..6 {
                    let want = wrap11(terms.iter().map(|t| t[g]).sum());
                    if lane(acc, g) != want {
                        return Err(format!("field {g}: {} != {want}", lane(acc, g)));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn spike_indicators_match_scalar_comparator() {
        forall_ctx(
            400,
            0x59CC,
            |rng| (edgy_values(rng), edgy_values(rng)),
            |&(v, t)| {
                let pv = from_lanes(&v);
                let pt = from_lanes(&t);
                let sum = pv + pt;
                for mode in [ComparatorMode::SignBit, ComparatorMode::MsbCout] {
                    let ind = spike_indicators(sum, mode);
                    for g in 0..6 {
                        let want = super::super::impulse::compare(mode, v[g], t[g]);
                        if indicator(ind, g) != want {
                            return Err(format!("{mode:?} field {g}: v={} t={}", v[g], t[g]));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn expand_mask_covers_indicated_lanes_exactly() {
        for bits in 0..64u32 {
            let mut flags = [false; 6];
            for (g, f) in flags.iter_mut().enumerate() {
                *f = (bits >> g) & 1 == 1;
            }
            let m = expand_mask(indicators_from_flags(&flags));
            for (g, &f) in flags.iter().enumerate() {
                let lane_bits = (m >> (g * FIELD_WIDTH)) & 0xFFF;
                assert_eq!(lane_bits, if f { 0xFFF } else { 0 }, "bits={bits:#x} g={g}");
            }
            assert_eq!(m & !FIELD_MASK, 0);
        }
    }
}
