//! The IMPULSE macro facade and its two execution engines.

use super::{swar, ComparatorMode, Engine, MacroConfig, TraceEvent, Tracer};
use crate::bitcell::{
    encode_weight_row, weight_index, BitArray, DualRead, FieldLayout, Parity, RowAddr,
    TripleRowDecoder, COL_MASK, FIELD_WIDTH, VALUES_PER_ROW, V_ROWS, W_ROWS,
};
use crate::bits::{wrap11, V_BITS};
use crate::isa::verify;
use crate::isa::{Instruction, InstructionKind, NeuronConfigRows, NeuronType, WriteMaskMode};
use crate::periph::{ColumnAdder, ConditionalWriteDriver, SpikeBuffers, WriteGate};
use anyhow::{bail, Result};

/// Architectural effects of one executed instruction.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ExecOutput {
    /// Values written back this cycle (post-write row content of the
    /// destination's active fields), if the instruction wrote.
    pub written: Option<[i64; 6]>,
    /// Spike buffer bank contents after this cycle, if it latched them.
    pub spikes: Option<[bool; 6]>,
    /// Values read out (ReadV).
    pub read: Option<[i64; 6]>,
}

/// Shared per-instruction compute: the comparator decision.
#[inline]
pub(crate) fn compare(mode: ComparatorMode, v: i64, neg_thr: i64) -> bool {
    match mode {
        ComparatorMode::SignBit => wrap11(v + neg_thr) >= 0,
        ComparatorMode::MsbCout => {
            let m = 1i64 << V_BITS;
            let vu = (v + m) % m;
            let tu = (neg_thr + m) % m;
            vu + tu >= m
        }
    }
}

pub use crate::isa::verify::MAX_FUSED_LANES;

fn parity_ix(p: Parity) -> usize {
    match p {
        Parity::Odd => 0,
        Parity::Even => 1,
    }
}

// ---------------------------------------------------------------------
// Bit-level engine
// ---------------------------------------------------------------------

/// Reference engine: simulates wordlines, bitlines, and every column
/// peripheral.
#[derive(Clone, Debug)]
struct BitLevelEngine {
    wmem: BitArray,
    vmem: BitArray,
    spikebuf: [SpikeBuffers; 2],
    decoder: TripleRowDecoder,
    comparator: ComparatorMode,
}

impl BitLevelEngine {
    fn new(comparator: ComparatorMode) -> Self {
        Self {
            wmem: BitArray::new(W_ROWS),
            vmem: BitArray::new(V_ROWS),
            spikebuf: [SpikeBuffers::new(), SpikeBuffers::new()],
            decoder: TripleRowDecoder,
            comparator,
        }
    }

    fn exec(&mut self, instr: &Instruction) -> Result<ExecOutput> {
        match *instr {
            Instruction::AccW2V {
                w_row,
                v_src,
                v_dst,
                parity,
            } => {
                self.decoder.decode(
                    &[RowAddr::W(w_row), RowAddr::V(v_src)],
                    Some(RowAddr::V(v_dst)),
                    parity,
                )?;
                let l = FieldLayout::new(parity);
                let sensed = DualRead::combine(
                    self.wmem.read_masked(w_row, l.w_drive_mask()),
                    self.vmem.read_masked(v_src, COL_MASK),
                );
                let out = ColumnAdder::for_acc_w2v(parity).propagate(&sensed);
                let cwd = ConditionalWriteDriver::new(parity);
                let mask = cwd.drive_mask(WriteGate::AllFields, &[false; 6]);
                self.vmem.write_masked(v_dst, out.sum, mask);
                let mut written = [0i64; 6];
                for g in 0..VALUES_PER_ROW {
                    written[g] = l.decode_value(self.vmem.row(v_dst), g);
                }
                Ok(ExecOutput {
                    written: Some(written),
                    ..Default::default()
                })
            }
            Instruction::AccV2V {
                src_a,
                src_b,
                dst,
                parity,
                mask,
            } => {
                self.decoder.decode(
                    &[RowAddr::V(src_a), RowAddr::V(src_b)],
                    Some(RowAddr::V(dst)),
                    parity,
                )?;
                let l = FieldLayout::new(parity);
                let sensed = DualRead::combine(
                    self.vmem.read_masked(src_a, COL_MASK),
                    self.vmem.read_masked(src_b, COL_MASK),
                );
                let out = ColumnAdder::for_v_plus_v(parity).propagate(&sensed);
                let gate = match mask {
                    WriteMaskMode::All => WriteGate::AllFields,
                    WriteMaskMode::Spiked => WriteGate::SpikedFields,
                };
                let cwd = ConditionalWriteDriver::new(parity);
                let wmask = cwd.drive_mask(gate, self.spikebuf[parity_ix(parity)].bits());
                self.vmem.write_masked(dst, out.sum, wmask);
                let mut written = [0i64; 6];
                for g in 0..VALUES_PER_ROW {
                    written[g] = l.decode_value(self.vmem.row(dst), g);
                }
                Ok(ExecOutput {
                    written: Some(written),
                    ..Default::default()
                })
            }
            Instruction::SpikeCheck {
                v_row,
                thr_row,
                parity,
            } => {
                self.decoder
                    .decode(&[RowAddr::V(v_row), RowAddr::V(thr_row)], None, parity)?;
                let sensed = DualRead::combine(
                    self.vmem.read_masked(v_row, COL_MASK),
                    self.vmem.read_masked(thr_row, COL_MASK),
                );
                let out = ColumnAdder::for_v_plus_v(parity).propagate(&sensed);
                let mut spikes = [false; 6];
                for g in 0..VALUES_PER_ROW {
                    spikes[g] = match self.comparator {
                        // sign bit 0 ⇒ V − θ ≥ 0 ⇒ spike
                        ComparatorMode::SignBit => !out.fields[g].sign,
                        ComparatorMode::MsbCout => out.fields[g].msb_cout,
                    };
                }
                self.spikebuf[parity_ix(parity)].latch(spikes);
                Ok(ExecOutput {
                    spikes: Some(spikes),
                    ..Default::default()
                })
            }
            Instruction::ResetV {
                reset_row,
                dst,
                parity,
            } => {
                self.decoder
                    .decode(&[RowAddr::V(reset_row)], Some(RowAddr::V(dst)), parity)?;
                // BLFA bypassed: the sensed reset value feeds the CWD.
                let sensed = self.vmem.read_masked(reset_row, COL_MASK);
                let cwd = ConditionalWriteDriver::new(parity);
                let spiked = self.spikebuf[parity_ix(parity)].bits();
                let wmask = cwd.drive_mask(WriteGate::SpikedFields, spiked);
                self.vmem.write_masked(dst, sensed.or, wmask);
                let l = FieldLayout::new(parity);
                let mut written = [0i64; 6];
                for g in 0..VALUES_PER_ROW {
                    written[g] = l.decode_value(self.vmem.row(dst), g);
                }
                Ok(ExecOutput {
                    written: Some(written),
                    ..Default::default()
                })
            }
            Instruction::ReadV { v_row, parity } => {
                self.decoder.decode(&[RowAddr::V(v_row)], None, parity)?;
                let l = FieldLayout::new(parity);
                let mut read = [0i64; 6];
                for g in 0..VALUES_PER_ROW {
                    read[g] = l.decode_value(self.vmem.row(v_row), g);
                }
                Ok(ExecOutput {
                    read: Some(read),
                    ..Default::default()
                })
            }
            Instruction::WriteV {
                v_row,
                parity,
                values,
            } => {
                self.decoder
                    .decode(&[], Some(RowAddr::V(v_row)), parity)?;
                let l = FieldLayout::new(parity);
                let encoded = l.encode_row(&values);
                self.vmem.write_masked(v_row, encoded, l.all_fields_mask());
                Ok(ExecOutput {
                    written: Some(values),
                    ..Default::default()
                })
            }
            Instruction::WriteW { w_row, weights } => {
                self.wmem.set_row(w_row, encode_weight_row(&weights));
                Ok(ExecOutput::default())
            }
        }
    }
}

// ---------------------------------------------------------------------
// Fast (word-level) engine
// ---------------------------------------------------------------------

/// Functional engine: same architectural state (packed rows), SWAR
/// word arithmetic (see [`swar`]) instead of per-column ripple — every
/// V-row instruction touches all six fields in a handful of u128 ops.
/// Weights additionally kept as per-parity SWAR addend words (written
/// rarely, read on every AccW2V).
#[derive(Clone, Debug)]
struct FastEngine {
    /// Packed V_MEM rows — authoritative, identical format to silicon.
    vmem: Vec<u128>,
    /// Per-parity SWAR weight addends, `w_swar[row][parity_ix]`: lane
    /// `g` holds the mod-2048 image of the weight AccW2V accumulates
    /// into field `g` under that parity (stagger-normalized).
    w_swar: Vec<[u128; 2]>,
    /// Packed W_MEM rows (kept for digest parity with the bit engine).
    wmem_packed: Vec<u128>,
    spikebuf: [SpikeBuffers; 2],
    comparator: ComparatorMode,
}

/// Extract field `g` (parity-aligned) of a packed row as an i64 in
/// [-1024, 1023]: low 5 bits | (top 6 bits << 5), sign-extended.
/// Single-field reference path; the engines use [`swar::pack`] +
/// [`swar::lane`] to extract all six at once.
#[inline]
pub(crate) fn extract_field(row: u128, g: usize, parity: Parity) -> i64 {
    let base = crate::bitcell::field_base(g, parity);
    let f = ((row >> base) & 0xFFF) as u32;
    let low = f & 0x1F;
    let high = (f >> 6) & 0x3F;
    let u = low | (high << 5); // 11-bit unsigned
    ((u as i64) << 53) >> 53 // sign-extend from bit 10
}

/// Encode an 11-bit signed value into its parity-aligned field
/// position. Single-field reference path; the engines use
/// [`swar::unpack`] to re-open all six holes at once.
#[inline]
pub(crate) fn insert_field(row: &mut u128, g: usize, parity: Parity, v: i64) {
    let base = crate::bitcell::field_base(g, parity);
    let u = (v as u64) & 0x7FF;
    let f = (u & 0x1F) | ((u >> 5) << 6); // re-open the hole at bit 5
    *row = (*row & !(0xFFFu128 << base)) | ((f as u128) << base);
}

impl FastEngine {
    fn new(comparator: ComparatorMode) -> Self {
        Self {
            vmem: vec![0u128; V_ROWS],
            w_swar: vec![[0u128; 2]; W_ROWS],
            wmem_packed: vec![0u128; W_ROWS],
            spikebuf: [SpikeBuffers::new(), SpikeBuffers::new()],
            comparator,
        }
    }

    /// Prevalidated straight-line runner for a fused union-AccW2V
    /// stream: the caller (see [`ImpulseMacro::acc_w2v_fused`]) has
    /// already proven the stream against the shared
    /// [`verify::check_fused_stream`] contract (row ranges, lane
    /// masks, distinct lanes, strictly ascending union rows), so this
    /// path issues no per-instruction enum dispatch and
    /// constructs no `Result` or [`ExecOutput`] — per union row it is
    /// one SWAR add per masked lane, and per touched lane one
    /// pack/add/unpack round-trip against V_MEM.
    fn run_accw2v_stream(
        &mut self,
        rows: &[(usize, u32)],
        lane_v_rows: &[usize],
        parity: Parity,
    ) {
        let pix = parity_ix(parity);
        let st = parity.stagger();
        let mut acc = [0u128; MAX_FUSED_LANES];
        let mut touched = 0u32;
        for &(w_row, mask) in rows {
            let wsw = self.w_swar[w_row][pix];
            let mut mm = mask;
            while mm != 0 {
                let b = mm.trailing_zeros() as usize;
                mm &= mm - 1;
                acc[b] = swar::add_wrap(acc[b], wsw);
            }
            touched |= mask;
        }
        let mut mm = touched;
        while mm != 0 {
            let b = mm.trailing_zeros() as usize;
            mm &= mm - 1;
            let row = self.vmem[lane_v_rows[b]];
            let sum = swar::add_wrap(swar::pack(row >> st), acc[b]);
            self.vmem[lane_v_rows[b]] =
                (row & !(swar::FIELD_MASK << st)) | (swar::unpack(sum) << st);
        }
    }

    /// Execute one instruction. Structural validity (row ranges,
    /// source aliasing) is the caller's contract —
    /// [`ImpulseMacro::execute`] gates every instruction through
    /// [`verify::check_instruction`] before any engine runs.
    fn exec(&mut self, instr: &Instruction) -> Result<ExecOutput> {
        match *instr {
            Instruction::AccW2V {
                w_row,
                v_src,
                v_dst,
                parity,
            } => {
                // SWAR: all six fields accumulate their weight in one
                // pack → add-wrap → unpack round-trip.
                let st = parity.stagger();
                let sum = swar::add_wrap(
                    swar::pack(self.vmem[v_src] >> st),
                    self.w_swar[w_row][parity_ix(parity)],
                );
                let dst = self.vmem[v_dst];
                self.vmem[v_dst] = (dst & !(swar::FIELD_MASK << st)) | (swar::unpack(sum) << st);
                let mut written = [0i64; 6];
                for (g, w) in written.iter_mut().enumerate() {
                    *w = swar::lane(sum, g);
                }
                Ok(ExecOutput {
                    written: Some(written),
                    ..Default::default()
                })
            }
            Instruction::AccV2V {
                src_a,
                src_b,
                dst,
                parity,
                mask,
            } => {
                let st = parity.stagger();
                let wrapped = swar::add_wrap(
                    swar::pack(self.vmem[src_a] >> st),
                    swar::pack(self.vmem[src_b] >> st),
                );
                let gate = match mask {
                    WriteMaskMode::All => swar::FIELD_MASK << st,
                    WriteMaskMode::Spiked => {
                        let spikes = self.spikebuf[parity_ix(parity)].bits();
                        swar::expand_mask(swar::indicators_from_flags(spikes)) << st
                    }
                };
                let d = self.vmem[dst];
                let new = (d & !gate) | ((swar::unpack(wrapped) << st) & gate);
                self.vmem[dst] = new;
                let mut written = [0i64; 6];
                for (g, w) in written.iter_mut().enumerate() {
                    *w = extract_field(new, g, parity);
                }
                Ok(ExecOutput {
                    written: Some(written),
                    ..Default::default()
                })
            }
            Instruction::SpikeCheck {
                v_row,
                thr_row,
                parity,
            } => {
                let st = parity.stagger();
                let sum = swar::pack(self.vmem[v_row] >> st)
                    + swar::pack(self.vmem[thr_row] >> st);
                let ind = swar::spike_indicators(sum, self.comparator);
                let mut spikes = [false; 6];
                for (g, s) in spikes.iter_mut().enumerate() {
                    *s = swar::indicator(ind, g);
                }
                self.spikebuf[parity_ix(parity)].latch(spikes);
                Ok(ExecOutput {
                    spikes: Some(spikes),
                    ..Default::default()
                })
            }
            Instruction::ResetV {
                reset_row,
                dst,
                parity,
            } => {
                let st = parity.stagger();
                let spikes = self.spikebuf[parity_ix(parity)].bits();
                let gate = swar::expand_mask(swar::indicators_from_flags(spikes)) << st;
                let d = (self.vmem[dst] & !gate) | (self.vmem[reset_row] & gate);
                self.vmem[dst] = d;
                let mut written = [0i64; 6];
                for (g, w) in written.iter_mut().enumerate() {
                    *w = extract_field(d, g, parity);
                }
                Ok(ExecOutput {
                    written: Some(written),
                    ..Default::default()
                })
            }
            Instruction::ReadV { v_row, parity } => {
                let lanes = swar::pack(self.vmem[v_row] >> parity.stagger());
                let mut read = [0i64; 6];
                for (g, r) in read.iter_mut().enumerate() {
                    *r = swar::lane(lanes, g);
                }
                Ok(ExecOutput {
                    read: Some(read),
                    ..Default::default()
                })
            }
            Instruction::WriteV {
                v_row,
                parity,
                values,
            } => {
                let mut row = self.vmem[v_row];
                for g in 0..VALUES_PER_ROW {
                    assert!(
                        crate::bits::fits(values[g], V_BITS),
                        "WriteV value {} out of 11-bit range",
                        values[g]
                    );
                    insert_field(&mut row, g, parity, values[g]);
                }
                self.vmem[v_row] = row;
                Ok(ExecOutput {
                    written: Some(values),
                    ..Default::default()
                })
            }
            Instruction::WriteW { w_row, weights } => {
                for &w in weights.iter() {
                    assert!(
                        crate::bits::fits(w, crate::bits::W_BITS),
                        "weight {w} out of 6-bit range"
                    );
                }
                let mut sw = [0u128; 2];
                for (pix, parity) in Parity::BOTH.iter().enumerate() {
                    for g in 0..VALUES_PER_ROW {
                        let w = weights[weight_index(g, *parity)];
                        sw[pix] |= (((w as u64) & 0x7FF) as u128) << (g * FIELD_WIDTH);
                    }
                }
                self.w_swar[w_row] = sw;
                self.wmem_packed[w_row] = encode_weight_row(&weights);
                Ok(ExecOutput::default())
            }
        }
    }
}

// ---------------------------------------------------------------------
// Facade
// ---------------------------------------------------------------------

/// One IMPULSE macro instance (128×78 W_MEM + 32×78 V_MEM + periphery).
#[derive(Clone, Debug)]
pub struct ImpulseMacro {
    config: MacroConfig,
    bit: Option<BitLevelEngine>,
    fast: Option<FastEngine>,
    cycle: u64,
    counts: [u64; 7],
    tracer: Tracer,
}

impl ImpulseMacro {
    pub fn new(config: MacroConfig) -> Self {
        let (bit, fast) = match config.engine {
            Engine::BitLevel => (Some(BitLevelEngine::new(config.comparator)), None),
            Engine::Fast => (None, Some(FastEngine::new(config.comparator))),
            Engine::Lockstep => (
                Some(BitLevelEngine::new(config.comparator)),
                Some(FastEngine::new(config.comparator)),
            ),
        };
        Self {
            config,
            bit,
            fast,
            cycle: 0,
            counts: [0; 7],
            tracer: Tracer::default(),
        }
    }

    /// Run one instruction through the configured engine(s) without
    /// touching the cycle counters (lockstep mode cross-checks state).
    fn exec_engines(&mut self, instr: &Instruction) -> Result<ExecOutput> {
        match (&mut self.bit, &mut self.fast) {
            (Some(b), None) => b.exec(instr),
            (None, Some(f)) => f.exec(instr),
            (Some(b), Some(f)) => {
                let ob = b.exec(instr)?;
                let of = f.exec(instr)?;
                if ob != of {
                    bail!(
                        "engine divergence on {instr:?}: bit-level {ob:?} vs fast {of:?}"
                    );
                }
                // Compare V_MEM state digests.
                for r in 0..V_ROWS {
                    if b.vmem.row(r) != f.vmem[r] {
                        bail!(
                            "V_MEM divergence at row {r} after {instr:?}: \
                             bit={:#x} fast={:#x}",
                            b.vmem.row(r),
                            f.vmem[r]
                        );
                    }
                }
                Ok(ob)
            }
            (None, None) => unreachable!("no engine configured"),
        }
    }

    /// Execute one instruction; returns its architectural effects.
    ///
    /// Every instruction first passes the shared structural validator
    /// ([`verify::check_instruction`]) — one contract for the
    /// bit-level engine, the fast engine, and lockstep. A rejected
    /// instruction leaves state, counters, and trace untouched.
    pub fn execute(&mut self, instr: &Instruction) -> Result<ExecOutput> {
        verify::check_instruction(instr)?;
        let out = self.exec_engines(instr)?;
        let k = instr.kind();
        self.counts[kind_ix(k)] += 1;
        self.cycle += 1;
        if self.config.trace {
            self.tracer.record(TraceEvent {
                cycle: self.cycle,
                kind: k,
                parity: instr.parity(),
                written: out.written,
                spikes: out.spikes,
            });
        }
        Ok(out)
    }

    /// Run a whole program, returning the last output.
    pub fn run(&mut self, program: &crate::isa::Program) -> Result<ExecOutput> {
        let mut last = ExecOutput::default();
        for i in program {
            last = self.execute(i)?;
        }
        Ok(last)
    }

    /// Batched AccW2V: issue one `AccW2V {w_row, v_src: v_row, v_dst:
    /// v_row, parity}` per entry of `w_rows`, semantically identical to
    /// the per-instruction loop (mod-2048 accumulation commutes with
    /// wrapping) but decoding/encoding the V-row fields once.
    ///
    /// This is the coordinator's hot path (one call per spiking-input
    /// burst per tile per timestep); the per-instruction cycle/energy
    /// accounting is preserved exactly. Falls back to the instruction
    /// loop on the bit-level/lockstep engines and when tracing.
    pub fn acc_w2v_batch(
        &mut self,
        w_rows: &[usize],
        v_row: usize,
        parity: Parity,
    ) -> Result<()> {
        let fast_only = self.bit.is_none() && !self.config.trace;
        if !fast_only {
            for &w_row in w_rows {
                self.execute(&Instruction::AccW2V {
                    w_row,
                    v_src: v_row,
                    v_dst: v_row,
                    parity,
                })?;
            }
            return Ok(());
        }
        verify::check_v_row(v_row)?;
        for &w_row in w_rows {
            verify::check_w_row(w_row)?;
        }
        let f = self.fast.as_mut().expect("fast engine");
        // SWAR accumulation: one add-wrap per spiking row folds all six
        // fields' weights at once (mod-2048 per add commutes with the
        // single final wrap of the scalar path).
        let pix = parity_ix(parity);
        let mut acc = 0u128;
        for &w_row in w_rows {
            acc = swar::add_wrap(acc, f.w_swar[w_row][pix]);
        }
        let st = parity.stagger();
        let row = f.vmem[v_row];
        let sum = swar::add_wrap(swar::pack(row >> st), acc);
        f.vmem[v_row] = (row & !(swar::FIELD_MASK << st)) | (swar::unpack(sum) << st);
        self.counts[kind_ix(InstructionKind::AccW2V)] += w_rows.len() as u64;
        self.cycle += w_rows.len() as u64;
        Ok(())
    }

    /// Fused batched AccW2V stream — the batching counterpart of
    /// [`ImpulseMacro::acc_w2v_batch`]. `rows` lists each spiking input
    /// row in the *union across batch lanes*, with a bitmask of the
    /// lanes whose input spiked; `lane_v_rows[b]` is lane b's membrane
    /// V row. Each union row is issued as a single instruction whose
    /// wordline read is broadcast to every masked lane's write-back
    /// (per-lane write enable), so the AccW2V count — and cycle cost —
    /// is `rows.len()` regardless of how many lanes latch it. This is
    /// the peripheral-cost amortization that makes batched inference
    /// cheaper than per-request issue.
    ///
    /// Functionally each lane accumulates exactly its own spiking rows
    /// (mod-2048 accumulation commutes with wrapping), so results are
    /// bit-identical to issuing the per-lane instruction streams.
    pub fn acc_w2v_fused(
        &mut self,
        rows: &[(usize, u32)],
        lane_v_rows: &[usize],
        parity: Parity,
    ) -> Result<()> {
        // Validate the whole stream before touching any state, so a
        // malformed entry cannot leave earlier rows committed (keeps
        // post-error state identical across engines). The contract —
        // lane count/range/uniqueness, mask width, strictly ascending
        // union rows — is the shared fused-stream precondition set.
        verify::check_fused_stream(rows, lane_v_rows)?;
        let fast_only = self.bit.is_none() && !self.config.trace;
        if !fast_only {
            // Bit-level / lockstep / tracing path: run the per-lane
            // effects through the engines, but keep fused accounting.
            for &(w_row, mask) in rows {
                let mut mm = mask;
                let mut last = ExecOutput::default();
                while mm != 0 {
                    let b = mm.trailing_zeros() as usize;
                    mm &= mm - 1;
                    let v = lane_v_rows[b];
                    last = self.exec_engines(&Instruction::AccW2V {
                        w_row,
                        v_src: v,
                        v_dst: v,
                        parity,
                    })?;
                }
                self.counts[kind_ix(InstructionKind::AccW2V)] += 1;
                self.cycle += 1;
                if self.config.trace {
                    self.tracer.record(TraceEvent {
                        cycle: self.cycle,
                        kind: InstructionKind::AccW2V,
                        parity: Some(parity),
                        written: last.written,
                        spikes: None,
                    });
                }
            }
            return Ok(());
        }
        // Straight-line SWAR runner: the stream above is fully
        // validated, so no further dispatch or per-instruction output
        // happens on this path.
        let f = self.fast.as_mut().expect("fast engine");
        f.run_accw2v_stream(rows, lane_v_rows, parity);
        self.counts[kind_ix(InstructionKind::AccW2V)] += rows.len() as u64;
        self.cycle += rows.len() as u64;
        Ok(())
    }

    /// Fused RMP neuron update on one V row: SpikeCheck against the
    /// negated-threshold row, then the spike-gated AccV2V soft reset —
    /// the Fig 6 RMP sequence — decoding the operand rows once.
    /// Semantics, spike-buffer state, and accounting (2 instructions,
    /// 2 cycles) are identical to issuing the two instructions through
    /// [`ImpulseMacro::execute`]; this is the batched serve path's hot
    /// kernel. Falls back to the instruction loop on the
    /// bit-level/lockstep engines and when tracing.
    pub fn rmp_update_fused(
        &mut self,
        v_row: usize,
        neg_thr_row: usize,
        parity: Parity,
    ) -> Result<[bool; 6]> {
        let seq = [
            Instruction::SpikeCheck {
                v_row,
                thr_row: neg_thr_row,
                parity,
            },
            Instruction::AccV2V {
                src_a: v_row,
                src_b: neg_thr_row,
                dst: v_row,
                parity,
                mask: WriteMaskMode::Spiked,
            },
        ];
        let fast_only = self.bit.is_none() && !self.config.trace;
        if !fast_only {
            for instr in &seq {
                self.execute(instr)?;
            }
            return Ok(self.spikes(parity));
        }
        for instr in &seq {
            verify::check_instruction(instr)?;
        }
        let f = self.fast.as_mut().expect("fast engine");
        // SWAR: one lane-wise add yields both the spike decision (sign
        // or carry-guard bit per lane) and the soft-reset sum; spiking
        // lanes select the wrapped sum via the expanded gate mask.
        let st = parity.stagger();
        let v = f.vmem[v_row];
        let sum = swar::pack(v >> st) + swar::pack(f.vmem[neg_thr_row] >> st);
        let ind = swar::spike_indicators(sum, f.comparator);
        let gate = swar::expand_mask(ind) << st;
        let stored = swar::unpack(sum & swar::VAL_MASK) << st;
        f.vmem[v_row] = (v & !gate) | (stored & gate);
        let mut spikes = [false; 6];
        for (g, s) in spikes.iter_mut().enumerate() {
            *s = swar::indicator(ind, g);
        }
        f.spikebuf[parity_ix(parity)].latch(spikes);
        self.counts[kind_ix(InstructionKind::SpikeCheck)] += 1;
        self.counts[kind_ix(InstructionKind::AccV2V)] += 1;
        self.cycle += 2;
        Ok(spikes)
    }

    /// Fused IF neuron update on one V row: SpikeCheck against the
    /// negated-threshold row, then the spike-gated hard reset from the
    /// reset row — the Fig 6 IF sequence — decoding the operand rows
    /// once. Semantics, spike-buffer state, and accounting
    /// (2 instructions, 2 cycles) are identical to issuing the two
    /// instructions through [`ImpulseMacro::execute`]. Falls back to
    /// the instruction loop on the bit-level/lockstep engines and when
    /// tracing.
    pub fn if_update_fused(
        &mut self,
        v_row: usize,
        neg_thr_row: usize,
        reset_row: usize,
        parity: Parity,
    ) -> Result<[bool; 6]> {
        let seq = [
            Instruction::SpikeCheck {
                v_row,
                thr_row: neg_thr_row,
                parity,
            },
            Instruction::ResetV {
                reset_row,
                dst: v_row,
                parity,
            },
        ];
        let fast_only = self.bit.is_none() && !self.config.trace;
        if !fast_only {
            for instr in &seq {
                self.execute(instr)?;
            }
            return Ok(self.spikes(parity));
        }
        for instr in &seq {
            verify::check_instruction(instr)?;
        }
        let f = self.fast.as_mut().expect("fast engine");
        // SWAR: spike decision per lane from one add; hard reset is a
        // raw field-bit copy of the reset row under the expanded gate,
        // exactly like ResetV.
        let st = parity.stagger();
        let v = f.vmem[v_row];
        let sum = swar::pack(v >> st) + swar::pack(f.vmem[neg_thr_row] >> st);
        let ind = swar::spike_indicators(sum, f.comparator);
        let gate = swar::expand_mask(ind) << st;
        f.vmem[v_row] = (v & !gate) | (f.vmem[reset_row] & gate);
        let mut spikes = [false; 6];
        for (g, s) in spikes.iter_mut().enumerate() {
            *s = swar::indicator(ind, g);
        }
        f.spikebuf[parity_ix(parity)].latch(spikes);
        self.counts[kind_ix(InstructionKind::SpikeCheck)] += 1;
        self.counts[kind_ix(InstructionKind::ResetV)] += 1;
        self.cycle += 2;
        Ok(spikes)
    }

    /// Fused LIF neuron update on one V row: the unconditional leak
    /// AccV2V, SpikeCheck against the negated-threshold row, then the
    /// spike-gated hard reset — the Fig 6 LIF sequence — decoding the
    /// operand rows once. Semantics, spike-buffer state, and
    /// accounting (3 instructions, 3 cycles) are identical to issuing
    /// the three instructions through [`ImpulseMacro::execute`]. Falls
    /// back to the instruction loop on the bit-level/lockstep engines
    /// and when tracing.
    pub fn lif_update_fused(
        &mut self,
        v_row: usize,
        neg_thr_row: usize,
        reset_row: usize,
        neg_leak_row: usize,
        parity: Parity,
    ) -> Result<[bool; 6]> {
        let seq = [
            Instruction::AccV2V {
                src_a: v_row,
                src_b: neg_leak_row,
                dst: v_row,
                parity,
                mask: WriteMaskMode::All,
            },
            Instruction::SpikeCheck {
                v_row,
                thr_row: neg_thr_row,
                parity,
            },
            Instruction::ResetV {
                reset_row,
                dst: v_row,
                parity,
            },
        ];
        let fast_only = self.bit.is_none() && !self.config.trace;
        if !fast_only {
            for instr in &seq {
                self.execute(instr)?;
            }
            return Ok(self.spikes(parity));
        }
        for instr in &seq {
            verify::check_instruction(instr)?;
        }
        let f = self.fast.as_mut().expect("fast engine");
        // SWAR: leak all six lanes with one add-wrap, derive the spike
        // decision from a second lane-wise add, then hard-reset the
        // spiking lanes by raw field-bit copy. In the unfused sequence
        // ResetV reads the reset row *after* the leak AccV2V wrote V —
        // so when reset_row aliases v_row, the spiked-field "reset" is
        // a self-copy of the leaked value (gate suppressed).
        let st = parity.stagger();
        let v = f.vmem[v_row];
        let leaked = swar::add_wrap(
            swar::pack(v >> st),
            swar::pack(f.vmem[neg_leak_row] >> st),
        );
        let sum = leaked + swar::pack(f.vmem[neg_thr_row] >> st);
        let ind = swar::spike_indicators(sum, f.comparator);
        let fields = swar::FIELD_MASK << st;
        let mut d = (v & !fields) | (swar::unpack(leaked) << st);
        if reset_row != v_row {
            let gate = swar::expand_mask(ind) << st;
            d = (d & !gate) | (f.vmem[reset_row] & gate);
        }
        f.vmem[v_row] = d;
        let mut spikes = [false; 6];
        for (g, s) in spikes.iter_mut().enumerate() {
            *s = swar::indicator(ind, g);
        }
        f.spikebuf[parity_ix(parity)].latch(spikes);
        self.counts[kind_ix(InstructionKind::AccV2V)] += 1;
        self.counts[kind_ix(InstructionKind::SpikeCheck)] += 1;
        self.counts[kind_ix(InstructionKind::ResetV)] += 1;
        self.cycle += 3;
        Ok(spikes)
    }

    /// Fused end-of-timestep neuron update for any [`NeuronType`] —
    /// dispatches to the type's fused kernel
    /// ([`ImpulseMacro::if_update_fused`],
    /// [`ImpulseMacro::lif_update_fused`],
    /// [`ImpulseMacro::rmp_update_fused`]), each bit-identical in
    /// state, spikes, and accounting to the corresponding
    /// [`crate::isa::neuron_sequence`] issued instruction by
    /// instruction. This is the batched serve path's per-lane hot
    /// kernel.
    pub fn neuron_update_fused(
        &mut self,
        neuron: NeuronType,
        v_row: usize,
        rows: NeuronConfigRows,
        parity: Parity,
    ) -> Result<[bool; 6]> {
        match neuron {
            NeuronType::IF => {
                self.if_update_fused(v_row, rows.neg_threshold, rows.reset, parity)
            }
            NeuronType::LIF => self.lif_update_fused(
                v_row,
                rows.neg_threshold,
                rows.reset,
                rows.neg_leak,
                parity,
            ),
            NeuronType::RMP => self.rmp_update_fused(v_row, rows.neg_threshold, parity),
        }
    }

    // ---- convenience accessors -------------------------------------

    /// Program all twelve weights of a W_MEM row.
    pub fn write_weights(&mut self, w_row: usize, weights: &[i64; 12]) -> Result<()> {
        self.execute(&Instruction::WriteW {
            w_row,
            weights: *weights,
        })
        .map(|_| ())
    }

    /// Program six values of a V_MEM row in the given parity alignment.
    pub fn write_v(&mut self, v_row: usize, parity: Parity, values: &[i64; 6]) -> Result<()> {
        self.execute(&Instruction::WriteV {
            v_row,
            parity,
            values: *values,
        })
        .map(|_| ())
    }

    /// Read six values of a V_MEM row (does not count as a CIM cycle
    /// in the paper's accounting; still counted as ReadV).
    pub fn read_v(&mut self, v_row: usize, parity: Parity) -> Result<[i64; 6]> {
        Ok(self
            .execute(&Instruction::ReadV { v_row, parity })?
            .read
            .expect("ReadV returns values"))
    }

    /// Current spike-buffer bank for a parity.
    pub fn spikes(&self, parity: Parity) -> [bool; 6] {
        let ix = parity_ix(parity);
        match (&self.bit, &self.fast) {
            (Some(b), _) => *b.spikebuf[ix].bits(),
            (None, Some(f)) => *f.spikebuf[ix].bits(),
            _ => unreachable!(),
        }
    }

    /// Executed-cycle count.
    pub fn cycles(&self) -> u64 {
        self.cycle
    }

    /// Instruction histogram (indexable by [`InstructionKind`]).
    pub fn counts(&self) -> std::collections::BTreeMap<InstructionKind, u64> {
        ALL_KINDS
            .iter()
            .zip(self.counts.iter())
            .filter(|(_, &c)| c > 0)
            .map(|(k, &c)| (*k, c))
            .collect()
    }

    /// Count for a single kind.
    pub fn count_of(&self, k: InstructionKind) -> u64 {
        self.counts[kind_ix(k)]
    }

    /// Reset instruction counters and cycle clock (state preserved).
    pub fn reset_counters(&mut self) {
        self.counts = [0; 7];
        self.cycle = 0;
        self.tracer.clear();
    }

    /// Recorded trace (empty unless `config.trace`).
    pub fn trace(&self) -> &Tracer {
        &self.tracer
    }

    /// The macro configuration.
    pub fn config(&self) -> &MacroConfig {
        &self.config
    }

    /// Fold this macro's V_MEM rows into an FNV-1a digest accumulator.
    ///
    /// Reads engine state directly — no instruction is issued, so the
    /// cycle clock, instruction counters, and trace are untouched; a
    /// digest taken between requests observes exactly the membrane
    /// state the next request starts from. In lockstep mode the fast
    /// engine is read (exec_engines already proved both agree).
    pub fn fold_vmem_digest(&self, h: &mut u64) {
        for r in 0..V_ROWS {
            let row = match (&self.fast, &self.bit) {
                (Some(f), _) => f.vmem[r],
                (None, Some(b)) => b.vmem.row(r),
                (None, None) => unreachable!("no engine configured"),
            };
            for b in row.to_le_bytes() {
                *h ^= b as u64;
                *h = h.wrapping_mul(0x100_0000_01B3);
            }
        }
    }
}

const ALL_KINDS: [InstructionKind; 7] = [
    InstructionKind::AccW2V,
    InstructionKind::AccV2V,
    InstructionKind::SpikeCheck,
    InstructionKind::ResetV,
    InstructionKind::ReadV,
    InstructionKind::WriteV,
    InstructionKind::WriteW,
];

fn kind_ix(k: InstructionKind) -> usize {
    ALL_KINDS.iter().position(|&x| x == k).unwrap()
}
