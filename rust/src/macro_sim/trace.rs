//! Instruction-level tracing.

use crate::bitcell::Parity;
use crate::isa::InstructionKind;

/// One executed instruction's record.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub cycle: u64,
    pub kind: InstructionKind,
    pub parity: Option<Parity>,
    /// Values written back this cycle (per field), if any.
    pub written: Option<[i64; 6]>,
    /// Spike buffer contents after this cycle (the active parity bank).
    pub spikes: Option<[bool; 6]>,
}

/// Bounded trace recorder (drops oldest beyond `capacity`).
#[derive(Clone, Debug)]
pub struct Tracer {
    events: std::collections::VecDeque<TraceEvent>,
    capacity: usize,
    dropped: u64,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new(1 << 16)
    }
}

impl Tracer {
    pub fn new(capacity: usize) -> Self {
        Self {
            events: std::collections::VecDeque::with_capacity(capacity.min(1024)),
            capacity,
            dropped: 0,
        }
    }

    pub fn record(&mut self, e: TraceEvent) {
        if self.events.len() == self.capacity {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(e);
    }

    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn clear(&mut self) {
        self.events.clear();
        self.dropped = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cycle: u64) -> TraceEvent {
        TraceEvent {
            cycle,
            kind: InstructionKind::AccW2V,
            parity: Some(Parity::Odd),
            written: None,
            spikes: None,
        }
    }

    #[test]
    fn bounded_with_drop_count() {
        let mut t = Tracer::new(3);
        for c in 0..5 {
            t.record(ev(c));
        }
        assert_eq!(t.len(), 3);
        assert_eq!(t.dropped(), 2);
        let cycles: Vec<u64> = t.events().map(|e| e.cycle).collect();
        assert_eq!(cycles, vec![2, 3, 4]);
        t.clear();
        assert!(t.is_empty());
    }
}
