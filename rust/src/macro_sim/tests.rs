//! Differential and behavioural tests of the macro.

use super::*;
use crate::bitcell::Parity;
use crate::bits::{wrap11, XorShiftRng};
use crate::isa::{Instruction, WriteMaskMode};

fn rand_weights(rng: &mut XorShiftRng) -> [i64; 12] {
    let mut w = [0i64; 12];
    for x in w.iter_mut() {
        *x = rng.gen_i64(-32, 31);
    }
    w
}

fn rand_values(rng: &mut XorShiftRng) -> [i64; 6] {
    let mut v = [0i64; 6];
    for x in v.iter_mut() {
        *x = rng.gen_i64(-1024, 1023);
    }
    v
}

fn rand_parity(rng: &mut XorShiftRng) -> Parity {
    if rng.gen_bool(0.5) {
        Parity::Odd
    } else {
        Parity::Even
    }
}

/// Drive a long random CIM instruction stream through the Lockstep
/// engine: any bit-level vs fast divergence fails inside execute().
#[test]
fn engines_agree_on_random_streams() {
    let mut rng = XorShiftRng::new(0xD1FF);
    let mut m = ImpulseMacro::new(MacroConfig::lockstep());
    // Program random weights and V rows.
    for r in 0..16 {
        m.write_weights(r, &rand_weights(&mut rng)).unwrap();
    }
    for r in 0..8 {
        let p = if r % 2 == 0 { Parity::Odd } else { Parity::Even };
        m.write_v(r, p, &rand_values(&mut rng)).unwrap();
    }
    for step in 0..2000 {
        let parity = if rng.gen_bool(0.5) { Parity::Odd } else { Parity::Even };
        // Keep rows parity-consistent: even rows odd-aligned, odd rows
        // even-aligned (as the mapper does).
        let vrow = |rng: &mut XorShiftRng, parity: Parity| -> usize {
            let base = rng.gen_range(4) as usize * 2;
            match parity {
                Parity::Odd => base,
                Parity::Even => base + 1,
            }
        };
        let choice = rng.gen_range(4);
        let instr = match choice {
            0 => Instruction::AccW2V {
                w_row: rng.gen_range(16) as usize,
                v_src: vrow(&mut rng, parity),
                v_dst: vrow(&mut rng, parity),
                parity,
            },
            1 => {
                let a = vrow(&mut rng, parity);
                let mut b = vrow(&mut rng, parity);
                if a == b {
                    b = if a >= 2 { a - 2 } else { a + 2 };
                }
                Instruction::AccV2V {
                    src_a: a,
                    src_b: b,
                    dst: vrow(&mut rng, parity),
                    parity,
                    mask: if rng.gen_bool(0.5) {
                        WriteMaskMode::All
                    } else {
                        WriteMaskMode::Spiked
                    },
                }
            }
            2 => {
                let a = vrow(&mut rng, parity);
                let mut b = vrow(&mut rng, parity);
                if a == b {
                    b = if a >= 2 { a - 2 } else { a + 2 };
                }
                Instruction::SpikeCheck {
                    v_row: a,
                    thr_row: b,
                    parity,
                }
            }
            _ => Instruction::ResetV {
                reset_row: vrow(&mut rng, parity),
                dst: vrow(&mut rng, parity),
                parity,
            },
        };
        m.execute(&instr)
            .unwrap_or_else(|e| panic!("step {step}: {e}"));
    }
    assert_eq!(m.cycles(), 2000 + 16 + 8);
}

#[test]
fn acc_w2v_accumulates_weights() {
    for engine in [MacroConfig::bit_level(), MacroConfig::fast()] {
        let mut m = ImpulseMacro::new(engine);
        let weights: [i64; 12] = [1, -2, 3, -4, 5, -6, 7, -8, 9, -10, 11, -12];
        m.write_weights(0, &weights).unwrap();
        m.write_v(0, Parity::Odd, &[100, 200, 300, 400, 500, 600]).unwrap();
        m.write_v(1, Parity::Even, &[-100, -200, -300, -400, -500, -600]).unwrap();

        let out = m
            .execute(&Instruction::AccW2V {
                w_row: 0,
                v_src: 0,
                v_dst: 0,
                parity: Parity::Odd,
            })
            .unwrap();
        // odd parity accumulates even-indexed weights 1,3,5,7,9,11
        assert_eq!(out.written.unwrap(), [101, 203, 305, 407, 509, 611]);

        let out = m
            .execute(&Instruction::AccW2V {
                w_row: 0,
                v_src: 1,
                v_dst: 1,
                parity: Parity::Even,
            })
            .unwrap();
        // even parity accumulates odd-indexed weights -2,-4,-6,-8,-10,-12
        assert_eq!(out.written.unwrap(), [-102, -204, -306, -408, -510, -612]);
    }
}

#[test]
fn spike_check_and_reset_implements_if_neuron() {
    for cfg in [MacroConfig::bit_level(), MacroConfig::fast()] {
        let mut m = ImpulseMacro::new(cfg);
        let theta = 50i64;
        m.write_v(0, Parity::Odd, &[60, 49, 50, -10, 1000, -1000]).unwrap();
        m.write_v(1, Parity::Odd, &[-theta; 6]).unwrap(); // −θ row
        m.write_v(2, Parity::Odd, &[0; 6]).unwrap(); // reset row

        let out = m
            .execute(&Instruction::SpikeCheck {
                v_row: 0,
                thr_row: 1,
                parity: Parity::Odd,
            })
            .unwrap();
        // Field 5 (V = −1000): V − θ = −1050 underflows the 11-bit adder
        // and wraps positive → the hardware *does* spike. Trained
        // networks keep V away from the rails; the artifact is real.
        assert_eq!(
            out.spikes.unwrap(),
            [true, false, true, false, true, true]
        );

        let out = m
            .execute(&Instruction::ResetV {
                reset_row: 2,
                dst: 0,
                parity: Parity::Odd,
            })
            .unwrap();
        // spiked fields reset to 0, others keep their potential
        assert_eq!(out.written.unwrap(), [0, 49, 0, -10, 0, 0]);
    }
}

#[test]
fn rmp_soft_reset_keeps_residual() {
    for cfg in [MacroConfig::bit_level(), MacroConfig::fast()] {
        let mut m = ImpulseMacro::new(cfg);
        let theta = 100i64;
        m.write_v(0, Parity::Odd, &[150, 99, 100, 730, -5, 1023]).unwrap();
        m.write_v(1, Parity::Odd, &[-theta; 6]).unwrap();

        m.execute(&Instruction::SpikeCheck {
            v_row: 0,
            thr_row: 1,
            parity: Parity::Odd,
        })
        .unwrap();
        let out = m
            .execute(&Instruction::AccV2V {
                src_a: 0,
                src_b: 1,
                dst: 0,
                parity: Parity::Odd,
                mask: WriteMaskMode::Spiked,
            })
            .unwrap();
        // spiking neurons subtract θ; non-spiking unchanged
        assert_eq!(out.written.unwrap(), [50, 99, 0, 630, -5, 923]);
    }
}

#[test]
fn lif_leak_applies_to_all_fields() {
    for cfg in [MacroConfig::bit_level(), MacroConfig::fast()] {
        let mut m = ImpulseMacro::new(cfg);
        m.write_v(0, Parity::Even, &[10, 0, -10, 500, -500, 3]).unwrap();
        m.write_v(1, Parity::Even, &[-2; 6]).unwrap(); // −leak
        let out = m
            .execute(&Instruction::AccV2V {
                src_a: 0,
                src_b: 1,
                dst: 0,
                parity: Parity::Even,
                mask: WriteMaskMode::All,
            })
            .unwrap();
        assert_eq!(out.written.unwrap(), [8, -2, -12, 498, -502, 1]);
    }
}

#[test]
fn vmem_wraps_at_11_bits() {
    for cfg in [MacroConfig::bit_level(), MacroConfig::fast()] {
        let mut m = ImpulseMacro::new(cfg);
        m.write_weights(0, &[31; 12]).unwrap();
        m.write_v(0, Parity::Odd, &[1020; 6]).unwrap();
        let out = m
            .execute(&Instruction::AccW2V {
                w_row: 0,
                v_src: 0,
                v_dst: 0,
                parity: Parity::Odd,
            })
            .unwrap();
        assert_eq!(out.written.unwrap(), [wrap11(1051); 6]);
        assert_eq!(wrap11(1051), -997);
    }
}

#[test]
fn comparator_modes_differ_on_negative_v() {
    // MsbCout (the literal circuit) spikes on negative V with positive θ
    // (unsigned wrap); SignBit does not. Documents modelling choice M3.
    for (mode, expect) in [
        (ComparatorMode::SignBit, false),
        (ComparatorMode::MsbCout, true),
    ] {
        let mut m = ImpulseMacro::new(MacroConfig::bit_level().with_comparator(mode));
        m.write_v(0, Parity::Odd, &[-1; 6]).unwrap();
        m.write_v(1, Parity::Odd, &[-5; 6]).unwrap(); // θ = 5
        let out = m
            .execute(&Instruction::SpikeCheck {
                v_row: 0,
                thr_row: 1,
                parity: Parity::Odd,
            })
            .unwrap();
        assert_eq!(out.spikes.unwrap(), [expect; 6], "{mode:?}");
    }
}

#[test]
fn comparator_modes_agree_on_nonnegative_v() {
    let mut rng = XorShiftRng::new(77);
    for _ in 0..200 {
        let v = rng.gen_i64(0, 1023);
        let theta = rng.gen_i64(1, 512);
        let mut a = ImpulseMacro::new(
            MacroConfig::fast().with_comparator(ComparatorMode::SignBit),
        );
        let mut b = ImpulseMacro::new(
            MacroConfig::fast().with_comparator(ComparatorMode::MsbCout),
        );
        for m in [&mut a, &mut b] {
            m.write_v(0, Parity::Odd, &[v; 6]).unwrap();
            m.write_v(1, Parity::Odd, &[-theta; 6]).unwrap();
            m.execute(&Instruction::SpikeCheck {
                v_row: 0,
                thr_row: 1,
                parity: Parity::Odd,
            })
            .unwrap();
        }
        assert_eq!(
            a.spikes(Parity::Odd),
            b.spikes(Parity::Odd),
            "v={v} theta={theta}"
        );
    }
}

#[test]
fn odd_and_even_rows_are_independent() {
    // Writing an even-aligned row must not disturb odd-aligned values
    // in a different row, and CIM ops only touch their parity's fields.
    let mut m = ImpulseMacro::new(MacroConfig::lockstep());
    m.write_v(0, Parity::Odd, &[11, 22, 33, 44, 55, 66]).unwrap();
    m.write_v(1, Parity::Even, &[-11, -22, -33, -44, -55, -66]).unwrap();
    m.write_weights(0, &[5; 12]).unwrap();
    m.execute(&Instruction::AccW2V {
        w_row: 0,
        v_src: 1,
        v_dst: 1,
        parity: Parity::Even,
    })
    .unwrap();
    assert_eq!(m.read_v(0, Parity::Odd).unwrap(), [11, 22, 33, 44, 55, 66]);
    assert_eq!(
        m.read_v(1, Parity::Even).unwrap(),
        [-6, -17, -28, -39, -50, -61]
    );
}

#[test]
fn counters_and_trace() {
    let mut m = ImpulseMacro::new(MacroConfig::fast().with_trace(true));
    m.write_v(0, Parity::Odd, &[0; 6]).unwrap();
    m.write_v(1, Parity::Odd, &[-1; 6]).unwrap();
    m.write_weights(0, &[1; 12]).unwrap();
    for _ in 0..5 {
        m.execute(&Instruction::AccW2V {
            w_row: 0,
            v_src: 0,
            v_dst: 0,
            parity: Parity::Odd,
        })
        .unwrap();
    }
    m.execute(&Instruction::SpikeCheck {
        v_row: 0,
        thr_row: 1,
        parity: Parity::Odd,
    })
    .unwrap();
    assert_eq!(m.count_of(crate::isa::InstructionKind::AccW2V), 5);
    assert_eq!(m.count_of(crate::isa::InstructionKind::SpikeCheck), 1);
    assert_eq!(m.trace().len(), 9);
    m.reset_counters();
    assert_eq!(m.cycles(), 0);
    assert_eq!(m.trace().len(), 0);
}

#[test]
fn out_of_range_rows_error() {
    let mut m = ImpulseMacro::new(MacroConfig::fast());
    assert!(m
        .execute(&Instruction::AccW2V {
            w_row: 128,
            v_src: 0,
            v_dst: 0,
            parity: Parity::Odd,
        })
        .is_err());
    assert!(m
        .execute(&Instruction::ReadV {
            v_row: 32,
            parity: Parity::Odd
        })
        .is_err());
    let mut b = ImpulseMacro::new(MacroConfig::bit_level());
    assert!(b
        .execute(&Instruction::SpikeCheck {
            v_row: 0,
            thr_row: 0,
            parity: Parity::Odd,
        })
        .is_err());
}

/// Sparsity hook: no spikes ⇒ no AccW2V issued ⇒ V unchanged. (The
/// scheduler-level property; here just the macro-side invariant that
/// executing zero instructions costs zero cycles.)
#[test]
fn idle_macro_burns_no_cycles() {
    let m = ImpulseMacro::new(MacroConfig::fast());
    assert_eq!(m.cycles(), 0);
    assert!(m.counts().is_empty());
}

/// The batched AccW2V hot path must be bit-identical to the
/// per-instruction loop (including counters), for random bursts.
#[test]
fn acc_w2v_batch_matches_instruction_loop() {
    let mut rng = XorShiftRng::new(0xBA7C);
    for _ in 0..100 {
        let mut fast = ImpulseMacro::new(MacroConfig::fast());
        let mut reference = ImpulseMacro::new(MacroConfig::bit_level());
        for r in 0..32 {
            let w = rand_weights(&mut rng);
            fast.write_weights(r, &w).unwrap();
            reference.write_weights(r, &w).unwrap();
        }
        let parity = rand_parity(&mut rng);
        let v0 = rand_values(&mut rng);
        fast.write_v(0, parity, &v0).unwrap();
        reference.write_v(0, parity, &v0).unwrap();
        let burst: Vec<usize> = (0..rng.gen_range(64) as usize)
            .map(|_| rng.gen_range(32) as usize)
            .collect();
        fast.acc_w2v_batch(&burst, 0, parity).unwrap();
        reference.acc_w2v_batch(&burst, 0, parity).unwrap(); // falls back to loop
        assert_eq!(
            fast.read_v(0, parity).unwrap(),
            reference.read_v(0, parity).unwrap(),
            "burst {burst:?}"
        );
        // accounting identical
        assert_eq!(
            fast.count_of(crate::isa::InstructionKind::AccW2V),
            burst.len() as u64
        );
        assert_eq!(
            fast.count_of(crate::isa::InstructionKind::AccW2V),
            reference.count_of(crate::isa::InstructionKind::AccW2V)
        );
    }
}

/// Empty burst: no instructions, no cycles, V untouched.
#[test]
fn acc_w2v_batch_empty_is_free() {
    let mut m = ImpulseMacro::new(MacroConfig::fast());
    m.write_v(0, Parity::Odd, &[7; 6]).unwrap();
    let c0 = m.cycles();
    m.acc_w2v_batch(&[], 0, Parity::Odd).unwrap();
    assert_eq!(m.cycles(), c0);
    assert_eq!(m.read_v(0, Parity::Odd).unwrap(), [7; 6]);
}

/// Fused (lane-masked) AccW2V: each lane must accumulate exactly its
/// own spiking rows, identical to per-lane instruction issue, while
/// the instruction count is the union length.
#[test]
fn acc_w2v_fused_matches_per_lane_issue() {
    let mut rng = XorShiftRng::new(0xFA5E);
    for parity in Parity::BOTH {
        let mut fused = ImpulseMacro::new(MacroConfig::fast());
        let mut reference = ImpulseMacro::new(MacroConfig::fast());
        for r in 0..32 {
            let w = rand_weights(&mut rng);
            fused.write_weights(r, &w).unwrap();
            reference.write_weights(r, &w).unwrap();
        }
        let lanes = 5usize;
        let lane_rows: Vec<usize> = (0..lanes)
            .map(|b| match parity {
                Parity::Odd => 2 * b,
                Parity::Even => 2 * b + 1,
            })
            .collect();
        for &v in &lane_rows {
            fused.write_v(v, parity, &[0; 6]).unwrap();
            reference.write_v(v, parity, &[0; 6]).unwrap();
        }
        fused.reset_counters();
        reference.reset_counters();
        // random union with random lane masks
        let mut rows: Vec<(usize, u32)> = Vec::new();
        for r in 0..32 {
            if rng.gen_bool(0.6) {
                rows.push((r, 1 + rng.gen_range((1u64 << lanes) - 1) as u32));
            }
        }
        fused.acc_w2v_fused(&rows, &lane_rows, parity).unwrap();
        for (b, &v_row) in lane_rows.iter().enumerate() {
            let mine: Vec<usize> = rows
                .iter()
                .filter(|&&(_, m)| m & (1 << b) != 0)
                .map(|&(r, _)| r)
                .collect();
            reference.acc_w2v_batch(&mine, v_row, parity).unwrap();
            assert_eq!(
                fused.read_v(v_row, parity).unwrap(),
                reference.read_v(v_row, parity).unwrap(),
                "lane {b} ({parity:?})"
            );
        }
        // fused accounting: one AccW2V per union row
        assert_eq!(
            fused.count_of(crate::isa::InstructionKind::AccW2V),
            rows.len() as u64
        );
    }
}

/// The fused path drives the bit-level engine too (lockstep asserts
/// per-instruction equality internally) with the same fused counts.
#[test]
fn acc_w2v_fused_lockstep_engine_agrees() {
    let mut rng = XorShiftRng::new(0xBA7C);
    let mut lock = ImpulseMacro::new(MacroConfig::lockstep());
    let mut fast = ImpulseMacro::new(MacroConfig::fast());
    for r in 0..16 {
        let w = rand_weights(&mut rng);
        lock.write_weights(r, &w).unwrap();
        fast.write_weights(r, &w).unwrap();
    }
    let lane_rows = [0usize, 2, 4];
    for &v in &lane_rows {
        lock.write_v(v, Parity::Odd, &[0; 6]).unwrap();
        fast.write_v(v, Parity::Odd, &[0; 6]).unwrap();
    }
    lock.reset_counters();
    fast.reset_counters();
    let rows: Vec<(usize, u32)> = vec![(0, 0b111), (3, 0b010), (7, 0b101), (12, 0b001)];
    lock.acc_w2v_fused(&rows, &lane_rows, Parity::Odd).unwrap();
    fast.acc_w2v_fused(&rows, &lane_rows, Parity::Odd).unwrap();
    for &v in &lane_rows {
        assert_eq!(
            lock.read_v(v, Parity::Odd).unwrap(),
            fast.read_v(v, Parity::Odd).unwrap()
        );
    }
    assert_eq!(lock.cycles(), fast.cycles());
    assert_eq!(lock.count_of(crate::isa::InstructionKind::AccW2V), 4);
}

/// Fused issue validation: bad rows, bad lanes, and over-wide masks
/// are rejected without corrupting the cycle counter.
#[test]
fn acc_w2v_fused_rejects_malformed_streams() {
    let mut m = ImpulseMacro::new(MacroConfig::fast());
    m.write_v(0, Parity::Odd, &[0; 6]).unwrap();
    let c0 = m.cycles();
    assert!(m.acc_w2v_fused(&[(200, 1)], &[0], Parity::Odd).is_err());
    assert!(m.acc_w2v_fused(&[(0, 0b10)], &[0], Parity::Odd).is_err());
    assert!(m.acc_w2v_fused(&[(0, 1)], &[99], Parity::Odd).is_err());
    assert_eq!(m.cycles(), c0);
    // empty stream is free
    m.acc_w2v_fused(&[], &[0], Parity::Odd).unwrap();
    assert_eq!(m.cycles(), c0);

    // a malformed entry later in the stream must not commit earlier
    // rows on any engine (validation precedes execution)
    for cfg in [MacroConfig::fast(), MacroConfig::lockstep()] {
        let mut m = ImpulseMacro::new(cfg);
        m.write_weights(0, &[7; 12]).unwrap();
        m.write_v(0, Parity::Odd, &[0; 6]).unwrap();
        let c0 = m.cycles();
        assert!(m
            .acc_w2v_fused(&[(0, 1), (200, 1)], &[0], Parity::Odd)
            .is_err());
        assert_eq!(m.cycles(), c0, "{cfg:?}");
        assert_eq!(m.read_v(0, Parity::Odd).unwrap(), [0; 6], "{cfg:?}");
    }
}

/// Each neuron type's fused update kernel must be bit-identical — in
/// returned spikes, spike-buffer state, membrane state, cycle count,
/// and instruction histogram — to issuing its Fig 6 sequence from
/// `isa::sequences` instruction by instruction.
#[test]
fn fused_neuron_updates_match_unfused_sequences() {
    use crate::isa::{neuron_sequence, NeuronConfigRows, NeuronType};
    let mut rng = XorShiftRng::new(0xF15E);
    for neuron in [NeuronType::IF, NeuronType::LIF, NeuronType::RMP] {
        for parity in Parity::BOTH {
            let (v_row, thr, reset, leak) = match parity {
                Parity::Odd => (0usize, 28usize, 30usize, 26usize),
                Parity::Even => (1usize, 29usize, 31usize, 27usize),
            };
            let rows = NeuronConfigRows {
                neg_threshold: thr,
                reset,
                neg_leak: leak,
            };
            for case in 0..50 {
                let theta = rng.gen_i64(1, 512);
                let leak_v = rng.gen_i64(0, 16);
                let reset_v = rng.gen_i64(-8, 8);
                let v0 = rand_values(&mut rng);
                let mut fused = ImpulseMacro::new(MacroConfig::fast());
                let mut reference = ImpulseMacro::new(MacroConfig::fast());
                for m in [&mut fused, &mut reference] {
                    m.write_v(thr, parity, &[-theta; 6]).unwrap();
                    m.write_v(reset, parity, &[reset_v; 6]).unwrap();
                    m.write_v(leak, parity, &[-leak_v; 6]).unwrap();
                    m.write_v(v_row, parity, &v0).unwrap();
                }
                let got = fused
                    .neuron_update_fused(neuron, v_row, rows, parity)
                    .unwrap();
                for instr in neuron_sequence(neuron, v_row, rows, parity) {
                    reference.execute(&instr).unwrap();
                }
                let want = reference.spikes(parity);
                assert_eq!(
                    got, want,
                    "case {case}: {neuron:?} {parity:?} v0={v0:?} θ={theta}"
                );
                assert_eq!(fused.spikes(parity), want, "{neuron:?} spike buffer");
                assert_eq!(
                    fused.read_v(v_row, parity).unwrap(),
                    reference.read_v(v_row, parity).unwrap(),
                    "case {case}: {neuron:?} {parity:?} membrane state"
                );
                assert_eq!(fused.cycles(), reference.cycles(), "{neuron:?} cycles");
                assert_eq!(fused.counts(), reference.counts(), "{neuron:?} histogram");
            }
        }
    }
}

/// On the lockstep engine the fused kernels fall back to instruction
/// issue (cross-checking bit-level vs fast internally) and must agree
/// with the fast-engine fused path in state and accounting.
#[test]
fn fused_neuron_updates_agree_across_engines() {
    use crate::isa::{NeuronConfigRows, NeuronType};
    let mut rng = XorShiftRng::new(0xD0C5);
    let rows = NeuronConfigRows {
        neg_threshold: 28,
        reset: 30,
        neg_leak: 26,
    };
    for neuron in [NeuronType::IF, NeuronType::LIF, NeuronType::RMP] {
        for _ in 0..10 {
            let theta = rng.gen_i64(1, 256);
            let v0 = rand_values(&mut rng);
            let mut lock = ImpulseMacro::new(MacroConfig::lockstep());
            let mut fast = ImpulseMacro::new(MacroConfig::fast());
            for m in [&mut lock, &mut fast] {
                m.write_v(28, Parity::Odd, &[-theta; 6]).unwrap();
                m.write_v(30, Parity::Odd, &[0; 6]).unwrap();
                m.write_v(26, Parity::Odd, &[-3; 6]).unwrap();
                m.write_v(0, Parity::Odd, &v0).unwrap();
            }
            let a = lock.neuron_update_fused(neuron, 0, rows, Parity::Odd).unwrap();
            let b = fast.neuron_update_fused(neuron, 0, rows, Parity::Odd).unwrap();
            assert_eq!(a, b, "{neuron:?} spikes");
            assert_eq!(
                lock.read_v(0, Parity::Odd).unwrap(),
                fast.read_v(0, Parity::Odd).unwrap(),
                "{neuron:?} membrane state"
            );
            assert_eq!(lock.cycles(), fast.cycles(), "{neuron:?} cycles");
        }
    }
}

/// Both comparator modes flow through the fused kernels identically to
/// the unfused sequences (the fused path shares `compare`).
#[test]
fn fused_neuron_updates_respect_comparator_mode() {
    use crate::isa::{neuron_sequence, NeuronConfigRows, NeuronType};
    let rows = NeuronConfigRows {
        neg_threshold: 28,
        reset: 30,
        neg_leak: 26,
    };
    for mode in [ComparatorMode::SignBit, ComparatorMode::MsbCout] {
        for neuron in [NeuronType::IF, NeuronType::LIF, NeuronType::RMP] {
            let mut fused = ImpulseMacro::new(MacroConfig::fast().with_comparator(mode));
            let mut reference =
                ImpulseMacro::new(MacroConfig::fast().with_comparator(mode));
            for m in [&mut fused, &mut reference] {
                m.write_v(28, Parity::Odd, &[-5; 6]).unwrap();
                m.write_v(30, Parity::Odd, &[0; 6]).unwrap();
                m.write_v(26, Parity::Odd, &[-1; 6]).unwrap();
                // straddle the threshold, including a negative V where
                // the two comparator modes disagree
                m.write_v(0, Parity::Odd, &[-1, 4, 5, 6, 1000, -1000]).unwrap();
            }
            let got = fused
                .neuron_update_fused(neuron, 0, rows, Parity::Odd)
                .unwrap();
            for instr in neuron_sequence(neuron, 0, rows, Parity::Odd) {
                reference.execute(&instr).unwrap();
            }
            assert_eq!(got, reference.spikes(Parity::Odd), "{mode:?} {neuron:?}");
            assert_eq!(
                fused.read_v(0, Parity::Odd).unwrap(),
                reference.read_v(0, Parity::Odd).unwrap(),
                "{mode:?} {neuron:?}"
            );
        }
    }
}

/// The fused kernels enforce the same operand-row invariants as the
/// underlying instructions, without corrupting the cycle counter.
#[test]
fn fused_neuron_update_rejects_bad_rows() {
    let mut m = ImpulseMacro::new(MacroConfig::fast());
    let c0 = m.cycles();
    assert!(m.if_update_fused(0, 0, 30, Parity::Odd).is_err()); // v == thr
    assert!(m.if_update_fused(0, 99, 30, Parity::Odd).is_err());
    assert!(m.lif_update_fused(0, 28, 30, 0, Parity::Odd).is_err()); // v == leak
    assert!(m.lif_update_fused(0, 28, 99, 26, Parity::Odd).is_err());
    assert_eq!(m.cycles(), c0);
}

/// Aliasing regression: when the reset row *is* the membrane row, the
/// unfused LIF sequence resets spiked fields to their post-leak value
/// (ResetV reads the row AccV2V just wrote). The fused kernel must
/// reproduce that, on the fast and lockstep engines alike.
#[test]
fn fused_lif_update_handles_reset_row_aliasing_v_row() {
    use crate::isa::neuron_sequence;
    use crate::isa::{NeuronConfigRows, NeuronType};
    let mut rng = XorShiftRng::new(0xA11A);
    // reset row aliases the membrane row (row 0)
    let rows = NeuronConfigRows {
        neg_threshold: 28,
        reset: 0,
        neg_leak: 26,
    };
    for cfg in [MacroConfig::fast(), MacroConfig::lockstep()] {
        for _ in 0..20 {
            let theta = rng.gen_i64(1, 64);
            let v0 = rand_values(&mut rng);
            let mut fused = ImpulseMacro::new(cfg);
            let mut reference = ImpulseMacro::new(cfg);
            for m in [&mut fused, &mut reference] {
                m.write_v(28, Parity::Odd, &[-theta; 6]).unwrap();
                m.write_v(26, Parity::Odd, &[-5; 6]).unwrap();
                m.write_v(0, Parity::Odd, &v0).unwrap();
            }
            let got = fused
                .neuron_update_fused(NeuronType::LIF, 0, rows, Parity::Odd)
                .unwrap();
            for instr in neuron_sequence(NeuronType::LIF, 0, rows, Parity::Odd) {
                reference.execute(&instr).unwrap();
            }
            assert_eq!(got, reference.spikes(Parity::Odd), "{cfg:?} v0={v0:?}");
            assert_eq!(
                fused.read_v(0, Parity::Odd).unwrap(),
                reference.read_v(0, Parity::Odd).unwrap(),
                "{cfg:?}: aliased reset must keep the leaked value, v0={v0:?}"
            );
        }
    }
}

/// PR 5 proptest: the SWAR six-field adder must match per-field
/// `extract_field`/`insert_field` arithmetic exactly — random rows,
/// both parities, with the carry-guard edge values ±1024/±1023 mixed
/// in. The per-field path is the pre-SWAR reference implementation.
#[test]
fn swar_adder_matches_extract_insert_fields() {
    use super::impulse::{extract_field, insert_field};
    crate::proptest_lite::forall_ctx(
        400,
        0x5A5A,
        |rng| {
            let edge = [-1024i64, -1023, 1022, 1023, 0];
            let mut a = [0i64; 6];
            let mut b = [0i64; 6];
            for x in a.iter_mut().chain(b.iter_mut()) {
                *x = if rng.gen_bool(0.35) {
                    edge[rng.gen_i64(0, 4) as usize]
                } else {
                    rng.gen_i64(-1024, 1023)
                };
            }
            (a, b, rand_parity(rng))
        },
        |&(a, b, parity)| {
            let st = parity.stagger();
            // build the stored rows field by field (reference encode)
            let mut row_a = 0u128;
            let mut row_b = 0u128;
            for g in 0..6 {
                insert_field(&mut row_a, g, parity, a[g]);
                insert_field(&mut row_b, g, parity, b[g]);
            }
            // SWAR: pack both, add-wrap, unpack
            let sum = swar::add_wrap(swar::pack(row_a >> st), swar::pack(row_b >> st));
            let swar_row = swar::unpack(sum) << st;
            // reference: per-field extract → wrap11 → insert
            let mut want_row = 0u128;
            for g in 0..6 {
                let w = wrap11(
                    extract_field(row_a, g, parity) + extract_field(row_b, g, parity),
                );
                insert_field(&mut want_row, g, parity, w);
            }
            if swar_row != want_row {
                return Err(format!("SWAR row {swar_row:#x} != per-field row {want_row:#x}"));
            }
            for g in 0..6 {
                let want = wrap11(a[g] + b[g]);
                if extract_field(swar_row, g, parity) != want {
                    return Err(format!("field {g}: want {want}"));
                }
            }
            Ok(())
        },
    );
}

/// The straight-line stream runner behind `acc_w2v_fused` must be
/// bit-identical to issuing the same union stream one `execute` at a
/// time on a second fast-engine macro (weights and state shared).
#[test]
fn accw2v_stream_runner_matches_instruction_dispatch() {
    let mut rng = XorShiftRng::new(0x57A7);
    for _ in 0..20 {
        let mut fused = ImpulseMacro::new(MacroConfig::fast());
        let mut reference = ImpulseMacro::new(MacroConfig::fast());
        for r in 0..32 {
            let w = rand_weights(&mut rng);
            fused.write_weights(r, &w).unwrap();
            reference.write_weights(r, &w).unwrap();
        }
        let lanes = rng.gen_i64(1, 8) as usize;
        let lane_rows: Vec<usize> = (0..lanes).map(|b| 2 * b).collect();
        for &v in &lane_rows {
            let v0 = rand_values(&mut rng);
            fused.write_v(v, Parity::Odd, &v0).unwrap();
            reference.write_v(v, Parity::Odd, &v0).unwrap();
        }
        let n_rows = rng.gen_i64(0, 24) as usize;
        let rows: Vec<(usize, u32)> = (0..n_rows)
            .map(|_| {
                let mask = (rng.gen_range(1u64 << lanes) as u32).max(1);
                (rng.gen_i64(0, 31) as usize, mask)
            })
            .collect();
        fused.acc_w2v_fused(&rows, &lane_rows, Parity::Odd).unwrap();
        for &(w_row, mask) in &rows {
            let mut mm = mask;
            while mm != 0 {
                let b = mm.trailing_zeros() as usize;
                mm &= mm - 1;
                reference
                    .execute(&Instruction::AccW2V {
                        w_row,
                        v_src: lane_rows[b],
                        v_dst: lane_rows[b],
                        parity: Parity::Odd,
                    })
                    .unwrap();
            }
        }
        for &v in &lane_rows {
            assert_eq!(
                fused.read_v(v, Parity::Odd).unwrap(),
                reference.read_v(v, Parity::Odd).unwrap(),
                "lane row {v}"
            );
        }
        // fused accounting stays at one AccW2V per union row
        assert_eq!(
            fused.count_of(crate::isa::InstructionKind::AccW2V),
            rows.len() as u64
        );
    }
}
