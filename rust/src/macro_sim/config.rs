//! Macro configuration.

/// How SpikeCheck turns the MSB column peripheral's outputs into the
/// spike decision. See DESIGN.md §5, modelling choice M3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComparatorMode {
    /// Literal circuit reading of the paper ("checking the COUT from
    /// [the] MSB column peripheral"): spike ⇔ unsigned carry-out of
    /// `V + (−θ)`. Equals the signed `V ≥ θ` only for `V ≥ 0`.
    MsbCout,
    /// Signed comparison via the MSB *sum* (sign) bit: spike ⇔
    /// `V − θ ≥ 0` under 11-bit wraparound. What the trained networks
    /// assume; the default.
    SignBit,
}

impl Default for ComparatorMode {
    fn default() -> Self {
        ComparatorMode::SignBit
    }
}

/// Which execution engine runs the instruction stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Column-by-column peripheral simulation (reference).
    BitLevel,
    /// Word-level functional model (fast path; bit-identical).
    Fast,
    /// Run both and assert equality after every instruction
    /// (differential testing / failure injection harness).
    Lockstep,
}

impl Default for Engine {
    fn default() -> Self {
        Engine::Fast
    }
}

/// Configuration of one macro instance.
#[derive(Clone, Copy, Debug, Default)]
pub struct MacroConfig {
    pub comparator: ComparatorMode,
    pub engine: Engine,
    /// Record a trace event per executed instruction.
    pub trace: bool,
}

impl MacroConfig {
    pub fn bit_level() -> Self {
        Self {
            engine: Engine::BitLevel,
            ..Self::default()
        }
    }

    pub fn fast() -> Self {
        Self {
            engine: Engine::Fast,
            ..Self::default()
        }
    }

    pub fn lockstep() -> Self {
        Self {
            engine: Engine::Lockstep,
            ..Self::default()
        }
    }

    pub fn with_comparator(mut self, c: ComparatorMode) -> Self {
        self.comparator = c;
        self
    }

    pub fn with_trace(mut self, t: bool) -> Self {
        self.trace = t;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        let c = MacroConfig::default();
        assert_eq!(c.comparator, ComparatorMode::SignBit);
        assert_eq!(c.engine, Engine::Fast);
        assert!(!c.trace);
    }

    #[test]
    fn builders() {
        let c = MacroConfig::bit_level()
            .with_comparator(ComparatorMode::MsbCout)
            .with_trace(true);
        assert_eq!(c.engine, Engine::BitLevel);
        assert_eq!(c.comparator, ComparatorMode::MsbCout);
        assert!(c.trace);
    }
}
