//! The IMPULSE macro: decoder + fused array + column peripherals
//! executing in-memory instruction streams.
//!
//! Two execution engines share the same architectural state and must be
//! bit-identical (enforced by differential tests and a `Lockstep`
//! mode):
//!
//! - [`Engine::BitLevel`] — drives the triple-row decoder, senses
//!   bitlines, ripples carries through each column peripheral exactly
//!   like the silicon. The reference model.
//! - [`Engine::Fast`] — word-level functional model (decode → wrap11
//!   arithmetic → encode). ~40× faster; what the coordinator uses for
//!   network-scale runs.

mod config;
mod impulse;
pub mod swar;
mod trace;

pub use config::{ComparatorMode, Engine, MacroConfig};
pub use impulse::{ExecOutput, ImpulseMacro, MAX_FUSED_LANES};
pub use trace::{TraceEvent, Tracer};

#[cfg(test)]
mod tests;
