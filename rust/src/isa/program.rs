//! Instruction streams and a builder for composing them.

use super::{Instruction, InstructionKind};
use std::collections::BTreeMap;

/// An ordered instruction stream for one macro.
#[derive(Clone, Debug, Default)]
pub struct Program {
    instrs: Vec<Instruction>,
}

impl Program {
    /// An empty program.
    pub fn new() -> Self {
        Self::default()
    }

    /// Wrap an instruction vector as a program.
    pub fn from_vec(instrs: Vec<Instruction>) -> Self {
        Self { instrs }
    }

    /// Append one instruction.
    #[inline]
    pub fn push(&mut self, i: Instruction) {
        self.instrs.push(i);
    }

    /// Append another program's instructions in order.
    pub fn extend(&mut self, other: &Program) {
        self.instrs.extend_from_slice(&other.instrs);
    }

    /// Number of instructions.
    #[inline]
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    /// Whether the program has no instructions.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Iterate the instructions in issue order.
    pub fn iter(&self) -> impl Iterator<Item = &Instruction> {
        self.instrs.iter()
    }

    /// Instruction histogram by kind — the input to energy accounting.
    pub fn histogram(&self) -> BTreeMap<InstructionKind, u64> {
        let mut h = BTreeMap::new();
        for i in &self.instrs {
            *h.entry(i.kind()).or_insert(0u64) += 1;
        }
        h
    }

    /// Number of CIM cycles (each CIM instruction is one cycle).
    pub fn cim_cycles(&self) -> u64 {
        self.instrs.iter().filter(|i| i.kind().is_cim()).count() as u64
    }
}

impl<'a> IntoIterator for &'a Program {
    type Item = &'a Instruction;
    type IntoIter = std::slice::Iter<'a, Instruction>;
    fn into_iter(self) -> Self::IntoIter {
        self.instrs.iter()
    }
}

/// Fluent builder used by the mapper/scheduler.
#[derive(Clone, Debug, Default)]
pub struct ProgramBuilder {
    p: Program,
}

impl ProgramBuilder {
    /// Start an empty builder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one instruction.
    pub fn instr(mut self, i: Instruction) -> Self {
        self.p.push(i);
        self
    }

    /// Append a sequence of instructions in order.
    pub fn instrs(mut self, is: impl IntoIterator<Item = Instruction>) -> Self {
        for i in is {
            self.p.push(i);
        }
        self
    }

    /// Finish and return the composed program.
    pub fn build(self) -> Program {
        self.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitcell::Parity;

    fn acc(w_row: usize) -> Instruction {
        Instruction::AccW2V {
            w_row,
            v_src: 0,
            v_dst: 0,
            parity: Parity::Odd,
        }
    }

    #[test]
    fn histogram_counts_kinds() {
        let p = ProgramBuilder::new()
            .instr(acc(0))
            .instr(acc(1))
            .instr(Instruction::SpikeCheck {
                v_row: 0,
                thr_row: 1,
                parity: Parity::Odd,
            })
            .instr(Instruction::ReadV {
                v_row: 0,
                parity: Parity::Odd,
            })
            .build();
        let h = p.histogram();
        assert_eq!(h[&InstructionKind::AccW2V], 2);
        assert_eq!(h[&InstructionKind::SpikeCheck], 1);
        assert_eq!(h[&InstructionKind::ReadV], 1);
        assert_eq!(p.cim_cycles(), 3);
        assert_eq!(p.len(), 4);
    }

    #[test]
    fn extend_concatenates() {
        let mut a = Program::from_vec(vec![acc(0)]);
        let b = Program::from_vec(vec![acc(1), acc(2)]);
        a.extend(&b);
        assert_eq!(a.len(), 3);
    }
}
