//! Instruction definitions and encodings.

use crate::bitcell::Parity;
use std::fmt;

/// Which fields the conditional write drivers actually drive.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WriteMaskMode {
    /// Unconditional write-back of all six fields.
    All,
    /// Only fields whose spike buffer is set (spiked neurons).
    Spiked,
}

/// One single-cycle in-memory instruction.
///
/// Row addresses: `w_row` indexes W_MEM (0..128); `v_*`, `thr_row`,
/// `reset_row`, `src_*`, `dst` index V_MEM (0..32). `parity` selects
/// RWLo/RWLe and the staggered field alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Instruction {
    /// `V[dst] ← V[src] + sext(W[w_row])` — the synaptic accumulate,
    /// issued once per input spike per parity.
    AccW2V {
        /// W_MEM row holding the presynaptic weights.
        w_row: usize,
        /// V_MEM row read as the accumulator input.
        v_src: usize,
        /// V_MEM row written back (usually `v_src`).
        v_dst: usize,
        /// Cycle parity (RWLo/RWLe) selecting the field alignment.
        parity: Parity,
    },
    /// `V[dst] ← V[src_a] + V[src_b]`, optionally gated by the spike
    /// buffers (RMP soft reset uses `Spiked`; LIF leak uses `All`).
    AccV2V {
        /// First V_MEM source row.
        src_a: usize,
        /// Second V_MEM source row (must differ from `src_a`).
        src_b: usize,
        /// V_MEM destination row.
        dst: usize,
        /// Cycle parity (RWLo/RWLe) selecting the field alignment.
        parity: Parity,
        /// Which fields the conditional write drivers actually drive.
        mask: WriteMaskMode,
    },
    /// Compare `V[v_row]` against the threshold row (which stores −θ)
    /// and latch the per-field comparator outputs into the spike
    /// buffers. No write.
    SpikeCheck {
        /// V_MEM row holding the membrane potentials.
        v_row: usize,
        /// V_MEM row holding −θ.
        thr_row: usize,
        /// Cycle parity (RWLo/RWLe) selecting the field alignment.
        parity: Parity,
    },
    /// `V[dst] ← V[reset_row]` for spiked fields only (BLFA bypassed;
    /// sensed reset value goes straight to the CWD).
    ResetV {
        /// V_MEM row holding the reset constant.
        reset_row: usize,
        /// V_MEM destination row (the membrane row).
        dst: usize,
        /// Cycle parity (RWLo/RWLe) selecting the field alignment.
        parity: Parity,
    },
    /// Plain SRAM read of a V_MEM row — used by the coordinator to
    /// drain output-layer potentials. Standard read, not a CIM op.
    /// Each V_MEM row is dedicated to one parity's staggered alignment
    /// ("stored in different rows"), so the parity tells the periphery
    /// how to frame the fields.
    ReadV {
        /// V_MEM row to read.
        v_row: usize,
        /// The row's field alignment.
        parity: Parity,
    },
    /// Plain SRAM write of a V_MEM row (one parity's six values).
    WriteV {
        /// V_MEM row to write.
        v_row: usize,
        /// The row's field alignment.
        parity: Parity,
        /// The six 11-bit values to encode into the row.
        values: [i64; 6],
    },
    /// Plain SRAM write of a W_MEM row (all twelve weights).
    WriteW {
        /// W_MEM row to write.
        w_row: usize,
        /// The twelve 6-bit weights, column order.
        weights: [i64; 12],
    },
}

/// Instruction kind — the unit of energy/latency accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum InstructionKind {
    /// Weight-to-V accumulate (the synaptic CIM op).
    AccW2V,
    /// V-to-V accumulate (leak, soft reset).
    AccV2V,
    /// Threshold comparison latching the spike buffers.
    SpikeCheck,
    /// Spike-gated hard reset from the reset row.
    ResetV,
    /// Plain SRAM read of a V row.
    ReadV,
    /// Plain SRAM write of a V row.
    WriteV,
    /// Plain SRAM write of a W row.
    WriteW,
}

impl InstructionKind {
    /// All CIM instruction kinds (the ones in the paper's Shmoo/energy
    /// tables).
    pub const CIM: [InstructionKind; 4] = [
        InstructionKind::AccW2V,
        InstructionKind::AccV2V,
        InstructionKind::SpikeCheck,
        InstructionKind::ResetV,
    ];

    /// Stable display name (matches the paper's nomenclature).
    pub fn name(&self) -> &'static str {
        match self {
            InstructionKind::AccW2V => "AccW2V",
            InstructionKind::AccV2V => "AccV2V",
            InstructionKind::SpikeCheck => "SpikeCheck",
            InstructionKind::ResetV => "ResetV",
            InstructionKind::ReadV => "ReadV",
            InstructionKind::WriteV => "WriteV",
            InstructionKind::WriteW => "WriteW",
        }
    }

    /// Is this a compute-in-memory instruction (vs a plain SRAM access)?
    pub fn is_cim(&self) -> bool {
        matches!(
            self,
            InstructionKind::AccW2V
                | InstructionKind::AccV2V
                | InstructionKind::SpikeCheck
                | InstructionKind::ResetV
        )
    }
}

impl fmt::Display for InstructionKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl Instruction {
    /// The accounting kind of this instruction.
    pub fn kind(&self) -> InstructionKind {
        match self {
            Instruction::AccW2V { .. } => InstructionKind::AccW2V,
            Instruction::AccV2V { .. } => InstructionKind::AccV2V,
            Instruction::SpikeCheck { .. } => InstructionKind::SpikeCheck,
            Instruction::ResetV { .. } => InstructionKind::ResetV,
            Instruction::ReadV { .. } => InstructionKind::ReadV,
            Instruction::WriteV { .. } => InstructionKind::WriteV,
            Instruction::WriteW { .. } => InstructionKind::WriteW,
        }
    }

    /// The cycle parity of a CIM instruction (None for plain accesses).
    pub fn parity(&self) -> Option<Parity> {
        match self {
            Instruction::AccW2V { parity, .. }
            | Instruction::AccV2V { parity, .. }
            | Instruction::SpikeCheck { parity, .. }
            | Instruction::ResetV { parity, .. } => Some(*parity),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cim_classification() {
        assert!(InstructionKind::AccW2V.is_cim());
        assert!(InstructionKind::SpikeCheck.is_cim());
        assert!(!InstructionKind::ReadV.is_cim());
        assert!(!InstructionKind::WriteW.is_cim());
        assert_eq!(InstructionKind::CIM.len(), 4);
    }

    #[test]
    fn parity_accessor() {
        let i = Instruction::SpikeCheck {
            v_row: 1,
            thr_row: 2,
            parity: Parity::Even,
        };
        assert_eq!(i.parity(), Some(Parity::Even));
        assert_eq!(
            Instruction::ReadV {
                v_row: 0,
                parity: Parity::Odd
            }
            .parity(),
            None
        );
    }

    #[test]
    fn display_names() {
        assert_eq!(InstructionKind::AccV2V.to_string(), "AccV2V");
        assert_eq!(InstructionKind::ResetV.to_string(), "ResetV");
    }
}
