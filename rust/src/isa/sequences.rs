//! Neuron functionality as instruction sequences (paper Fig 6).
//!
//! | Neuron | Sequence                                   |
//! |--------|--------------------------------------------|
//! | IF     | SpikeCheck; ResetV                          |
//! | LIF    | AccV2V (−leak, all); SpikeCheck; ResetV     |
//! | RMP    | SpikeCheck; AccV2V (−θ, spiked-only)        |

use super::{Instruction, WriteMaskMode};
use crate::bitcell::Parity;

/// Supported neuron models.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NeuronType {
    /// Integrate-and-fire: hard reset to the reset row's value.
    IF,
    /// Leaky integrate-and-fire: subtractive leak each timestep, then
    /// hard reset on spike.
    LIF,
    /// Residual membrane potential: soft reset — θ is subtracted from
    /// spiking neurons, the residual is retained.
    RMP,
}

impl NeuronType {
    /// Stable display name (paper nomenclature).
    pub fn name(&self) -> &'static str {
        match self {
            NeuronType::IF => "IF",
            NeuronType::LIF => "LIF",
            NeuronType::RMP => "RMP",
        }
    }

    /// CIM instructions per neuron update (per parity) — Fig 6's
    /// sequence lengths.
    pub fn instructions_per_update(&self) -> usize {
        match self {
            NeuronType::IF => 2,
            NeuronType::LIF => 3,
            NeuronType::RMP => 2,
        }
    }

    /// Parse a (case-insensitive) neuron name: `if`, `lif`, or `rmp`.
    pub fn parse(s: &str) -> Option<NeuronType> {
        match s.to_ascii_lowercase().as_str() {
            "if" => Some(NeuronType::IF),
            "lif" => Some(NeuronType::LIF),
            "rmp" => Some(NeuronType::RMP),
            _ => None,
        }
    }
}

/// The V_MEM rows holding a mapped layer's constants for one parity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NeuronConfigRows {
    /// Row storing −θ (negated threshold).
    pub neg_threshold: usize,
    /// Row storing the hard-reset value (usually 0).
    pub reset: usize,
    /// Row storing −leak (LIF only; ignored otherwise).
    pub neg_leak: usize,
}

/// Emit the end-of-timestep neuron-update sequence for one V_MEM row of
/// membrane potentials in one parity.
pub fn neuron_sequence(
    neuron: NeuronType,
    v_row: usize,
    rows: NeuronConfigRows,
    parity: Parity,
) -> Vec<Instruction> {
    match neuron {
        NeuronType::IF => vec![
            Instruction::SpikeCheck {
                v_row,
                thr_row: rows.neg_threshold,
                parity,
            },
            Instruction::ResetV {
                reset_row: rows.reset,
                dst: v_row,
                parity,
            },
        ],
        NeuronType::LIF => vec![
            Instruction::AccV2V {
                src_a: v_row,
                src_b: rows.neg_leak,
                dst: v_row,
                parity,
                mask: WriteMaskMode::All,
            },
            Instruction::SpikeCheck {
                v_row,
                thr_row: rows.neg_threshold,
                parity,
            },
            Instruction::ResetV {
                reset_row: rows.reset,
                dst: v_row,
                parity,
            },
        ],
        NeuronType::RMP => vec![
            Instruction::SpikeCheck {
                v_row,
                thr_row: rows.neg_threshold,
                parity,
            },
            Instruction::AccV2V {
                src_a: v_row,
                src_b: rows.neg_threshold,
                dst: v_row,
                parity,
                mask: WriteMaskMode::Spiked,
            },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::InstructionKind;

    const ROWS: NeuronConfigRows = NeuronConfigRows {
        neg_threshold: 30,
        reset: 29,
        neg_leak: 28,
    };

    fn kinds(n: NeuronType) -> Vec<InstructionKind> {
        neuron_sequence(n, 0, ROWS, Parity::Odd)
            .iter()
            .map(|i| i.kind())
            .collect()
    }

    #[test]
    fn if_sequence_matches_fig6() {
        assert_eq!(
            kinds(NeuronType::IF),
            vec![InstructionKind::SpikeCheck, InstructionKind::ResetV]
        );
    }

    #[test]
    fn lif_sequence_matches_fig6() {
        assert_eq!(
            kinds(NeuronType::LIF),
            vec![
                InstructionKind::AccV2V,
                InstructionKind::SpikeCheck,
                InstructionKind::ResetV
            ]
        );
    }

    #[test]
    fn rmp_sequence_matches_fig6() {
        assert_eq!(
            kinds(NeuronType::RMP),
            vec![InstructionKind::SpikeCheck, InstructionKind::AccV2V]
        );
    }

    #[test]
    fn rmp_soft_reset_is_spike_gated_subtract_of_theta() {
        let seq = neuron_sequence(NeuronType::RMP, 3, ROWS, Parity::Even);
        match seq[1] {
            Instruction::AccV2V {
                src_a,
                src_b,
                dst,
                mask,
                ..
            } => {
                assert_eq!(src_a, 3);
                assert_eq!(src_b, ROWS.neg_threshold);
                assert_eq!(dst, 3);
                assert_eq!(mask, WriteMaskMode::Spiked);
            }
            ref other => panic!("expected AccV2V, got {other:?}"),
        }
    }

    #[test]
    fn sequence_lengths_match_instructions_per_update() {
        for n in [NeuronType::IF, NeuronType::LIF, NeuronType::RMP] {
            assert_eq!(
                neuron_sequence(n, 0, ROWS, Parity::Odd).len(),
                n.instructions_per_update()
            );
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(NeuronType::parse("rmp"), Some(NeuronType::RMP));
        assert_eq!(NeuronType::parse("IF"), Some(NeuronType::IF));
        assert_eq!(NeuronType::parse("Lif"), Some(NeuronType::LIF));
        assert_eq!(NeuronType::parse("x"), None);
    }
}
