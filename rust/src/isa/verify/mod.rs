//! Static program analysis for IMPULSE instruction streams.
//!
//! Two layers, one entry point ([`ProgramValidator`]):
//!
//! - **Structural** ([`check_instruction`], [`check_fused_stream`]):
//!   operand range checks against the macro geometry, `AccV2V`
//!   source aliasing, `SpikeCheck` self-comparison, per-row parity
//!   binding, and the exact preconditions the fused SWAR runner
//!   (`FastEngine::run_accw2v_stream`) executes without re-checking.
//!   These are the same checks `ImpulseMacro::execute` gates every
//!   instruction on — factored here so the bit-level engine, the fast
//!   engine, and lockstep all enforce one contract.
//! - **Dataflow**: a linear abstract-interpretation pass tracking
//!   per-(V row, parity) def/use state and spike-buffer freshness,
//!   diagnosing use-before-init, gated ops with a never-latched or
//!   stale spike buffer, clobbers of threshold/reset rows, and dead
//!   stores.
//!
//! Diagnostics carry a stable [`RuleCode`] (`S…` structural, `F…`
//! fused-stream, `D…` dataflow), a severity, and the offending
//! instruction index; a [`Report`] renders them human-readable or as
//! JSON. See `docs/VALIDATION.md` for the full rule catalog and
//! `impulse check` for the CLI surface.
#![warn(clippy::must_use_candidate, clippy::cast_possible_truncation)]

mod dataflow;
mod diag;
mod structural;

pub use diag::{Diagnostic, Report, RuleCode, Severity};
pub use structural::{
    check_fused_stream, check_instruction, check_instruction_values, check_v_row, check_w_row,
};

use crate::isa::{Instruction, Program};

/// Maximum lanes a fused union-AccW2V batch may carry: the per-lane
/// spike masks are `u32` bitsets, and V_MEM pressure caps useful
/// batch widths well before that.
pub const MAX_FUSED_LANES: usize = 32;

/// Static analyzer for IMPULSE instruction streams.
///
/// ```
/// use impulse::isa::verify::ProgramValidator;
/// use impulse::isa::{neuron_sequence, NeuronConfigRows, NeuronType};
/// use impulse::bitcell::Parity;
///
/// let rows = NeuronConfigRows { neg_threshold: 28, reset: 30, neg_leak: 26 };
/// let seq = neuron_sequence(NeuronType::LIF, 0, rows, Parity::Odd);
/// let report = ProgramValidator::new()
///     .assume_initialized(true)
///     .validate_instrs(&seq);
/// assert!(report.is_clean(), "{report}");
/// ```
#[derive(Debug, Clone, Copy, Default)]
pub struct ProgramValidator {
    assume_initialized: bool,
}

impl ProgramValidator {
    /// A strict validator: V_MEM is assumed uninitialized, so any
    /// read before a write in the stream is flagged.
    #[must_use]
    pub fn new() -> Self {
        Self::default()
    }

    /// Treat every V row as already initialized — appropriate for
    /// fragments (e.g. a single timestep's update sequence) that run
    /// against a macro programmed earlier.
    #[must_use]
    pub fn assume_initialized(mut self, yes: bool) -> Self {
        self.assume_initialized = yes;
        self
    }

    /// Validate a [`Program`].
    #[must_use]
    pub fn validate(&self, program: &Program) -> Report {
        let instrs: Vec<Instruction> = program.iter().copied().collect();
        self.validate_instrs(&instrs)
    }

    /// Validate a raw instruction slice.
    #[must_use]
    pub fn validate_instrs(&self, instrs: &[Instruction]) -> Report {
        let mut diags = Vec::new();
        structural::check_stream(instrs, &mut diags);
        dataflow::check_stream(instrs, self.assume_initialized, &mut diags);
        Report::new(instrs.len(), diags)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitcell::Parity;
    use crate::isa::{neuron_sequence, NeuronConfigRows, NeuronType};

    fn rows(parity: Parity) -> NeuronConfigRows {
        match parity {
            Parity::Odd => NeuronConfigRows {
                neg_threshold: 28,
                reset: 30,
                neg_leak: 26,
            },
            Parity::Even => NeuronConfigRows {
                neg_threshold: 29,
                reset: 31,
                neg_leak: 27,
            },
        }
    }

    #[test]
    fn neuron_sequences_validate_clean_as_fragments() {
        for parity in [Parity::Odd, Parity::Even] {
            for kind in [NeuronType::IF, NeuronType::LIF, NeuronType::RMP] {
                let seq = neuron_sequence(kind, 0, rows(parity), parity);
                let report = ProgramValidator::new()
                    .assume_initialized(true)
                    .validate_instrs(&seq);
                assert!(report.is_clean(), "{kind:?}/{parity:?}: {report}");
            }
        }
    }

    #[test]
    fn strict_mode_flags_uninitialized_fragment() {
        let seq = neuron_sequence(NeuronType::IF, 0, rows(Parity::Odd), Parity::Odd);
        let report = ProgramValidator::new().validate_instrs(&seq);
        assert!(report.has(RuleCode::UseBeforeInit));
        assert!(report.passes(), "use-before-init is a warning: {report}");
    }
}
