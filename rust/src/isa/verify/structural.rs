//! Layer 1 — structural checks.
//!
//! Single-instruction operand validation (the rules
//! [`crate::macro_sim::ImpulseMacro::execute`] gates on), per-row
//! parity-binding consistency across a stream, and the fused-stream
//! preconditions `run_accw2v_stream` assumes.

use super::{Diagnostic, RuleCode, MAX_FUSED_LANES};
use crate::bitcell::{Parity, V_ROWS, W_ROWS};
use crate::bits::{fits, V_BITS, W_BITS};
use crate::isa::Instruction;

/// Range-check a V_MEM row operand.
///
/// # Errors
/// [`RuleCode::VRowRange`] when `row >= 32`.
#[inline]
pub fn check_v_row(row: usize) -> Result<(), Diagnostic> {
    if row >= V_ROWS {
        return Err(Diagnostic::stream(
            RuleCode::VRowRange,
            format!("V row {row} out of range (V_MEM has {V_ROWS} rows)"),
        ));
    }
    Ok(())
}

/// Range-check a W_MEM row operand.
///
/// # Errors
/// [`RuleCode::WRowRange`] when `row >= 128`.
#[inline]
pub fn check_w_row(row: usize) -> Result<(), Diagnostic> {
    if row >= W_ROWS {
        return Err(Diagnostic::stream(
            RuleCode::WRowRange,
            format!("W row {row} out of range (W_MEM has {W_ROWS} rows)"),
        ));
    }
    Ok(())
}

/// Structurally validate one instruction's row operands: every row in
/// range, `AccV2V` sources distinct, `SpikeCheck` not self-comparing.
///
/// This is the shared per-instruction gate: `ImpulseMacro::execute`
/// calls it before dispatching to any engine, and the program-level
/// validator applies it to every instruction. Written values are NOT
/// checked here (the engines assert on those — see
/// [`check_instruction_values`] for the static version).
///
/// # Errors
/// The first violated rule as a [`Diagnostic`]
/// ([`RuleCode::WRowRange`], [`RuleCode::VRowRange`],
/// [`RuleCode::AccV2VSameSrc`], or [`RuleCode::SpikeCheckSelf`]).
pub fn check_instruction(instr: &Instruction) -> Result<(), Diagnostic> {
    match *instr {
        Instruction::AccW2V {
            w_row,
            v_src,
            v_dst,
            ..
        } => {
            check_w_row(w_row)?;
            check_v_row(v_src)?;
            check_v_row(v_dst)?;
        }
        Instruction::AccV2V {
            src_a, src_b, dst, ..
        } => {
            check_v_row(src_a)?;
            check_v_row(src_b)?;
            check_v_row(dst)?;
            if src_a == src_b {
                return Err(Diagnostic::stream(
                    RuleCode::AccV2VSameSrc,
                    format!("AccV2V with identical source rows ({src_a})"),
                ));
            }
        }
        Instruction::SpikeCheck { v_row, thr_row, .. } => {
            check_v_row(v_row)?;
            check_v_row(thr_row)?;
            if v_row == thr_row {
                return Err(Diagnostic::stream(
                    RuleCode::SpikeCheckSelf,
                    format!("SpikeCheck with v_row == thr_row ({v_row})"),
                ));
            }
        }
        Instruction::ResetV { reset_row, dst, .. } => {
            check_v_row(reset_row)?;
            check_v_row(dst)?;
        }
        Instruction::ReadV { v_row, .. } => check_v_row(v_row)?,
        Instruction::WriteV { v_row, .. } => check_v_row(v_row)?,
        Instruction::WriteW { w_row, .. } => check_w_row(w_row)?,
    }
    Ok(())
}

/// Statically check the written values of a `WriteV`/`WriteW`
/// instruction against their field widths (11-bit V values, 6-bit
/// weights). The engines enforce the same invariant with asserts at
/// execution time; the validator reports it as a diagnostic instead
/// so `impulse check` can flag it without panicking.
///
/// # Errors
/// [`RuleCode::ValueRange`] naming the first offending value.
pub fn check_instruction_values(instr: &Instruction) -> Result<(), Diagnostic> {
    match *instr {
        Instruction::WriteV { values, .. } => {
            for v in values {
                if !fits(v, V_BITS) {
                    return Err(Diagnostic::stream(
                        RuleCode::ValueRange,
                        format!("WriteV value {v} exceeds the {V_BITS}-bit field"),
                    ));
                }
            }
        }
        Instruction::WriteW { weights, .. } => {
            for w in weights {
                if !fits(w, W_BITS) {
                    return Err(Diagnostic::stream(
                        RuleCode::ValueRange,
                        format!("WriteW weight {w} exceeds the {W_BITS}-bit field"),
                    ));
                }
            }
        }
        _ => {}
    }
    Ok(())
}

/// Validate the preconditions of a fused union-AccW2V stream — the
/// exact contract `FastEngine::run_accw2v_stream` executes without
/// further checks, shared by every engine via
/// `ImpulseMacro::acc_w2v_fused`:
///
/// - at most [`MAX_FUSED_LANES`] lanes, each lane V row in range and
///   pairwise distinct;
/// - every union W row in range, strictly ascending (sorted,
///   duplicate-free — the order `spike_union_planes` emits);
/// - every lane mask confined to the lane table.
///
/// # Errors
/// The first violated rule as a [`Diagnostic`]; row-level findings
/// carry the offending entry's position in `rows` as their index.
pub fn check_fused_stream(
    rows: &[(usize, u32)],
    lane_v_rows: &[usize],
) -> Result<(), Diagnostic> {
    let lanes = lane_v_rows.len();
    if lanes > MAX_FUSED_LANES {
        return Err(Diagnostic::stream(
            RuleCode::FusedLaneCount,
            format!("fused batch of {lanes} lanes exceeds {MAX_FUSED_LANES}"),
        ));
    }
    for (b, &v) in lane_v_rows.iter().enumerate() {
        check_v_row(v)?;
        if lane_v_rows[..b].contains(&v) {
            return Err(Diagnostic::stream(
                RuleCode::FusedLaneDup,
                format!("lane V row {v} assigned to more than one lane"),
            ));
        }
    }
    let mut prev: Option<usize> = None;
    for (i, &(w_row, mask)) in rows.iter().enumerate() {
        if let Err(mut d) = check_w_row(w_row) {
            d.index = Some(i);
            return Err(d);
        }
        if lanes < 32 && (mask >> lanes) != 0 {
            return Err(Diagnostic::at(
                i,
                RuleCode::FusedMaskWidth,
                format!("lane mask {mask:#x} references a lane >= {lanes}"),
            ));
        }
        if let Some(p) = prev {
            if w_row <= p {
                return Err(Diagnostic::at(
                    i,
                    RuleCode::FusedRowOrder,
                    format!(
                        "union rows must be strictly ascending (row {w_row} after {p})"
                    ),
                ));
            }
        }
        prev = Some(w_row);
    }
    Ok(())
}

/// The V rows an instruction touches, with the parity alignment it
/// touches them under (`None` for `WriteW`, which only addresses
/// W_MEM).
pub(super) fn v_rows_touched(instr: &Instruction) -> Option<(Parity, [Option<usize>; 3])> {
    match *instr {
        Instruction::AccW2V {
            v_src,
            v_dst,
            parity,
            ..
        } => Some((parity, [Some(v_src), Some(v_dst), None])),
        Instruction::AccV2V {
            src_a,
            src_b,
            dst,
            parity,
            ..
        } => Some((parity, [Some(src_a), Some(src_b), Some(dst)])),
        Instruction::SpikeCheck {
            v_row,
            thr_row,
            parity,
        } => Some((parity, [Some(v_row), Some(thr_row), None])),
        Instruction::ResetV {
            reset_row,
            dst,
            parity,
        } => Some((parity, [Some(reset_row), Some(dst), None])),
        Instruction::ReadV { v_row, parity } => Some((parity, [Some(v_row), None, None])),
        Instruction::WriteV { v_row, parity, .. } => {
            Some((parity, [Some(v_row), None, None]))
        }
        Instruction::WriteW { .. } => None,
    }
}

/// Run the structural pass over a stream: per-instruction operand
/// checks, value range checks, and per-row parity-binding consistency
/// (each V_MEM row is dedicated to one staggered alignment — a row
/// touched under both parities is flagged once, at its first
/// conflicting use).
pub(super) fn check_stream(instrs: &[Instruction], diags: &mut Vec<Diagnostic>) {
    // first_touch[row] = (parity of first touch, its index);
    // conflict-reported rows are latched so one bad row doesn't spam.
    let mut first_touch: [Option<(Parity, usize)>; V_ROWS] = [None; V_ROWS];
    let mut reported: [bool; V_ROWS] = [false; V_ROWS];
    for (ix, instr) in instrs.iter().enumerate() {
        let structurally_ok = match check_instruction(instr) {
            Ok(()) => true,
            Err(mut d) => {
                d.index = Some(ix);
                diags.push(d);
                false
            }
        };
        if let Err(mut d) = check_instruction_values(instr) {
            d.index = Some(ix);
            diags.push(d);
        }
        if !structurally_ok {
            // out-of-range rows would poison the binding table
            continue;
        }
        if let Some((parity, rows)) = v_rows_touched(instr) {
            for row in rows.into_iter().flatten() {
                match first_touch[row] {
                    None => first_touch[row] = Some((parity, ix)),
                    Some((p0, ix0)) if p0 != parity && !reported[row] => {
                        reported[row] = true;
                        diags.push(Diagnostic::at(
                            ix,
                            RuleCode::ParityConflict,
                            format!(
                                "V row {row} touched as {parity:?} but bound to \
                                 {p0:?} since #{ix0}; each row is dedicated to \
                                 one staggered alignment"
                            ),
                        ));
                    }
                    _ => {}
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::WriteMaskMode;

    #[test]
    fn instruction_rules_fire() {
        assert_eq!(
            check_instruction(&Instruction::AccW2V {
                w_row: 128,
                v_src: 0,
                v_dst: 0,
                parity: Parity::Odd,
            })
            .unwrap_err()
            .code,
            RuleCode::WRowRange
        );
        assert_eq!(
            check_instruction(&Instruction::ReadV {
                v_row: 32,
                parity: Parity::Odd,
            })
            .unwrap_err()
            .code,
            RuleCode::VRowRange
        );
        assert_eq!(
            check_instruction(&Instruction::AccV2V {
                src_a: 3,
                src_b: 3,
                dst: 3,
                parity: Parity::Odd,
                mask: WriteMaskMode::All,
            })
            .unwrap_err()
            .code,
            RuleCode::AccV2VSameSrc
        );
        assert_eq!(
            check_instruction(&Instruction::SpikeCheck {
                v_row: 5,
                thr_row: 5,
                parity: Parity::Even,
            })
            .unwrap_err()
            .code,
            RuleCode::SpikeCheckSelf
        );
    }

    #[test]
    fn value_rules_fire() {
        assert_eq!(
            check_instruction_values(&Instruction::WriteV {
                v_row: 0,
                parity: Parity::Odd,
                values: [5000, 0, 0, 0, 0, 0],
            })
            .unwrap_err()
            .code,
            RuleCode::ValueRange
        );
        assert_eq!(
            check_instruction_values(&Instruction::WriteW {
                w_row: 0,
                weights: [64; 12],
            })
            .unwrap_err()
            .code,
            RuleCode::ValueRange
        );
        assert!(check_instruction_values(&Instruction::WriteW {
            w_row: 0,
            weights: [31; 12],
        })
        .is_ok());
    }

    #[test]
    fn fused_stream_rules_fire() {
        // lane table too wide
        let wide: Vec<usize> = (0..33).collect();
        assert_eq!(
            check_fused_stream(&[], &wide).unwrap_err().code,
            RuleCode::FusedLaneCount
        );
        // lane row out of range / duplicated
        assert_eq!(
            check_fused_stream(&[], &[99]).unwrap_err().code,
            RuleCode::VRowRange
        );
        assert_eq!(
            check_fused_stream(&[], &[0, 2, 0]).unwrap_err().code,
            RuleCode::FusedLaneDup
        );
        // union row out of range, over-wide mask, ordering
        assert_eq!(
            check_fused_stream(&[(200, 1)], &[0]).unwrap_err().code,
            RuleCode::WRowRange
        );
        assert_eq!(
            check_fused_stream(&[(0, 0b10)], &[0]).unwrap_err().code,
            RuleCode::FusedMaskWidth
        );
        let d = check_fused_stream(&[(4, 1), (4, 1)], &[0]).unwrap_err();
        assert_eq!(d.code, RuleCode::FusedRowOrder);
        assert_eq!(d.index, Some(1));
        assert_eq!(
            check_fused_stream(&[(7, 1), (3, 1)], &[0]).unwrap_err().code,
            RuleCode::FusedRowOrder
        );
        // the canonical shape passes
        assert!(check_fused_stream(&[(0, 0b11), (5, 0b01)], &[0, 2]).is_ok());
        assert!(check_fused_stream(&[], &[]).is_ok());
    }

    #[test]
    fn parity_binding_conflict_detected_once() {
        let instrs = vec![
            Instruction::WriteV {
                v_row: 4,
                parity: Parity::Odd,
                values: [0; 6],
            },
            Instruction::ReadV {
                v_row: 4,
                parity: Parity::Even,
            },
            Instruction::ReadV {
                v_row: 4,
                parity: Parity::Even,
            },
        ];
        let mut diags = Vec::new();
        check_stream(&instrs, &mut diags);
        let conflicts: Vec<_> = diags
            .iter()
            .filter(|d| d.code == RuleCode::ParityConflict)
            .collect();
        assert_eq!(conflicts.len(), 1, "{diags:?}");
        assert_eq!(conflicts[0].index, Some(1));
    }
}
