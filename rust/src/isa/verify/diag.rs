//! Diagnostic types for the static program analyzer: severities,
//! stable rule codes, per-finding diagnostics, and the report that
//! renders them for humans and machines.

use std::fmt;

/// How bad a finding is.
///
/// `Error` findings describe streams the macro will (or should) refuse
/// to execute — out-of-range operands, malformed fused streams,
/// spike-gated writes with nothing latched. `Warn` findings describe
/// streams that execute but probably don't mean what the emitter
/// intended — reads of never-written rows, stores no one observes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    /// The program is malformed; engines must reject it.
    Error,
    /// The program is executable but suspicious.
    Warn,
}

impl Severity {
    /// Lowercase display name (`error` / `warn`).
    #[must_use]
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warn",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Stable rule codes — the machine-readable identity of each check.
///
/// Codes are grouped by analysis layer: `S…` structural (single
/// instruction + per-row parity binding), `F…` fused-stream
/// preconditions (the contract of `ImpulseMacro::acc_w2v_fused` /
/// `FastEngine::run_accw2v_stream`), `D…` dataflow hazards (the linear
/// abstract-interpretation pass). The full catalog with worked
/// examples lives in `docs/VALIDATION.md`; codes are append-only and
/// never renumbered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RuleCode {
    /// S001 — W_MEM row operand out of range (`w_row >= 128`).
    WRowRange,
    /// S002 — V_MEM row operand out of range (`row >= 32`).
    VRowRange,
    /// S003 — `AccV2V` with identical source rows (one wordline
    /// cannot fire twice in a dual-row read).
    AccV2VSameSrc,
    /// S004 — `SpikeCheck` comparing a row against itself.
    SpikeCheckSelf,
    /// S005 — a V_MEM row touched under both parities; each row is
    /// dedicated to one staggered field alignment.
    ParityConflict,
    /// S006 — a written value exceeds its field width (11-bit V
    /// values, 6-bit weights).
    ValueRange,
    /// F001 — fused stream addresses more lanes than
    /// [`super::MAX_FUSED_LANES`].
    FusedLaneCount,
    /// F002 — a fused lane mask references a lane beyond the lane
    /// table.
    FusedMaskWidth,
    /// F003 — fused union rows not strictly ascending (sorted,
    /// duplicate-free) as `run_accw2v_stream` assumes.
    FusedRowOrder,
    /// F004 — fused lane V rows not pairwise distinct.
    FusedLaneDup,
    /// D001 — a V row (in its parity alignment) is read before any
    /// write defines it.
    UseBeforeInit,
    /// D002 — a spike-gated op (`ResetV`, spiked `AccV2V`) issued
    /// before any `SpikeCheck` latched that parity's buffer.
    GateNeverLatched,
    /// D003 — a spike-gated op issued after the checked row was
    /// rewritten, so the latched buffer is stale for it.
    GateStale,
    /// D004 — a CIM write clobbers a row later used as a
    /// threshold/reset constant.
    ConstClobber,
    /// D005 — a full-row store overwritten before anything reads it.
    DeadStore,
}

impl RuleCode {
    /// The stable short code (`S002`, `D003`, …).
    #[must_use]
    pub fn code(&self) -> &'static str {
        match self {
            RuleCode::WRowRange => "S001",
            RuleCode::VRowRange => "S002",
            RuleCode::AccV2VSameSrc => "S003",
            RuleCode::SpikeCheckSelf => "S004",
            RuleCode::ParityConflict => "S005",
            RuleCode::ValueRange => "S006",
            RuleCode::FusedLaneCount => "F001",
            RuleCode::FusedMaskWidth => "F002",
            RuleCode::FusedRowOrder => "F003",
            RuleCode::FusedLaneDup => "F004",
            RuleCode::UseBeforeInit => "D001",
            RuleCode::GateNeverLatched => "D002",
            RuleCode::GateStale => "D003",
            RuleCode::ConstClobber => "D004",
            RuleCode::DeadStore => "D005",
        }
    }

    /// The stable kebab-case rule name.
    #[must_use]
    pub fn name(&self) -> &'static str {
        match self {
            RuleCode::WRowRange => "w-row-range",
            RuleCode::VRowRange => "v-row-range",
            RuleCode::AccV2VSameSrc => "accv2v-same-src",
            RuleCode::SpikeCheckSelf => "spikecheck-self",
            RuleCode::ParityConflict => "parity-conflict",
            RuleCode::ValueRange => "value-range",
            RuleCode::FusedLaneCount => "fused-lane-count",
            RuleCode::FusedMaskWidth => "fused-mask-width",
            RuleCode::FusedRowOrder => "fused-row-order",
            RuleCode::FusedLaneDup => "fused-lane-dup",
            RuleCode::UseBeforeInit => "use-before-init",
            RuleCode::GateNeverLatched => "gate-never-latched",
            RuleCode::GateStale => "gate-stale",
            RuleCode::ConstClobber => "const-clobber",
            RuleCode::DeadStore => "dead-store",
        }
    }

    /// The severity this rule always reports at.
    #[must_use]
    pub fn severity(&self) -> Severity {
        match self {
            RuleCode::UseBeforeInit
            | RuleCode::GateStale
            | RuleCode::DeadStore => Severity::Warn,
            _ => Severity::Error,
        }
    }
}

impl fmt::Display for RuleCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} ({})", self.code(), self.name())
    }
}

/// One finding: where, how bad, which rule, and a human sentence.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    /// Index of the offending instruction in the analyzed stream
    /// (`None` for stream-level findings such as a fused lane table
    /// problem).
    pub index: Option<usize>,
    /// Severity ([`RuleCode::severity`] of `code`).
    pub severity: Severity,
    /// The stable rule that fired.
    pub code: RuleCode,
    /// Human-readable description of this specific finding.
    pub message: String,
}

impl Diagnostic {
    /// Build a diagnostic for `code` at instruction `index`.
    #[must_use]
    pub fn at(index: usize, code: RuleCode, message: String) -> Self {
        Self {
            index: Some(index),
            severity: code.severity(),
            code,
            message,
        }
    }

    /// Build a stream-level diagnostic (no instruction index).
    #[must_use]
    pub fn stream(code: RuleCode, message: String) -> Self {
        Self {
            index: None,
            severity: code.severity(),
            code,
            message,
        }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.index {
            Some(ix) => write!(
                f,
                "{}[{}] at #{ix}: {} [{}]",
                self.severity,
                self.code.code(),
                self.message,
                self.code.name()
            ),
            None => write!(
                f,
                "{}[{}]: {} [{}]",
                self.severity,
                self.code.code(),
                self.message,
                self.code.name()
            ),
        }
    }
}

impl std::error::Error for Diagnostic {}

/// The outcome of validating one instruction stream.
#[derive(Clone, Debug, Default)]
pub struct Report {
    instructions: usize,
    diags: Vec<Diagnostic>,
}

impl Report {
    /// Assemble a report over `instructions` analyzed instructions.
    #[must_use]
    pub fn new(instructions: usize, mut diags: Vec<Diagnostic>) -> Self {
        diags.sort_by_key(|d| (d.index.unwrap_or(usize::MAX), d.code));
        Self {
            instructions,
            diags,
        }
    }

    /// How many instructions were analyzed.
    #[must_use]
    pub fn instructions(&self) -> usize {
        self.instructions
    }

    /// All findings, ordered by instruction index.
    #[must_use]
    pub fn diagnostics(&self) -> &[Diagnostic] {
        &self.diags
    }

    /// Number of `Error`-severity findings.
    #[must_use]
    pub fn error_count(&self) -> usize {
        self.diags
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .count()
    }

    /// Number of `Warn`-severity findings.
    #[must_use]
    pub fn warning_count(&self) -> usize {
        self.diags
            .iter()
            .filter(|d| d.severity == Severity::Warn)
            .count()
    }

    /// No findings at all (neither errors nor warnings).
    #[must_use]
    pub fn is_clean(&self) -> bool {
        self.diags.is_empty()
    }

    /// No errors (warnings permitted) — the admission criterion.
    #[must_use]
    pub fn passes(&self) -> bool {
        self.error_count() == 0
    }

    /// Whether any finding carries the given rule code.
    #[must_use]
    pub fn has(&self, code: RuleCode) -> bool {
        self.diags.iter().any(|d| d.code == code)
    }

    /// Render the report as a JSON object (hand-rolled — the crate
    /// carries no serialization dependency; same discipline as the
    /// bench JSON emitter).
    #[must_use]
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(128 + 96 * self.diags.len());
        s.push_str(&format!(
            "{{\"instructions\":{},\"errors\":{},\"warnings\":{},\"diagnostics\":[",
            self.instructions,
            self.error_count(),
            self.warning_count()
        ));
        for (i, d) in self.diags.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            match d.index {
                Some(ix) => s.push_str(&format!("{{\"index\":{ix},")),
                None => s.push_str("{\"index\":null,"),
            }
            s.push_str(&format!(
                "\"severity\":\"{}\",\"code\":\"{}\",\"rule\":\"{}\",\"message\":\"{}\"}}",
                d.severity.name(),
                d.code.code(),
                d.code.name(),
                json_escape(&d.message)
            ));
        }
        s.push_str("]}");
        s
    }
}

impl fmt::Display for Report {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "{} instructions: {} error(s), {} warning(s)",
            self.instructions,
            self.error_count(),
            self.warning_count()
        )?;
        for d in &self.diags {
            writeln!(f, "  {d}")?;
        }
        Ok(())
    }
}

/// Escape a string for embedding in a JSON string literal.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_and_unique() {
        let all = [
            RuleCode::WRowRange,
            RuleCode::VRowRange,
            RuleCode::AccV2VSameSrc,
            RuleCode::SpikeCheckSelf,
            RuleCode::ParityConflict,
            RuleCode::ValueRange,
            RuleCode::FusedLaneCount,
            RuleCode::FusedMaskWidth,
            RuleCode::FusedRowOrder,
            RuleCode::FusedLaneDup,
            RuleCode::UseBeforeInit,
            RuleCode::GateNeverLatched,
            RuleCode::GateStale,
            RuleCode::ConstClobber,
            RuleCode::DeadStore,
        ];
        let mut codes: Vec<&str> = all.iter().map(|c| c.code()).collect();
        codes.sort_unstable();
        codes.dedup();
        assert_eq!(codes.len(), all.len(), "rule codes must be unique");
    }

    #[test]
    fn report_counts_and_json() {
        let r = Report::new(
            5,
            vec![
                Diagnostic::at(3, RuleCode::VRowRange, "V row 40 out of range".into()),
                Diagnostic::at(1, RuleCode::DeadStore, "store \"x\" unread".into()),
            ],
        );
        assert_eq!(r.error_count(), 1);
        assert_eq!(r.warning_count(), 1);
        assert!(!r.passes() || r.error_count() == 0);
        assert!(r.has(RuleCode::VRowRange));
        // sorted by index
        assert_eq!(r.diagnostics()[0].index, Some(1));
        let j = r.to_json();
        assert!(j.contains("\"errors\":1"), "{j}");
        assert!(j.contains("\"code\":\"S002\""), "{j}");
        assert!(j.contains("store \\\"x\\\" unread"), "{j}");
    }

    #[test]
    fn display_renders_index_and_code() {
        let d = Diagnostic::at(7, RuleCode::GateNeverLatched, "ResetV with no latch".into());
        let s = d.to_string();
        assert!(s.contains("#7"), "{s}");
        assert!(s.contains("D002"), "{s}");
        assert!(s.contains("error"), "{s}");
    }
}
