//! Layer 2 — dataflow hazard analysis.
//!
//! A single linear abstract-interpretation pass over the stream. Per
//! (V row, parity) we track whether the row has been defined and
//! whether its last store was ever observed; per parity we track the
//! spike-buffer state (never latched / latched-and-fresh / latched-
//! but-stale). The lattice is deliberately tiny — IMPULSE streams are
//! straight-line, so one forward walk is exact, not approximate.

use super::structural::check_instruction;
use super::{Diagnostic, RuleCode};
use crate::bitcell::{Parity, V_ROWS};
use crate::isa::{Instruction, WriteMaskMode};

/// Spike-buffer abstract state for one parity.
#[derive(Clone, Copy, PartialEq, Eq)]
enum SpikeState {
    /// No `SpikeCheck` has executed yet on this parity.
    Never,
    /// A `SpikeCheck` latched the buffer from `checked_row`; `fresh`
    /// drops to false once that row's membrane is overwritten.
    Latched { checked_row: usize, fresh: bool },
}

fn pidx(p: Parity) -> usize {
    match p {
        Parity::Odd => 0,
        Parity::Even => 1,
    }
}

struct State {
    /// Per (parity, row): has the row been written at least once?
    init: [[bool; V_ROWS]; 2],
    /// Per (parity, row): index of a store not yet read (dead-store
    /// candidate), if any.
    pending_store: [[Option<usize>; V_ROWS]; 2],
    /// D001 is reported once per (parity, row), not per use.
    warned_uninit: [[bool; V_ROWS]; 2],
    spike: [SpikeState; 2],
    assume_initialized: bool,
}

impl State {
    fn new(assume_initialized: bool) -> Self {
        State {
            init: [[assume_initialized; V_ROWS]; 2],
            pending_store: [[None; V_ROWS]; 2],
            warned_uninit: [[false; V_ROWS]; 2],
            spike: [SpikeState::Never; 2],
            assume_initialized,
        }
    }

    /// A read of `row` under `parity` at instruction `ix`.
    fn read(&mut self, ix: usize, parity: Parity, row: usize, diags: &mut Vec<Diagnostic>) {
        let p = pidx(parity);
        if !self.init[p][row] && !self.warned_uninit[p][row] {
            self.warned_uninit[p][row] = true;
            diags.push(Diagnostic::at(
                ix,
                RuleCode::UseBeforeInit,
                format!("V row {row} ({parity:?}) read before any write"),
            ));
        }
        // the store feeding this read is observed — not dead
        self.pending_store[p][row] = None;
    }

    /// A full (unconditional) overwrite of `row` under `parity`.
    fn write_full(&mut self, ix: usize, parity: Parity, row: usize, diags: &mut Vec<Diagnostic>) {
        let p = pidx(parity);
        if let Some(prev) = self.pending_store[p][row] {
            diags.push(Diagnostic::at(
                prev,
                RuleCode::DeadStore,
                format!(
                    "store to V row {row} ({parity:?}) is overwritten at #{ix} \
                     without an intervening read"
                ),
            ));
        }
        self.init[p][row] = true;
        self.pending_store[p][row] = Some(ix);
        self.stale_if_checked(parity, row);
    }

    /// A spike-gated (partial) write: some fields may survive, so the
    /// prior value is live — treat as read-modify-write.
    fn write_gated(&mut self, ix: usize, parity: Parity, row: usize, diags: &mut Vec<Diagnostic>) {
        self.read(ix, parity, row, diags);
        let p = pidx(parity);
        self.init[p][row] = true;
        self.pending_store[p][row] = Some(ix);
        self.stale_if_checked(parity, row);
    }

    /// Overwriting the row the spike buffer was latched from makes
    /// the buffer stale for subsequent gated ops.
    fn stale_if_checked(&mut self, parity: Parity, row: usize) {
        let p = pidx(parity);
        if let SpikeState::Latched { checked_row, fresh: true } = self.spike[p] {
            if checked_row == row {
                self.spike[p] = SpikeState::Latched {
                    checked_row,
                    fresh: false,
                };
            }
        }
    }

    /// Validate the spike buffer before a gated op (`ResetV`,
    /// `AccV2V` with [`WriteMaskMode::Spiked`]).
    fn check_gate(&self, ix: usize, parity: Parity, what: &str, diags: &mut Vec<Diagnostic>) {
        match self.spike[pidx(parity)] {
            SpikeState::Never => diags.push(Diagnostic::at(
                ix,
                RuleCode::GateNeverLatched,
                format!(
                    "{what} ({parity:?}) gated on a spike buffer that no \
                     SpikeCheck has latched"
                ),
            )),
            SpikeState::Latched { checked_row, fresh: false } => diags.push(Diagnostic::at(
                ix,
                RuleCode::GateStale,
                format!(
                    "{what} ({parity:?}) gated on a spike buffer latched from \
                     V row {checked_row}, whose membrane has since changed"
                ),
            )),
            SpikeState::Latched { fresh: true, .. } => {}
        }
    }
}

/// Indices at which each (parity, row) pair is used as a `thr_row` or
/// `reset_row` — the rows the schedule treats as constants.
fn const_row_uses(instrs: &[Instruction]) -> Vec<(usize, Parity, usize, &'static str)> {
    let mut uses = Vec::new();
    for (ix, instr) in instrs.iter().enumerate() {
        match *instr {
            Instruction::SpikeCheck { thr_row, parity, .. } => {
                uses.push((ix, parity, thr_row, "thr_row"));
            }
            Instruction::ResetV { reset_row, parity, .. } => {
                uses.push((ix, parity, reset_row, "reset_row"));
            }
            _ => {}
        }
    }
    uses
}

/// Run the dataflow pass. Instructions that fail structural checks
/// are skipped (their operands cannot be trusted to index state).
pub(super) fn check_stream(
    instrs: &[Instruction],
    assume_initialized: bool,
    diags: &mut Vec<Diagnostic>,
) {
    let const_uses = const_row_uses(instrs);
    let mut st = State::new(assume_initialized);
    for (ix, instr) in instrs.iter().enumerate() {
        if check_instruction(instr).is_err() {
            continue;
        }
        // D004: a CIM write clobbering a row a later instruction
        // reads as thr_row/reset_row
        let cim_write_target: Option<(Parity, usize)> = match *instr {
            Instruction::AccW2V { v_dst, parity, .. } => Some((parity, v_dst)),
            Instruction::AccV2V { dst, parity, .. } => Some((parity, dst)),
            Instruction::ResetV { dst, parity, .. } => Some((parity, dst)),
            _ => None,
        };
        if let Some((parity, row)) = cim_write_target {
            if let Some(&(use_ix, _, _, role)) = const_uses
                .iter()
                .find(|&&(j, p, r, _)| j > ix && p == parity && r == row)
            {
                diags.push(Diagnostic::at(
                    ix,
                    RuleCode::ConstClobber,
                    format!(
                        "write clobbers V row {row} ({parity:?}), used as \
                         {role} at #{use_ix}"
                    ),
                ));
            }
        }
        match *instr {
            Instruction::AccW2V {
                v_src,
                v_dst,
                parity,
                ..
            } => {
                st.read(ix, parity, v_src, diags);
                st.write_full(ix, parity, v_dst, diags);
            }
            Instruction::AccV2V {
                src_a,
                src_b,
                dst,
                parity,
                mask,
            } => {
                st.read(ix, parity, src_a, diags);
                st.read(ix, parity, src_b, diags);
                match mask {
                    WriteMaskMode::All => st.write_full(ix, parity, dst, diags),
                    WriteMaskMode::Spiked => {
                        st.check_gate(ix, parity, "AccV2V(Spiked)", diags);
                        st.write_gated(ix, parity, dst, diags);
                    }
                }
            }
            Instruction::SpikeCheck { v_row, thr_row, parity } => {
                st.read(ix, parity, v_row, diags);
                st.read(ix, parity, thr_row, diags);
                st.spike[pidx(parity)] = SpikeState::Latched {
                    checked_row: v_row,
                    fresh: true,
                };
            }
            Instruction::ResetV { reset_row, dst, parity } => {
                st.check_gate(ix, parity, "ResetV", diags);
                st.read(ix, parity, reset_row, diags);
                st.write_gated(ix, parity, dst, diags);
            }
            Instruction::ReadV { v_row, parity } => {
                st.read(ix, parity, v_row, diags);
            }
            Instruction::WriteV { v_row, parity, .. } => {
                // host-side programming; a later overwrite without a
                // read still counts as a dead store
                st.write_full(ix, parity, v_row, diags);
            }
            Instruction::WriteW { .. } => {}
        }
    }
    // stores still pending at end-of-stream are NOT dead: macro state
    // persists across programs (streaming sessions read it later).
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitcell::Parity::{Even, Odd};

    fn run(instrs: &[Instruction], assume: bool) -> Vec<Diagnostic> {
        let mut diags = Vec::new();
        check_stream(instrs, assume, &mut diags);
        diags
    }

    #[test]
    fn use_before_init_warned_once_per_row() {
        let p = [
            Instruction::ReadV { v_row: 3, parity: Odd },
            Instruction::ReadV { v_row: 3, parity: Odd },
        ];
        let diags = run(&p, false);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].code, RuleCode::UseBeforeInit);
        assert!(run(&p, true).is_empty());
    }

    #[test]
    fn gate_never_latched_is_error() {
        let p = [Instruction::ResetV {
            reset_row: 30,
            dst: 0,
            parity: Odd,
        }];
        let diags = run(&p, true);
        assert!(diags.iter().any(|d| d.code == RuleCode::GateNeverLatched));
    }

    #[test]
    fn gate_goes_stale_when_checked_row_changes() {
        let p = [
            Instruction::SpikeCheck { v_row: 0, thr_row: 28, parity: Odd },
            Instruction::WriteV { v_row: 0, parity: Odd, values: [0; 6] },
            Instruction::ResetV { reset_row: 30, dst: 0, parity: Odd },
        ];
        let diags = run(&p, true);
        assert!(diags.iter().any(|d| d.code == RuleCode::GateStale));
        // per-parity isolation: an Even gate is unaffected by Odd latches
        let q = [
            Instruction::SpikeCheck { v_row: 0, thr_row: 28, parity: Odd },
            Instruction::ResetV { reset_row: 31, dst: 1, parity: Even },
        ];
        assert!(run(&q, true)
            .iter()
            .any(|d| d.code == RuleCode::GateNeverLatched));
    }

    #[test]
    fn fresh_gate_sequence_is_clean() {
        // the IF sequence shape from Fig. 6
        let p = [
            Instruction::SpikeCheck { v_row: 0, thr_row: 28, parity: Odd },
            Instruction::ResetV { reset_row: 30, dst: 0, parity: Odd },
        ];
        assert!(run(&p, true).is_empty());
    }

    #[test]
    fn const_clobber_is_error() {
        let p = [
            Instruction::AccW2V { w_row: 0, v_src: 28, v_dst: 28, parity: Odd },
            Instruction::SpikeCheck { v_row: 0, thr_row: 28, parity: Odd },
        ];
        let diags = run(&p, true);
        assert!(diags.iter().any(|d| d.code == RuleCode::ConstClobber));
    }

    #[test]
    fn dead_store_warned_at_first_store() {
        let p = [
            Instruction::WriteV { v_row: 2, parity: Odd, values: [1; 6] },
            Instruction::WriteV { v_row: 2, parity: Odd, values: [2; 6] },
            Instruction::ReadV { v_row: 2, parity: Odd },
        ];
        let diags = run(&p, true);
        let dead: Vec<_> = diags
            .iter()
            .filter(|d| d.code == RuleCode::DeadStore)
            .collect();
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].index, Some(0));
        // a store that is read, then overwritten, is not dead; nor is
        // a store pending at end-of-stream
        let q = [
            Instruction::WriteV { v_row: 2, parity: Odd, values: [1; 6] },
            Instruction::ReadV { v_row: 2, parity: Odd },
            Instruction::WriteV { v_row: 2, parity: Odd, values: [2; 6] },
        ];
        assert!(run(&q, true).is_empty());
    }
}
