//! The in-memory SNN instruction set.
//!
//! Every CIM instruction is single-cycle and operates on a whole row
//! (six values) at once. The instruction stream *is* the neuron model:
//! IF, LIF and RMP neurons are different sequences of the same four
//! instructions (Fig 5/6 of the paper).

#![warn(missing_docs)]

mod instruction;
mod program;
mod sequences;
pub mod verify;

pub use instruction::{Instruction, InstructionKind, WriteMaskMode};
pub use program::{Program, ProgramBuilder};
pub use sequences::{neuron_sequence, NeuronConfigRows, NeuronType};
pub use verify::{ProgramValidator, Report};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitcell::Parity;

    #[test]
    fn kind_of_every_instruction() {
        let i = Instruction::AccW2V {
            w_row: 0,
            v_src: 0,
            v_dst: 0,
            parity: Parity::Odd,
        };
        assert_eq!(i.kind(), InstructionKind::AccW2V);
        assert_eq!(i.kind().name(), "AccW2V");
    }
}
