//! The triple-row decoder.
//!
//! IMPULSE's decoder takes up to three addresses per cycle and fires
//! two read wordlines and one write wordline *simultaneously* — that is
//! what lets a single cycle read two operand rows through the shared
//! bitlines, push the sums through the column-peripheral adders, and
//! write the result back.

use super::{Parity, V_ROWS, W_ROWS};
use thiserror::Error;

/// A decoded row address within the fused macro.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RowAddr {
    /// A W_MEM row; which interleaved half is read depends on the cycle
    /// parity (RWLo vs RWLe).
    W(usize),
    /// A V_MEM row (single RWL).
    V(usize),
}

impl RowAddr {
    /// Validate the address against the macro geometry.
    pub fn validate(&self) -> Result<(), DecodeError> {
        match *self {
            RowAddr::W(r) if r >= W_ROWS => Err(DecodeError::WRowOutOfRange(r)),
            RowAddr::V(r) if r >= V_ROWS => Err(DecodeError::VRowOutOfRange(r)),
            _ => Ok(()),
        }
    }
}

/// Errors from wordline selection.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum DecodeError {
    #[error("W_MEM row {0} out of range (0..{W_ROWS})")]
    WRowOutOfRange(usize),
    #[error("V_MEM row {0} out of range (0..{V_ROWS})")]
    VRowOutOfRange(usize),
    #[error("write target must be a V_MEM row, got {0:?}")]
    WriteToWMem(RowAddr),
    #[error("CIM reads enable at most two rows")]
    TooManyReads,
    #[error("read rows must be distinct when both are V_MEM row {0}")]
    DuplicateVRead(usize),
}

/// The set of wordlines fired in one cycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WordlineSet {
    /// Up to two read rows.
    pub reads: [Option<RowAddr>; 2],
    /// Optional write row (CIM writes always land in V_MEM — weights
    /// are written through the normal SRAM write port, not during CIM).
    pub write: Option<usize>,
    /// Cycle parity (selects RWLo/RWLe and the field stagger).
    pub parity: Parity,
}

/// Functional model of the triple-row decoder: validates and produces a
/// [`WordlineSet`]. In silicon this is two read decoders and one write
/// decoder operating in parallel on a shared address bus.
#[derive(Clone, Copy, Debug, Default)]
pub struct TripleRowDecoder;

impl TripleRowDecoder {
    /// Decode a (reads, write, parity) request into fired wordlines.
    pub fn decode(
        &self,
        reads: &[RowAddr],
        write: Option<RowAddr>,
        parity: Parity,
    ) -> Result<WordlineSet, DecodeError> {
        if reads.len() > 2 {
            return Err(DecodeError::TooManyReads);
        }
        for r in reads {
            r.validate()?;
        }
        if reads.len() == 2 {
            if let (RowAddr::V(a), RowAddr::V(b)) = (reads[0], reads[1]) {
                if a == b {
                    return Err(DecodeError::DuplicateVRead(a));
                }
            }
        }
        let write = match write {
            None => None,
            Some(RowAddr::V(r)) => {
                RowAddr::V(r).validate()?;
                Some(r)
            }
            Some(other) => return Err(DecodeError::WriteToWMem(other)),
        };
        let mut rd = [None, None];
        for (i, r) in reads.iter().enumerate() {
            rd[i] = Some(*r);
        }
        Ok(WordlineSet {
            reads: rd,
            write,
            parity,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_triple_decode() {
        let d = TripleRowDecoder;
        let ws = d
            .decode(
                &[RowAddr::W(5), RowAddr::V(3)],
                Some(RowAddr::V(3)),
                Parity::Odd,
            )
            .unwrap();
        assert_eq!(ws.reads[0], Some(RowAddr::W(5)));
        assert_eq!(ws.reads[1], Some(RowAddr::V(3)));
        assert_eq!(ws.write, Some(3));
        assert_eq!(ws.parity, Parity::Odd);
    }

    #[test]
    fn rejects_out_of_range() {
        let d = TripleRowDecoder;
        assert_eq!(
            d.decode(&[RowAddr::W(128)], None, Parity::Odd),
            Err(DecodeError::WRowOutOfRange(128))
        );
        assert_eq!(
            d.decode(&[RowAddr::V(32)], None, Parity::Odd),
            Err(DecodeError::VRowOutOfRange(32))
        );
    }

    #[test]
    fn rejects_write_to_wmem() {
        let d = TripleRowDecoder;
        assert_eq!(
            d.decode(&[RowAddr::V(0)], Some(RowAddr::W(0)), Parity::Even),
            Err(DecodeError::WriteToWMem(RowAddr::W(0)))
        );
    }

    #[test]
    fn rejects_three_reads_and_duplicate_v() {
        let d = TripleRowDecoder;
        assert_eq!(
            d.decode(
                &[RowAddr::V(0), RowAddr::V(1), RowAddr::V(2)],
                None,
                Parity::Odd
            ),
            Err(DecodeError::TooManyReads)
        );
        assert_eq!(
            d.decode(&[RowAddr::V(7), RowAddr::V(7)], None, Parity::Odd),
            Err(DecodeError::DuplicateVRead(7))
        );
    }

    #[test]
    fn same_w_row_both_halves_is_legal() {
        // Reading a W row together with a V row is the AccW2V shape;
        // reading the same W row twice is silently the same wordline.
        let d = TripleRowDecoder;
        assert!(d
            .decode(&[RowAddr::W(3), RowAddr::W(3)], None, Parity::Even)
            .is_ok());
    }
}
