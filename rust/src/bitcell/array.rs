//! Packed bit-array storage with the 10T dual-row read semantics.

use super::{COLS, COL_MASK};

/// Result of a (possibly dual-row) bitline read.
///
/// `or` carries, per column, the OR of all *driven* cells; `and` the AND
/// over driven cells. `driven` marks columns where at least one enabled
/// cell is connected. Undriven columns leave both bitlines precharged,
/// which the sensing stage reports as `(or=0, and=1)` — peripherals must
/// only be active on driven columns (enforced by the adder config).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DualRead {
    pub or: u128,
    pub and: u128,
    pub driven: u128,
}

impl DualRead {
    /// Combine two single-port reads sharing the bitlines.
    pub fn combine(a: DualRead, b: DualRead) -> DualRead {
        let driven = a.driven | b.driven;
        // OR of driven bits: undriven contributes 0.
        let or = (a.or & a.driven) | (b.or & b.driven);
        // AND over driven bits: undriven contributes 1 (vacuous).
        let and = (a.or | !a.driven) & (b.or | !b.driven) & COL_MASK;
        DualRead { or, and, driven }
    }

    /// A read with no enabled rows (both bitlines precharged).
    pub fn idle() -> DualRead {
        DualRead {
            or: 0,
            and: COL_MASK,
            driven: 0,
        }
    }

    /// Per-column XOR of the two operands (valid only on driven columns
    /// where exactly the intended cells drive).
    #[inline]
    pub fn xor(&self) -> u128 {
        self.or & !self.and
    }
}

/// A rows×COLS bit array, one `u128` per row (COLS = 78 ≤ 128).
///
/// This is the storage substrate for both W_MEM and V_MEM. It knows
/// nothing about weights or membrane potentials — the layout module and
/// the macro give the bits meaning.
#[derive(Clone, Debug)]
pub struct BitArray {
    rows: Vec<u128>,
}

impl BitArray {
    /// All-zero array with `rows` rows.
    pub fn new(rows: usize) -> Self {
        Self {
            rows: vec![0u128; rows],
        }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    /// Raw row bits (low `COLS` bits used).
    #[inline]
    pub fn row(&self, r: usize) -> u128 {
        self.rows[r]
    }

    /// Overwrite a full row.
    #[inline]
    pub fn set_row(&mut self, r: usize, bits: u128) {
        debug_assert_eq!(bits & !COL_MASK, 0, "bits beyond column {COLS}");
        self.rows[r] = bits & COL_MASK;
    }

    /// Read one bit.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(c < COLS);
        (self.rows[r] >> c) & 1 == 1
    }

    /// Write one bit.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        debug_assert!(c < COLS);
        if v {
            self.rows[r] |= 1u128 << c;
        } else {
            self.rows[r] &= !(1u128 << c);
        }
    }

    /// Single-row read through a drive mask: only columns in `mask`
    /// have cells connected to the fired wordline (RWLo/RWLe interleave
    /// for W_MEM; full-row for V_MEM).
    #[inline]
    pub fn read_masked(&self, r: usize, mask: u128) -> DualRead {
        let bits = self.rows[r] & mask;
        DualRead {
            or: bits,
            and: (bits | !mask) & COL_MASK,
            driven: mask & COL_MASK,
        }
    }

    /// Masked write: columns in `mask` take `data`'s bit, others keep
    /// their value (the conditional write driver leaves their bitlines
    /// precharged).
    #[inline]
    pub fn write_masked(&mut self, r: usize, data: u128, mask: u128) {
        let m = mask & COL_MASK;
        self.rows[r] = (self.rows[r] & !m) | (data & m);
    }

    /// Zero every row.
    pub fn clear(&mut self) {
        for r in self.rows.iter_mut() {
            *r = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::XorShiftRng;

    #[test]
    fn get_set_roundtrip() {
        let mut a = BitArray::new(4);
        a.set(2, 77, true);
        a.set(2, 0, true);
        assert!(a.get(2, 77));
        assert!(a.get(2, 0));
        assert!(!a.get(2, 38));
        a.set(2, 77, false);
        assert!(!a.get(2, 77));
    }

    #[test]
    fn dual_read_is_or_and_of_driven_cells() {
        let mut a = BitArray::new(2);
        // col0: 1,1 -> or=1 and=1; col1: 1,0 -> or=1 and=0;
        // col2: 0,0 -> or=0 and=0; col3 driven only in row0: bit=1.
        a.set(0, 0, true);
        a.set(1, 0, true);
        a.set(0, 1, true);
        a.set(0, 3, true);
        let ra = a.read_masked(0, 0b1111);
        let rb = a.read_masked(1, 0b0111);
        let d = DualRead::combine(ra, rb);
        assert_eq!(d.or & 0b1111, 0b1011);
        assert_eq!(d.and & 0b1111, 0b1001); // col3 single-driven: and = bit
        assert_eq!(d.driven & 0b1111, 0b1111);
        assert_eq!(d.xor() & 0b1111, 0b0010);
    }

    #[test]
    fn undriven_columns_read_precharged() {
        let a = BitArray::new(1);
        let d = DualRead::combine(a.read_masked(0, 0), a.read_masked(0, 0));
        assert_eq!(d, DualRead::idle());
        assert_eq!(d.or, 0);
        assert_eq!(d.and, COL_MASK);
    }

    #[test]
    fn reads_are_non_destructive() {
        // 10T property: any sequence of reads leaves the array unchanged.
        let mut a = BitArray::new(8);
        let mut rng = XorShiftRng::new(11);
        for r in 0..8 {
            a.set_row(r, (rng.next_u64() as u128) & COL_MASK);
        }
        let before: Vec<u128> = (0..8).map(|r| a.row(r)).collect();
        for _ in 0..100 {
            let r1 = rng.gen_range(8) as usize;
            let r2 = rng.gen_range(8) as usize;
            let m = (rng.next_u64() as u128) & COL_MASK;
            let _ = DualRead::combine(a.read_masked(r1, m), a.read_masked(r2, !m & COL_MASK));
        }
        let after: Vec<u128> = (0..8).map(|r| a.row(r)).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn masked_write_only_touches_masked_columns() {
        let mut a = BitArray::new(1);
        a.set_row(0, 0b1010_1010);
        a.write_masked(0, 0b0101_0101, 0b0000_1111);
        assert_eq!(a.row(0), 0b1010_0101);
    }

    #[test]
    fn single_row_read_equals_self_pair() {
        // Reading one row must look like the row paired with itself:
        // or = and = bits on driven columns.
        let mut a = BitArray::new(1);
        a.set_row(0, 0b1100);
        let d = a.read_masked(0, 0b1111);
        assert_eq!(d.or & 0b1111, 0b1100);
        assert_eq!(d.and & 0b1111, 0b1100);
        assert_eq!(d.xor(), 0);
    }
}
