//! Staggered data layout: where weights and membrane potentials live
//! within a row's 78 columns.

use super::{Parity, COLS, FIELD_WIDTH, VALUES_PER_ROW, WEIGHTS_PER_ROW};
use crate::bits::{from_bits_le, to_bits_le, V_BITS, W_BITS};

/// Bit offset (within a 12-column field) of the "hole" column — the
/// column that carries the weight sign bit in AccW2V and is therefore
/// kept `0` in every stored V_MEM value.
pub const VALUE_HOLE_OFFSET: usize = 5;

/// Base column of value field `g` (0..6) in the given parity.
#[inline]
pub fn field_base(g: usize, parity: Parity) -> usize {
    debug_assert!(g < VALUES_PER_ROW);
    g * FIELD_WIDTH + parity.stagger()
}

/// Column-layout helper for one parity: encodes/decodes weights and
/// 11-bit values to/from packed 78-bit row words.
#[derive(Clone, Copy, Debug)]
pub struct FieldLayout {
    pub parity: Parity,
}

impl FieldLayout {
    pub fn new(parity: Parity) -> Self {
        Self { parity }
    }

    /// Columns (as a mask) of value field `g`.
    pub fn field_mask(&self, g: usize) -> u128 {
        ((1u128 << FIELD_WIDTH) - 1) << field_base(g, self.parity)
    }

    /// Mask over all six value fields of this parity.
    pub fn all_fields_mask(&self) -> u128 {
        (0..VALUES_PER_ROW).fold(0u128, |m, g| m | self.field_mask(g))
    }

    /// Mask of the hole columns (bit 5 of each field) of this parity.
    pub fn hole_mask(&self) -> u128 {
        (0..VALUES_PER_ROW).fold(0u128, |m, g| {
            m | (1u128 << (field_base(g, self.parity) + VALUE_HOLE_OFFSET))
        })
    }

    /// Drive mask of the W_MEM read wordline for this parity: the cells
    /// of even-indexed weights hang off RWLo (odd parity), odd-indexed
    /// off RWLe (even parity).
    pub fn w_drive_mask(&self) -> u128 {
        let mut m = 0u128;
        for j in 0..WEIGHTS_PER_ROW {
            let on_this_parity = match self.parity {
                Parity::Odd => j % 2 == 0,
                Parity::Even => j % 2 == 1,
            };
            if on_this_parity {
                m |= ((1u128 << W_BITS) - 1) << (j * W_BITS as usize);
            }
        }
        m
    }

    /// Encode an 11-bit signed value into its 12-column field position
    /// (bits 0..4 at field offsets 0..4, bits 5..10 at offsets 6..11;
    /// offset 5 — the hole — stays 0).
    pub fn encode_value(&self, g: usize, value: i64) -> u128 {
        let bits = to_bits_le(value, V_BITS);
        let base = field_base(g, self.parity);
        let mut word = 0u128;
        for (i, &b) in bits.iter().enumerate() {
            if b {
                let off = if i < VALUE_HOLE_OFFSET { i } else { i + 1 };
                word |= 1u128 << (base + off);
            }
        }
        word
    }

    /// Decode field `g` of a packed row into an 11-bit signed value.
    /// The hole column is ignored (asserted 0 in debug builds).
    pub fn decode_value(&self, row: u128, g: usize) -> i64 {
        let base = field_base(g, self.parity);
        debug_assert_eq!(
            (row >> (base + VALUE_HOLE_OFFSET)) & 1,
            0,
            "V_MEM hole column must be 0 (field {g})"
        );
        let mut bits = [false; V_BITS as usize];
        for (i, b) in bits.iter_mut().enumerate() {
            let off = if i < VALUE_HOLE_OFFSET { i } else { i + 1 };
            *b = (row >> (base + off)) & 1 == 1;
        }
        from_bits_le(&bits)
    }

    /// Encode a full V_MEM row (six values) into a packed row word.
    pub fn encode_row(&self, values: &[i64]) -> u128 {
        assert_eq!(values.len(), VALUES_PER_ROW);
        values
            .iter()
            .enumerate()
            .fold(0u128, |w, (g, &v)| w | self.encode_value(g, v))
    }

    /// Decode all six values of a packed row word.
    pub fn decode_row(&self, row: u128) -> Vec<i64> {
        (0..VALUES_PER_ROW).map(|g| self.decode_value(row, g)).collect()
    }
}

/// Encode one 6-bit signed weight at its column-sequential position
/// (weight `j` at columns `6j..6j+5`, LSB lowest).
pub fn encode_weight(j: usize, w: i64) -> u128 {
    assert!(j < WEIGHTS_PER_ROW);
    let bits = to_bits_le(w, W_BITS);
    let base = j * W_BITS as usize;
    bits.iter()
        .enumerate()
        .fold(0u128, |acc, (i, &b)| if b { acc | (1u128 << (base + i)) } else { acc })
}

/// Decode weight `j` from a packed W_MEM row word.
pub fn decode_weight(row: u128, j: usize) -> i64 {
    assert!(j < WEIGHTS_PER_ROW);
    let base = j * W_BITS as usize;
    let bits: Vec<bool> = (0..W_BITS as usize)
        .map(|i| (row >> (base + i)) & 1 == 1)
        .collect();
    from_bits_le(&bits)
}

/// Encode a full W_MEM row of twelve 6-bit weights.
pub fn encode_weight_row(ws: &[i64]) -> u128 {
    assert_eq!(ws.len(), WEIGHTS_PER_ROW);
    ws.iter()
        .enumerate()
        .fold(0u128, |acc, (j, &w)| acc | encode_weight(j, w))
}

/// Decode a full W_MEM row.
pub fn decode_weight_row(row: u128) -> Vec<i64> {
    (0..WEIGHTS_PER_ROW).map(|j| decode_weight(row, j)).collect()
}

/// The weight index accumulated into field `g` during a cycle of the
/// given parity (odd cycles touch even-indexed weights and vice versa).
#[inline]
pub fn weight_index(g: usize, parity: Parity) -> usize {
    match parity {
        Parity::Odd => 2 * g,
        Parity::Even => 2 * g + 1,
    }
}

/// Sanity: every field fits within the physical columns.
pub fn check_geometry() {
    for parity in Parity::BOTH {
        for g in 0..VALUES_PER_ROW {
            assert!(field_base(g, parity) + FIELD_WIDTH <= COLS);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::XorShiftRng;

    #[test]
    fn value_roundtrip_all() {
        for parity in Parity::BOTH {
            let l = FieldLayout::new(parity);
            for g in 0..VALUES_PER_ROW {
                for v in [-1024i64, -513, -1, 0, 1, 2, 511, 1023] {
                    let w = l.encode_value(g, v);
                    assert_eq!(l.decode_value(w, g), v, "parity={parity:?} g={g} v={v}");
                }
            }
        }
    }

    #[test]
    fn hole_is_always_zero() {
        let l = FieldLayout::new(Parity::Odd);
        let mut rng = XorShiftRng::new(1);
        for _ in 0..200 {
            let vals: Vec<i64> = (0..VALUES_PER_ROW).map(|_| rng.gen_i64(-1024, 1023)).collect();
            let row = l.encode_row(&vals);
            assert_eq!(row & l.hole_mask(), 0);
            assert_eq!(l.decode_row(row), vals);
        }
    }

    #[test]
    fn weight_roundtrip_all() {
        for j in 0..WEIGHTS_PER_ROW {
            for w in -32..=31 {
                let row = encode_weight(j, w);
                assert_eq!(decode_weight(row, j), w);
            }
        }
    }

    #[test]
    fn weight_row_roundtrip() {
        let mut rng = XorShiftRng::new(2);
        for _ in 0..100 {
            let ws: Vec<i64> = (0..WEIGHTS_PER_ROW).map(|_| rng.gen_i64(-32, 31)).collect();
            assert_eq!(decode_weight_row(encode_weight_row(&ws)), ws);
        }
    }

    #[test]
    fn weight_sign_column_aligns_with_hole() {
        // The MSB (sign) column of the weight accumulated into field g
        // must be exactly the hole column of that field.
        for parity in Parity::BOTH {
            for g in 0..VALUES_PER_ROW {
                let j = weight_index(g, parity);
                let sign_col = j * W_BITS as usize + (W_BITS as usize - 1);
                assert_eq!(
                    sign_col,
                    field_base(g, parity) + VALUE_HOLE_OFFSET,
                    "parity={parity:?} g={g} j={j}"
                );
            }
        }
    }

    #[test]
    fn w_drive_masks_partition_weight_columns() {
        let o = FieldLayout::new(Parity::Odd).w_drive_mask();
        let e = FieldLayout::new(Parity::Even).w_drive_mask();
        assert_eq!(o & e, 0);
        assert_eq!(o | e, (1u128 << 72) - 1);
    }

    #[test]
    fn field_masks_are_disjoint_and_within_cols() {
        check_geometry();
        for parity in Parity::BOTH {
            let l = FieldLayout::new(parity);
            let mut seen = 0u128;
            for g in 0..VALUES_PER_ROW {
                let m = l.field_mask(g);
                assert_eq!(seen & m, 0);
                seen |= m;
            }
            assert_eq!(seen, l.all_fields_mask());
            assert_eq!(seen & !super::super::COL_MASK, 0);
        }
    }

    #[test]
    fn weight_lands_in_low_half_of_its_field() {
        // Weight j for field g occupies the first 6 columns of the field.
        for parity in Parity::BOTH {
            for g in 0..VALUES_PER_ROW {
                let j = weight_index(g, parity);
                assert_eq!(j * W_BITS as usize, field_base(g, parity));
            }
        }
    }
}
