//! 10T-SRAM bitcell array simulation.
//!
//! The IMPULSE macro fuses two subarrays on common bitlines:
//!
//! - **W_MEM** — 128 rows × 78 columns. Each row stores twelve 6-bit
//!   signed weights laid out column-sequentially (weight *j* occupies
//!   columns `6j..6j+5`, LSB at the lowest column). Each row has two
//!   read wordlines: cells of even-indexed weights connect to **RWLo**
//!   (fired in *odd* cycles), cells of odd-indexed weights to **RWLe**
//!   (fired in *even* cycles).
//! - **V_MEM** — 32 rows × 78 columns with a single RWL per row, each
//!   row holding six 11-bit signed membrane potentials in 12-column
//!   fields. Odd-cycle fields start at columns {0,12,…,60}; even-cycle
//!   fields are staggered by 6 (columns {6,18,…,66}); within a field the
//!   bit at offset 5 (the column carrying the weight sign in AccW2V) is
//!   hardware-forced to `0` — the "hole" that makes an 11-bit value
//!   occupy a 12-column field.
//!
//! The 10T cell has a differential read port: an enabled cell pulls RBL
//! low when it stores `1` and RBLB low when it stores `0`. With two rows
//! enabled on the same bitlines, RBL therefore senses `NOR(a,b)` and
//! RBLB senses `¬AND … ` — functionally, after the sensing inverters the
//! peripherals see `OR` and `AND` of the enabled cells (see
//! [`crate::periph`]). Reads are non-destructive (no read disturb) —
//! the decoupled read port never exposes the storage nodes.

mod array;
mod decoder;
mod layout;

pub use array::{BitArray, DualRead};
pub use decoder::{DecodeError, RowAddr, TripleRowDecoder, WordlineSet};
pub use layout::{
    check_geometry, decode_weight, decode_weight_row, encode_weight, encode_weight_row,
    field_base, weight_index, FieldLayout, VALUE_HOLE_OFFSET,
};

/// Cycle parity selecting which interleaved half of the macro is active.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Parity {
    /// "Odd" cycle: RWLo fires; fields based at columns {0,12,…,60}.
    Odd,
    /// "Even" cycle: RWLe fires; fields based at columns {6,18,…,66}.
    Even,
}

impl Parity {
    /// Column offset the staggered mapping adds in this parity.
    #[inline]
    pub fn stagger(self) -> usize {
        match self {
            Parity::Odd => 0,
            Parity::Even => FIELD_WIDTH / 2,
        }
    }

    /// The opposite parity.
    #[inline]
    pub fn flip(self) -> Parity {
        match self {
            Parity::Odd => Parity::Even,
            Parity::Even => Parity::Odd,
        }
    }

    /// Both parities, in instruction-issue order.
    pub const BOTH: [Parity; 2] = [Parity::Odd, Parity::Even];
}

/// Number of rows in the weight subarray (= max fan-in of a layer).
pub const W_ROWS: usize = 128;
/// Number of rows in the membrane-potential subarray.
pub const V_ROWS: usize = 32;
/// Physical bitline columns. 72 weight columns + 6 stagger columns so
/// the even-cycle fields {6..17, …, 66..77} fit (modelling choice M1 in
/// DESIGN.md §5 — the paper does not state the physical column count).
pub const COLS: usize = 78;
/// Weights stored per W_MEM row (6 per parity).
pub const WEIGHTS_PER_ROW: usize = 12;
/// Values (membrane potentials) per V_MEM row per parity.
pub const VALUES_PER_ROW: usize = 6;
/// Columns spanned by one accumulate field (11-bit value + sign hole).
pub const FIELD_WIDTH: usize = 12;

/// Mask with the low `COLS` bits set — every physical column.
pub const COL_MASK: u128 = (1u128 << COLS) - 1;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_is_consistent() {
        // 12 weights of 6 bits each = 72 weight columns.
        assert_eq!(WEIGHTS_PER_ROW * crate::bits::W_BITS as usize, 72);
        // Even-parity last field must end exactly at the last column.
        let last_even_field = field_base(VALUES_PER_ROW - 1, Parity::Even);
        assert_eq!(last_even_field + FIELD_WIDTH, COLS);
        // Odd-parity fields tile the first 72 columns.
        let last_odd_field = field_base(VALUES_PER_ROW - 1, Parity::Odd);
        assert_eq!(last_odd_field + FIELD_WIDTH, 72);
    }

    #[test]
    fn parity_helpers() {
        assert_eq!(Parity::Odd.stagger(), 0);
        assert_eq!(Parity::Even.stagger(), 6);
        assert_eq!(Parity::Odd.flip(), Parity::Even);
        assert_eq!(Parity::Even.flip(), Parity::Odd);
    }
}
