//! Convolutional layer mapping (paper Fig 3b).
//!
//! A 3×3, C_in-channel conv kernel with C_out output channels is an FC
//! block with fan-in `9·C_in` (≤ 128 when C_in = 14 — the paper's
//! constraint) shared across output pixels. W_MEM row `(ky·3 + kx)·C_in
//! + c` holds the kernel tap for window offset (ky, kx) and input
//! channel c; output channels are weight slots.
//!
//! Membrane potentials are *per output pixel per channel*, so pixels
//! are distributed over a pool of macros (the paper's "distributed
//! multi-macro architecture"): each macro's V_MEM holds up to 13
//! odd/even row pairs = 13 pixels × 12 channels, with the constant rows
//! on top.

use super::fc::{ConstRows, OUTPUTS_PER_TILE};
use super::MapError;
use crate::bitcell::W_ROWS;

/// Where one output pixel's potentials live.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PixelAssignment {
    /// Macro index within the layer's pool (channel-group major).
    pub macro_id: usize,
    pub v_row_odd: usize,
    pub v_row_even: usize,
}

/// Mapping of one conv layer onto a macro pool.
///
/// With `lanes > 1` (see [`ConvLayout::with_lanes`]) each output pixel
/// owns one odd/even V-row *pair per batch lane* in its macro, so a
/// fused AccW2V stream can broadcast one weight-row read to every
/// lane's membrane potential — the conv analogue of the FC batching
/// lanes. The per-macro pixel budget shrinks accordingly
/// (`⌊13 / lanes⌋`) and the pool grows to compensate.
#[derive(Clone, Debug)]
pub struct ConvLayout {
    pub height: usize,
    pub width: usize,
    pub c_in: usize,
    pub c_out: usize,
    pub ksize: usize,
    /// Channel groups of ≤ 12 output channels (weight slots).
    pub n_channel_groups: usize,
    /// Pixels per macro (V-row-pair budget, already divided by lanes).
    pub pixels_per_macro: usize,
    pub const_rows: ConstRows,
    /// Batch lanes co-resident per pixel (1 = classic layout).
    lanes: usize,
}

impl ConvLayout {
    /// SAME-padded ksize×ksize convolution over H×W×C_in producing
    /// H×W×C_out.
    pub fn new(
        height: usize,
        width: usize,
        c_in: usize,
        c_out: usize,
        ksize: usize,
    ) -> Result<Self, MapError> {
        let fan_in = ksize * ksize * c_in;
        if fan_in > W_ROWS {
            return Err(MapError::FanInTooLarge(fan_in));
        }
        if c_out == 0 || height == 0 || width == 0 {
            return Err(MapError::EmptyLayer);
        }
        let const_rows = ConstRows::default();
        let pixels_per_macro = const_rows.first_row() / 2;
        Ok(Self {
            height,
            width,
            c_in,
            c_out,
            ksize,
            n_channel_groups: c_out.div_ceil(OUTPUTS_PER_TILE),
            pixels_per_macro,
            const_rows,
            lanes: 1,
        })
    }

    /// The same geometry re-laid-out for `lanes` co-resident batch
    /// lanes per pixel: pixel slot `p`, lane `b` lives in V-row pair
    /// `(2(p·lanes + b), 2(p·lanes + b) + 1)`. Errs when the V_MEM
    /// row budget below the constant block cannot host even one pixel
    /// at that lane count.
    pub fn with_lanes(&self, lanes: usize) -> Result<Self, MapError> {
        let pair_budget = self.const_rows.first_row() / 2;
        if lanes == 0 || lanes > pair_budget {
            return Err(MapError::VmemOverflow {
                need: 2 * lanes.max(1),
                have: self.const_rows.first_row(),
            });
        }
        let mut l = self.clone();
        l.lanes = lanes;
        l.pixels_per_macro = pair_budget / lanes;
        Ok(l)
    }

    /// Batch lanes this layout hosts per pixel (1 = classic layout).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Fan-in (W rows used).
    pub fn fan_in(&self) -> usize {
        self.ksize * self.ksize * self.c_in
    }

    /// Macros per channel group.
    pub fn macros_per_group(&self) -> usize {
        (self.height * self.width).div_ceil(self.pixels_per_macro)
    }

    /// Total macros in the pool.
    pub fn num_macros(&self) -> usize {
        self.macros_per_group() * self.n_channel_groups
    }

    /// W row holding kernel tap (ky, kx, c_in_channel).
    #[inline]
    pub fn tap_row(&self, ky: usize, kx: usize, c: usize) -> usize {
        (ky * self.ksize + kx) * self.c_in + c
    }

    /// The pixel's assignment within a channel group (lane 0).
    pub fn assign(&self, y: usize, x: usize, group: usize) -> PixelAssignment {
        self.assign_lane(y, x, group, 0)
    }

    /// Where batch lane `lane` of pixel (y, x) lives within a channel
    /// group. All lanes of one pixel share a macro (so a fused AccW2V
    /// can broadcast one weight read across them); the macro id does
    /// not depend on the lane.
    pub fn assign_lane(
        &self,
        y: usize,
        x: usize,
        group: usize,
        lane: usize,
    ) -> PixelAssignment {
        debug_assert!(lane < self.lanes, "lane {lane} >= {}", self.lanes);
        let p = y * self.width + x;
        let macro_in_group = p / self.pixels_per_macro;
        let slot = p % self.pixels_per_macro;
        let pair = slot * self.lanes + lane;
        PixelAssignment {
            macro_id: group * self.macros_per_group() + macro_in_group,
            v_row_odd: 2 * pair,
            v_row_even: 2 * pair + 1,
        }
    }

    /// The twelve weights of W row `(ky,kx,c)` for channel group `g`,
    /// from a dense kernel `k[ky][kx][c_in][c_out]` flattened
    /// row-major.
    pub fn tile_row_weights(
        &self,
        kernel_flat: &[i64],
        group: usize,
        ky: usize,
        kx: usize,
        c: usize,
    ) -> [i64; 12] {
        let mut out = [0i64; 12];
        for (slot, item) in out.iter_mut().enumerate() {
            let co = group * OUTPUTS_PER_TILE + slot;
            if co < self.c_out {
                let idx = ((ky * self.ksize + kx) * self.c_in + c) * self.c_out + co;
                *item = kernel_flat[idx];
            }
        }
        out
    }

    /// Enumerate the SAME-padding input window of output pixel (y, x):
    /// yields `(w_row, in_y, in_x, c)` for taps inside the image.
    pub fn window(&self, y: usize, x: usize) -> Vec<(usize, usize, usize, usize)> {
        let mut out = Vec::with_capacity(self.fan_in());
        let half = self.ksize / 2;
        for ky in 0..self.ksize {
            for kx in 0..self.ksize {
                let iy = y as isize + ky as isize - half as isize;
                let ix = x as isize + kx as isize - half as isize;
                if iy < 0 || ix < 0 || iy >= self.height as isize || ix >= self.width as isize
                {
                    continue;
                }
                for c in 0..self.c_in {
                    out.push((self.tap_row(ky, kx, c), iy as usize, ix as usize, c));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_conv_geometry_fits() {
        // 3×3×14 = 126 ≤ 128 — the paper's exact constraint.
        let l = ConvLayout::new(14, 14, 14, 14, 3).unwrap();
        assert_eq!(l.fan_in(), 126);
        assert_eq!(l.n_channel_groups, 2); // 14 channels = 12 + 2
        assert_eq!(l.pixels_per_macro, 13);
        assert_eq!(l.macros_per_group(), (14 * 14usize).div_ceil(13));
        assert_eq!(l.num_macros(), 2 * 16);
    }

    #[test]
    fn oversized_fan_in_rejected() {
        assert_eq!(
            ConvLayout::new(14, 14, 15, 14, 3).unwrap_err(),
            MapError::FanInTooLarge(135)
        );
    }

    #[test]
    fn tap_rows_are_dense_and_unique() {
        let l = ConvLayout::new(7, 7, 14, 14, 3).unwrap();
        let mut seen = std::collections::HashSet::new();
        for ky in 0..3 {
            for kx in 0..3 {
                for c in 0..14 {
                    assert!(seen.insert(l.tap_row(ky, kx, c)));
                }
            }
        }
        assert_eq!(seen.len(), 126);
        assert_eq!(*seen.iter().max().unwrap(), 125);
    }

    #[test]
    fn window_clips_at_borders() {
        let l = ConvLayout::new(5, 5, 2, 4, 3).unwrap();
        // center pixel: full 3×3 window
        assert_eq!(l.window(2, 2).len(), 9 * 2);
        // corner: 2×2 window
        assert_eq!(l.window(0, 0).len(), 4 * 2);
        // edge: 2×3
        assert_eq!(l.window(0, 2).len(), 6 * 2);
    }

    #[test]
    fn pixel_assignment_covers_pool_without_collision() {
        let l = ConvLayout::new(6, 6, 3, 4, 3).unwrap();
        let mut seen = std::collections::HashSet::new();
        for y in 0..6 {
            for x in 0..6 {
                let a = l.assign(y, x, 0);
                assert!(a.macro_id < l.macros_per_group());
                assert!(a.v_row_even < l.const_rows.first_row());
                assert!(seen.insert((a.macro_id, a.v_row_odd)));
            }
        }
        // second channel group gets distinct macros
        let a0 = l.assign(0, 0, 0);
        // group index 0 only exists here (c_out=4 → 1 group); synthetic:
        assert_eq!(a0.macro_id, 0);
    }

    #[test]
    fn lane_layout_shrinks_pixel_budget_and_stays_collision_free() {
        let base = ConvLayout::new(6, 6, 3, 4, 3).unwrap();
        assert_eq!(base.lanes(), 1);
        let l = base.with_lanes(4).unwrap();
        assert_eq!(l.lanes(), 4);
        assert_eq!(l.pixels_per_macro, 13 / 4);
        assert!(l.macros_per_group() > base.macros_per_group());
        // every (pixel, lane) pair gets a distinct V-row pair below
        // the constant block, and lanes of one pixel share a macro
        let mut seen = std::collections::HashSet::new();
        for y in 0..6 {
            for x in 0..6 {
                let m0 = l.assign_lane(y, x, 0, 0).macro_id;
                for b in 0..4 {
                    let a = l.assign_lane(y, x, 0, b);
                    assert_eq!(a.macro_id, m0, "lanes of one pixel must co-reside");
                    assert_eq!(a.v_row_even, a.v_row_odd + 1);
                    assert!(a.v_row_even < l.const_rows.first_row());
                    assert!(seen.insert((a.macro_id, a.v_row_odd)));
                }
            }
        }
        // lane 0 of the 1-lane layout is the classic assignment
        let a = base.assign(2, 3, 0);
        assert_eq!(a, base.assign_lane(2, 3, 0, 0));
    }

    #[test]
    fn lane_overflow_rejected() {
        let l = ConvLayout::new(4, 4, 2, 4, 3).unwrap();
        assert!(l.with_lanes(0).is_err());
        assert!(l.with_lanes(14).is_err());
        assert_eq!(l.with_lanes(13).unwrap().pixels_per_macro, 1);
    }

    #[test]
    fn tile_row_weights_indexes_kernel_correctly() {
        let l = ConvLayout::new(4, 4, 2, 14, 3).unwrap();
        // kernel[ky][kx][c][co] = co for easy checking
        let n = 3 * 3 * 2 * 14;
        let kernel: Vec<i64> = (0..n).map(|i| (i % 14) as i64).collect();
        let row = l.tile_row_weights(&kernel, 1, 0, 0, 0);
        // group 1 covers channels 12..14
        assert_eq!(row[0], 12);
        assert_eq!(row[1], 13);
        for slot in 2..12 {
            assert_eq!(row[slot], 0);
        }
    }
}
