//! Mapping neural-network layers onto IMPULSE macros (paper Fig 3b).
//!
//! One macro tile holds a 128-input × 12-output weight block: output
//! neuron *o* (local) lives in weight slot *o* of every W_MEM row —
//! even slots are accumulated in odd cycles into the odd-aligned V row,
//! odd slots in even cycles into the even-aligned V row (the staggered
//! mapping). Constant rows at the top of V_MEM hold −θ, reset, and
//! −leak per alignment.
//!
//! Layers wider than 12 neurons span multiple tiles; fan-in is capped
//! at 128 — exactly the constraint the paper designs its networks
//! around ("input channels for Conv layers were kept 14 with 3×3
//! kernel size to restrict the fan-in to 128").

mod conv;
mod fc;

pub use conv::{ConvLayout, PixelAssignment};
pub use fc::{ConstRows, FcLayout, TileMapping, OUTPUTS_PER_TILE};

use thiserror::Error;

/// Mapping errors.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum MapError {
    #[error(
        "fan-in {0} exceeds the macro's 128 rows (the paper's own constraint; \
         restructure the layer)"
    )]
    FanInTooLarge(usize),
    #[error("layer has no outputs")]
    EmptyLayer,
    #[error("V_MEM budget exceeded: need {need} value rows, have {have}")]
    VmemOverflow { need: usize, have: usize },
}
