//! Fully-connected layer mapping.

use super::MapError;
use crate::bitcell::{Parity, V_ROWS, W_ROWS, WEIGHTS_PER_ROW};
use crate::isa::NeuronConfigRows;

/// Output neurons handled by one macro tile (6 odd-cycle + 6 even).
pub const OUTPUTS_PER_TILE: usize = WEIGHTS_PER_ROW;

/// The V_MEM rows reserved for per-layer constants, per alignment.
/// (Rows 26–31; value rows grow from 0.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConstRows {
    pub neg_leak_odd: usize,
    pub neg_leak_even: usize,
    pub neg_thr_odd: usize,
    pub neg_thr_even: usize,
    pub reset_odd: usize,
    pub reset_even: usize,
}

impl Default for ConstRows {
    fn default() -> Self {
        Self {
            neg_leak_odd: 26,
            neg_leak_even: 27,
            neg_thr_odd: 28,
            neg_thr_even: 29,
            reset_odd: 30,
            reset_even: 31,
        }
    }
}

impl ConstRows {
    /// The neuron-sequence row bundle for one parity.
    pub fn for_parity(&self, p: Parity) -> NeuronConfigRows {
        match p {
            Parity::Odd => NeuronConfigRows {
                neg_threshold: self.neg_thr_odd,
                reset: self.reset_odd,
                neg_leak: self.neg_leak_odd,
            },
            Parity::Even => NeuronConfigRows {
                neg_threshold: self.neg_thr_even,
                reset: self.reset_even,
                neg_leak: self.neg_leak_even,
            },
        }
    }

    /// First V row index used by constants (value rows must stay below).
    pub fn first_row(&self) -> usize {
        self.neg_leak_odd
    }
}

/// One tile: a 128×12 weight block plus one odd/even V-row pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileMapping {
    pub tile_id: usize,
    /// First global output neuron this tile covers.
    pub out_base: usize,
    /// Number of covered outputs (≤ 12; the last tile may be partial).
    pub out_count: usize,
    /// Odd-aligned V row (even weight slots).
    pub v_row_odd: usize,
    /// Even-aligned V row (odd weight slots).
    pub v_row_even: usize,
}

impl TileMapping {
    /// Map a local output index (0..out_count) to its (parity, field).
    #[inline]
    pub fn slot(&self, local_out: usize) -> (Parity, usize) {
        debug_assert!(local_out < OUTPUTS_PER_TILE);
        if local_out % 2 == 0 {
            (Parity::Odd, local_out / 2)
        } else {
            (Parity::Even, local_out / 2)
        }
    }

    /// Inverse of [`TileMapping::slot`].
    #[inline]
    pub fn local_out(&self, parity: Parity, field: usize) -> usize {
        match parity {
            Parity::Odd => 2 * field,
            Parity::Even => 2 * field + 1,
        }
    }
}

/// A complete FC-layer mapping.
#[derive(Clone, Debug)]
pub struct FcLayout {
    pub fan_in: usize,
    pub width: usize,
    pub tiles: Vec<TileMapping>,
    pub const_rows: ConstRows,
}

impl FcLayout {
    /// Map a `fan_in → width` FC layer.
    pub fn new(fan_in: usize, width: usize) -> Result<Self, MapError> {
        if fan_in > W_ROWS {
            return Err(MapError::FanInTooLarge(fan_in));
        }
        if width == 0 {
            return Err(MapError::EmptyLayer);
        }
        let const_rows = ConstRows::default();
        // Each tile needs one odd/even V-row pair; a single-layer FC
        // tile uses rows 0 and 1 of its own macro.
        if 2 > const_rows.first_row() {
            return Err(MapError::VmemOverflow {
                need: 2,
                have: const_rows.first_row(),
            });
        }
        debug_assert!(2 <= V_ROWS);
        let n_tiles = width.div_ceil(OUTPUTS_PER_TILE);
        let tiles = (0..n_tiles)
            .map(|t| TileMapping {
                tile_id: t,
                out_base: t * OUTPUTS_PER_TILE,
                out_count: OUTPUTS_PER_TILE.min(width - t * OUTPUTS_PER_TILE),
                v_row_odd: 0,
                v_row_even: 1,
            })
            .collect();
        Ok(Self {
            fan_in,
            width,
            tiles,
            const_rows,
        })
    }

    /// The twelve weight values to program into W row `i` of tile `t`,
    /// taken from a dense `[fan_in][width]` weight matrix. Slots beyond
    /// the layer width are zero.
    pub fn tile_row_weights(
        &self,
        weights: &[Vec<i64>],
        tile: &TileMapping,
        i: usize,
    ) -> [i64; 12] {
        let mut out = [0i64; 12];
        for (slot, o) in out.iter_mut().zip(0..OUTPUTS_PER_TILE) {
            let global = tile.out_base + o;
            if global < self.width {
                *slot = weights[i][global];
            }
        }
        out
    }

    /// Number of macros this layout occupies.
    pub fn num_macros(&self) -> usize {
        self.tiles.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_128x128_uses_11_tiles() {
        let l = FcLayout::new(128, 128).unwrap();
        assert_eq!(l.tiles.len(), 11);
        assert_eq!(l.tiles[10].out_count, 128 - 120);
        assert_eq!(l.num_macros(), 11);
    }

    #[test]
    fn layout_100x128() {
        let l = FcLayout::new(100, 128).unwrap();
        assert_eq!(l.fan_in, 100);
        assert_eq!(l.tiles.len(), 11);
    }

    #[test]
    fn fan_in_cap_matches_paper_constraint() {
        assert_eq!(
            FcLayout::new(129, 8).unwrap_err(),
            MapError::FanInTooLarge(129)
        );
        assert!(FcLayout::new(128, 8).is_ok());
        assert_eq!(FcLayout::new(10, 0).unwrap_err(), MapError::EmptyLayer);
    }

    #[test]
    fn slot_roundtrip() {
        let l = FcLayout::new(16, 24).unwrap();
        let t = &l.tiles[0];
        for o in 0..OUTPUTS_PER_TILE {
            let (p, f) = t.slot(o);
            assert_eq!(t.local_out(p, f), o);
        }
        // even local outputs are odd-parity fields
        assert_eq!(t.slot(0), (Parity::Odd, 0));
        assert_eq!(t.slot(1), (Parity::Even, 0));
        assert_eq!(t.slot(10), (Parity::Odd, 5));
        assert_eq!(t.slot(11), (Parity::Even, 5));
    }

    #[test]
    fn tile_row_weights_extracts_block() {
        let l = FcLayout::new(3, 20).unwrap();
        // weights[i][o] = 100*i + o (clipped into 6-bit range by test design)
        let w: Vec<Vec<i64>> = (0..3)
            .map(|i| (0..20).map(|o| ((i * 7 + o) % 30) as i64 - 15).collect())
            .collect();
        let t1 = l.tiles[1]; // outputs 12..20
        let row = l.tile_row_weights(&w, &t1, 2);
        for o in 0..8 {
            assert_eq!(row[o], w[2][12 + o]);
        }
        for o in 8..12 {
            assert_eq!(row[o], 0); // beyond layer width
        }
    }

    #[test]
    fn const_rows_do_not_collide_with_value_rows() {
        let l = FcLayout::new(64, 12).unwrap();
        let c = l.const_rows;
        for t in &l.tiles {
            assert!(t.v_row_odd < c.first_row());
            assert!(t.v_row_even < c.first_row());
        }
        let rows = [
            c.neg_leak_odd,
            c.neg_leak_even,
            c.neg_thr_odd,
            c.neg_thr_even,
            c.reset_odd,
            c.reset_even,
        ];
        let mut dedup = rows.to_vec();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), rows.len());
        assert!(rows.iter().all(|&r| r < V_ROWS));
    }
}
