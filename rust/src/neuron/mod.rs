//! Functional golden neuron models with hardware-exact semantics.
//!
//! These are the oracles the macro simulator (and, transitively, the
//! Pallas kernel via the shared artifact tests) is validated against:
//! plain Rust integer code implementing the same 11-bit wraparound
//! accumulate / threshold / reset dynamics, with no bit-level machinery.

#![warn(missing_docs)]

use crate::bits::wrap11;
use crate::isa::NeuronType;

/// Parameters of a neuron population (shared per layer, as on the
/// macro: one −θ row, one reset row, one −leak row per parity).
#[derive(Clone, Copy, Debug)]
pub struct NeuronParams {
    /// Which update sequence this population runs.
    pub neuron: NeuronType,
    /// Firing threshold θ (positive).
    pub threshold: i64,
    /// Hard-reset value (IF/LIF), usually 0.
    pub reset: i64,
    /// Subtractive leak per timestep (LIF), ≥ 0.
    pub leak: i64,
}

impl NeuronParams {
    /// Integrate-and-fire with the given threshold (hard reset to 0).
    pub fn if_neuron(threshold: i64) -> Self {
        Self {
            neuron: NeuronType::IF,
            threshold,
            reset: 0,
            leak: 0,
        }
    }

    /// Leaky integrate-and-fire with the given threshold and
    /// per-timestep subtractive leak (hard reset to 0).
    pub fn lif_neuron(threshold: i64, leak: i64) -> Self {
        Self {
            neuron: NeuronType::LIF,
            threshold,
            reset: 0,
            leak,
        }
    }

    /// Residual-membrane-potential neuron: soft reset retains `V − θ`.
    pub fn rmp_neuron(threshold: i64) -> Self {
        Self {
            neuron: NeuronType::RMP,
            threshold,
            reset: 0,
            leak: 0,
        }
    }
}

/// One neuron's state: its membrane potential (11-bit wrapped).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NeuronState {
    /// Membrane potential, wrapped to the hardware's 11-bit range.
    pub v: i64,
}

impl NeuronState {
    /// Accumulate one synaptic weight (an input spike arrived).
    #[inline]
    pub fn accumulate(&mut self, weight: i64) {
        self.v = wrap11(self.v + weight);
    }

    /// End-of-timestep update. Returns whether the neuron spiked.
    ///
    /// Matches the macro's instruction sequences exactly:
    /// - IF:  spike = V ≥ θ; if spike, V ← reset.
    /// - LIF: V ← V − leak (wrapped); spike = V ≥ θ; if spike V ← reset.
    /// - RMP: spike = V ≥ θ; if spike, V ← V − θ (wrapped).
    pub fn update(&mut self, p: &NeuronParams) -> bool {
        match p.neuron {
            NeuronType::IF => {
                let spike = wrap11(self.v - p.threshold) >= 0;
                if spike {
                    self.v = wrap11(p.reset);
                }
                spike
            }
            NeuronType::LIF => {
                self.v = wrap11(self.v - p.leak);
                let spike = wrap11(self.v - p.threshold) >= 0;
                if spike {
                    self.v = wrap11(p.reset);
                }
                spike
            }
            NeuronType::RMP => {
                let spike = wrap11(self.v - p.threshold) >= 0;
                if spike {
                    self.v = wrap11(self.v - p.threshold);
                }
                spike
            }
        }
    }
}

/// A population of neurons driven by a dense weight matrix — the
/// functional model of one mapped layer (fan-in ≤ 128, any width).
///
/// `weights[i][n]` is the 6-bit weight from input `i` to neuron `n`.
#[derive(Clone, Debug)]
pub struct GoldenLayer {
    /// Shared neuron parameters of the population.
    pub params: NeuronParams,
    /// Dense weight matrix, `weights[input][neuron]`.
    pub weights: Vec<Vec<i64>>,
    /// Per-neuron membrane state.
    pub state: Vec<NeuronState>,
}

impl GoldenLayer {
    /// Build a layer from parameters and a dense weight matrix (all
    /// rows must have the same width).
    pub fn new(params: NeuronParams, weights: Vec<Vec<i64>>) -> Self {
        let n = weights.first().map(|r| r.len()).unwrap_or(0);
        assert!(weights.iter().all(|r| r.len() == n));
        Self {
            params,
            weights,
            state: vec![NeuronState::default(); n],
        }
    }

    /// Layer fan-in (rows of the weight matrix).
    pub fn num_inputs(&self) -> usize {
        self.weights.len()
    }

    /// Number of neurons (columns of the weight matrix).
    pub fn num_neurons(&self) -> usize {
        self.state.len()
    }

    /// Process one timestep: accumulate all spiking inputs, then run the
    /// neuron update. Returns the output spike vector.
    pub fn step(&mut self, in_spikes: &[bool]) -> Vec<bool> {
        assert_eq!(in_spikes.len(), self.num_inputs());
        for (i, &s) in in_spikes.iter().enumerate() {
            if s {
                for (n, st) in self.state.iter_mut().enumerate() {
                    st.accumulate(self.weights[i][n]);
                }
            }
        }
        self.state
            .iter_mut()
            .map(|st| st.update(&self.params))
            .collect()
    }

    /// Process one timestep from a packed spike plane — semantically
    /// identical to [`GoldenLayer::step`], visiting only the *set*
    /// inputs. The oracle counterpart of the mapped layers'
    /// plane-native paths.
    pub fn step_plane(&mut self, in_spikes: &crate::snn::SpikePlane) -> Vec<bool> {
        assert_eq!(in_spikes.len(), self.num_inputs());
        for i in in_spikes.iter_ones() {
            for (n, st) in self.state.iter_mut().enumerate() {
                st.accumulate(self.weights[i][n]);
            }
        }
        self.state
            .iter_mut()
            .map(|st| st.update(&self.params))
            .collect()
    }

    /// Current membrane potentials.
    pub fn potentials(&self) -> Vec<i64> {
        self.state.iter().map(|s| s.v).collect()
    }

    /// Reset all membrane potentials to zero.
    pub fn reset_state(&mut self) {
        for s in self.state.iter_mut() {
            *s = NeuronState::default();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn if_neuron_integrates_and_fires() {
        let p = NeuronParams::if_neuron(10);
        let mut s = NeuronState::default();
        s.accumulate(4);
        assert!(!s.update(&p));
        assert_eq!(s.v, 4);
        s.accumulate(7); // v = 11 ≥ 10
        assert!(s.update(&p));
        assert_eq!(s.v, 0); // hard reset
    }

    #[test]
    fn lif_neuron_leaks() {
        let p = NeuronParams::lif_neuron(10, 2);
        let mut s = NeuronState { v: 9 };
        assert!(!s.update(&p)); // leak first: 7 < 10
        assert_eq!(s.v, 7);
        s.accumulate(5); // 12
        assert!(s.update(&p)); // 12-2=10 ≥ 10 → spike
        assert_eq!(s.v, 0);
    }

    #[test]
    fn rmp_neuron_soft_resets() {
        let p = NeuronParams::rmp_neuron(10);
        let mut s = NeuronState { v: 27 };
        assert!(s.update(&p));
        assert_eq!(s.v, 17);
        assert!(s.update(&p));
        assert_eq!(s.v, 7);
        assert!(!s.update(&p));
        assert_eq!(s.v, 7); // residual retained
    }

    #[test]
    fn accumulate_wraps() {
        let mut s = NeuronState { v: 1023 };
        s.accumulate(1);
        assert_eq!(s.v, -1024);
    }

    #[test]
    fn negative_v_does_not_spike_signed() {
        let p = NeuronParams::if_neuron(5);
        let mut s = NeuronState { v: -1 };
        assert!(!s.update(&p));
        assert_eq!(s.v, -1);
    }

    #[test]
    fn golden_layer_steps() {
        // 2 inputs, 3 neurons.
        let w = vec![vec![5, 6, 7], vec![-5, 6, 0]];
        let mut l = GoldenLayer::new(NeuronParams::if_neuron(10), w);
        let out = l.step(&[true, true]);
        // v = [0, 12, 7] → spikes [false, true, false]
        assert_eq!(out, vec![false, true, false]);
        assert_eq!(l.potentials(), vec![0, 0, 7]);
        l.reset_state();
        assert_eq!(l.potentials(), vec![0, 0, 0]);
    }

    #[test]
    fn golden_step_plane_matches_step() {
        let w = vec![vec![5, 6, 7], vec![-5, 6, 0]];
        let mut a = GoldenLayer::new(NeuronParams::if_neuron(10), w.clone());
        let mut b = GoldenLayer::new(NeuronParams::if_neuron(10), w);
        for bits in [[true, true], [false, true], [false, false]] {
            let plane = crate::snn::SpikePlane::from_bools(&bits);
            assert_eq!(a.step(&bits), b.step_plane(&plane));
            assert_eq!(a.potentials(), b.potentials());
        }
    }

    #[test]
    fn no_input_spikes_no_accumulation() {
        let w = vec![vec![5], vec![9]];
        let mut l = GoldenLayer::new(NeuronParams::rmp_neuron(100), w);
        let out = l.step(&[false, false]);
        assert_eq!(out, vec![false]);
        assert_eq!(l.potentials(), vec![0]);
    }
}
