//! IMPULSE — software reproduction of "IMPULSE: A 65nm Digital
//! Compute-in-Memory Macro with Fused Weights and Membrane Potential for
//! Spike-based Sequential Learning Tasks" (IEEE SSCL 2021,
//! 10.1109/LSSC.2021.3092727).
//!
//! The crate is organized bottom-up:
//!
//! - [`bits`] — fixed-width two's-complement arithmetic and bit vectors.
//! - [`bitcell`] — 10T-SRAM array simulation (dual-RWL NOR/NAND reads,
//!   triple-row decoder, fused W_MEM/V_MEM geometry).
//! - [`periph`] — reconfigurable column peripherals (SINV, BLFA, CMUX,
//!   CWD, spike buffers) composing the in-array ripple-carry adders.
//! - [`isa`] — the in-memory SNN instruction set, neuron sequences, and
//!   the static program analyzer ([`isa::verify`], `docs/VALIDATION.md`).
//! - [`macro_sim`] — the IMPULSE macro: decoder + array + peripherals
//!   executing instruction streams, with cycle/energy tracing.
//! - [`neuron`] — functional golden neuron models (IF/LIF/RMP) with
//!   hardware-exact 11-bit semantics.
//! - [`mapper`] — FC/Conv layer mapping onto macros (staggered layout).
//! - [`snn`] — network-level inference engine over mapped macros.
//! - [`coordinator`] — multi-macro scheduler, spike routing, sparsity-
//!   aware instruction issue, worker threads.
//! - [`serve`] — the serving front-end: binary frame codec
//!   (`docs/PROTOCOL.md`), multi-client TCP listener, and the
//!   transport-agnostic session path shared with the stdio loop.
//! - [`proxy`] — the fault-tolerant front tier (`docs/PROXY.md`):
//!   health-checked least-loaded routing over a backend fleet,
//!   stream pinning, transparent re-submission of idempotent work on
//!   backend death, and a fault-injection relay for chaos testing.
//! - [`telemetry`] — live serving telemetry: the lock-free registry,
//!   `StatsRequest`/`StatsResponse` snapshots, Prometheus exposition,
//!   and backpressure signalling.
//! - [`obs`] — per-request lifecycle tracing (span recorder, Chrome
//!   trace-event export, `docs/OBSERVABILITY.md`) and the leveled
//!   structured logger behind [`error!`]/[`warn!`]/[`info!`]/[`debug!`].
//! - [`replay`] — deterministic record/replay of serve traffic (wire
//!   taps + per-request V_MEM digests, `docs/REPLAY.md`) and the
//!   scripted scenario load generator.
//! - [`energy`] — silicon-calibrated power/energy/EDP, Shmoo, and area
//!   models.
//! - [`baselines`] — LSTM baseline, non-fused accelerator model, and the
//!   Table I comparison data.
//! - [`data`] — artifact (weights/datasets) binary format loaders and
//!   synthetic dataset mirrors.
//! - [`runtime`] — PJRT (XLA) client that loads the AOT-compiled JAX
//!   graphs from `artifacts/*.hlo.txt` for cross-validation.
//! - [`metrics`], [`config`], [`bench_harness`], [`proptest_lite`] —
//!   supporting infrastructure (reporting, TOML-subset config, offline
//!   bench/property-test harnesses).

// Every unsafe operation must sit in its own `unsafe` block with a
// `// SAFETY:` justification, even inside `unsafe fn` (CI greps for
// the comments; see the unsafe-audit job).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod baselines;
pub mod bench_harness;
pub mod bitcell;
pub mod bits;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod isa;
pub mod macro_sim;
pub mod mapper;
pub mod metrics;
pub mod neuron;
pub mod obs;
pub mod periph;
pub mod proptest_lite;
pub mod proxy;
pub mod replay;
pub mod runtime;
pub mod serve;
pub mod snn;
pub mod telemetry;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// The paper's nominal operating point (point D): 0.85 V, 200 MHz.
pub const NOMINAL_VDD: f64 = 0.85;
/// Nominal clock frequency in Hz (200 MHz).
pub const NOMINAL_FREQ_HZ: f64 = 200.0e6;
