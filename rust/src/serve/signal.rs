//! Graceful-shutdown signal wiring for `impulse serve --listen`.
//!
//! Installs SIGINT/SIGTERM handlers that only flip a process-global
//! atomic (the sole async-signal-safe thing a handler may do here);
//! the CLI's serve loop polls the flag and calls
//! [`TcpServeHandle::stop`], so in-flight requests drain and every
//! connection flushes its responses before the process exits —
//! instead of running until killed.
//!
//! Implemented against the raw C `signal(2)` entry point so the
//! offline build needs no `libc` crate; on non-Unix targets the
//! handlers are a no-op and the flag simply never fires. A *second*
//! SIGINT/SIGTERM while the drain is still running restores the
//! default disposition and re-raises — the operator's force-quit
//! escape hatch if a connection wedges the drain.
//!
//! [`TcpServeHandle::stop`]: super::TcpServeHandle::stop

use std::sync::atomic::{AtomicBool, Ordering};

/// Set by the signal handler once SIGINT or SIGTERM arrives.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod ffi {
    pub const SIGINT: i32 = 2;
    pub const SIGTERM: i32 = 15;
    /// `SIG_DFL` — the default signal disposition.
    pub const SIG_DFL: usize = 0;
    extern "C" {
        pub fn signal(signum: i32, handler: usize) -> usize;
        pub fn raise(signum: i32) -> i32;
    }
}

#[cfg(unix)]
extern "C" fn on_signal(signum: i32) {
    // A store on an AtomicBool is async-signal-safe; everything else
    // (allocation, locks, IO) is forbidden in handler context.
    // `signal`/`raise` are on the POSIX async-signal-safe list.
    if SHUTDOWN.swap(true, Ordering::SeqCst) {
        // Second signal while the drain is still running: restore the
        // default action and re-deliver, so an operator can force-quit
        // a wedged shutdown with a second Ctrl+C instead of SIGKILL.
        // SAFETY: `signal` and `raise` are both on the POSIX
        // async-signal-safe list, so they may be called from handler
        // context; SIG_DFL is a valid disposition for any signal and
        // `signum` is the signal currently being delivered.
        unsafe {
            ffi::signal(signum, ffi::SIG_DFL);
            ffi::raise(signum);
        }
    }
}

/// Install SIGINT/SIGTERM handlers (idempotent) and return the flag
/// they set. Callers poll the flag and run their own orderly shutdown
/// — see the `impulse serve` listen loop.
pub fn install_shutdown_handler() -> &'static AtomicBool {
    #[cfg(unix)]
    // SAFETY: `on_signal` is an `extern "C" fn(i32)` — the exact shape
    // `signal(2)` expects for a handler address — and it only touches
    // async-signal-safe state (one atomic plus `signal`/`raise`).
    // Re-installing over a previous registration is defined behavior,
    // which keeps this entry point idempotent.
    unsafe {
        ffi::signal(ffi::SIGINT, on_signal as usize);
        ffi::signal(ffi::SIGTERM, on_signal as usize);
    }
    &SHUTDOWN
}

/// Whether a shutdown signal has arrived since
/// [`install_shutdown_handler`] was called.
pub fn shutdown_requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    /// The regression the ROADMAP tracked: a delivered SIGTERM must
    /// reach the drain path. `raise` delivers synchronously to the
    /// calling thread, so the handler has run by the time it returns.
    #[test]
    fn sigterm_sets_the_shutdown_flag() {
        let flag = install_shutdown_handler();
        assert!(!flag.load(Ordering::SeqCst), "flag must start clear");
        // SAFETY: `raise` is always safe to call with a valid signal
        // number; the handler installed above is the process-wide
        // disposition, so delivery lands in `on_signal`, which only
        // flips the atomic on a first signal.
        unsafe {
            ffi::raise(ffi::SIGTERM);
        }
        assert!(flag.load(Ordering::SeqCst), "SIGTERM must set the flag");
        assert!(shutdown_requested());
        // reset so other tests in this binary see a clean flag
        flag.store(false, Ordering::SeqCst);
    }
}
