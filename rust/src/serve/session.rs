//! Transport-agnostic serving sessions and payload codecs.
//!
//! [`ServeCore`] wraps the coordinator's [`InferenceServer`] with a
//! response dispatcher so *many* concurrent clients can share one
//! batcher/worker pool: every submission is re-keyed onto a private
//! internal id, and the dispatcher routes each response back to the
//! session that submitted it with the client's own request id
//! restored. The TCP listener and the `--stdio` line loop both sit on
//! this path, which is what makes their results bit-identical.
//!
//! This module also owns the payload encodings inside
//! [`Frame`] payload bytes (hello/ack, infer request/response, stream
//! session payloads, error) — the layouts are specified byte-for-byte
//! in `docs/PROTOCOL.md`. Typed payloads implement [`WirePayload`]
//! (`encode`/`decode`/`TYPE_ID`); the original free functions remain
//! as the byte-identical implementation the trait delegates to.

use super::frame::{decode_backpressure, ErrorCode, Frame, FrameReader, PayloadType,
    WireError, PROTOCOL_VERSION};
use super::stream::StreamTable;
use crate::coordinator::{
    InferenceServer, Request, Response, ServerOptions, Submitter, Workload, WorkloadInput,
    WorkloadKind, WorkloadOutput,
};
use crate::telemetry::{
    kind_code, kind_from_code, KindStats, StatsSnapshot, Telemetry, TelemetryConfig, Transport,
    TransportStats, STATS_VERSION,
};
use crate::Result;
use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// Payload codecs (see docs/PROTOCOL.md §4)
// ---------------------------------------------------------------------

/// Maximum word ids one `InferRequest` may carry (u16 count field).
pub const MAX_WORDS_PER_REQUEST: usize = u16::MAX as usize;

/// A payload that failed to parse: the protocol error code to report
/// plus a human-readable cause.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PayloadError {
    /// Protocol error code for the `Error` response frame.
    pub code: ErrorCode,
    /// Human-readable cause (sent as the error message).
    pub msg: String,
}

impl PayloadError {
    fn new(code: ErrorCode, msg: impl Into<String>) -> PayloadError {
        PayloadError { code, msg: msg.into() }
    }
}

impl std::fmt::Display for PayloadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}: {}", self.code, self.msg)
    }
}

impl std::error::Error for PayloadError {}

/// Decoded `InferResponse` payload (the client-side view of a
/// [`Response`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireResponse {
    /// Predicted label (1 = positive).
    pub pred: u8,
    /// Final output-neuron membrane potential.
    pub v_out: i64,
    /// Macro cycles attributed to this request (honest per-request
    /// share of its fused batch, not an even split).
    pub cycles: u64,
    /// Server-side latency in microseconds (saturating).
    pub latency_us: u64,
    /// Micro-batch size this request was served in.
    pub batch: u16,
    /// Worker shard that ran the batch.
    pub worker: u16,
}

/// Capability bit a client may request in an extended `Hello`: the
/// server stamps backpressure advertisements (queue depth + soft-limit
/// bit) into the flags word of its frames on this connection.
pub const CAP_BACKPRESSURE: u8 = 0x01;

/// Capability bit a client may request in an extended `Hello`: infer
/// requests on this connection may set
/// [`super::frame::FLAG_TRACE_ECHO`], asking the server to append its
/// per-phase timing breakdown ([`TraceEcho`]) to the response payload.
/// The server only honours the echo when it is itself tracing
/// (`--trace-dir`) — otherwise no measurements exist to echo.
pub const CAP_TRACE_ECHO: u8 = 0x02;

/// All capability bits this server grants; unknown requested bits are
/// masked off in the `HelloAck`, never granted.
pub const SUPPORTED_CAPS: u8 = CAP_BACKPRESSURE | CAP_TRACE_ECHO;

/// Outcome of a successful `Hello` negotiation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Negotiated {
    /// The protocol version both sides will speak.
    pub version: u8,
    /// The capability bits granted (requested ∩ [`SUPPORTED_CAPS`];
    /// 0 for a 2-byte v1 `Hello`).
    pub caps: u8,
}

/// Encode a `Hello` payload: the client's supported version range.
pub fn hello_payload(min_version: u8, max_version: u8) -> Vec<u8> {
    vec![min_version, max_version]
}

/// Encode an extended `Hello` payload: version range plus requested
/// capability bits (e.g. [`CAP_BACKPRESSURE`]).
pub fn hello_caps_payload(min_version: u8, max_version: u8, caps: u8) -> Vec<u8> {
    vec![min_version, max_version, caps]
}

/// Server-side `Hello` handling: pick the highest mutually supported
/// version (or report [`ErrorCode::UnsupportedVersion`]) and grant the
/// supported subset of any requested capability bits. A 2-byte payload
/// is the v1 hello (no capabilities); a 3-byte payload adds the
/// capability request byte.
pub fn negotiate(payload: &[u8]) -> std::result::Result<Negotiated, PayloadError> {
    if payload.len() != 2 && payload.len() != 3 {
        return Err(PayloadError::new(
            ErrorCode::Malformed,
            format!("hello payload must be 2 or 3 bytes, got {}", payload.len()),
        ));
    }
    let (min, max) = (payload[0], payload[1]);
    if min > max {
        return Err(PayloadError::new(
            ErrorCode::Malformed,
            format!("hello version range {min}..{max} is empty"),
        ));
    }
    if min > PROTOCOL_VERSION || max < PROTOCOL_VERSION {
        return Err(PayloadError::new(
            ErrorCode::UnsupportedVersion,
            format!("server speaks v{PROTOCOL_VERSION}, client offers {min}..{max}"),
        ));
    }
    let caps = payload.get(2).copied().unwrap_or(0) & SUPPORTED_CAPS;
    Ok(Negotiated { version: PROTOCOL_VERSION, caps })
}

/// Encode an `InferRequest` payload: `count:u16` then `count` i32
/// word ids, all big-endian. Ids outside i32 range are saturated (the
/// server clamps into the vocabulary anyway).
///
/// Requests with more than [`MAX_WORDS_PER_REQUEST`] word ids are
/// rejected with [`ErrorCode::RequestTooLarge`] — writing
/// `len() as u16` would silently wrap the count and emit a
/// wrong-but-valid-looking frame the server then rejects as
/// `Malformed` (or, worse, misparses).
pub fn encode_infer_request(word_ids: &[i64]) -> std::result::Result<Vec<u8>, PayloadError> {
    if word_ids.len() > MAX_WORDS_PER_REQUEST {
        return Err(PayloadError::new(
            ErrorCode::RequestTooLarge,
            format!(
                "{} word ids exceed the {MAX_WORDS_PER_REQUEST}-word request cap",
                word_ids.len()
            ),
        ));
    }
    let mut out = Vec::with_capacity(2 + 4 * word_ids.len());
    out.extend_from_slice(&(word_ids.len() as u16).to_be_bytes());
    for &w in word_ids {
        out.extend_from_slice(&(w.clamp(i32::MIN as i64, i32::MAX as i64) as i32).to_be_bytes());
    }
    Ok(out)
}

/// Decode an `InferRequest` payload into word ids.
pub fn decode_infer_request(payload: &[u8]) -> std::result::Result<Vec<i64>, PayloadError> {
    if payload.len() < 2 {
        return Err(PayloadError::new(ErrorCode::Malformed, "missing word count"));
    }
    let count = u16::from_be_bytes([payload[0], payload[1]]) as usize;
    if payload.len() != 2 + 4 * count {
        return Err(PayloadError::new(
            ErrorCode::Malformed,
            format!("{count} word ids need {} payload bytes, got {}", 2 + 4 * count, payload.len()),
        ));
    }
    let mut ids = Vec::with_capacity(count);
    for i in 0..count {
        let o = 2 + 4 * i;
        ids.push(i32::from_be_bytes([
            payload[o],
            payload[o + 1],
            payload[o + 2],
            payload[o + 3],
        ]) as i64);
    }
    Ok(ids)
}

/// Encode a `DigitsInferRequest` payload: `height:u8`, `width:u8`,
/// then `height·width` pixels, each an IEEE-754 binary32 big-endian,
/// row-major (see `docs/PROTOCOL.md` §4.5).
pub fn encode_digits_request(
    h: usize,
    w: usize,
    pixels: &[f32],
) -> std::result::Result<Vec<u8>, PayloadError> {
    if h == 0 || w == 0 {
        return Err(PayloadError::new(ErrorCode::EmptyRequest, "zero-sized image"));
    }
    if h > u8::MAX as usize || w > u8::MAX as usize {
        return Err(PayloadError::new(
            ErrorCode::RequestTooLarge,
            format!("{h}×{w} image exceeds the 255×255 wire cap"),
        ));
    }
    if pixels.len() != h * w {
        return Err(PayloadError::new(
            ErrorCode::Malformed,
            format!("{h}×{w} image needs {} pixels, got {}", h * w, pixels.len()),
        ));
    }
    let mut out = Vec::with_capacity(2 + 4 * pixels.len());
    out.push(h as u8);
    out.push(w as u8);
    for &p in pixels {
        out.extend_from_slice(&p.to_be_bytes());
    }
    Ok(out)
}

/// Decode a `DigitsInferRequest` payload into `(h, w, pixels)`.
pub fn decode_digits_request(
    payload: &[u8],
) -> std::result::Result<(usize, usize, Vec<f32>), PayloadError> {
    if payload.len() < 2 {
        return Err(PayloadError::new(ErrorCode::Malformed, "missing image dimensions"));
    }
    let (h, w) = (payload[0] as usize, payload[1] as usize);
    if h == 0 || w == 0 {
        return Err(PayloadError::new(ErrorCode::EmptyRequest, "zero-sized image"));
    }
    if payload.len() != 2 + 4 * h * w {
        return Err(PayloadError::new(
            ErrorCode::Malformed,
            format!(
                "{h}×{w} image needs {} payload bytes, got {}",
                2 + 4 * h * w,
                payload.len()
            ),
        ));
    }
    let pixels = (0..h * w)
        .map(|i| {
            let o = 2 + 4 * i;
            f32::from_be_bytes([payload[o], payload[o + 1], payload[o + 2], payload[o + 3]])
        })
        .collect();
    Ok((h, w, pixels))
}

/// Decoded `DigitsInferResponse` payload (the client-side view of a
/// digits [`Response`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireDigitsResponse {
    /// Predicted digit (0–9).
    pub pred: u8,
    /// Per-class output potentials (ties resolve to the lowest index).
    pub v_all: Vec<i64>,
    /// Macro cycles attributed to this request (honest per-request
    /// share of its fused batch, not an even split).
    pub cycles: u64,
    /// Server-side latency in microseconds (saturating).
    pub latency_us: u64,
    /// Micro-batch size this request was served in.
    pub batch: u16,
    /// Worker shard that ran the batch.
    pub worker: u16,
}

/// Decode a `DigitsInferResponse` payload (`pred:u8`, `n_classes:u8`,
/// `n_classes` i64 potentials, `cycles:u64`, `latency_us:u64`,
/// `batch:u16`, `worker:u16` — all big-endian).
pub fn decode_digits_response(
    payload: &[u8],
) -> std::result::Result<WireDigitsResponse, PayloadError> {
    if payload.len() < 2 {
        return Err(PayloadError::new(ErrorCode::Malformed, "missing digits header"));
    }
    let n = payload[1] as usize;
    let want = 2 + 8 * n + 20;
    if payload.len() != want {
        return Err(PayloadError::new(
            ErrorCode::Malformed,
            format!("digits response with {n} classes needs {want} bytes, got {}", payload.len()),
        ));
    }
    let be8 = |o: usize| {
        u64::from_be_bytes([
            payload[o],
            payload[o + 1],
            payload[o + 2],
            payload[o + 3],
            payload[o + 4],
            payload[o + 5],
            payload[o + 6],
            payload[o + 7],
        ])
    };
    let v_all: Vec<i64> = (0..n).map(|i| be8(2 + 8 * i) as i64).collect();
    let o = 2 + 8 * n;
    Ok(WireDigitsResponse {
        pred: payload[0],
        v_all,
        cycles: be8(o),
        latency_us: be8(o + 8),
        batch: u16::from_be_bytes([payload[o + 16], payload[o + 17]]),
        worker: u16::from_be_bytes([payload[o + 18], payload[o + 19]]),
    })
}

/// Encode an `Error` payload: `code:u16`, `msg_len:u16`, UTF-8 bytes.
pub fn error_payload(code: ErrorCode, msg: &str) -> Vec<u8> {
    let bytes = msg.as_bytes();
    let n = bytes.len().min(u16::MAX as usize);
    let mut out = Vec::with_capacity(4 + n);
    out.extend_from_slice(&code.as_u16().to_be_bytes());
    out.extend_from_slice(&(n as u16).to_be_bytes());
    out.extend_from_slice(&bytes[..n]);
    out
}

/// Decode an `Error` payload into `(raw code, message)`.
pub fn decode_error(payload: &[u8]) -> std::result::Result<(u16, String), PayloadError> {
    if payload.len() < 4 {
        return Err(PayloadError::new(ErrorCode::Malformed, "error payload under 4 bytes"));
    }
    let code = u16::from_be_bytes([payload[0], payload[1]]);
    let n = u16::from_be_bytes([payload[2], payload[3]]) as usize;
    if payload.len() != 4 + n {
        return Err(PayloadError::new(ErrorCode::Malformed, "error message length mismatch"));
    }
    let msg = String::from_utf8_lossy(&payload[4..]).into_owned();
    Ok((code, msg))
}

/// Build an `Error` frame for a request id.
pub fn error_frame(request_id: u64, code: ErrorCode, msg: &str) -> Frame {
    Frame::new(PayloadType::Error, request_id, error_payload(code, msg))
}

// ---------------------------------------------------------------------
// Stats payloads (docs/PROTOCOL.md §4.8–4.9)
// ---------------------------------------------------------------------

/// Encode a `StatsRequest` payload — empty by definition (§4.8).
pub fn encode_stats_request() -> Vec<u8> {
    Vec::new()
}

/// Encode a `StatsResponse` payload from a telemetry snapshot (§4.9):
/// `stats_version:u8`, `reserved:u8`, the queue/batch globals, then
/// length-prefixed per-kind, per-instruction, and per-transport
/// sections — all integers big-endian, EDP as IEEE-754 binary64 bits.
pub fn encode_stats_response(s: &StatsSnapshot) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + 65 * s.kinds.len() + 9 * s.instr.len());
    out.push(STATS_VERSION);
    out.push(0); // reserved
    out.extend_from_slice(&s.queue_depth.to_be_bytes());
    out.extend_from_slice(&s.queue_soft_limit.to_be_bytes());
    out.push(u8::from(s.soft_limited));
    out.extend_from_slice(&s.batches.to_be_bytes());
    out.extend_from_slice(&s.batch_lanes.to_be_bytes());
    out.extend_from_slice(&s.batch_lane_capacity.to_be_bytes());
    out.push(s.kinds.len().min(u8::MAX as usize) as u8);
    for k in s.kinds.iter().take(u8::MAX as usize) {
        out.push(kind_code(k.kind));
        out.extend_from_slice(&k.submitted.to_be_bytes());
        out.extend_from_slice(&k.ok.to_be_bytes());
        out.extend_from_slice(&k.err.to_be_bytes());
        out.extend_from_slice(&k.cycles.to_be_bytes());
        out.extend_from_slice(&k.energy_fj.to_be_bytes());
        out.extend_from_slice(&k.edp_js.to_bits().to_be_bytes());
        out.extend_from_slice(&k.input_units.to_be_bytes());
        out.extend_from_slice(&k.input_active.to_be_bytes());
    }
    out.push(s.instr.len().min(u8::MAX as usize) as u8);
    for &(code, n) in s.instr.iter().take(u8::MAX as usize) {
        out.push(code);
        out.extend_from_slice(&n.to_be_bytes());
    }
    out.push(s.transports.len().min(u8::MAX as usize) as u8);
    for t in s.transports.iter().take(u8::MAX as usize) {
        out.push(t.transport.code());
        out.extend_from_slice(&t.count.to_be_bytes());
        out.extend_from_slice(&t.sum_us.to_be_bytes());
        out.push(t.buckets.len().min(u8::MAX as usize) as u8);
        for &b in t.buckets.iter().take(u8::MAX as usize) {
            out.extend_from_slice(&b.to_be_bytes());
        }
    }
    out
}

/// A little big-endian cursor over a stats payload.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl Cursor<'_> {
    fn u8(&mut self) -> std::result::Result<u8, PayloadError> {
        let v = *self
            .buf
            .get(self.at)
            .ok_or_else(|| PayloadError::new(ErrorCode::Malformed, "stats payload truncated"))?;
        self.at += 1;
        Ok(v)
    }

    fn u64(&mut self) -> std::result::Result<u64, PayloadError> {
        let end = self.at + 8;
        let bytes = self
            .buf
            .get(self.at..end)
            .ok_or_else(|| PayloadError::new(ErrorCode::Malformed, "stats payload truncated"))?;
        self.at = end;
        Ok(u64::from_be_bytes(bytes.try_into().expect("8-byte slice")))
    }
}

/// Decode a `StatsResponse` payload into a [`StatsSnapshot`] (§4.9).
pub fn decode_stats_response(
    payload: &[u8],
) -> std::result::Result<StatsSnapshot, PayloadError> {
    let mut c = Cursor { buf: payload, at: 0 };
    let version = c.u8()?;
    if version != STATS_VERSION {
        return Err(PayloadError::new(
            ErrorCode::Malformed,
            format!("stats payload version {version}, this build speaks {STATS_VERSION}"),
        ));
    }
    let _reserved = c.u8()?;
    let queue_depth = c.u64()?;
    let queue_soft_limit = c.u64()?;
    let soft_limited = c.u8()? != 0;
    let batches = c.u64()?;
    let batch_lanes = c.u64()?;
    let batch_lane_capacity = c.u64()?;
    let n_kinds = c.u8()? as usize;
    let mut kinds = Vec::with_capacity(n_kinds);
    for _ in 0..n_kinds {
        let code = c.u8()?;
        let kind = kind_from_code(code).ok_or_else(|| {
            PayloadError::new(ErrorCode::Malformed, format!("unknown workload kind {code}"))
        })?;
        kinds.push(KindStats {
            kind,
            submitted: c.u64()?,
            ok: c.u64()?,
            err: c.u64()?,
            cycles: c.u64()?,
            energy_fj: c.u64()?,
            edp_js: f64::from_bits(c.u64()?),
            input_units: c.u64()?,
            input_active: c.u64()?,
        });
    }
    let n_instr = c.u8()? as usize;
    let mut instr = Vec::with_capacity(n_instr);
    for _ in 0..n_instr {
        let code = c.u8()?;
        instr.push((code, c.u64()?));
    }
    let n_transports = c.u8()? as usize;
    let mut transports = Vec::with_capacity(n_transports);
    for _ in 0..n_transports {
        let code = c.u8()?;
        let transport = Transport::from_code(code).ok_or_else(|| {
            PayloadError::new(ErrorCode::Malformed, format!("unknown transport {code}"))
        })?;
        let count = c.u64()?;
        let sum_us = c.u64()?;
        let n_buckets = c.u8()? as usize;
        let mut buckets = Vec::with_capacity(n_buckets);
        for _ in 0..n_buckets {
            buckets.push(c.u64()?);
        }
        transports.push(TransportStats { transport, count, sum_us, buckets });
    }
    if c.at != payload.len() {
        return Err(PayloadError::new(
            ErrorCode::Malformed,
            format!("{} trailing bytes after stats payload", payload.len() - c.at),
        ));
    }
    Ok(StatsSnapshot {
        queue_depth,
        queue_soft_limit,
        soft_limited,
        batches,
        batch_lanes,
        batch_lane_capacity,
        kinds,
        instr,
        transports,
    })
}

/// Encode a coordinator [`Response`] as its wire frame: an
/// `InferResponse` (sentiment) or `DigitsInferResponse` (digits) on
/// success — chosen by [`Response::kind`] — or an `Error` frame with
/// [`ErrorCode::InferenceFailed`] when [`Response::err`] is set.
pub fn response_frame(r: &Response) -> Frame {
    if let Some(err) = &r.err {
        return error_frame(r.id, ErrorCode::InferenceFailed, err);
    }
    let us = u64::try_from(r.latency.as_micros()).unwrap_or(u64::MAX);
    let batch = r.batch_size.min(u16::MAX as usize) as u16;
    let worker = r.worker.min(u16::MAX as usize) as u16;
    match r.kind {
        WorkloadKind::Sentiment => WireResponse {
            pred: r.pred,
            v_out: r.v_out,
            cycles: r.cycles,
            latency_us: us,
            batch,
            worker,
        }
        .frame(r.id)
        .expect("infer response encoding is infallible"),
        WorkloadKind::Digits => WireDigitsResponse {
            pred: r.pred,
            v_all: r.v_all.clone(),
            cycles: r.cycles,
            latency_us: us,
            batch,
            worker,
        }
        .frame(r.id)
        .expect("digits response encoding is infallible"),
    }
}

/// Decode an `InferResponse` payload.
pub fn decode_infer_response(
    payload: &[u8],
) -> std::result::Result<WireResponse, PayloadError> {
    if payload.len() != 29 {
        return Err(PayloadError::new(
            ErrorCode::Malformed,
            format!("infer response payload must be 29 bytes, got {}", payload.len()),
        ));
    }
    let be8 = |o: usize| {
        u64::from_be_bytes([
            payload[o],
            payload[o + 1],
            payload[o + 2],
            payload[o + 3],
            payload[o + 4],
            payload[o + 5],
            payload[o + 6],
            payload[o + 7],
        ])
    };
    Ok(WireResponse {
        pred: payload[0],
        v_out: be8(1) as i64,
        cycles: be8(9),
        latency_us: be8(17),
        batch: u16::from_be_bytes([payload[25], payload[26]]),
        worker: u16::from_be_bytes([payload[27], payload[28]]),
    })
}

// ---------------------------------------------------------------------
// Trace echo (docs/OBSERVABILITY.md)
// ---------------------------------------------------------------------

/// Length of the trace-echo trailer a server appends to a successful
/// infer response when the request asked for it: four u32 phase
/// durations, big-endian.
pub const TRACE_ECHO_LEN: usize = 16;

/// The server-side timing breakdown echoed on a response, in
/// microseconds per phase (each saturating at `u32::MAX`). The write
/// phase is absent by construction — it has not happened yet when the
/// response is encoded.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceEcho {
    /// Frame + payload decode in the listener.
    pub decode_us: u32,
    /// Queue wait from submit until batch pickup.
    pub queue_us: u32,
    /// Batch formation until a worker began executing.
    pub batch_us: u32,
    /// Engine execution of the request's batch.
    pub execute_us: u32,
}

/// Encode a [`TraceEcho`] trailer ([`TRACE_ECHO_LEN`] bytes).
pub fn encode_trace_echo(e: &TraceEcho) -> [u8; TRACE_ECHO_LEN] {
    let mut out = [0u8; TRACE_ECHO_LEN];
    out[0..4].copy_from_slice(&e.decode_us.to_be_bytes());
    out[4..8].copy_from_slice(&e.queue_us.to_be_bytes());
    out[8..12].copy_from_slice(&e.batch_us.to_be_bytes());
    out[12..16].copy_from_slice(&e.execute_us.to_be_bytes());
    out
}

/// Split a response payload into its body and (when the frame's flags
/// carry [`super::frame::FLAG_TRACE_ECHO`] and the payload is long
/// enough) the decoded trace-echo trailer. Payloads without the flag
/// pass through untouched — the body codecs never see the trailer.
pub fn split_trace_echo(flags: u16, payload: &[u8]) -> (&[u8], Option<TraceEcho>) {
    use super::frame::{FLAG_TELEMETRY, FLAG_TRACE_ECHO};
    let flagged = flags & FLAG_TELEMETRY != 0 && flags & FLAG_TRACE_ECHO != 0;
    if !flagged || payload.len() < TRACE_ECHO_LEN {
        return (payload, None);
    }
    let at = payload.len() - TRACE_ECHO_LEN;
    let t = &payload[at..];
    let u32_at = |o: usize| u32::from_be_bytes([t[o], t[o + 1], t[o + 2], t[o + 3]]);
    (
        &payload[..at],
        Some(TraceEcho {
            decode_us: u32_at(0),
            queue_us: u32_at(4),
            batch_us: u32_at(8),
            execute_us: u32_at(12),
        }),
    )
}

/// Saturate a µs count into the u32 the echo trailer carries.
fn echo_us(us: u64) -> u32 {
    us.min(u32::MAX as u64) as u32
}

/// Append the trace-echo trailer to a *successful* response frame and
/// mark it in the flags word. Error frames are left untouched (their
/// codec rejects trailing bytes on older clients). Returns the flag
/// bits to OR into the frame's flags word.
pub fn attach_trace_echo(f: &mut Frame, s: &crate::obs::trace::TraceSummary) -> u16 {
    use super::frame::{FLAG_TELEMETRY, FLAG_TRACE_ECHO};
    if f.payload_type == PayloadType::Error {
        return 0;
    }
    let echo = TraceEcho {
        decode_us: echo_us(s.decode_us),
        queue_us: echo_us(s.queue_us),
        batch_us: echo_us(s.batch_us),
        execute_us: echo_us(s.execute_us),
    };
    f.payload.extend_from_slice(&encode_trace_echo(&echo));
    FLAG_TELEMETRY | FLAG_TRACE_ECHO
}

// ---------------------------------------------------------------------
// Stream session payloads (docs/PROTOCOL.md §4.10–4.14)
// ---------------------------------------------------------------------

/// Chunk kind byte inside a `StreamAppend` payload: word ids (the
/// sentiment/text shape, §4.4 body layout).
pub const STREAM_KIND_WORDS: u8 = 0;

/// Chunk kind byte inside a `StreamAppend` payload: one image frame,
/// integrated for one membrane timestep (§4.5 body layout).
pub const STREAM_KIND_IMAGE: u8 = 1;

/// Encode a `StreamAppend` payload: `stream_id:u64`, `kind:u8`
/// ([`STREAM_KIND_WORDS`] / [`STREAM_KIND_IMAGE`]), then the chunk in
/// the matching one-shot request layout — byte-for-byte the §4.4 or
/// §4.5 body, so chunked and one-shot requests share one codec.
pub fn encode_stream_append(
    stream_id: u64,
    chunk: &WorkloadInput,
) -> std::result::Result<Vec<u8>, PayloadError> {
    let (kind, body) = match chunk {
        WorkloadInput::Words(ids) => (STREAM_KIND_WORDS, encode_infer_request(ids)?),
        WorkloadInput::Image { h, w, pixels } => {
            (STREAM_KIND_IMAGE, encode_digits_request(*h, *w, pixels)?)
        }
    };
    let mut out = Vec::with_capacity(9 + body.len());
    out.extend_from_slice(&stream_id.to_be_bytes());
    out.push(kind);
    out.extend_from_slice(&body);
    Ok(out)
}

/// Decode a `StreamAppend` payload into `(stream_id, chunk)`.
pub fn decode_stream_append(
    payload: &[u8],
) -> std::result::Result<(u64, WorkloadInput), PayloadError> {
    if payload.len() < 9 {
        return Err(PayloadError::new(ErrorCode::Malformed, "stream append under 9 bytes"));
    }
    let stream_id = u64::from_be_bytes(payload[..8].try_into().expect("8-byte slice"));
    let body = &payload[9..];
    let chunk = match payload[8] {
        STREAM_KIND_WORDS => WorkloadInput::Words(decode_infer_request(body)?),
        STREAM_KIND_IMAGE => {
            let (h, w, pixels) = decode_digits_request(body)?;
            WorkloadInput::Image { h, w, pixels }
        }
        k => {
            return Err(PayloadError::new(
                ErrorCode::Malformed,
                format!("unknown stream chunk kind {k}"),
            ))
        }
    };
    Ok((stream_id, chunk))
}

/// Encode a `StreamReadOut`/`StreamClose` payload: `stream_id:u64`.
pub fn encode_stream_ref(stream_id: u64) -> Vec<u8> {
    stream_id.to_be_bytes().to_vec()
}

/// Decode a `StreamReadOut`/`StreamClose` payload into its stream id.
pub fn decode_stream_ref(payload: &[u8]) -> std::result::Result<u64, PayloadError> {
    if payload.len() != 8 {
        return Err(PayloadError::new(
            ErrorCode::Malformed,
            format!("stream ref payload must be 8 bytes, got {}", payload.len()),
        ));
    }
    Ok(u64::from_be_bytes(payload.try_into().expect("8-byte slice")))
}

/// `StreamAck` op byte: acknowledges a `StreamOpen`.
pub const STREAM_OP_OPEN: u8 = 0;
/// `StreamAck` op byte: acknowledges a `StreamAppend`.
pub const STREAM_OP_APPEND: u8 = 1;
/// `StreamAck` op byte: acknowledges a `StreamClose`.
pub const STREAM_OP_CLOSE: u8 = 2;

/// Decoded `StreamAck` payload: the server's acknowledgement of a
/// stream open, append, or close (§4.14).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireStreamAck {
    /// Which operation is acknowledged ([`STREAM_OP_OPEN`] /
    /// [`STREAM_OP_APPEND`] / [`STREAM_OP_CLOSE`]).
    pub op: u8,
    /// The stream this ack belongs to.
    pub stream_id: u64,
    /// The engine lane the stream's membrane state is pinned to.
    pub lane: u16,
    /// Macro cycles this stream has spent since its open.
    pub cycles: u64,
}

/// Encode a `StreamAck` payload: `op:u8`, `stream_id:u64`, `lane:u16`,
/// `cycles:u64` — 19 bytes, all big-endian.
pub fn encode_stream_ack(a: &WireStreamAck) -> Vec<u8> {
    let mut out = Vec::with_capacity(19);
    out.push(a.op);
    out.extend_from_slice(&a.stream_id.to_be_bytes());
    out.extend_from_slice(&a.lane.to_be_bytes());
    out.extend_from_slice(&a.cycles.to_be_bytes());
    out
}

/// Decode a `StreamAck` payload.
pub fn decode_stream_ack(payload: &[u8]) -> std::result::Result<WireStreamAck, PayloadError> {
    if payload.len() != 19 {
        return Err(PayloadError::new(
            ErrorCode::Malformed,
            format!("stream ack payload must be 19 bytes, got {}", payload.len()),
        ));
    }
    if payload[0] > STREAM_OP_CLOSE {
        return Err(PayloadError::new(
            ErrorCode::Malformed,
            format!("unknown stream ack op {}", payload[0]),
        ));
    }
    Ok(WireStreamAck {
        op: payload[0],
        stream_id: u64::from_be_bytes(payload[1..9].try_into().expect("8-byte slice")),
        lane: u16::from_be_bytes([payload[9], payload[10]]),
        cycles: u64::from_be_bytes(payload[11..19].try_into().expect("8-byte slice")),
    })
}

// ---------------------------------------------------------------------
// WirePayload: one typed codec surface per payload
// ---------------------------------------------------------------------

/// A typed IMP1 payload: the frame type byte it travels under plus its
/// byte-exact body codec, so new payloads add a type + impl instead of
/// another pile of free-function match arms. The original free
/// functions remain the canonical byte layouts (the pinned-hex tests
/// exercise them directly); every impl here delegates to — or is
/// asserted byte-identical with — those functions.
pub trait WirePayload: Sized {
    /// The frame type this payload travels under.
    const TYPE_ID: PayloadType;

    /// Encode the payload body (the bytes between header and CRC).
    fn encode(&self) -> std::result::Result<Vec<u8>, PayloadError>;

    /// Decode a payload body.
    fn decode(payload: &[u8]) -> std::result::Result<Self, PayloadError>;

    /// Wrap the encoded payload in a frame under [`Self::TYPE_ID`].
    fn frame(&self, request_id: u64) -> std::result::Result<Frame, PayloadError> {
        Ok(Frame::new(Self::TYPE_ID, request_id, self.encode()?))
    }
}

/// Typed `InferRequest` payload: one review's word ids (§4.4).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WordsPayload(pub Vec<i64>);

impl WirePayload for WordsPayload {
    const TYPE_ID: PayloadType = PayloadType::InferRequest;

    fn encode(&self) -> std::result::Result<Vec<u8>, PayloadError> {
        encode_infer_request(&self.0)
    }

    fn decode(payload: &[u8]) -> std::result::Result<Self, PayloadError> {
        decode_infer_request(payload).map(WordsPayload)
    }
}

/// Typed `DigitsInferRequest` payload: one image, row-major (§4.5).
#[derive(Clone, Debug, PartialEq)]
pub struct ImagePayload {
    /// Image height in pixels (1–255 on the wire).
    pub h: usize,
    /// Image width in pixels (1–255 on the wire).
    pub w: usize,
    /// Row-major pixels, `h · w` of them.
    pub pixels: Vec<f32>,
}

impl WirePayload for ImagePayload {
    const TYPE_ID: PayloadType = PayloadType::DigitsInferRequest;

    fn encode(&self) -> std::result::Result<Vec<u8>, PayloadError> {
        encode_digits_request(self.h, self.w, &self.pixels)
    }

    fn decode(payload: &[u8]) -> std::result::Result<Self, PayloadError> {
        decode_digits_request(payload).map(|(h, w, pixels)| ImagePayload { h, w, pixels })
    }
}

impl WirePayload for WireResponse {
    const TYPE_ID: PayloadType = PayloadType::InferResponse;

    fn encode(&self) -> std::result::Result<Vec<u8>, PayloadError> {
        let mut p = Vec::with_capacity(29);
        p.push(self.pred);
        p.extend_from_slice(&self.v_out.to_be_bytes());
        p.extend_from_slice(&self.cycles.to_be_bytes());
        p.extend_from_slice(&self.latency_us.to_be_bytes());
        p.extend_from_slice(&self.batch.to_be_bytes());
        p.extend_from_slice(&self.worker.to_be_bytes());
        Ok(p)
    }

    fn decode(payload: &[u8]) -> std::result::Result<Self, PayloadError> {
        decode_infer_response(payload)
    }
}

impl WirePayload for WireDigitsResponse {
    const TYPE_ID: PayloadType = PayloadType::DigitsInferResponse;

    fn encode(&self) -> std::result::Result<Vec<u8>, PayloadError> {
        let n = self.v_all.len().min(u8::MAX as usize);
        let mut p = Vec::with_capacity(2 + 8 * n + 20);
        p.push(self.pred);
        p.push(n as u8);
        for &v in &self.v_all[..n] {
            p.extend_from_slice(&v.to_be_bytes());
        }
        p.extend_from_slice(&self.cycles.to_be_bytes());
        p.extend_from_slice(&self.latency_us.to_be_bytes());
        p.extend_from_slice(&self.batch.to_be_bytes());
        p.extend_from_slice(&self.worker.to_be_bytes());
        Ok(p)
    }

    fn decode(payload: &[u8]) -> std::result::Result<Self, PayloadError> {
        decode_digits_response(payload)
    }
}

impl WirePayload for StatsSnapshot {
    const TYPE_ID: PayloadType = PayloadType::StatsResponse;

    fn encode(&self) -> std::result::Result<Vec<u8>, PayloadError> {
        Ok(encode_stats_response(self))
    }

    fn decode(payload: &[u8]) -> std::result::Result<Self, PayloadError> {
        decode_stats_response(payload)
    }
}

/// Typed `StreamOpen` payload — empty by definition (§4.10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamOpenPayload;

impl WirePayload for StreamOpenPayload {
    const TYPE_ID: PayloadType = PayloadType::StreamOpen;

    fn encode(&self) -> std::result::Result<Vec<u8>, PayloadError> {
        Ok(Vec::new())
    }

    fn decode(payload: &[u8]) -> std::result::Result<Self, PayloadError> {
        if !payload.is_empty() {
            return Err(PayloadError::new(
                ErrorCode::Malformed,
                format!("stream open payload must be empty, got {} bytes", payload.len()),
            ));
        }
        Ok(StreamOpenPayload)
    }
}

/// Typed `StreamAppend` payload (§4.11).
#[derive(Clone, Debug)]
pub struct StreamAppendPayload {
    /// The stream to advance.
    pub stream_id: u64,
    /// The chunk to integrate into the pinned membrane state.
    pub chunk: WorkloadInput,
}

impl WirePayload for StreamAppendPayload {
    const TYPE_ID: PayloadType = PayloadType::StreamAppend;

    fn encode(&self) -> std::result::Result<Vec<u8>, PayloadError> {
        encode_stream_append(self.stream_id, &self.chunk)
    }

    fn decode(payload: &[u8]) -> std::result::Result<Self, PayloadError> {
        decode_stream_append(payload)
            .map(|(stream_id, chunk)| StreamAppendPayload { stream_id, chunk })
    }
}

/// Typed `StreamReadOut` payload (§4.12).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamReadOutPayload {
    /// The stream to read the prediction from.
    pub stream_id: u64,
}

impl WirePayload for StreamReadOutPayload {
    const TYPE_ID: PayloadType = PayloadType::StreamReadOut;

    fn encode(&self) -> std::result::Result<Vec<u8>, PayloadError> {
        Ok(encode_stream_ref(self.stream_id))
    }

    fn decode(payload: &[u8]) -> std::result::Result<Self, PayloadError> {
        decode_stream_ref(payload).map(|stream_id| StreamReadOutPayload { stream_id })
    }
}

/// Typed `StreamClose` payload (§4.13).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamClosePayload {
    /// The stream to close.
    pub stream_id: u64,
}

impl WirePayload for StreamClosePayload {
    const TYPE_ID: PayloadType = PayloadType::StreamClose;

    fn encode(&self) -> std::result::Result<Vec<u8>, PayloadError> {
        Ok(encode_stream_ref(self.stream_id))
    }

    fn decode(payload: &[u8]) -> std::result::Result<Self, PayloadError> {
        decode_stream_ref(payload).map(|stream_id| StreamClosePayload { stream_id })
    }
}

impl WirePayload for WireStreamAck {
    const TYPE_ID: PayloadType = PayloadType::StreamAck;

    fn encode(&self) -> std::result::Result<Vec<u8>, PayloadError> {
        Ok(encode_stream_ack(self))
    }

    fn decode(payload: &[u8]) -> std::result::Result<Self, PayloadError> {
        decode_stream_ack(payload)
    }
}

/// A server-reported error decoded from an `Error` frame: the raw
/// wire code (which may be newer than this build's [`ErrorCode`])
/// plus the server's message. The typed surface
/// ([`FrameClient::call`] / [`FrameClient::wait`] and the stream
/// methods) bails with this as the error source, so callers can
/// downcast and branch on the code:
///
/// ```ignore
/// match err.downcast_ref::<ServerError>() {
///     Some(e) if e.error_code() == Some(ErrorCode::StreamExpired) => reopen(),
///     _ => return Err(err),
/// }
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServerError {
    /// Raw wire error code (see [`ErrorCode`]).
    pub code: u16,
    /// Server-provided message.
    pub msg: String,
}

impl ServerError {
    /// The typed error code, when this build knows it.
    pub fn error_code(&self) -> Option<ErrorCode> {
        ErrorCode::from_u16(self.code)
    }
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server error (code {}): {}", self.code, self.msg)
    }
}

impl std::error::Error for ServerError {}

impl WirePayload for ServerError {
    const TYPE_ID: PayloadType = PayloadType::Error;

    fn encode(&self) -> std::result::Result<Vec<u8>, PayloadError> {
        let bytes = self.msg.as_bytes();
        let n = bytes.len().min(u16::MAX as usize);
        let mut out = Vec::with_capacity(4 + n);
        out.extend_from_slice(&self.code.to_be_bytes());
        out.extend_from_slice(&(n as u16).to_be_bytes());
        out.extend_from_slice(&bytes[..n]);
        Ok(out)
    }

    fn decode(payload: &[u8]) -> std::result::Result<Self, PayloadError> {
        let (code, msg) = decode_error(payload)?;
        Ok(ServerError { code, msg })
    }
}

// ---------------------------------------------------------------------
// Adaptive pacing: the client half of the backpressure loop
// ---------------------------------------------------------------------

/// Opt-in client-side pacing driven by the server's backpressure
/// advertisements (the flags word on [`CAP_BACKPRESSURE`]
/// connections). Frames with the soft-limit bit set double the delay
/// applied before the next submit/append (starting at `base`, capped
/// at `max`); advertisements with the bit clear halve it back toward
/// zero. Frames without an advertisement leave the delay untouched.
#[derive(Clone, Copy, Debug)]
pub struct Pacer {
    base: Duration,
    max: Duration,
    cur: Duration,
}

impl Pacer {
    /// A pacer that starts delaying at `base` on the first
    /// soft-limited frame and backs off exponentially up to `max`.
    pub fn new(base: Duration, max: Duration) -> Pacer {
        Pacer { base, max: max.max(base), cur: Duration::ZERO }
    }

    /// Observe one received frame's flags word and adapt the delay.
    pub fn observe(&mut self, flags: u16) {
        if let Some(bp) = decode_backpressure(flags) {
            self.cur = if bp.soft_limited {
                if self.cur.is_zero() {
                    self.base
                } else {
                    (self.cur * 2).min(self.max)
                }
            } else {
                self.cur / 2
            };
        }
    }

    /// The delay to apply before the next submit/append.
    pub fn delay(&self) -> Duration {
        self.cur
    }
}

// ---------------------------------------------------------------------
// ServeCore: many sessions over one inference server
// ---------------------------------------------------------------------

struct PendingReply {
    external_id: u64,
    deliver: Box<dyn FnOnce(Response) + Send>,
}

type PendingMap = Arc<Mutex<HashMap<u64, PendingReply>>>;

/// The serving front-end core: one shared [`InferenceServer`]
/// (batcher + work-stealing workers) plus a dispatcher thread that
/// routes responses back to the submitting [`ClientSession`].
///
/// Sessions re-key every request onto a process-unique internal id, so
/// clients can use any request ids they like — including colliding
/// ones — and still get exactly one response each, with their own id
/// echoed back.
pub struct ServeCore {
    submitter: Mutex<Option<Submitter>>,
    pending: PendingMap,
    next_id: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
    vocab: i64,
    telemetry: Arc<Telemetry>,
    streams: Arc<StreamTable>,
    next_conn: AtomicU64,
    recorder: Mutex<Option<Arc<crate::replay::Recorder>>>,
    trace: Option<Arc<crate::obs::trace::TraceRecorder>>,
}

impl ServeCore {
    /// Spawn the worker pool and dispatcher over any [`Workload`]
    /// model (sentiment or digits). `vocab` is the embedding table
    /// size; sessions clamp incoming *word-id* inputs into
    /// `[0, vocab)` (identically on every transport; image inputs are
    /// validated for shape instead — pass `1` for image workloads).
    pub fn start_with<W, F>(opts: ServerOptions, vocab: i64, factory: F) -> Result<ServeCore>
    where
        W: Workload,
        F: Fn() -> Result<W> + Send + Sync + 'static,
    {
        anyhow::ensure!(vocab >= 1, "vocabulary must be non-empty");
        // every serve core has a telemetry registry: use the caller's
        // (wired through ServerOptions so the worker pool shares it)
        // or create a default one and hand it to the pool ourselves
        let mut opts = opts;
        let telemetry = match &opts.telemetry {
            Some(t) => Arc::clone(t),
            None => {
                let t = Arc::new(Telemetry::new(TelemetryConfig::default()));
                opts.telemetry = Some(Arc::clone(&t));
                t
            }
        };
        // tracing stays None unless the caller wired a recorder — the
        // disabled path must stay bit-identical to a build without it
        let trace = opts.trace.clone();
        let factory = Arc::new(factory);
        let streams = Arc::new(StreamTable::new(
            {
                let f = Arc::clone(&factory);
                Box::new(move || f().map(|w| Box::new(w) as Box<dyn Workload>))
            },
            opts.max_streams,
            opts.stream_ttl,
            vocab,
            Arc::clone(&telemetry),
            trace.clone(),
        ));
        let server = InferenceServer::start_with(opts, move || factory())?;
        let submitter = server.submitter();
        let pending: PendingMap = Arc::new(Mutex::new(HashMap::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let dispatcher = {
            let pending = Arc::clone(&pending);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                loop {
                    match server.recv_timeout(Duration::from_millis(25)) {
                        Ok(mut r) => {
                            let entry = pending.lock().expect("pending poisoned").remove(&r.id);
                            if let Some(e) = entry {
                                r.id = e.external_id;
                                (e.deliver)(r);
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            if stop.load(Ordering::SeqCst)
                                && pending.lock().expect("pending poisoned").is_empty()
                            {
                                break;
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                server.shutdown();
            })
        };
        Ok(ServeCore {
            submitter: Mutex::new(Some(submitter)),
            pending,
            next_id: Arc::new(AtomicU64::new(1)),
            stop,
            dispatcher: Mutex::new(Some(dispatcher)),
            vocab,
            telemetry,
            streams,
            next_conn: AtomicU64::new(1),
            recorder: Mutex::new(None),
            trace,
        })
    }

    /// Attach a wire/digest recorder: every TCP connection accepted
    /// from now on taps its inbound bytes, outbound frames, and
    /// per-request V-digests into it (`docs/REPLAY.md`). Recording is
    /// a server-side tap — nothing changes on the wire.
    pub fn set_recorder(&self, rec: Arc<crate::replay::Recorder>) {
        *self.recorder.lock().expect("recorder poisoned") = Some(rec);
    }

    /// The attached recorder, if any (cloned per connection at accept
    /// time).
    pub fn recorder(&self) -> Option<Arc<crate::replay::Recorder>> {
        self.recorder.lock().expect("recorder poisoned").clone()
    }

    /// The stream session table: membrane state pinned per
    /// `(connection, stream id)` key until closed or TTL-evicted.
    pub fn streams(&self) -> &Arc<StreamTable> {
        &self.streams
    }

    /// Allocate a connection id for stream scoping — stream ids are
    /// per-connection, so every transport connection that can open
    /// streams takes one of these at accept time.
    pub fn next_conn_id(&self) -> u64 {
        self.next_conn.fetch_add(1, Ordering::SeqCst)
    }

    /// The live telemetry registry this core's worker pool updates —
    /// what `StatsRequest` frames, the metrics endpoint, and the
    /// backpressure flags word are answered from.
    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.telemetry
    }

    /// The span recorder wired through [`ServerOptions::trace`], if
    /// any. Transports clone it per connection; `None` means tracing
    /// is off and every chokepoint takes its single disabled branch.
    pub fn trace(&self) -> Option<&Arc<crate::obs::trace::TraceRecorder>> {
        self.trace.as_ref()
    }

    /// Open a session (one logical client). Sessions may live on any
    /// thread; dropping one abandons nothing — in-flight requests
    /// still drain through the dispatcher.
    pub fn client(&self) -> Result<ClientSession> {
        let submitter = self
            .submitter
            .lock()
            .expect("submitter poisoned")
            .clone()
            .ok_or_else(|| anyhow::anyhow!("serve core is shut down"))?;
        let (tx, rx) = mpsc::channel();
        Ok(ClientSession {
            sender: SessionSender {
                submitter,
                pending: Arc::clone(&self.pending),
                next_id: Arc::clone(&self.next_id),
                tx,
                vocab: self.vocab,
            },
            rx,
        })
    }

    /// Responses not yet routed back to their sessions.
    pub fn pending(&self) -> usize {
        self.pending.lock().expect("pending poisoned").len()
    }

    /// Stop accepting new sessions, drain in-flight requests, and join
    /// the dispatcher and worker pool. All [`ClientSession`]s (and
    /// their [`SessionSender`] halves) must be dropped first — the
    /// worker pool only winds down once every submission handle is
    /// gone.
    pub fn shutdown(&self) {
        self.submitter.lock().expect("submitter poisoned").take();
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.dispatcher.lock().expect("dispatcher poisoned").take() {
            let _ = h.join();
        }
    }
}

/// The submit half of a session (usable from a reader thread while
/// another thread drains responses).
pub struct SessionSender {
    submitter: Submitter,
    pending: PendingMap,
    next_id: Arc<AtomicU64>,
    tx: mpsc::Sender<Response>,
    vocab: i64,
}

impl SessionSender {
    /// Submit one sentiment request. Word ids are clamped into
    /// `[0, vocab)` — the same normalization on every transport.
    /// Errors if the request is empty, exceeds
    /// [`MAX_WORDS_PER_REQUEST`], or the server is shutting down.
    pub fn submit(&self, external_id: u64, word_ids: &[i64]) -> Result<()> {
        self.submit_input(external_id, WorkloadInput::Words(word_ids.to_vec()))
    }

    /// Submit one request of any workload kind, with the transport's
    /// normalization applied: word ids clamped into `[0, vocab)`,
    /// image shapes validated.
    pub fn submit_input(&self, external_id: u64, input: WorkloadInput) -> Result<()> {
        self.submit_input_traced(external_id, input, None)
    }

    /// [`SessionSender::submit_input`] with a trace context attached:
    /// the coordinator's queue/batch/execute spans are recorded under
    /// `trace.trace_id` and the timing summary rides back on the
    /// [`Response`]. `None` is the untraced path, bit-identical to
    /// [`SessionSender::submit_input`].
    pub fn submit_input_traced(
        &self,
        external_id: u64,
        input: WorkloadInput,
        trace: Option<crate::obs::trace::TraceCtx>,
    ) -> Result<()> {
        let input = match input {
            WorkloadInput::Words(ids) => {
                anyhow::ensure!(!ids.is_empty(), "request {external_id}: no word ids");
                anyhow::ensure!(
                    ids.len() <= MAX_WORDS_PER_REQUEST,
                    "request {external_id}: {} word ids exceed the \
                     {MAX_WORDS_PER_REQUEST}-word request cap",
                    ids.len()
                );
                WorkloadInput::Words(
                    ids.iter().map(|&w| w.clamp(0, self.vocab - 1)).collect(),
                )
            }
            WorkloadInput::Image { h, w, pixels } => {
                anyhow::ensure!(
                    h > 0 && w > 0 && pixels.len() == h * w,
                    "request {external_id}: {h}×{w} image with {} pixels",
                    pixels.len()
                );
                WorkloadInput::Image { h, w, pixels }
            }
        };
        let internal = self.next_id.fetch_add(1, Ordering::SeqCst);
        let tx = self.tx.clone();
        self.pending.lock().expect("pending poisoned").insert(
            internal,
            PendingReply {
                external_id,
                deliver: Box::new(move |r| {
                    let _ = tx.send(r);
                }),
            },
        );
        match self.submitter.submit(Request { id: internal, input, trace }) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.pending.lock().expect("pending poisoned").remove(&internal);
                Err(e)
            }
        }
    }
}

/// One logical client of a [`ServeCore`]: submit requests, receive
/// exactly one [`Response`] per request with the caller's request id.
pub struct ClientSession {
    sender: SessionSender,
    rx: mpsc::Receiver<Response>,
}

impl ClientSession {
    /// Submit one sentiment request (see [`SessionSender::submit`]).
    pub fn submit(&self, external_id: u64, word_ids: &[i64]) -> Result<()> {
        self.sender.submit(external_id, word_ids)
    }

    /// Submit one request of any workload kind (see
    /// [`SessionSender::submit_input`]).
    pub fn submit_input(&self, external_id: u64, input: WorkloadInput) -> Result<()> {
        self.sender.submit_input(external_id, input)
    }

    /// Block for the next response of this session.
    pub fn recv(&self) -> Result<Response> {
        Ok(self.rx.recv()?)
    }

    /// A ready response, if any (non-blocking).
    pub fn try_recv(&self) -> Option<Response> {
        self.rx.try_recv().ok()
    }

    /// Block up to `timeout` for the next response.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        self.rx.recv_timeout(timeout).ok()
    }

    /// Split into the submit half and the raw response receiver, so a
    /// reader thread can submit while a writer thread drains (the TCP
    /// connection shape).
    pub fn split(self) -> (SessionSender, mpsc::Receiver<Response>) {
        (self.sender, self.rx)
    }
}

// ---------------------------------------------------------------------
// FrameClient: a minimal blocking client for the binary protocol
// ---------------------------------------------------------------------

/// A not-yet-awaited response on the typed surface: the request id
/// [`FrameClient::call`] assigned, tagged with the output type
/// [`FrameClient::wait`] will decode it into.
#[derive(Clone, Copy, Debug)]
pub struct Pending<T> {
    id: u64,
    _out: std::marker::PhantomData<fn() -> T>,
}

impl<T> Pending<T> {
    /// The request id the server will echo on the response frame.
    pub fn id(&self) -> u64 {
        self.id
    }
}

/// An open streaming session on the server: a model lane's membrane
/// potentials stay pinned to this handle's stream id across appends,
/// until [`FrameClient::stream_close`], connection EOF, or TTL
/// eviction.
#[derive(Clone, Copy, Debug)]
pub struct StreamHandle {
    id: u64,
    lane: u16,
}

impl StreamHandle {
    /// The stream id (the `StreamOpen` frame's request id).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The server-side engine lane the membrane state is pinned to.
    pub fn lane(&self) -> u16 {
        self.lane
    }
}

/// Decode a typed-surface response frame into a [`WorkloadOutput`]
/// plus the trace-echo trailer when the frame carries one
/// (`InferResponse` or `DigitsInferResponse`); `Error` frames bail
/// with a downcastable [`ServerError`].
fn decode_output_traced(f: &Frame) -> Result<(WorkloadOutput, Option<TraceEcho>)> {
    let (body, echo) = split_trace_echo(f.flags, &f.payload);
    match f.payload_type {
        PayloadType::InferResponse => {
            let r = WireResponse::decode(body).map_err(anyhow::Error::from)?;
            Ok((
                WorkloadOutput {
                    pred: r.pred,
                    v_out: r.v_out,
                    v_all: vec![r.v_out],
                    cycles: r.cycles,
                },
                echo,
            ))
        }
        PayloadType::DigitsInferResponse => {
            let r = WireDigitsResponse::decode(body).map_err(anyhow::Error::from)?;
            let v_out = r.v_all.get(r.pred as usize).copied().unwrap_or_default();
            Ok((WorkloadOutput { pred: r.pred, v_out, v_all: r.v_all, cycles: r.cycles }, echo))
        }
        PayloadType::Error => {
            // error frames never carry the trailer (attach_trace_echo
            // skips them), so decode the payload as sent
            let e = ServerError::decode(&f.payload).map_err(anyhow::Error::from)?;
            Err(anyhow::Error::new(e))
        }
        other => anyhow::bail!("unexpected frame type {other:?} for request {}", f.request_id),
    }
}

/// Decode a typed-surface response frame, dropping any trace-echo
/// trailer.
fn decode_output(f: &Frame) -> Result<WorkloadOutput> {
    decode_output_traced(f).map(|(out, _)| out)
}

/// A blocking TCP client for the framed protocol — used by the
/// integration tests, the CLI, and handy as a reference
/// implementation.
///
/// The typed surface is [`FrameClient::call`] → [`FrameClient::wait`]
/// (plus the `stream_*` methods and [`FrameClient::stats`]): one entry
/// point per direction, correlated by request id, workload-agnostic.
/// The per-workload `send_*`/`next_*` pairs are deprecated thin
/// wrappers kept for existing callers.
pub struct FrameClient {
    w: TcpStream,
    reader: FrameReader<TcpStream>,
    next_id: u64,
    stash: HashMap<u64, Frame>,
    pacer: Option<Pacer>,
    trace_echo: bool,
}

impl FrameClient {
    /// Connect to a framed server.
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<FrameClient> {
        let w = TcpStream::connect(addr)?;
        w.set_nodelay(true).ok();
        let r = w.try_clone()?;
        Ok(FrameClient {
            w,
            reader: FrameReader::new(r),
            next_id: 1,
            stash: HashMap::new(),
            pacer: None,
            trace_echo: false,
        })
    }

    /// Connect with bounded retries and exponential backoff: up to
    /// `attempts` connection attempts, sleeping `base` after the first
    /// failure and doubling (capped at 5 s) between the rest. Lets a
    /// client ride out a proxy or backend restart instead of erroring
    /// on the first refused connection.
    pub fn connect_with_backoff(
        addr: impl std::net::ToSocketAddrs + Clone,
        attempts: u32,
        base: Duration,
    ) -> Result<FrameClient> {
        let attempts = attempts.max(1);
        let mut delay = base;
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..attempts {
            match FrameClient::connect(addr.clone()) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    last = Some(e);
                    if attempt + 1 < attempts {
                        std::thread::sleep(delay);
                        delay = (delay * 2).min(Duration::from_secs(5));
                    }
                }
            }
        }
        Err(last
            .expect("at least one attempt was made")
            .context(format!("connect failed after {attempts} attempt(s)")))
    }

    /// Set the socket read timeout (both halves share the socket).
    pub fn set_read_timeout(&self, d: Option<Duration>) -> Result<()> {
        self.w.set_read_timeout(d)?;
        Ok(())
    }

    // --- the typed request surface -----------------------------------

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1).max(1);
        id
    }

    fn pace(&self) {
        if let Some(p) = &self.pacer {
            let d = p.delay();
            if !d.is_zero() {
                std::thread::sleep(d);
            }
        }
    }

    /// Enable adaptive pacing (see [`Pacer`]): every subsequent
    /// [`FrameClient::call`] and [`FrameClient::stream_append`] sleeps
    /// the pacer's current delay before writing. Negotiate
    /// [`CAP_BACKPRESSURE`] first (via
    /// [`FrameClient::hello_with_caps`]) or no received frame will
    /// carry an advertisement to adapt to.
    pub fn enable_pacing(&mut self, base: Duration, max: Duration) {
        self.pacer = Some(Pacer::new(base, max));
    }

    /// The pacer's current delay: zero when pacing is off or the
    /// server has not advertised congestion.
    pub fn pacing_delay(&self) -> Duration {
        self.pacer.map(|p| p.delay()).unwrap_or(Duration::ZERO)
    }

    /// Ask the server to echo its per-phase timing breakdown on every
    /// subsequent [`FrameClient::call`] response. Negotiate
    /// [`CAP_TRACE_ECHO`] first (via [`FrameClient::hello_with_caps`])
    /// — without the grant the server ignores the request flag — and
    /// read the echo back with [`FrameClient::wait_with_trace`]. The
    /// echo is only populated when the server itself is tracing
    /// (`--trace-dir`).
    pub fn set_trace_echo(&mut self, on: bool) {
        self.trace_echo = on;
    }

    /// Submit one request of any workload kind on the typed surface.
    /// Assigns a request id, writes the matching wire payload (words →
    /// `InferRequest`, image → `DigitsInferRequest`), and returns a
    /// correlation handle; block for the result with
    /// [`FrameClient::wait`]. Multiple calls may be in flight at once
    /// — responses are correlated by id, in any arrival order.
    ///
    /// Auto-assigned ids count up from 1; don't mix the typed surface
    /// with explicit-id sends on one connection.
    pub fn call(&mut self, input: &WorkloadInput) -> Result<Pending<WorkloadOutput>> {
        self.pace();
        let id = self.fresh_id();
        let (ty, payload) = match input {
            WorkloadInput::Words(ids) => (
                PayloadType::InferRequest,
                encode_infer_request(ids).map_err(anyhow::Error::from)?,
            ),
            WorkloadInput::Image { h, w, pixels } => (
                PayloadType::DigitsInferRequest,
                encode_digits_request(*h, *w, pixels).map_err(anyhow::Error::from)?,
            ),
        };
        let mut f = Frame::new(ty, id, payload);
        if self.trace_echo {
            use super::frame::{FLAG_TELEMETRY, FLAG_TRACE_ECHO};
            f = f.with_flags(FLAG_TELEMETRY | FLAG_TRACE_ECHO);
        }
        f.write_to(&mut self.w)?;
        Ok(Pending { id, _out: std::marker::PhantomData })
    }

    /// Block until the response for `pending` arrives. Frames for
    /// other in-flight requests are stashed for their own waiters, so
    /// `wait` order need not match `call` order. `Error` responses
    /// bail with a downcastable [`ServerError`].
    pub fn wait(&mut self, pending: &Pending<WorkloadOutput>) -> Result<WorkloadOutput> {
        let f = self.frame_for(pending.id)?;
        decode_output(&f)
    }

    /// Like [`FrameClient::wait`], but also returns the trace-echo
    /// trailer when the response carries one (requires
    /// [`FrameClient::set_trace_echo`] and a [`CAP_TRACE_ECHO`] grant;
    /// `None` when the server is not tracing).
    pub fn wait_with_trace(
        &mut self,
        pending: &Pending<WorkloadOutput>,
    ) -> Result<(WorkloadOutput, Option<TraceEcho>)> {
        let f = self.frame_for(pending.id)?;
        decode_output_traced(&f)
    }

    /// Like [`FrameClient::wait`], but with a per-request deadline:
    /// bails if `pending`'s response has not arrived within `timeout`.
    /// The connection stays usable after a deadline miss — a partial
    /// frame's bytes are preserved by the reader's carry buffer, and a
    /// later wait (or [`FrameClient::wait_timeout`] retry) picks up
    /// where the read left off. The previously configured socket read
    /// timeout is restored on every exit path.
    pub fn wait_timeout(
        &mut self,
        pending: &Pending<WorkloadOutput>,
        timeout: Duration,
    ) -> Result<WorkloadOutput> {
        let f = self.frame_for_deadline(pending.id, timeout)?;
        decode_output(&f)
    }

    /// [`FrameClient::frame_for`] with a deadline: polls the socket in
    /// short read-timeout slices (the frame reader's carry buffer
    /// keeps partial frames across slices) and bails once `timeout`
    /// has elapsed without `id`'s response.
    fn frame_for_deadline(&mut self, id: u64, timeout: Duration) -> Result<Frame> {
        if let Some(f) = self.stash.remove(&id) {
            return Ok(f);
        }
        let deadline = Instant::now() + timeout;
        let prev = self.w.read_timeout().ok().flatten();
        let restore = |w: &TcpStream| {
            w.set_read_timeout(prev).ok();
        };
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                restore(&self.w);
                anyhow::bail!(
                    "request {id}: no response within {}ms",
                    timeout.as_millis()
                );
            }
            if self.w.set_read_timeout(Some(left.min(Duration::from_millis(50)))).is_err() {
                restore(&self.w);
                anyhow::bail!("request {id}: failed to arm the read timeout");
            }
            match self.reader.next_frame() {
                Ok(None) => {
                    restore(&self.w);
                    anyhow::bail!("connection closed while awaiting request {id}");
                }
                Ok(Some(f)) => {
                    if let Some(p) = self.pacer.as_mut() {
                        p.observe(f.flags);
                    }
                    if f.request_id == id {
                        restore(&self.w);
                        return Ok(f);
                    }
                    self.stash.insert(f.request_id, f);
                }
                // a read-timeout slice elapsed mid-frame: the carry
                // buffer holds what arrived; keep polling until the
                // overall deadline
                Err(WireError::Io(e))
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) => {}
                Err(e) => {
                    restore(&self.w);
                    return Err(anyhow::Error::from(e));
                }
            }
        }
    }

    /// Read frames until `id`'s response shows up, stashing frames
    /// addressed to other requests.
    fn frame_for(&mut self, id: u64) -> Result<Frame> {
        if let Some(f) = self.stash.remove(&id) {
            return Ok(f);
        }
        loop {
            match self.next_frame()? {
                None => anyhow::bail!("connection closed while awaiting request {id}"),
                Some(f) if f.request_id == id => return Ok(f),
                Some(f) => {
                    self.stash.insert(f.request_id, f);
                }
            }
        }
    }

    /// Request a telemetry snapshot on the typed surface and block for
    /// it. Returns the snapshot plus the response frame's flags word
    /// (a backpressure advertisement when [`CAP_BACKPRESSURE`] was
    /// negotiated — decode with [`super::frame::decode_backpressure`]).
    pub fn stats(&mut self) -> Result<(StatsSnapshot, u16)> {
        let id = self.fresh_id();
        Frame::new(PayloadType::StatsRequest, id, encode_stats_request())
            .write_to(&mut self.w)?;
        let f = self.frame_for(id)?;
        match f.payload_type {
            PayloadType::StatsResponse => {
                let snap = StatsSnapshot::decode(&f.payload).map_err(anyhow::Error::from)?;
                Ok((snap, f.flags))
            }
            PayloadType::Error => {
                let e = ServerError::decode(&f.payload).map_err(anyhow::Error::from)?;
                Err(anyhow::Error::new(e).context("stats request failed"))
            }
            other => anyhow::bail!("expected StatsResponse, got {other:?}"),
        }
    }

    // --- streaming sessions ------------------------------------------

    /// Open a streaming session: the server pins a model lane's
    /// membrane potentials to the returned handle until
    /// [`FrameClient::stream_close`], connection EOF, or TTL eviction.
    pub fn stream_open(&mut self) -> Result<StreamHandle> {
        let id = self.fresh_id();
        StreamOpenPayload
            .frame(id)
            .map_err(anyhow::Error::from)?
            .write_to(&mut self.w)?;
        let a = self.stream_ack(id, STREAM_OP_OPEN)?;
        Ok(StreamHandle { id: a.stream_id, lane: a.lane })
    }

    /// Append a chunk to an open stream — word ids for a sentiment
    /// stream, or one image frame (= one membrane timestep) for a
    /// digits stream. Returns the server's ack carrying the stream's
    /// cumulative macro cycles. Paced when pacing is enabled.
    pub fn stream_append(
        &mut self,
        h: &StreamHandle,
        chunk: &WorkloadInput,
    ) -> Result<WireStreamAck> {
        self.pace();
        let id = self.fresh_id();
        let payload = encode_stream_append(h.id, chunk).map_err(anyhow::Error::from)?;
        Frame::new(PayloadType::StreamAppend, id, payload).write_to(&mut self.w)?;
        self.stream_ack(id, STREAM_OP_APPEND)
    }

    /// Read the stream's current prediction from its pinned membrane
    /// state; the stream stays open for further appends.
    pub fn stream_read_out(&mut self, h: &StreamHandle) -> Result<WorkloadOutput> {
        let id = self.fresh_id();
        Frame::new(PayloadType::StreamReadOut, id, encode_stream_ref(h.id))
            .write_to(&mut self.w)?;
        let f = self.frame_for(id)?;
        decode_output(&f)
    }

    /// Close the stream and free its lane for the next session.
    /// Returns the final ack with the stream's total macro cycles.
    pub fn stream_close(&mut self, h: &StreamHandle) -> Result<WireStreamAck> {
        let id = self.fresh_id();
        Frame::new(PayloadType::StreamClose, id, encode_stream_ref(h.id))
            .write_to(&mut self.w)?;
        self.stream_ack(id, STREAM_OP_CLOSE)
    }

    fn stream_ack(&mut self, id: u64, op: u8) -> Result<WireStreamAck> {
        let f = self.frame_for(id)?;
        match f.payload_type {
            PayloadType::StreamAck => {
                let a = WireStreamAck::decode(&f.payload).map_err(anyhow::Error::from)?;
                anyhow::ensure!(a.op == op, "stream ack op {} while awaiting {op}", a.op);
                Ok(a)
            }
            PayloadType::Error => {
                let e = ServerError::decode(&f.payload).map_err(anyhow::Error::from)?;
                Err(anyhow::Error::new(e))
            }
            other => anyhow::bail!("expected StreamAck, got {other:?}"),
        }
    }

    /// Negotiate the protocol version (`Hello`/`HelloAck`). Returns
    /// the version the server chose.
    pub fn hello(&mut self) -> Result<u8> {
        Frame::new(PayloadType::Hello, 0, hello_payload(PROTOCOL_VERSION, PROTOCOL_VERSION))
            .write_to(&mut self.w)?;
        match self.next_frame()? {
            Some(f) if f.payload_type == PayloadType::HelloAck => {
                anyhow::ensure!(f.payload.len() == 1, "hello ack payload must be 1 byte");
                Ok(f.payload[0])
            }
            Some(f) if f.payload_type == PayloadType::Error => {
                let (code, msg) = decode_error(&f.payload).map_err(anyhow::Error::from)?;
                anyhow::bail!("server refused hello (code {code}): {msg}")
            }
            other => anyhow::bail!("expected HelloAck, got {other:?}"),
        }
    }

    /// Negotiate version *and* capabilities with an extended 3-byte
    /// `Hello` (e.g. [`CAP_BACKPRESSURE`]). Returns the negotiated
    /// `(version, granted caps)` from the 2-byte `HelloAck`.
    pub fn hello_with_caps(&mut self, caps: u8) -> Result<(u8, u8)> {
        Frame::new(
            PayloadType::Hello,
            0,
            hello_caps_payload(PROTOCOL_VERSION, PROTOCOL_VERSION, caps),
        )
        .write_to(&mut self.w)?;
        match self.next_frame()? {
            Some(f) if f.payload_type == PayloadType::HelloAck => {
                anyhow::ensure!(
                    f.payload.len() == 2,
                    "extended hello ack payload must be 2 bytes, got {}",
                    f.payload.len()
                );
                Ok((f.payload[0], f.payload[1]))
            }
            Some(f) if f.payload_type == PayloadType::Error => {
                let (code, msg) = decode_error(&f.payload).map_err(anyhow::Error::from)?;
                anyhow::bail!("server refused hello (code {code}): {msg}")
            }
            other => anyhow::bail!("expected HelloAck, got {other:?}"),
        }
    }

    /// Send one `StatsRequest` (does not wait for the response).
    #[deprecated(note = "use the typed surface: `FrameClient::stats`")]
    pub fn send_stats(&mut self, request_id: u64) -> Result<()> {
        Frame::new(PayloadType::StatsRequest, request_id, encode_stats_request())
            .write_to(&mut self.w)?;
        Ok(())
    }

    /// Request a telemetry snapshot and block for it. Returns the
    /// snapshot plus the response frame's flags word (a backpressure
    /// advertisement when [`CAP_BACKPRESSURE`] was negotiated — decode
    /// with [`super::frame::decode_backpressure`]). Expects a quiet
    /// connection (the `impulse stats` shape); with inference
    /// responses in flight, use [`FrameClient::stats`], which
    /// correlates frames by request id.
    #[deprecated(note = "use the typed surface: `FrameClient::stats`")]
    #[allow(deprecated)]
    pub fn fetch_stats(&mut self, request_id: u64) -> Result<(StatsSnapshot, u16)> {
        self.send_stats(request_id)?;
        match self.next_frame()? {
            Some(f) if f.payload_type == PayloadType::StatsResponse => {
                anyhow::ensure!(
                    f.request_id == request_id,
                    "stats response for id {} while awaiting {request_id}",
                    f.request_id
                );
                let snap = decode_stats_response(&f.payload).map_err(anyhow::Error::from)?;
                Ok((snap, f.flags))
            }
            Some(f) if f.payload_type == PayloadType::Error => {
                let (code, msg) = decode_error(&f.payload).map_err(anyhow::Error::from)?;
                anyhow::bail!("stats request failed (code {code}): {msg}")
            }
            other => anyhow::bail!("expected StatsResponse, got {other:?}"),
        }
    }

    /// Send one `InferRequest` (does not wait for the response).
    /// Oversized requests (> [`MAX_WORDS_PER_REQUEST`] word ids) are
    /// rejected client-side before any bytes hit the wire.
    #[deprecated(note = "use the typed surface: `FrameClient::call` + `wait`")]
    pub fn send_infer(&mut self, request_id: u64, word_ids: &[i64]) -> Result<()> {
        let payload = encode_infer_request(word_ids).map_err(anyhow::Error::from)?;
        Frame::new(PayloadType::InferRequest, request_id, payload).write_to(&mut self.w)?;
        Ok(())
    }

    /// Send one `DigitsInferRequest` (does not wait for the response).
    #[deprecated(note = "use the typed surface: `FrameClient::call` + `wait`")]
    pub fn send_digits_infer(
        &mut self,
        request_id: u64,
        h: usize,
        w: usize,
        pixels: &[f32],
    ) -> Result<()> {
        let payload = encode_digits_request(h, w, pixels).map_err(anyhow::Error::from)?;
        Frame::new(PayloadType::DigitsInferRequest, request_id, payload)
            .write_to(&mut self.w)?;
        Ok(())
    }

    /// Read the next frame from the server (`None` on clean EOF).
    /// Every received frame's flags word feeds the pacer when pacing
    /// is enabled.
    pub fn next_frame(&mut self) -> Result<Option<Frame>> {
        let f = self.reader.next_frame().map_err(anyhow::Error::from)?;
        if let (Some(p), Some(f)) = (self.pacer.as_mut(), f.as_ref()) {
            p.observe(f.flags);
        }
        Ok(f)
    }

    /// Read the next `InferResponse`/`Error` frame, decoded. Returns
    /// the request id and either the response or `(code, message)`.
    #[deprecated(note = "use the typed surface: `FrameClient::call` + `wait`")]
    #[allow(clippy::type_complexity)]
    pub fn next_result(
        &mut self,
    ) -> Result<Option<(u64, std::result::Result<WireResponse, (u16, String)>)>> {
        match self.next_frame()? {
            None => Ok(None),
            Some(f) => match f.payload_type {
                PayloadType::InferResponse => {
                    let r = decode_infer_response(&f.payload).map_err(anyhow::Error::from)?;
                    Ok(Some((f.request_id, Ok(r))))
                }
                PayloadType::Error => {
                    let e = decode_error(&f.payload).map_err(anyhow::Error::from)?;
                    Ok(Some((f.request_id, Err(e))))
                }
                other => anyhow::bail!("unexpected frame type {other:?} mid-stream"),
            },
        }
    }

    /// Read the next `DigitsInferResponse`/`Error` frame, decoded.
    /// Returns the request id and either the digits response or
    /// `(code, message)`.
    #[deprecated(note = "use the typed surface: `FrameClient::call` + `wait`")]
    #[allow(clippy::type_complexity)]
    pub fn next_digits_result(
        &mut self,
    ) -> Result<Option<(u64, std::result::Result<WireDigitsResponse, (u16, String)>)>> {
        match self.next_frame()? {
            None => Ok(None),
            Some(f) => match f.payload_type {
                PayloadType::DigitsInferResponse => {
                    let r = decode_digits_response(&f.payload).map_err(anyhow::Error::from)?;
                    Ok(Some((f.request_id, Ok(r))))
                }
                PayloadType::Error => {
                    let e = decode_error(&f.payload).map_err(anyhow::Error::from)?;
                    Ok(Some((f.request_id, Err(e))))
                }
                other => anyhow::bail!("unexpected frame type {other:?} mid-stream"),
            },
        }
    }

    /// Half-close the write side so the server sees EOF and drains.
    pub fn finish_writes(&self) -> Result<()> {
        self.w.shutdown(std::net::Shutdown::Write)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_request_payload_roundtrip() {
        let ids = vec![0i64, 3, 19, 7];
        let p = encode_infer_request(&ids).unwrap();
        assert_eq!(p.len(), 2 + 4 * ids.len());
        assert_eq!(decode_infer_request(&p).unwrap(), ids);
    }

    #[test]
    fn infer_request_rejects_length_mismatch() {
        let mut p = encode_infer_request(&[1, 2, 3]).unwrap();
        p.pop();
        let e = decode_infer_request(&p).unwrap_err();
        assert_eq!(e.code, ErrorCode::Malformed);
        assert_eq!(decode_infer_request(&[]).unwrap_err().code, ErrorCode::Malformed);
    }

    /// The u16 count-field boundary: exactly 65 535 word ids encode
    /// and round-trip; one more is rejected client-side with
    /// `RequestTooLarge` instead of silently wrapping the count into
    /// a wrong-but-valid frame.
    #[test]
    fn infer_request_boundary_at_u16_count() {
        let max: Vec<i64> = (0..MAX_WORDS_PER_REQUEST as i64).collect();
        let p = encode_infer_request(&max).unwrap();
        assert_eq!(p.len(), 2 + 4 * MAX_WORDS_PER_REQUEST);
        assert_eq!(u16::from_be_bytes([p[0], p[1]]), u16::MAX);
        assert_eq!(decode_infer_request(&p).unwrap().len(), MAX_WORDS_PER_REQUEST);

        let over = vec![0i64; MAX_WORDS_PER_REQUEST + 1];
        let e = encode_infer_request(&over).unwrap_err();
        assert_eq!(e.code, ErrorCode::RequestTooLarge);
    }

    #[test]
    fn digits_request_payload_roundtrip() {
        let pixels: Vec<f32> = (0..12).map(|i| i as f32 * 0.25 - 1.0).collect();
        let p = encode_digits_request(3, 4, &pixels).unwrap();
        assert_eq!(p.len(), 2 + 4 * 12);
        assert_eq!(decode_digits_request(&p).unwrap(), (3, 4, pixels));
    }

    #[test]
    fn digits_request_rejects_bad_shapes() {
        assert_eq!(
            encode_digits_request(0, 4, &[]).unwrap_err().code,
            ErrorCode::EmptyRequest
        );
        let big = vec![0.0f32; 90000];
        assert_eq!(
            encode_digits_request(300, 300, &big).unwrap_err().code,
            ErrorCode::RequestTooLarge
        );
        assert_eq!(
            encode_digits_request(2, 2, &[0.0; 3]).unwrap_err().code,
            ErrorCode::Malformed
        );
        let mut p = encode_digits_request(2, 2, &[0.0; 4]).unwrap();
        p.pop();
        assert_eq!(decode_digits_request(&p).unwrap_err().code, ErrorCode::Malformed);
        assert_eq!(decode_digits_request(&[]).unwrap_err().code, ErrorCode::Malformed);
        assert_eq!(
            decode_digits_request(&[0, 3]).unwrap_err().code,
            ErrorCode::EmptyRequest
        );
    }

    #[test]
    fn digits_response_frame_roundtrip() {
        let r = Response {
            id: 11,
            kind: WorkloadKind::Digits,
            pred: 3,
            v_out: 40,
            v_all: vec![0, -5, 12, 40, 7, -2, 0, 3, 9, 1],
            cycles: 1234,
            latency: Duration::from_micros(99),
            worker: 1,
            batch_size: 4,
            err: None,
            v_digest: None,
            trace: None,
        };
        let f = response_frame(&r);
        assert_eq!(f.payload_type, PayloadType::DigitsInferResponse);
        assert_eq!(f.request_id, 11);
        let w = decode_digits_response(&f.payload).unwrap();
        assert_eq!(
            w,
            WireDigitsResponse {
                pred: 3,
                v_all: r.v_all.clone(),
                cycles: 1234,
                latency_us: 99,
                batch: 4,
                worker: 1
            }
        );
    }

    #[test]
    fn error_payload_roundtrip() {
        let p = error_payload(ErrorCode::EmptyRequest, "no word ids");
        let (code, msg) = decode_error(&p).unwrap();
        assert_eq!(code, ErrorCode::EmptyRequest.as_u16());
        assert_eq!(msg, "no word ids");
    }

    #[test]
    fn negotiation_picks_v1_or_refuses() {
        assert_eq!(negotiate(&hello_payload(1, 1)).unwrap(), Negotiated { version: 1, caps: 0 });
        assert_eq!(negotiate(&hello_payload(1, 9)).unwrap().version, 1);
        let e = negotiate(&hello_payload(2, 9)).unwrap_err();
        assert_eq!(e.code, ErrorCode::UnsupportedVersion);
        assert_eq!(negotiate(&[1]).unwrap_err().code, ErrorCode::Malformed);
        assert_eq!(negotiate(&[1, 1, 0, 0]).unwrap_err().code, ErrorCode::Malformed);
        assert_eq!(negotiate(&hello_payload(3, 1)).unwrap_err().code, ErrorCode::Malformed);
    }

    #[test]
    fn negotiation_grants_only_supported_caps() {
        // a plain v1 hello grants nothing
        assert_eq!(negotiate(&hello_payload(1, 1)).unwrap().caps, 0);
        // requested unknown bits are masked off, never granted
        let n = negotiate(&hello_caps_payload(1, 1, 0xFF)).unwrap();
        assert_eq!(n, Negotiated { version: 1, caps: SUPPORTED_CAPS });
        assert_eq!(negotiate(&hello_caps_payload(1, 1, 0)).unwrap().caps, 0);
        assert_eq!(
            negotiate(&hello_caps_payload(1, 1, CAP_BACKPRESSURE)).unwrap().caps,
            CAP_BACKPRESSURE
        );
        // version rules are unchanged by the caps byte
        let e = negotiate(&hello_caps_payload(2, 9, CAP_BACKPRESSURE)).unwrap_err();
        assert_eq!(e.code, ErrorCode::UnsupportedVersion);
    }

    #[test]
    fn stats_payload_roundtrips() {
        use crate::telemetry::N_LATENCY_BUCKETS;
        let snap = StatsSnapshot {
            queue_depth: 3,
            queue_soft_limit: 1024,
            soft_limited: false,
            batches: 7,
            batch_lanes: 19,
            batch_lane_capacity: 91,
            kinds: vec![
                KindStats {
                    submitted: 20,
                    ok: 18,
                    err: 2,
                    cycles: 123_456,
                    energy_fj: 987_654,
                    edp_js: 3.25e-12,
                    input_units: 400,
                    input_active: 110,
                    ..KindStats::zero(WorkloadKind::Sentiment)
                },
                KindStats::zero(WorkloadKind::Digits),
            ],
            instr: vec![(0, 5000), (2, 800), (6, 0)],
            transports: vec![
                TransportStats {
                    transport: Transport::Tcp,
                    count: 20,
                    sum_us: 40_000,
                    buckets: vec![1; N_LATENCY_BUCKETS],
                },
                TransportStats {
                    transport: Transport::Stdio,
                    count: 0,
                    sum_us: 0,
                    buckets: vec![0; N_LATENCY_BUCKETS],
                },
            ],
        };
        let p = encode_stats_response(&snap);
        assert_eq!(decode_stats_response(&p).unwrap(), snap);
        assert!(encode_stats_request().is_empty());
    }

    #[test]
    fn stats_payload_rejects_malformed_inputs() {
        let snap = StatsSnapshot {
            queue_depth: 0,
            queue_soft_limit: 0,
            soft_limited: true,
            batches: 0,
            batch_lanes: 0,
            batch_lane_capacity: 0,
            kinds: vec![],
            instr: vec![],
            transports: vec![],
        };
        let p = encode_stats_response(&snap);
        // truncation anywhere is Malformed
        for cut in 0..p.len() {
            assert_eq!(
                decode_stats_response(&p[..cut]).unwrap_err().code,
                ErrorCode::Malformed,
                "cut {cut}"
            );
        }
        // trailing garbage is Malformed
        let mut long = p.clone();
        long.push(0);
        assert_eq!(decode_stats_response(&long).unwrap_err().code, ErrorCode::Malformed);
        // an unknown stats version is Malformed
        let mut vers = p.clone();
        vers[0] = 9;
        assert_eq!(decode_stats_response(&vers).unwrap_err().code, ErrorCode::Malformed);
        // an unknown workload-kind code is Malformed
        let mut bad = encode_stats_response(&StatsSnapshot {
            kinds: vec![KindStats::zero(WorkloadKind::Sentiment)],
            ..snap
        });
        bad[44] = 99; // the kind code of the first row
        assert_eq!(decode_stats_response(&bad).unwrap_err().code, ErrorCode::Malformed);
    }

    #[test]
    fn response_frame_encodes_success_and_error() {
        let ok = Response {
            id: 4,
            kind: WorkloadKind::Sentiment,
            pred: 1,
            v_out: -17,
            v_all: vec![-17],
            cycles: 42,
            latency: Duration::from_micros(181),
            worker: 2,
            batch_size: 3,
            err: None,
            v_digest: None,
            trace: None,
        };
        let f = response_frame(&ok);
        assert_eq!(f.payload_type, PayloadType::InferResponse);
        assert_eq!(f.request_id, 4);
        let w = decode_infer_response(&f.payload).unwrap();
        assert_eq!(
            w,
            WireResponse {
                pred: 1,
                v_out: -17,
                cycles: 42,
                latency_us: 181,
                batch: 3,
                worker: 2
            }
        );

        let bad = Response { err: Some("word id out of range".into()), ..ok };
        let f = response_frame(&bad);
        assert_eq!(f.payload_type, PayloadType::Error);
        let (code, msg) = decode_error(&f.payload).unwrap();
        assert_eq!(code, ErrorCode::InferenceFailed.as_u16());
        assert!(msg.contains("out of range"));
    }

    #[test]
    fn stream_payloads_roundtrip() {
        let p = encode_stream_append(7, &WorkloadInput::Words(vec![1, 2, 3])).unwrap();
        assert_eq!(p.len(), 8 + 1 + 2 + 4 * 3);
        let (sid, chunk) = decode_stream_append(&p).unwrap();
        assert_eq!(sid, 7);
        assert_eq!(chunk, WorkloadInput::Words(vec![1, 2, 3]));

        let img = WorkloadInput::Image { h: 2, w: 2, pixels: vec![0.0, 0.5, -1.0, 2.0] };
        let p = encode_stream_append(u64::MAX, &img).unwrap();
        let (sid, chunk) = decode_stream_append(&p).unwrap();
        assert_eq!(sid, u64::MAX);
        assert_eq!(chunk, img);

        assert_eq!(decode_stream_ref(&encode_stream_ref(42)).unwrap(), 42);
        let a = WireStreamAck { op: STREAM_OP_APPEND, stream_id: 9, lane: 3, cycles: 1234 };
        assert_eq!(decode_stream_ack(&encode_stream_ack(&a)).unwrap(), a);
    }

    #[test]
    fn stream_payloads_reject_malformed() {
        assert_eq!(decode_stream_append(&[0; 8]).unwrap_err().code, ErrorCode::Malformed);
        let mut p = encode_stream_append(1, &WorkloadInput::Words(vec![5])).unwrap();
        p[8] = 9; // unknown chunk kind
        assert_eq!(decode_stream_append(&p).unwrap_err().code, ErrorCode::Malformed);
        assert_eq!(decode_stream_ref(&[0; 7]).unwrap_err().code, ErrorCode::Malformed);
        assert_eq!(decode_stream_ack(&[0; 18]).unwrap_err().code, ErrorCode::Malformed);
        let bad_op = WireStreamAck { op: 9, stream_id: 0, lane: 0, cycles: 0 };
        assert_eq!(
            decode_stream_ack(&encode_stream_ack(&bad_op)).unwrap_err().code,
            ErrorCode::Malformed
        );
        assert!(StreamOpenPayload::decode(&[]).is_ok());
        assert_eq!(StreamOpenPayload::decode(&[0]).unwrap_err().code, ErrorCode::Malformed);
    }

    /// The `WirePayload` impls must be byte-identical to the free
    /// functions the pinned-hex frame_codec tests exercise.
    #[test]
    fn wire_payload_trait_matches_free_functions() {
        let ids = vec![1i64, 2, 3];
        assert_eq!(
            WordsPayload(ids.clone()).encode().unwrap(),
            encode_infer_request(&ids).unwrap()
        );
        assert_eq!(WordsPayload::decode(&encode_infer_request(&ids).unwrap()).unwrap().0, ids);

        let pixels = vec![0.25f32; 4];
        assert_eq!(
            ImagePayload { h: 2, w: 2, pixels: pixels.clone() }.encode().unwrap(),
            encode_digits_request(2, 2, &pixels).unwrap()
        );

        let f = StreamOpenPayload.frame(5).unwrap();
        assert_eq!(f.payload_type, PayloadType::StreamOpen);
        assert_eq!(f.request_id, 5);
        assert!(f.payload.is_empty());

        let e = ServerError { code: ErrorCode::StreamExpired.as_u16(), msg: "gone".into() };
        assert_eq!(e.encode().unwrap(), error_payload(ErrorCode::StreamExpired, "gone"));
        assert_eq!(ServerError::decode(&e.encode().unwrap()).unwrap(), e);
        assert_eq!(e.error_code(), Some(ErrorCode::StreamExpired));

        let ack = WireStreamAck { op: STREAM_OP_OPEN, stream_id: 1, lane: 0, cycles: 0 };
        assert_eq!(ack.frame(1).unwrap().payload_type, PayloadType::StreamAck);
    }

    #[test]
    fn trace_echo_trailer_roundtrips_and_gates_on_flags() {
        use super::super::frame::{FLAG_TELEMETRY, FLAG_TRACE_ECHO};
        use crate::obs::trace::TraceSummary;

        let ok = Response {
            id: 4,
            kind: WorkloadKind::Sentiment,
            pred: 1,
            v_out: -17,
            v_all: vec![-17],
            cycles: 42,
            latency: Duration::from_micros(181),
            worker: 2,
            batch_size: 3,
            err: None,
            v_digest: None,
            trace: None,
        };
        let summary = TraceSummary {
            trace_id: 9,
            decode_us: 5,
            queue_us: 120,
            batch_us: 40,
            execute_us: 800,
            echo: true,
        };
        let mut f = response_frame(&ok);
        let body_len = f.payload.len();
        let bits = attach_trace_echo(&mut f, &summary);
        assert_eq!(bits, FLAG_TELEMETRY | FLAG_TRACE_ECHO);
        assert_eq!(f.payload.len(), body_len + TRACE_ECHO_LEN);
        let f = f.with_flags(bits);

        let (body, echo) = split_trace_echo(f.flags, &f.payload);
        assert_eq!(body.len(), body_len);
        assert_eq!(
            echo,
            Some(TraceEcho { decode_us: 5, queue_us: 120, batch_us: 40, execute_us: 800 })
        );
        // the stripped body still decodes as a plain response
        assert_eq!(decode_infer_response(body).unwrap().cycles, 42);
        // and the typed decode path strips it too
        let (out, echo2) = decode_output_traced(&f).unwrap();
        assert_eq!(out.cycles, 42);
        assert_eq!(echo2, echo);

        // without the flag the payload passes through untouched, even
        // if it happens to be ≥ 16 bytes
        let (body, none) = split_trace_echo(0, &f.payload);
        assert_eq!(body.len(), f.payload.len());
        assert_eq!(none, None);
        // a backpressure-only flags word does not strip either
        let bp = super::super::frame::encode_backpressure(3, true);
        assert_eq!(split_trace_echo(bp, &f.payload).1, None);

        // error frames never gain a trailer
        let bad = Response { err: Some("boom".into()), ..ok };
        let mut ef = response_frame(&bad);
        let elen = ef.payload.len();
        assert_eq!(attach_trace_echo(&mut ef, &summary), 0);
        assert_eq!(ef.payload.len(), elen);
    }

    #[test]
    fn trace_echo_cap_is_granted_and_masked() {
        assert_eq!(SUPPORTED_CAPS, CAP_BACKPRESSURE | CAP_TRACE_ECHO);
        assert_eq!(
            negotiate(&hello_caps_payload(1, 1, CAP_TRACE_ECHO)).unwrap().caps,
            CAP_TRACE_ECHO
        );
        assert_eq!(
            negotiate(&hello_caps_payload(1, 1, CAP_BACKPRESSURE)).unwrap().caps,
            CAP_BACKPRESSURE
        );
    }

    #[test]
    fn pacer_backs_off_and_recovers() {
        use super::super::frame::encode_backpressure;
        let mut p = Pacer::new(Duration::from_millis(1), Duration::from_millis(8));
        assert!(p.delay().is_zero());
        // frames without a backpressure advertisement leave it alone
        p.observe(0);
        assert!(p.delay().is_zero());
        let limited = encode_backpressure(3, true);
        let clear = encode_backpressure(0, false);
        p.observe(limited);
        assert_eq!(p.delay(), Duration::from_millis(1));
        p.observe(limited);
        assert_eq!(p.delay(), Duration::from_millis(2));
        for _ in 0..10 {
            p.observe(limited);
        }
        assert_eq!(p.delay(), Duration::from_millis(8)); // capped at max
        p.observe(clear);
        assert_eq!(p.delay(), Duration::from_millis(4)); // decays
        for _ in 0..30 {
            p.observe(clear);
        }
        assert!(p.delay().is_zero());
    }
}
