//! Multi-client TCP listener for the framed protocol.
//!
//! Each accepted connection gets its own [`ClientSession`] over the
//! shared [`ServeCore`], a reader thread (this connection's spawned
//! thread) that decodes request frames and submits them, and a
//! responder thread that streams responses back as they complete —
//! so a client waiting on one answer never blocks the server from
//! delivering it, and slow clients never stall other connections.
//!
//! Framing errors (bad magic, bad CRC, truncation) are answered with
//! one `Error` frame and a close: once byte alignment is lost the
//! stream cannot be resynchronized. Request-level errors (malformed
//! payload, empty request, inference failure) are answered per
//! request id and the connection stays up.
//!
//! [`ClientSession`]: super::ClientSession

use super::frame::{
    encode_backpressure, ErrorCode, Frame, FrameReader, PayloadType, WireError, FLAG_TRACE_ECHO,
};
use super::session::{
    attach_trace_echo, decode_digits_request, decode_infer_request, decode_stream_append,
    decode_stream_ref, encode_stats_response, encode_stream_ack, error_frame, negotiate,
    response_frame, ServeCore, WireDigitsResponse, WirePayload, WireResponse, CAP_BACKPRESSURE,
    CAP_TRACE_ECHO,
};
use crate::coordinator::{WorkloadInput, WorkloadKind};
use crate::obs::trace::{elapsed_us, Phase, Span, TraceCtx, TraceRecorder};
use crate::replay::{Recorder, TapRead};
use crate::telemetry::{Telemetry, Transport};
use crate::Result;
use std::io::ErrorKind;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// How long blocking reads and response waits poll before rechecking
/// stop/drain conditions.
const POLL: Duration = Duration::from_millis(50);

/// Upper bound on one blocking socket write. Without it a client that
/// stops reading (full kernel send buffer) wedges its responder thread
/// in `write_all` forever — and with it the connection join, the
/// accept-loop join, and the graceful SIGINT/SIGTERM drain. A client
/// that cannot absorb a frame within this window is treated as dead
/// and its connection torn down; slow-but-draining clients are fine
/// (the timeout applies per write, not per connection).
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// A running TCP serving front-end (accept loop + connections).
pub struct TcpServeHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl TcpServeHandle {
    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the accept loop and all connections to wind down, then
    /// join them. In-flight requests still get their responses.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Block until the accept loop exits (i.e. serve until the
    /// process is killed or the listener fails).
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Whether the accept loop has already exited (e.g. the listener
    /// failed) — lets a supervisor poll without blocking, as the CLI's
    /// signal-driven shutdown loop does.
    pub fn is_finished(&self) -> bool {
        self.accept.as_ref().map(|h| h.is_finished()).unwrap_or(true)
    }
}

/// Bind `addr` (e.g. `127.0.0.1:7878`, or port `0` for an ephemeral
/// port) and serve framed requests over the shared core.
pub fn serve_tcp(addr: &str, core: Arc<ServeCore>) -> Result<TcpServeHandle> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let accept = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            loop {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        if stream.set_nonblocking(false).is_err() {
                            continue;
                        }
                        let core = Arc::clone(&core);
                        let stop = Arc::clone(&stop);
                        conns.push(std::thread::spawn(move || {
                            if let Err(e) = handle_conn(stream, &core, &stop) {
                                crate::error!("serve", "connection error: {e:#}");
                            }
                        }));
                        conns.retain(|h| !h.is_finished());
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        // idle tick: reap streaming sessions whose
                        // clients vanished without closing
                        core.streams().sweep();
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    Err(e) => {
                        crate::error!("serve", "accept failed: {e}");
                        break;
                    }
                }
            }
            for c in conns {
                let _ = c.join();
            }
            // final sweep so a stop/drain never strands pinned lanes
            core.streams().sweep();
        })
    };
    Ok(TcpServeHandle { addr: local, stop, accept: Some(accept) })
}

/// The shared write half of one connection. The reader and responder
/// threads both reply; the mutex keeps frames contiguous on the wire.
/// When a [`Recorder`] is attached the encoded frame is recorded
/// *inside* the lock, so capture order is exactly wire order.
#[derive(Clone)]
struct ConnWriter {
    stream: Arc<Mutex<TcpStream>>,
    tap: Option<(Arc<Recorder>, u64)>,
    /// Span recorder + this connection's id, for write spans (lock
    /// wait included — writer-lock contention is part of the phase).
    trace: Option<(Arc<TraceRecorder>, u64)>,
}

impl ConnWriter {
    fn write(&self, f: &Frame) -> std::io::Result<()> {
        self.write_inner(f, None)
    }

    /// Write a response frame, recording the write span under the
    /// request's `trace_id` when tracing is on.
    fn write_traced(&self, f: &Frame, trace_id: u64) -> std::io::Result<()> {
        self.write_inner(f, Some(trace_id))
    }

    fn write_inner(&self, f: &Frame, span: Option<u64>) -> std::io::Result<()> {
        use std::io::Write;
        let bytes = f.encode();
        let t0 = if self.trace.is_some() && span.is_some() { Some(Instant::now()) } else { None };
        let mut g = self.stream.lock().expect("writer poisoned");
        if let Some((rec, conn)) = &self.tap {
            rec.frame_out(*conn, &bytes);
        }
        let res = g.write_all(&bytes);
        drop(g);
        if let (Some((tr, conn)), Some(trace_id), Some(t0)) = (&self.trace, span, t0) {
            tr.record(
                Span::new(
                    Phase::Write,
                    trace_id,
                    f.request_id,
                    *conn,
                    tr.us_of(t0),
                    elapsed_us(t0),
                )
                .with_ok(res.is_ok()),
            );
        }
        res
    }

    fn shutdown_write(&self) {
        if let Ok(g) = self.stream.lock() {
            let _ = g.shutdown(Shutdown::Write);
        }
    }
}

/// Write one reader-side frame (acks and inline errors) through the
/// shared writer; these are not response frames, so no write span.
fn write_frame(writer: &ConnWriter, f: &Frame) -> std::io::Result<()> {
    writer.write(f)
}

/// The flags word for the next server→client frame: a live
/// backpressure advertisement when the client negotiated
/// [`CAP_BACKPRESSURE`], the all-zero v1 word otherwise.
fn frame_flags(bp: &AtomicBool, tele: &Telemetry) -> u16 {
    if bp.load(Ordering::Relaxed) {
        encode_backpressure(tele.queue_depth(), tele.soft_limited())
    } else {
        0
    }
}

/// Drive one connection to completion: read frames until EOF, a
/// framing error, or server stop; then drain outstanding responses.
fn handle_conn(stream: TcpStream, core: &ServeCore, stop: &Arc<AtomicBool>) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(POLL))?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    let (sender, responses) = core.client()?.split();
    // stream ids are per-connection: take a connection id for scoping
    let conn_id = core.next_conn_id();
    // record/replay tap (docs/REPLAY.md): inbound bytes below the
    // decoder, outbound frames under the write lock, V-digests per
    // answered request — all keyed by this connection id
    let recorder = core.recorder().map(|r| (r, conn_id));
    // per-request lifecycle tracing (docs/OBSERVABILITY.md): decode
    // spans are recorded here in the reader, write spans in the
    // responder via the shared writer
    let trace = core.trace().cloned();
    let writer = ConnWriter {
        stream: Arc::new(Mutex::new(stream.try_clone()?)),
        tap: recorder.clone(),
        trace: trace.clone().map(|t| (t, conn_id)),
    };
    let done = Arc::new(AtomicBool::new(false));
    let outstanding = Arc::new(AtomicU64::new(0));
    let tele = Arc::clone(core.telemetry());
    // whether this client negotiated backpressure advertisements
    // (reader sets it on an extended Hello; responder stamps flags)
    let backpressure = Arc::new(AtomicBool::new(false));

    let responder = {
        let writer = writer.clone();
        let done = Arc::clone(&done);
        let outstanding = Arc::clone(&outstanding);
        let tele = Arc::clone(&tele);
        let backpressure = Arc::clone(&backpressure);
        let recorder = recorder.clone();
        std::thread::spawn(move || {
            loop {
                match responses.recv_timeout(POLL) {
                    Ok(r) => {
                        outstanding.fetch_sub(1, Ordering::SeqCst);
                        tele.record_wire(Transport::Tcp, r.latency);
                        if let (Some((rec, conn)), Some(d)) = (&recorder, r.v_digest) {
                            rec.digest(*conn, r.id, d);
                        }
                        let mut f = response_frame(&r);
                        let mut flags = frame_flags(&backpressure, &tele);
                        if let Some(s) = r.trace.as_ref().filter(|s| s.echo) {
                            flags |= attach_trace_echo(&mut f, s);
                        }
                        let f = f.with_flags(flags);
                        let wrote = match r.trace.as_ref() {
                            Some(s) => writer.write_traced(&f, s.trace_id),
                            None => writer.write(&f),
                        };
                        if wrote.is_err() {
                            break;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        // Exit only once the reader is finished AND
                        // every accepted request has been answered —
                        // a server stop must not drop in-flight
                        // responses (the reader exits on stop, which
                        // sets `done`; the core drains before its own
                        // shutdown completes).
                        if done.load(Ordering::SeqCst)
                            && outstanding.load(Ordering::SeqCst) == 0
                        {
                            break;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        })
    };

    // the tap reads *below* the frame decoder: malformed or fuzzed
    // traffic is captured verbatim, exactly as it arrived
    let mut reader = FrameReader::new(TapRead::new(stream, recorder.clone()));
    let mut negotiated = super::frame::PROTOCOL_VERSION; // implicit v1 until Hello
    // whether this client negotiated the trace-echo capability (only
    // the reader consults it, so no cross-thread sharing needed)
    let mut trace_echo_cap = false;
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let frame = match reader.next_frame() {
            Ok(Some(f)) => f,
            Ok(None) => break, // clean EOF
            Err(WireError::Io(e))
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                continue
            }
            Err(e) => {
                // Alignment is lost; answer once (request id 0) and close.
                let _ = writer.write(&error_frame(0, e.code(), &e.to_string()));
                break;
            }
        };
        match frame.payload_type {
            PayloadType::Hello => match negotiate(&frame.payload) {
                Ok(n) => {
                    negotiated = n.version;
                    backpressure.store(n.caps & CAP_BACKPRESSURE != 0, Ordering::Relaxed);
                    trace_echo_cap = n.caps & CAP_TRACE_ECHO != 0;
                    // a 2-byte v1 hello gets the pinned 1-byte ack; an
                    // extended hello gets [version, granted caps]
                    let ack_payload = if frame.payload.len() == 3 {
                        vec![n.version, n.caps]
                    } else {
                        vec![n.version]
                    };
                    let ack = Frame::new(PayloadType::HelloAck, frame.request_id, ack_payload);
                    if writer.write(&ack).is_err() {
                        break;
                    }
                }
                Err(e) => {
                    let _ = writer.write(&error_frame(frame.request_id, e.code, &e.msg));
                    break; // failed negotiation closes the connection
                }
            },
            PayloadType::StatsRequest => {
                if frame.version != negotiated {
                    let msg = format!(
                        "frame version {} after negotiating v{negotiated}",
                        frame.version
                    );
                    let _ = write_frame(
                        &writer,
                        &error_frame(frame.request_id, ErrorCode::UnsupportedVersion, &msg),
                    );
                    continue;
                }
                if !frame.payload.is_empty() {
                    let _ = write_frame(
                        &writer,
                        &error_frame(
                            frame.request_id,
                            ErrorCode::Malformed,
                            "stats request payload must be empty",
                        ),
                    );
                    continue;
                }
                // answered inline from the registry — never queued, so
                // stats stay responsive under full inference backlog
                let f = Frame::new(
                    PayloadType::StatsResponse,
                    frame.request_id,
                    encode_stats_response(&tele.snapshot()),
                )
                .with_flags(frame_flags(&backpressure, &tele));
                if writer.write(&f).is_err() {
                    break;
                }
            }
            PayloadType::InferRequest | PayloadType::DigitsInferRequest => {
                if frame.version != negotiated {
                    let msg = format!(
                        "frame version {} after negotiating v{negotiated}",
                        frame.version
                    );
                    let _ = write_frame(
                        &writer,
                        &error_frame(frame.request_id, ErrorCode::UnsupportedVersion, &msg),
                    );
                    continue;
                }
                // decode per payload type into the workload-tagged input
                let t_dec = trace.as_deref().map(|_| Instant::now());
                let input = match frame.payload_type {
                    PayloadType::InferRequest => match decode_infer_request(&frame.payload) {
                        Ok(ids) if ids.is_empty() => {
                            let _ = write_frame(
                                &writer,
                                &error_frame(
                                    frame.request_id,
                                    ErrorCode::EmptyRequest,
                                    "no word ids",
                                ),
                            );
                            continue;
                        }
                        Ok(ids) => WorkloadInput::Words(ids),
                        Err(e) => {
                            let _ = write_frame(
                                &writer,
                                &error_frame(frame.request_id, e.code, &e.msg),
                            );
                            continue;
                        }
                    },
                    _ => match decode_digits_request(&frame.payload) {
                        Ok((h, w, pixels)) => WorkloadInput::Image { h, w, pixels },
                        Err(e) => {
                            let _ = write_frame(
                                &writer,
                                &error_frame(frame.request_id, e.code, &e.msg),
                            );
                            continue;
                        }
                    },
                };
                // decode span: payload decode only — socket wait is
                // idle time, not part of any request's lifecycle
                let ctx = match (trace.as_deref(), t_dec) {
                    (Some(tr), Some(t_dec)) => {
                        let trace_id = tr.next_trace_id();
                        let decode_us = elapsed_us(t_dec);
                        tr.record(Span::new(
                            Phase::Decode,
                            trace_id,
                            frame.request_id,
                            conn_id,
                            tr.us_of(t_dec),
                            decode_us,
                        ));
                        Some(TraceCtx {
                            trace_id,
                            conn: conn_id,
                            request_id: frame.request_id,
                            decode_us,
                            echo: trace_echo_cap && frame.flags & FLAG_TRACE_ECHO != 0,
                        })
                    }
                    _ => None,
                };
                // count before submitting: the response may land (and
                // be decremented by the responder) the instant submit
                // returns
                outstanding.fetch_add(1, Ordering::SeqCst);
                match sender.submit_input_traced(frame.request_id, input, ctx) {
                    Ok(()) => {}
                    Err(e) => {
                        outstanding.fetch_sub(1, Ordering::SeqCst);
                        let _ = write_frame(
                            &writer,
                            &error_frame(
                                frame.request_id,
                                ErrorCode::Internal,
                                &format!("{e:#}"),
                            ),
                        );
                        break; // core is shutting down
                    }
                }
            }
            PayloadType::StreamOpen
            | PayloadType::StreamAppend
            | PayloadType::StreamReadOut
            | PayloadType::StreamClose => {
                if frame.version != negotiated {
                    let msg = format!(
                        "frame version {} after negotiating v{negotiated}",
                        frame.version
                    );
                    let _ = write_frame(
                        &writer,
                        &error_frame(frame.request_id, ErrorCode::UnsupportedVersion, &msg),
                    );
                    continue;
                }
                // stream ops bypass the batcher queue (a chunk must
                // integrate into *its* pinned lane) and are answered
                // inline; errors keep the connection up
                let answer = stream_op(core, conn_id, &frame, &tele, recorder.as_ref())
                    .with_flags(frame_flags(&backpressure, &tele));
                if writer.write(&answer).is_err() {
                    break;
                }
            }
            // Server→client types are invalid from a client.
            PayloadType::HelloAck
            | PayloadType::InferResponse
            | PayloadType::DigitsInferResponse
            | PayloadType::StatsResponse
            | PayloadType::StreamAck
            | PayloadType::Error => {
                let _ = write_frame(
                    &writer,
                    &error_frame(
                        frame.request_id,
                        ErrorCode::Malformed,
                        &format!("{:?} frames are server-to-client only", frame.payload_type),
                    ),
                );
            }
        }
    }
    done.store(true, Ordering::SeqCst);
    drop(sender); // release the submission handle before draining
    let _ = responder.join();
    // a vanished connection releases its pinned lanes immediately —
    // no stream outlives its transport
    core.streams().close_conn(conn_id);
    writer.shutdown_write();
    Ok(())
}

/// Answer one stream-payload frame inline against the core's stream
/// table, scoped to this connection's id. Always produces exactly one
/// frame (a `StreamAck`, a read-out response, or an `Error`); stream
/// errors keep the connection up — only this stream dies.
///
/// With a recorder attached, every *successful* open/append/read-out
/// also checkpoints the pinned lane's V-digest under the frame's
/// request id (close frees the lane, so there is nothing to digest).
fn stream_op(
    core: &ServeCore,
    conn_id: u64,
    frame: &Frame,
    tele: &Telemetry,
    rec: Option<&(Arc<Recorder>, u64)>,
) -> Frame {
    let id = frame.request_id;
    let streams = core.streams();
    let checkpoint = |sid: u64| {
        if let Some((rec, conn)) = rec {
            if let Some(d) = streams.v_digest(conn_id, sid) {
                rec.digest(*conn, id, d);
            }
        }
    };
    match frame.payload_type {
        PayloadType::StreamOpen => {
            if !frame.payload.is_empty() {
                return error_frame(id, ErrorCode::Malformed, "stream open payload must be empty");
            }
            // the open frame's request id becomes the stream id
            match streams.open(conn_id, id) {
                Ok(ack) => {
                    checkpoint(id);
                    Frame::new(PayloadType::StreamAck, id, encode_stream_ack(&ack))
                }
                Err(e) => error_frame(id, e.code, &e.msg),
            }
        }
        PayloadType::StreamAppend => {
            let (sid, chunk) = match decode_stream_append(&frame.payload) {
                Ok(v) => v,
                Err(e) => return error_frame(id, e.code, &e.msg),
            };
            let t0 = Instant::now();
            match streams.append(conn_id, sid, &chunk) {
                Ok(ack) => {
                    tele.record_wire(Transport::Tcp, t0.elapsed());
                    checkpoint(sid);
                    Frame::new(PayloadType::StreamAck, id, encode_stream_ack(&ack))
                }
                Err(e) => error_frame(id, e.code, &e.msg),
            }
        }
        PayloadType::StreamReadOut => {
            let sid = match decode_stream_ref(&frame.payload) {
                Ok(v) => v,
                Err(e) => return error_frame(id, e.code, &e.msg),
            };
            let t0 = Instant::now();
            match streams.read_out(conn_id, sid) {
                Ok((out, kind, _lane)) => {
                    let latency = t0.elapsed();
                    tele.record_wire(Transport::Tcp, latency);
                    checkpoint(sid);
                    let latency_us = u64::try_from(latency.as_micros()).unwrap_or(u64::MAX);
                    // a read-out answers in the one-shot response
                    // encoding for its kind: stream-unaware tooling
                    // can decode it
                    match kind {
                        WorkloadKind::Sentiment => WireResponse {
                            pred: out.pred,
                            v_out: out.v_out,
                            cycles: out.cycles,
                            latency_us,
                            batch: 1,
                            worker: 0,
                        }
                        .frame(id),
                        WorkloadKind::Digits => WireDigitsResponse {
                            pred: out.pred,
                            v_all: out.v_all,
                            cycles: out.cycles,
                            latency_us,
                            batch: 1,
                            worker: 0,
                        }
                        .frame(id),
                    }
                    .expect("stream read-out response encoding is infallible")
                }
                Err(e) => error_frame(id, e.code, &e.msg),
            }
        }
        PayloadType::StreamClose => {
            let sid = match decode_stream_ref(&frame.payload) {
                Ok(v) => v,
                Err(e) => return error_frame(id, e.code, &e.msg),
            };
            match streams.close(conn_id, sid) {
                Ok(ack) => Frame::new(PayloadType::StreamAck, id, encode_stream_ack(&ack)),
                Err(e) => error_frame(id, e.code, &e.msg),
            }
        }
        _ => error_frame(id, ErrorCode::Internal, "not a stream payload"),
    }
}
