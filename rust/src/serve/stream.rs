//! The server-side stream session table: membrane state pinned to a
//! client stream id.
//!
//! A one-shot request carries its whole input and the lane's membrane
//! potentials die with the response. A *stream* keeps them alive: the
//! client opens a session, appends input chunks as they arrive (words
//! for sentiment, image frames for digits), reads predictions out
//! mid-stream, and closes when done. Between appends the session's
//! engine — and with it every layer's VMEM contents — stays pinned in
//! this table, keyed by `(connection id, stream id)`.
//!
//! Design points:
//!
//! - **One engine per live stream.** Streaming engines are stateful,
//!   so a stream owns an engine *lane* exclusively until it closes or
//!   expires. Closed lanes keep their engine pooled for the next open
//!   ([`Workload::begin_stream`] fully resets it), so steady-state
//!   traffic never rebuilds a network.
//! - **Appends compute under the table lock.** Stream traffic bypasses
//!   the batcher queue (chunks must integrate into *this* lane's
//!   membrane, not any free lane), and a per-chunk step is micro-
//!   seconds of SWAR work — a mutex hold that short beats per-stream
//!   worker threads. Telemetry's queue-depth gauge is untouched for
//!   the same reason: stream ops never enter the queue.
//! - **Eviction is lazy plus swept.** Every table op first evicts
//!   sessions idle past the TTL, and the TCP accept loop calls
//!   [`StreamTable::sweep`] on its idle ticks so abandoned sessions
//!   are reaped even when no other client is talking — including
//!   during a SIGTERM drain. A capped session count bounds pinned
//!   memory; opens past the cap are rejected with
//!   [`ErrorCode::StreamLimit`].

use super::frame::ErrorCode;
use super::session::{WireStreamAck, STREAM_OP_APPEND, STREAM_OP_CLOSE, STREAM_OP_OPEN};
use crate::coordinator::{Workload, WorkloadInput, WorkloadKind, WorkloadOutput};
use crate::obs::trace::{elapsed_us, Phase, Span, TraceRecorder};
use crate::telemetry::Telemetry;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Builds a fresh streaming engine for a lane (the serve core wraps
/// its workload factory into this form).
pub type EngineFactory = Box<dyn Fn() -> crate::Result<Box<dyn Workload>> + Send + Sync>;

/// A stream-table operation failure, carrying the wire error code the
/// listener answers with.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamError {
    /// Protocol error code for the `Error` frame.
    pub code: ErrorCode,
    /// Human-readable detail (travels in the error payload).
    pub msg: String,
}

impl StreamError {
    fn new(code: ErrorCode, msg: impl Into<String>) -> StreamError {
        StreamError { code, msg: msg.into() }
    }
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "stream error ({:?}): {}", self.code, self.msg)
    }
}

impl std::error::Error for StreamError {}

/// The live half of a lane: who owns it and how fresh it is.
struct StreamOwner {
    conn: u64,
    id: u64,
    last_used: Instant,
    appends: u64,
    cycles: u64,
}

/// One engine slot. `engine` survives its owner (pooled for reuse);
/// `owner` is `Some` only while a stream is live on the lane.
struct Lane {
    engine: Option<Box<dyn Workload>>,
    owner: Option<StreamOwner>,
}

struct TableInner {
    lanes: Vec<Lane>,
    /// `(connection id, stream id)` → lane index.
    by_key: HashMap<(u64, u64), usize>,
}

/// The session table a [`ServeCore`](super::ServeCore) owns: every
/// transport connection that speaks the stream payloads routes them
/// here.
pub struct StreamTable {
    inner: Mutex<TableInner>,
    factory: EngineFactory,
    max_streams: usize,
    ttl: Duration,
    vocab: i64,
    telemetry: Arc<Telemetry>,
    trace: Option<Arc<TraceRecorder>>,
}

impl std::fmt::Debug for StreamTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StreamTable")
            .field("max_streams", &self.max_streams)
            .field("ttl", &self.ttl)
            .field("active", &self.active())
            .finish_non_exhaustive()
    }
}

impl StreamTable {
    /// An empty table. `vocab` drives the same word-id clamp the
    /// one-shot submit path applies, so a streamed review sees exactly
    /// the ids a one-shot request of the concatenation would.
    pub fn new(
        factory: EngineFactory,
        max_streams: usize,
        ttl: Duration,
        vocab: i64,
        telemetry: Arc<Telemetry>,
        trace: Option<Arc<TraceRecorder>>,
    ) -> StreamTable {
        StreamTable {
            inner: Mutex::new(TableInner { lanes: Vec::new(), by_key: HashMap::new() }),
            factory,
            max_streams: max_streams.max(1),
            ttl,
            vocab,
            telemetry,
            trace,
        }
    }

    /// Number of live (open, unexpired) streams.
    pub fn active(&self) -> usize {
        self.lock().by_key.len()
    }

    /// Open a stream: claim a lane, reset its engine's membrane state,
    /// and pin it to `(conn, stream_id)`. Fails with
    /// [`ErrorCode::StreamLimit`] at the session cap and
    /// [`ErrorCode::Malformed`] on a duplicate open.
    pub fn open(&self, conn: u64, stream_id: u64) -> Result<WireStreamAck, StreamError> {
        let mut t = self.lock();
        self.sweep_locked(&mut t, Instant::now());
        let key = (conn, stream_id);
        if t.by_key.contains_key(&key) {
            return Err(StreamError::new(
                ErrorCode::Malformed,
                format!("stream {stream_id} is already open on this connection"),
            ));
        }
        if t.by_key.len() >= self.max_streams {
            self.telemetry.record_stream_rejected();
            crate::warn!(
                "stream",
                "rejected stream open conn={conn} stream_id={stream_id} \
                 live={} cap={} reason=cap",
                t.by_key.len(),
                self.max_streams
            );
            return Err(StreamError::new(
                ErrorCode::StreamLimit,
                format!("stream limit reached ({} live sessions)", self.max_streams),
            ));
        }
        let lane = match t.lanes.iter().position(|l| l.owner.is_none()) {
            Some(i) => i,
            None => {
                t.lanes.push(Lane { engine: None, owner: None });
                t.lanes.len() - 1
            }
        };
        if t.lanes[lane].engine.is_none() {
            let engine = (self.factory)().map_err(|e| {
                StreamError::new(ErrorCode::Internal, format!("engine construction failed: {e:#}"))
            })?;
            t.lanes[lane].engine = Some(engine);
        }
        let engine = t.lanes[lane].engine.as_mut().expect("lane engine just ensured");
        engine.begin_stream().map_err(|e| {
            StreamError::new(ErrorCode::Internal, format!("stream begin failed: {e:#}"))
        })?;
        t.lanes[lane].owner = Some(StreamOwner {
            conn,
            id: stream_id,
            last_used: Instant::now(),
            appends: 0,
            cycles: 0,
        });
        t.by_key.insert(key, lane);
        self.telemetry.record_stream_open();
        Ok(WireStreamAck { op: STREAM_OP_OPEN, stream_id, lane: lane as u16, cycles: 0 })
    }

    /// Integrate one chunk into a live stream's pinned membrane state.
    /// The chunk gets the submit path's input normalization (word ids
    /// clamped into `[0, vocab)`); the ack reports the session's
    /// cumulative cycles. A step failure is fatal to the stream: the
    /// lane is evicted (its engine discarded — membrane state is
    /// undefined after a mid-step error).
    pub fn append(
        &self,
        conn: u64,
        stream_id: u64,
        chunk: &WorkloadInput,
    ) -> Result<WireStreamAck, StreamError> {
        let t0 = self.trace.as_deref().map(|_| Instant::now());
        let chunk = self.normalize(chunk);
        let mut t = self.lock();
        self.sweep_locked(&mut t, Instant::now());
        let key = (conn, stream_id);
        let lane = *t.by_key.get(&key).ok_or_else(|| expired(stream_id))?;
        let engine = t.lanes[lane].engine.as_mut().expect("live lane has an engine");
        let cycles = match engine.step_stream(&chunk) {
            Ok(c) => c,
            Err(e) => {
                t.lanes[lane].engine = None;
                t.lanes[lane].owner = None;
                t.by_key.remove(&key);
                self.telemetry.record_stream_closed();
                self.record_append_span(conn, stream_id, t0, 0, false);
                return Err(StreamError::new(
                    ErrorCode::InferenceFailed,
                    format!("stream append failed: {e:#}"),
                ));
            }
        };
        let owner = t.lanes[lane].owner.as_mut().expect("live lane has an owner");
        owner.last_used = Instant::now();
        owner.appends += 1;
        owner.cycles = cycles;
        self.telemetry.record_stream_append();
        self.telemetry.record_input(&chunk);
        drop(t);
        self.record_append_span(conn, stream_id, t0, cycles, true);
        Ok(WireStreamAck { op: STREAM_OP_APPEND, stream_id, lane: lane as u16, cycles })
    }

    /// Record one stream-append span (`request_id` = the stream id,
    /// `cycles` = the session's cumulative cycles at ack time). A
    /// no-op when tracing is off.
    fn record_append_span(
        &self,
        conn: u64,
        stream_id: u64,
        t0: Option<Instant>,
        cycles: u64,
        ok: bool,
    ) {
        if let (Some(tr), Some(t0)) = (self.trace.as_deref(), t0) {
            tr.record(
                Span::new(
                    Phase::StreamAppend,
                    tr.next_trace_id(),
                    stream_id,
                    conn,
                    tr.us_of(t0),
                    elapsed_us(t0),
                )
                .with_cost(cycles, 0)
                .with_ok(ok),
            );
        }
    }

    /// Read the current prediction out of a live stream without ending
    /// it. Returns the output, the workload kind (picks the response
    /// wire encoding), and the lane index.
    pub fn read_out(
        &self,
        conn: u64,
        stream_id: u64,
    ) -> Result<(WorkloadOutput, WorkloadKind, u16), StreamError> {
        let mut t = self.lock();
        self.sweep_locked(&mut t, Instant::now());
        let key = (conn, stream_id);
        let lane = *t.by_key.get(&key).ok_or_else(|| expired(stream_id))?;
        let engine = t.lanes[lane].engine.as_mut().expect("live lane has an engine");
        let kind = engine.kind();
        let out = match engine.read_out() {
            Ok(o) => o,
            Err(e) => {
                t.lanes[lane].engine = None;
                t.lanes[lane].owner = None;
                t.by_key.remove(&key);
                self.telemetry.record_stream_closed();
                return Err(StreamError::new(
                    ErrorCode::InferenceFailed,
                    format!("stream read-out failed: {e:#}"),
                ));
            }
        };
        let owner = t.lanes[lane].owner.as_mut().expect("live lane has an owner");
        owner.last_used = Instant::now();
        owner.cycles = out.cycles;
        Ok((out, kind, lane as u16))
    }

    /// Close a stream: release the lane (the engine stays pooled for
    /// the next open). The ack carries the session's final cumulative
    /// cycles.
    pub fn close(&self, conn: u64, stream_id: u64) -> Result<WireStreamAck, StreamError> {
        let mut t = self.lock();
        self.sweep_locked(&mut t, Instant::now());
        let key = (conn, stream_id);
        let lane = *t.by_key.get(&key).ok_or_else(|| expired(stream_id))?;
        let owner = t.lanes[lane].owner.take().expect("live lane has an owner");
        t.by_key.remove(&key);
        self.telemetry.record_stream_closed();
        Ok(WireStreamAck {
            op: STREAM_OP_CLOSE,
            stream_id,
            lane: lane as u16,
            cycles: owner.cycles,
        })
    }

    /// Peek the pinned lane's V-digest ([`Workload::v_digest`]) — the
    /// record/replay checkpoint for stream traffic. A pure state read:
    /// `last_used` is *not* refreshed (recording must never extend a
    /// stream's TTL) and no instruction is issued. `None` when the
    /// stream is not live or its workload exposes no membrane state.
    pub fn v_digest(&self, conn: u64, stream_id: u64) -> Option<u64> {
        let t = self.lock();
        let lane = *t.by_key.get(&(conn, stream_id))?;
        t.lanes[lane].engine.as_ref().and_then(|e| e.v_digest())
    }

    /// Evict every stream idle past the TTL (engines stay pooled —
    /// [`Workload::begin_stream`] resets them on reuse). The TCP
    /// accept loop calls this on idle ticks and during shutdown drain;
    /// every table op also runs it first, so expiry is enforced even
    /// without a sweeper.
    pub fn sweep(&self) {
        let mut t = self.lock();
        self.sweep_locked(&mut t, Instant::now());
    }

    /// Release every stream owned by connection `conn` (counted as
    /// closed, not expired): called when a transport connection ends
    /// so its sessions never linger until the TTL.
    pub fn close_conn(&self, conn: u64) {
        let mut t = self.lock();
        let keys: Vec<(u64, u64)> = t.by_key.keys().filter(|k| k.0 == conn).copied().collect();
        for key in keys {
            if let Some(lane) = t.by_key.remove(&key) {
                t.lanes[lane].owner = None;
                self.telemetry.record_stream_closed();
            }
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, TableInner> {
        self.inner.lock().expect("stream table poisoned")
    }

    fn sweep_locked(&self, t: &mut TableInner, now: Instant) {
        let ttl = self.ttl;
        let dead: Vec<(u64, u64, Duration)> = t
            .lanes
            .iter()
            .filter_map(|l| l.owner.as_ref())
            .filter(|o| now.duration_since(o.last_used) >= ttl)
            .map(|o| (o.conn, o.id, now.duration_since(o.last_used)))
            .collect();
        for (conn, id, idle) in dead {
            if let Some(lane) = t.by_key.remove(&(conn, id)) {
                t.lanes[lane].owner = None;
                self.telemetry.record_stream_expired();
                // the client only discovers the eviction on its next
                // append (StreamExpired) — leave the operator a trail
                crate::warn!(
                    "stream",
                    "evicted idle stream conn={conn} stream_id={id} \
                     idle_ms={} ttl_ms={} reason=ttl",
                    idle.as_millis(),
                    ttl.as_millis()
                );
            }
        }
    }

    /// The submit path's input normalization, applied per chunk.
    fn normalize(&self, chunk: &WorkloadInput) -> WorkloadInput {
        match chunk {
            WorkloadInput::Words(ids) => {
                WorkloadInput::Words(ids.iter().map(|&w| w.clamp(0, self.vocab - 1)).collect())
            }
            img @ WorkloadInput::Image { .. } => img.clone(),
        }
    }
}

/// The error for a stream id with no live table entry. Unknown, closed
/// and TTL-evicted streams are deliberately indistinguishable on the
/// wire: the client's recovery is the same (re-open and replay).
fn expired(id: u64) -> StreamError {
    StreamError::new(ErrorCode::StreamExpired, format!("stream {id} is unknown, closed, or expired"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::TelemetryConfig;

    /// A deterministic streaming engine for table-logic tests: cycles
    /// = units integrated so far, read-out exposes the running sum of
    /// word ids.
    struct MockEngine {
        sum: i64,
        steps: u64,
        begun: bool,
    }

    impl Workload for MockEngine {
        fn run_one(&mut self, _input: &WorkloadInput) -> crate::Result<WorkloadOutput> {
            anyhow::bail!("mock engine is stream-only")
        }

        fn run_batched(&mut self, _inputs: &[&WorkloadInput]) -> crate::Result<Vec<WorkloadOutput>> {
            anyhow::bail!("mock engine is stream-only")
        }

        fn max_batch_lanes(&self) -> usize {
            1
        }

        fn kind(&self) -> WorkloadKind {
            WorkloadKind::Sentiment
        }

        fn begin_stream(&mut self) -> crate::Result<()> {
            self.sum = 0;
            self.steps = 0;
            self.begun = true;
            Ok(())
        }

        fn step_stream(&mut self, chunk: &WorkloadInput) -> crate::Result<u64> {
            anyhow::ensure!(self.begun, "step before begin");
            match chunk {
                WorkloadInput::Words(ids) => {
                    self.sum += ids.iter().sum::<i64>();
                    self.steps += ids.len() as u64;
                }
                WorkloadInput::Image { .. } => anyhow::bail!("mock step rejects images"),
            }
            Ok(self.steps)
        }

        fn read_out(&mut self) -> crate::Result<WorkloadOutput> {
            Ok(WorkloadOutput {
                pred: u8::from(self.sum >= 0),
                v_out: self.sum,
                v_all: vec![self.sum],
                cycles: self.steps,
            })
        }
    }

    fn table(max_streams: usize, ttl: Duration) -> StreamTable {
        StreamTable::new(
            Box::new(|| Ok(Box::new(MockEngine { sum: 0, steps: 0, begun: false }) as Box<dyn Workload>)),
            max_streams,
            ttl,
            100,
            Arc::new(Telemetry::new(TelemetryConfig::default())),
            None,
        )
    }

    fn words(ids: &[i64]) -> WorkloadInput {
        WorkloadInput::Words(ids.to_vec())
    }

    #[test]
    fn open_append_read_close_pins_state_per_key() {
        let t = table(4, Duration::from_secs(60));
        let a = t.open(1, 10).unwrap();
        assert_eq!((a.op, a.stream_id, a.cycles), (STREAM_OP_OPEN, 10, 0));
        // a second stream on the same connection gets its own lane
        let b = t.open(1, 11).unwrap();
        assert_ne!(a.lane, b.lane);
        assert_eq!(t.active(), 2);

        t.append(1, 10, &words(&[2, 3])).unwrap();
        t.append(1, 11, &words(&[40])).unwrap();
        let ack = t.append(1, 10, &words(&[5])).unwrap();
        assert_eq!(ack.cycles, 3); // cumulative across appends

        let (out, kind, lane) = t.read_out(1, 10).unwrap();
        assert_eq!(kind, WorkloadKind::Sentiment);
        assert_eq!(lane, a.lane);
        assert_eq!(out.v_out, 10); // 2+3+5: state pinned, not mixed with stream 11
        assert_eq!(t.read_out(1, 11).unwrap().0.v_out, 40);

        let fin = t.close(1, 10).unwrap();
        assert_eq!((fin.op, fin.cycles), (STREAM_OP_CLOSE, 3));
        assert_eq!(t.active(), 1);
        // operations on the closed stream now fail as expired
        assert_eq!(t.append(1, 10, &words(&[1])).unwrap_err().code, ErrorCode::StreamExpired);
    }

    #[test]
    fn word_ids_get_the_submit_path_clamp() {
        let t = table(1, Duration::from_secs(60));
        t.open(1, 1).unwrap();
        // vocab is 100: -5 clamps to 0, 10_000 clamps to 99
        t.append(1, 1, &words(&[-5, 10_000])).unwrap();
        assert_eq!(t.read_out(1, 1).unwrap().0.v_out, 99);
    }

    #[test]
    fn cap_rejects_and_close_frees_a_slot() {
        let t = table(2, Duration::from_secs(60));
        t.open(1, 1).unwrap();
        t.open(2, 1).unwrap(); // same stream id, different connection: distinct key
        let err = t.open(1, 2).unwrap_err();
        assert_eq!(err.code, ErrorCode::StreamLimit);
        // duplicate open of a live key is malformed, not a cap hit
        assert_eq!(t.open(1, 1).unwrap_err().code, ErrorCode::Malformed);
        t.close(2, 1).unwrap();
        t.open(1, 2).unwrap();
        let s = t.telemetry.stream_stats();
        assert_eq!((s.opened, s.rejected, s.active), (3, 1, 2));
    }

    #[test]
    fn ttl_sweep_evicts_idle_streams_and_pools_engines() {
        let t = table(2, Duration::from_millis(20));
        let a = t.open(1, 1).unwrap();
        t.append(1, 1, &words(&[7])).unwrap();
        std::thread::sleep(Duration::from_millis(40));
        t.sweep();
        assert_eq!(t.active(), 0);
        assert_eq!(t.read_out(1, 1).unwrap_err().code, ErrorCode::StreamExpired);
        let s = t.telemetry.stream_stats();
        assert_eq!((s.expired, s.active), (1, 0));
        // the lane's engine was pooled and fully reset by the reopen
        let b = t.open(1, 1).unwrap();
        assert_eq!(b.lane, a.lane);
        assert_eq!(t.read_out(1, 1).unwrap().0.v_out, 0);
    }

    #[test]
    fn connection_end_releases_its_streams_only() {
        let t = table(4, Duration::from_secs(60));
        t.open(1, 1).unwrap();
        t.open(1, 2).unwrap();
        t.open(2, 1).unwrap();
        t.close_conn(1);
        assert_eq!(t.active(), 1);
        assert!(t.read_out(2, 1).is_ok());
        let s = t.telemetry.stream_stats();
        assert_eq!((s.closed, s.expired), (2, 0));
    }

    #[test]
    fn step_failure_evicts_the_stream_and_discards_the_engine() {
        let t = table(1, Duration::from_secs(60));
        t.open(1, 1).unwrap();
        let img = WorkloadInput::Image { h: 1, w: 1, pixels: vec![1.0] };
        let err = t.append(1, 1, &img).unwrap_err();
        assert_eq!(err.code, ErrorCode::InferenceFailed);
        assert_eq!(t.active(), 0);
        // the lane is reusable with a freshly built engine
        t.open(1, 1).unwrap();
        assert_eq!(t.read_out(1, 1).unwrap().0.v_out, 0);
    }
}
