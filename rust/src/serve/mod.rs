//! The production serving front-end (Layer 4).
//!
//! Turns the coordinator's in-process [`InferenceServer`] into a
//! network service speaking a versioned, length-prefixed binary
//! protocol — specified byte-for-byte in `docs/PROTOCOL.md`:
//!
//! - [`frame`] — the wire codec: magic/version/type/request-id/CRC-32
//!   framing, incremental [`FrameReader`], error codes.
//! - [`session`] — the codec-agnostic request path: [`ServeCore`]
//!   multiplexes many client sessions onto one batcher/worker pool and
//!   routes each response back to its submitter; payload codecs (the
//!   [`WirePayload`] trait); a reference [`FrameClient`] with a typed
//!   `call`/`wait` surface and stream methods.
//! - [`stream`] — the [`StreamTable`]: membrane state pinned to a
//!   client stream id across `StreamAppend` frames, with a TTL sweep
//!   and a max-streams cap.
//! - [`listener`] — the multi-client TCP accept loop
//!   ([`serve_tcp`]), one reader + one responder thread per
//!   connection. Serves `StatsRequest` frames inline from the core's
//!   [`telemetry`](crate::telemetry) registry and stamps backpressure
//!   advertisements (queue depth + soft-limit bit) into the flags word
//!   for clients that negotiated [`CAP_BACKPRESSURE`].
//! - [`signal`] — SIGINT/SIGTERM wiring so `impulse serve --listen`
//!   drains in-flight requests and exits cleanly
//!   ([`install_shutdown_handler`]).
//!
//! The `impulse serve` CLI fronts this module: `--listen <addr>`
//! serves the binary protocol over TCP, `--stdio` (the default) keeps
//! the line-oriented stdin/stdout loop — both over the same
//! [`ServeCore`] path, so a request answers bit-identically on either
//! transport.
//!
//! [`InferenceServer`]: crate::coordinator::InferenceServer

#![warn(missing_docs)]

pub mod frame;
pub mod listener;
pub mod session;
pub mod signal;
pub mod stream;

pub use frame::{
    crc32, decode_backpressure, encode_backpressure, Backpressure, Decoded, ErrorCode, Frame,
    FrameReader, PayloadType, WireError, CRC_LEN, FLAG_DEPTH_MASK, FLAG_SOFT_LIMIT,
    FLAG_TELEMETRY, FLAG_TRACE_ECHO, HEADER_LEN, MAGIC, MAX_PAYLOAD, PROTOCOL_VERSION,
};
pub use listener::{serve_tcp, TcpServeHandle};
pub use session::{
    attach_trace_echo, decode_digits_request, decode_digits_response, decode_error,
    decode_infer_request, decode_infer_response, decode_stats_response, decode_stream_ack,
    decode_stream_append, decode_stream_ref, encode_digits_request, encode_infer_request,
    encode_stats_request, encode_stats_response, encode_stream_ack, encode_stream_append,
    encode_stream_ref, encode_trace_echo, error_frame, error_payload, hello_caps_payload,
    hello_payload, negotiate, response_frame, split_trace_echo, ClientSession, FrameClient,
    ImagePayload, Negotiated, Pacer, PayloadError, Pending, ServeCore, ServerError,
    SessionSender, StreamAppendPayload, StreamClosePayload, StreamHandle, StreamOpenPayload,
    StreamReadOutPayload, TraceEcho, WireDigitsResponse, WirePayload, WireResponse,
    WireStreamAck, WordsPayload, CAP_BACKPRESSURE, CAP_TRACE_ECHO, MAX_WORDS_PER_REQUEST,
    STREAM_KIND_IMAGE, STREAM_KIND_WORDS, STREAM_OP_APPEND, STREAM_OP_CLOSE, STREAM_OP_OPEN,
    SUPPORTED_CAPS, TRACE_ECHO_LEN,
};
pub use signal::{install_shutdown_handler, shutdown_requested};
pub use stream::{EngineFactory, StreamError, StreamTable};
