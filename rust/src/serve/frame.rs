//! The IMPULSE binary frame codec (wire format v1).
//!
//! Every message on a framed transport is one length-prefixed frame:
//!
//! ```text
//! offset size field
//! 0      4    magic "IMP1" (0x49 0x4D 0x50 0x31)
//! 4      1    protocol version (1)
//! 5      1    payload type
//! 6      2    flags (zero, or a telemetry flags word), big-endian
//! 8      8    request id, big-endian
//! 16     4    payload length N (≤ 1 MiB), big-endian
//! 20     N    payload
//! 20+N   4    CRC-32 (IEEE) over bytes [0, 20+N), big-endian
//! ```
//!
//! The byte-exact contract — including decode-error precedence and
//! worked hex examples — lives in `docs/PROTOCOL.md`; the codec tests
//! in `rust/tests/frame_codec.rs` pin this module to that document
//! field-for-field. Change either only in lockstep with the other.

use std::io::Read;

/// The four magic bytes opening every frame (`"IMP1"`).
pub const MAGIC: [u8; 4] = *b"IMP1";

/// The protocol version this build speaks (and the only one so far).
pub const PROTOCOL_VERSION: u8 = 1;

/// Fixed header length: magic + version + type + flags + id + length.
pub const HEADER_LEN: usize = 20;

/// Trailing checksum length.
pub const CRC_LEN: usize = 4;

/// Maximum payload length a peer may send (1 MiB). Frames claiming
/// more are rejected before any payload bytes are buffered.
pub const MAX_PAYLOAD: usize = 1 << 20;

// ---------------------------------------------------------------------
// The flags word (header bytes 6–7)
// ---------------------------------------------------------------------
//
// v1 reserved the word as all-zero. The telemetry subsystem defines
// the first nonzero use: when bit 15 is set, the word is a telemetry
// flags word — a backpressure advertisement on server→client frames,
// or (bit 13, tracing) a trace-echo request on client→server infer
// frames. Any pattern without bit 15 is still rejected as Malformed,
// and nonzero flags only flow between peers that negotiated the
// matching capability in their Hello — so all-zero v1 traffic is
// preserved byte-for-byte.

/// Flags bit 15: the word carries a telemetry flags word (negotiated
/// via Hello caps; without this bit, nonzero flags are Malformed).
pub const FLAG_TELEMETRY: u16 = 0x8000;

/// Flags bit 14: the server's queue depth is at or over its soft
/// limit — clients should slow their submission rate.
pub const FLAG_SOFT_LIMIT: u16 = 0x4000;

/// Flags bit 13: trace echo. On a client→server infer request (from a
/// connection that negotiated `CAP_TRACE_ECHO`), asks the server to
/// append its per-phase timing breakdown to the response payload; on
/// the server→client response, marks that the trailer is present. See
/// `docs/OBSERVABILITY.md`.
pub const FLAG_TRACE_ECHO: u16 = 0x2000;

/// Flags bits 0–12: the server's queue depth, saturating at
/// [`FLAG_DEPTH_MASK`].
pub const FLAG_DEPTH_MASK: u16 = 0x1FFF;

/// A decoded backpressure advertisement from a frame's flags word.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Backpressure {
    /// Queue depth at send time (saturated at [`FLAG_DEPTH_MASK`]).
    pub queue_depth: u16,
    /// Whether the server asked clients to slow down (soft limit hit).
    pub soft_limited: bool,
}

/// Encode a backpressure advertisement into a flags word.
pub fn encode_backpressure(queue_depth: u64, soft_limited: bool) -> u16 {
    let depth = queue_depth.min(FLAG_DEPTH_MASK as u64) as u16;
    let soft = if soft_limited { FLAG_SOFT_LIMIT } else { 0 };
    FLAG_TELEMETRY | soft | depth
}

/// Decode a frame's flags word: `None` for the all-zero v1 encoding,
/// `Some` when the telemetry bit is set. (Words that are neither never
/// pass [`Frame::decode`].)
pub fn decode_backpressure(flags: u16) -> Option<Backpressure> {
    if flags & FLAG_TELEMETRY == 0 {
        return None;
    }
    Some(Backpressure {
        queue_depth: flags & FLAG_DEPTH_MASK,
        soft_limited: flags & FLAG_SOFT_LIMIT != 0,
    })
}

/// Payload type discriminants (byte 5 of the header).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PayloadType {
    /// Client → server: version negotiation offer (`[min, max]`).
    Hello,
    /// Server → client: accepted protocol version (`[version]`).
    HelloAck,
    /// Client → server: word-id sequence to classify.
    InferRequest,
    /// Server → client: successful classification result.
    InferResponse,
    /// Client → server: image to classify on the digits workload.
    DigitsInferRequest,
    /// Server → client: digits classification result (10-class).
    DigitsInferResponse,
    /// Client → server: live server-statistics request (empty
    /// payload).
    StatsRequest,
    /// Server → client: telemetry snapshot (see `docs/PROTOCOL.md`
    /// §4.9).
    StatsResponse,
    /// Client → server: open a streaming session pinned to this
    /// frame's request id (empty payload; `docs/PROTOCOL.md` §4.10).
    StreamOpen,
    /// Client → server: append one input chunk (words or an image
    /// frame) to an open stream (§4.11).
    StreamAppend,
    /// Client → server: read the stream's running prediction without
    /// disturbing its pinned membrane state (§4.12).
    StreamReadOut,
    /// Client → server: close a stream and free its lane (§4.13).
    StreamClose,
    /// Server → client: acknowledgement of a stream open/append/close
    /// (op, stream id, lane, accumulated cycles — §4.14).
    StreamAck,
    /// Server → client: request- or connection-level failure.
    Error,
}

impl PayloadType {
    /// Wire encoding of this payload type.
    pub fn as_u8(self) -> u8 {
        match self {
            PayloadType::Hello => 0x01,
            PayloadType::HelloAck => 0x02,
            PayloadType::InferRequest => 0x10,
            PayloadType::InferResponse => 0x11,
            PayloadType::DigitsInferRequest => 0x12,
            PayloadType::DigitsInferResponse => 0x13,
            PayloadType::StatsRequest => 0x14,
            PayloadType::StatsResponse => 0x15,
            PayloadType::StreamOpen => 0x16,
            PayloadType::StreamAppend => 0x17,
            PayloadType::StreamReadOut => 0x18,
            PayloadType::StreamClose => 0x19,
            PayloadType::StreamAck => 0x1A,
            PayloadType::Error => 0x7F,
        }
    }

    /// Decode a wire byte; `None` for unassigned discriminants.
    pub fn from_u8(b: u8) -> Option<PayloadType> {
        match b {
            0x01 => Some(PayloadType::Hello),
            0x02 => Some(PayloadType::HelloAck),
            0x10 => Some(PayloadType::InferRequest),
            0x11 => Some(PayloadType::InferResponse),
            0x12 => Some(PayloadType::DigitsInferRequest),
            0x13 => Some(PayloadType::DigitsInferResponse),
            0x14 => Some(PayloadType::StatsRequest),
            0x15 => Some(PayloadType::StatsResponse),
            0x16 => Some(PayloadType::StreamOpen),
            0x17 => Some(PayloadType::StreamAppend),
            0x18 => Some(PayloadType::StreamReadOut),
            0x19 => Some(PayloadType::StreamClose),
            0x1A => Some(PayloadType::StreamAck),
            0x7F => Some(PayloadType::Error),
            _ => None,
        }
    }
}

/// Error codes carried in [`PayloadType::Error`] payloads (u16).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ErrorCode {
    /// The stream did not start with the `IMP1` magic.
    BadMagic,
    /// No mutually supported protocol version.
    UnsupportedVersion,
    /// Frame checksum mismatch (corruption in transit).
    BadCrc,
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    Oversized,
    /// Payload bytes do not parse as their declared type (or nonzero
    /// reserved flags, or a type invalid in this direction).
    Malformed,
    /// Unassigned payload-type discriminant.
    UnknownType,
    /// Inference itself failed; the message carries the cause.
    InferenceFailed,
    /// An `InferRequest` carried zero word ids.
    EmptyRequest,
    /// Server-side internal failure (e.g. shutting down).
    Internal,
    /// The request exceeds a per-request limit (e.g. more than 65 535
    /// word ids — the u16 count field's ceiling). Rejected instead of
    /// silently truncating into a wrong-but-valid frame.
    RequestTooLarge,
    /// The referenced stream id is unknown on this connection — never
    /// opened, already closed, or evicted by the TTL sweep. The
    /// connection stays usable.
    StreamExpired,
    /// The server's stream table is full (`--max-streams`); the open
    /// was rejected. The connection stays usable.
    StreamLimit,
    /// A proxy tier lost the backend this request (or the stream it
    /// belonged to) was routed to, and could not transparently
    /// re-submit it — non-idempotent, out of retries, or past its
    /// deadline. Streams must be re-opened (the membrane state died
    /// with the backend); one-shots may simply be retried. The
    /// connection to the proxy stays usable.
    BackendLost,
}

impl ErrorCode {
    /// Wire encoding of this error code.
    pub fn as_u16(self) -> u16 {
        match self {
            ErrorCode::BadMagic => 1,
            ErrorCode::UnsupportedVersion => 2,
            ErrorCode::BadCrc => 3,
            ErrorCode::Oversized => 4,
            ErrorCode::Malformed => 5,
            ErrorCode::UnknownType => 6,
            ErrorCode::InferenceFailed => 7,
            ErrorCode::EmptyRequest => 8,
            ErrorCode::Internal => 9,
            ErrorCode::RequestTooLarge => 10,
            ErrorCode::StreamExpired => 11,
            ErrorCode::StreamLimit => 12,
            ErrorCode::BackendLost => 13,
        }
    }

    /// Decode a wire code; `None` for unassigned values.
    pub fn from_u16(v: u16) -> Option<ErrorCode> {
        match v {
            1 => Some(ErrorCode::BadMagic),
            2 => Some(ErrorCode::UnsupportedVersion),
            3 => Some(ErrorCode::BadCrc),
            4 => Some(ErrorCode::Oversized),
            5 => Some(ErrorCode::Malformed),
            6 => Some(ErrorCode::UnknownType),
            7 => Some(ErrorCode::InferenceFailed),
            8 => Some(ErrorCode::EmptyRequest),
            9 => Some(ErrorCode::Internal),
            10 => Some(ErrorCode::RequestTooLarge),
            11 => Some(ErrorCode::StreamExpired),
            12 => Some(ErrorCode::StreamLimit),
            13 => Some(ErrorCode::BackendLost),
            _ => None,
        }
    }
}

/// A decoded frame (header fields + raw payload bytes).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    /// Protocol version byte as sent by the peer. The codec does not
    /// enforce a version; sessions validate it after negotiation.
    pub version: u8,
    /// What the payload bytes encode.
    pub payload_type: PayloadType,
    /// The flags word: zero (the v1 encoding), or a backpressure
    /// advertisement with [`FLAG_TELEMETRY`] set (see
    /// [`decode_backpressure`]). Servers emit nonzero flags only to
    /// clients that negotiated the capability.
    pub flags: u16,
    /// Caller-chosen correlation id, echoed verbatim in responses.
    pub request_id: u64,
    /// Raw payload bytes (≤ [`MAX_PAYLOAD`]).
    pub payload: Vec<u8>,
}

/// A wire-level failure while decoding or reading frames.
#[derive(Debug)]
pub enum WireError {
    /// The first four bytes were not `IMP1`.
    BadMagic([u8; 4]),
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    Oversized(usize),
    /// Checksum mismatch: `expected` (computed) vs `found` (on wire).
    BadCrc {
        /// CRC computed over the received header + payload bytes.
        expected: u32,
        /// CRC carried in the frame trailer.
        found: u32,
    },
    /// Unassigned payload-type byte.
    UnknownType(u8),
    /// A nonzero flags word without the telemetry bit — no such
    /// encoding is assigned.
    BadFlags(u16),
    /// The stream ended inside a frame.
    Truncated,
    /// Underlying transport error (including read timeouts).
    Io(std::io::Error),
}

impl WireError {
    /// The protocol error code a server reports for this failure.
    pub fn code(&self) -> ErrorCode {
        match self {
            WireError::BadMagic(_) => ErrorCode::BadMagic,
            WireError::Oversized(_) => ErrorCode::Oversized,
            WireError::BadCrc { .. } => ErrorCode::BadCrc,
            WireError::UnknownType(_) => ErrorCode::UnknownType,
            WireError::BadFlags(_) => ErrorCode::Malformed,
            WireError::Truncated => ErrorCode::Malformed,
            WireError::Io(_) => ErrorCode::Internal,
        }
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic(m) => write!(f, "bad magic {m:02X?} (want \"IMP1\")"),
            WireError::Oversized(n) => {
                write!(f, "payload of {n} bytes exceeds the {MAX_PAYLOAD}-byte cap")
            }
            WireError::BadCrc { expected, found } => {
                write!(f, "CRC mismatch: computed {expected:#010X}, frame says {found:#010X}")
            }
            WireError::UnknownType(b) => write!(f, "unknown payload type {b:#04X}"),
            WireError::BadFlags(v) => {
                write!(f, "flags must be zero or a telemetry word, got {v:#06X}")
            }
            WireError::Truncated => write!(f, "stream ended inside a frame"),
            WireError::Io(e) => write!(f, "transport error: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// Outcome of decoding a byte buffer that may hold a partial frame.
#[derive(Debug)]
pub enum Decoded {
    /// A complete frame, plus how many buffer bytes it consumed.
    Frame(Frame, usize),
    /// Not enough bytes yet; the frame needs at least this many total.
    NeedMore(usize),
}

/// CRC-32 (IEEE 802.3, reflected, `0xEDB88320`) — the same polynomial
/// as zlib's `crc32`, so `crc32(b"123456789") == 0xCBF43926`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

impl Frame {
    /// Build a frame with the current [`PROTOCOL_VERSION`] and the
    /// all-zero v1 flags word.
    pub fn new(payload_type: PayloadType, request_id: u64, payload: Vec<u8>) -> Frame {
        Frame {
            version: PROTOCOL_VERSION,
            payload_type,
            flags: 0,
            request_id,
            payload,
        }
    }

    /// The same frame with its flags word replaced (builder-style;
    /// used by the listener to stamp backpressure advertisements on
    /// responses to capability-negotiated clients).
    pub fn with_flags(mut self, flags: u16) -> Frame {
        self.flags = flags;
        self
    }

    /// Encoded size of this frame on the wire.
    pub fn encoded_len(&self) -> usize {
        HEADER_LEN + self.payload.len() + CRC_LEN
    }

    /// Serialize to wire bytes (header, payload, CRC trailer).
    pub fn encode(&self) -> Vec<u8> {
        assert!(self.payload.len() <= MAX_PAYLOAD, "payload exceeds MAX_PAYLOAD");
        let mut out = Vec::with_capacity(self.encoded_len());
        out.extend_from_slice(&MAGIC);
        out.push(self.version);
        out.push(self.payload_type.as_u8());
        out.extend_from_slice(&self.flags.to_be_bytes());
        out.extend_from_slice(&self.request_id.to_be_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_be_bytes());
        out.extend_from_slice(&self.payload);
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_be_bytes());
        out
    }

    /// Write the encoded frame to a transport.
    pub fn write_to(&self, w: &mut impl std::io::Write) -> std::io::Result<()> {
        w.write_all(&self.encode())
    }

    /// Decode one frame from the front of `buf`.
    ///
    /// Check order (and therefore error precedence) is part of the
    /// wire contract: magic → declared length (oversized) → complete
    /// frame present → CRC → payload type → flags. The CRC is checked
    /// before the payload-type and flags bytes are interpreted, so a
    /// corrupted discriminant reports [`WireError::BadCrc`], not
    /// [`WireError::UnknownType`]. A flags word must be zero or have
    /// [`FLAG_TELEMETRY`] set; any other nonzero pattern is
    /// [`WireError::BadFlags`].
    pub fn decode(buf: &[u8]) -> Result<Decoded, WireError> {
        if buf.len() >= 4 && buf[..4] != MAGIC {
            let mut m = [0u8; 4];
            m.copy_from_slice(&buf[..4]);
            return Err(WireError::BadMagic(m));
        }
        if buf.len() < HEADER_LEN {
            return Ok(Decoded::NeedMore(HEADER_LEN));
        }
        let len = u32::from_be_bytes([buf[16], buf[17], buf[18], buf[19]]) as usize;
        if len > MAX_PAYLOAD {
            return Err(WireError::Oversized(len));
        }
        let total = HEADER_LEN + len + CRC_LEN;
        if buf.len() < total {
            return Ok(Decoded::NeedMore(total));
        }
        let body = &buf[..HEADER_LEN + len];
        let found = u32::from_be_bytes([
            buf[HEADER_LEN + len],
            buf[HEADER_LEN + len + 1],
            buf[HEADER_LEN + len + 2],
            buf[HEADER_LEN + len + 3],
        ]);
        let expected = crc32(body);
        if expected != found {
            return Err(WireError::BadCrc { expected, found });
        }
        let payload_type =
            PayloadType::from_u8(buf[5]).ok_or(WireError::UnknownType(buf[5]))?;
        let flags = u16::from_be_bytes([buf[6], buf[7]]);
        if flags != 0 && flags & FLAG_TELEMETRY == 0 {
            return Err(WireError::BadFlags(flags));
        }
        let request_id = u64::from_be_bytes([
            buf[8], buf[9], buf[10], buf[11], buf[12], buf[13], buf[14], buf[15],
        ]);
        Ok(Decoded::Frame(
            Frame {
                version: buf[4],
                payload_type,
                flags,
                request_id,
                payload: buf[HEADER_LEN..HEADER_LEN + len].to_vec(),
            },
            total,
        ))
    }
}

/// Incremental frame reader over any [`Read`] transport.
///
/// Keeps a carry buffer across calls, so short reads and read
/// timeouts (surfaced as [`WireError::Io`]) never lose partial-frame
/// bytes — callers poll [`FrameReader::next_frame`] again and the
/// stream resumes where it left off.
pub struct FrameReader<R: Read> {
    r: R,
    buf: Vec<u8>,
}

impl<R: Read> FrameReader<R> {
    /// Wrap a transport.
    pub fn new(r: R) -> FrameReader<R> {
        FrameReader { r, buf: Vec::with_capacity(4096) }
    }

    /// Read the next complete frame. `Ok(None)` on a clean EOF at a
    /// frame boundary; [`WireError::Truncated`] if the stream ends
    /// mid-frame; [`WireError::Io`] on transport errors (including
    /// read timeouts — safe to retry).
    pub fn next_frame(&mut self) -> Result<Option<Frame>, WireError> {
        loop {
            match Frame::decode(&self.buf)? {
                Decoded::Frame(f, used) => {
                    self.buf.drain(..used);
                    return Ok(Some(f));
                }
                Decoded::NeedMore(_) => {}
            }
            let mut chunk = [0u8; 4096];
            let n = self.r.read(&mut chunk)?;
            if n == 0 {
                if self.buf.is_empty() {
                    return Ok(None);
                }
                return Err(WireError::Truncated);
            }
            self.buf.extend_from_slice(&chunk[..n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_reference_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let f = Frame::new(PayloadType::InferRequest, 0xDEAD_BEEF, vec![1, 2, 3]);
        let bytes = f.encode();
        assert_eq!(bytes.len(), f.encoded_len());
        match Frame::decode(&bytes).unwrap() {
            Decoded::Frame(g, used) => {
                assert_eq!(g, f);
                assert_eq!(used, bytes.len());
            }
            other => panic!("expected frame, got {other:?}"),
        }
    }

    #[test]
    fn decode_wants_more_bytes_for_prefixes() {
        let bytes = Frame::new(PayloadType::Hello, 1, vec![1, 1]).encode();
        for cut in 0..bytes.len() {
            match Frame::decode(&bytes[..cut]).unwrap() {
                Decoded::NeedMore(n) => assert!(n > cut),
                Decoded::Frame(..) => panic!("frame from a {cut}-byte prefix"),
            }
        }
    }

    #[test]
    fn bad_magic_rejected_immediately() {
        let mut bytes = Frame::new(PayloadType::Hello, 1, vec![1, 1]).encode();
        bytes[0] = b'X';
        assert!(matches!(Frame::decode(&bytes[..4]), Err(WireError::BadMagic(_))));
    }

    #[test]
    fn oversized_rejected_from_header_alone() {
        let mut bytes = Frame::new(PayloadType::Hello, 1, vec![]).encode();
        bytes[16..20].copy_from_slice(&((MAX_PAYLOAD as u32) + 1).to_be_bytes());
        assert!(matches!(
            Frame::decode(&bytes[..HEADER_LEN]),
            Err(WireError::Oversized(_))
        ));
    }

    #[test]
    fn corrupt_payload_reports_bad_crc() {
        let mut bytes = Frame::new(PayloadType::InferRequest, 2, vec![9, 9, 9]).encode();
        bytes[HEADER_LEN] ^= 0x40;
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadCrc { .. })));
    }

    #[test]
    fn crc_checked_before_type_and_flags() {
        // Corrupting the type byte must surface as BadCrc, not
        // UnknownType — the discriminant is untrusted until the
        // checksum passes.
        let mut bytes = Frame::new(PayloadType::Hello, 3, vec![1, 1]).encode();
        bytes[5] = 0x55; // unassigned type
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadCrc { .. })));
    }

    #[test]
    fn nonzero_flags_without_telemetry_bit_rejected() {
        // Re-encode with valid CRC but an unassigned flags pattern.
        let f = Frame::new(PayloadType::Hello, 3, vec![1, 1]);
        let mut bytes = f.encode();
        bytes[7] = 1;
        let crc = crc32(&bytes[..bytes.len() - CRC_LEN]);
        let n = bytes.len();
        bytes[n - 4..].copy_from_slice(&crc.to_be_bytes());
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadFlags(1))));
    }

    #[test]
    fn telemetry_flags_roundtrip_through_the_codec() {
        let flags = encode_backpressure(37, true);
        let f = Frame::new(PayloadType::InferResponse, 5, vec![0; 29]).with_flags(flags);
        let bytes = f.encode();
        match Frame::decode(&bytes).unwrap() {
            Decoded::Frame(g, used) => {
                assert_eq!(used, bytes.len());
                assert_eq!(g, f);
                assert_eq!(
                    decode_backpressure(g.flags),
                    Some(Backpressure { queue_depth: 37, soft_limited: true })
                );
            }
            other => panic!("expected frame, got {other:?}"),
        }
    }

    #[test]
    fn backpressure_word_encoding() {
        assert_eq!(encode_backpressure(0, false), FLAG_TELEMETRY);
        assert_eq!(encode_backpressure(3, false), FLAG_TELEMETRY | 3);
        assert_eq!(encode_backpressure(3, true), FLAG_TELEMETRY | FLAG_SOFT_LIMIT | 3);
        // depth saturates into the 14-bit field
        assert_eq!(
            encode_backpressure(u64::MAX, false) & FLAG_DEPTH_MASK,
            FLAG_DEPTH_MASK
        );
        assert_eq!(decode_backpressure(0), None);
        assert_eq!(
            decode_backpressure(FLAG_TELEMETRY | FLAG_SOFT_LIMIT | 9),
            Some(Backpressure { queue_depth: 9, soft_limited: true })
        );
    }

    #[test]
    fn reader_reassembles_fragmented_stream() {
        struct Trickle(Vec<u8>, usize);
        impl Read for Trickle {
            fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
                let n = 3.min(self.0.len() - self.1).min(out.len());
                out[..n].copy_from_slice(&self.0[self.1..self.1 + n]);
                self.1 += n;
                Ok(n)
            }
        }
        let a = Frame::new(PayloadType::InferRequest, 1, vec![0, 1, 0, 0, 0, 5]);
        let b = Frame::new(PayloadType::Hello, 2, vec![1, 1]);
        let mut stream = a.encode();
        stream.extend_from_slice(&b.encode());
        let mut rd = FrameReader::new(Trickle(stream, 0));
        assert_eq!(rd.next_frame().unwrap(), Some(a));
        assert_eq!(rd.next_frame().unwrap(), Some(b));
        assert_eq!(rd.next_frame().unwrap(), None);
    }

    #[test]
    fn reader_flags_mid_frame_eof() {
        let bytes = Frame::new(PayloadType::Hello, 1, vec![1, 1]).encode();
        let cut = bytes.len() - 2;
        let mut rd = FrameReader::new(std::io::Cursor::new(bytes[..cut].to_vec()));
        assert!(matches!(rd.next_frame(), Err(WireError::Truncated)));
    }
}
