//! Run configuration: a minimal TOML-subset parser (offline — no serde)
//! plus the typed `RunConfig` used by the CLI and examples.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with
//! string ("…"), integer, float, and boolean values, and `#` comments.

mod toml_lite;

pub use toml_lite::{TomlDoc, TomlValue};

use crate::macro_sim::{ComparatorMode, Engine, MacroConfig};
use anyhow::{Context, Result};
use std::path::Path;

/// Typed run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Supply voltage for energy reporting.
    pub vdd: f64,
    /// Clock frequency (Hz).
    pub freq_hz: f64,
    /// Execution engine.
    pub engine: Engine,
    /// Comparator mode (modelling choice M3).
    pub comparator: ComparatorMode,
    /// Worker threads for the coordinator.
    pub workers: usize,
    /// Samples to evaluate in e2e runs (0 = all).
    pub max_samples: usize,
    /// Timesteps per word (sentiment) / per image (digits).
    pub timesteps: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            vdd: crate::NOMINAL_VDD,
            freq_hz: crate::NOMINAL_FREQ_HZ,
            engine: Engine::Fast,
            comparator: ComparatorMode::SignBit,
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(16),
            max_samples: 0,
            timesteps: 10,
        }
    }
}

impl RunConfig {
    /// Load from a TOML-subset file; missing keys keep defaults.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let doc = TomlDoc::parse(
            &std::fs::read_to_string(path.as_ref())
                .with_context(|| format!("read {}", path.as_ref().display()))?,
        )?;
        let mut cfg = Self::default();
        cfg.apply(&doc)?;
        Ok(cfg)
    }

    /// Apply a parsed document over the current values.
    pub fn apply(&mut self, doc: &TomlDoc) -> Result<()> {
        if let Some(v) = doc.get_f64("macro", "vdd") {
            self.vdd = v;
        }
        if let Some(v) = doc.get_f64("macro", "freq_mhz") {
            self.freq_hz = v * 1e6;
        }
        if let Some(v) = doc.get_str("macro", "engine") {
            self.engine = match v {
                "bit" | "bit_level" => Engine::BitLevel,
                "fast" => Engine::Fast,
                "lockstep" => Engine::Lockstep,
                other => anyhow::bail!("unknown engine '{other}'"),
            };
        }
        if let Some(v) = doc.get_str("macro", "comparator") {
            self.comparator = match v {
                "sign" | "sign_bit" => ComparatorMode::SignBit,
                "cout" | "msb_cout" => ComparatorMode::MsbCout,
                other => anyhow::bail!("unknown comparator '{other}'"),
            };
        }
        if let Some(v) = doc.get_i64("run", "workers") {
            self.workers = (v.max(1)) as usize;
        }
        if let Some(v) = doc.get_i64("run", "max_samples") {
            self.max_samples = v.max(0) as usize;
        }
        if let Some(v) = doc.get_i64("run", "timesteps") {
            self.timesteps = v.clamp(1, 1000) as usize;
        }
        Ok(())
    }

    /// The macro configuration implied by this run config.
    pub fn macro_config(&self) -> MacroConfig {
        MacroConfig {
            engine: self.engine,
            comparator: self.comparator,
            trace: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_nominal_point_d() {
        let c = RunConfig::default();
        assert_eq!(c.vdd, 0.85);
        assert_eq!(c.freq_hz, 200e6);
        assert!(c.workers >= 1);
    }

    #[test]
    fn apply_overrides() {
        let doc = TomlDoc::parse(
            r#"
            [macro]
            vdd = 1.2
            freq_mhz = 500.0
            engine = "lockstep"
            comparator = "cout"
            [run]
            workers = 3
            max_samples = 100
            timesteps = 5
            "#,
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.vdd, 1.2);
        assert_eq!(c.freq_hz, 500e6);
        assert_eq!(c.engine, Engine::Lockstep);
        assert_eq!(c.comparator, ComparatorMode::MsbCout);
        assert_eq!(c.workers, 3);
        assert_eq!(c.max_samples, 100);
        assert_eq!(c.timesteps, 5);
    }

    #[test]
    fn bad_enum_value_errors() {
        let doc = TomlDoc::parse("[macro]\nengine = \"warp\"\n").unwrap();
        assert!(RunConfig::default().apply(&doc).is_err());
    }
}
