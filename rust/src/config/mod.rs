//! Run configuration: a minimal TOML-subset parser (offline — no serde)
//! plus the typed `RunConfig` used by the CLI and examples.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with
//! string ("…"), integer, float, and boolean values, and `#` comments.

mod toml_lite;

pub use toml_lite::{TomlDoc, TomlValue};

use crate::macro_sim::{ComparatorMode, Engine, MacroConfig};
use anyhow::{Context, Result};
use std::path::Path;

/// Typed run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Supply voltage for energy reporting.
    pub vdd: f64,
    /// Clock frequency (Hz).
    pub freq_hz: f64,
    /// Execution engine.
    pub engine: Engine,
    /// Comparator mode (modelling choice M3).
    pub comparator: ComparatorMode,
    /// Worker threads for the coordinator.
    pub workers: usize,
    /// Max requests fused into one serve micro-batch (1 = no batching).
    pub batch: usize,
    /// Micro-batch fill deadline in microseconds.
    pub batch_deadline_us: u64,
    /// Pipeline singleton batches across layer-stage threads.
    pub pipeline: bool,
    /// Queue-depth-driven batch sizing (overrides the fixed `batch`).
    pub adaptive: bool,
    /// TCP listen address for `impulse serve` (e.g. `127.0.0.1:7878`);
    /// `None` keeps the stdio line loop.
    pub listen: Option<String>,
    /// Plaintext metrics exposition address (Prometheus text format)
    /// for `impulse serve`; `None` disables the endpoint.
    pub metrics_listen: Option<String>,
    /// Queue depth at which the server signals backpressure (the
    /// soft-limit bit in response flags and `StatsResponse`); 0
    /// signals unconditionally (maintenance/drain mode).
    pub queue_soft_limit: u64,
    /// Most streaming sessions `impulse serve` pins at once; opens
    /// past the cap are rejected with `StreamLimit`.
    pub max_streams: usize,
    /// Idle seconds before a streaming session is TTL-evicted.
    pub stream_ttl_s: u64,
    /// Samples to evaluate in e2e runs (0 = all).
    pub max_samples: usize,
    /// Timesteps per word (sentiment) / per image (digits).
    pub timesteps: usize,
    /// Directory for per-request lifecycle traces (Chrome trace-event
    /// JSON rotations, `docs/OBSERVABILITY.md`); `None` disables
    /// tracing entirely.
    pub trace_dir: Option<String>,
    /// Stderr log verbosity (`error`/`warn`/`info`/`debug`); `None`
    /// defers to the `IMPULSE_LOG` environment variable, then `info`.
    pub log_level: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            vdd: crate::NOMINAL_VDD,
            freq_hz: crate::NOMINAL_FREQ_HZ,
            engine: Engine::Fast,
            comparator: ComparatorMode::SignBit,
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(16),
            batch: 1,
            batch_deadline_us: 200,
            pipeline: false,
            adaptive: false,
            listen: None,
            metrics_listen: None,
            queue_soft_limit: crate::telemetry::DEFAULT_QUEUE_SOFT_LIMIT,
            max_streams: 8,
            stream_ttl_s: 120,
            max_samples: 0,
            timesteps: 10,
            trace_dir: None,
            log_level: None,
        }
    }
}

impl RunConfig {
    /// Load from a TOML-subset file; missing keys keep defaults.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let doc = TomlDoc::parse(
            &std::fs::read_to_string(path.as_ref())
                .with_context(|| format!("read {}", path.as_ref().display()))?,
        )?;
        let mut cfg = Self::default();
        cfg.apply(&doc)?;
        Ok(cfg)
    }

    /// Apply a parsed document over the current values.
    pub fn apply(&mut self, doc: &TomlDoc) -> Result<()> {
        if let Some(v) = doc.get_f64("macro", "vdd") {
            self.vdd = v;
        }
        if let Some(v) = doc.get_f64("macro", "freq_mhz") {
            self.freq_hz = v * 1e6;
        }
        if let Some(v) = doc.get_str("macro", "engine") {
            self.engine = match v {
                "bit" | "bit_level" => Engine::BitLevel,
                "fast" => Engine::Fast,
                "lockstep" => Engine::Lockstep,
                other => anyhow::bail!("unknown engine '{other}'"),
            };
        }
        if let Some(v) = doc.get_str("macro", "comparator") {
            self.comparator = match v {
                "sign" | "sign_bit" => ComparatorMode::SignBit,
                "cout" | "msb_cout" => ComparatorMode::MsbCout,
                other => anyhow::bail!("unknown comparator '{other}'"),
            };
        }
        if let Some(v) = doc.get_i64("run", "workers") {
            self.workers = (v.max(1)) as usize;
        }
        if let Some(v) = doc.get_i64("run", "batch") {
            self.batch = v.max(1) as usize;
        }
        if let Some(v) = doc.get_i64("run", "batch_deadline_us") {
            self.batch_deadline_us = v.max(0) as u64;
        }
        if let Some(v) = doc.get_bool("run", "pipeline") {
            self.pipeline = v;
        }
        if let Some(v) = doc.get_bool("run", "adaptive") {
            self.adaptive = v;
        }
        if let Some(v) = doc.get_str("run", "listen") {
            self.listen = Some(v.to_string());
        }
        if let Some(v) = doc.get_str("run", "metrics_listen") {
            self.metrics_listen = Some(v.to_string());
        }
        if let Some(v) = doc.get_i64("run", "queue_soft_limit") {
            self.queue_soft_limit = v.max(0) as u64;
        }
        if let Some(v) = doc.get_i64("run", "max_streams") {
            self.max_streams = v.max(1) as usize;
        }
        if let Some(v) = doc.get_i64("run", "stream_ttl_s") {
            self.stream_ttl_s = v.max(1) as u64;
        }
        if let Some(v) = doc.get_i64("run", "max_samples") {
            self.max_samples = v.max(0) as usize;
        }
        if let Some(v) = doc.get_i64("run", "timesteps") {
            self.timesteps = v.clamp(1, 1000) as usize;
        }
        if let Some(v) = doc.get_str("run", "trace_dir") {
            self.trace_dir = Some(v.to_string());
        }
        if let Some(v) = doc.get_str("run", "log_level") {
            anyhow::ensure!(
                crate::obs::log::parse_level(v).is_some(),
                "unknown log_level '{v}' (error|warn|info|debug)"
            );
            self.log_level = Some(v.to_string());
        }
        Ok(())
    }

    /// The macro configuration implied by this run config.
    pub fn macro_config(&self) -> MacroConfig {
        MacroConfig {
            engine: self.engine,
            comparator: self.comparator,
            trace: false,
        }
    }

    /// The server options implied by this run config (telemetry is
    /// wired in by the serve CLI, which owns the registry).
    pub fn server_options(&self) -> crate::coordinator::ServerOptions {
        crate::coordinator::ServerOptions {
            workers: self.workers,
            batch_size: self.batch.max(1),
            batch_deadline: std::time::Duration::from_micros(self.batch_deadline_us),
            pipeline: self.pipeline,
            adaptive: self.adaptive,
            max_streams: self.max_streams,
            stream_ttl: std::time::Duration::from_secs(self.stream_ttl_s),
            ..crate::coordinator::ServerOptions::default()
        }
    }

    /// The telemetry configuration implied by this run config: energy
    /// attribution at the configured operating point, backpressure at
    /// the configured soft limit.
    pub fn telemetry_config(&self) -> crate::telemetry::TelemetryConfig {
        crate::telemetry::TelemetryConfig {
            vdd: self.vdd,
            freq_hz: self.freq_hz,
            queue_soft_limit: self.queue_soft_limit,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_nominal_point_d() {
        let c = RunConfig::default();
        assert_eq!(c.vdd, 0.85);
        assert_eq!(c.freq_hz, 200e6);
        assert!(c.workers >= 1);
    }

    #[test]
    fn apply_overrides() {
        let doc = TomlDoc::parse(
            r#"
            [macro]
            vdd = 1.2
            freq_mhz = 500.0
            engine = "lockstep"
            comparator = "cout"
            [run]
            workers = 3
            batch = 16
            batch_deadline_us = 500
            pipeline = true
            adaptive = true
            listen = "127.0.0.1:7878"
            metrics_listen = "127.0.0.1:9200"
            queue_soft_limit = 64
            max_streams = 3
            stream_ttl_s = 15
            max_samples = 100
            timesteps = 5
            trace_dir = "/tmp/impulse-trace"
            log_level = "debug"
            "#,
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.vdd, 1.2);
        assert_eq!(c.freq_hz, 500e6);
        assert_eq!(c.engine, Engine::Lockstep);
        assert_eq!(c.comparator, ComparatorMode::MsbCout);
        assert_eq!(c.workers, 3);
        assert_eq!(c.batch, 16);
        assert_eq!(c.batch_deadline_us, 500);
        assert!(c.pipeline);
        assert!(c.adaptive);
        assert_eq!(c.listen.as_deref(), Some("127.0.0.1:7878"));
        assert_eq!(c.metrics_listen.as_deref(), Some("127.0.0.1:9200"));
        assert_eq!(c.queue_soft_limit, 64);
        assert_eq!(c.max_streams, 3);
        assert_eq!(c.stream_ttl_s, 15);
        assert_eq!(c.max_samples, 100);
        assert_eq!(c.timesteps, 5);
        assert_eq!(c.trace_dir.as_deref(), Some("/tmp/impulse-trace"));
        assert_eq!(c.log_level.as_deref(), Some("debug"));
        let t = c.telemetry_config();
        assert_eq!(t.vdd, 1.2);
        assert_eq!(t.freq_hz, 500e6);
        assert_eq!(t.queue_soft_limit, 64);
        let opts = c.server_options();
        assert_eq!(opts.workers, 3);
        assert_eq!(opts.batch_size, 16);
        assert_eq!(opts.batch_deadline, std::time::Duration::from_micros(500));
        assert!(opts.pipeline);
        assert!(opts.adaptive);
        assert_eq!(opts.max_streams, 3);
        assert_eq!(opts.stream_ttl, std::time::Duration::from_secs(15));
    }

    #[test]
    fn batch_defaults_are_unbatched() {
        let c = RunConfig::default();
        assert_eq!(c.batch, 1);
        assert!(!c.pipeline);
        assert!(!c.adaptive);
        assert!(c.listen.is_none());
        assert_eq!(c.server_options().batch_size, 1);
    }

    #[test]
    fn bad_enum_value_errors() {
        let doc = TomlDoc::parse("[macro]\nengine = \"warp\"\n").unwrap();
        assert!(RunConfig::default().apply(&doc).is_err());
    }

    #[test]
    fn bad_log_level_errors() {
        let doc = TomlDoc::parse("[run]\nlog_level = \"verbose\"\n").unwrap();
        assert!(RunConfig::default().apply(&doc).is_err());
    }
}
