//! A deliberately small TOML-subset parser: sections, scalar
//! key-values, comments. Enough for run configs without external deps.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

/// A parsed document: `section → key → value`. Keys before any section
/// header land in the "" section.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: unterminated section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key = value", lineno + 1);
            };
            let key = k.trim().to_string();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let value = parse_value(v.trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key)? {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn get_i64(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key)? {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key)? {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            bail!("unterminated string");
        }
        return Ok(TomlValue::Str(s[1..s.len() - 1].to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value '{s}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = TomlDoc::parse(
            r#"
            top = 1
            [a]
            s = "hello # not a comment"
            i = -42       # trailing comment
            f = 2.5
            b = true
            [b]
            x = 0.0
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_i64("", "top"), Some(1));
        assert_eq!(doc.get_str("a", "s"), Some("hello # not a comment"));
        assert_eq!(doc.get_i64("a", "i"), Some(-42));
        assert_eq!(doc.get_f64("a", "f"), Some(2.5));
        assert_eq!(doc.get_bool("a", "b"), Some(true));
        assert_eq!(doc.get_f64("a", "i"), Some(-42.0)); // int→float widening
        assert_eq!(doc.get("missing", "x"), None);
        assert_eq!(doc.sections().count(), 3);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("keyonly").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
        assert!(TomlDoc::parse("[]").is_err());
        assert!(TomlDoc::parse("k = what").is_err());
    }

    #[test]
    fn later_keys_override() {
        let doc = TomlDoc::parse("[a]\nx = 1\nx = 2\n").unwrap();
        assert_eq!(doc.get_i64("a", "x"), Some(2));
    }
}
