//! A self-describing fixed-width signed word.

use super::{fits, signed_range, wrap};
use std::fmt;

/// A signed two's-complement value carrying its bit width.
///
/// Used at module boundaries (mapper → macro, artifact loaders) where
/// mixing 6-bit weights and 11-bit potentials silently would be a bug.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct SignedWord {
    value: i64,
    bits: u32,
}

impl SignedWord {
    /// Construct, asserting the value fits the width.
    pub fn new(value: i64, bits: u32) -> Self {
        assert!(
            fits(value, bits),
            "value {value} does not fit in {bits}-bit signed word"
        );
        Self { value, bits }
    }

    /// Construct by wrapping the value into the width.
    pub fn wrapped(value: i64, bits: u32) -> Self {
        Self {
            value: wrap(value, bits),
            bits,
        }
    }

    /// A 6-bit weight word.
    pub fn weight(value: i64) -> Self {
        Self::new(value, super::W_BITS)
    }

    /// An 11-bit membrane-potential word.
    pub fn vmem(value: i64) -> Self {
        Self::new(value, super::V_BITS)
    }

    /// The numeric value.
    #[inline]
    pub fn value(&self) -> i64 {
        self.value
    }

    /// The bit width.
    #[inline]
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Wrapping addition at this word's width. Panics if widths differ.
    pub fn wrapping_add(&self, other: &SignedWord) -> SignedWord {
        assert_eq!(self.bits, other.bits, "width mismatch in wrapping_add");
        SignedWord::wrapped(self.value + other.value, self.bits)
    }

    /// Wrapping addition of a plain integer at this word's width.
    pub fn wrapping_add_i64(&self, rhs: i64) -> SignedWord {
        SignedWord::wrapped(self.value + rhs, self.bits)
    }

    /// The word's range `(min, max)`.
    pub fn range(&self) -> (i64, i64) {
        signed_range(self.bits)
    }

    /// Little-endian bits of the word.
    pub fn bits_le(&self) -> Vec<bool> {
        super::to_bits_le(self.value, self.bits)
    }
}

impl fmt::Debug for SignedWord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}i{}", self.value, self.bits)
    }
}

impl fmt::Display for SignedWord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_read() {
        let w = SignedWord::weight(-17);
        assert_eq!(w.value(), -17);
        assert_eq!(w.bits(), 6);
        let v = SignedWord::vmem(1000);
        assert_eq!(v.value(), 1000);
        assert_eq!(v.bits(), 11);
    }

    #[test]
    #[should_panic]
    fn out_of_range_panics() {
        SignedWord::weight(40);
    }

    #[test]
    fn wrapping_add_wraps() {
        let a = SignedWord::vmem(1000);
        let b = SignedWord::vmem(100);
        assert_eq!(a.wrapping_add(&b).value(), crate::bits::wrap11(1100));
        assert_eq!(a.wrapping_add_i64(23).value(), 1023);
        assert_eq!(a.wrapping_add_i64(24).value(), -1024);
    }

    #[test]
    fn bits_le_roundtrip() {
        for v in [-1024i64, -3, 0, 7, 1023] {
            let w = SignedWord::vmem(v);
            assert_eq!(crate::bits::from_bits_le(&w.bits_le()), v);
        }
    }

    #[test]
    fn display_and_debug() {
        let w = SignedWord::weight(-5);
        assert_eq!(format!("{w}"), "-5");
        assert_eq!(format!("{w:?}"), "-5i6");
    }
}
