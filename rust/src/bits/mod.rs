//! Fixed-width two's-complement arithmetic and bit-vector utilities.
//!
//! Everything the hardware does is defined over small signed fields:
//! 6-bit weights, 11-bit membrane potentials, with wraparound on
//! overflow (a ripple-carry adder simply drops the final carry). These
//! helpers centralize that arithmetic so the bit-level simulator, the
//! functional golden models, and the artifact loaders all share one
//! definition.

mod rng;
mod word;

pub use rng::XorShiftRng;
pub use word::SignedWord;

/// Number of bits in a stored weight (signed).
pub const W_BITS: u32 = 6;
/// Number of bits in a stored membrane potential (signed).
pub const V_BITS: u32 = 11;

/// Wrap an arbitrary integer into `bits`-bit two's complement
/// (interpreting the low `bits` bits as a signed value).
///
/// This is exactly what a `bits`-wide ripple-carry adder computes when
/// the final carry-out is dropped.
#[inline]
pub fn wrap(value: i64, bits: u32) -> i64 {
    debug_assert!(bits >= 1 && bits <= 63);
    let m = 1i64 << bits;
    let half = m >> 1;
    ((value % m) + m + half) % m - half
}

/// Wrap into the 11-bit membrane-potential range [-1024, 1023].
#[inline]
pub fn wrap11(value: i64) -> i64 {
    wrap(value, V_BITS)
}

/// Wrap into the 6-bit weight range [-32, 31].
#[inline]
pub fn wrap6(value: i64) -> i64 {
    wrap(value, W_BITS)
}

/// Inclusive range of a `bits`-bit signed field: `(min, max)`.
#[inline]
pub fn signed_range(bits: u32) -> (i64, i64) {
    let half = 1i64 << (bits - 1);
    (-half, half - 1)
}

/// True iff `value` is representable in `bits`-bit two's complement.
#[inline]
pub fn fits(value: i64, bits: u32) -> bool {
    let (lo, hi) = signed_range(bits);
    value >= lo && value <= hi
}

/// Encode a signed value into its `bits` low-order bits
/// (two's complement), as a little-endian bit vector (bit 0 = LSB).
pub fn to_bits_le(value: i64, bits: u32) -> Vec<bool> {
    debug_assert!(fits(value, bits), "{value} does not fit in {bits} bits");
    let u = (value as u64) & ((1u64 << bits) - 1);
    (0..bits).map(|i| (u >> i) & 1 == 1).collect()
}

/// Decode a little-endian bit vector as a signed two's-complement value.
pub fn from_bits_le(bits: &[bool]) -> i64 {
    debug_assert!(!bits.is_empty() && bits.len() <= 63);
    let mut u: u64 = 0;
    for (i, &b) in bits.iter().enumerate() {
        if b {
            u |= 1 << i;
        }
    }
    let n = bits.len() as u32;
    wrap(u as i64, n)
}

/// Sign-extend a `from`-bit signed value to `to` bits (identity on the
/// numeric value; asserts it fits).
#[inline]
pub fn sext(value: i64, from: u32, to: u32) -> i64 {
    debug_assert!(fits(value, from));
    debug_assert!(to >= from);
    value
}

/// Saturate (clamp) a value into a `bits`-bit signed range. The silicon
/// wraps rather than saturates; this exists for the quantizer paths that
/// deliberately clamp (weight quantization), never for V_MEM updates.
#[inline]
pub fn saturate(value: i64, bits: u32) -> i64 {
    let (lo, hi) = signed_range(bits);
    value.clamp(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrap_identity_in_range() {
        for v in -1024..=1023 {
            assert_eq!(wrap11(v), v);
        }
        for v in -32..=31 {
            assert_eq!(wrap6(v), v);
        }
    }

    #[test]
    fn wrap_overflow_wraps_around() {
        assert_eq!(wrap11(1024), -1024);
        assert_eq!(wrap11(-1025), 1023);
        assert_eq!(wrap11(2048), 0);
        assert_eq!(wrap11(2047), -1);
        assert_eq!(wrap6(32), -32);
        assert_eq!(wrap6(-33), 31);
    }

    #[test]
    fn wrap_matches_adder_semantics() {
        // wrap(a + b) must equal the n-bit ripple add with dropped carry.
        for a in [-1024i64, -512, -1, 0, 1, 511, 1023] {
            for b in [-1024i64, -33, -1, 0, 1, 32, 1023] {
                let m = 1u64 << V_BITS;
                let ua = (a as u64) & (m - 1);
                let ub = (b as u64) & (m - 1);
                let us = (ua + ub) & (m - 1); // drop carry
                let expect = from_bits_le(
                    &(0..V_BITS).map(|i| (us >> i) & 1 == 1).collect::<Vec<_>>(),
                );
                assert_eq!(wrap11(a + b), expect, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn bits_roundtrip() {
        for v in -1024..=1023 {
            assert_eq!(from_bits_le(&to_bits_le(v, V_BITS)), v);
        }
        for v in -32..=31 {
            assert_eq!(from_bits_le(&to_bits_le(v, W_BITS)), v);
        }
    }

    #[test]
    fn signed_range_bounds() {
        assert_eq!(signed_range(6), (-32, 31));
        assert_eq!(signed_range(11), (-1024, 1023));
        assert!(fits(31, 6));
        assert!(!fits(32, 6));
        assert!(fits(-1024, 11));
        assert!(!fits(-1025, 11));
    }

    #[test]
    fn saturate_clamps() {
        assert_eq!(saturate(100, 6), 31);
        assert_eq!(saturate(-100, 6), -32);
        assert_eq!(saturate(5, 6), 5);
    }
}
